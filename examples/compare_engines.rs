//! Native vs XLA engine comparison: same workload, identical discords,
//! side-by-side timings (the L3-vs-AOT sanity check for DESIGN.md §Perf).
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example compare_engines
//! ```

use std::time::Instant;

use palmad::analysis::report::{fmt_secs, Table};
use palmad::coordinator::config::{build_engine, EngineChoice, EngineOptions};
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::gen::registry;

fn main() -> anyhow::Result<()> {
    let spec = registry::dataset_prefix("ecg2", 12_000, 5)?;
    let series = spec.series;
    println!("workload: {series}, lengths 96..128, top-1");

    let cfg = MerlinConfig { min_l: 96, max_l: 128, top_k: 1, ..Default::default() };
    let mut table = Table::new("engine comparison", &["engine", "segn", "time", "discords", "tiles"]);
    let mut results = Vec::new();

    for choice in [EngineChoice::Native, EngineChoice::Xla] {
        let opts = EngineOptions { choice, segn: 256, ..Default::default() };
        let engine = match build_engine(&opts) {
            Ok(e) => e,
            Err(e) => {
                println!("skipping {choice:?}: {e}");
                continue;
            }
        };
        let t0 = Instant::now();
        let res = Merlin::new(&*engine, cfg.clone()).run(&series)?;
        let dt = t0.elapsed().as_secs_f64();
        let n: usize = res.lengths.iter().map(|l| l.discords.len()).sum();
        table.row(&[
            engine.name().to_string(),
            engine.segn().to_string(),
            fmt_secs(dt),
            n.to_string(),
            res.metrics.drag.tiles_computed.to_string(),
        ]);
        results.push(res);
    }
    print!("{}", table.to_text());

    if results.len() == 2 {
        // The engines must find the same discords (within f32 slack).
        for (a, b) in results[0].lengths.iter().zip(&results[1].lengths) {
            anyhow::ensure!(a.m == b.m);
            anyhow::ensure!(
                (a.discords[0].nn_dist - b.discords[0].nn_dist).abs()
                    < 1e-2 * (1.0 + a.discords[0].nn_dist),
                "m={}: native {} vs xla {}",
                a.m,
                a.discords[0].nn_dist,
                b.discords[0].nn_dist
            );
        }
        println!("engines agree on all {} lengths: OK", results[0].lengths.len());
    }
    Ok(())
}
