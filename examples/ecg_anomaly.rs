//! ECG anomaly discovery: a synthetic electrocardiogram with planted
//! premature ventricular contractions (PVC) — the motivating workload of
//! the discord literature (HOTSAX, MERLIN) — discovered by PALMAD and
//! cross-checked against HOTSAX and the matrix profile.
//!
//! ```bash
//! cargo run --release --example ecg_anomaly
//! ```

use std::time::Instant;

use palmad::analysis::report::{fmt_secs, Table};
use palmad::baselines::{hotsax, stomp};
use palmad::coordinator::config::{build_engine, EngineOptions};
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::gen::ecg::{beat_sample, ecg_with_pvc};

fn main() -> anyhow::Result<()> {
    let fs = 180.0;
    let bpm = 72.0;
    let pvc_beats = [37usize, 171];
    let series = ecg_with_pvc(30_000, fs, bpm, &pvc_beats, 11);
    let pvc_pos: Vec<usize> = pvc_beats.iter().map(|&b| beat_sample(fs, bpm, b)).collect();
    println!("series: {series}; planted PVCs near samples {pvc_pos:?}");

    let beat_len = (fs * 60.0 / bpm) as usize; // ~150 samples
    let near_pvc = |idx: usize, m: usize| {
        pvc_pos.iter().any(|&p| p < idx + m + beat_len && idx < p + 2 * beat_len)
    };

    // --- PALMAD: both PVCs via top-2, across a length range ---------------
    let engine = build_engine(&EngineOptions::default())?;
    let cfg = MerlinConfig { min_l: beat_len, max_l: beat_len + 16, top_k: 2, ..Default::default() };
    let t0 = Instant::now();
    let res = Merlin::new(&*engine, cfg).run(&series)?;
    let palmad_time = t0.elapsed().as_secs_f64();

    let mut table = Table::new("PALMAD discords (top-2 per length)", &["m", "idx", "nnDist", "near PVC"]);
    let mut hits = 0;
    let mut count = 0;
    for lr in &res.lengths {
        for d in &lr.discords {
            count += 1;
            let hit = near_pvc(d.idx, d.m);
            hits += hit as usize;
            if lr.m == beat_len {
                table.row(&[
                    d.m.to_string(),
                    d.idx.to_string(),
                    format!("{:.3}", d.nn_dist),
                    hit.to_string(),
                ]);
            }
        }
    }
    print!("{}", table.to_text());
    println!("PALMAD: {hits}/{count} discords at planted PVCs, {}", fmt_secs(palmad_time));

    // --- Cross-check: HOTSAX top-2 at the beat length ---------------------
    let t0 = Instant::now();
    let hs = hotsax::top_k_discords(&series.values, beat_len, 2, &hotsax::HotsaxConfig::default());
    let hotsax_time = t0.elapsed().as_secs_f64();
    for d in &hs {
        println!("HOTSAX:  m={} idx={} dist={:.3} near_pvc={}", d.m, d.idx, d.nn_dist, near_pvc(d.idx, d.m));
    }
    println!("HOTSAX time: {}", fmt_secs(hotsax_time));

    // --- Cross-check: matrix profile top-2 --------------------------------
    let t0 = Instant::now();
    let mp = stomp::top_k_discords(&series.values, beat_len, 2, 8);
    let mp_time = t0.elapsed().as_secs_f64();
    for d in &mp {
        println!("STOMP:   m={} idx={} dist={:.3} near_pvc={}", d.m, d.idx, d.nn_dist, near_pvc(d.idx, d.m));
    }
    println!("STOMP time: {}", fmt_secs(mp_time));

    // All three must agree on the top discord's location class.
    let palmad_top = res.lengths.iter().find(|l| l.m == beat_len).unwrap().discords[0];
    anyhow::ensure!(near_pvc(palmad_top.idx, beat_len), "PALMAD top discord not at a PVC");
    anyhow::ensure!(near_pvc(hs[0].idx, beat_len), "HOTSAX top discord not at a PVC");
    anyhow::ensure!(near_pvc(mp[0].idx, beat_len), "STOMP top discord not at a PVC");
    anyhow::ensure!(hits * 2 >= count, "PALMAD missed too many PVCs");
    println!("ecg_anomaly OK");
    Ok(())
}
