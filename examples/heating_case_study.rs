//! §5 case study, end to end: the PolyTER-like smart-heating trace
//! (one year, 4 samples/hour, n = 35040), arbitrary-length discord
//! discovery from 12 hours to 7 days, the discord heatmap (Eq. 11), and
//! the top-6 interesting discords (Eq. 12) — checked against the planted
//! ground truth (3 stuck sensors, 2 dropouts, 1 inefficient mode).
//!
//! This is the repo's end-to-end validation driver (EXPERIMENTS.md §E2E):
//! all three layers compose on a realistic workload.
//!
//! ```bash
//! cargo run --release --example heating_case_study            # native
//! PALMAD_ENGINE=xla cargo run --release --example heating_case_study
//! ```

use std::time::Instant;

use palmad::analysis::heatmap::Heatmap;
use palmad::analysis::image;
use palmad::analysis::ranking::top_k_interesting;
use palmad::analysis::report::{fmt_secs, Table};
use palmad::coordinator::config::{build_engine, EngineChoice, EngineOptions};
use palmad::coordinator::merlin::{Merlin, MerlinConfig, MerlinResult};
use palmad::gen::heating::{heating_year, HeatingAnomaly};

fn main() -> anyhow::Result<()> {
    let (series, planted) = heating_year(20260710);
    println!("case study series: {series}");
    for p in &planted {
        println!("  planted {:?} at {}..{}", p.kind, p.start, p.start + p.len);
    }

    let mut opts = EngineOptions::default();
    if std::env::var("PALMAD_ENGINE").as_deref() == Ok("xla") {
        opts.choice = EngineChoice::Xla;
    }
    let engine = build_engine(&opts)?;
    println!("engine: {} (segn={})", engine.name(), engine.segn());

    // Paper range: 12h..7d = 48..672 samples.  The heatmap needs per-length
    // survivor sets; a stride keeps the demo's wall-clock sane while
    // covering the whole range (EXPERIMENTS.md reports the full sweep).
    let (min_l, max_l) = (48usize, 672usize);
    let stride: usize = std::env::var("PALMAD_STRIDE").ok().and_then(|s| s.parse().ok()).unwrap_or(48);

    let t0 = Instant::now();
    let mut lengths = Vec::new();
    let mut m = min_l;
    while m <= max_l {
        let cfg = MerlinConfig { min_l: m, max_l: m, top_k: 0, ..Default::default() };
        let res = Merlin::new(&*engine, cfg).run(&series)?;
        lengths.extend(res.lengths);
        m += stride;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let res = MerlinResult { lengths, metrics: Default::default() };
    let total: usize = res.lengths.iter().map(|l| l.discords.len()).sum();
    println!(
        "\ndiscovered {total} discords over {} lengths in {}",
        res.lengths.len(),
        fmt_secs(elapsed)
    );

    // Heatmap (Eq. 11) + rendering.
    let hm = Heatmap::from_result(&res, series.len());
    image::render_heatmap(&hm, "heating_heatmap.ppm", 1600, 300)?;
    image::render_series(&series.values, "heating_series.pgm", 1600, 200)?;
    println!("wrote heating_heatmap.ppm, heating_series.pgm");

    // Top-6 interesting discords (Eq. 12) vs ground truth.
    let top = top_k_interesting(&hm, 6);
    let mut table = Table::new("top-6 interesting discords (Eq. 12)", &["rank", "idx", "m", "score", "matches planted"]);
    let mut hits = 0;
    for (k, r) in top.iter().enumerate() {
        let hit = planted.iter().find(|p| {
            let (a1, a2) = (p.start, p.start + p.len);
            let (b1, b2) = (r.idx, r.idx + r.m);
            a1 < b2 && b1 < a2
        });
        hits += hit.is_some() as usize;
        table.row(&[
            (k + 1).to_string(),
            r.idx.to_string(),
            r.m.to_string(),
            format!("{:.4}", r.score),
            hit.map(|p| format!("{:?}", p.kind)).unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{}", table.to_text());

    // The paper's qualitative claim: the top discords are the sensor
    // malfunctions and the inefficient heating period.
    let stuck_found = top.iter().any(|r| {
        planted.iter().any(|p| {
            p.kind == HeatingAnomaly::StuckSensor && p.start < r.idx + r.m && r.idx < p.start + p.len
        })
    });
    println!("\n{hits}/6 top discords match planted anomalies (stuck sensor found: {stuck_found})");
    anyhow::ensure!(hits >= 3, "case study failed to surface the planted anomalies");
    anyhow::ensure!(stuck_found, "stuck-sensor anomaly not in the top discords");
    println!("heating case study OK");
    Ok(())
}
