//! Quickstart: plant an anomaly in a random walk, discover it with
//! MERLIN over a range of lengths, and verify the hit.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use palmad::coordinator::config::{build_engine, EngineOptions};
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::core::series::TimeSeries;
use palmad::gen::inject::{inject, Injection, InjectionKind};
use palmad::gen::random_walk::random_walk;

fn main() -> anyhow::Result<()> {
    // 1. A 20k-sample random walk with one planted 96-sample anomaly.
    let mut series: TimeSeries = random_walk(20_000, 7);
    let planted = Injection { start: 13_500, len: 96, kind: InjectionKind::SpikeTrain };
    inject(&mut series, planted, 99);
    println!("series: {series}, planted anomaly at {}..{}", planted.start, planted.start + planted.len);

    // 2. An engine (native by default; `PALMAD_ENGINE=xla` uses the AOT
    //    Pallas artifacts after `make artifacts`).
    let mut opts = EngineOptions::default();
    if std::env::var("PALMAD_ENGINE").as_deref() == Ok("xla") {
        opts.choice = palmad::coordinator::config::EngineChoice::Xla;
    }
    let engine = build_engine(&opts)?;
    println!("engine: {} (segn={})", engine.name(), engine.segn());

    // 3. MERLIN: every discord length in [64, 96], top-1 each.
    let cfg = MerlinConfig { min_l: 64, max_l: 96, top_k: 1, ..Default::default() };
    let result = Merlin::new(&*engine, cfg).run(&series)?;

    // 4. Report and verify.
    let mut hits = 0;
    for lr in &result.lengths {
        let d = lr.discords[0];
        let hit = planted.hit(d.idx, d.m);
        hits += hit as usize;
        if lr.m % 8 == 0 {
            println!(
                "m={:3}  discord at {:5}  nnDist={:7.3}  r={:6.3}  {}",
                d.m,
                d.idx,
                d.nn_dist,
                lr.r_used,
                if hit { "HIT" } else { "miss" }
            );
        }
    }
    println!("\n{} / {} lengths hit the planted anomaly", hits, result.lengths.len());
    println!("metrics: {}", result.metrics);
    anyhow::ensure!(hits * 2 > result.lengths.len(), "discovery missed the planted anomaly");
    println!("quickstart OK");
    Ok(())
}
