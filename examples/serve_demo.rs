//! Job-service demo: start the TCP service in-process, submit jobs over
//! the wire protocol, stream results back, report service metrics.
//!
//! ```bash
//! cargo run --release --example serve_demo
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use palmad::coordinator::config::EngineOptions;
use palmad::coordinator::service::Service;

fn main() -> anyhow::Result<()> {
    // Service with 2 workers on an ephemeral port.
    let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 2)?;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    println!("service on {addr}");

    let svc = std::sync::Arc::new(svc);
    let svc_srv = std::sync::Arc::clone(&svc);
    let server = std::thread::spawn(move || -> anyhow::Result<()> {
        // Accept loop is part of Service::serve in production; the demo
        // drives the protocol handler directly so it can stop cleanly.
        for stream in listener.incoming() {
            let stream = stream?;
            if svc_srv.handle_conn_public(stream) {
                break;
            }
        }
        Ok(())
    });

    let mut conn = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut line = String::new();

    let mut send = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| -> anyhow::Result<String> {
        writeln!(conn, "{req}")?;
        line.clear();
        reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    };

    // Submit three jobs.
    let mut ids = Vec::new();
    for (gen, minl, maxl) in [("ecg2", 100, 110), ("respiration", 64, 72), ("power_demand", 96, 100)] {
        let resp = send(&mut conn, &mut reader, &format!("RUN gen={gen} n=6000 minl={minl} maxl={maxl} topk=1 seed=3"))?;
        println!("-> {resp}");
        let id: u64 = resp.rsplit(' ').next().unwrap().parse()?;
        ids.push((gen, id));
    }

    // Poll for completion, printing discord streams.
    for (gen, id) in ids {
        loop {
            let resp = send(&mut conn, &mut reader, &format!("STATUS {id}"))?;
            if resp.starts_with("OK DONE") {
                println!("job {id} ({gen}): {resp}");
                loop {
                    let mut l = String::new();
                    reader.read_line(&mut l)?;
                    if l.trim() == "END" {
                        break;
                    }
                    println!("  {}", l.trim());
                }
                break;
            } else if resp.starts_with("OK FAILED") {
                anyhow::bail!("job {id} failed: {resp}");
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    }

    let metrics = send(&mut conn, &mut reader, "METRICS")?;
    println!("{metrics}");
    anyhow::ensure!(metrics.contains("done=3"), "expected 3 completed jobs");

    let bye = send(&mut conn, &mut reader, "SHUTDOWN")?;
    println!("{bye}");
    server.join().unwrap()?;
    println!("serve_demo OK");
    Ok(())
}
