//! Scripted protocol client for the service smoke test
//! (`scripts/ci.sh --service-smoke`): drives a full session —
//! parse-time rejections, a DATA upload swept end-to-end, a large job
//! cancelled mid-sweep, METRICS introspection, graceful SHUTDOWN —
//! against a live `palmad serve`, exiting non-zero on any deviation.
//!
//! ```bash
//! target/release/palmad serve --addr 127.0.0.1:0 &  # prints LISTENING <addr>
//! target/release/examples/service_client <addr>
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, ensure, Context, Result};

struct Client {
    conn: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: &str) -> Result<Self> {
        let conn = TcpStream::connect(addr).context("connect")?;
        let reader = BufReader::new(conn.try_clone()?);
        Ok(Self { conn, reader })
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            bail!("server closed the connection");
        }
        Ok(line.trim().to_string())
    }

    fn send(&mut self, req: &str) -> Result<String> {
        writeln!(self.conn, "{req}")?;
        self.read_line()
    }

    fn expect_err(&mut self, req: &str, why: &str) -> Result<()> {
        let resp = self.send(req)?;
        ensure!(resp.starts_with("ERR"), "{why}: expected ERR, got {resp:?} for {req:?}");
        println!("  rejected as expected ({why}): {resp}");
        Ok(())
    }

    fn run(&mut self, req: &str) -> Result<u64> {
        let resp = self.send(req)?;
        ensure!(resp.starts_with("OK JOB "), "{req:?} -> {resp:?}");
        let id = resp.rsplit(' ').next().unwrap_or("").parse()?;
        println!("  submitted job {id}");
        Ok(id)
    }

    /// Poll STATUS until DONE; returns the number of DISCORD lines.
    fn wait_done(&mut self, id: u64) -> Result<usize> {
        for _ in 0..2_000 {
            let resp = self.send(&format!("STATUS {id}"))?;
            if resp.starts_with("OK DONE") {
                let mut count = 0;
                loop {
                    let l = self.read_line()?;
                    if l == "END" {
                        break;
                    }
                    ensure!(l.starts_with("DISCORD "), "{l:?}");
                    count += 1;
                }
                return Ok(count);
            }
            ensure!(
                resp.starts_with("OK QUEUED") || resp.starts_with("OK RUNNING"),
                "job {id}: {resp:?}"
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        bail!("job {id} did not finish in time");
    }
}

fn main() -> Result<()> {
    let addr = std::env::args().nth(1).context("usage: service_client <host:port>")?;
    let mut c = Client::connect(&addr)?;

    println!("== parse-time validation");
    c.expect_err("RUN gen=ecg2 n=3000 minl=64 maxl=32", "minl > maxl")?;
    c.expect_err("RUN gen=ecg2 n=3000 minl=2 maxl=32", "minl < 4")?;
    c.expect_err("RUN gen=ecg2 n=3000 minl=16 maxl=32 topk=0", "topk = 0")?;
    c.expect_err("RUN gen=ecg2 n=99999999999 minl=16 maxl=32", "absurd n")?;
    c.expect_err("RUN data=ghost minl=16 maxl=32", "unknown upload")?;

    println!("== DATA upload + sweep");
    writeln!(c.conn, "DATA name=smoke n=600")?;
    for chunk_start in (0..600).step_by(100) {
        let vals: Vec<String> = (chunk_start..chunk_start + 100)
            .map(|i| {
                let base = (i as f64 * 0.2).sin();
                let v = if (300..316).contains(&i) { base + 3.0 } else { base };
                format!("{v}")
            })
            .collect();
        writeln!(c.conn, "{}", vals.join(" "))?;
    }
    let resp = c.read_line()?;
    ensure!(resp == "OK DATA smoke n=600", "{resp:?}");
    let uploaded = c.run("RUN data=smoke minl=16 maxl=18 topk=1")?;
    let count = c.wait_done(uploaded)?;
    ensure!(count == 3, "expected 3 discords (one per length), got {count}");
    println!("  swept uploaded series: {count} discords");

    println!("== cancellation mid-sweep");
    let big = c.run("RUN gen=respiration n=8000 minl=32 maxl=400 seed=1")?;
    let resp = c.send(&format!("CANCEL {big}"))?;
    ensure!(resp == format!("OK CANCELLED {big}"), "{resp:?}");
    // The cancel lands at the next step boundary.
    for _ in 0..2_000 {
        let s = c.send(&format!("STATUS {big}"))?;
        if s == "OK CANCELLED" {
            break;
        }
        ensure!(s.starts_with("OK RUNNING") || s.starts_with("OK QUEUED"), "{s:?}");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    ensure!(c.send(&format!("STATUS {big}"))? == "OK CANCELLED", "cancel never landed");
    println!("  job {big} cancelled at a step boundary");

    println!("== metrics");
    let metrics = c.send("METRICS")?;
    println!("  {metrics}");
    let needles = [
        "done=1",
        "cancelled=1",
        "uploads=1",
        "sched(steps/preempts/leases)=",
        "lease(sticky/rebinds)=",
    ];
    for needle in needles {
        ensure!(metrics.contains(needle), "METRICS missing {needle:?}: {metrics}");
    }

    println!("== shutdown");
    let bye = c.send("SHUTDOWN")?;
    ensure!(bye == "OK BYE", "{bye:?}");
    println!("service_client OK");
    Ok(())
}
