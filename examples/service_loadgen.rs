//! Serving-path load generator: drives the evented front end with a
//! flooding tenant plus three weighted tenants, once under the flat
//! round-robin scheduler (the PR-5 baseline) and once under weighted
//! fair queueing, and writes the comparison to `BENCH_service.json`.
//!
//! ```bash
//! cargo run --release --example service_loadgen            # writes BENCH_service.json
//! cargo run --release --example service_loadgen -- out.json
//! ```
//!
//! Per scenario it reports:
//! - p50/p99 job completion latency (submit → DONE over the wire),
//!   overall and for the weighted ("paid") tenants alone — the number
//!   weighted fairness exists to protect;
//! - a Jain fairness index over per-tenant weighted step shares
//!   (`x_i = steps_i / weight_i`), sampled mid-run while every tenant
//!   still has queued work (at the end of the run everyone's work is
//!   done and every policy looks "fair");
//! - admission-control counters from a deliberate burst over
//!   `max_queued` (`rejected` must be non-zero — `scripts/ci.sh
//!   --service-smoke` asserts it).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};
use palmad::coordinator::config::EngineOptions;
use palmad::coordinator::queue::SchedPolicy;
use palmad::coordinator::service::{Service, ServiceConfig};

/// (tenant, weight, jobs): one low-weight tenant floods the queue; the
/// high-weight tenants submit a handful of jobs each and should not sit
/// behind the flood.
const TENANTS: &[(&str, u32, usize)] = &[
    ("flood", 1, 32),
    ("paid-a", 4, 4),
    ("paid-b", 4, 4),
    ("paid-c", 4, 4),
];
const MIN_L: usize = 16;
const MAX_L: usize = 31; // 16 sweep steps per job
const N: usize = 800;
/// Queue bound for the admission burst (phase 2); generous enough that
/// phase 1's 44 jobs are never rejected.
const MAX_QUEUED: usize = 64;
const BURST: usize = 200;

struct Client {
    conn: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        let conn = TcpStream::connect(addr).context("connect")?;
        let reader = BufReader::new(conn.try_clone()?);
        Ok(Self { conn, reader })
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            bail!("server closed the connection");
        }
        Ok(line.trim().to_string())
    }

    fn send(&mut self, req: &str) -> Result<String> {
        writeln!(self.conn, "{req}")?;
        self.read_line()
    }
}

struct JobTrack {
    id: u64,
    tenant: &'static str,
    submitted: Instant,
    latency: Option<Duration>,
}

struct Scenario {
    policy: &'static str,
    p50_ms: f64,
    p99_ms: f64,
    paid_p50_ms: f64,
    paid_p99_ms: f64,
    fairness_jain: f64,
    shares: Vec<(String, u32, u64)>,
    rejected: u64,
    budget_exhausted: u64,
    batched_rounds: u64,
    wall_ms: f64,
}

fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted_ms.len() as f64 - 1.0) * p).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

/// Jain fairness index over weighted shares `x_i = steps_i / weight_i`:
/// `J = (Σx)² / (n·Σx²)`, 1.0 = perfectly weight-proportional.
fn jain(shares: &[(String, u32, u64)]) -> f64 {
    let xs: Vec<f64> =
        shares.iter().map(|(_, w, s)| *s as f64 / (*w).max(1) as f64).collect();
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().sum();
    let sumsq: f64 = xs.iter().map(|x| x * x).sum();
    if sumsq == 0.0 {
        return f64::NAN;
    }
    (sum * sum) / (n * sumsq)
}

fn run_scenario(policy: SchedPolicy, label: &'static str) -> Result<Scenario> {
    let svc = Arc::new(Service::start_with(ServiceConfig {
        engine_opts: EngineOptions { segn: 64, ..Default::default() },
        workers: 2,
        sched_policy: policy,
        max_queued: MAX_QUEUED,
        ..Default::default()
    })?);
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let svc_srv = Arc::clone(&svc);
    let reactor = std::thread::spawn(move || {
        palmad::coordinator::frontend::serve_listener(&svc_srv, listener)
    });
    let mut c = Client::connect(addr)?;
    let started = Instant::now();

    // ---- Phase 1: the contended workload (flood first, then paid).
    let mut jobs: Vec<JobTrack> = Vec::new();
    for &(tenant, weight, count) in TENANTS {
        for j in 0..count {
            let req = format!(
                "RUN gen=ecg2 n={N} minl={MIN_L} maxl={MAX_L} topk=1 seed={} \
                 tenant={tenant} weight={weight}",
                j as u64 + 1
            );
            let resp = c.send(&req)?;
            ensure!(resp.starts_with("OK JOB "), "{req:?} -> {resp:?}");
            let id = resp.rsplit(' ').next().unwrap_or("").parse()?;
            jobs.push(JobTrack { id, tenant, submitted: Instant::now(), latency: None });
        }
    }

    // Mid-run share snapshot: once a quarter of the expected steps have
    // run, every tenant still has queued work, so the per-weight shares
    // reflect the scheduler's choices rather than the workload totals.
    let total_jobs = jobs.len();
    let expected_steps = (total_jobs * (MAX_L - MIN_L + 1)) as u64;
    let mut snapshot: Option<Vec<(String, u32, u64)>> = None;

    let mut done = 0usize;
    while done < total_jobs {
        if snapshot.is_none() && svc.sched_metrics().steps >= expected_steps / 4 {
            snapshot = Some(
                svc.tenant_shares()
                    .into_iter()
                    .map(|s| (s.name, s.weight, s.steps))
                    .collect(),
            );
        }
        let mut progressed = false;
        for job in jobs.iter_mut().filter(|j| j.latency.is_none()) {
            let resp = c.send(&format!("STATUS {}", job.id))?;
            if resp.starts_with("OK DONE") {
                loop {
                    if c.read_line()? == "END" {
                        break;
                    }
                }
                job.latency = Some(job.submitted.elapsed());
                done += 1;
                progressed = true;
            } else if resp.starts_with("OK FAILED") || resp.starts_with("OK CANCELLED") {
                bail!("job {} ({}) ended abnormally: {resp}", job.id, job.tenant);
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    let wall_ms = started.elapsed().as_secs_f64() * 1e3;
    let shares = snapshot.unwrap_or_else(|| {
        svc.tenant_shares().into_iter().map(|s| (s.name, s.weight, s.steps)).collect()
    });

    // ---- Phase 2: admission burst.  Fire BURST tiny submissions
    // without polling; everything past the queue bound answers
    // `ERR BUSY retry_after=...`.
    let mut busy = 0usize;
    for j in 0..BURST {
        let resp = c.send(&format!(
            "RUN gen=ecg2 n=400 minl=16 maxl=17 topk=1 seed={} tenant=burst",
            j as u64 + 1
        ))?;
        if resp.starts_with("ERR BUSY") {
            busy += 1;
            ensure!(resp.contains("retry_after="), "BUSY without retry hint: {resp}");
        } else {
            ensure!(resp.starts_with("OK JOB "), "{resp:?}");
        }
    }
    ensure!(busy > 0, "burst of {BURST} over max_queued={MAX_QUEUED} must trip ERR BUSY");

    let m = svc.sched_metrics();
    let bye = c.send("SHUTDOWN")?;
    ensure!(bye == "OK BYE", "{bye:?}");
    match reactor.join() {
        Ok(r) => r?,
        Err(_) => bail!("reactor thread panicked"),
    }

    let mut all: Vec<f64> = jobs
        .iter()
        .filter_map(|j| j.latency)
        .map(|d| d.as_secs_f64() * 1e3)
        .collect();
    all.sort_by(|a, b| a.total_cmp(b));
    let mut paid: Vec<f64> = jobs
        .iter()
        .filter(|j| j.tenant.starts_with("paid"))
        .filter_map(|j| j.latency)
        .map(|d| d.as_secs_f64() * 1e3)
        .collect();
    paid.sort_by(|a, b| a.total_cmp(b));

    Ok(Scenario {
        policy: label,
        p50_ms: percentile(&all, 0.50),
        p99_ms: percentile(&all, 0.99),
        paid_p50_ms: percentile(&paid, 0.50),
        paid_p99_ms: percentile(&paid, 0.99),
        fairness_jain: jain(&shares),
        shares,
        rejected: m.rejected,
        budget_exhausted: m.budget_exhausted,
        batched_rounds: m.batched_rounds,
        wall_ms,
    })
}

fn scenario_json(s: &Scenario) -> String {
    let shares: Vec<String> = s
        .shares
        .iter()
        .map(|(n, w, st)| format!("{{\"tenant\": {n:?}, \"weight\": {w}, \"steps\": {st}}}"))
        .collect();
    format!(
        "{{\n    \"policy\": {:?},\n    \"p50_ms\": {:.2},\n    \"p99_ms\": {:.2},\n    \
         \"paid_p50_ms\": {:.2},\n    \"paid_p99_ms\": {:.2},\n    \
         \"fairness_jain\": {:.4},\n    \"rejected\": {},\n    \
         \"budget_exhausted\": {},\n    \"batched_rounds\": {},\n    \
         \"wall_ms\": {:.1},\n    \"mid_run_shares\": [{}]\n  }}",
        s.policy,
        s.p50_ms,
        s.p99_ms,
        s.paid_p50_ms,
        s.paid_p99_ms,
        s.fairness_jain,
        s.rejected,
        s.budget_exhausted,
        s.batched_rounds,
        s.wall_ms,
        shares.join(", ")
    )
}

fn main() -> Result<()> {
    let out = std::env::args().nth(1).unwrap_or_else(|| "BENCH_service.json".into());

    println!("== baseline: flat round-robin");
    let before = run_scenario(SchedPolicy::RoundRobin, "round_robin")?;
    println!(
        "   p50 {:.1}ms p99 {:.1}ms | paid p99 {:.1}ms | jain {:.3} | rejected {}",
        before.p50_ms, before.p99_ms, before.paid_p99_ms, before.fairness_jain, before.rejected
    );

    println!("== weighted fair queueing");
    let after = run_scenario(SchedPolicy::WeightedFair, "weighted_fair")?;
    println!(
        "   p50 {:.1}ms p99 {:.1}ms | paid p99 {:.1}ms | jain {:.3} | rejected {} | \
         budget_exhausted {} | batched_rounds {}",
        after.p50_ms,
        after.p99_ms,
        after.paid_p99_ms,
        after.fairness_jain,
        after.rejected,
        after.budget_exhausted,
        after.batched_rounds
    );

    ensure!(
        after.fairness_jain >= before.fairness_jain - 0.05,
        "weighted fairness regressed: {:.3} -> {:.3}",
        before.fairness_jain,
        after.fairness_jain
    );
    ensure!(after.budget_exhausted > 0, "DRR budgets never rotated — weights inert?");

    let json = format!(
        "{{\n  \"bench\": \"service_loadgen\",\n  \"workload\": {{\n    \
         \"tenants\": [{}],\n    \"steps_per_job\": {},\n    \"n\": {},\n    \
         \"max_queued\": {},\n    \"burst\": {},\n    \"workers\": 2\n  }},\n  \
         \"before\": {},\n  \"after\": {}\n}}\n",
        TENANTS
            .iter()
            .map(|(n, w, c)| format!("{{\"tenant\": {n:?}, \"weight\": {w}, \"jobs\": {c}}}"))
            .collect::<Vec<_>>()
            .join(", "),
        MAX_L - MIN_L + 1,
        N,
        MAX_QUEUED,
        BURST,
        scenario_json(&before),
        scenario_json(&after)
    );
    std::fs::write(&out, json)?;
    println!("wrote {out}");
    println!("service_loadgen OK");
    Ok(())
}
