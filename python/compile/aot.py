"""AOT compiler: lower the layer-2 graphs to HLO *text* artifacts.

Run once at build time (`make artifacts`); python never appears on the
request path.  Interchange is HLO text, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly.

Usage:
    python -m compile.aot --out-dir ../artifacts [--quick]

`--quick` compiles only the smallest shape of each kind (used by the spike
smoke test and CI-ish fast paths).  The manifest is a line-oriented file so
the rust side needs no JSON parser:

    # kind segn mmax nmax file
    tile 64 128 0 tile_64x128.hlo.txt
    stats_init 0 0 16384 stats_init_16384.hlo.txt
"""

import argparse
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model, shapes  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_tile(segn: int, mmax: int) -> str:
    return to_hlo_text(jax.jit(model.tile_min).lower(*model.tile_min_specs(segn, mmax)))


def lower_stats_init(nmax: int) -> str:
    return to_hlo_text(jax.jit(model.stats_init).lower(*model.stats_init_specs(nmax)))


def lower_stats_update(nmax: int) -> str:
    return to_hlo_text(jax.jit(model.stats_update).lower(*model.stats_update_specs(nmax)))


def build(out_dir: str, quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = ["# kind segn mmax nmax file"]

    tile_shapes = shapes.TILE_SHAPES[:1] if quick else shapes.TILE_SHAPES
    stats_shapes = shapes.STATS_SHAPES[:1] if quick else shapes.STATS_SHAPES

    for segn, mmax in tile_shapes:
        name = f"tile_{segn}x{mmax}.hlo.txt"
        text = lower_tile(segn, mmax)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest.append(f"tile {segn} {mmax} 0 {name}")
        print(f"  tile {segn}x{mmax}: {len(text)} chars", file=sys.stderr)

    for nmax in stats_shapes:
        name = f"stats_init_{nmax}.hlo.txt"
        text = lower_stats_init(nmax)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest.append(f"stats_init 0 0 {nmax} {name}")
        print(f"  stats_init {nmax}: {len(text)} chars", file=sys.stderr)

        name = f"stats_update_{nmax}.hlo.txt"
        text = lower_stats_update(nmax)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest.append(f"stats_update 0 0 {nmax} {name}")
        print(f"  stats_update {nmax}: {len(text)} chars", file=sys.stderr)

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest) - 1} artifacts to {out_dir}", file=sys.stderr)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--quick", action="store_true")
    args = p.parse_args()
    build(args.out_dir, quick=args.quick)


if __name__ == "__main__":
    main()
