"""Pure-numpy oracles for the Pallas kernels and layer-2 functions.

Everything here is written in the most literal way possible (explicit
z-normalization, explicit pairwise loops) so that pytest can check the fast
paths against an implementation whose correctness is obvious.  Mirrors
Eqs. 4-8 of the paper.
"""

import numpy as np

SIGMA_FLOOR = 1e-8
FLAT_EPS = 1e-6


def _is_flat(w: np.ndarray) -> bool:
    w = np.asarray(w, dtype=np.float64)
    mu = w.mean()
    var = max((w * w).mean() - mu * mu, 0.0)
    sig = max(np.sqrt(var), SIGMA_FLOOR)
    return sig <= FLAT_EPS * max(abs(mu), 1.0)


def window_stats(t: np.ndarray, m: int):
    """Mean/std of every m-length window of ``t`` (Eq. 4), f64, floored sigma.

    Returns (mu, sig) of length len(t) - m + 1.
    """
    t = np.asarray(t, dtype=np.float64)
    n = len(t)
    cnt = n - m + 1
    mu = np.empty(cnt)
    sig = np.empty(cnt)
    for i in range(cnt):
        w = t[i : i + m]
        mu[i] = w.mean()
        var = max((w * w).mean() - mu[i] * mu[i], 0.0)
        sig[i] = max(np.sqrt(var), SIGMA_FLOOR)
    return mu, sig


def stats_update(t: np.ndarray, mu: np.ndarray, sig: np.ndarray, m: int):
    """Eqs. 7/8: stats for length m+1 from stats for length m (oracle form).

    mu/sig cover windows of length m; the result covers len(t) - m windows.
    """
    t = np.asarray(t, dtype=np.float64)
    cnt = len(t) - m
    mu2 = np.empty(cnt)
    sig2 = np.empty(cnt)
    for i in range(cnt):
        tn = t[i + m]
        mu2[i] = (m * mu[i] + tn) / (m + 1)
        var = (m / (m + 1)) * (sig[i] ** 2 + (mu[i] - tn) ** 2 / (m + 1))
        sig2[i] = max(np.sqrt(max(var, 0.0)), SIGMA_FLOOR)
    return mu2, sig2


def znorm(w: np.ndarray):
    w = np.asarray(w, dtype=np.float64)
    mu = w.mean()
    var = max((w * w).mean() - mu * mu, 0.0)
    sig = max(np.sqrt(var), SIGMA_FLOOR)
    return (w - mu) / sig


def ed2norm(a: np.ndarray, b: np.ndarray) -> float:
    """Squared Euclidean distance between z-normalized windows (Eq. 5/6),
    with the flat-window convention (flat/flat -> 0, flat/normal -> 2m)."""
    flat_a = _is_flat(a)
    flat_b = _is_flat(b)
    if flat_a and flat_b:
        return 0.0
    if flat_a or flat_b:
        return 2.0 * len(a)
    d = znorm(a) - znorm(b)
    return float(np.dot(d, d))


def qt_ref(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """QT[i, j] = dot(a[i], b[j]) — oracle for kernels.tile.qt_tile."""
    return np.asarray(a, np.float64) @ np.asarray(b, np.float64).T


def dist_tile_ref(
    t: np.ndarray,
    seg_start: int,
    chunk_start: int,
    segn: int,
    m: int,
    r2: float,
):
    """Oracle for the full layer-2 tile_min: brute-force distances between
    windows [seg_start, seg_start + segn) and [chunk_start, chunk_start +
    segn), with the |i-j| >= m exclusion zone and bounds validity.

    Returns (row_min, col_min, row_kill, col_kill), each length segn.
    Invalid/excluded pairs are +inf and never kill.
    """
    t = np.asarray(t, dtype=np.float64)
    n = len(t)
    nwin = n - m + 1
    row_min = np.full(segn, np.inf)
    col_min = np.full(segn, np.inf)
    row_kill = np.zeros(segn)
    col_kill = np.zeros(segn)
    for i in range(segn):
        gi = seg_start + i
        if gi >= nwin:
            continue
        for j in range(segn):
            gj = chunk_start + j
            if gj >= nwin or abs(gj - gi) < m:
                continue
            d = ed2norm(t[gi : gi + m], t[gj : gj + m])
            row_min[i] = min(row_min[i], d)
            col_min[j] = min(col_min[j], d)
            if d < r2:
                row_kill[i] = 1.0
                col_kill[j] = 1.0
    return row_min, col_min, row_kill, col_kill
