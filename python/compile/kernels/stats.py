"""Layer-1 Pallas kernel: recurrent subsequence statistics (Eqs. 7/8).

MERLIN re-runs DRAG once per subsequence length m in [minL, maxL].  The
paper's key arithmetic saving is that the rolling mean / standard deviation
vectors for length m+1 are an O(1) elementwise update of the length-m
vectors:

    mu'_i    = (m * mu_i + t_{i+m}) / (m + 1)                        (Eq. 7)
    sigma'^2 = m/(m+1) * (sigma_i^2 + (mu_i - t_{i+m})^2 / (m+1))    (Eq. 8)

This kernel applies the update elementwise over NMAX-length vectors in f64
(the cancellation in sigma^2 is catastrophic in f32 for large-magnitude
series such as random walks).  Layer 2 supplies ``t_next[i] = t[i + m]``
as a pre-gathered vector so the kernel itself is purely elementwise and
blocks trivially.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import shapes


def _update_kernel(m_ref, mu_ref, sig_ref, tn_ref, omu_ref, osig_ref):
    m = m_ref[0]
    mu = mu_ref[...]
    sig = sig_ref[...]
    tn = tn_ref[...]
    m1 = m + 1.0
    omu_ref[...] = (m * mu + tn) / m1
    var = (m / m1) * (sig * sig + (mu - tn) * (mu - tn) / m1)
    osig_ref[...] = jnp.maximum(jnp.sqrt(jnp.maximum(var, 0.0)), shapes.SIGMA_FLOOR)


@functools.partial(jax.jit, static_argnames=("block",))
def stats_update_pallas(m_f, mu, sig, t_next, *, block=None):
    """Apply Eqs. 7/8 elementwise.  All arrays f64[NMAX]; m_f f64[1]."""
    (n,) = mu.shape
    blk = min(block or shapes.STATS_BLOCK, n)
    assert n % blk == 0
    grid = (n // blk,)
    vec = pl.BlockSpec((blk,), lambda i: (i,))
    scal = pl.BlockSpec((1,), lambda i: (0,))
    out = jax.ShapeDtypeStruct((n,), jnp.float64)
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[scal, vec, vec, vec],
        out_specs=[vec, vec],
        out_shape=[out, out],
        interpret=True,
    )(m_f, mu, sig, t_next)
