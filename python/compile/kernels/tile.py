"""Layer-1 Pallas kernel: the distance-tile hot spot.

The paper's PD3 computes, per (segment, chunk) pair staged in GPU shared
memory, all pairwise z-normalized Euclidean distances between the segment's
subsequences and the chunk's subsequences (Alg. 3/4) via the scalar-product
form of the distance (Eq. 6) with an O(1) diagonal recurrence (Eq. 10).

TPU adaptation (see DESIGN.md §2): the serial diagonal recurrence starves a
systolic array, so the tile is recast as a *blocked masked matmul* —
windows are materialized, masked to the live length ``m`` and z-normalized
by layer 2; this kernel computes ``QT = A @ B^T`` with a 3-D grid
``(I, J, K)`` whose BlockSpecs express the HBM->VMEM staging schedule the
CUDA code expressed with thread blocks + shared memory.  The normalized
form makes the distance an affine function of QT:

    ED^2_norm(a_i, b_j) = 2 * (m - QT[i, j])

which layer 2 applies together with exclusion-zone / validity masking and
the row/col min + kill reductions.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU efficiency is estimated in DESIGN.md §9.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import shapes


def _qt_kernel(a_ref, b_ref, o_ref):
    """One (BI, BJ, BK) grid step: accumulate a QT block in VMEM.

    a_ref: (BI, BK) block of normalized segment windows
    b_ref: (BJ, BK) block of normalized chunk windows
    o_ref: (BI, BJ) accumulator block (revisited across the K grid axis)
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "block_k"))
def qt_tile(a, b, *, block_i=None, block_j=None, block_k=None):
    """QT[i, j] = dot(a[i, :], b[j, :]) via the blocked Pallas kernel.

    a: f32[SEGN_A, MMAX] — masked, z-normalized segment windows
    b: f32[SEGN_B, MMAX] — masked, z-normalized chunk windows
    returns f32[SEGN_A, SEGN_B]
    """
    na, mm = a.shape
    nb, mmb = b.shape
    assert mm == mmb, (a.shape, b.shape)
    bi = min(block_i or shapes.TILE_BLOCK_I, na)
    bj = min(block_j or shapes.TILE_BLOCK_J, nb)
    bk = min(block_k or shapes.TILE_BLOCK_K, mm)
    assert na % bi == 0 and nb % bj == 0 and mm % bk == 0

    grid = (na // bi, nb // bj, mm // bk)
    return pl.pallas_call(
        _qt_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bj, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((na, nb), jnp.float32),
        interpret=True,
    )(a, b)
