"""Layer-2 JAX compute graphs, AOT-lowered to HLO for the rust coordinator.

Three entry points (see DESIGN.md §3):

- ``tile_min``     — the PD3 inner loop: all pairwise distances between one
                     segment and one chunk of subsequences (calls the L1
                     Pallas tile kernel), reduced to per-row/col minima and
                     r-threshold kill flags.  One compiled executable per
                     (SEGN, MMAX) serves *every* subsequence length
                     m <= MMAX through masking — MERLIN's length sweep never
                     recompiles.
- ``stats_init``   — rolling mean/std of all m-length windows (Eq. 4) via a
                     f64 cumulative-sum scan.
- ``stats_update`` — the paper's recurrent update m -> m+1 (Eqs. 7/8), via
                     the L1 elementwise Pallas kernel.

All dynamic quantities (m, global offsets, validity counts, threshold) are
runtime scalars so shapes stay static for AOT.
"""

import jax
import jax.numpy as jnp

from . import shapes
from .kernels import stats as stats_kernels
from .kernels import tile as tile_kernels


def _windows(src, segn: int, mmax: int):
    """Materialize the [segn, mmax] window matrix of a source slice.

    Indices are built from iotas (not constants) so the lowered HLO stays
    small: HLO text with a SEGNxMMAX constant gather index would be MBs.
    """
    i = jnp.arange(segn, dtype=jnp.int32)[:, None]
    k = jnp.arange(mmax, dtype=jnp.int32)[None, :]
    return src[i + k]


def _norm_windows(src, mu, sig, m, segn: int, mmax: int):
    """Masked, z-normalized window matrix.

    Positions k >= m are zeroed *after* normalization so each row is the
    z-normalized live window padded with zeros; dot products of two such
    rows equal m * pearson(a, b), giving ED^2 = 2 * (m - QT).
    """
    a = _windows(src, segn, mmax)
    mask = (jnp.arange(mmax, dtype=jnp.int32)[None, :] < m).astype(jnp.float32)
    return (a - mu[:, None]) / sig[:, None] * mask


def tile_min(seg_src, chunk_src, mu_a, sig_a, mu_b, sig_b, m, delta, na, nb, r2):
    """Distances between a segment's and a chunk's subsequences, reduced.

    seg_src   f32[SEGN+MMAX-1]  raw series slice starting at the segment's
                                first subsequence
    chunk_src f32[SEGN+MMAX-1]  raw slice starting at the chunk's first
                                subsequence
    mu_a, sig_a f32[SEGN]       per-window stats for the segment rows
    mu_b, sig_b f32[SEGN]       per-window stats for the chunk columns
    m     i32 scalar            live subsequence length (m <= MMAX)
    delta i32 scalar            chunk_global_start - seg_global_start
    na/nb i32 scalar            number of valid windows in segment / chunk
    r2    f32 scalar            squared range-discord threshold

    Returns (row_min, col_min, row_kill, col_kill), each f32[SEGN]:
    row = segment subsequences, col = chunk subsequences.  Pairs inside the
    exclusion zone |gj - gi| < m or out of bounds are +inf / never kill.
    """
    segn = mu_a.shape[0]
    mmax = seg_src.shape[0] - segn + 1
    a = _norm_windows(seg_src, mu_a, sig_a, m, segn, mmax)
    b = _norm_windows(chunk_src, mu_b, sig_b, m, segn, mmax)

    qt = tile_kernels.qt_tile(a, b)
    m_f = m.astype(jnp.float32)
    dist = jnp.clip(2.0 * (m_f - qt), 0.0, 4.0 * m_f)

    # Flat-window convention (see shapes.FLAT_EPS): the normalized windows
    # of a constant subsequence are numerical garbage, so overwrite.  The
    # test is relative to |mu| (sliding-stat drift scales with E[x^2]).
    flat_a = (sig_a <= shapes.FLAT_EPS * jnp.maximum(jnp.abs(mu_a), 1.0))[:, None]
    flat_b = (sig_b <= shapes.FLAT_EPS * jnp.maximum(jnp.abs(mu_b), 1.0))[None, :]
    dist = jnp.where(flat_a & flat_b, 0.0, dist)
    dist = jnp.where(flat_a ^ flat_b, 2.0 * m_f, dist)

    i = jnp.arange(segn, dtype=jnp.int32)
    gi = i[:, None]
    gj = delta + i[None, :]
    bad = (jnp.abs(gj - gi) < m) | (i[:, None] >= na) | (i[None, :] >= nb)
    dist = jnp.where(bad, jnp.inf, dist)

    row_min = jnp.min(dist, axis=1)
    col_min = jnp.min(dist, axis=0)
    kill = dist < r2
    row_kill = jnp.any(kill, axis=1).astype(jnp.float32)
    col_kill = jnp.any(kill, axis=0).astype(jnp.float32)
    return row_min, col_min, row_kill, col_kill


def stats_init(t, m):
    """Rolling mean/std (Eq. 4) of every m-window of t, f64 cumsum scan.

    t f32[NMAX], m i32 scalar -> (mu, sig) f64[NMAX].  Entries at positions
    i > NMAX - m are padding garbage the rust runtime never reads.
    """
    nmax = t.shape[0]
    td = t.astype(jnp.float64)
    z = jnp.zeros((1,), jnp.float64)
    c1 = jnp.concatenate([z, jnp.cumsum(td)])
    c2 = jnp.concatenate([z, jnp.cumsum(td * td)])
    i = jnp.arange(nmax, dtype=jnp.int32)
    j = jnp.minimum(i + m, nmax)
    m_f = m.astype(jnp.float64)
    s1 = c1[j] - c1[i]
    s2 = c2[j] - c2[i]
    mu = s1 / m_f
    var = jnp.maximum(s2 / m_f - mu * mu, 0.0)
    sig = jnp.maximum(jnp.sqrt(var), shapes.SIGMA_FLOOR)
    return mu, sig


def stats_update(t, mu, sig, m):
    """Eqs. 7/8 recurrent update, delegating to the L1 Pallas kernel.

    t f32[NMAX], mu/sig f64[NMAX] (length-m stats), m i32 scalar
    -> (mu', sig') f64[NMAX] (length-(m+1) stats).
    """
    nmax = t.shape[0]
    td = t.astype(jnp.float64)
    i = jnp.arange(nmax, dtype=jnp.int32)
    t_next = td[jnp.minimum(i + m, nmax - 1)]
    m_f = m.astype(jnp.float64).reshape((1,))
    mu2, sig2 = stats_kernels.stats_update_pallas(m_f, mu, sig, t_next)
    return mu2, sig2


def tile_min_specs(segn: int, mmax: int):
    """ShapeDtypeStructs for lowering tile_min at a given (SEGN, MMAX)."""
    src = jax.ShapeDtypeStruct((shapes.tile_src_len(segn, mmax),), jnp.float32)
    vec = jax.ShapeDtypeStruct((segn,), jnp.float32)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    f32 = jax.ShapeDtypeStruct((), jnp.float32)
    return (src, src, vec, vec, vec, vec, i32, i32, i32, i32, f32)


def stats_init_specs(nmax: int):
    t = jax.ShapeDtypeStruct((nmax,), jnp.float32)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    return (t, i32)


def stats_update_specs(nmax: int):
    t = jax.ShapeDtypeStruct((nmax,), jnp.float32)
    v = jax.ShapeDtypeStruct((nmax,), jnp.float64)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    return (t, v, v, i32)
