"""Artifact shape grid shared by the AOT compiler, tests, and manifest.

The rust runtime picks the smallest bucket that fits a request, so the grid
below defines the only shapes ever compiled.  `SEGN` is the tile edge (the
paper's `segN`, the number of subsequences a GPU thread block owns), `MMAX`
the padded window width (every subsequence length `m <= MMAX` is served by
the same executable through masking), `NMAX` the padded time-series length
for the stats kernels.
"""

# (SEGN, MMAX) pairs for the distance-tile kernel.
TILE_SHAPES = [
    (64, 128),
    (128, 128),
    (256, 128),
    (512, 128),
    (64, 512),
    (128, 512),
    (256, 512),
    (512, 512),
]

# NMAX buckets for stats_init / stats_update.
STATS_SHAPES = [16384, 65536, 262144, 1048576]

# Pallas block edges for the tile kernel (rows, cols, K-depth).
TILE_BLOCK_I = 64
TILE_BLOCK_J = 64
TILE_BLOCK_K = 128

# Pallas block length for the elementwise stats-update kernel.
STATS_BLOCK = 4096

# Floor applied to every standard deviation so constant (stuck-sensor)
# windows produce finite, stable distances.  Must match
# `rust/src/core/stats.rs::SIGMA_FLOOR`.
SIGMA_FLOOR = 1e-8

# Windows with sigma <= FLAT_EPS * max(|mu|, 1) are treated as constant
# ("flat"): the correlation form of the distance is numerically meaningless
# for them, so semantics are pinned instead (flat-vs-flat -> 0,
# flat-vs-normal -> 2m).  The test is relative to the mean because sliding
# statistics carry rounding drift proportional to eps * E[x^2].
# Must match `rust/src/core/distance.rs::FLAT_EPS` / `is_flat`.
FLAT_EPS = 1e-6


def tile_src_len(segn: int, mmax: int) -> int:
    """Length of the raw source slice backing SEGN windows of width MMAX."""
    return segn + mmax - 1
