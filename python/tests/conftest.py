import os
import sys

import jax

# x64 must be enabled before any jax computation: the stats kernels are f64.
jax.config.update("jax_enable_x64", True)

# Make `compile` importable when pytest runs from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
