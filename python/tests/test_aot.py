"""AOT lowering sanity: HLO text artifacts parse, stay reasonably small
(no giant constants), and the manifest format matches what the rust
runtime parses."""

import os

from compile import aot, shapes


class TestLowering:
    def test_tile_hlo_has_no_giant_constants(self):
        text = aot.lower_tile(64, 128)
        assert "ENTRY" in text
        # Window indices must come from iotas, not materialized constants:
        # a 64x128 i32 constant would serialize to >100KB of text.
        assert len(text) < 200_000, f"HLO text suspiciously large: {len(text)}"
        assert "iota" in text

    def test_tile_hlo_contains_dot(self):
        text = aot.lower_tile(64, 128)
        assert "dot(" in text or "dot " in text, "pallas matmul should lower to HLO dot"

    def test_stats_init_lowering(self):
        text = aot.lower_stats_init(16384)
        assert "ENTRY" in text
        assert "f64" in text, "stats must compute in f64"

    def test_stats_update_lowering(self):
        text = aot.lower_stats_update(16384)
        assert "ENTRY" in text
        assert "f64" in text

    def test_quick_build_writes_manifest(self, tmp_path):
        out = tmp_path / "artifacts"
        aot.build(str(out), quick=True)
        manifest = (out / "manifest.txt").read_text().strip().splitlines()
        body = [l for l in manifest if not l.startswith("#")]
        assert len(body) == 3  # 1 tile + stats_init + stats_update
        for line in body:
            fields = line.split()
            assert len(fields) == 5
            kind, segn, mmax, nmax, fname = fields
            assert kind in ("tile", "stats_init", "stats_update")
            assert os.path.exists(out / fname)
            int(segn), int(mmax), int(nmax)

    def test_shape_grid_is_consistent(self):
        for segn, mmax in shapes.TILE_SHAPES:
            assert segn % shapes.TILE_BLOCK_I == 0 or segn < shapes.TILE_BLOCK_I
            assert mmax % shapes.TILE_BLOCK_K == 0 or mmax < shapes.TILE_BLOCK_K
        for nmax in shapes.STATS_SHAPES:
            assert nmax % shapes.STATS_BLOCK == 0
