"""Layer-2 `tile_min` vs the brute-force oracle, including the exclusion
zone, validity masking, flat-window convention, and kill flags."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model, shapes
from compile.kernels import ref

SEGN, MMAX = 64, 128
SRC = shapes.tile_src_len(SEGN, MMAX)


def _run_tile(t, seg_start, chunk_start, m, r2, segn=SEGN, mmax=MMAX):
    t = np.asarray(t, np.float64)
    n = len(t)
    nwin = n - m + 1
    src = shapes.tile_src_len(segn, mmax)

    def slc(s):
        out = np.zeros(src, np.float32)
        if s < n:
            avail = min(src, n - s)
            out[:avail] = t[s : s + avail]
        return out

    mu, sig = ref.window_stats(t, m)

    def stat(s):
        muo = np.zeros(segn, np.float32)
        sio = np.ones(segn, np.float32)
        avail = max(0, min(segn, nwin - s))
        muo[:avail] = mu[s : s + avail]
        sio[:avail] = sig[s : s + avail]
        return muo, sio

    mu_a, sig_a = stat(seg_start)
    mu_b, sig_b = stat(chunk_start)
    na = max(0, min(segn, nwin - seg_start))
    nb = max(0, min(segn, nwin - chunk_start))
    out = model.tile_min(
        jnp.asarray(slc(seg_start)),
        jnp.asarray(slc(chunk_start)),
        jnp.asarray(mu_a),
        jnp.asarray(sig_a),
        jnp.asarray(mu_b),
        jnp.asarray(sig_b),
        jnp.int32(m),
        jnp.int32(chunk_start - seg_start),
        jnp.int32(na),
        jnp.int32(nb),
        jnp.float32(r2),
    )
    return [np.asarray(x) for x in out]


def _check(t, seg_start, chunk_start, m, r2, segn=SEGN, mmax=MMAX, tol=2e-3):
    rm, cm, rk, ck = _run_tile(t, seg_start, chunk_start, m, r2, segn, mmax)
    rm0, cm0, rk0, ck0 = ref.dist_tile_ref(t, seg_start, chunk_start, segn, m, r2)
    assert np.array_equal(np.isinf(rm), np.isinf(rm0)), "row finiteness"
    assert np.array_equal(np.isinf(cm), np.isinf(cm0)), "col finiteness"
    fin = np.isfinite(rm0)
    np.testing.assert_allclose(rm[fin], rm0[fin], rtol=tol, atol=tol * m)
    fin = np.isfinite(cm0)
    np.testing.assert_allclose(cm[fin], cm0[fin], rtol=tol, atol=tol * m)
    # Kill flags: compare only where the oracle distance is clearly away
    # from the threshold (f32 slack near the boundary is legitimate).
    margin = 1e-3 * (1.0 + r2)
    for k in range(segn):
        if np.isfinite(rm0[k]) and abs(rm0[k] - r2) > margin:
            assert rk[k] == rk0[k], f"row_kill {k}: min {rm0[k]} r2 {r2}"
        if np.isfinite(cm0[k]) and abs(cm0[k] - r2) > margin:
            assert ck[k] == ck0[k], f"col_kill {k}"


def _walk(n, seed):
    return np.cumsum(np.random.default_rng(seed).normal(size=n))


class TestTileMin:
    def test_disjoint_pair(self):
        _check(_walk(600, 0), 10, 200, 50, 30.0)

    def test_self_tile_exclusion(self):
        _check(_walk(500, 1), 64, 64, 40, 20.0)

    def test_partial_overlap(self):
        _check(_walk(500, 2), 50, 80, 40, 20.0)

    def test_left_chunk(self):
        _check(_walk(500, 3), 256, 0, 40, 25.0)

    def test_ragged_tail(self):
        t = _walk(260, 4)
        _check(t, 180, 100, 30, 15.0)

    def test_flat_regions(self):
        t = _walk(600, 5)
        t[250:420] = 13.0
        _check(t, 192, 320, 40, 10.0)

    def test_all_flat_series(self):
        t = np.full(400, 2.5)
        rm, cm, rk, ck = _run_tile(t, 0, 128, 16, 1.0)
        # Every valid pair is flat-flat -> 0 distance, killed by r2=1.
        assert np.all(rm[np.isfinite(rm)] == 0.0)
        assert np.all(rk[: 64] == 1.0)

    def test_max_m_equals_mmax(self):
        _check(_walk(800, 6), 0, 300, MMAX, 60.0)

    def test_r2_zero_kills_nothing(self):
        t = _walk(500, 7)
        _, _, rk, ck = _run_tile(t, 0, 200, 30, 0.0)
        assert not rk.any() and not ck.any()

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(3, MMAX),
        seg=st.integers(0, 400),
        delta=st.integers(-300, 300),
        r2=st.sampled_from([0.5, 5.0, 20.0, 100.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, m, seg, delta, r2, seed):
        t = _walk(520, seed)
        chunk = seg + delta
        if chunk < 0:
            chunk = 0
        _check(t, seg, chunk, m, r2)

    @settings(max_examples=10, deadline=None)
    @given(
        segn=st.sampled_from([16, 32, 64]),
        mmax=st.sampled_from([32, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_shape_grid_sweep(self, segn, mmax, seed):
        t = _walk(400, seed)
        m = mmax // 2
        _check(t, 0, segn + m, m, 10.0, segn=segn, mmax=mmax)
