"""Stats kernels (Eq. 4 init, Eqs. 7/8 recurrent update) vs oracles."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model, shapes
from compile.kernels import ref
from compile.kernels.stats import stats_update_pallas


def _series(n, seed, kind="walk"):
    rng = np.random.default_rng(seed)
    if kind == "walk":
        return np.cumsum(rng.normal(size=n))
    if kind == "large":
        return rng.normal(size=n) * 1e3 + 1e4
    return rng.normal(size=n)


class TestStatsInit:
    def _run(self, t, m, nmax=2048):
        tp = np.zeros(nmax, np.float32)
        tp[: len(t)] = t
        mu, sig = model.stats_init(jnp.asarray(tp), jnp.int32(m))
        nwin = len(t) - m + 1
        return np.asarray(mu)[:nwin], np.asarray(sig)[:nwin]

    def test_matches_oracle(self):
        t = _series(1500, 0)
        mu, sig = self._run(t, 100)
        mu0, sig0 = ref.window_stats(t.astype(np.float32).astype(np.float64), 100)
        np.testing.assert_allclose(mu, mu0, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(sig, sig0, rtol=1e-6, atol=1e-9)

    def test_constant_series_floors_sigma(self):
        t = np.full(500, 7.25)
        mu, sig = self._run(t, 32)
        np.testing.assert_allclose(mu, 7.25, rtol=1e-12)
        assert np.all(sig == shapes.SIGMA_FLOOR)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(50, 1200),
        m=st.integers(3, 48),
        kind=st.sampled_from(["walk", "large", "noise"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_sweep(self, n, m, kind, seed):
        t = _series(n, seed, kind)
        mu, sig = self._run(t, m)
        mu0, sig0 = ref.window_stats(t.astype(np.float32).astype(np.float64), m)
        np.testing.assert_allclose(mu, mu0, rtol=1e-9, atol=1e-7)
        np.testing.assert_allclose(sig, sig0, rtol=1e-5, atol=1e-8)


class TestStatsUpdate:
    def _run_update(self, t, mu, sig, m, nmax=2048):
        tp = np.zeros(nmax, np.float32)
        tp[: len(t)] = t
        mup = np.zeros(nmax)
        sigp = np.ones(nmax)
        mup[: len(mu)] = mu
        sigp[: len(sig)] = sig
        mu2, sig2 = model.stats_update(
            jnp.asarray(tp), jnp.asarray(mup), jnp.asarray(sigp), jnp.int32(m)
        )
        nwin = len(t) - m
        return np.asarray(mu2)[:nwin], np.asarray(sig2)[:nwin]

    def test_one_step_matches_oracle(self):
        t = _series(800, 3).astype(np.float32).astype(np.float64)
        m = 64
        mu, sig = ref.window_stats(t, m)
        mu2, sig2 = self._run_update(t, mu, sig, m)
        mu2_ref, sig2_ref = ref.stats_update(t, mu, sig, m)
        np.testing.assert_allclose(mu2, mu2_ref, rtol=1e-9)
        np.testing.assert_allclose(sig2, sig2_ref, rtol=1e-6, atol=1e-9)
        # And equals fresh stats at m+1.
        mu_f, sig_f = ref.window_stats(t, m + 1)
        np.testing.assert_allclose(mu2, mu_f, rtol=1e-9)
        np.testing.assert_allclose(sig2, sig_f, rtol=1e-5, atol=1e-8)

    def test_chained_updates_stay_exact(self):
        """Apply the recurrence many times; drift must stay tiny (this is
        the paper's central arithmetic claim)."""
        t = _series(600, 4).astype(np.float32).astype(np.float64)
        m0 = 16
        mu, sig = ref.window_stats(t, m0)
        for step in range(40):
            m = m0 + step
            mu, sig = self._run_update(t, mu, sig, m)
        mu_f, sig_f = ref.window_stats(t, m0 + 40)
        np.testing.assert_allclose(mu, mu_f, rtol=1e-8)
        np.testing.assert_allclose(sig, sig_f, rtol=1e-5, atol=1e-8)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(60, 800),
        m=st.integers(3, 40),
        kind=st.sampled_from(["walk", "large"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_sweep(self, n, m, kind, seed):
        t = _series(n, seed, kind).astype(np.float32).astype(np.float64)
        mu, sig = ref.window_stats(t, m)
        mu2, sig2 = self._run_update(t, mu, sig, m)
        mu_f, sig_f = ref.window_stats(t, m + 1)
        np.testing.assert_allclose(mu2, mu_f, rtol=1e-7, atol=1e-7)
        np.testing.assert_allclose(sig2, sig_f, rtol=1e-5, atol=1e-7)


class TestPallasUpdateKernel:
    def test_blocks_partition_correctly(self):
        n = 4096
        rng = np.random.default_rng(5)
        mu = rng.normal(size=n)
        sig = np.abs(rng.normal(size=n)) + 0.1
        tn = rng.normal(size=n)
        m = np.array([17.0])
        for block in (512, 1024, 4096):
            mu2, sig2 = stats_update_pallas(
                jnp.asarray(m), jnp.asarray(mu), jnp.asarray(sig), jnp.asarray(tn), block=block
            )
            mu_ref = (17.0 * mu + tn) / 18.0
            var_ref = (17.0 / 18.0) * (sig**2 + (mu - tn) ** 2 / 18.0)
            sig_ref = np.maximum(np.sqrt(var_ref), shapes.SIGMA_FLOOR)
            np.testing.assert_allclose(np.asarray(mu2), mu_ref, rtol=1e-12)
            np.testing.assert_allclose(np.asarray(sig2), sig_ref, rtol=1e-12)
