"""Layer-1 Pallas tile kernel vs the numpy oracle.

The kernel is a blocked masked matmul; correctness here is the core signal
that the MXU-shaped reformulation of the paper's Eq. 10 recurrence is
exact.  Hypothesis sweeps shapes and block configurations.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.tile import qt_tile


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=shape) * scale).astype(np.float32)


class TestQtTile:
    def test_matches_oracle_default_blocks(self):
        a = _rand((128, 128), 0)
        b = _rand((128, 128), 1)
        got = np.asarray(qt_tile(a, b))
        want = ref.qt_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    def test_rectangular(self):
        a = _rand((64, 512), 2)
        b = _rand((128, 512), 3)
        got = np.asarray(qt_tile(a, b))
        np.testing.assert_allclose(got, ref.qt_ref(a, b), rtol=1e-5, atol=1e-3)

    def test_identity_rows(self):
        a = np.eye(64, 128, dtype=np.float32)
        got = np.asarray(qt_tile(a, a))
        np.testing.assert_allclose(got, np.eye(64, dtype=np.float32), atol=1e-6)

    def test_zero_inputs(self):
        a = np.zeros((64, 128), np.float32)
        got = np.asarray(qt_tile(a, a))
        assert np.all(got == 0)

    @settings(max_examples=20, deadline=None)
    @given(
        bi=st.sampled_from([16, 32, 64]),
        bj=st.sampled_from([16, 32, 64]),
        bk=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_block_shape_invariance(self, bi, bj, bk, seed):
        """The K-accumulating grid must give the same answer for any
        block decomposition."""
        a = _rand((64, 128), seed)
        b = _rand((64, 128), seed + 1)
        got = np.asarray(qt_tile(a, b, block_i=bi, block_j=bj, block_k=bk))
        want = ref.qt_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        rows=st.sampled_from([16, 48, 64, 96]),
        k=st.sampled_from([32, 128, 256]),
        scale=st.sampled_from([1e-3, 1.0, 1e3]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_shape_and_scale_sweep(self, rows, k, scale, seed):
        a = _rand((rows, k), seed, scale)
        b = _rand((rows, k), seed + 7, scale)
        bi = 16 if rows % 16 == 0 else rows
        got = np.asarray(qt_tile(a, b, block_i=bi, block_j=bi, block_k=min(32, k)))
        want = ref.qt_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5 * scale * scale * k)

    def test_rejects_mismatched_k(self):
        a = _rand((64, 128), 0)
        b = _rand((64, 256), 1)
        with pytest.raises(AssertionError):
            qt_tile(a, b)
