//! Ablation: PD3's pruning machinery — segment early-stop (Alg. 3 l.14)
//! and direct vs deferred neighbor kills (the paper's `Neighbor` bitmap,
//! Alg. 3 l.11 / Alg. 4 l.2) — measured by time and by tiles evaluated.

use palmad::bench::harness::{default_reps, measure, quick_mode, Bench};
use palmad::coordinator::drag::Pd3Config;
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::engines::native::NativeEngine;
use palmad::gen::registry;

fn main() {
    let mut bench = Bench::new("ablation_pruning");
    let n = if quick_mode() { 8_000 } else { 24_000 };
    let t = registry::dataset_prefix("ecg", n, 42).unwrap().series;
    let (min_l, max_l) = (128, 136);

    let cases: [(&str, Pd3Config); 3] = [
        ("early_stop+direct_kill", Pd3Config { early_stop: true, deferred_neighbor_kill: false }),
        ("early_stop+deferred_kill", Pd3Config { early_stop: true, deferred_neighbor_kill: true }),
        ("no_early_stop", Pd3Config { early_stop: false, deferred_neighbor_kill: false }),
    ];

    for (label, pd3) in cases {
        let engine = NativeEngine::with_segn(256);
        let cfg = MerlinConfig { min_l, max_l, top_k: 1, pd3, ..Default::default() };
        let mut tiles = (0u64, 0u64);
        let s = measure(0, default_reps(), || {
            let res = Merlin::new(&engine, cfg.clone()).run(&t).unwrap();
            tiles = (res.metrics.drag.tiles_computed, res.metrics.drag.tiles_skipped);
        });
        bench.record(
            label,
            format!("n={n} range={min_l}..{max_l}"),
            s,
            vec![
                ("tiles".into(), tiles.0.to_string()),
                ("skipped".into(), tiles.1.to_string()),
            ],
        );
    }
    bench.finish();
}
