//! Ablation the paper omits: serial MERLIN (the 2020 original, with
//! per-length from-scratch normalization and serial DRAG) vs PALMAD on
//! the same CPU — the parallelization + recurrence speedup in isolation
//! from GPU-vs-CPU hardware differences.

use palmad::baselines::merlin_serial;
use palmad::bench::harness::{quick_mode, Bench};
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::engines::native::NativeEngine;
use palmad::gen::registry;

fn main() {
    let mut bench = Bench::new("ablation_serial_vs_palmad");
    let n = if quick_mode() { 2_000 } else { 6_000 };
    let (min_l, max_l) = (48, 64);

    for name in ["ecg2", "random_walk_1m"] {
        let t = registry::dataset_prefix(name, n, 42).unwrap().series;

        bench.run("serial_merlin", format!("{name} n={n} range={min_l}..{max_l}"), || {
            merlin_serial::merlin(&t.values, min_l, max_l, 1);
        });

        for segn in [64usize, 256] {
            let engine = NativeEngine::with_segn(segn);
            let cfg = MerlinConfig { min_l, max_l, top_k: 1, ..Default::default() };
            bench.run(
                format!("palmad_segn{segn}"),
                format!("{name} n={n} range={min_l}..{max_l}"),
                || {
                    Merlin::new(&engine, cfg.clone()).run(&t).unwrap();
                },
            );
        }
    }
    bench.finish();
}
