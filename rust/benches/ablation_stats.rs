//! Ablation: the paper's recurrent statistics (Eqs. 7/8) vs recomputing
//! window stats from scratch at every length — the headline
//! redundancy-elimination claim, isolated.
//!
//! Also times the AOT `stats_update` Pallas kernel path when artifacts
//! are available (its PJRT call overhead vs in-process arithmetic is a
//! DESIGN.md §Perf data point).

use palmad::bench::harness::{quick_mode, Bench};
use palmad::coordinator::merlin::{Merlin, MerlinConfig, StatsBackend};
use palmad::core::stats::RollingStats;
use palmad::engines::native::NativeEngine;
use palmad::gen::registry;

fn main() {
    let mut bench = Bench::new("ablation_recurrent_stats");
    let n = if quick_mode() { 8_000 } else { 32_000 };
    let (min_l, max_l) = if quick_mode() { (64, 96) } else { (64, 256) };
    let t = registry::dataset_prefix("random_walk_1m", n, 42).unwrap().series;

    // Stats-only microcomparison: recurrence vs from-scratch across the
    // whole length sweep.
    bench.run("stats_recurrence_only", format!("n={n} range={min_l}..{max_l}"), || {
        let mut s = RollingStats::compute(&t.values, min_l);
        for _ in min_l..max_l {
            s.advance(&t.values);
        }
        std::hint::black_box(&s);
    });
    bench.run("stats_fresh_only", format!("n={n} range={min_l}..{max_l}"), || {
        for m in min_l..=max_l {
            std::hint::black_box(RollingStats::compute(&t.values, m));
        }
    });

    // Whole-pipeline effect.
    let engine = NativeEngine::with_segn(256);
    for (label, backend) in [
        ("merlin_recurrent", StatsBackend::Native),
        ("merlin_fresh", StatsBackend::NaivePerLength),
    ] {
        let cfg = MerlinConfig {
            min_l,
            max_l,
            top_k: 1,
            stats_backend: backend,
            ..Default::default()
        };
        bench.run(label, format!("n={n} range={min_l}..{max_l}"), || {
            Merlin::new(&engine, cfg.clone()).run(&t).unwrap();
        });
    }

    // AOT stats path (optional).
    if let Ok(artifacts) =
        palmad::runtime::artifact::ArtifactSet::load(palmad::runtime::artifact::ArtifactSet::default_dir())
    {
        if let Some(&segn) = artifacts.tile_segns().first() {
            use palmad::engines::Engine as _;
            let engine = palmad::engines::xla::XlaEngine::new(artifacts, segn).unwrap();
            let span = if quick_mode() { 8 } else { 32 };
            bench.run("stats_aot_kernel", format!("n={n} steps={span}"), || {
                let mut s = engine.aot_stats_init(&t.values, min_l).unwrap();
                for _ in 0..span {
                    s = engine.aot_stats_update(&t.values, &s).unwrap();
                }
                std::hint::black_box(&s);
            });
        }
    } else {
        println!("  (no artifacts; skipping AOT stats row)");
    }
    bench.finish();
}
