//! Fig. 4 reproduction: PALMAD vs KBF (brute-force K-distance discord) on
//! the Koski-ECG surrogate — total runtime and time-per-discord vs series
//! length.
//!
//! Scale note: the paper runs n up to 100k on a Tesla V100; KBF is
//! O(n^2 m) with no pruning, so on this CPU testbed the sweep uses
//! n in {2k, 4k, 8k} with m = 256 (458 in the paper).  The comparison
//! *shape* is the target: PALMAD wins outright on total time, and wins
//! per-discord by a growing factor, exactly as Fig. 4 reports.

use palmad::baselines::kbf;
use palmad::bench::harness::{default_reps, measure, quick_mode, Bench};
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::engines::native::NativeEngine;
use palmad::gen::registry;

fn main() {
    let mut bench = Bench::new("fig4_palmad_vs_kbf");
    let sizes: &[usize] = if quick_mode() { &[2_000] } else { &[2_000, 4_000, 8_000] };
    let m = 256;

    for &n in sizes {
        let spec = registry::dataset_prefix("koski_ecg", n, 42).unwrap();
        let t = spec.series;

        // PALMAD, all discords of the single length (minL = maxL = m).
        let engine = NativeEngine::with_segn(256);
        let cfg = MerlinConfig { min_l: m, max_l: m, top_k: 0, ..Default::default() };
        let mut discords = 0usize;
        let s = measure(0, default_reps(), || {
            let res = Merlin::new(&engine, cfg.clone()).run(&t).unwrap();
            discords = res.lengths[0].discords.len();
        });
        let per = s.median / discords.max(1) as f64;
        bench.record(
            "palmad",
            format!("n={n} m={m}"),
            s,
            vec![
                ("discords".into(), discords.to_string()),
                ("per_discord_ms".into(), format!("{:.2}", per * 1e3)),
            ],
        );

        // KBF: top-1 K-distance discord (K=3 per the rival's paper).
        let s = measure(0, default_reps(), || {
            kbf::kbf_top1(&t.values, m, 3, palmad::util::pool::default_threads()).unwrap();
        });
        bench.record(
            "kbf_k3",
            format!("n={n} m={m}"),
            s,
            vec![
                ("discords".into(), "1".into()),
                ("per_discord_ms".into(), format!("{:.2}", s.median * 1e3)),
            ],
        );
    }
    bench.finish();
}
