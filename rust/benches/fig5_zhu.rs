//! Fig. 5 reproduction: PALMAD vs Zhu et al.'s top-1 framework over the
//! Tab. 1 roster — runtime, number of discords found, and average time to
//! discover one discord.
//!
//! Scale note: series are truncated to 6k-sample prefixes (1M/2M random
//! walks included) and discord lengths capped at 256 so the O(n^2 m)
//! rival finishes on CPU.  The Fig. 5 shape to reproduce: Zhu wins total
//! time (it stops after one discord), PALMAD finds orders of magnitude
//! more discords and wins time-per-discord.

use palmad::baselines::zhu;
use palmad::bench::harness::{default_reps, measure, quick_mode, Bench};
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::engines::native::NativeEngine;
use palmad::gen::registry;

fn main() {
    let mut bench = Bench::new("fig5_palmad_vs_zhu");
    let roster: &[&str] = if quick_mode() {
        &["ecg2"]
    } else {
        &["space_shuttle", "ecg", "ecg2", "koski_ecg", "respiration", "power_demand", "random_walk_1m"]
    };
    let n = 6_000;

    for name in roster {
        let spec = registry::dataset_prefix(name, n, 42).unwrap();
        let m = spec.m.min(256);
        let t = spec.series;

        let engine = NativeEngine::with_segn(256);
        let cfg = MerlinConfig { min_l: m, max_l: m, top_k: 0, ..Default::default() };
        let mut discords = 0usize;
        let s = measure(0, default_reps(), || {
            let res = Merlin::new(&engine, cfg.clone()).run(&t).unwrap();
            discords = res.lengths[0].discords.len();
        });
        bench.record(
            "palmad",
            format!("{name} n={n} m={m}"),
            s,
            vec![
                ("discords".into(), discords.to_string()),
                ("per_discord_ms".into(), format!("{:.3}", s.median * 1e3 / discords.max(1) as f64)),
            ],
        );

        let s = measure(0, default_reps(), || {
            zhu::zhu_top1(&t.values, m, palmad::util::pool::default_threads()).unwrap();
        });
        bench.record(
            "zhu_top1",
            format!("{name} n={n} m={m}"),
            s,
            vec![
                ("discords".into(), "1".into()),
                ("per_discord_ms".into(), format!("{:.3}", s.median * 1e3)),
            ],
        );
    }
    bench.finish();
}
