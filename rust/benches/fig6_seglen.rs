//! Fig. 6 reproduction: PALMAD runtime vs the segment length (tile edge
//! `segN`), on a real-world surrogate and a synthetic random walk.
//!
//! The paper's finding: larger segments run faster (less staging
//! overhead per distance), with runtime roughly proportional to the
//! segment-count.  Here `segN` controls tile granularity: larger tiles
//! amortize per-tile setup (stats slicing, QT seed rows) the same way
//! larger CUDA blocks amortize shared-memory staging.

use palmad::bench::harness::{default_reps, measure, quick_mode, Bench};
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::engines::native::NativeEngine;
use palmad::engines::xla::XlaEngine;
use palmad::gen::registry;
use palmad::runtime::artifact::ArtifactSet;

fn main() {
    let mut bench = Bench::new("fig6_seglen");
    let segns: &[usize] = if quick_mode() { &[64, 256] } else { &[64, 128, 256, 512] };
    let workloads: &[(&str, usize, usize)] = if quick_mode() {
        &[("ecg", 8_000, 128)]
    } else {
        // (dataset, n, m)
        &[("ecg", 16_000, 128), ("random_walk_1m", 16_000, 128)]
    };

    for &(name, n, m) in workloads {
        let t = registry::dataset_prefix(name, n, 42).unwrap().series;
        for &segn in segns {
            let engine = NativeEngine::with_segn(segn);
            let cfg = MerlinConfig { min_l: m, max_l: m + 8, top_k: 1, ..Default::default() };
            let mut tiles = 0u64;
            let s = measure(0, default_reps(), || {
                let res = Merlin::new(&engine, cfg.clone()).run(&t).unwrap();
                tiles = res.metrics.drag.tiles_computed;
            });
            bench.record(
                format!("native segn={segn}"),
                format!("{name} n={n} m={m}..{}", m + 8),
                s,
                vec![("tiles".into(), tiles.to_string())],
            );
        }
    }

    // The AOT/PJRT path is where the paper's mechanism (per-launch staging
    // amortized by larger segments) applies directly: each tile pays a
    // fixed PJRT call overhead, so larger segN should win — the Fig. 6
    // shape.  (On the native path finer segments win instead: early-stop
    // granularity dominates; both series are reported.)
    if let Ok(artifacts) = ArtifactSet::load(ArtifactSet::default_dir()) {
        let (name, n, m) = ("ecg", if quick_mode() { 4_000 } else { 8_000 }, 100);
        let t = registry::dataset_prefix(name, n, 42).unwrap().series;
        for &segn in segns {
            if artifacts.max_m_for_segn(segn).map_or(true, |mm| mm < m) {
                continue;
            }
            let engine = XlaEngine::new(artifacts.clone(), segn).unwrap();
            let cfg = MerlinConfig { min_l: m, max_l: m, top_k: 1, ..Default::default() };
            let mut tiles = 0u64;
            let s = measure(0, default_reps(), || {
                let res = Merlin::new(&engine, cfg.clone()).run(&t).unwrap();
                tiles = res.metrics.drag.tiles_computed;
            });
            bench.record(
                format!("xla segn={segn}"),
                format!("{name} n={n} m={m}"),
                s,
                vec![("tiles".into(), tiles.to_string())],
            );
        }
    } else {
        println!("  (no artifacts; skipping xla seglen series)");
    }
    bench.finish();
}
