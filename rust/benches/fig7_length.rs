//! Fig. 7 reproduction: PALMAD runtime vs series length `n` (prefixes of
//! a real-world surrogate and of the random walk), fixed discord range.
//!
//! The paper reports near-linear growth (thanks to range pruning); the
//! shape to reproduce is monotone growth distinctly below quadratic.

use palmad::bench::harness::{quick_mode, Bench};
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::engines::native::NativeEngine;
use palmad::gen::registry;

fn main() {
    let mut bench = Bench::new("fig7_series_length");
    let sizes: &[usize] = if quick_mode() { &[4_000, 8_000] } else { &[4_000, 8_000, 16_000, 32_000] };
    let workloads: &[(&str, usize)] =
        if quick_mode() { &[("koski_ecg", 128)] } else { &[("koski_ecg", 128), ("random_walk_1m", 128)] };

    for &(name, m) in workloads {
        for &n in sizes {
            let t = registry::dataset_prefix(name, n, 42).unwrap().series;
            let engine = NativeEngine::with_segn(256);
            let cfg = MerlinConfig { min_l: m, max_l: m + 16, top_k: 1, ..Default::default() };
            bench.run(format!("n={n}"), format!("{name} m={m}..{}", m + 16), || {
                Merlin::new(&engine, cfg.clone()).run(&t).unwrap();
            });
        }
    }
    bench.finish();
}
