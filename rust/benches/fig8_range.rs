//! Fig. 8 reproduction: PALMAD runtime vs the width of the discord length
//! range `[minL, maxL]` — the arbitrary-length capability that headlines
//! MERLIN.  The paper reports runtime proportional to the range width;
//! the recurrences (Eqs. 7/8) keep the per-length overhead flat.

use palmad::bench::harness::{quick_mode, Bench};
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::engines::native::NativeEngine;
use palmad::gen::registry;

fn main() {
    let mut bench = Bench::new("fig8_range_width");
    let widths: &[usize] = if quick_mode() { &[1, 9] } else { &[1, 9, 25, 57] };
    let workloads: &[(&str, usize, usize)] = if quick_mode() {
        &[("ecg", 8_000, 128)]
    } else {
        &[("ecg", 12_000, 128), ("random_walk_1m", 12_000, 128)]
    };

    for &(name, n, min_l) in workloads {
        let t = registry::dataset_prefix(name, n, 42).unwrap().series;
        for &w in widths {
            let engine = NativeEngine::with_segn(256);
            let cfg = MerlinConfig {
                min_l,
                max_l: min_l + w - 1,
                top_k: 1,
                ..Default::default()
            };
            bench.run(
                format!("width={w}"),
                format!("{name} n={n} range={min_l}..{}", min_l + w - 1),
                || {
                    Merlin::new(&engine, cfg.clone()).run(&t).unwrap();
                },
            );
        }
    }
    bench.finish();
}
