//! Hot-path microbenchmarks for the L3 perf pass (EXPERIMENTS.md §Perf):
//! dot products, early-abandon distance, the rolling-stat recurrence, the
//! native tile in both pipelines (legacy alloc-per-tile vs scratch-arena),
//! the end-to-end MERLIN before/after, and the PJRT tile call.
//!
//! Besides the human-readable table (and the usual dump under
//! `target/bench-results/`), this bench emits two machine-readable
//! artifacts at the repo root so the perf trajectory is trackable across
//! PRs:
//!
//! - `BENCH_native_tile.json` — single-tile cost, legacy vs scratch
//!   pipeline, with cells/s rates and the speedup ratio.
//! - `BENCH_merlin.json` — end-to-end MERLIN (n = 2^16, lengths 64..128,
//!   native engine) for the pre-PR baseline pipeline and the current one.

use palmad::bench::harness::{default_reps, measure, quick_mode, Bench};
use palmad::bench::stats::Summary;
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::coordinator::streaming::{StreamConfig, StreamMonitor};
use palmad::core::distance::{dot, ed2_early_abandon, znorm};
use palmad::core::stats::RollingStats;
use palmad::engines::native::{
    compute_tile, compute_tile_alloc, compute_tile_with_kernel, NativeConfig, NativeEngine,
    TilePipeline,
};
use palmad::engines::scratch::QtSeedCache;
use palmad::engines::{Engine, SeriesView, TileKernel, TileTask, LANES};
use palmad::gen::random_walk::random_walk;
use palmad::util::json::Json;
use palmad::util::pool::{self, RoundPool};

fn summary_json(s: &Summary) -> Json {
    Json::obj()
        .set("median_s", s.median)
        .set("min_s", s.min)
        .set("mean_s", s.mean)
        .set("reps", s.reps)
}

fn write_root_json(name: &str, json: Json) {
    match std::fs::write(name, json.to_string()) {
        Ok(()) => println!("wrote {name}"),
        Err(e) => eprintln!("warn: could not write {name}: {e}"),
    }
}

fn main() {
    let mut bench = Bench::new("microbench");
    let t = random_walk(100_000, 42);
    let m = 256;
    let segn = 256;

    // Raw dot product (the QT seed cost).
    let a = &t.values[0..m];
    let b = &t.values[m..2 * m];
    let s = measure(2, default_reps(), || {
        for _ in 0..10_000 {
            std::hint::black_box(dot(std::hint::black_box(a), std::hint::black_box(b)));
        }
    });
    let flops = 2.0 * m as f64 * 10_000.0 / s.median / 1e9;
    bench.record("dot_m256", "10k iters", s, vec![("gflops".into(), format!("{flops:.2}"))]);

    // Early-abandon distance.
    let an = znorm(a);
    let bn = znorm(b);
    let s = measure(2, default_reps(), || {
        for _ in 0..10_000 {
            std::hint::black_box(ed2_early_abandon(
                std::hint::black_box(&an),
                std::hint::black_box(&bn),
                f64::INFINITY,
            ));
        }
    });
    bench.record("ed2_early_abandon_m256", "10k iters, no abandon", s, vec![]);

    // Rolling stats: initial vs recurrent advance.
    let s = measure(1, default_reps(), || {
        std::hint::black_box(RollingStats::compute(&t.values, m));
    });
    let rate = t.len() as f64 / s.median / 1e6;
    bench.record("stats_compute", "n=100k m=256", s, vec![("melem_per_s".into(), format!("{rate:.0}"))]);

    let s = measure(1, default_reps(), || {
        let mut st = RollingStats::compute(&t.values, m);
        st.advance(&t.values);
        std::hint::black_box(&st);
    });
    bench.record("stats_advance_incl_init", "n=100k", s, vec![]);

    // One native tile, both pipelines: the inner-loop workhorse and the
    // headline before/after of the zero-allocation refactor.
    let stats = RollingStats::compute(&t.values, m);
    let view = SeriesView { t: &t.values, stats: &stats };
    let task = TileTask { seg_start: 0, chunk_start: 4096 };
    let cells = (segn * segn) as f64;

    let s_legacy = measure(1, default_reps(), || {
        std::hint::black_box(compute_tile_alloc(&view, segn, 1.0, task));
    });
    bench.record(
        "native_tile_legacy_256x256_m256",
        "alloc-per-tile pipeline",
        s_legacy,
        vec![("mcells_per_s".into(), format!("{:.1}", cells / s_legacy.median / 1e6))],
    );

    let s_scratch = measure(1, default_reps(), || {
        std::hint::black_box(compute_tile(&view, segn, 1.0, task));
    });
    bench.record(
        "native_tile_scratch_256x256_m256",
        "scratch-arena SoA pipeline",
        s_scratch,
        vec![
            ("mcells_per_s".into(), format!("{:.1}", cells / s_scratch.median / 1e6)),
            ("speedup_vs_legacy".into(), format!("{:.2}", s_legacy.median / s_scratch.median)),
        ],
    );

    // Explicit SIMD kernel vs the scalar oracle on the same tile: the
    // before/after of the lane-chunked inner loop (EXPERIMENTS.md
    // §SIMD).  Same scratch pipeline, same seedless entry point — the
    // only variable is the TileKernel dispatch.
    let s_k_scalar = measure(1, default_reps(), || {
        std::hint::black_box(compute_tile_with_kernel(
            &view,
            segn,
            1.0,
            task,
            TileKernel::Scalar,
        ));
    });
    bench.record(
        "native_tile_kernel_scalar",
        "per-column scalar inner loop",
        s_k_scalar,
        vec![("mcells_per_s".into(), format!("{:.1}", cells / s_k_scalar.median / 1e6))],
    );
    let s_k_lanes = measure(1, default_reps(), || {
        std::hint::black_box(compute_tile_with_kernel(
            &view,
            segn,
            1.0,
            task,
            TileKernel::Lanes4,
        ));
    });
    bench.record(
        "native_tile_kernel_lanes4",
        format!("LANES={LANES} chunked inner loop"),
        s_k_lanes,
        vec![
            ("mcells_per_s".into(), format!("{:.1}", cells / s_k_lanes.median / 1e6)),
            ("speedup_vs_scalar".into(), format!("{:.2}", s_k_scalar.median / s_k_lanes.median)),
        ],
    );
    // The width/precision variants of the same generic lane body: the
    // AVX-512-width f64 kernel (safe Rust everywhere; fast where the
    // hardware has 512-bit units — the note records what Auto picked on
    // this host) and the tolerance-banded f32 kernel (the accelerator
    // parity story; ~2x lane density on the same vector width).
    let auto_kernel = TileKernel::Auto.resolve();
    let s_k_lanes8 = measure(1, default_reps(), || {
        std::hint::black_box(compute_tile_with_kernel(
            &view,
            segn,
            1.0,
            task,
            TileKernel::Lanes8,
        ));
    });
    bench.record(
        "native_tile_kernel_lanes8",
        format!("W=8 chunked inner loop (auto resolves to {})", auto_kernel.name()),
        s_k_lanes8,
        vec![
            ("mcells_per_s".into(), format!("{:.1}", cells / s_k_lanes8.median / 1e6)),
            ("speedup_vs_scalar".into(), format!("{:.2}", s_k_scalar.median / s_k_lanes8.median)),
        ],
    );
    let s_k_f32 = measure(1, default_reps(), || {
        std::hint::black_box(compute_tile_with_kernel(
            &view,
            segn,
            1.0,
            task,
            TileKernel::Lanes4F32,
        ));
    });
    bench.record(
        "native_tile_kernel_lanes4f32",
        "f32 lanes, tolerance-banded",
        s_k_f32,
        vec![
            ("mcells_per_s".into(), format!("{:.1}", cells / s_k_f32.median / 1e6)),
            ("speedup_vs_scalar".into(), format!("{:.2}", s_k_scalar.median / s_k_f32.median)),
        ],
    );

    // Seed prefetch: K cached QT rows walked m0 -> m1, lazily (one
    // seed_into advance per row, serialized through the shard locks) vs
    // the bulk advance_all sweep (one parallel pass).  Seeding the rows
    // at m0 is common setup, measured separately so the JSON lets the
    // net advance cost be recovered by subtraction.
    let (pf_rows, pf_nb, pf_m0, pf_m1) = (512usize, 256usize, 64usize, 320usize);
    let pf_keys: Vec<(usize, usize)> = (0..pf_rows)
        .map(|k| (k * 7 % 4096, 8192 + (k * 131) % 32768))
        .collect();
    let mut pf_buf = vec![0.0; pf_nb];
    let seed_all = |cache: &QtSeedCache, m: usize, buf: &mut [f64]| {
        cache.prepare(&t.values);
        for &(a, cs) in &pf_keys {
            cache.seed_into(&t.values, m, a, cs, pf_nb, buf);
        }
    };
    let s_pf_setup = measure(1, default_reps(), || {
        let cache = QtSeedCache::new();
        seed_all(&cache, pf_m0, &mut pf_buf);
        std::hint::black_box(&pf_buf);
    });
    bench.record(
        "seed_prefetch_setup",
        format!("{pf_rows} rows nb={pf_nb} seed m={pf_m0}"),
        s_pf_setup,
        vec![],
    );
    let s_pf_lazy = measure(1, default_reps(), || {
        let cache = QtSeedCache::new();
        seed_all(&cache, pf_m0, &mut pf_buf);
        for &(a, cs) in &pf_keys {
            cache.seed_into(&t.values, pf_m1, a, cs, pf_nb, &mut pf_buf);
        }
        std::hint::black_box(&pf_buf);
    });
    let pf_pool = RoundPool::new(pool::default_threads().saturating_sub(1));
    let mut prefetched_rows = 0u64;
    let s_pf_bulk = measure(1, default_reps(), || {
        let cache = QtSeedCache::new();
        seed_all(&cache, pf_m0, &mut pf_buf);
        prefetched_rows = cache.advance_all(&t.values, pf_m1, Some(&pf_pool));
        std::hint::black_box(prefetched_rows);
    });
    let pf_lazy_net = (s_pf_lazy.median - s_pf_setup.median).max(0.0);
    let pf_bulk_net = (s_pf_bulk.median - s_pf_setup.median).max(1e-12);
    bench.record(
        "seed_prefetch_lazy",
        format!("{pf_rows} rows m{pf_m0}->{pf_m1}"),
        s_pf_lazy,
        vec![("net_s".into(), format!("{pf_lazy_net:.6}"))],
    );
    bench.record(
        "seed_prefetch_bulk",
        format!("{pf_rows} rows m{pf_m0}->{pf_m1}"),
        s_pf_bulk,
        vec![
            ("net_s".into(), format!("{pf_bulk_net:.6}")),
            ("speedup_net".into(), format!("{:.2}", pf_lazy_net / pf_bulk_net)),
            ("prefetched_rows".into(), format!("{prefetched_rows}")),
        ],
    );

    write_root_json(
        "BENCH_native_tile.json",
        Json::obj()
            .set("bench", "native_tile")
            .set("quick", quick_mode())
            .set("segn", segn)
            .set("m", m)
            .set("series_n", t.len())
            .set(
                "legacy",
                summary_json(&s_legacy)
                    .set("mcells_per_s", cells / s_legacy.median / 1e6),
            )
            .set(
                "scratch",
                summary_json(&s_scratch)
                    .set("mcells_per_s", cells / s_scratch.median / 1e6),
            )
            .set("speedup", s_legacy.median / s_scratch.median)
            .set(
                "seed_prefetch",
                Json::obj()
                    .set("rows", pf_rows)
                    .set("nb", pf_nb)
                    .set("m_from", pf_m0)
                    .set("m_to", pf_m1)
                    .set("prefetched_rows", prefetched_rows as usize)
                    .set("setup", summary_json(&s_pf_setup))
                    .set("lazy", summary_json(&s_pf_lazy).set("net_s", pf_lazy_net))
                    .set("bulk", summary_json(&s_pf_bulk).set("net_s", pf_bulk_net))
                    .set("speedup_net", pf_lazy_net / pf_bulk_net),
            )
            .set(
                "simd_kernel",
                Json::obj()
                    .set("lanes", LANES)
                    .set("auto_resolves_to", auto_kernel.name())
                    .set(
                        "scalar",
                        summary_json(&s_k_scalar)
                            .set("mcells_per_s", cells / s_k_scalar.median / 1e6),
                    )
                    .set(
                        "lanes4",
                        summary_json(&s_k_lanes)
                            .set("mcells_per_s", cells / s_k_lanes.median / 1e6),
                    )
                    .set(
                        "lanes8",
                        summary_json(&s_k_lanes8)
                            .set("mcells_per_s", cells / s_k_lanes8.median / 1e6)
                            .set("speedup_vs_scalar", s_k_scalar.median / s_k_lanes8.median),
                    )
                    .set(
                        "lanes4f32",
                        summary_json(&s_k_f32)
                            .set("mcells_per_s", cells / s_k_f32.median / 1e6)
                            .set("speedup_vs_scalar", s_k_scalar.median / s_k_f32.median),
                    )
                    .set("speedup", s_k_scalar.median / s_k_lanes.median),
            ),
    );

    // End-to-end MERLIN before/after: the acceptance workload
    // (n = 2^16, lengths 64..128, top-1, native engine).  Engines are
    // reused across reps, so the scratch side runs in its steady state
    // (warm pools, warm seed cache) — exactly the regime the refactor
    // targets; the legacy side has no reusable state by construction.
    let n = if quick_mode() { 1 << 14 } else { 1 << 16 };
    let series = random_walk(n, 7);
    let merlin_cfg = MerlinConfig { min_l: 64, max_l: 128, top_k: 1, ..Default::default() };

    let legacy_engine = NativeEngine::new(NativeConfig {
        segn,
        pipeline: TilePipeline::Legacy,
        ..Default::default()
    });
    let s_merlin_legacy = measure(1, default_reps(), || {
        let res = Merlin::new(&legacy_engine, merlin_cfg.clone()).run(&series).unwrap();
        std::hint::black_box(res.lengths.len());
    });
    bench.record(
        "merlin_e2e_legacy",
        format!("n={n} l=64..128"),
        s_merlin_legacy,
        vec![],
    );

    let scratch_engine = NativeEngine::new(NativeConfig { segn, ..Default::default() });
    let s_merlin_scratch = measure(1, default_reps(), || {
        let res = Merlin::new(&scratch_engine, merlin_cfg.clone()).run(&series).unwrap();
        std::hint::black_box(res.lengths.len());
    });
    let merlin_speedup = s_merlin_legacy.median / s_merlin_scratch.median;
    bench.record(
        "merlin_e2e_scratch",
        format!("n={n} l=64..128"),
        s_merlin_scratch,
        vec![("speedup_vs_legacy".into(), format!("{merlin_speedup:.2}"))],
    );

    // Streaming ingest: steady-state points/sec through the monitor —
    // the amortized ring slide vs the pre-PR O(window)-per-push drain
    // slide (`StreamConfig::legacy_slide`), same engine, same stream.
    let stream_points = if quick_mode() { 10_000 } else { 100_000 };
    let (stream_window, stream_m, stream_refresh) = (4_096usize, 64usize, 2_048usize);
    let stream_engine = NativeEngine::new(NativeConfig { segn, ..Default::default() });
    let mut ingest = |legacy: bool| -> Summary {
        let mut mon = StreamMonitor::new(
            &stream_engine,
            StreamConfig {
                window: stream_window,
                m: stream_m,
                refresh: stream_refresh,
                alert_frac: 1.1,
                legacy_slide: legacy,
            },
        );
        let mut i = 0usize;
        measure(1, default_reps(), || {
            for _ in 0..stream_points {
                let x = (i as f64 * 0.2).sin() + 0.05 * (i as f64 * 0.013).sin();
                let _ = mon.push(x).unwrap();
                i += 1;
            }
        })
    };
    let s_ingest_legacy = ingest(true);
    let s_ingest_ring = ingest(false);
    let ingest_speedup = s_ingest_legacy.median / s_ingest_ring.median;
    bench.record(
        "stream_ingest_legacy_drain",
        format!("{stream_points} pts w={stream_window} m={stream_m}"),
        s_ingest_legacy,
        vec![(
            "mpts_per_s".into(),
            format!("{:.2}", stream_points as f64 / s_ingest_legacy.median / 1e6),
        )],
    );
    bench.record(
        "stream_ingest_ring",
        format!("{stream_points} pts w={stream_window} m={stream_m}"),
        s_ingest_ring,
        vec![
            (
                "mpts_per_s".into(),
                format!("{:.2}", stream_points as f64 / s_ingest_ring.median / 1e6),
            ),
            ("speedup_vs_drain".into(), format!("{ingest_speedup:.2}")),
        ],
    );

    write_root_json(
        "BENCH_merlin.json",
        Json::obj()
            .set("bench", "merlin_e2e")
            .set("quick", quick_mode())
            .set("engine", "native")
            .set("segn", segn)
            .set("n", n)
            .set("min_l", 64usize)
            .set("max_l", 128usize)
            .set("top_k", 1usize)
            .set("baseline_legacy", summary_json(&s_merlin_legacy))
            .set("scratch", summary_json(&s_merlin_scratch))
            .set("speedup", merlin_speedup)
            .set(
                "streaming_ingest",
                Json::obj()
                    .set("window", stream_window)
                    .set("m", stream_m)
                    .set("refresh", stream_refresh)
                    .set("points_per_rep", stream_points)
                    .set(
                        "legacy_drain",
                        summary_json(&s_ingest_legacy).set(
                            "mpts_per_s",
                            stream_points as f64 / s_ingest_legacy.median / 1e6,
                        ),
                    )
                    .set(
                        "ring",
                        summary_json(&s_ingest_ring).set(
                            "mpts_per_s",
                            stream_points as f64 / s_ingest_ring.median / 1e6,
                        ),
                    )
                    .set("speedup", ingest_speedup),
            ),
    );

    // PJRT tile call (when a runtime and artifacts exist): per-call
    // overhead + compute.
    if palmad::runtime::pjrt_runtime_available() {
        if let Ok(artifacts) = palmad::runtime::artifact::ArtifactSet::load(
            palmad::runtime::artifact::ArtifactSet::default_dir(),
        ) {
            if artifacts.tiles.keys().any(|s| s.segn == segn && s.mmax >= m) {
                let engine = palmad::engines::xla::XlaEngine::new(artifacts, segn).unwrap();
                let tasks: Vec<TileTask> = (0..8)
                    .map(|k| TileTask { seg_start: k * segn, chunk_start: 4096 + k * segn })
                    .collect();
                // Warm the executable cache first.
                engine.compute_tiles(&view, 1.0, &tasks[..1]).unwrap();
                let s = measure(1, default_reps(), || {
                    std::hint::black_box(engine.compute_tiles(&view, 1.0, &tasks).unwrap());
                });
                bench.record(
                    "xla_tile_batch8_256x512",
                    "8 tiles/call",
                    s,
                    vec![("ms_per_tile".into(), format!("{:.2}", s.median * 1e3 / 8.0))],
                );
            }
        }
    } else {
        println!("  (xla tile bench skipped: PJRT runtime unavailable)");
    }

    // Bitmap scan rate (segment-liveness checks).
    let bm = palmad::core::bitmap::Bitmap::ones(1_000_000);
    let s = measure(2, default_reps(), || {
        let mut alive = 0;
        for seg in 0..(1_000_000 / 256) {
            alive += bm.any_in_range(seg * 256, (seg + 1) * 256) as usize;
        }
        std::hint::black_box(alive);
    });
    bench.record("bitmap_liveness_1m", "3906 ranges", s, vec![]);

    if quick_mode() {
        println!("  (quick mode: reps reduced)");
    }
    bench.finish();
}
