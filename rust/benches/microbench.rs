//! Hot-path microbenchmarks for the L3 perf pass (EXPERIMENTS.md §Perf):
//! dot products, early-abandon distance, the rolling-stat recurrence, one
//! native tile, and the PJRT tile call, with derived throughput rates.

use palmad::bench::harness::{default_reps, measure, quick_mode, Bench};
use palmad::core::distance::{dot, ed2_early_abandon, znorm};
use palmad::core::stats::RollingStats;
use palmad::engines::native::compute_tile;
use palmad::engines::{Engine, SeriesView, TileTask};
use palmad::gen::random_walk::random_walk;

fn main() {
    let mut bench = Bench::new("microbench");
    let t = random_walk(100_000, 42);
    let m = 256;
    let segn = 256;

    // Raw dot product (the QT seed cost).
    let a = &t.values[0..m];
    let b = &t.values[m..2 * m];
    let s = measure(2, default_reps(), || {
        for _ in 0..10_000 {
            std::hint::black_box(dot(std::hint::black_box(a), std::hint::black_box(b)));
        }
    });
    let flops = 2.0 * m as f64 * 10_000.0 / s.median / 1e9;
    bench.record("dot_m256", "10k iters", s, vec![("gflops".into(), format!("{flops:.2}"))]);

    // Early-abandon distance.
    let an = znorm(a);
    let bn = znorm(b);
    let s = measure(2, default_reps(), || {
        for _ in 0..10_000 {
            std::hint::black_box(ed2_early_abandon(
                std::hint::black_box(&an),
                std::hint::black_box(&bn),
                f64::INFINITY,
            ));
        }
    });
    bench.record("ed2_early_abandon_m256", "10k iters, no abandon", s, vec![]);

    // Rolling stats: initial vs recurrent advance.
    let s = measure(1, default_reps(), || {
        std::hint::black_box(RollingStats::compute(&t.values, m));
    });
    let rate = t.len() as f64 / s.median / 1e6;
    bench.record("stats_compute", "n=100k m=256", s, vec![("melem_per_s".into(), format!("{rate:.0}"))]);

    let s = measure(1, default_reps(), || {
        let mut st = RollingStats::compute(&t.values, m);
        st.advance(&t.values);
        std::hint::black_box(&st);
    });
    bench.record("stats_advance_incl_init", "n=100k", s, vec![]);

    // One native tile: the inner-loop workhorse.
    let stats = RollingStats::compute(&t.values, m);
    let view = SeriesView { t: &t.values, stats: &stats };
    let s = measure(1, default_reps(), || {
        std::hint::black_box(compute_tile(
            &view,
            segn,
            1.0,
            TileTask { seg_start: 0, chunk_start: 4096 },
        ));
    });
    let cells = (segn * segn) as f64;
    bench.record(
        "native_tile_256x256_m256",
        "one tile",
        s,
        vec![("mcells_per_s".into(), format!("{:.1}", cells / s.median / 1e6))],
    );

    // PJRT tile call (when artifacts exist): per-call overhead + compute.
    if let Ok(artifacts) =
        palmad::runtime::artifact::ArtifactSet::load(palmad::runtime::artifact::ArtifactSet::default_dir())
    {
        if artifacts.tiles.keys().any(|s| s.segn == segn && s.mmax >= m) {
            let engine = palmad::engines::xla::XlaEngine::new(artifacts, segn).unwrap();
            let tasks: Vec<TileTask> = (0..8)
                .map(|k| TileTask { seg_start: k * segn, chunk_start: 4096 + k * segn })
                .collect();
            // Warm the executable cache first.
            engine.compute_tiles(&view, 1.0, &tasks[..1]).unwrap();
            let s = measure(1, default_reps(), || {
                std::hint::black_box(engine.compute_tiles(&view, 1.0, &tasks).unwrap());
            });
            bench.record(
                "xla_tile_batch8_256x512",
                "8 tiles/call",
                s,
                vec![("ms_per_tile".into(), format!("{:.2}", s.median * 1e3 / 8.0))],
            );
        }
    }

    // Bitmap scan rate (segment-liveness checks).
    let bm = palmad::core::bitmap::Bitmap::ones(1_000_000);
    let s = measure(2, default_reps(), || {
        let mut alive = 0;
        for seg in 0..(1_000_000 / 256) {
            alive += bm.any_in_range(seg * 256, (seg + 1) * 256) as usize;
        }
        std::hint::black_box(alive);
    });
    bench.record("bitmap_liveness_1m", "3906 ranges", s, vec![]);

    if quick_mode() {
        println!("  (quick mode: reps reduced)");
    }
    bench.finish();
}
