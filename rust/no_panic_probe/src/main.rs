//! Link-time proof that the annotated distance kernels are panic-free.
//!
//! Technique (after dtolnay's `no-panic`): each probed call is wrapped
//! in a guard whose `Drop` calls an **undefined** extern symbol, and
//! the guard is `mem::forget`-ed on the normal return path.  The drop
//! therefore only runs on the unwind edge — if the optimizer can prove
//! the call never panics, the unwind edge (and the undefined-symbol
//! reference) is deleted and the probe links; if any panic path
//! survives into the release build, linking fails with
//! `undefined reference to PANIC_REACHABLE_IN_<kernel>`.
//!
//! Scope: the kernels whose `// panic-free:` notes claim *provable*
//! freedom — `ed2norm_from_qt`, `corr_to_ed2`, `corr_saturates`,
//! `ed2_lane_chunk` plus its width/precision-generic core
//! `ed2_lane_chunk_w` at the two other shipped instantiations
//! (`<f64, 8>` for `Lanes8`, `<f32, 4>` for `Lanes4F32`), `dot`, and
//! `ed2_early_abandon`.  `dot` and `ed2_early_abandon` document "both
//! slices the same length" as a caller guarantee, so the probe drives
//! them with statically equal-length inputs — it proves the annotated
//! claim (panic-free under the stated precondition), not an
//! unconditional absence the functions never promised.  Inputs pass
//! through `black_box` so the proof cannot lean on constant folding.
//!
//! Run via `scripts/ci.sh --no-panic` (release build; skipped with a
//! notice when cargo is absent).

use std::hint::black_box;

use palmad::core::distance::{
    corr_saturates, corr_to_ed2, dot, ed2_early_abandon, ed2_lane_chunk, ed2_lane_chunk_w,
    ed2norm_from_qt, LANES, MAX_LANES,
};

/// Wrap `$body`; reaching a panic from it becomes a link error naming
/// `$sym`.
macro_rules! assert_no_panic {
    ($sym:ident, $body:expr) => {{
        struct Guard;
        impl Drop for Guard {
            fn drop(&mut self) {
                extern "C" {
                    fn $sym();
                }
                // SAFETY: this call is intentionally unreachable — the
                // symbol is undefined, and the whole point is that the
                // linker rejects any build where this path survives.
                unsafe { $sym() }
            }
        }
        let guard = Guard;
        let result = $body;
        std::mem::forget(guard);
        result
    }};
}

fn main() {
    let qt = black_box(12.5f64);
    let m = black_box(16usize);
    let stats = black_box([0.1f64, 1.2, -0.3, 0.9]);

    let d1 = assert_no_panic!(
        PANIC_REACHABLE_IN_ed2norm_from_qt,
        ed2norm_from_qt(qt, m, stats[0], stats[1], stats[2], stats[3])
    );

    let d2 = assert_no_panic!(PANIC_REACHABLE_IN_corr_to_ed2, corr_to_ed2(d1, 32.0));

    let sat = assert_no_panic!(PANIC_REACHABLE_IN_corr_saturates, corr_saturates(d2));

    let lanes_in = black_box([1.0f64; LANES]);
    let mmu = black_box([0.5f64; LANES]);
    let inv_sig = black_box([2.0f64; LANES]);
    let mut dist = [0.0f64; LANES];
    let sat2 = assert_no_panic!(
        PANIC_REACHABLE_IN_ed2_lane_chunk,
        ed2_lane_chunk(&lanes_in, &mmu, &inv_sig, 0.25, 4.0, 32.0, &mut dist)
    );

    // The generic core at its other shipped instantiations: W=8 f64
    // (Lanes8) and W=4 f32 (Lanes4F32).  Fixed-extent array refs make
    // the claim structural at every width/precision, but only probed
    // instantiations are *proved* — so probe them all.
    let lanes8_in = black_box([1.0f64; MAX_LANES]);
    let mmu8 = black_box([0.5f64; MAX_LANES]);
    let inv_sig8 = black_box([2.0f64; MAX_LANES]);
    let mut dist8 = [0.0f64; MAX_LANES];
    let sat8 = assert_no_panic!(
        PANIC_REACHABLE_IN_ed2_lane_chunk_w_f64x8,
        ed2_lane_chunk_w::<f64, MAX_LANES>(&lanes8_in, &mmu8, &inv_sig8, 0.25, 4.0, 32.0, &mut dist8)
    );

    let lanes_f32 = black_box([1.0f32; LANES]);
    let mmu_f32 = black_box([0.5f32; LANES]);
    let inv_sig_f32 = black_box([2.0f32; LANES]);
    let mut dist_f32 = [0.0f32; LANES];
    let sat_f32 = assert_no_panic!(
        PANIC_REACHABLE_IN_ed2_lane_chunk_w_f32x4,
        ed2_lane_chunk_w::<f32, LANES>(
            &lanes_f32,
            &mmu_f32,
            &inv_sig_f32,
            0.25,
            4.0,
            32.0,
            &mut dist_f32
        )
    );

    // Statically equal-length windows: the kernels' documented caller
    // guarantee, under which their panic-free notes hold.
    let a = black_box([0.125f64; 37]);
    let b = black_box([0.25f64; 37]);
    let d3 = assert_no_panic!(PANIC_REACHABLE_IN_dot, dot(&a, &b));

    let d4 = assert_no_panic!(
        PANIC_REACHABLE_IN_ed2_early_abandon,
        ed2_early_abandon(&a, &b, black_box(1.0e9))
    );

    // Consume every result so nothing is dead-code-eliminated before
    // the guards have done their job.
    println!(
        "no-panic probe: {} {} {} {} {} {} {} {:?} {:?} {:?} {:?}",
        d1, d2, sat, sat2, sat8, sat_f32, d3, d4, dist, dist8, dist_f32
    );
}
