//! Discord heatmap (Eq. 11): a `(maxL - minL + 1) x (n - minL)` intensity
//! matrix where cell `(m, i)` is the normalized nearest-neighbor distance
//! of discord `T[i, m]`:
//!
//! ```text
//! heatmap(m, i) = nnDist^2(T_i,m) / (2m)        (Eq. 11, squared form)
//! ```
//!
//! Non-discord cells are 0.  Built from a MERLIN run with `top_k = 0`
//! (collect all survivors per length).

use crate::coordinator::merlin::MerlinResult;

/// Dense heatmap with length-major rows.
#[derive(Clone, Debug)]
pub struct Heatmap {
    pub min_l: usize,
    pub max_l: usize,
    /// Number of index columns (`n - minL`).
    pub width: usize,
    /// Row-major `(maxL - minL + 1) x width` scores in `[0, 1]`-ish range
    /// (Eq. 11's normalization bounds scores by 2).
    pub data: Vec<f64>,
}

impl Heatmap {
    pub fn rows(&self) -> usize {
        self.max_l - self.min_l + 1
    }

    #[inline]
    pub fn get(&self, m: usize, i: usize) -> f64 {
        self.data[(m - self.min_l) * self.width + i]
    }

    #[inline]
    fn set(&mut self, m: usize, i: usize, v: f64) {
        self.data[(m - self.min_l) * self.width + i] = v;
    }

    /// Build from a MERLIN result over an `n`-sample series.
    ///
    /// Uses the squared-distance normalization `nnDist^2 / (2m)` per the
    /// paper's Eq. 11 ("we employ the normalizing divisor 2m according to
    /// Equation 6", whose left side is the squared distance; scores then
    /// land in [0, 2]).
    pub fn from_result(res: &MerlinResult, n: usize) -> Heatmap {
        let (min_l, max_l) = match (res.lengths.first(), res.lengths.last()) {
            (Some(a), Some(b)) => (a.m, b.m),
            _ => (0, 0),
        };
        let width = n.saturating_sub(min_l);
        let mut hm = Heatmap {
            min_l,
            max_l,
            width,
            data: vec![0.0; (max_l - min_l + 1) * width],
        };
        for lr in &res.lengths {
            for d in &lr.discords {
                if d.idx < width {
                    let score = (d.nn_dist * d.nn_dist) / (2.0 * d.m as f64);
                    hm.set(lr.m, d.idx, score);
                }
            }
        }
        hm
    }

    /// Max score (for display normalization).
    pub fn max_score(&self) -> f64 {
        self.data.iter().cloned().fold(0.0, f64::max)
    }

    /// Downsample by max-pooling to at most `(max_rows, max_cols)` — the
    /// rendering path for year-long series.
    pub fn downsample(&self, max_rows: usize, max_cols: usize) -> Heatmap {
        let rows = self.rows();
        let r_factor = rows.div_ceil(max_rows.max(1)).max(1);
        let c_factor = self.width.div_ceil(max_cols.max(1)).max(1);
        let new_rows = rows.div_ceil(r_factor);
        let new_cols = self.width.div_ceil(c_factor);
        let mut data = vec![0.0; new_rows * new_cols];
        for r in 0..rows {
            for c in 0..self.width {
                let v = self.data[r * self.width + c];
                let cell = &mut data[(r / r_factor) * new_cols + c / c_factor];
                if v > *cell {
                    *cell = v;
                }
            }
        }
        Heatmap {
            min_l: self.min_l,
            max_l: self.min_l + new_rows - 1, // row labels compressed
            width: new_cols,
            data,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::drag::Discord;
    use crate::coordinator::merlin::{LengthResult, MerlinResult};
    use crate::coordinator::metrics::MerlinMetrics;

    fn fake_result() -> MerlinResult {
        MerlinResult {
            lengths: vec![
                LengthResult {
                    m: 4,
                    r_used: 1.0,
                    retries: 0,
                    discords: vec![Discord { idx: 2, m: 4, nn_dist: 2.0 }],
                },
                LengthResult {
                    m: 5,
                    r_used: 1.0,
                    retries: 0,
                    discords: vec![
                        Discord { idx: 7, m: 5, nn_dist: 3.0 },
                        Discord { idx: 0, m: 5, nn_dist: 1.0 },
                    ],
                },
            ],
            metrics: MerlinMetrics::default(),
        }
    }

    #[test]
    fn scores_match_eq11() {
        let hm = Heatmap::from_result(&fake_result(), 20);
        assert_eq!(hm.rows(), 2);
        assert_eq!(hm.width, 16);
        assert!((hm.get(4, 2) - 4.0 / 8.0).abs() < 1e-12);
        assert!((hm.get(5, 7) - 9.0 / 10.0).abs() < 1e-12);
        assert!((hm.get(5, 0) - 1.0 / 10.0).abs() < 1e-12);
        assert_eq!(hm.get(4, 3), 0.0);
        assert!((hm.max_score() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn downsample_max_pools() {
        let hm = Heatmap::from_result(&fake_result(), 20);
        let small = hm.downsample(1, 4);
        assert_eq!(small.rows(), 1);
        assert_eq!(small.width, 4);
        // Col block [4..8) holds the 0.9 score.
        assert!((small.data[1] - 0.9).abs() < 1e-12);
    }
}
