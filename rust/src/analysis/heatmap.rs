//! Discord heatmap (Eq. 11): a `(maxL - minL + 1) x (n - minL + 1)`
//! intensity matrix where cell `(m, i)` is the normalized
//! nearest-neighbor distance of discord `T[i, m]`:
//!
//! ```text
//! heatmap(m, i) = nnDist^2(T_i,m) / (2m)        (Eq. 11, squared form)
//! ```
//!
//! Non-discord cells are 0.  Built from a MERLIN run with `top_k = 0`
//! (collect all survivors per length).

use crate::coordinator::merlin::MerlinResult;
use crate::core::windows::window_count;

/// Dense heatmap with length-major rows.
#[derive(Clone, Debug)]
pub struct Heatmap {
    pub min_l: usize,
    pub max_l: usize,
    /// Number of index columns: the window count at `minL`
    /// (`n - minL + 1` — the final window index `n - minL` is a valid
    /// column; an earlier `n - minL` sizing silently dropped discords at
    /// the last window).
    pub width: usize,
    /// Row-major `(maxL - minL + 1) x width` scores in `[0, 1]`-ish range
    /// (Eq. 11's normalization bounds scores by 2).
    pub data: Vec<f64>,
}

impl Heatmap {
    /// Row count; 0 for the empty heatmap (no cells at all).
    pub fn rows(&self) -> usize {
        if self.data.is_empty() {
            0
        } else {
            self.max_l - self.min_l + 1
        }
    }

    /// True when the heatmap has no cells (empty MERLIN result, or a
    /// series shorter than `min_l`).
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn get(&self, m: usize, i: usize) -> f64 {
        self.data[(m - self.min_l) * self.width + i]
    }

    #[inline]
    fn set(&mut self, m: usize, i: usize, v: f64) {
        self.data[(m - self.min_l) * self.width + i] = v;
    }

    /// Build from a MERLIN result over an `n`-sample series.
    ///
    /// Uses the squared-distance normalization `nnDist^2 / (2m)` per the
    /// paper's Eq. 11 ("we employ the normalizing divisor 2m according to
    /// Equation 6", whose left side is the squared distance; scores then
    /// land in [0, 2]).
    pub fn from_result(res: &MerlinResult, n: usize) -> Heatmap {
        let (min_l, max_l) = match (res.lengths.first(), res.lengths.last()) {
            (Some(a), Some(b)) => (a.m, b.m),
            // No lengths: an actually-empty heatmap (no rows, no cells)
            // instead of a fabricated 1 x n all-zero matrix.
            _ => return Heatmap { min_l: 0, max_l: 0, width: 0, data: Vec::new() },
        };
        // Length-m windows start at 0..=n-m, so row minL has
        // `n - minL + 1` valid columns (0 when the series is shorter
        // than minL, making the heatmap empty).
        let width = window_count(n, min_l);
        let mut hm = Heatmap {
            min_l,
            max_l,
            width,
            data: vec![0.0; (max_l - min_l + 1) * width],
        };
        for lr in &res.lengths {
            for d in &lr.discords {
                if d.idx < width {
                    let score = (d.nn_dist * d.nn_dist) / (2.0 * d.m as f64);
                    hm.set(lr.m, d.idx, score);
                }
            }
        }
        hm
    }

    /// Max score (for display normalization).
    pub fn max_score(&self) -> f64 {
        self.data.iter().cloned().fold(0.0, f64::max)
    }

    /// Downsample by max-pooling to at most `(max_rows, max_cols)` — the
    /// rendering path for year-long series.
    pub fn downsample(&self, max_rows: usize, max_cols: usize) -> Heatmap {
        if self.data.is_empty() {
            return self.clone();
        }
        let rows = self.rows();
        let r_factor = rows.div_ceil(max_rows.max(1)).max(1);
        let c_factor = self.width.div_ceil(max_cols.max(1)).max(1);
        let new_rows = rows.div_ceil(r_factor);
        let new_cols = self.width.div_ceil(c_factor);
        let mut data = vec![0.0; new_rows * new_cols];
        for r in 0..rows {
            for c in 0..self.width {
                let v = self.data[r * self.width + c];
                let cell = &mut data[(r / r_factor) * new_cols + c / c_factor];
                if v > *cell {
                    *cell = v;
                }
            }
        }
        Heatmap {
            min_l: self.min_l,
            max_l: self.min_l + new_rows - 1, // row labels compressed
            width: new_cols,
            data,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::drag::Discord;
    use crate::coordinator::merlin::{LengthResult, MerlinResult};
    use crate::coordinator::metrics::MerlinMetrics;

    fn fake_result() -> MerlinResult {
        MerlinResult {
            lengths: vec![
                LengthResult {
                    m: 4,
                    r_used: 1.0,
                    retries: 0,
                    discords: vec![Discord { idx: 2, m: 4, nn_dist: 2.0 }],
                },
                LengthResult {
                    m: 5,
                    r_used: 1.0,
                    retries: 0,
                    discords: vec![
                        Discord { idx: 7, m: 5, nn_dist: 3.0 },
                        Discord { idx: 0, m: 5, nn_dist: 1.0 },
                    ],
                },
            ],
            metrics: MerlinMetrics::default(),
        }
    }

    #[test]
    fn scores_match_eq11() {
        let hm = Heatmap::from_result(&fake_result(), 20);
        assert_eq!(hm.rows(), 2);
        // n = 20, minL = 4: windows 0..=16, so 17 columns.
        assert_eq!(hm.width, 17);
        assert!((hm.get(4, 2) - 4.0 / 8.0).abs() < 1e-12);
        assert!((hm.get(5, 7) - 9.0 / 10.0).abs() < 1e-12);
        assert!((hm.get(5, 0) - 1.0 / 10.0).abs() < 1e-12);
        assert_eq!(hm.get(4, 3), 0.0);
        assert!((hm.max_score() - 0.9).abs() < 1e-12);
    }

    /// Regression for the off-by-one: a discord at the *last* valid
    /// window index (`idx == n - minL` at `m == minL`) used to fail the
    /// `idx < width` guard and silently vanish from the heatmap and
    /// every ranking built on it.
    #[test]
    fn last_window_discord_is_kept() {
        let n = 20;
        let res = MerlinResult {
            lengths: vec![LengthResult {
                m: 4,
                r_used: 1.0,
                retries: 0,
                discords: vec![Discord { idx: 16, m: 4, nn_dist: 2.0 }],
            }],
            metrics: MerlinMetrics::default(),
        };
        let hm = Heatmap::from_result(&res, n);
        assert_eq!(hm.width, 17);
        assert!((hm.get(4, 16) - 4.0 / 8.0).abs() < 1e-12, "last-window discord dropped");
        assert!((hm.max_score() - 0.5).abs() < 1e-12);
        let top = crate::analysis::ranking::top_k_interesting(&hm, 1);
        assert_eq!(top.len(), 1);
        assert_eq!((top[0].idx, top[0].m), (16, 4));
    }

    #[test]
    fn empty_result_gives_empty_heatmap() {
        let res = MerlinResult { lengths: Vec::new(), metrics: MerlinMetrics::default() };
        let hm = Heatmap::from_result(&res, 50);
        assert!(hm.is_empty());
        assert_eq!((hm.rows(), hm.width, hm.data.len()), (0, 0, 0));
        assert_eq!(hm.max_score(), 0.0);
        let small = hm.downsample(4, 4);
        assert!(small.is_empty(), "downsampling empty stays empty");
        assert!(crate::analysis::ranking::top_k_interesting(&hm, 3).is_empty());
    }

    #[test]
    fn series_shorter_than_min_l_gives_empty_heatmap() {
        // Zero windows at minL: no fabricated columns.
        let hm = Heatmap::from_result(&fake_result(), 3);
        assert!(hm.is_empty());
        assert_eq!(hm.rows(), 0);
    }

    #[test]
    fn downsample_max_pools() {
        let hm = Heatmap::from_result(&fake_result(), 20);
        let small = hm.downsample(1, 4);
        assert_eq!(small.rows(), 1);
        assert_eq!(small.width, 4);
        // Col block [4..8) holds the 0.9 score.
        assert!((small.data[1] - 0.9).abs() < 1e-12);
    }
}
