//! Minimal image output: binary PGM (grayscale) and PPM (color) writers,
//! plus the heatmap renderer.  No image crates are available offline;
//! PGM/PPM open everywhere and convert trivially.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use super::heatmap::Heatmap;

/// Write an 8-bit grayscale PGM (`P5`).
pub fn write_pgm(path: impl AsRef<Path>, width: usize, height: usize, pixels: &[u8]) -> Result<()> {
    anyhow::ensure!(pixels.len() == width * height, "pixel buffer size mismatch");
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    write!(f, "P5\n{width} {height}\n255\n")?;
    f.write_all(pixels)?;
    Ok(())
}

/// Write an 8-bit RGB PPM (`P6`).
pub fn write_ppm(path: impl AsRef<Path>, width: usize, height: usize, rgb: &[u8]) -> Result<()> {
    anyhow::ensure!(rgb.len() == 3 * width * height, "pixel buffer size mismatch");
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    write!(f, "P6\n{width} {height}\n255\n")?;
    f.write_all(rgb)?;
    Ok(())
}

/// Render a heatmap to a "hot" color PPM, downsampled to at most
/// `max_w x max_h` cells.  Rows = lengths (minL at top), cols = indices.
pub fn render_heatmap(hm: &Heatmap, path: impl AsRef<Path>, max_w: usize, max_h: usize) -> Result<()> {
    let small = hm.downsample(max_h, max_w);
    let (w, h) = (small.width.max(1), small.rows().max(1));
    let peak = small.max_score().max(1e-12);
    let mut rgb = vec![0u8; 3 * w * h];
    for r in 0..h {
        for c in 0..small.width {
            let v = (small.data[r * small.width + c] / peak).clamp(0.0, 1.0);
            let (rr, gg, bb) = hot_color(v);
            let o = 3 * (r * w + c);
            rgb[o] = rr;
            rgb[o + 1] = gg;
            rgb[o + 2] = bb;
        }
    }
    write_ppm(path, w, h, &rgb)
}

/// Black -> red -> yellow -> white ramp.
fn hot_color(v: f64) -> (u8, u8, u8) {
    let x = v.clamp(0.0, 1.0);
    let r = (3.0 * x).min(1.0);
    let g = (3.0 * x - 1.0).clamp(0.0, 1.0);
    let b = (3.0 * x - 2.0).clamp(0.0, 1.0);
    ((r * 255.0) as u8, (g * 255.0) as u8, (b * 255.0) as u8)
}

/// Render a 1-D series as a simple line plot PGM (for the examples).
pub fn render_series(values: &[f64], path: impl AsRef<Path>, width: usize, height: usize) -> Result<()> {
    let n = values.len();
    anyhow::ensure!(n >= 2 && width >= 2 && height >= 2, "degenerate plot");
    let mut px = vec![255u8; width * height];
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in values {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(1e-12);
    let y_of = |v: f64| ((1.0 - (v - lo) / span) * (height - 1) as f64) as usize;
    let mut prev_y = y_of(values[0]);
    for c in 0..width {
        let i = c * (n - 1) / (width - 1);
        let y = y_of(values[i]);
        let (a, b) = if y <= prev_y { (y, prev_y) } else { (prev_y, y) };
        for yy in a..=b {
            px[yy * width + c] = 0;
        }
        prev_y = y;
    }
    write_pgm(path, width, height, &px)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("palmad_img");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn pgm_roundtrip_header() {
        let p = tmp("x.pgm");
        write_pgm(&p, 4, 2, &[0, 64, 128, 255, 1, 2, 3, 4]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P5\n4 2\n255\n"));
        assert_eq!(bytes.len(), 11 + 8);
    }

    #[test]
    fn ppm_size_check() {
        assert!(write_ppm(tmp("bad.ppm"), 2, 2, &[0u8; 5]).is_err());
        write_ppm(tmp("ok.ppm"), 2, 2, &[0u8; 12]).unwrap();
    }

    #[test]
    fn hot_ramp_endpoints() {
        assert_eq!(hot_color(0.0), (0, 0, 0));
        assert_eq!(hot_color(1.0), (255, 255, 255));
        let (r, g, b) = hot_color(0.34);
        assert!(r == 255 && g < 20 && b == 0, "{r} {g} {b}");
    }

    #[test]
    fn series_plot_writes() {
        let vals: Vec<f64> = (0..100).map(|i| (i as f64 * 0.2).sin()).collect();
        let p = tmp("series.pgm");
        render_series(&vals, &p, 200, 60).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(bytes.len(), b"P5\n200 60\n255\n".len() + 200 * 60);
        // Some black pixels exist.
        assert!(bytes.iter().skip(15).any(|&b| b == 0));
    }

    #[test]
    fn heatmap_renders() {
        use crate::analysis::heatmap::Heatmap;
        let hm = Heatmap { min_l: 4, max_l: 5, width: 10, data: {
            let mut d = vec![0.0; 20];
            d[3] = 1.0;
            d
        }};
        render_heatmap(&hm, tmp("hm.ppm"), 10, 2).unwrap();
    }

    #[test]
    fn empty_heatmap_renders_placeholder() {
        // The degenerate (no-result) heatmap must stay renderable: a
        // 1 x 1 black placeholder, not a panic or a zero-sized header.
        use crate::analysis::heatmap::Heatmap;
        let hm = Heatmap { min_l: 0, max_l: 0, width: 0, data: Vec::new() };
        let p = tmp("hm_empty.ppm");
        render_heatmap(&hm, &p, 10, 2).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P6\n1 1\n255\n"));
        assert_eq!(bytes.len(), b"P6\n1 1\n255\n".len() + 3);
    }
}
