//! Discord analysis & visualization: the §5 case-study tooling.
//!
//! - [`heatmap`] — the discord heatmap (Eq. 11): anomaly score as color
//!   intensity over (length, index).
//! - [`ranking`] — Eq. 12: extracting the most "interesting" discords
//!   across lengths from the heatmap.
//! - [`image`] — PGM/PPM writers (no image crates offline).
//! - [`report`] — text/JSON experiment tables.

pub mod heatmap;
pub mod image;
pub mod ranking;
pub mod report;
