//! Discord analysis & visualization: the §5 case-study tooling.
//!
//! - [`heatmap`] — the discord heatmap (Eq. 11): anomaly score as color
//!   intensity over (length, index).
//! - [`ranking`] — Eq. 12: extracting the most "interesting" discords
//!   across lengths from the heatmap.
//! - [`image`] — PGM/PPM writers (no image crates offline).
//! - [`report`] — text/JSON experiment tables.
//!
//! This layer faces user-supplied data (parsed CSVs with NaN cells,
//! empty discovery results), so panicking `unwrap`s are denied outright
//! — handle the degenerate case or use a total ordering instead.  The
//! same gate covers `core::windows`; `scripts/ci.sh --clippy` runs it.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]
#![forbid(unsafe_code)]

pub mod heatmap;
pub mod image;
pub mod ranking;
pub mod report;
