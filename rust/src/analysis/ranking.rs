//! Discord interest ranking across lengths (Eq. 12): the most interesting
//! discord maximizes the heatmap score over all lengths sharing its index;
//! top-k extraction de-overlaps by index (using each winner's own length).
//!
//! NaN placement: a NaN heatmap cell (a NaN sample in the source series
//! propagates into nnDist) never wins a ranking — the per-index max
//! ignores it, and the ordering is the total [`cmp_score_desc`] (NaN
//! last), so ranking can no longer panic on such input.

use crate::core::windows::cmp_score_desc;

use super::heatmap::Heatmap;

/// A ranked multi-length discord.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RankedDiscord {
    pub idx: usize,
    pub m: usize,
    /// Eq. 11 score (normalized squared distance).
    pub score: f64,
}

/// Eq. 12 over the heatmap: for each index, the best length; then the
/// top-k indices by that score, mutually non-overlapping (an index is
/// excluded if it falls within a previous winner's window).
pub fn top_k_interesting(hm: &Heatmap, k: usize) -> Vec<RankedDiscord> {
    let rows = hm.rows();
    // Best (score, m) per index.
    let mut best: Vec<(f64, usize)> = vec![(0.0, 0); hm.width];
    for r in 0..rows {
        let m = hm.min_l + r;
        for i in 0..hm.width {
            let v = hm.data[r * hm.width + i];
            if v > best[i].0 {
                best[i] = (v, m);
            }
        }
    }
    let mut order: Vec<usize> = (0..hm.width).filter(|&i| best[i].0 > 0.0).collect();
    order.sort_by(|&a, &b| cmp_score_desc(best[a].0, best[b].0).then(a.cmp(&b)));

    let mut out: Vec<RankedDiscord> = Vec::new();
    'outer: for i in order {
        let (score, m) = best[i];
        for w in &out {
            // Overlap if either window contains the other's start.
            let sep = w.m.max(m);
            if w.idx.abs_diff(i) < sep {
                continue 'outer;
            }
        }
        out.push(RankedDiscord { idx: i, m, score });
        if out.len() == k {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::heatmap::Heatmap;

    fn hm(min_l: usize, rows: usize, width: usize) -> Heatmap {
        Heatmap { min_l, max_l: min_l + rows - 1, width, data: vec![0.0; rows * width] }
    }

    #[test]
    fn picks_best_length_per_index() {
        let mut h = hm(4, 3, 30);
        h.data[30 * 0 + 10] = 0.3; // m=4, idx=10
        h.data[30 * 2 + 10] = 0.7; // m=6, idx=10
        h.data[30 * 1 + 25] = 0.5; // m=5, idx=25
        let top = top_k_interesting(&h, 3);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0], RankedDiscord { idx: 10, m: 6, score: 0.7 });
        assert_eq!(top[1], RankedDiscord { idx: 25, m: 5, score: 0.5 });
    }

    #[test]
    fn de_overlaps_by_window() {
        let mut h = hm(10, 1, 50);
        h.data[20] = 0.9;
        h.data[25] = 0.8; // within 10 of the winner -> excluded
        h.data[35] = 0.7; // far enough
        let top = top_k_interesting(&h, 5);
        let idxs: Vec<usize> = top.iter().map(|r| r.idx).collect();
        assert_eq!(idxs, vec![20, 35]);
    }

    #[test]
    fn k_truncates() {
        let mut h = hm(5, 1, 100);
        for i in [0, 20, 40, 60] {
            h.data[i] = 0.5 + i as f64 / 1000.0;
        }
        assert_eq!(top_k_interesting(&h, 2).len(), 2);
    }

    #[test]
    fn empty_heatmap_empty_result() {
        let h = hm(5, 2, 10);
        assert!(top_k_interesting(&h, 3).is_empty());
    }

    #[test]
    fn zero_cell_heatmap_empty_result() {
        // The degenerate (empty MerlinResult) heatmap: no rows, no cells.
        let h = Heatmap { min_l: 0, max_l: 0, width: 0, data: Vec::new() };
        assert_eq!(h.rows(), 0);
        assert!(top_k_interesting(&h, 3).is_empty());
    }

    #[test]
    fn nan_cells_never_panic_or_win() {
        let mut h = hm(8, 1, 40);
        h.data[5] = f64::NAN;
        h.data[25] = 0.5;
        h.data[33] = f64::NAN;
        let top = top_k_interesting(&h, 5);
        assert_eq!(top.len(), 1, "NaN cells are not rankable: {top:?}");
        assert_eq!(top[0].idx, 25);
    }
}
