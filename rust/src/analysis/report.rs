//! Experiment report tables: fixed-width text (for the terminal and
//! EXPERIMENTS.md) and JSON (for downstream plotting), built on
//! [`crate::util::json`].

use std::fmt::Write as _;

use crate::util::json::Json;

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Render as aligned text.
    pub fn to_text(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut s = String::new();
        // ok-drop: fmt::Write into String cannot fail (and the same for
        // every discarded write!/writeln! in this renderer).
        let _ = writeln!(s, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut out = String::new();
            for (c, w) in cells.iter().zip(widths) {
                // ok-drop: infallible String write (see above).
                let _ = write!(out, "{c:>w$}  ", w = w);
            }
            out.trim_end().to_string()
        };
        // ok-drop: infallible String writes (see above).
        let _ = writeln!(s, "{}", line(&self.headers, &widths));
        let _ = writeln!(s, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            // ok-drop: infallible String write (see above).
            let _ = writeln!(s, "{}", line(row, &widths));
        }
        s
    }

    /// Render as a JSON object (`{title, headers, rows}`).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("title", self.title.clone())
            .set("headers", self.headers.clone())
            .set(
                "rows",
                Json::Arr(self.rows.iter().map(|r| Json::from(r.clone())).collect()),
            )
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new("demo", &["name", "n"]);
        t.row(&["ecg".into(), "45000".into()]);
        t.row(&["rw".into(), "7".into()]);
        let s = t.to_text();
        assert!(s.contains("== demo =="));
        assert!(s.contains("45000"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only one".into()]);
    }

    #[test]
    fn json_shape() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["1".into()]);
        assert_eq!(t.to_json().to_string(), r#"{"title":"x","headers":["a"],"rows":[["1"]]}"#);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(0.0000005), "0.5us");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(1.5), "1.500s");
    }
}
