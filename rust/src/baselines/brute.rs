//! Exact brute-force discord discovery: the O(n^2) oracle every fast path
//! is validated against, with optional early-abandoning to make it usable
//! as a (weak) baseline on real sizes.

use crate::core::distance::{ed2_early_abandon, is_flat, znorm};
use crate::core::stats::RollingStats;
use crate::core::topk::{top_k_non_overlapping, Scored};
use crate::coordinator::drag::Discord;

/// Exact nearest-neighbor distance profile (squared ED): for each window,
/// the min distance to any non-self match.  O(n^2 m) — small inputs only.
///
/// Applies the stack-wide flat-window convention (see
/// [`crate::core::distance::FLAT_EPS`]): flat/flat pairs are 0, flat/normal
/// pairs are `2m` — NOT the `m` the bare znorm-subtract arithmetic would
/// produce (a zero vector against a unit-norm one).
pub fn nn_profile(t: &[f64], m: usize) -> Vec<f64> {
    let nwin = t.len() + 1 - m;
    let stats = RollingStats::compute(t, m);
    let flat: Vec<bool> =
        stats.sig.iter().zip(&stats.mu).map(|(&s, &mu)| is_flat(s, mu)).collect();
    let norms: Vec<Vec<f64>> = (0..nwin).map(|i| znorm(&t[i..i + m])).collect();
    let mut nn = vec![f64::INFINITY; nwin];
    let two_m = 2.0 * m as f64;
    for i in 0..nwin {
        for j in i + m..nwin {
            let d = if flat[i] || flat[j] {
                Some(if flat[i] && flat[j] { 0.0 } else { two_m })
            } else {
                // Early abandon against the worse of the two current minima.
                ed2_early_abandon(&norms[i], &norms[j], nn[i].max(nn[j]))
            };
            if let Some(d) = d {
                if d < nn[i] {
                    nn[i] = d;
                }
                if d < nn[j] {
                    nn[j] = d;
                }
            }
        }
    }
    nn
}

/// Exact top-k discords (non-overlapping), ED units.
pub fn top_k_discords(t: &[f64], m: usize, k: usize) -> Vec<Discord> {
    let nn = nn_profile(t, m);
    let scored: Vec<Scored> = nn
        .iter()
        .enumerate()
        .filter(|(_, d)| d.is_finite())
        .map(|(idx, &d)| Scored { idx, nn_dist: d.sqrt() })
        .collect();
    top_k_non_overlapping(&scored, m, k)
        .into_iter()
        .map(|s| Discord { idx: s.idx, m, nn_dist: s.nn_dist })
        .collect()
}

/// Exact range discords (every window with nnDist >= r), ED units.
pub fn range_discords(t: &[f64], m: usize, r: f64) -> Vec<Discord> {
    let nn = nn_profile(t, m);
    nn.iter()
        .enumerate()
        .filter(|(_, d)| d.is_finite() && **d >= r * r)
        .map(|(idx, &d)| Discord { idx, m, nn_dist: d.sqrt() })
        .collect()
}

/// Quick sanity wrapper reused by several tests: stats + profile agree.
pub fn stats_for(t: &[f64], m: usize) -> RollingStats {
    RollingStats::compute(t, m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::ed2norm;
    use crate::util::rng::Rng;

    fn walk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect()
    }

    #[test]
    fn profile_matches_naive_loop() {
        let t = walk(150, 1);
        let m = 12;
        let nn = nn_profile(&t, m);
        let nwin = t.len() - m + 1;
        for i in 0..nwin {
            let mut best = f64::INFINITY;
            for j in 0..nwin {
                if i.abs_diff(j) >= m {
                    best = best.min(ed2norm(&t[i..i + m], &t[j..j + m]));
                }
            }
            assert!((nn[i] - best).abs() < 1e-9 * (1.0 + best), "i={i}: {} vs {best}", nn[i]);
        }
    }

    #[test]
    fn top1_is_argmax_of_profile() {
        let t = walk(200, 2);
        let m = 10;
        let nn = nn_profile(&t, m);
        let d = top_k_discords(&t, m, 1);
        assert_eq!(d.len(), 1);
        let best = nn.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!((d[0].nn_dist * d[0].nn_dist - best).abs() < 1e-9 * (1.0 + best));
    }

    #[test]
    fn range_discords_consistent_with_topk() {
        let t = walk(180, 3);
        let m = 8;
        let top = top_k_discords(&t, m, 1)[0];
        let range = range_discords(&t, m, top.nn_dist - 1e-9);
        assert!(range.iter().any(|d| d.idx == top.idx));
        // Nothing above the top discord's distance.
        let over = range_discords(&t, m, top.nn_dist + 1e-9);
        assert!(over.is_empty());
    }
}
