//! Serial DRAG (Yankov, Keogh, Rebbapragada 2007) — Alg. 2 of the PALMAD
//! paper, implemented faithfully: a growing candidate set over one forward
//! scan (selection), then one more scan refining candidates with
//! early-abandoning distances.
//!
//! This is PD3's serial ancestor and the engine of serial MERLIN; it is
//! also an independent oracle for the parallel path (they must return the
//! same discord set for any `r`).

use crate::core::distance::{ed2_early_abandon, is_flat, znorm};
use crate::core::stats::RollingStats;
use crate::coordinator::drag::Discord;

/// Candidate during refinement.
struct Cand {
    idx: usize,
    nn2: f64, // squared nnDist upper bound
}

/// Flat-convention-aware pairwise distance with early abandon; `None`
/// means "abandoned above `cutoff`" (see [`is_flat`]).
#[inline]
fn pair_dist(
    norms: &[Vec<f64>],
    flat: &[bool],
    m: usize,
    i: usize,
    j: usize,
    cutoff: f64,
) -> Option<f64> {
    if flat[i] || flat[j] {
        let d = if flat[i] && flat[j] { 0.0 } else { 2.0 * m as f64 };
        if d >= cutoff {
            None
        } else {
            Some(d)
        }
    } else {
        ed2_early_abandon(&norms[i], &norms[j], cutoff)
    }
}

/// Range discords with threshold `r` (ED units): all windows whose nearest
/// non-self match is at distance >= r, with exact nnDist.
pub fn drag(t: &[f64], m: usize, r: f64) -> Vec<Discord> {
    let Some(nw) = t.len().checked_sub(m) else { return Vec::new() };
    let nwin = nw + 1;
    if nwin == 0 {
        return Vec::new();
    }
    let r2 = r * r;
    let stats = RollingStats::compute(t, m);
    let flat: Vec<bool> =
        stats.sig.iter().zip(&stats.mu).map(|(&s, &mu)| is_flat(s, mu)).collect();
    let norms: Vec<Vec<f64>> = (0..nwin).map(|i| znorm(&t[i..i + m])).collect();

    // ---- Phase 1: candidate selection (Alg. 2 left) ----------------------
    let mut cands: Vec<usize> = vec![0];
    for s in 1..nwin {
        let mut is_cand = true;
        let mut k = 0;
        while k < cands.len() {
            let c = cands[k];
            if s.abs_diff(c) >= m {
                // dist < r kills both the candidate and s's candidacy.
                if pair_dist(&norms, &flat, m, s, c, r2).is_some() {
                    cands.swap_remove(k);
                    is_cand = false;
                    continue; // do not advance k (swap_remove)
                }
            }
            k += 1;
        }
        if is_cand {
            cands.push(s);
        }
    }

    // ---- Phase 2: refinement (Alg. 2 right) -------------------------------
    let mut refined: Vec<Cand> = cands.into_iter().map(|idx| Cand { idx, nn2: f64::INFINITY }).collect();
    for s in 0..nwin {
        let mut k = 0;
        while k < refined.len() {
            let c = &mut refined[k];
            if s.abs_diff(c.idx) >= m {
                // EarlyAbandonED against the candidate's current nnDist.
                if let Some(d) = pair_dist(&norms, &flat, m, s, c.idx, c.nn2) {
                    if d < r2 {
                        refined.swap_remove(k); // false positive
                        continue;
                    }
                    c.nn2 = d;
                }
            }
            k += 1;
        }
    }

    let mut out: Vec<Discord> = refined
        .into_iter()
        .filter(|c| c.nn2.is_finite())
        .map(|c| Discord { idx: c.idx, m, nn_dist: c.nn2.max(0.0).sqrt() })
        .collect();
    out.sort_by_key(|d| d.idx);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::brute;
    use crate::util::rng::Rng;

    fn walk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect()
    }

    #[test]
    fn matches_brute_force_range_discords() {
        for (seed, r) in [(1u64, 3.0), (2, 4.5), (3, 2.0)] {
            let t = walk(250, seed);
            let m = 12;
            let got = drag(&t, m, r);
            let mut want = brute::range_discords(&t, m, r);
            want.sort_by_key(|d| d.idx);
            assert_eq!(
                got.iter().map(|d| d.idx).collect::<Vec<_>>(),
                want.iter().map(|d| d.idx).collect::<Vec<_>>(),
                "seed {seed} r {r}"
            );
            for (g, w) in got.iter().zip(&want) {
                assert!((g.nn_dist - w.nn_dist).abs() < 1e-9 * (1.0 + w.nn_dist));
            }
        }
    }

    #[test]
    fn r_above_max_returns_empty() {
        let t = walk(200, 4);
        assert!(drag(&t, 10, 2.0 * (10f64).sqrt() + 0.1).is_empty());
    }

    #[test]
    fn agrees_with_pd3() {
        use crate::coordinator::drag::{pd3, Pd3Config};
        use crate::coordinator::metrics::DragMetrics;
        use crate::core::stats::RollingStats;
        use crate::engines::native::NativeEngine;
        use crate::engines::SeriesView;
        let t = walk(300, 5);
        let m = 14;
        let r = 3.0;
        let serial = drag(&t, m, r);
        let stats = RollingStats::compute(&t, m);
        let view = SeriesView { t: &t, stats: &stats };
        let engine = NativeEngine::with_segn(32);
        let mut metrics = DragMetrics::default();
        let mut parallel = pd3(&engine, &view, r, &Pd3Config::default(), &mut metrics).unwrap();
        parallel.sort_by_key(|d| d.idx);
        assert_eq!(
            serial.iter().map(|d| d.idx).collect::<Vec<_>>(),
            parallel.iter().map(|d| d.idx).collect::<Vec<_>>()
        );
    }
}
