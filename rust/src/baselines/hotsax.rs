//! HOTSAX (Keogh, Lin, Fu 2005): heuristically-ordered exact top-1
//! discord search.
//!
//! Outer loop visits candidate windows; inner loop visits comparison
//! windows; the best-so-far discord distance prunes candidates whose
//! nearest neighbor is already closer.  The SAX heuristic supplies the
//! magic ordering: outer candidates with the *rarest* SAX words first
//! (likely discords -> high best-so-far early), inner comparisons with
//! *same-word* windows first (likely close neighbors -> fast abandons).

use crate::core::distance::{ed2_early_abandon, znorm};
use crate::coordinator::drag::Discord;
use std::collections::HashMap;

/// HOTSAX parameters (word length / alphabet per the original paper).
#[derive(Clone, Copy, Debug)]
pub struct HotsaxConfig {
    pub paa_segments: usize,
    pub alphabet: usize,
}

impl Default for HotsaxConfig {
    fn default() -> Self {
        Self { paa_segments: 3, alphabet: 3 }
    }
}

/// Exact top-1 discord via the HOTSAX search order.
pub fn top1_discord(t: &[f64], m: usize, cfg: &HotsaxConfig) -> Option<Discord> {
    let nwin = t.len().checked_sub(m)? + 1;
    if nwin < m + 1 {
        // No window has a non-self match.
        return None;
    }
    // Precompute normalized windows once (memory O(n*m); HOTSAX sizes are
    // RAM-bounded by construction, §1).
    let norms: Vec<Vec<f64>> = (0..nwin).map(|i| znorm(&t[i..i + m])).collect();

    // SAX table: word -> window indices.
    let words = super::sax::sax_words(t, m, cfg.paa_segments, cfg.alphabet);
    let mut table: HashMap<&[u8], Vec<usize>> = HashMap::new();
    for (i, w) in words.iter().enumerate() {
        table.entry(w.as_slice()).or_default().push(i);
    }

    // Outer order: ascending bucket size (rarest words first).
    let mut outer: Vec<usize> = (0..nwin).collect();
    outer.sort_by_key(|&i| table[words[i].as_slice()].len());

    let mut best_dist = f64::NEG_INFINITY; // squared
    let mut best_idx = None;

    for &i in &outer {
        let mut nn = f64::INFINITY;
        let mut abandoned = false;
        // Inner pass 1: same-word windows (closest first, probably).
        for &j in &table[words[i].as_slice()] {
            if i.abs_diff(j) < m {
                continue;
            }
            if let Some(d) = ed2_early_abandon(&norms[i], &norms[j], nn) {
                nn = d;
            }
            if nn < best_dist {
                abandoned = true; // candidate i cannot beat best-so-far
                break;
            }
        }
        // Inner pass 2: everything else.
        if !abandoned {
            for j in 0..nwin {
                if i.abs_diff(j) < m || words[j] == words[i] {
                    continue;
                }
                if let Some(d) = ed2_early_abandon(&norms[i], &norms[j], nn) {
                    nn = d;
                }
                if nn < best_dist {
                    abandoned = true;
                    break;
                }
            }
        }
        if !abandoned && nn.is_finite() && nn > best_dist {
            best_dist = nn;
            best_idx = Some(i);
        }
    }
    best_idx.map(|idx| Discord { idx, m, nn_dist: best_dist.max(0.0).sqrt() })
}

/// Top-k by repeated top-1 with exclusion (the standard extension).
pub fn top_k_discords(t: &[f64], m: usize, k: usize, cfg: &HotsaxConfig) -> Vec<Discord> {
    // Simple correct implementation: compute the full profile ordering via
    // repeated exclusion on a copy of the candidate set.
    let mut out: Vec<Discord> = Vec::new();
    let mut excluded: Vec<(usize, usize)> = Vec::new(); // (start, end)
    for _ in 0..k {
        let found = top1_excluding(t, m, cfg, &excluded);
        match found {
            Some(d) => {
                excluded.push((d.idx.saturating_sub(m - 1), d.idx + m));
                out.push(d);
            }
            None => break,
        }
    }
    out
}

fn top1_excluding(
    t: &[f64],
    m: usize,
    cfg: &HotsaxConfig,
    excluded: &[(usize, usize)],
) -> Option<Discord> {
    let nwin = t.len().checked_sub(m)? + 1;
    let is_excluded = |i: usize| excluded.iter().any(|&(s, e)| i >= s && i < e);
    let norms: Vec<Vec<f64>> = (0..nwin).map(|i| znorm(&t[i..i + m])).collect();
    let words = super::sax::sax_words(t, m, cfg.paa_segments, cfg.alphabet);
    let mut table: HashMap<&[u8], Vec<usize>> = HashMap::new();
    for (i, w) in words.iter().enumerate() {
        table.entry(w.as_slice()).or_default().push(i);
    }
    let mut outer: Vec<usize> = (0..nwin).filter(|&i| !is_excluded(i)).collect();
    outer.sort_by_key(|&i| table[words[i].as_slice()].len());

    let mut best_dist = f64::NEG_INFINITY;
    let mut best_idx = None;
    for &i in &outer {
        let mut nn = f64::INFINITY;
        let mut dead = false;
        for &j in &table[words[i].as_slice()] {
            if i.abs_diff(j) < m {
                continue;
            }
            if let Some(d) = ed2_early_abandon(&norms[i], &norms[j], nn) {
                nn = d;
            }
            if nn < best_dist {
                dead = true;
                break;
            }
        }
        if !dead {
            for j in 0..nwin {
                if i.abs_diff(j) < m || words[j] == words[i] {
                    continue;
                }
                if let Some(d) = ed2_early_abandon(&norms[i], &norms[j], nn) {
                    nn = d;
                }
                if nn < best_dist {
                    dead = true;
                    break;
                }
            }
        }
        if !dead && nn.is_finite() && nn > best_dist {
            best_dist = nn;
            best_idx = Some(i);
        }
    }
    best_idx.map(|idx| Discord { idx, m, nn_dist: best_dist.max(0.0).sqrt() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::brute;
    use crate::util::rng::Rng;

    fn walk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect()
    }

    #[test]
    fn matches_brute_force_top1() {
        for seed in [1, 2, 3] {
            let t = walk(300, seed);
            let m = 16;
            let got = top1_discord(&t, m, &HotsaxConfig::default()).unwrap();
            let want = brute::top_k_discords(&t, m, 1)[0];
            assert!(
                (got.nn_dist - want.nn_dist).abs() < 1e-9 * (1.0 + want.nn_dist),
                "seed {seed}: {} vs {}",
                got.nn_dist,
                want.nn_dist
            );
        }
    }

    #[test]
    fn finds_planted_anomaly() {
        let mut t: Vec<f64> = (0..500).map(|i| (i as f64 * 0.25).sin()).collect();
        for (k, v) in t[250..270].iter_mut().enumerate() {
            *v += if k % 3 == 0 { 1.0 } else { -0.5 };
        }
        let d = top1_discord(&t, 20, &HotsaxConfig::default()).unwrap();
        assert!((231..=269).contains(&d.idx), "found {}", d.idx);
    }

    #[test]
    fn top_k_non_overlapping_and_sorted() {
        let t = walk(400, 4);
        let ds = top_k_discords(&t, 12, 3, &HotsaxConfig::default());
        assert_eq!(ds.len(), 3);
        for w in ds.windows(2) {
            assert!(w[0].nn_dist >= w[1].nn_dist);
            assert!(w[0].idx.abs_diff(w[1].idx) >= 12);
        }
    }

    #[test]
    fn too_short_series_returns_none() {
        let t = walk(20, 5);
        assert!(top1_discord(&t, 16, &HotsaxConfig::default()).is_none());
    }
}
