//! KBF — brute-force K-distance discord (Thuy, Anh, Chau 2021), the
//! Fig. 4 rival (their GPU version parallelizes the inner loop; this is
//! the same algorithm with the inner loop running across a thread pool,
//! the honest CPU equivalent).
//!
//! A K-distance discord maximizes the *sum* of distances to its K nearest
//! non-overlapping neighbors — the "twin freak"-robust variant of the
//! discord.  There is no early abandoning in KBF (that is the point of
//! the comparison: PALMAD's pruning vs brute force).

use crate::core::distance::znorm;
use crate::coordinator::drag::Discord;
use crate::util::pool::parallel_map_indexed;

/// Top-1 K-distance discord.  Returns the window index and the *sum* of
/// squared distances to its K nearest neighbors, sqrt'ed for consistency
/// with [`Discord::nn_dist`] reporting (documented in the bench output).
pub fn kbf_top1(t: &[f64], m: usize, k_neighbors: usize, threads: usize) -> Option<Discord> {
    let nwin = t.len().checked_sub(m)? + 1;
    if nwin < 2 {
        return None;
    }
    let norms: Vec<Vec<f64>> = (0..nwin).map(|i| znorm(&t[i..i + m])).collect();

    // For each candidate: K smallest distances to non-self matches (full
    // scan, no pruning — brute force by design).
    let scores = parallel_map_indexed(nwin, threads, |i| {
        let mut smallest: Vec<f64> = Vec::with_capacity(k_neighbors + 1);
        for j in 0..nwin {
            if i.abs_diff(j) < m {
                continue;
            }
            let mut d = 0.0;
            let (a, b) = (&norms[i], &norms[j]);
            for t in 0..m {
                let x = a[t] - b[t];
                d += x * x;
            }
            // Insert into the running K-smallest set.
            let pos = smallest.partition_point(|&x| x < d);
            if pos < k_neighbors {
                smallest.insert(pos, d);
                smallest.truncate(k_neighbors);
            }
        }
        if smallest.len() < k_neighbors {
            f64::NEG_INFINITY
        } else {
            smallest.iter().sum::<f64>()
        }
    });

    let (idx, &best) = scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).expect("kbf scores are finite or -inf, never NaN"))?;
    if best.is_finite() {
        Some(Discord { idx, m, nn_dist: best.max(0.0).sqrt() })
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::brute;
    use crate::util::rng::Rng;

    fn walk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect()
    }

    #[test]
    fn k1_matches_classic_discord() {
        let t = walk(250, 1);
        let m = 12;
        let got = kbf_top1(&t, m, 1, 2).unwrap();
        let want = brute::top_k_discords(&t, m, 1)[0];
        assert!((got.nn_dist - want.nn_dist).abs() < 1e-9 * (1.0 + want.nn_dist));
        assert_eq!(got.idx, want.idx);
    }

    #[test]
    fn k3_solves_twin_freak() {
        // Plant the SAME anomaly twice: a classic (K=1) discord scores the
        // twins low (they are each other's neighbor), K=3 re-surfaces them
        // above the background.
        let mut t: Vec<f64> = (0..600).map(|i| (i as f64 * 0.2).sin()).collect();
        let pattern: Vec<f64> = (0..20).map(|k| if k % 2 == 0 { 2.0 } else { -2.0 }).collect();
        for (k, v) in pattern.iter().enumerate() {
            t[150 + k] += v;
            t[450 + k] += v;
        }
        let m = 20;
        let k1 = kbf_top1(&t, m, 1, 2).unwrap();
        let k3 = kbf_top1(&t, m, 3, 2).unwrap();
        let near_planted = |idx: usize| {
            (131..=169).contains(&idx) || (431..=469).contains(&idx)
        };
        // With K=3 the twins dominate.
        assert!(near_planted(k3.idx), "K=3 found {}", k3.idx);
        // And K=3 must score them strictly higher than K=1 does.
        assert!(k3.nn_dist > k1.nn_dist);
    }

    #[test]
    fn thread_count_invariant() {
        let t = walk(200, 3);
        let a = kbf_top1(&t, 10, 2, 1).unwrap();
        let b = kbf_top1(&t, 10, 2, 8).unwrap();
        assert_eq!(a.idx, b.idx);
        assert_eq!(a.nn_dist, b.nn_dist);
    }
}
