//! Serial MERLIN (Nakamura, Imamura, Mercer, Keogh 2020) — Alg. 1 of the
//! PALMAD paper over the serial DRAG, with from-scratch per-length window
//! normalization (exactly the redundancy PALMAD's recurrences remove).
//!
//! Used as the ablation/"paper omits it" baseline: PALMAD must return the
//! same discords, faster.

use crate::core::topk::{top_k_non_overlapping, Scored};
use crate::coordinator::drag::Discord;

use super::drag_serial;

/// Serial MERLIN outcome per length.
#[derive(Clone, Debug)]
pub struct SerialLengthResult {
    pub m: usize,
    pub r_used: f64,
    pub discords: Vec<Discord>,
}

/// Run serial MERLIN over `[min_l, max_l]`, top-k per length (0 = all).
pub fn merlin(t: &[f64], min_l: usize, max_l: usize, top_k: usize) -> Vec<SerialLengthResult> {
    assert!(3 <= min_l && min_l <= max_l);
    let mut out: Vec<SerialLengthResult> = Vec::new();
    let mut last5: Vec<f64> = Vec::new();
    for m in min_l..=max_l {
        let step = m - min_l;
        let max_r = 2.0 * (m as f64).sqrt();
        let r_floor = 1e-4 * max_r;
        let mut r = if step == 0 {
            max_r
        } else if step <= 4 {
            0.99 * last5.last().copied().expect("step >= 1 pushed a prior radius")
        } else {
            let (mu, sd) = mean_std(&last5);
            (mu - 2.0 * sd).clamp(r_floor, max_r)
        };
        let mut retries = 0;
        let (r_used, picked) = loop {
            let ds = drag_serial::drag(t, m, r);
            let picked = pick(&ds, m, top_k);
            let enough = if top_k == 0 { !picked.is_empty() } else { picked.len() >= top_k };
            if enough || r <= r_floor || retries > 80 {
                break (r, picked);
            }
            retries += 1;
            r = if step == 0 {
                0.5 * r
            } else if step <= 4 {
                0.99 * r
            } else {
                let (mu, sd) = mean_std(&last5);
                let dec = if sd > 1e-12 * (1.0 + mu) { sd } else { 0.05 * mu.max(1e-9) };
                (r - dec).max(r_floor)
            };
        };
        let min_nn = picked.iter().map(|d| d.nn_dist).fold(f64::INFINITY, f64::min);
        last5.push(if min_nn.is_finite() {
            min_nn
        } else {
            last5.last().copied().unwrap_or(0.5 * max_r)
        });
        if last5.len() > 5 {
            last5.remove(0);
        }
        out.push(SerialLengthResult { m, r_used, discords: picked });
    }
    out
}

fn pick(ds: &[Discord], m: usize, k: usize) -> Vec<Discord> {
    let scored: Vec<Scored> = ds.iter().map(|d| Scored { idx: d.idx, nn_dist: d.nn_dist }).collect();
    top_k_non_overlapping(&scored, m, k)
        .into_iter()
        .map(|s| Discord { idx: s.idx, m, nn_dist: s.nn_dist })
        .collect()
}

fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mu = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / n;
    (mu, var.max(0.0).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::merlin::{Merlin, MerlinConfig};
    use crate::core::series::TimeSeries;
    use crate::engines::native::NativeEngine;
    use crate::util::rng::Rng;

    fn walk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect()
    }

    #[test]
    fn serial_and_parallel_merlin_agree() {
        let values = walk(400, 31);
        let serial = merlin(&values, 10, 22, 1);
        let t = TimeSeries::new("w", values);
        let engine = NativeEngine::with_segn(64);
        let cfg = MerlinConfig { min_l: 10, max_l: 22, top_k: 1, ..Default::default() };
        let par = Merlin::new(&engine, cfg).run(&t).unwrap();
        assert_eq!(serial.len(), par.lengths.len());
        for (s, p) in serial.iter().zip(&par.lengths) {
            assert_eq!(s.m, p.m);
            assert_eq!(s.discords.len(), 1, "m={}", s.m);
            assert_eq!(p.discords.len(), 1, "m={}", p.m);
            // Same discord distance (indices may differ on exact ties).
            assert!(
                (s.discords[0].nn_dist - p.discords[0].nn_dist).abs()
                    < 1e-6 * (1.0 + s.discords[0].nn_dist),
                "m={}: serial {} vs parallel {}",
                s.m,
                s.discords[0].nn_dist,
                p.discords[0].nn_dist
            );
        }
    }

    #[test]
    fn lengths_covered() {
        let values = walk(300, 32);
        let res = merlin(&values, 8, 12, 1);
        let ms: Vec<usize> = res.iter().map(|r| r.m).collect();
        assert_eq!(ms, vec![8, 9, 10, 11, 12]);
    }
}
