//! Baseline & rival algorithms, reimplemented from their papers.
//!
//! The PALMAD paper compares against published systems whose sources are
//! unavailable (KBF_GPU, Zhu et al.'s framework) and builds on serial
//! algorithms (HOTSAX, DRAG, MERLIN).  Each is implemented here from its
//! original description so the benchmark harness can regenerate the
//! paper's comparison *shapes* on one testbed:
//!
//! | module          | algorithm                              | role |
//! |-----------------|----------------------------------------|------|
//! | [`brute`]       | exact O(n^2 m) top-k discord           | test oracle |
//! | [`sax`]         | PAA + SAX discretization               | HOTSAX substrate |
//! | [`hotsax`]      | Keogh et al. 2005 heuristic search     | serial reference |
//! | [`drag_serial`] | Yankov/Keogh 2007 two-phase DRAG       | PD3's serial ancestor |
//! | [`merlin_serial`]| Nakamura et al. 2020 MERLIN           | PALMAD's serial ancestor |
//! | [`kbf`]         | Thuy et al. 2021 K-distance brute force| Fig. 4 rival |
//! | [`zhu`]         | Zhu et al. 2021 top-1 early-stop       | Fig. 5 rival |
//! | [`stomp`]       | Zhu et al. 2016 matrix profile         | MP comparison (§1) |
#![forbid(unsafe_code)]

pub mod brute;
pub mod drag_serial;
pub mod hotsax;
pub mod kbf;
pub mod merlin_serial;
pub mod sax;
pub mod stomp;
pub mod zhu;
