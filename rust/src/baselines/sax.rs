//! SAX — Symbolic Aggregate approXimation (Lin et al. 2003).
//!
//! The discretization substrate HOTSAX needs: each window is z-normalized,
//! reduced to `w` PAA segments, and each segment mapped to one of `a`
//! symbols via equiprobable Gaussian breakpoints.

use crate::core::distance::znorm;

/// Gaussian breakpoints for alphabet sizes 2..=10 (standard SAX tables).
fn breakpoints(a: usize) -> &'static [f64] {
    match a {
        2 => &[0.0],
        3 => &[-0.43, 0.43],
        4 => &[-0.67, 0.0, 0.67],
        5 => &[-0.84, -0.25, 0.25, 0.84],
        6 => &[-0.97, -0.43, 0.0, 0.43, 0.97],
        7 => &[-1.07, -0.57, -0.18, 0.18, 0.57, 1.07],
        8 => &[-1.15, -0.67, -0.32, 0.0, 0.32, 0.67, 1.15],
        9 => &[-1.22, -0.76, -0.43, -0.14, 0.14, 0.43, 0.76, 1.22],
        10 => &[-1.28, -0.84, -0.52, -0.25, 0.0, 0.25, 0.52, 0.84, 1.28],
        _ => panic!("unsupported SAX alphabet size {a} (2..=10)"),
    }
}

/// Piecewise Aggregate Approximation of a (z-normalized) window into `w`
/// equal segments (handles non-divisible lengths by fractional weighting).
pub fn paa(x: &[f64], w: usize) -> Vec<f64> {
    let m = x.len();
    assert!(w >= 1 && w <= m);
    if m % w == 0 {
        let seg = m / w;
        return x.chunks(seg).map(|c| c.iter().sum::<f64>() / seg as f64).collect();
    }
    // Fractional assignment: element i spreads over segments it overlaps.
    let mut out = vec![0.0; w];
    for i in 0..m {
        let lo = i as f64 * w as f64 / m as f64;
        let hi = (i + 1) as f64 * w as f64 / m as f64;
        let (s0, s1) = (lo.floor() as usize, (hi.ceil() as usize).min(w));
        for s in s0..s1 {
            let seg_lo = s as f64;
            let seg_hi = s as f64 + 1.0;
            let overlap = hi.min(seg_hi) - lo.max(seg_lo);
            if overlap > 0.0 {
                out[s] += x[i] * overlap;
            }
        }
    }
    // Overlaps are measured in segment space (each segment has width 1.0
    // there and total overlap exactly 1.0), so `out` already holds the
    // weighted averages.
    out
}

/// SAX word of one raw window: z-normalize, PAA to `w`, discretize to
/// alphabet size `a`.  Symbols are 0-based.
pub fn sax_word(window: &[f64], w: usize, a: usize) -> Vec<u8> {
    let bp = breakpoints(a);
    let normed = znorm(window);
    paa(&normed, w)
        .into_iter()
        .map(|v| bp.iter().take_while(|&&b| v > b).count() as u8)
        .collect()
}

/// All SAX words of a series (one per m-window).
pub fn sax_words(t: &[f64], m: usize, w: usize, a: usize) -> Vec<Vec<u8>> {
    let nwin = t.len() + 1 - m;
    (0..nwin).map(|i| sax_word(&t[i..i + m], w, a)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paa_divisible() {
        let x = [1.0, 1.0, 2.0, 2.0, 3.0, 3.0];
        assert_eq!(paa(&x, 3), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn paa_non_divisible_preserves_mean() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let p = paa(&x, 2);
        let mean_orig = x.iter().sum::<f64>() / 5.0;
        let mean_paa = p.iter().sum::<f64>() / 2.0;
        assert!((mean_orig - mean_paa).abs() < 1e-9, "{p:?}");
        assert!(p[0] < p[1]);
    }

    #[test]
    fn word_is_monotone_in_value() {
        // Rising ramp -> non-decreasing symbols.
        let x: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let wrd = sax_word(&x, 4, 4);
        assert_eq!(wrd.len(), 4);
        for k in 1..wrd.len() {
            assert!(wrd[k] >= wrd[k - 1], "{wrd:?}");
        }
        assert!(wrd[0] < wrd[3]);
    }

    #[test]
    fn identical_shape_same_word() {
        let x: Vec<f64> = (0..16).map(|i| (i as f64 * 0.5).sin()).collect();
        let y: Vec<f64> = x.iter().map(|v| 100.0 + 5.0 * v).collect(); // affine
        assert_eq!(sax_word(&x, 4, 5), sax_word(&y, 4, 5));
    }

    #[test]
    fn symbols_within_alphabet() {
        let t: Vec<f64> = (0..200).map(|i| (i as f64 * 0.1).sin()).collect();
        for wrd in sax_words(&t, 20, 5, 6) {
            assert!(wrd.iter().all(|&s| s < 6));
        }
    }
}
