//! STOMP (Zhu et al. 2016): the O(n^2) exact matrix profile via the QT
//! diagonal recurrence.  Discords fall out as the argmax of the profile —
//! the "MP as a by-product" approach §1 reviews (and which MERLIN beats
//! on this task, as the benches show).

use crate::core::distance::{dot, ed2norm_from_qt};
use crate::core::stats::RollingStats;
use crate::core::topk::{top_k_non_overlapping, Scored};
use crate::coordinator::drag::Discord;
use crate::util::pool::parallel_map_indexed;

/// The matrix profile (squared distances) of `t` at window length `m`.
///
/// `mp[i]` = squared z-normalized ED from window `i` to its nearest
/// non-self match.  Diagonal-parallel: each diagonal is independent given
/// its seed dot product, so diagonals are sharded across threads and the
/// per-thread partial minima merged.
pub fn matrix_profile(t: &[f64], m: usize, threads: usize) -> Vec<f64> {
    let nwin = t.len() + 1 - m;
    let stats = RollingStats::compute(t, m);
    // Diagonals k = m..nwin-1 (only |i-j| >= m are valid).
    let diags: Vec<usize> = (m..nwin).collect();
    let partials = parallel_map_indexed(threads.max(1), threads, |w| {
        let mut mp = vec![f64::INFINITY; nwin];
        let mut idx = w;
        while idx < diags.len() {
            let k = diags[idx];
            // Walk diagonal (i, i+k), i = 0..nwin-k.
            let mut qt = dot(&t[0..m], &t[k..k + m]);
            for i in 0..nwin - k {
                let j = i + k;
                if i > 0 {
                    qt += t[i + m - 1] * t[j + m - 1] - t[i - 1] * t[j - 1];
                }
                let d = ed2norm_from_qt(qt, m, stats.mu[i], stats.sig[i], stats.mu[j], stats.sig[j]);
                if d < mp[i] {
                    mp[i] = d;
                }
                if d < mp[j] {
                    mp[j] = d;
                }
            }
            idx += threads.max(1);
        }
        mp
    });
    // Merge.
    let mut mp = vec![f64::INFINITY; nwin];
    for p in partials {
        for (a, b) in mp.iter_mut().zip(p) {
            if b < *a {
                *a = b;
            }
        }
    }
    mp
}

/// Top-k discords from the matrix profile (ED units, non-overlapping).
pub fn top_k_discords(t: &[f64], m: usize, k: usize, threads: usize) -> Vec<Discord> {
    let mp = matrix_profile(t, m, threads);
    let scored: Vec<Scored> = mp
        .iter()
        .enumerate()
        .filter(|(_, d)| d.is_finite())
        .map(|(idx, &d)| Scored { idx, nn_dist: d.max(0.0).sqrt() })
        .collect();
    top_k_non_overlapping(&scored, m, k)
        .into_iter()
        .map(|s| Discord { idx: s.idx, m, nn_dist: s.nn_dist })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::brute;
    use crate::util::rng::Rng;

    fn walk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect()
    }

    #[test]
    fn profile_matches_brute_force() {
        let t = walk(220, 1);
        let m = 11;
        let mp = matrix_profile(&t, m, 4);
        let nn = brute::nn_profile(&t, m);
        assert_eq!(mp.len(), nn.len());
        for i in 0..mp.len() {
            assert_eq!(mp[i].is_finite(), nn[i].is_finite(), "i={i}");
            if nn[i].is_finite() {
                assert!((mp[i] - nn[i]).abs() < 1e-6 * (1.0 + nn[i]), "i={i}: {} vs {}", mp[i], nn[i]);
            }
        }
    }

    #[test]
    fn discords_match_brute_force() {
        let t = walk(300, 2);
        let m = 15;
        let got = top_k_discords(&t, m, 2, 4);
        let want = brute::top_k_discords(&t, m, 2);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g.nn_dist - w.nn_dist).abs() < 1e-6 * (1.0 + w.nn_dist));
        }
    }

    #[test]
    fn thread_invariance() {
        let t = walk(180, 3);
        let a = matrix_profile(&t, 9, 1);
        let b = matrix_profile(&t, 9, 7);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12 || (x.is_infinite() && y.is_infinite()));
        }
    }
}
