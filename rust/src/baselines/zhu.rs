//! Zhu et al. 2021 (TPDS): top-1 discord via the two computational
//! patterns the paper describes — (1) per-candidate minimum distance then
//! global maximum, (2) early stop as soon as a candidate's running
//! minimum falls below the best-so-far (both the candidate and the
//! matching window are then provably not the top discord).
//!
//! Uses the same Pearson-correlation distance (Eq. 6) and precomputed
//! stats as PALMAD, so the Fig. 5 comparison isolates the algorithmic
//! difference (top-1-only with global pruning vs all range discords of
//! every length).

use crate::core::distance::ed2norm_from_qt;
use crate::core::stats::RollingStats;
use crate::coordinator::drag::Discord;
use crate::util::pool::parallel_map_indexed;

/// Exact top-1 discord with best-so-far early stopping.
///
/// The scan order follows the paper: candidates in index order, inner
/// windows in index order with the QT running dot product, aborting the
/// candidate as soon as its minimum can no longer exceed `best`.
pub fn zhu_top1(t: &[f64], m: usize, threads: usize) -> Option<Discord> {
    let nwin = t.len().checked_sub(m)? + 1;
    if nwin < 2 {
        return None;
    }
    let stats = RollingStats::compute(t, m);

    // Shared best-so-far (squared).  Workers read it opportunistically;
    // staleness only weakens pruning, never correctness.
    use std::sync::atomic::{AtomicU64, Ordering};
    let best_bits = AtomicU64::new(0f64.to_bits());
    let load_best = || f64::from_bits(best_bits.load(Ordering::Relaxed));
    let store_best = |v: f64| {
        // CAS-max loop.
        let mut cur = best_bits.load(Ordering::Relaxed);
        while v > f64::from_bits(cur) {
            match best_bits.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    };

    // Process candidates in blocks so early stop benefits from a warm
    // best-so-far established by earlier blocks.
    const BLOCK: usize = 64;
    let nblocks = nwin.div_ceil(BLOCK);
    let results = parallel_map_indexed(nblocks, threads, |blk| {
        let mut local_best: Option<(usize, f64)> = None;
        for i in (blk * BLOCK)..((blk + 1) * BLOCK).min(nwin) {
            let cutoff = load_best();
            let mut nn = f64::INFINITY;
            let mut alive = true;
            for j in 0..nwin {
                if i.abs_diff(j) < m {
                    continue;
                }
                let qt = crate::core::distance::dot(&t[i..i + m], &t[j..j + m]);
                let d = ed2norm_from_qt(qt, m, stats.mu[i], stats.sig[i], stats.mu[j], stats.sig[j]);
                if d < nn {
                    nn = d;
                    if nn < cutoff {
                        alive = false; // pattern 2: early stop
                        break;
                    }
                }
            }
            if alive && nn.is_finite() {
                store_best(nn);
                match local_best {
                    Some((_, b)) if b >= nn => {}
                    _ => local_best = Some((i, nn)),
                }
            }
        }
        local_best
    });

    // The winner's distance is discarded (`_nn2`): the block-parallel
    // early stop can leave it as an upper-bound tie, so the winner is
    // recomputed exactly below.
    let (idx, _nn2) = results
        .into_iter()
        .flatten()
        .max_by(|a, b| a.1.partial_cmp(&b.1).expect("surviving candidates are finite"))?;
    let exact = exact_nn(t, m, &stats, idx);
    Some(Discord { idx, m, nn_dist: exact.max(0.0).sqrt() })
}

fn exact_nn(t: &[f64], m: usize, stats: &RollingStats, i: usize) -> f64 {
    let nwin = t.len() - m + 1;
    let mut nn = f64::INFINITY;
    for j in 0..nwin {
        if i.abs_diff(j) < m {
            continue;
        }
        let qt = crate::core::distance::dot(&t[i..i + m], &t[j..j + m]);
        let d = ed2norm_from_qt(qt, m, stats.mu[i], stats.sig[i], stats.mu[j], stats.sig[j]);
        nn = nn.min(d);
    }
    nn
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::brute;
    use crate::util::rng::Rng;

    fn walk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect()
    }

    #[test]
    fn matches_brute_force() {
        for seed in [1u64, 2, 3] {
            let t = walk(300, seed);
            let m = 14;
            let got = zhu_top1(&t, m, 4).unwrap();
            let want = brute::top_k_discords(&t, m, 1)[0];
            assert!(
                (got.nn_dist - want.nn_dist).abs() < 1e-6 * (1.0 + want.nn_dist),
                "seed {seed}: {} vs {}",
                got.nn_dist,
                want.nn_dist
            );
        }
    }

    #[test]
    fn deterministic_winner_distance_across_threads() {
        let t = walk(250, 4);
        let a = zhu_top1(&t, 12, 1).unwrap();
        let b = zhu_top1(&t, 12, 8).unwrap();
        assert!((a.nn_dist - b.nn_dist).abs() < 1e-12);
    }
}
