//! The benchmark runner: warmup + timed repetitions, result tables, JSON
//! dumps under `target/bench-results/`.

use std::time::Instant;

use crate::analysis::report::{fmt_secs, Table};
use crate::util::json::Json;

use super::stats::{summarize, Summary};

/// Time `f` with `warmup` unmeasured runs and `reps` measured ones.
pub fn measure<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    summarize(&samples)
}

/// Is quick mode on (shrunken workloads)?
pub fn quick_mode() -> bool {
    std::env::var("PALMAD_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Repetitions to use unless quick mode caps them.
pub fn default_reps() -> usize {
    if quick_mode() {
        1
    } else {
        3
    }
}

/// One benchmark's accumulated rows.
pub struct Bench {
    pub name: &'static str,
    /// (label, params, summary, extra key=value annotations)
    rows: Vec<(String, String, Summary, Vec<(String, String)>)>,
}

impl Bench {
    pub fn new(name: &'static str) -> Self {
        println!("# bench {name}{}", if quick_mode() { " (QUICK)" } else { "" });
        Self { name, rows: Vec::new() }
    }

    /// Record a measured row.
    pub fn record(
        &mut self,
        label: impl Into<String>,
        params: impl Into<String>,
        summary: Summary,
        extra: Vec<(String, String)>,
    ) {
        let (label, params) = (label.into(), params.into());
        println!(
            "  {label} [{params}] median={} min={} (reps={}){}",
            fmt_secs(summary.median),
            fmt_secs(summary.min),
            summary.reps,
            extra
                .iter()
                .map(|(k, v)| format!(" {k}={v}"))
                .collect::<String>()
        );
        self.rows.push((label, params, summary, extra));
    }

    /// Convenience: measure and record in one call.
    pub fn run<F: FnMut()>(
        &mut self,
        label: impl Into<String>,
        params: impl Into<String>,
        f: F,
    ) -> Summary {
        let s = measure(if quick_mode() { 0 } else { 1 }, default_reps(), f);
        self.record(label, params, s, Vec::new());
        s
    }

    /// Print the final table and write the JSON dump.  Returns the table
    /// text (the benches also embed it in EXPERIMENTS.md).
    pub fn finish(self) -> String {
        let mut table = Table::new(self.name, &["case", "params", "median", "min", "mean", "extra"]);
        let mut json_rows = Vec::new();
        for (label, params, s, extra) in &self.rows {
            table.row(&[
                label.clone(),
                params.clone(),
                fmt_secs(s.median),
                fmt_secs(s.min),
                fmt_secs(s.mean),
                extra.iter().map(|(k, v)| format!("{k}={v} ")).collect::<String>().trim_end().to_string(),
            ]);
            let mut obj = Json::obj()
                .set("case", label.clone())
                .set("params", params.clone())
                .set("median_s", s.median)
                .set("min_s", s.min)
                .set("mean_s", s.mean)
                .set("reps", s.reps);
            for (k, v) in extra {
                obj = obj.set(k, v.clone());
            }
            json_rows.push(obj);
        }
        let text = table.to_text();
        println!("\n{text}");
        // JSON dump (best-effort).
        let dir = std::path::Path::new("target/bench-results");
        // ok-drop: best-effort mkdir; a real failure surfaces as the write
        // warning just below, and benches must not abort on dump trouble.
        let _ = std::fs::create_dir_all(dir);
        let json = Json::obj()
            .set("bench", self.name)
            .set("quick", quick_mode())
            .set("rows", Json::Arr(json_rows));
        let path = dir.join(format!("{}.json", self.name));
        if let Err(e) = std::fs::write(&path, json.to_string()) {
            eprintln!("warn: could not write {path:?}: {e}");
        } else {
            println!("wrote {}", path.display());
        }
        text
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_reps() {
        let mut n = 0;
        let s = measure(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.reps, 5);
        assert!(s.min >= 0.0);
    }

    #[test]
    fn bench_records_and_finishes() {
        let mut b = Bench::new("unit_test_bench");
        b.run("case_a", "n=10", || {
            std::hint::black_box(1 + 1);
        });
        b.record(
            "case_b",
            "n=20",
            summarize(&[0.5]),
            vec![("discords".into(), "3".into())],
        );
        let text = b.finish();
        assert!(text.contains("case_a"));
        assert!(text.contains("discords=3"));
    }
}
