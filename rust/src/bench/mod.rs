//! Criterion-replacement benchmark harness.
//!
//! `cargo bench` runs the `rust/benches/*.rs` binaries (Cargo `[[bench]]`
//! targets with `harness = false`); each uses [`harness::Bench`] to time
//! closures with warmup + repetition, prints a paper-style table, and
//! drops a JSON row dump under `target/bench-results/` for plotting.
//!
//! `PALMAD_BENCH_QUICK=1` shrinks workloads (used by the test-path smoke
//! runs so `cargo bench` can be exercised quickly).
#![forbid(unsafe_code)]

pub mod harness;
pub mod stats;
