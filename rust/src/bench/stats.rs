//! Summary statistics for benchmark samples.

/// Summary of repeated timing samples (seconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub reps: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    /// Median absolute deviation (robust spread).
    pub mad: f64,
}

/// Summarize a non-empty sample set.
pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty());
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("timing samples are finite"));
    let median = percentile_sorted(&sorted, 50.0);
    let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).expect("deviations of finite samples are finite"));
    Summary {
        reps: samples.len(),
        min: sorted[0],
        max: *sorted.last().expect("samples asserted non-empty above"),
        mean: samples.iter().sum::<f64>() / samples.len() as f64,
        median,
        mad: percentile_sorted(&devs, 50.0),
    }
}

/// Linear-interpolated percentile of a sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_samples() {
        let s = summarize(&[3.0, 1.0, 2.0]);
        assert_eq!(s.reps, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.mad, 1.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
        assert_eq!(percentile_sorted(&v, 50.0), 5.0);
        assert_eq!(percentile_sorted(&[7.0], 30.0), 7.0);
    }

    #[test]
    fn single_sample() {
        let s = summarize(&[0.5]);
        assert_eq!(s.median, 0.5);
        assert_eq!(s.mad, 0.0);
    }
}
