//! `palmad-analyze` — the hot-path dataflow analysis gate.
//!
//! Reconstructs per-function scopes over `rust/src` and enforces the
//! three passes documented in ANALYSIS.md: P1 panic-freedom in
//! hot-path functions, P2 numeric determinism in result-bearing
//! modules, and P3 result discipline everywhere.  Exits non-zero on
//! any violation; run by `scripts/ci.sh --analyze`, which falls back
//! to the semantically identical `scripts/analyze_invariants.py` when
//! no Rust toolchain is present.
//!
//! Usage: `palmad-analyze [repo-root]` (default: current directory).

#![forbid(unsafe_code)]

use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args().nth(1).unwrap_or_else(|| ".".to_string());
    match palmad::util::analyze::run(std::path::Path::new(&root)) {
        Ok(violations) => {
            for v in &violations {
                println!("{v}");
            }
            println!("analyze-invariants: {} violation(s)", violations.len());
            if violations.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("palmad-analyze: {e}");
            ExitCode::FAILURE
        }
    }
}
