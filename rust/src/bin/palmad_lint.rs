//! `palmad-lint` — the repo-invariant lint gate.
//!
//! Scans `rust/src`, `rust/tests`, and `examples` for violations of the
//! unsafe-code and concurrency invariants documented in CONCURRENCY.md
//! (SAFETY comments, transmute containment, the memory-ordering audit
//! table, coordinator lock discipline, unwrap creep).  Exits non-zero
//! on any violation; run by `scripts/ci.sh --lint-invariants`, which
//! falls back to the semantically identical
//! `scripts/lint_invariants.py` when no Rust toolchain is present.
//!
//! Usage: `palmad-lint [repo-root]` (default: current directory).

#![forbid(unsafe_code)]

use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args().nth(1).unwrap_or_else(|| ".".to_string());
    match palmad::util::lint::run(std::path::Path::new(&root)) {
        Ok(violations) => {
            for v in &violations {
                println!("{v}");
            }
            println!("lint-invariants: {} violation(s)", violations.len());
            if violations.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("palmad-lint: {e}");
            ExitCode::FAILURE
        }
    }
}
