//! Crash-safe job checkpoints: a versioned binary snapshot of a
//! service job (spec + [`MerlinSweep`] state + engine seed-cache rows)
//! written at step boundaries so an interrupted sweep resumes exactly
//! where it stopped — bit-identically, see `rust/tests/chaos_faults.rs`.
//!
//! Durability discipline: [`CheckpointStore::save`] writes a temp file
//! in the same directory, `sync_all`s it, then atomically renames it
//! over `job-<id>.ckpt`.  A crash at any instant therefore leaves
//! either the previous complete checkpoint or the new complete
//! checkpoint, never a torn file; the [`binio`] envelope (magic,
//! version, FNV-1a checksum) rejects anything that slipped through
//! anyway (filesystem corruption, manual tampering).
//!
//! What is and is not persisted:
//! - generated series (`gen=` jobs) are *not* stored — they
//!   rematerialize deterministically from `(dataset, n, seed)`;
//! - uploaded series (`data=` jobs) *are* stored verbatim, because the
//!   upload table does not survive a restart;
//! - engine seed-cache rows are carried because a fresh QT seed dot
//!   rounds differently in the low-order bits than the incremental
//!   cross-length advance — without them a resume would be numerically
//!   close but not bit-identical (see `engines::SeedRowSnapshot`);
//! - deadlines restart from resume time (the wall-clock budget is a
//!   protection against runaway jobs, not a promise about outages).
//!
//! [`binio`]: crate::util::binio
//! [`MerlinSweep`]: super::merlin::MerlinSweep

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::engines::SeedRowSnapshot;
use crate::util::binio::{seal, unseal, ByteReader, ByteWriter};

const JOB_MAGIC: &[u8; 8] = b"PALMJOB\0";
/// v2 appends the scheduling identity (`tenant`, `weight`) after the
/// seed rows.  v1 files (pre-weighted-fair deployments) still decode —
/// they come back with an empty tenant and weight 0, which the service
/// maps to the default tenant/weight at resume.
const JOB_VERSION: u32 = 2;

/// Everything needed to reconstruct a parked job after a crash.
#[derive(Clone, Debug, PartialEq)]
pub struct JobCheckpoint {
    pub job_id: u64,
    /// Generator dataset name (`gen=` jobs); empty for uploads.
    pub dataset: String,
    pub n: Option<u64>,
    pub seed: u64,
    pub min_l: u64,
    pub max_l: u64,
    pub top_k: u64,
    /// Original deadline budget in ms; re-armed from resume time.
    pub deadline_ms: Option<u64>,
    /// `(name, values)` for uploaded series; `None` for generated ones.
    pub series: Option<(String, Vec<f64>)>,
    /// Sealed [`MerlinSweep::snapshot`] blob (its own inner envelope —
    /// cheap, and it keeps the sweep codec independently verifiable).
    ///
    /// [`MerlinSweep::snapshot`]: super::merlin::MerlinSweep::snapshot
    pub sweep: Vec<u8>,
    /// Seed-cache rows exported from the leased engine right after the
    /// checkpointed step (i.e. already advanced/prefetched to the next
    /// length), so the resumed engine replays verbatim-hit seeding.
    pub seed_rows: Vec<SeedRowSnapshot>,
    /// Scheduling identity (v2): the tenant name the job was submitted
    /// under.  Empty on v1 files; the service substitutes its default
    /// tenant at resume.
    pub tenant: String,
    /// Scheduling weight (v2).  0 on v1 files (= "use the configured
    /// default"), matching `JobSpec::weight` semantics.
    pub weight: u32,
}

impl JobCheckpoint {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u64(self.job_id);
        w.put_str(&self.dataset);
        w.put_opt_u64(self.n);
        w.put_u64(self.seed);
        w.put_u64(self.min_l);
        w.put_u64(self.max_l);
        w.put_u64(self.top_k);
        w.put_opt_u64(self.deadline_ms);
        match &self.series {
            Some((name, values)) => {
                w.put_bool(true);
                w.put_str(name);
                w.put_f64s(values);
            }
            None => w.put_bool(false),
        }
        w.put_bytes(&self.sweep);
        w.put_usize(self.seed_rows.len());
        for r in &self.seed_rows {
            w.put_usize(r.a);
            w.put_usize(r.cs);
            w.put_usize(r.m);
            w.put_f64s(&r.qt);
        }
        // v2 fields go last so a v1 decoder (which calls finish())
        // rejects v2 files loudly instead of misparsing them.
        w.put_str(&self.tenant);
        w.put_u64(self.weight as u64);
        seal(JOB_MAGIC, JOB_VERSION, w.bytes())
    }

    pub fn decode(bytes: &[u8]) -> Result<Self> {
        // `unseal` is exact-version, so try current-then-v1.  On a file
        // that is neither (corruption, or a future version), surface the
        // current-version error — it names the actual on-disk version.
        let (payload, ver) = match unseal(JOB_MAGIC, JOB_VERSION, bytes) {
            Ok(p) => (p, JOB_VERSION),
            Err(e) => match unseal(JOB_MAGIC, 1, bytes) {
                Ok(p) => (p, 1),
                Err(_) => return Err(e),
            },
        };
        let mut r = ByteReader::new(payload);
        let job_id = r.get_u64()?;
        let dataset = r.get_str()?;
        let n = r.get_opt_u64()?;
        let seed = r.get_u64()?;
        let min_l = r.get_u64()?;
        let max_l = r.get_u64()?;
        let top_k = r.get_u64()?;
        let deadline_ms = r.get_opt_u64()?;
        let series = if r.get_bool()? {
            let name = r.get_str()?;
            let values = r.get_f64s()?;
            Some((name, values))
        } else {
            None
        };
        let sweep = r.get_bytes()?.to_vec();
        let n_rows = r.get_usize()?;
        let mut seed_rows = Vec::with_capacity(n_rows.min(4096));
        for _ in 0..n_rows {
            let a = r.get_usize()?;
            let cs = r.get_usize()?;
            let m = r.get_usize()?;
            let qt = r.get_f64s()?;
            seed_rows.push(SeedRowSnapshot { a, cs, m, qt });
        }
        let (tenant, weight) = if ver >= 2 {
            let tenant = r.get_str()?;
            let w = r.get_u64()?;
            let weight = u32::try_from(w)
                .map_err(|_| anyhow::anyhow!("checkpoint weight {w} overflows u32"))?;
            (tenant, weight)
        } else {
            (String::new(), 0)
        };
        r.finish()?;
        let ckpt = Self {
            job_id,
            dataset,
            n,
            seed,
            min_l,
            max_l,
            top_k,
            deadline_ms,
            series,
            sweep,
            seed_rows,
            tenant,
            weight,
        };
        if ckpt.dataset.is_empty() && ckpt.series.is_none() {
            bail!("checkpoint for job {job_id} names no series source");
        }
        Ok(ckpt)
    }
}

/// A directory of `job-<id>.ckpt` files with atomic-rename saves.
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    /// Open (creating if needed) a checkpoint directory.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
        Ok(Self { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path(&self, job_id: u64) -> PathBuf {
        self.dir.join(format!("job-{job_id}.ckpt"))
    }

    /// Durably persist a checkpoint: write `.palmad-tmp-<id>` in the
    /// same directory, fsync it, rename over the final name.  Readers
    /// never observe a partial file.
    pub fn save(&self, ckpt: &JobCheckpoint) -> Result<()> {
        let bytes = ckpt.encode();
        let tmp = self.dir.join(format!(".palmad-tmp-{}", ckpt.job_id));
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("create {}", tmp.display()))?;
            use std::io::Write;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        let dst = self.path(ckpt.job_id);
        std::fs::rename(&tmp, &dst)
            .with_context(|| format!("rename {} -> {}", tmp.display(), dst.display()))?;
        Ok(())
    }

    pub fn load(&self, job_id: u64) -> Result<JobCheckpoint> {
        let path = self.path(job_id);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("read checkpoint {}", path.display()))?;
        let ckpt = JobCheckpoint::decode(&bytes)
            .with_context(|| format!("decode checkpoint {}", path.display()))?;
        if ckpt.job_id != job_id {
            bail!("checkpoint {} claims job id {}", path.display(), ckpt.job_id);
        }
        Ok(ckpt)
    }

    pub fn exists(&self, job_id: u64) -> bool {
        self.path(job_id).is_file()
    }

    /// Remove a job's checkpoint.  An absent file is `Ok` (removal races
    /// with nothing since saves go through rename); any other I/O error
    /// is returned so the caller can count it — a checkpoint that will
    /// not delete resurrects a cancelled/forgotten job at next boot,
    /// which operators should see in METRICS rather than discover.
    pub fn remove(&self, job_id: u64) -> std::io::Result<()> {
        match std::fs::remove_file(self.path(job_id)) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e),
        }
    }

    /// Job ids with a checkpoint on disk, **sorted ascending by job id**
    /// regardless of `read_dir` enumeration order.  Temp files and
    /// foreign names are ignored.
    ///
    /// The ordering is a contract, not an accident: boot resume replays
    /// `scan()` in order, and resume order feeds lease stickiness (the
    /// first resumed job binds the first engine lease), so a
    /// filesystem-dependent order would make post-crash engine binding —
    /// and therefore seed-cache reuse — nondeterministic across hosts.
    /// Pinned by `scan_sorts_ids_regardless_of_creation_order`.
    pub fn scan(&self) -> Vec<u64> {
        let mut ids = Vec::new();
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return ids };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(id) = name
                .strip_prefix("job-")
                .and_then(|s| s.strip_suffix(".ckpt"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                ids.push(id);
            }
        }
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_store(tag: &str) -> CheckpointStore {
        let dir = std::env::temp_dir()
            .join(format!("palmad-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        CheckpointStore::new(dir).unwrap()
    }

    fn sample(job_id: u64) -> JobCheckpoint {
        JobCheckpoint {
            job_id,
            dataset: "ecg2".into(),
            n: Some(2_000),
            seed: 7,
            min_l: 16,
            max_l: 20,
            top_k: 1,
            deadline_ms: Some(5_000),
            series: None,
            sweep: vec![1, 2, 3, 4, 5],
            seed_rows: vec![
                SeedRowSnapshot { a: 0, cs: 64, m: 16, qt: vec![1.5, -0.0, f64::NAN] },
                SeedRowSnapshot { a: 128, cs: 0, m: 16, qt: vec![2.25] },
            ],
            tenant: "acme".into(),
            weight: 3,
        }
    }

    /// Re-encode a checkpoint exactly as the v1 codec did: same field
    /// order, no tenant/weight, sealed with version 1.
    fn encode_v1(ckpt: &JobCheckpoint) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u64(ckpt.job_id);
        w.put_str(&ckpt.dataset);
        w.put_opt_u64(ckpt.n);
        w.put_u64(ckpt.seed);
        w.put_u64(ckpt.min_l);
        w.put_u64(ckpt.max_l);
        w.put_u64(ckpt.top_k);
        w.put_opt_u64(ckpt.deadline_ms);
        match &ckpt.series {
            Some((name, values)) => {
                w.put_bool(true);
                w.put_str(name);
                w.put_f64s(values);
            }
            None => w.put_bool(false),
        }
        w.put_bytes(&ckpt.sweep);
        w.put_usize(ckpt.seed_rows.len());
        for r in &ckpt.seed_rows {
            w.put_usize(r.a);
            w.put_usize(r.cs);
            w.put_usize(r.m);
            w.put_f64s(&r.qt);
        }
        seal(JOB_MAGIC, 1, w.bytes())
    }

    #[test]
    fn codec_round_trips_every_field() {
        let ckpt = sample(42);
        let back = JobCheckpoint::decode(&ckpt.encode()).unwrap();
        // NaN != NaN breaks PartialEq; compare bits for the qt rows.
        assert_eq!(back.job_id, 42);
        assert_eq!(back.dataset, "ecg2");
        assert_eq!(back.n, Some(2_000));
        assert_eq!(
            (back.seed, back.min_l, back.max_l, back.top_k, back.deadline_ms),
            (7, 16, 20, 1, Some(5_000))
        );
        assert_eq!(back.sweep, vec![1, 2, 3, 4, 5]);
        assert_eq!((back.tenant.as_str(), back.weight), ("acme", 3));
        assert_eq!(back.seed_rows.len(), 2);
        for (a, b) in ckpt.seed_rows.iter().zip(&back.seed_rows) {
            assert_eq!((a.a, a.cs, a.m), (b.a, b.cs, b.m));
            let ab: Vec<u64> = a.qt.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u64> = b.qt.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb, "qt rows must round-trip to the bit");
        }

        let uploaded = JobCheckpoint {
            dataset: String::new(),
            series: Some(("mine".into(), vec![0.5, 1.5, 2.5])),
            deadline_ms: None,
            n: None,
            // NaN != NaN would defeat the PartialEq comparison below.
            seed_rows: vec![SeedRowSnapshot { a: 4, cs: 0, m: 8, qt: vec![3.75] }],
            ..sample(9)
        };
        let back = JobCheckpoint::decode(&uploaded.encode()).unwrap();
        assert_eq!(back, uploaded);
    }

    #[test]
    fn decode_rejects_corruption_and_sourceless_jobs() {
        let bytes = sample(1).encode();
        for cut in 0..bytes.len() {
            assert!(JobCheckpoint::decode(&bytes[..cut]).is_err(), "cut {cut}");
        }
        for i in (0..bytes.len()).step_by(5) {
            let mut bad = bytes.clone();
            bad[i] ^= 0x10;
            assert!(JobCheckpoint::decode(&bad).is_err(), "flip at {i}");
        }
        let orphan = JobCheckpoint { dataset: String::new(), series: None, ..sample(2) };
        assert!(JobCheckpoint::decode(&orphan.encode()).is_err());
    }

    /// v1 files written before the weighted-fair scheduler must keep
    /// loading: tenant comes back empty and weight 0 (the service maps
    /// both to its defaults at resume).  A file that is neither v1 nor
    /// v2 is rejected with the *actual* on-disk version in the error.
    #[test]
    fn v1_checkpoints_still_decode() {
        let ckpt = sample(21);
        let v1 = encode_v1(&ckpt);
        let back = JobCheckpoint::decode(&v1).unwrap();
        assert_eq!(back.job_id, 21);
        assert_eq!(back.tenant, "", "v1 carries no tenant");
        assert_eq!(back.weight, 0, "v1 weight means 'use the default'");
        assert_eq!(back.sweep, ckpt.sweep, "shared fields decode as before");

        // Trailing-byte discipline still holds per version: a v1
        // payload sealed as v2 is short, a v2 payload sealed as v1 has
        // trailing bytes — both must be rejected, not misread.
        let v2_payload = unseal(JOB_MAGIC, 2, &ckpt.encode()).unwrap().to_vec();
        assert!(JobCheckpoint::decode(&seal(JOB_MAGIC, 1, &v2_payload)).is_err());
        let v1_payload = unseal(JOB_MAGIC, 1, &v1).unwrap().to_vec();
        assert!(JobCheckpoint::decode(&seal(JOB_MAGIC, 2, &v1_payload)).is_err());

        let future = seal(JOB_MAGIC, 9, &v2_payload);
        let err = format!("{:#}", JobCheckpoint::decode(&future).unwrap_err());
        assert!(err.contains("version 9"), "error names the on-disk version: {err}");
    }

    #[test]
    fn store_saves_atomically_and_scans() {
        let store = temp_store("scan");
        assert!(store.scan().is_empty());
        assert!(!store.exists(3));
        assert!(store.load(3).is_err(), "missing checkpoint is an error");

        store.save(&sample(3)).unwrap();
        store.save(&sample(11)).unwrap();
        // Overwrite in place: still one file per job.
        store.save(&JobCheckpoint { top_k: 2, ..sample(3) }).unwrap();
        assert_eq!(store.scan(), vec![3, 11]);
        assert!(store.exists(3));
        assert_eq!(store.load(3).unwrap().top_k, 2, "save replaces");

        // No temp droppings survive a completed save.
        let leftovers: Vec<_> = std::fs::read_dir(store.dir())
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().starts_with(".palmad-tmp-"))
            .collect();
        assert!(leftovers.is_empty(), "temp files must be renamed away");

        store.remove(3).unwrap();
        store.remove(3).unwrap(); // idempotent (absent file is Ok)
        assert_eq!(store.scan(), vec![11]);

        // A torn/corrupt file on disk loads as Err, never a panic.
        std::fs::write(store.dir().join("job-12.ckpt"), b"garbage").unwrap();
        assert!(store.load(12).is_err());
        assert_eq!(store.scan(), vec![11, 12], "scan lists it; load rejects it");

        // An id-mismatched but otherwise valid file is rejected.
        std::fs::write(store.dir().join("job-13.ckpt"), sample(14).encode()).unwrap();
        assert!(store.load(13).is_err());

        let _ = std::fs::remove_dir_all(store.dir());
    }

    /// The scan() ordering contract: ids come back ascending no matter
    /// what order the files were created in (and therefore no matter
    /// what order `read_dir` yields — creation order is the one knob a
    /// portable test can turn).  A seeded LCG drives the shuffle so a
    /// failure reproduces exactly.
    #[test]
    fn scan_sorts_ids_regardless_of_creation_order() {
        let store = temp_store("scan-order");
        let mut ids: Vec<u64> = (0..32u64).map(|i| i * 7 + 1).collect();
        // Fisher-Yates with a fixed-seed LCG (no rand dep).
        let mut state = 0x9e37_79b9_7f4a_7c15u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state >> 33
        };
        for i in (1..ids.len()).rev() {
            let j = (next() % (i as u64 + 1)) as usize;
            ids.swap(i, j);
        }
        let sorted = {
            let mut v = ids.clone();
            v.sort_unstable();
            v
        };
        assert_ne!(ids, sorted, "seeded shuffle must actually permute");
        for &id in &ids {
            store.save(&sample(id)).unwrap();
        }
        assert_eq!(store.scan(), sorted, "boot-resume order is sorted by job id");
    }
}
