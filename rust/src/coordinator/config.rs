//! Engine selection & construction shared by the CLI, the service, the
//! examples and the benches.

use anyhow::Result;

use crate::engines::fault::{FaultPlan, FaultyEngine};
use crate::engines::native::{NativeConfig, NativeEngine};
use crate::engines::xla::XlaEngine;
use crate::engines::{Engine, TileKernel};
use crate::runtime::artifact::ArtifactSet;
use crate::util::pool;

/// Which tile backend to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum EngineChoice {
    /// Pure-rust f64 engine (always available).
    #[default]
    Native,
    /// AOT Pallas/JAX artifacts via PJRT (requires `make artifacts`).
    Xla,
}

impl EngineChoice {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(Self::Native),
            "xla" => Ok(Self::Xla),
            other => anyhow::bail!("unknown engine {other:?} (native|xla)"),
        }
    }
}

/// Runtime options for engine construction.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub choice: EngineChoice,
    /// Tile edge; for XLA must be one of the compiled buckets.
    pub segn: usize,
    /// Native-engine worker threads.
    pub threads: usize,
    /// Native tile kernel (`--kernel` / `PALMAD_TILE_KERNEL`); the XLA
    /// engine has its own compiled kernel and ignores this.
    pub kernel: TileKernel,
    /// Artifact directory override (`None` = `$PALMAD_ARTIFACTS` or ./artifacts).
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Wrap the built engine in a [`FaultyEngine`] with this
    /// misbehavior schedule (chaos tests only; `None` in production).
    pub fault: Option<FaultPlan>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self {
            choice: EngineChoice::Native,
            segn: 256,
            threads: pool::default_threads(),
            kernel: TileKernel::from_env(),
            artifacts_dir: None,
            fault: None,
        }
    }
}

/// Build the chosen engine.
pub fn build_engine(opts: &EngineOptions) -> Result<Box<dyn Engine>> {
    let inner: Box<dyn Engine> = match opts.choice {
        EngineChoice::Native => Box::new(NativeEngine::new(NativeConfig {
            segn: opts.segn,
            threads: opts.threads,
            kernel: opts.kernel,
            ..Default::default()
        })),
        EngineChoice::Xla => {
            let dir = opts
                .artifacts_dir
                .clone()
                .unwrap_or_else(ArtifactSet::default_dir);
            let artifacts = ArtifactSet::load(&dir)?;
            Box::new(XlaEngine::new(artifacts, opts.segn)?)
        }
    };
    Ok(match &opts.fault {
        Some(plan) => Box::new(FaultyEngine::new(inner, plan.clone())),
        None => inner,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_choices() {
        assert_eq!(EngineChoice::parse("native").unwrap(), EngineChoice::Native);
        assert_eq!(EngineChoice::parse("xla").unwrap(), EngineChoice::Xla);
        assert!(EngineChoice::parse("cuda").is_err());
    }

    #[test]
    fn parse_kernels() {
        assert_eq!(TileKernel::parse("auto").unwrap(), TileKernel::Auto);
        assert_eq!(TileKernel::parse("scalar").unwrap(), TileKernel::Scalar);
        assert_eq!(TileKernel::parse("lanes4").unwrap(), TileKernel::Lanes4);
        assert_eq!(TileKernel::parse("lanes8").unwrap(), TileKernel::Lanes8);
        assert_eq!(TileKernel::parse("lanes4f32").unwrap(), TileKernel::Lanes4F32);
        assert!(TileKernel::parse("avx512").is_err(), "feature names are not kernel names");
    }

    #[test]
    fn kernel_threads_through_to_native_engine() {
        // Every kernel builds (Lanes8 is safe Rust on any host — the
        // AVX-512 speedup is the runtime dispatcher's concern, not a
        // construction gate); selection is observable only through the
        // conformance counters, so here we just pin that construction
        // accepts each.
        for kernel in [
            TileKernel::Auto,
            TileKernel::Scalar,
            TileKernel::Lanes4,
            TileKernel::Lanes8,
            TileKernel::Lanes4F32,
        ] {
            let e = build_engine(&EngineOptions { kernel, ..Default::default() }).unwrap();
            assert_eq!(e.name(), "native");
        }
    }

    #[test]
    fn native_builds() {
        let e = build_engine(&EngineOptions::default()).unwrap();
        assert_eq!(e.name(), "native");
        assert_eq!(e.segn(), 256);
    }

    #[test]
    fn fault_plan_wraps_the_built_engine() {
        let e = build_engine(&EngineOptions {
            fault: Some(FaultPlan { error_every: 4, ..Default::default() }),
            ..Default::default()
        })
        .unwrap();
        assert_eq!(e.name(), "faulty");
        assert_eq!(e.segn(), 256, "wrapper must delegate geometry");
    }

    #[test]
    fn xla_without_artifacts_errors() {
        let opts = EngineOptions {
            choice: EngineChoice::Xla,
            artifacts_dir: Some("/nonexistent_palmad".into()),
            ..Default::default()
        };
        assert!(build_engine(&opts).is_err());
    }
}
