//! Distributed DRAG simulation — the cluster-of-nodes scheme the paper
//! reviews (§1) and lists as future work (a).
//!
//! The series' subsequences are partitioned across `P` simulated nodes.
//! Each node selects range-discord candidates *within its partition*;
//! the candidate sets are exchanged and refined globally:
//!
//! - **Yankov** (Yankov/Keogh 2008, MapReduce DRAG): exchange the raw
//!   local candidate sets `C = U C_i`.
//! - **LocalRefine** (Zymbler et al. 2021): each node first refines its
//!   own candidates against its own partition, exchanging only the
//!   survivors `C = U C~_i` — the paper reports this significantly
//!   shrinks the exchange, which [`DistMetrics::exchanged`] measures.
//!
//! Both variants return exactly the brute-force range-discord set
//! (integration-tested); they differ only in intermediate traffic — the
//! quantity a real cluster pays for.  Nodes here are loop iterations (the
//! testbed exposes one core); the communication structure is what is
//! being reproduced.

use crate::core::distance::{ed2_early_abandon, is_flat, znorm};
use crate::core::stats::RollingStats;
use crate::coordinator::drag::Discord;

/// Exchange strategy (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeMode {
    Yankov,
    LocalRefine,
}

/// Simulated-cluster counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct DistMetrics {
    /// Candidates surviving local selection, summed over nodes.
    pub local_candidates: usize,
    /// Candidates placed on the wire (the global set size).
    pub exchanged: usize,
    /// Final discords.
    pub survivors: usize,
}

struct Partitioned {
    m: usize,
    bounds: Vec<(usize, usize)>,
    norms: Vec<Vec<f64>>,
    flat: Vec<bool>,
}

impl Partitioned {
    fn new(t: &[f64], m: usize, parts: usize) -> Self {
        let nwin = t.len() + 1 - m;
        let parts = parts.clamp(1, nwin.max(1));
        let chunk = nwin.div_ceil(parts);
        let bounds: Vec<(usize, usize)> =
            (0..parts).map(|p| (p * chunk, ((p + 1) * chunk).min(nwin))).filter(|(a, b)| a < b).collect();
        let stats = RollingStats::compute(t, m);
        let flat = stats.sig.iter().zip(&stats.mu).map(|(&s, &mu)| is_flat(s, mu)).collect();
        let norms = (0..nwin).map(|i| znorm(&t[i..i + m])).collect();
        Self { m, bounds, norms, flat }
    }

    /// Flat-aware pairwise squared distance with early abandon.
    #[inline]
    fn dist(&self, i: usize, j: usize, cutoff: f64) -> Option<f64> {
        if self.flat[i] || self.flat[j] {
            let d = if self.flat[i] && self.flat[j] { 0.0 } else { 2.0 * self.m as f64 };
            if d >= cutoff {
                None
            } else {
                Some(d)
            }
        } else {
            ed2_early_abandon(&self.norms[i], &self.norms[j], cutoff)
        }
    }
}

/// Run distributed DRAG over `parts` simulated nodes.
///
/// Returns the exact range-discord set (nnDist in ED units) plus the
/// communication metrics.
pub fn distributed_drag(
    t: &[f64],
    m: usize,
    r: f64,
    parts: usize,
    mode: ExchangeMode,
) -> (Vec<Discord>, DistMetrics) {
    let mut metrics = DistMetrics::default();
    if t.len() < m {
        return (Vec::new(), metrics);
    }
    let pt = Partitioned::new(t, m, parts);
    let r2 = r * r;

    // ---- Per-node local selection (serial DRAG phase 1 on the slice) ----
    let mut local_sets: Vec<Vec<usize>> = Vec::with_capacity(pt.bounds.len());
    for &(lo, hi) in &pt.bounds {
        let mut cands: Vec<usize> = Vec::new();
        for s in lo..hi {
            let mut is_cand = true;
            let mut k = 0;
            while k < cands.len() {
                let c = cands[k];
                if s.abs_diff(c) >= pt.m && pt.dist(s, c, r2).is_some() {
                    cands.swap_remove(k);
                    is_cand = false;
                    continue;
                }
                k += 1;
            }
            if is_cand {
                cands.push(s);
            }
        }
        metrics.local_candidates += cands.len();

        if mode == ExchangeMode::LocalRefine {
            // Zymbler-style: refine against the whole local partition
            // before exchanging (kills twins the selection order missed).
            cands.retain(|&c| {
                for s in lo..hi {
                    if s.abs_diff(c) >= pt.m && pt.dist(s, c, r2).is_some() {
                        return false;
                    }
                }
                true
            });
        }
        local_sets.push(cands);
    }

    // ---- Exchange: the global candidate set ------------------------------
    let mut global: Vec<(usize, f64)> =
        local_sets.into_iter().flatten().map(|idx| (idx, f64::INFINITY)).collect();
    global.sort_by_key(|&(idx, _)| idx);
    metrics.exchanged = global.len();

    // ---- Global refinement: every node checks every candidate -----------
    for &(lo, hi) in &pt.bounds {
        let mut k = 0;
        while k < global.len() {
            let (c, ref mut nn2) = global[k];
            let mut killed = false;
            for s in lo..hi {
                if s.abs_diff(c) < pt.m {
                    continue;
                }
                if let Some(d) = pt.dist(s, c, *nn2) {
                    if d < r2 {
                        killed = true;
                        break;
                    }
                    *nn2 = d;
                }
            }
            if killed {
                global.swap_remove(k);
            } else {
                k += 1;
            }
        }
    }
    global.sort_by_key(|&(idx, _)| idx);

    let discords: Vec<Discord> = global
        .into_iter()
        .filter(|(_, nn2)| nn2.is_finite())
        .map(|(idx, nn2)| Discord { idx, m: pt.m, nn_dist: nn2.max(0.0).sqrt() })
        .collect();
    metrics.survivors = discords.len();
    (discords, metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::brute;
    use crate::util::rng::Rng;

    fn walk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect()
    }

    fn check_equals_brute(t: &[f64], m: usize, r: f64, parts: usize, mode: ExchangeMode) {
        let (got, _) = distributed_drag(t, m, r, parts, mode);
        let mut want = brute::range_discords(t, m, r);
        want.sort_by_key(|d| d.idx);
        assert_eq!(
            got.iter().map(|d| d.idx).collect::<Vec<_>>(),
            want.iter().map(|d| d.idx).collect::<Vec<_>>(),
            "parts={parts} mode={mode:?}"
        );
        for (g, w) in got.iter().zip(&want) {
            assert!((g.nn_dist - w.nn_dist).abs() < 1e-9 * (1.0 + w.nn_dist));
        }
    }

    #[test]
    fn matches_brute_force_across_partitions() {
        let t = walk(300, 61);
        for parts in [1, 2, 3, 7] {
            check_equals_brute(&t, 14, 3.5, parts, ExchangeMode::Yankov);
            check_equals_brute(&t, 14, 3.5, parts, ExchangeMode::LocalRefine);
        }
    }

    #[test]
    fn local_refine_exchanges_fewer() {
        let t = walk(800, 62);
        let (_, my) = distributed_drag(&t, 16, 2.5, 4, ExchangeMode::Yankov);
        let (_, ml) = distributed_drag(&t, 16, 2.5, 4, ExchangeMode::LocalRefine);
        assert!(ml.exchanged <= my.exchanged, "{} vs {}", ml.exchanged, my.exchanged);
        assert_eq!(my.survivors, ml.survivors);
    }

    #[test]
    fn single_partition_degenerates_to_serial() {
        let t = walk(200, 63);
        let (got, metrics) = distributed_drag(&t, 10, 3.0, 1, ExchangeMode::Yankov);
        let serial = crate::baselines::drag_serial::drag(&t, 10, 3.0);
        assert_eq!(
            got.iter().map(|d| d.idx).collect::<Vec<_>>(),
            serial.iter().map(|d| d.idx).collect::<Vec<_>>()
        );
        assert_eq!(metrics.survivors, got.len());
    }

    #[test]
    fn more_partitions_than_windows_is_safe() {
        let t = walk(40, 64);
        let (got, _) = distributed_drag(&t, 8, 2.0, 1000, ExchangeMode::LocalRefine);
        let want = brute::range_discords(&t, 8, 2.0);
        assert_eq!(got.len(), want.len());
    }
}
