//! Distributed DRAG simulation — the cluster-of-nodes scheme the paper
//! reviews (§1) and lists as future work (a).
//!
//! The series' subsequences are partitioned across `P` simulated nodes
//! as consecutive, tile-aligned *segment* ranges, so every node's work
//! runs through [`crate::engines::Engine::compute_tiles_into`] and one
//! shared, recycled [`MerlinWorkspace`] — the same zero-allocation
//! machinery as PD3 itself (the pre-port implementation materialized a
//! `Vec<Vec<f64>>` of z-normalized windows up front and walked it
//! pairwise).  Each node selects range-discord candidates *within its
//! partition*; the candidate sets are exchanged and refined globally:
//!
//! - **Yankov** (Yankov/Keogh 2008, MapReduce DRAG): nodes run only the
//!   selection scan and exchange the raw local candidate sets
//!   `C = U C_i`.
//! - **LocalRefine** (Zymbler et al. 2021): each node additionally runs
//!   the refinement scan against its own partition, exchanging only the
//!   survivors `C = U C~_i` — the paper reports this significantly
//!   shrinks the exchange, which [`DistMetrics::exchanged`] measures.
//!
//! The global refinement is a candidate-seeded PD3 pass (both scan
//! directions over every chunk, early-stopping segments whose
//! candidates die), so both variants return exactly the brute-force
//! range-discord set with exact nnDist (integration- and
//! property-tested); they differ only in intermediate traffic — the
//! quantity a real cluster pays for.  Nodes here are loop iterations
//! (the testbed exposes one core); the communication structure is what
//! is being reproduced.

use std::time::Instant;

use anyhow::Result;

use super::drag::{pd3_prepared, scan_phase, Discord, Pd3Config, Scan};
use super::merlin::{MerlinConfig, MerlinResult, MerlinSweep, SweepExecutor};
use super::metrics::DragMetrics;
use super::segmentation::Segmentation;
use super::workspace::MerlinWorkspace;
use crate::core::stats::RollingStats;
use crate::engines::{Engine, SeriesView};

/// Exchange strategy (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeMode {
    Yankov,
    LocalRefine,
}

/// Simulated-cluster counters.
#[derive(Clone, Debug, Default)]
pub struct DistMetrics {
    /// Candidates surviving local selection, summed over nodes.
    pub local_candidates: usize,
    /// Candidates placed on the wire (the global set size).
    pub exchanged: usize,
    /// Final discords.
    pub survivors: usize,
    /// Engine-level PD3 counters across the local and global scans
    /// (tile volume, early-stop skips, kills, phase timings) — the
    /// measurable side of the within-partition recompute trade-off.
    pub drag: DragMetrics,
}

/// Run distributed DRAG over `parts` simulated nodes on `engine`.
///
/// Returns the exact range-discord set (nnDist in ED units) plus the
/// communication metrics.  `parts` is clamped to the number of tile
/// segments; partitions are tile-aligned so every node's scans touch
/// only windows it owns.
pub fn distributed_drag(
    engine: &dyn Engine,
    t: &[f64],
    m: usize,
    r: f64,
    parts: usize,
    mode: ExchangeMode,
) -> Result<(Vec<Discord>, DistMetrics)> {
    let mut metrics = DistMetrics::default();
    if t.len() < m || m < 2 {
        return Ok((Vec::new(), metrics));
    }
    let stats = RollingStats::compute(t, m);
    let view = SeriesView { t, stats: &stats };
    let nwin = view.n_windows();
    if nwin == 0 {
        return Ok((Vec::new(), metrics));
    }

    let cfg = Pd3Config::default();
    let mut drag = DragMetrics::default();
    let mut ws = MerlinWorkspace::new();
    engine.prepare_series(&view);
    distributed_pass(engine, &view, r, &cfg, parts, mode, &mut drag, &mut ws, &mut metrics)?;

    let mut discords = std::mem::take(&mut ws.discords);
    discords.sort_by_key(|d| d.idx);
    metrics.drag = drag;
    Ok((discords, metrics))
}

/// One complete distributed pass at a single (length, threshold):
/// per-node local selection (+ optional local refinement), exchange,
/// global candidate-seeded refinement.  Shared by [`distributed_drag`]
/// (fixed threshold, the paper's range-discord setting) and
/// [`DistributedExecutor`] (MERLIN's adaptive threshold schedule), and
/// accumulates into `metrics` so multi-length sweeps report cumulative
/// traffic.  The caller must have run `Engine::prepare_series`.
#[allow(clippy::too_many_arguments)]
fn distributed_pass(
    engine: &dyn Engine,
    view: &SeriesView<'_>,
    r: f64,
    cfg: &Pd3Config,
    parts: usize,
    mode: ExchangeMode,
    drag: &mut DragMetrics,
    ws: &mut MerlinWorkspace,
    metrics: &mut DistMetrics,
) -> Result<()> {
    let nwin = view.n_windows();
    let seg = Segmentation::new(nwin, engine.segn());
    let parts = parts.clamp(1, seg.nseg);
    let seg_chunk = seg.nseg.div_ceil(parts);
    ws.reset_all_candidates(nwin);
    let r2 = r * r;

    // ---- Per-node local phase -------------------------------------------
    // Nodes own disjoint segment ranges, and a restricted scan only ever
    // reads/writes windows inside its range — so one shared bitmap
    // carries every node's local result without interference.
    for p in 0..parts {
        let lo = p * seg_chunk;
        let hi = ((p + 1) * seg_chunk).min(seg.nseg);
        if lo >= hi {
            continue;
        }
        let t0 = Instant::now();
        scan_phase(engine, view, r2, cfg, drag, ws, &seg, lo, hi, Scan::Select)?;
        drag.select_time += t0.elapsed();
        // Selection survivors are counted *before* any local refinement,
        // so `local_candidates - exchanged` exposes exactly the traffic
        // reduction the LocalRefine variant buys.
        let win_lo = seg.seg_start(lo);
        let win_hi = seg.seg_range(hi - 1).end;
        metrics.local_candidates += ws.candidate_count_in(win_lo, win_hi);
        if mode == ExchangeMode::LocalRefine {
            // Zymbler-style: refine against the whole local partition
            // before exchanging (kills twins the selection order missed).
            let t1 = Instant::now();
            scan_phase(engine, view, r2, cfg, drag, ws, &seg, lo, hi, Scan::Refine)?;
            drag.refine_time += t1.elapsed();
        }
    }

    // ---- Exchange: the global candidate set ------------------------------
    // The union of the local sets is exactly what is left in the bitmap.
    metrics.exchanged += ws.candidate_count();

    // ---- Global refinement: every node checks every candidate -----------
    // A candidate-seeded PD3 pass: surviving candidates' rows cover every
    // chunk across both scan directions, so their nnDist is exact and
    // every non-discord in the exchange gets killed by a real distance.
    //
    // Within-partition tiles of still-live segments are recomputed here
    // even though the local phase measured them: under Yankov (no local
    // refine) a candidate's within-partition *left* coverage can be
    // incomplete when early-stop skipped a dead segment's tiles, so
    // skipping same-partition pairs would be unsound for that mode.
    // The QT seed rows are served from the engine cache either way;
    // mode-aware pair skipping is a possible future optimization.
    pd3_prepared(engine, view, r, cfg, drag, ws)?;
    metrics.survivors += ws.discords().len();
    Ok(())
}

/// [`SweepExecutor`] that swaps MERLIN's per-length PD3 call for the
/// distributed exchange procedure, so arbitrary-length discovery runs
/// with the cluster communication structure while sharing the exact
/// threshold schedule, retry policy, and per-length selection of every
/// other sweep client ([`MerlinSweep`] is the only sweep driver).
pub struct DistributedExecutor {
    pub parts: usize,
    pub mode: ExchangeMode,
    /// Cumulative exchange traffic across every (length, threshold)
    /// pass of the sweep.
    pub metrics: DistMetrics,
}

impl DistributedExecutor {
    pub fn new(parts: usize, mode: ExchangeMode) -> Self {
        Self { parts, mode, metrics: DistMetrics::default() }
    }
}

impl SweepExecutor for DistributedExecutor {
    fn discover(
        &mut self,
        engine: &dyn Engine,
        view: &SeriesView<'_>,
        r: f64,
        pd3: &Pd3Config,
        drag: &mut DragMetrics,
        ws: &mut MerlinWorkspace,
    ) -> Result<()> {
        engine.prepare_series(view);
        distributed_pass(
            engine,
            view,
            r,
            pd3,
            self.parts,
            self.mode,
            drag,
            ws,
            &mut self.metrics,
        )
    }
}

/// Arbitrary-length (MERLIN) discovery over the simulated cluster: a
/// [`MerlinSweep`] whose per-length discovery is [`distributed_pass`].
/// Because every pass returns the exact range-discord set — property-
/// tested equal to brute force for both exchange modes — the adaptive
/// threshold schedule evolves exactly as the single-node sweep's, and
/// the per-length results match `Merlin::run` (unit-tested below).
/// Returns the sweep result plus cumulative communication metrics.
pub fn distributed_merlin(
    engine: &dyn Engine,
    t: &[f64],
    cfg: MerlinConfig,
    parts: usize,
    mode: ExchangeMode,
) -> Result<(MerlinResult, DistMetrics)> {
    let mut sweep = MerlinSweep::new(cfg, t.len())?;
    let mut ws = MerlinWorkspace::new();
    let mut exec = DistributedExecutor::new(parts, mode);
    while sweep.step_with(engine, t, &mut ws, &mut exec)?.is_pending() {}
    let res = sweep.finish();
    let mut metrics = exec.metrics;
    metrics.drag = res.metrics.drag.clone();
    Ok((res, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::brute;
    use crate::engines::native::NativeEngine;
    use crate::util::rng::Rng;

    fn walk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect()
    }

    fn check_equals_brute(t: &[f64], m: usize, r: f64, parts: usize, mode: ExchangeMode) {
        let engine = NativeEngine::with_segn(24);
        let (got, metrics) = distributed_drag(&engine, t, m, r, parts, mode).unwrap();
        let mut want = brute::range_discords(t, m, r);
        want.sort_by_key(|d| d.idx);
        assert_eq!(
            got.iter().map(|d| d.idx).collect::<Vec<_>>(),
            want.iter().map(|d| d.idx).collect::<Vec<_>>(),
            "parts={parts} mode={mode:?}"
        );
        // 1e-6 relative: the engine's Eq. 6 dot-product form and the
        // oracle's direct z-norm form round differently.
        for (g, w) in got.iter().zip(&want) {
            assert!((g.nn_dist - w.nn_dist).abs() < 1e-6 * (1.0 + w.nn_dist));
        }
        assert!(metrics.exchanged >= metrics.survivors);
        assert_eq!(metrics.survivors, got.len());
    }

    #[test]
    fn matches_brute_force_across_partitions() {
        let t = walk(300, 61);
        for parts in [1, 2, 3, 7] {
            check_equals_brute(&t, 14, 3.5, parts, ExchangeMode::Yankov);
            check_equals_brute(&t, 14, 3.5, parts, ExchangeMode::LocalRefine);
        }
    }

    #[test]
    fn local_refine_exchanges_fewer() {
        let t = walk(800, 62);
        let engine = NativeEngine::with_segn(32);
        let (_, my) = distributed_drag(&engine, &t, 16, 2.5, 4, ExchangeMode::Yankov).unwrap();
        let (_, ml) =
            distributed_drag(&engine, &t, 16, 2.5, 4, ExchangeMode::LocalRefine).unwrap();
        assert!(ml.exchanged <= my.exchanged, "{} vs {}", ml.exchanged, my.exchanged);
        assert_eq!(my.survivors, ml.survivors);
        // Identical deterministic selection phases => identical
        // pre-refinement counts, and under Yankov the raw selection set
        // goes on the wire verbatim.
        assert_eq!(my.local_candidates, ml.local_candidates);
        assert_eq!(my.exchanged, my.local_candidates);
        assert!(ml.exchanged <= ml.local_candidates);
        // The engine-level counters surface the scan volume.
        assert!(my.drag.tiles_computed > 0);
        assert!(ml.drag.tiles_computed > 0);
    }

    #[test]
    fn single_partition_degenerates_to_serial() {
        let t = walk(200, 63);
        let engine = NativeEngine::with_segn(32);
        let (got, metrics) =
            distributed_drag(&engine, &t, 10, 3.0, 1, ExchangeMode::Yankov).unwrap();
        let serial = crate::baselines::drag_serial::drag(&t, 10, 3.0);
        assert_eq!(
            got.iter().map(|d| d.idx).collect::<Vec<_>>(),
            serial.iter().map(|d| d.idx).collect::<Vec<_>>()
        );
        assert_eq!(metrics.survivors, got.len());
    }

    #[test]
    fn more_partitions_than_segments_is_safe() {
        let t = walk(40, 64);
        let engine = NativeEngine::with_segn(8);
        let (got, _) =
            distributed_drag(&engine, &t, 8, 2.0, 1000, ExchangeMode::LocalRefine).unwrap();
        let want = brute::range_discords(&t, 8, 2.0);
        assert_eq!(got.len(), want.len());
    }

    #[test]
    fn distributed_merlin_matches_single_node_sweep() {
        use crate::coordinator::merlin::Merlin;
        use crate::core::series::TimeSeries;
        let t = walk(420, 65);
        let cfg = MerlinConfig { min_l: 10, max_l: 18, top_k: 1, ..Default::default() };
        let engine = NativeEngine::with_segn(32);
        let want = Merlin::new(&engine, cfg.clone())
            .run(&TimeSeries::new("walk", t.clone()))
            .unwrap();
        for mode in [ExchangeMode::Yankov, ExchangeMode::LocalRefine] {
            let node = NativeEngine::with_segn(32);
            let (got, dm) = distributed_merlin(&node, &t, cfg.clone(), 3, mode).unwrap();
            assert_eq!(got.lengths.len(), want.lengths.len(), "{mode:?}");
            for (w, g) in want.lengths.iter().zip(&got.lengths) {
                assert_eq!(w.m, g.m);
                assert_eq!(w.retries, g.retries, "m={} {mode:?}", w.m);
                assert_eq!(
                    w.discords.iter().map(|d| d.idx).collect::<Vec<_>>(),
                    g.discords.iter().map(|d| d.idx).collect::<Vec<_>>(),
                    "m={} {mode:?}",
                    w.m
                );
                for (wd, gd) in w.discords.iter().zip(&g.discords) {
                    assert!(
                        (wd.nn_dist - gd.nn_dist).abs() < 1e-9 * (1.0 + wd.nn_dist.abs()),
                        "m={} {mode:?}: {} vs {}",
                        w.m,
                        wd.nn_dist,
                        gd.nn_dist
                    );
                }
            }
            // Every (length, retry) pass contributes to the exchange
            // traffic, and survivors accumulate across lengths.
            assert!(dm.exchanged >= dm.survivors, "{mode:?}");
            assert!(dm.survivors as u64 >= got.metrics.discords, "{mode:?}");
            assert!(dm.drag.tiles_computed > 0, "{mode:?}");
        }
    }

    #[test]
    fn short_series_returns_empty() {
        let engine = NativeEngine::with_segn(8);
        let (got, metrics) =
            distributed_drag(&engine, &[1.0, 2.0], 8, 1.0, 2, ExchangeMode::Yankov).unwrap();
        assert!(got.is_empty());
        assert_eq!(metrics.exchanged, 0);
    }
}
