//! PD3 — Parallel DRAG-based Discord Discovery (Algs. 3/4 of the paper).
//!
//! Finds every *range discord*: subsequences whose nearest non-self match
//! is at squared distance >= `r^2`.  Two phases over the segmented series:
//!
//! - **Selection** (Alg. 3): every segment scans itself and the chunks to
//!   its *right*.  A distance below `r` kills both sides' candidacy; each
//!   computed distance tightens the running nearest-neighbor minima.
//! - **Refinement** (Alg. 4): segments that still hold candidates scan the
//!   chunks to their *left*, completing the distance coverage for every
//!   survivor (so survivors' nnDist values are exact).
//!
//! Scheduling is round-based: in round `k` of a phase, every live segment
//! `i` evaluates chunk `i +/- k`; the whole round is one engine batch
//! (native: thread-pooled tiles, xla: pipelined PJRT executions), mirroring
//! the paper's lock-step GPU grid while letting kill information propagate
//! between rounds — the paper's block-level early termination.
//!
//! Deviations from the pseudocode (documented in DESIGN.md §6):
//! - `col_kill` information can clear `Cand` bits directly
//!   ([`Pd3Config::deferred_neighbor_kill`] = false, the default) instead
//!   of transiting through the `Neighbor` bitmap; both are implemented and
//!   the ablation bench compares them.  Either way the survivor set equals
//!   the brute-force range-discord set (integration-tested).
//! - Padding dummies are replaced by in-kernel validity masks (Eq. 9 is
//!   kept in [`super::segmentation::pad_len`] for the record).

use std::time::Instant;

use anyhow::Result;

use super::metrics::DragMetrics;
use super::segmentation::Segmentation;
use super::workspace::MerlinWorkspace;
use crate::core::bitmap::Bitmap;
use crate::engines::{Engine, SeriesView, TileTask};

/// A discovered discord: subsequence index, length, and the exact distance
/// to its nearest non-self match (ED units, not squared).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Discord {
    pub idx: usize,
    pub m: usize,
    pub nn_dist: f64,
}

/// PD3 knobs (ablation benches flip these).
#[derive(Clone, Copy, Debug)]
pub struct Pd3Config {
    /// Mimic the paper exactly: chunk-side kills go to the `Neighbor`
    /// bitmap and only merge into `Cand` between the phases.  `false`
    /// (default) kills directly, which prunes strictly earlier.
    pub deferred_neighbor_kill: bool,
    /// Skip tiles of fully-pruned segments (Alg. 3 l.14; Alg. 4 l.3).
    pub early_stop: bool,
}

impl Default for Pd3Config {
    fn default() -> Self {
        Self { deferred_neighbor_kill: false, early_stop: true }
    }
}

/// Range-discord discovery at the view's current subsequence length.
///
/// Returns all survivors (unfiltered by top-k) with exact `nn_dist`.
/// Allocating convenience wrapper over [`pd3_into`]; hot callers
/// (MERLIN's retry loop, the streaming monitor) keep a
/// [`MerlinWorkspace`] alive instead.
pub fn pd3(
    engine: &dyn Engine,
    view: &SeriesView<'_>,
    r_ed: f64,
    cfg: &Pd3Config,
    metrics: &mut DragMetrics,
) -> Result<Vec<Discord>> {
    let mut ws = MerlinWorkspace::new();
    pd3_into(engine, view, r_ed, cfg, metrics, &mut ws)?;
    Ok(std::mem::take(&mut ws.discords))
}

/// Range-discord discovery into a recycled [`MerlinWorkspace`].
///
/// Survivors land in `ws.discords()`; every buffer (bitmaps, nnDist
/// minima, round task lists, tile-output blocks) is reused across calls,
/// so a warmed workspace makes repeated invocations allocation-free
/// (proved by `rust/tests/alloc_steady_state.rs`).
pub fn pd3_into(
    engine: &dyn Engine,
    view: &SeriesView<'_>,
    r_ed: f64,
    cfg: &Pd3Config,
    metrics: &mut DragMetrics,
    ws: &mut MerlinWorkspace,
) -> Result<()> {
    let nwin = view.n_windows();
    ws.reset_all_candidates(nwin);
    if nwin == 0 {
        return Ok(());
    }
    // Let the engine bind per-series state (e.g. the native QT seed
    // cache) before any tile is evaluated.
    engine.prepare_series(view);
    pd3_prepared(engine, view, r_ed, cfg, metrics, ws)
}

/// Which scan a phase performs (and which kill counter it feeds).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Scan {
    /// Alg. 3: every segment scans itself and the chunks to its right.
    Select,
    /// Alg. 4: surviving segments scan the chunks to their left.
    Refine,
}

/// Run both PD3 phases over a workspace whose candidate bitmap the
/// caller has already bound to `view` (all-ones for classic PD3, the
/// exchanged candidate set for the distributed refinement).  Survivors
/// land in `ws.discords` with exact nnDist.  The caller must have run
/// [`Engine::prepare_series`] for `view` (its O(n) content fingerprint
/// is thus paid once per outer run, not per phase pass).
pub(crate) fn pd3_prepared(
    engine: &dyn Engine,
    view: &SeriesView<'_>,
    r_ed: f64,
    cfg: &Pd3Config,
    metrics: &mut DragMetrics,
    ws: &mut MerlinWorkspace,
) -> Result<()> {
    let nwin = view.n_windows();
    debug_assert_eq!(ws.cand.len(), nwin, "workspace not bound to this view");
    let seg = Segmentation::new(nwin, engine.segn());
    let r2 = r_ed * r_ed;

    // ---- Phase 1: selection (self + right scan) --------------------------
    let t0 = Instant::now();
    scan_phase(engine, view, r2, cfg, metrics, ws, &seg, 0, seg.nseg, Scan::Select)?;
    metrics.select_time += t0.elapsed();

    // ---- Phase 2: refinement (left scan) ---------------------------------
    let t1 = Instant::now();
    if cfg.deferred_neighbor_kill {
        ws.cand.and_with(&ws.neighbor); // Alg. 4 l.1-2
    }
    scan_phase(engine, view, r2, cfg, metrics, ws, &seg, 0, seg.nseg, Scan::Refine)?;
    metrics.refine_time += t1.elapsed();

    collect_survivors(view.stats.m, r2, metrics, ws);
    Ok(())
}

/// One scan phase over the segments `[seg_lo, seg_hi)`, with both tile
/// sides restricted to that range — `[0, nseg)` for classic PD3; a
/// node's own segment span for the distributed local phases.  Round `k`
/// pairs every live segment `i` with chunk `i + k` (Select) or `i - k`
/// (Refine); each round is one engine batch through the workspace's
/// recycled task/output buffers.
#[allow(clippy::too_many_arguments)]
pub(crate) fn scan_phase(
    engine: &dyn Engine,
    view: &SeriesView<'_>,
    r2: f64,
    cfg: &Pd3Config,
    metrics: &mut DragMetrics,
    ws: &mut MerlinWorkspace,
    seg: &Segmentation,
    seg_lo: usize,
    seg_hi: usize,
    scan: Scan,
) -> Result<()> {
    let nwin = view.n_windows();
    let span = seg_hi - seg_lo;
    let k_from = match scan {
        Scan::Select => 0,
        Scan::Refine => 1,
    };
    for k in k_from..span {
        ws.tasks.clear();
        ws.rows.clear();
        let pair_of = |i: usize| match scan {
            Scan::Select => (i, i + k),
            Scan::Refine => (i, i - k),
        };
        let i_range = match scan {
            Scan::Select => seg_lo..seg_hi - k,
            Scan::Refine => seg_lo + k..seg_hi,
        };
        for (i, j) in i_range.map(pair_of) {
            let ri = seg.seg_range(i);
            if cfg.early_stop && !ws.cand.any_in_range(ri.start, ri.end) {
                metrics.tiles_skipped += 1;
                continue;
            }
            ws.tasks.push(TileTask { seg_start: seg.seg_start(i), chunk_start: seg.seg_start(j) });
            ws.rows.push((i, j));
        }
        if ws.tasks.is_empty() {
            continue;
        }
        metrics.tiles_computed += ws.tasks.len() as u64;
        engine.compute_tiles_into(view, r2, &ws.tasks, &mut ws.tile_buf)?;
        let kill_counter = match scan {
            Scan::Select => &mut metrics.kills_select,
            Scan::Refine => &mut metrics.kills_refine,
        };
        for (&(i, j), out) in ws.rows.iter().zip(&ws.tile_buf) {
            apply_side(
                &mut ws.cand,
                &mut ws.nn_dist,
                seg.seg_start(i),
                nwin,
                &out.row_min,
                &out.row_kill,
                None,
                kill_counter,
            );
            // Chunk-side kills are equally valid in either direction; in
            // the selection phase they optionally transit the Neighbor
            // bitmap (the paper's deferred merge).
            let neighbor_bm = if scan == Scan::Select && cfg.deferred_neighbor_kill {
                Some(&mut ws.neighbor)
            } else {
                None
            };
            apply_side(
                &mut ws.cand,
                &mut ws.nn_dist,
                seg.seg_start(j),
                nwin,
                &out.col_min,
                &out.col_kill,
                neighbor_bm,
                kill_counter,
            );
        }
    }
    Ok(())
}

/// Fold the candidate bitmap + minima into `ws.discords`.
fn collect_survivors(m: usize, r2: f64, metrics: &mut DragMetrics, ws: &mut MerlinWorkspace) {
    ws.discords.clear();
    for idx in ws.cand.iter_set() {
        let d2 = ws.nn_dist[idx];
        debug_assert!(
            d2.is_infinite() || d2 >= r2 - 1e-6 * (1.0 + r2),
            "survivor {idx} has nnDist^2 {d2} < r^2 {r2}"
        );
        if d2.is_finite() {
            ws.discords.push(Discord { idx, m, nn_dist: d2.max(0.0).sqrt() });
        }
        // A survivor with infinite nnDist means the series has no valid
        // non-self match for it (nwin <= m); nothing to report.
    }
    metrics.survivors += ws.discords.len() as u64;
}

/// Fold one tile side (rows or cols) into the global state.
#[allow(clippy::too_many_arguments)]
fn apply_side(
    cand: &mut Bitmap,
    nn_dist: &mut [f64],
    start: usize,
    nwin: usize,
    mins: &[f64],
    kills: &[bool],
    neighbor: Option<&mut Bitmap>,
    kill_counter: &mut u64,
) {
    let len = mins.len().min(nwin.saturating_sub(start));
    match neighbor {
        None => {
            for k in 0..len {
                let g = start + k;
                if mins[k] < nn_dist[g] {
                    nn_dist[g] = mins[k];
                }
                if kills[k] && cand.get(g) {
                    cand.clear(g);
                    *kill_counter += 1;
                }
            }
        }
        Some(nb) => {
            for k in 0..len {
                let g = start + k;
                if mins[k] < nn_dist[g] {
                    nn_dist[g] = mins[k];
                }
                if kills[k] && nb.get(g) {
                    nb.clear(g);
                    *kill_counter += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::ed2norm;
    use crate::core::stats::RollingStats;
    use crate::engines::native::NativeEngine;
    use crate::util::rng::Rng;

    fn random_walk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect()
    }

    /// Brute-force range discords: for every window, exact nnDist.
    fn brute_range_discords(t: &[f64], m: usize, r_ed: f64) -> Vec<Discord> {
        let nwin = t.len() - m + 1;
        let mut out = Vec::new();
        for i in 0..nwin {
            let mut best = f64::INFINITY;
            for j in 0..nwin {
                if i.abs_diff(j) < m {
                    continue;
                }
                best = best.min(ed2norm(&t[i..i + m], &t[j..j + m]));
            }
            if best.is_finite() && best >= r_ed * r_ed {
                out.push(Discord { idx: i, m, nn_dist: best.sqrt() });
            }
        }
        out
    }

    fn run_pd3(t: &[f64], m: usize, r: f64, cfg: &Pd3Config, segn: usize) -> Vec<Discord> {
        let stats = RollingStats::compute(t, m);
        let view = SeriesView { t, stats: &stats };
        let engine = NativeEngine::with_segn(segn);
        let mut metrics = DragMetrics::default();
        let mut got = pd3(&engine, &view, r, cfg, &mut metrics).unwrap();
        got.sort_by_key(|d| d.idx);
        got
    }

    fn check_equals_brute(t: &[f64], m: usize, r: f64, cfg: &Pd3Config, segn: usize) {
        let got = run_pd3(t, m, r, cfg, segn);
        let want = brute_range_discords(t, m, r);
        assert_eq!(
            got.iter().map(|d| d.idx).collect::<Vec<_>>(),
            want.iter().map(|d| d.idx).collect::<Vec<_>>(),
            "survivor sets differ (m={m}, r={r}, segn={segn})"
        );
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g.nn_dist - w.nn_dist).abs() < 1e-6 * (1.0 + w.nn_dist),
                "nnDist mismatch at {}: {} vs {}",
                g.idx,
                g.nn_dist,
                w.nn_dist
            );
        }
    }

    #[test]
    fn matches_brute_force_medium_r() {
        let t = random_walk(300, 11);
        check_equals_brute(&t, 16, 4.0, &Pd3Config::default(), 32);
    }

    #[test]
    fn matches_brute_force_various_segn() {
        let t = random_walk(250, 12);
        for segn in [8, 17, 64, 300] {
            check_equals_brute(&t, 12, 3.5, &Pd3Config::default(), segn);
        }
    }

    #[test]
    fn deferred_neighbor_matches_direct() {
        let t = random_walk(300, 13);
        let direct = run_pd3(&t, 16, 4.0, &Pd3Config::default(), 32);
        let deferred = run_pd3(
            &t,
            16,
            4.0,
            &Pd3Config { deferred_neighbor_kill: true, early_stop: true },
            32,
        );
        assert_eq!(direct, deferred);
    }

    #[test]
    fn no_early_stop_matches() {
        let t = random_walk(300, 14);
        let a = run_pd3(&t, 16, 4.0, &Pd3Config::default(), 32);
        let b = run_pd3(&t, 16, 4.0, &Pd3Config { early_stop: false, ..Default::default() }, 32);
        assert_eq!(a, b);
    }

    #[test]
    fn huge_r_returns_empty() {
        let t = random_walk(200, 15);
        let got = run_pd3(&t, 16, 2.0 * 4.0 + 1.0, &Pd3Config::default(), 32);
        assert!(got.is_empty());
    }

    #[test]
    fn tiny_r_returns_everything() {
        let t = random_walk(120, 16);
        let m = 10;
        let got = run_pd3(&t, m, 0.0, &Pd3Config::default(), 16);
        assert_eq!(got.len(), t.len() - m + 1);
        // And nnDists equal the full matrix-profile values.
        let want = brute_range_discords(&t, m, 0.0);
        for (g, w) in got.iter().zip(&want) {
            assert!((g.nn_dist - w.nn_dist).abs() < 1e-6 * (1.0 + w.nn_dist));
        }
    }

    #[test]
    fn planted_discord_found() {
        let mut t: Vec<f64> = (0..400).map(|i| (i as f64 * 0.3).sin()).collect();
        // Plant an anomaly at 200..216.
        for (k, v) in t[200..216].iter_mut().enumerate() {
            *v += if k % 2 == 0 { 1.5 } else { -1.5 };
        }
        let m = 16;
        let got = run_pd3(&t, m, 3.0, &Pd3Config::default(), 32);
        assert!(!got.is_empty());
        let best = got.iter().max_by(|a, b| a.nn_dist.partial_cmp(&b.nn_dist).unwrap()).unwrap();
        assert!(
            (185..=215).contains(&best.idx),
            "best discord at {} not near planted anomaly",
            best.idx
        );
    }

    #[test]
    fn recycled_workspace_matches_fresh_runs() {
        // The MERLIN retry-loop shape: one workspace, descending r at a
        // fixed length.  Every recycled run must agree with a fresh
        // (allocating) pd3 call, and only the cold rebind may grow.
        let t = random_walk(400, 18);
        let stats = RollingStats::compute(&t, 16);
        let view = SeriesView { t: &t, stats: &stats };
        let engine = NativeEngine::with_segn(32);
        let mut ws = MerlinWorkspace::new();
        let mut metrics = DragMetrics::default();
        let rs = [6.0, 4.0, 2.5, 0.5];
        let mut recycled: Vec<Vec<Discord>> = Vec::new();
        for &r in &rs {
            pd3_into(&engine, &view, r, &Pd3Config::default(), &mut metrics, &mut ws).unwrap();
            recycled.push(ws.discords().to_vec());
        }
        for (k, &r) in rs.iter().enumerate() {
            let fresh =
                pd3(&engine, &view, r, &Pd3Config::default(), &mut DragMetrics::default())
                    .unwrap();
            assert_eq!(recycled[k], fresh, "r={r}");
        }
        let c = ws.counters();
        assert_eq!(c.resets, rs.len() as u64);
        assert_eq!(c.grows, 1, "only the cold rebind may grow the arena");
    }

    #[test]
    fn early_stop_skips_tiles() {
        let t = random_walk(2000, 17);
        let stats = RollingStats::compute(&t, 32);
        let view = SeriesView { t: &t, stats: &stats };
        let engine = NativeEngine::with_segn(64);
        let mut metrics = DragMetrics::default();
        // High r (close to the 2*sqrt(32) ~ 11.3 bound) kills candidates
        // fast, so whole segments die and their tiles are skipped.
        pd3(&engine, &view, 8.0, &Pd3Config::default(), &mut metrics).unwrap();
        assert!(metrics.tiles_skipped > 0, "expected early-stop skips");
    }
}
