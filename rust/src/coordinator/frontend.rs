//! Evented serving front end: one reactor thread multiplexes every
//! client connection over non-blocking sockets.
//!
//! The PR-5 front end spawned a thread per connection and spun each on
//! a 500 ms read timeout; N idle clients cost N threads and N wakeups
//! per half-second, which caps connection counts long before the
//! engines saturate.  The reactor inverts that: the listener and every
//! accepted stream are switched to non-blocking mode, and a single
//! thread runs a level-triggered scan loop — accept burst, per-
//! connection flush/read/process, then an *adaptive* idle sleep (500µs
//! doubling to 5ms) only when a full scan made no progress.  N idle
//! connections therefore cost N registered sockets and one mostly-
//! sleeping thread (`idle_connections_share_one_thread` in
//! `rust/tests/frontend_service.rs` pins the thread count).
//!
//! Why a scan loop and not epoll/kqueue: `coordinator/` is
//! `#![forbid(unsafe_code)]` and the container offers no safe poll
//! binding, so the portable scan is the baseline; its cost is O(conns)
//! per wakeup with zero syscalls per *idle* connection beyond the
//! non-blocking `read`.  The loop structure (accept → drive conns →
//! sleep-if-idle) is exactly the shape an epoll readiness list would
//! feed, so swapping one in later is a local change to `serve_listener`
//! — nothing in the protocol layer knows how readiness is discovered.
//!
//! Protocol execution is shared with the blocking path:
//! [`Service::execute_line`] produces either a complete reply or a
//! [`DataIngest`] state machine, and this module only shuttles bytes —
//! so both front ends speak byte-for-byte the same protocol.
//!
//! Admission: beyond [`ServiceConfig::max_conns`] open connections,
//! new arrivals get a best-effort `ERR BUSY retry_after=<ms>` and are
//! closed immediately (counted in `wfq(rejected)=`).
//!
//! [`ServiceConfig::max_conns`]: super::service::ServiceConfig::max_conns

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use anyhow::Result;

use super::service::{DataIngest, LineOutcome, Service};

/// Bytes pulled per non-blocking read.
const READ_CHUNK: usize = 16 * 1024;
/// A request line (not DATA values) longer than this is a protocol
/// error: reply ERR and drop the connection rather than buffer
/// unboundedly.
const MAX_LINE: usize = 64 * 1024;
/// In DATA mode, a partial line this long is fed to the ingester at a
/// whitespace boundary instead of waiting for the newline, so a
/// single-line multi-megabyte upload never accumulates in `inbuf`.
const DATA_FEED_THRESHOLD: usize = 64 * 1024;
/// Adaptive idle sleep: a scan that made progress resets to the
/// minimum; consecutive idle scans double toward the maximum.
const IDLE_SLEEP_MIN: Duration = Duration::from_micros(500);
const IDLE_SLEEP_MAX: Duration = Duration::from_millis(5);

struct Conn {
    stream: TcpStream,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
    /// Mid-upload state machine (DATA verb).
    data: Option<DataIngest>,
    /// Flush `outbuf`, then close (BUSY reject, oversized line, or
    /// service shutdown).
    closing: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Self {
        Self { stream, inbuf: Vec::new(), outbuf: Vec::new(), data: None, closing: false }
    }
}

/// What one scan pass over a connection concluded.
enum ConnScan {
    /// Keep the connection registered.
    Keep { progressed: bool },
    /// Unregister (EOF, I/O error, or `closing` with an empty outbuf).
    Drop,
    /// The connection requested SHUTDOWN (its `OK BYE` is flushed).
    Shutdown,
}

/// Run the reactor over an already-bound listener until a SHUTDOWN
/// request (or [`Service::stop_listener`]) arrives, then drain the
/// scheduler via [`Service::shutdown`].  Used by [`Service::serve`];
/// tests bind their own ephemeral listener and call this directly.
pub fn serve_listener(svc: &Service, listener: TcpListener) -> Result<()> {
    listener.set_nonblocking(true)?;
    let mut conns: Vec<Conn> = Vec::new();
    let mut idle_sleep = IDLE_SLEEP_MIN;
    let mut scratch = vec![0u8; READ_CHUNK];
    let mut shutdown_requested = false;
    'reactor: loop {
        let mut progressed = false;
        // ---- Accept burst: take everything pending, then move on.
        loop {
            match listener.accept() {
                Ok((stream, peer)) => {
                    progressed = true;
                    if stream.set_nonblocking(true).is_err() {
                        continue; // socket died between accept and here
                    }
                    if !svc.conn_opened() {
                        // Over max_conns: 429-equivalent, then close.
                        // ok-drop: best-effort courtesy on a socket we are
                        // dropping either way.
                        let _ = write!(
                            &stream,
                            "ERR BUSY retry_after={} (too many connections)\n",
                            svc.retry_after_ms()
                        );
                        continue;
                    }
                    crate::log_debug!("frontend: accepted {peer}");
                    conns.push(Conn::new(stream));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    // A transient accept failure (EMFILE under load)
                    // must not kill the serving loop.
                    crate::log_warn!("frontend: accept failed: {e}");
                    break;
                }
            }
        }
        // ---- Drive every connection: flush, read, process.
        let mut i = 0;
        while i < conns.len() {
            match drive_conn(svc, &mut conns[i], &mut scratch) {
                ConnScan::Keep { progressed: p } => {
                    progressed |= p;
                    i += 1;
                }
                ConnScan::Drop => {
                    conns.swap_remove(i);
                    svc.conn_closed();
                    progressed = true;
                }
                ConnScan::Shutdown => {
                    conns.swap_remove(i);
                    svc.conn_closed();
                    shutdown_requested = true;
                    break 'reactor;
                }
            }
        }
        if svc.listener_stopped() {
            break;
        }
        // ---- Adaptive idle backoff: busy scans spin (sub-millisecond
        // latency under load), quiet ones sleep up to IDLE_SLEEP_MAX.
        if progressed {
            idle_sleep = IDLE_SLEEP_MIN;
        } else {
            std::thread::sleep(idle_sleep);
            idle_sleep = (idle_sleep * 2).min(IDLE_SLEEP_MAX);
        }
    }
    // ---- Teardown: stop accepting, drain the scheduler, and give the
    // surviving connections a best-effort goodbye flush.
    svc.stop_listener();
    if shutdown_requested {
        svc.shutdown();
    }
    for conn in &mut conns {
        if !conn.outbuf.is_empty() {
            // ok-drop: closing flush; the peer may already be gone.
            let _ = conn.stream.write_all(&conn.outbuf);
        }
        svc.conn_closed();
    }
    Ok(())
}

/// One scan pass over a single connection: flush pending output, pull
/// whatever bytes are ready, process complete lines.
fn drive_conn(svc: &Service, conn: &mut Conn, scratch: &mut [u8]) -> ConnScan {
    let mut progressed = false;
    // ---- Flush.
    while !conn.outbuf.is_empty() {
        match conn.stream.write(&conn.outbuf) {
            Ok(0) => return ConnScan::Drop,
            Ok(n) => {
                conn.outbuf.drain(..n);
                progressed = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return ConnScan::Drop,
        }
    }
    if conn.closing {
        return if conn.outbuf.is_empty() { ConnScan::Drop } else { ConnScan::Keep { progressed } };
    }
    // ---- Read.
    loop {
        match conn.stream.read(scratch) {
            Ok(0) => {
                // EOF: anything already buffered still gets processed
                // below (the reply flushes on the next scan if the peer
                // only half-closed); a fully gone peer drops then.
                conn.closing = true;
                progressed = true;
                break;
            }
            Ok(n) => {
                conn.inbuf.extend_from_slice(&scratch[..n]);
                progressed = true;
                // Keep scanning fair under a fire-hose client: one
                // chunk per scan pass is plenty (the loop comes right
                // back while progress holds).
                break;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return ConnScan::Drop,
        }
    }
    // ---- Process complete lines (and, in DATA mode, whitespace-
    // bounded partial chunks, so single-line bulk uploads never pool
    // up in inbuf).
    loop {
        // DATA ingestion first: value lines are not commands.
        if let Some(ing) = conn.data.as_mut() {
            let Some(feed_end) = data_feed_end(&conn.inbuf) else { break };
            let chunk: Vec<u8> = conn.inbuf.drain(..feed_end).collect();
            let text = String::from_utf8_lossy(&chunk);
            if ing.feed_line(&text) {
                let reply = ing.finish(svc);
                conn.outbuf.extend_from_slice(reply.as_bytes());
                conn.data = None;
            }
            progressed = true;
            continue;
        }
        let Some(pos) = conn.inbuf.iter().position(|&b| b == b'\n') else {
            if conn.inbuf.len() > MAX_LINE {
                conn.outbuf.extend_from_slice(b"ERR request line too long\n");
                conn.closing = true;
            }
            break;
        };
        let line: Vec<u8> = conn.inbuf.drain(..=pos).collect();
        let text = String::from_utf8_lossy(&line);
        let req = text.trim();
        if req.is_empty() {
            continue;
        }
        progressed = true;
        crate::log_debug!("frontend request: {req}");
        match svc.execute_line(req) {
            LineOutcome::Reply(reply) => conn.outbuf.extend_from_slice(reply.as_bytes()),
            LineOutcome::BeginData(ing) => conn.data = Some(ing),
            LineOutcome::Shutdown(reply) => {
                // Flush the goodbye synchronously (bounded by the
                // socket buffer; the peer asked and is reading).
                conn.outbuf.extend_from_slice(reply.as_bytes());
                // ok-drop: if the peer vanished mid-goodbye the
                // shutdown proceeds regardless.
                let _ = conn.stream.set_nonblocking(false);
                let _ = conn.stream.write_all(&conn.outbuf);
                conn.outbuf.clear();
                return ConnScan::Shutdown;
            }
        }
    }
    if conn.closing && conn.outbuf.is_empty() {
        return ConnScan::Drop;
    }
    ConnScan::Keep { progressed }
}

/// How many leading bytes of `inbuf` can be fed to the DATA ingester:
/// up to and including a newline, or — for an oversized partial line —
/// up to the last whitespace (a number token is never split).  `None`
/// means wait for more bytes.
fn data_feed_end(inbuf: &[u8]) -> Option<usize> {
    if let Some(pos) = inbuf.iter().position(|&b| b == b'\n') {
        return Some(pos + 1);
    }
    if inbuf.len() >= DATA_FEED_THRESHOLD {
        if let Some(ws) = inbuf.iter().rposition(|b| b.is_ascii_whitespace()) {
            return Some(ws + 1);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_feed_end_respects_token_boundaries() {
        assert_eq!(data_feed_end(b"1.5 2.5\n"), Some(8));
        assert_eq!(data_feed_end(b"1.5 2.5"), None, "short partial line waits");
        // Oversized partial line: feed to the last whitespace.
        let mut big = b"1.5 ".repeat(DATA_FEED_THRESHOLD / 4 + 1);
        big.extend_from_slice(b"17.25");
        let end = data_feed_end(&big).expect("oversized chunk must feed");
        assert_eq!(&big[end..], b"17.25", "the split token stays buffered");
        // A single giant token has no safe split point.
        let giant = vec![b'7'; DATA_FEED_THRESHOLD + 16];
        assert_eq!(data_feed_end(&giant), None);
    }
}
