//! Keyed engine/workspace lease pool — the serving layer's shared
//! substrate for interleaved tenants.
//!
//! The pre-scheduler service gave every worker thread its own engine
//! for its whole lifetime, so a worker's QT seed cache and PD3 arena
//! served exactly one job at a time and sat idle between jobs.  The
//! step scheduler (`coordinator/service.rs`) instead checks an
//! `(engine, MerlinWorkspace)` pair out of this pool *per step*, keyed
//! by job id:
//!
//! - **Sticky checkout**: a tenant prefers the entry it used last.  The
//!   native engine's seed cache is bound to one series at a time
//!   (content fingerprint, `engines/scratch.rs`), so stickiness is what
//!   preserves the paper's cross-length QT reuse when many jobs
//!   interleave — a sticky hit re-enters `prepare_series` as a no-op
//!   and the next length opens on prefetched rows.
//! - **LRU steal**: with more tenants than entries, a checkout takes
//!   the least-recently-used entry; the victim tenant's binding is
//!   evicted on the thief's first `prepare_series` (rows recycle
//!   through the cache's spare pools, so steals churn bindings, not
//!   allocations).
//! - **Blocking**: checkouts beyond capacity wait on a condvar; the
//!   service sizes the pool to its worker count so steps never queue
//!   here in the default configuration.
//!
//! `rust/tests/alloc_steady_state.rs` proves a warm pool is
//! allocation-free across interleaved jobs: checkout, step, and return
//! touch no heap once every arena has reached its high-water mark.
//!
//! The checkout/blocking/steal protocol is model-checked: primitives
//! come through [`crate::util::loomsync`], and the `engine_pool_*`
//! models in `rust/tests/loom_models.rs` explore sticky-vs-steal races
//! and the condvar wakeup on return.  Orderings are audited in
//! `CONCURRENCY.md` §lease.rs.

use crate::util::loomsync::atomic::{AtomicU64, Ordering};
use crate::util::loomsync::{Condvar, Mutex};

use anyhow::Result;

use super::config::{build_engine, EngineOptions};
use super::workspace::MerlinWorkspace;
use crate::engines::Engine;
use crate::util::sync::{lock_recover, wait_recover};

/// Pool traffic counters (the `lease(sticky/rebinds)=` gauges of the
/// service metrics line).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolCounters {
    /// Total checkouts.
    pub leases: u64,
    /// Checkouts that found an entry already keyed to the caller (warm
    /// engine cache + warm workspace).
    pub sticky_hits: u64,
    /// Checkouts that had to steal an entry keyed to a *different*
    /// tenant, evicting its series binding.
    pub rebinds: u64,
}

struct PoolEntry {
    engine: Box<dyn Engine>,
    ws: MerlinWorkspace,
    /// Tenant that last used this entry (None = never keyed).
    key: Option<u64>,
    /// Monotonic return tick, for LRU victim selection.
    last_used: u64,
}

/// Fixed-capacity pool of engine/workspace pairs (module docs).
pub struct EnginePool {
    /// `None` marks a slot whose entry is currently leased out.
    slots: Mutex<Vec<Option<PoolEntry>>>,
    free: Condvar,
    tick: AtomicU64,
    leases: AtomicU64,
    sticky_hits: AtomicU64,
    rebinds: AtomicU64,
}

impl EnginePool {
    /// Build `capacity` engines up front (clamped to >= 1).
    pub fn new(opts: &EngineOptions, capacity: usize) -> Result<Self> {
        let capacity = capacity.max(1);
        let mut slots = Vec::with_capacity(capacity);
        for _ in 0..capacity {
            slots.push(Some(PoolEntry {
                engine: build_engine(opts)?,
                ws: MerlinWorkspace::new(),
                key: None,
                last_used: 0,
            }));
        }
        Ok(Self {
            slots: Mutex::new(slots),
            free: Condvar::new(),
            tick: AtomicU64::new(0),
            leases: AtomicU64::new(0),
            sticky_hits: AtomicU64::new(0),
            rebinds: AtomicU64::new(0),
        })
    }

    pub fn capacity(&self) -> usize {
        lock_recover(&self.slots).len()
    }

    pub fn counters(&self) -> PoolCounters {
        PoolCounters {
            leases: self.leases.load(Ordering::Relaxed),
            sticky_hits: self.sticky_hits.load(Ordering::Relaxed),
            rebinds: self.rebinds.load(Ordering::Relaxed),
        }
    }

    /// Check out an engine + workspace for tenant `key`, blocking until
    /// one is free.  Preference order: the entry last used by `key`
    /// (sticky), then a never-keyed entry, then the least-recently-used
    /// entry of another tenant (steal).
    pub fn checkout(&self, key: u64) -> Lease<'_> {
        let mut slots = lock_recover(&self.slots);
        loop {
            let mut sticky: Option<usize> = None;
            let mut unkeyed: Option<(usize, u64)> = None;
            let mut other: Option<(usize, u64)> = None;
            for (i, slot) in slots.iter().enumerate() {
                let Some(e) = slot else { continue };
                if e.key == Some(key) {
                    sticky = Some(i);
                    break;
                }
                let best = if e.key.is_none() { &mut unkeyed } else { &mut other };
                let better = match *best {
                    None => true,
                    Some((_, lu)) => e.last_used < lu,
                };
                if better {
                    *best = Some((i, e.last_used));
                }
            }
            let (idx, stolen) = match (sticky, unkeyed, other) {
                (Some(i), _, _) => (i, false),
                (None, Some((i, _)), _) => (i, false),
                (None, None, Some((i, _))) => (i, true),
                (None, None, None) => {
                    slots = wait_recover(&self.free, slots);
                    continue;
                }
            };
            let mut entry = slots[idx].take().expect("picked slot holds an entry");
            self.leases.fetch_add(1, Ordering::Relaxed);
            if sticky.is_some() {
                self.sticky_hits.fetch_add(1, Ordering::Relaxed);
            }
            if stolen {
                self.rebinds.fetch_add(1, Ordering::Relaxed);
            }
            entry.key = Some(key);
            return Lease { pool: self, slot: idx, entry: Some(entry) };
        }
    }
}

/// A checked-out engine/workspace pair; returns to its pool on drop.
pub struct Lease<'p> {
    pool: &'p EnginePool,
    slot: usize,
    entry: Option<PoolEntry>,
}

impl Lease<'_> {
    pub fn engine(&self) -> &dyn Engine {
        &*self.entry.as_ref().expect("live lease").engine
    }

    /// Split borrow for [`super::merlin::MerlinSweep::step`], which
    /// needs the engine and the workspace simultaneously.
    pub fn engine_and_workspace(&mut self) -> (&dyn Engine, &mut MerlinWorkspace) {
        let e = self.entry.as_mut().expect("live lease");
        (&*e.engine, &mut e.ws)
    }
}

impl Drop for Lease<'_> {
    fn drop(&mut self) {
        if let Some(mut e) = self.entry.take() {
            // ordering: Relaxed suffices — `tick` is an RMW counter whose
            // total modification order alone defines LRU age, and
            // `last_used` is published to readers by the `slots` mutex
            // below, never by the atomic itself (CONCURRENCY.md
            // §lease.rs; the ordering was audited, not just assumed).
            e.last_used = self.pool.tick.fetch_add(1, Ordering::Relaxed) + 1;
            let mut slots = lock_recover(&self.pool.slots);
            slots[self.slot] = Some(e);
            // Notify while still holding `slots`: a blocked checkout is
            // either already waiting (gets the notify) or has not yet
            // re-checked the slots it can only scan under this lock — the
            // loom model `engine_pool_blocked_checkout_wakes` pins this.
            self.pool.free.notify_one();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(capacity: usize) -> EnginePool {
        EnginePool::new(&EngineOptions { segn: 32, threads: 1, ..Default::default() }, capacity)
            .unwrap()
    }

    #[test]
    fn sticky_checkout_returns_the_same_engine() {
        let p = pool(2);
        let first = {
            let lease = p.checkout(7);
            lease.engine() as *const dyn Engine as *const ()
        };
        // Another tenant takes the *other* (unkeyed) entry, not ours.
        {
            let other = p.checkout(8);
            assert_ne!(other.engine() as *const dyn Engine as *const (), first);
        }
        let again = {
            let lease = p.checkout(7);
            lease.engine() as *const dyn Engine as *const ()
        };
        assert_eq!(again, first, "tenant 7 must get its sticky entry back");
        let c = p.counters();
        assert_eq!(c.leases, 3);
        assert_eq!(c.sticky_hits, 1);
        assert_eq!(c.rebinds, 0);
    }

    #[test]
    fn steal_prefers_least_recently_used() {
        let p = pool(2);
        // Key both entries, touching tenant 1 last.
        drop(p.checkout(1));
        let two = {
            let lease = p.checkout(2);
            lease.engine() as *const dyn Engine as *const ()
        };
        drop(p.checkout(1));
        // Tenant 3 must steal tenant 2's entry (older return tick).
        let three = {
            let lease = p.checkout(3);
            lease.engine() as *const dyn Engine as *const ()
        };
        assert_eq!(three, two, "the steal victim is the LRU entry");
        let c = p.counters();
        assert_eq!(c.rebinds, 1);
        assert_eq!(c.sticky_hits, 1);
    }

    #[test]
    fn exhausted_pool_blocks_until_a_lease_returns() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;
        let p = Arc::new(pool(1));
        let lease = p.checkout(1);
        let got_it = Arc::new(AtomicBool::new(false));
        let (p2, flag) = (Arc::clone(&p), Arc::clone(&got_it));
        let waiter = std::thread::spawn(move || {
            let _lease = p2.checkout(2);
            flag.store(true, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!got_it.load(Ordering::SeqCst), "checkout must block while the pool is empty");
        drop(lease);
        waiter.join().unwrap();
        assert!(got_it.load(Ordering::SeqCst));
        assert_eq!(p.counters().leases, 2);
    }

    #[test]
    fn capacity_is_clamped_to_one() {
        assert_eq!(pool(0).capacity(), 1);
    }
}
