//! MERLIN (Alg. 1): arbitrary-length discord discovery via adaptive
//! range-threshold selection over repeated PD3 calls.
//!
//! For each length `m` in `[minL, maxL]` the driver picks a threshold `r`
//! that is "a little less" than the eventual discord distance — close
//! enough that PD3 prunes almost everything, but not above it (which would
//! return nothing):
//!
//! - `m = minL`: start at the theoretical maximum `2*sqrt(m)`, halve until
//!   PD3 succeeds.
//! - next four lengths: `r = 0.99 * nnDist_{m-1}`, shaving 1% per retry.
//! - afterwards: `r = mean - 2*std` of the previous five nnDists,
//!   subtracting one std per retry.
//!
//! The per-length window statistics are *not* recomputed: the rolling
//! vectors advance by the paper's recurrences (Eqs. 7/8) — the
//! redundant-calculation elimination that headlines the paper — either
//! natively or through the AOT `stats_update` kernel
//! ([`MerlinConfig::stats_backend`]).
//!
//! The driver itself is a resumable state machine, [`MerlinSweep`]: one
//! [`MerlinSweep::step`] advances exactly one length (threshold
//! selection + adaptive-r PD3 retries) and returns
//! [`SweepStatus::Pending`] or [`SweepStatus::Done`], carrying the
//! rolling stats, the last-five nnDist ring, and the accumulated
//! metrics between steps.  [`Merlin::run`] is a thin loop over `step`;
//! the job service schedules *steps* of many concurrent sweeps over a
//! shared engine lease pool (`coordinator/service.rs`), the streaming
//! monitor drives a single-length sweep per refresh, and the
//! distributed coordinator plugs its exchange procedure in via
//! [`SweepExecutor`] — one sweep driver for every path in the tree.

use std::time::Instant;

use anyhow::{bail, Result};

use super::drag::{pd3_into, Discord, Pd3Config};
use super::metrics::{DragMetrics, MerlinMetrics};
use super::workspace::MerlinWorkspace;
use crate::core::series::TimeSeries;
use crate::core::stats::RollingStats;
use crate::core::topk::{top_k_non_overlapping_into, Scored};
use crate::core::windows::cmp_score_desc;
use crate::engines::{Engine, SeriesView};

/// Envelope identity for [`MerlinSweep::snapshot`] buffers.  Bump the
/// version on any wire-format change; `restore` rejects other versions.
const SNAPSHOT_MAGIC: &[u8; 8] = b"PALMSWP\0";
const SNAPSHOT_VERSION: u32 = 1;

/// How the rolling stats vectors are produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum StatsBackend {
    /// f64 in-process (Eq. 4 scan + Eqs. 7/8 recurrence).
    #[default]
    Native,
    /// The AOT `stats_init` / `stats_update` kernels via PJRT (same math,
    /// exercised end-to-end; slower at small n due to call overhead).
    Aot,
    /// Recompute from scratch every length (ablation baseline: what the
    /// paper's recurrences save).
    NaivePerLength,
}

/// MERLIN driver configuration.
#[derive(Clone, Debug)]
pub struct MerlinConfig {
    pub min_l: usize,
    pub max_l: usize,
    /// Top-k discords to report per length (0 = all survivors).
    pub top_k: usize,
    pub pd3: Pd3Config,
    pub stats_backend: StatsBackend,
    /// Retry guard per length (each retry lowers r and re-runs PD3).
    pub max_retries: usize,
    /// Give up lowering r below this fraction of `2*sqrt(m)`.
    pub r_floor_frac: f64,
}

impl Default for MerlinConfig {
    fn default() -> Self {
        Self {
            min_l: 64,
            max_l: 128,
            top_k: 1,
            pd3: Pd3Config::default(),
            stats_backend: StatsBackend::Native,
            max_retries: 60,
            r_floor_frac: 1e-4,
        }
    }
}

/// Per-length outcome.
#[derive(Clone, Debug)]
pub struct LengthResult {
    pub m: usize,
    /// Threshold the successful PD3 call used (ED units).
    pub r_used: f64,
    /// Retries needed at this length.
    pub retries: usize,
    /// Top-k (or all) discords, sorted by nn_dist descending.
    pub discords: Vec<Discord>,
}

/// Whole-run outcome.
#[derive(Clone, Debug)]
pub struct MerlinResult {
    pub lengths: Vec<LengthResult>,
    pub metrics: MerlinMetrics,
}

impl MerlinResult {
    /// Flatten all per-length discords.
    pub fn all_discords(&self) -> impl Iterator<Item = &Discord> {
        self.lengths.iter().flat_map(|l| l.discords.iter())
    }

    /// The single most anomalous subsequence across lengths, scored by the
    /// length-normalized distance (nnDist / (2*sqrt(m)), cf. Eq. 11).
    /// NaN scores rank last ([`cmp_score_desc`]) instead of panicking.
    pub fn top_normalized(&self) -> Option<&Discord> {
        self.all_discords().max_by(|a, b| {
            let na = a.nn_dist / (2.0 * (a.m as f64).sqrt());
            let nb = b.nn_dist / (2.0 * (b.m as f64).sqrt());
            // max_by wants ascending order; the descending comparator
            // with swapped arguments provides it, NaN pinned smallest.
            cmp_score_desc(nb, na)
        })
    }
}

/// Outcome of one [`MerlinSweep::step`] call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepStatus {
    /// More lengths remain; call `step` again.
    Pending,
    /// Every length in `[min_l, max_l]` has been processed.
    Done,
}

impl SweepStatus {
    pub fn is_pending(self) -> bool {
        matches!(self, SweepStatus::Pending)
    }
}

/// Per-length discovery hook: given the current view and threshold,
/// leave the exact range-discord set in `ws.discords()`.
///
/// The default ([`Pd3Executor`]) is classic single-node PD3; the
/// distributed coordinator substitutes its partition/exchange/global
/// refinement procedure (`coordinator/distributed.rs`) so multi-node
/// sweeps share the threshold schedule, retry policy, and metrics of
/// every other path instead of reimplementing them.
pub trait SweepExecutor {
    fn discover(
        &mut self,
        engine: &dyn Engine,
        view: &SeriesView<'_>,
        r: f64,
        pd3: &Pd3Config,
        drag: &mut DragMetrics,
        ws: &mut MerlinWorkspace,
    ) -> Result<()>;
}

/// The default executor: one PD3 pass over the whole series.
pub struct Pd3Executor;

impl SweepExecutor for Pd3Executor {
    fn discover(
        &mut self,
        engine: &dyn Engine,
        view: &SeriesView<'_>,
        r: f64,
        pd3: &Pd3Config,
        drag: &mut DragMetrics,
        ws: &mut MerlinWorkspace,
    ) -> Result<()> {
        pd3_into(engine, view, r, pd3, drag, ws)
    }
}

/// Fixed-capacity ring of the last five per-length nnDist minima (the
/// Alg. 1 threshold schedule's memory).  Plain array so sweep steps
/// never touch the heap for it.
#[derive(Clone, Copy, Debug, Default)]
struct Last5 {
    buf: [f64; 5],
    len: usize,
}

impl Last5 {
    fn clear(&mut self) {
        self.len = 0;
    }

    fn push(&mut self, x: f64) {
        if self.len == 5 {
            self.buf.copy_within(1..5, 0);
            self.buf[4] = x;
        } else {
            self.buf[self.len] = x;
            self.len += 1;
        }
    }

    fn last(&self) -> Option<f64> {
        self.len.checked_sub(1).map(|i| self.buf[i])
    }

    fn mean_std(&self) -> (f64, f64) {
        let xs = &self.buf[..self.len];
        let n = xs.len() as f64;
        let mu = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / n;
        (mu, var.max(0.0).sqrt())
    }
}

/// Resumable MERLIN sweep (module docs): the per-length loop of Alg. 1
/// decomposed into an explicit state machine.
///
/// The sweep owns everything that must survive between lengths — the
/// rolling stats, the last-five nnDist ring, the per-length results,
/// and recycled selection scratch — while the engine and the PD3
/// workspace arrive *per step* (the job service leases them from a
/// shared pool keyed by job id, so interleaved tenants reuse warm
/// arenas).  A warmed sweep's `step` performs zero heap allocations,
/// and [`rebind`](MerlinSweep::rebind) recycles a finished sweep for
/// the next run over a same-shape series (the streaming monitor's
/// refresh path) — both proved in `rust/tests/alloc_steady_state.rs`.
pub struct MerlinSweep {
    cfg: MerlinConfig,
    /// Expected series length (re-checked every step: the series is
    /// caller-owned and must not change under a parked sweep).
    n: usize,
    /// Next length to process (`> cfg.max_l` once done).
    next_m: usize,
    /// Initial-threshold override for the first length (the streaming
    /// monitor seeds it with 0.99x the previous discord distance).
    r_start: Option<f64>,
    stats: RollingStats,
    stats_ready: bool,
    last5: Last5,
    lengths: Vec<LengthResult>,
    metrics: MerlinMetrics,
    /// Selection scratch + spare per-length discord vectors, recycled
    /// across lengths and rebinds.
    scored: Vec<Scored>,
    picked: Vec<Scored>,
    spare: Vec<Vec<Discord>>,
}

impl MerlinSweep {
    /// Create a sweep over a series of length `n`.  Engine-independent
    /// validation happens here; engine limits (`max_m`) are checked by
    /// the first `step`, which is where an engine first appears.
    pub fn new(cfg: MerlinConfig, n: usize) -> Result<Self> {
        validate(&cfg, n)?;
        let min_l = cfg.min_l;
        Ok(Self {
            cfg,
            n,
            next_m: min_l,
            r_start: None,
            stats: RollingStats { m: min_l, mu: Vec::new(), sig: Vec::new() },
            stats_ready: false,
            last5: Last5::default(),
            lengths: Vec::new(),
            metrics: MerlinMetrics::default(),
            scored: Vec::new(),
            picked: Vec::new(),
            spare: Vec::new(),
        })
    }

    pub fn config(&self) -> &MerlinConfig {
        &self.cfg
    }

    /// True once every length has been processed.
    pub fn done(&self) -> bool {
        self.next_m > self.cfg.max_l
    }

    /// (lengths completed, lengths total).
    pub fn progress(&self) -> (usize, usize) {
        (self.lengths.len(), self.cfg.max_l - self.cfg.min_l + 1)
    }

    /// Per-length results so far.
    pub fn lengths(&self) -> &[LengthResult] {
        &self.lengths
    }

    /// Metrics accumulated so far.
    pub fn metrics(&self) -> &MerlinMetrics {
        &self.metrics
    }

    /// Reset for a fresh run over a series of length `n`, recycling
    /// every internal buffer (stats storage, result vectors, scratch).
    pub fn rebind(&mut self, n: usize) -> Result<()> {
        self.rebind_with(n, None)
    }

    /// [`rebind`](Self::rebind) with an initial-threshold override for
    /// the first length (clamped to the theoretical max `2*sqrt(m)`).
    pub fn rebind_with(&mut self, n: usize, r_start: Option<f64>) -> Result<()> {
        validate(&self.cfg, n)?;
        self.n = n;
        self.next_m = self.cfg.min_l;
        self.r_start = r_start;
        self.stats_ready = false;
        self.last5.clear();
        for lr in self.lengths.drain(..) {
            let mut v = lr.discords;
            v.clear();
            self.spare.push(v);
        }
        self.metrics = MerlinMetrics::default();
        Ok(())
    }

    /// Bind the engine's per-series state to `t` and run its bulk
    /// prefetch hook for the next length, *before* the step's retry
    /// loop.  Plain sweeps don't need this (PD3 prepares lazily and
    /// MERLIN only prefetches between lengths); the streaming monitor
    /// does, because its ring buffer can recycle a slice identity while
    /// the content slides — the unconditional content-fingerprint bind
    /// must precede the identity-guarded prefetch fast path.
    pub fn bind_series(&mut self, engine: &dyn Engine, t: &[f64]) -> Result<()> {
        if t.len() != self.n {
            bail!("series length changed under the sweep ({} != {})", t.len(), self.n);
        }
        self.ensure_stats(engine, t)?;
        let view = SeriesView { t, stats: &self.stats };
        engine.prepare_series(&view);
        engine.prefetch_length(t, self.next_m);
        Ok(())
    }

    /// Advance the sweep by exactly one length (threshold selection +
    /// adaptive-r PD3 retries) through the default [`Pd3Executor`].
    ///
    /// The engine and workspace are borrowed for this step only: the
    /// caller may hand a different (leased) pair to every step, as the
    /// job service does.  Engine perf counters and workspace reuse
    /// counters are snapshotted around the step, so shared resources
    /// attribute their traffic to this sweep's metrics correctly.
    pub fn step(
        &mut self,
        engine: &dyn Engine,
        t: &[f64],
        ws: &mut MerlinWorkspace,
    ) -> Result<SweepStatus> {
        self.step_with(engine, t, ws, &mut Pd3Executor)
    }

    /// [`step`](Self::step) with a custom per-length discovery
    /// procedure (see [`SweepExecutor`]).
    pub fn step_with(
        &mut self,
        engine: &dyn Engine,
        t: &[f64],
        ws: &mut MerlinWorkspace,
        exec: &mut dyn SweepExecutor,
    ) -> Result<SweepStatus> {
        if self.done() {
            return Ok(SweepStatus::Done);
        }
        if self.cfg.max_l > engine.max_m() {
            bail!("max_l {} exceeds engine max_m {}", self.cfg.max_l, engine.max_m());
        }
        if t.len() != self.n {
            bail!("series length changed under the sweep ({} != {})", t.len(), self.n);
        }

        let t_start = Instant::now();
        let seed0 = engine.perf_counters();
        let ws0 = ws.counters();
        self.ensure_stats(engine, t)?;
        let m = self.next_m;
        debug_assert_eq!(self.stats.m, m);
        let view = SeriesView { t, stats: &self.stats };
        let step = m - self.cfg.min_l;
        let max_r = 2.0 * (m as f64).sqrt();
        let r_floor = self.cfg.r_floor_frac * max_r;

        // Initial threshold per Alg. 1.
        let mut r = if step == 0 {
            self.r_start.unwrap_or(max_r).min(max_r)
        } else if step <= 4 {
            // Invariant: `last5` gains exactly one entry per completed
            // length — the no-discord outcome pushes a carry value (see
            // below) — so at step >= 1 it is provably non-empty.  The
            // all-flat-series unit test exercises the carry branch.
            0.99 * self.last5.last().expect("last5 carries an entry per completed length")
        } else {
            let (mu, sigma) = self.last5.mean_std();
            (mu - 2.0 * sigma).clamp(r_floor, max_r)
        };

        let mut retries = 0usize;
        let result = loop {
            self.metrics.drag_calls += 1;
            exec.discover(engine, &view, r, &self.cfg.pd3, &mut self.metrics.drag, ws)?;
            self.scored.clear();
            self.scored
                .extend(ws.discords().iter().map(|d| Scored { idx: d.idx, nn_dist: d.nn_dist }));
            top_k_non_overlapping_into(&mut self.scored, m, self.cfg.top_k, &mut self.picked);
            let enough = if self.cfg.top_k == 0 {
                !self.picked.is_empty()
            } else {
                self.picked.len() >= self.cfg.top_k
            };
            if enough || r <= r_floor || retries >= self.cfg.max_retries {
                let mut discords = self.spare.pop().unwrap_or_default();
                discords.clear();
                discords.extend(
                    self.picked.iter().map(|s| Discord { idx: s.idx, m, nn_dist: s.nn_dist }),
                );
                break LengthResult { m, r_used: r, retries, discords };
            }
            // Lower r per Alg. 1 and retry.
            retries += 1;
            self.metrics.retries += 1;
            r = if step == 0 {
                0.5 * r
            } else if step <= 4 {
                0.99 * r
            } else {
                let (mu, sigma) = self.last5.mean_std();
                let dec = if sigma > 1e-12 * (1.0 + mu) { sigma } else { 0.05 * mu.max(1e-9) };
                (r - dec).max(r_floor)
            };
        };

        // Track min nnDist among reported discords for the r schedule.
        let min_nn =
            result.discords.iter().map(|d| d.nn_dist).fold(f64::INFINITY, f64::min);
        if min_nn.is_finite() {
            self.last5.push(min_nn);
        } else {
            // Total failure at this length (pathological series):
            // carry the previous value so the schedule can continue.
            let carry = self.last5.last().unwrap_or(0.5 * max_r);
            self.last5.push(carry);
        }
        self.metrics.discords += result.discords.len() as u64;
        self.lengths.push(result);

        // Advance stats m -> m+1 (Eqs. 7/8) unless this was the last.
        let status = if m < self.cfg.max_l {
            let st = Instant::now();
            self.advance_stats(engine, t)?;
            self.metrics.stats_time += st.elapsed();
            // Bulk seed prefetch: advance every cached QT seed row to
            // m+1 in one engine-side sweep while no tiles are in
            // flight, so the next length's tiles open on verbatim
            // cache hits instead of serialized per-row advances under
            // the shard locks (ROADMAP "batch-level seed prefetch").
            // Under sticky leases the same engine usually serves this
            // sweep's next step, so the hint lands where it pays off.
            let pf = Instant::now();
            engine.prefetch_length(t, m + 1);
            self.metrics.prefetch_time += pf.elapsed();
            SweepStatus::Pending
        } else {
            SweepStatus::Done
        };
        self.next_m = m + 1;
        self.metrics.seed.accumulate(engine.perf_counters().since(seed0));
        self.metrics.workspace.accumulate(ws.counters().since(ws0));
        self.metrics.total_time += t_start.elapsed();
        Ok(status)
    }

    /// Consume the sweep into its result.
    pub fn finish(self) -> MerlinResult {
        MerlinResult { lengths: self.lengths, metrics: self.metrics }
    }

    /// Serialize the sweep's durable state to a versioned, checksummed
    /// byte buffer (see `util::binio` for the envelope convention).
    ///
    /// Everything that decides future control flow or appears in the
    /// final [`MerlinResult`] is captured exactly: the config, the
    /// progress cursor, the rolling stats (raw `f64` bits), the
    /// `Last5` threshold ring (which encodes mid-sweep adaptive-r
    /// state), the per-length results, and the accumulated metrics.
    /// Selection scratch (`scored`/`picked`/`spare`) is per-step
    /// recycling only and is deliberately excluded — a restored sweep
    /// re-warms it on the first step.
    ///
    /// Restoring onto a *cold* engine replays the same indices and
    /// thresholds but can differ from an uninterrupted run in the
    /// low-order distance bits, because a fresh QT seed pass rounds
    /// differently from the incremental cross-length advance (see
    /// `engines::scratch`).  For bit-identical resume, also persist
    /// [`Engine::export_seed_rows`](crate::engines::Engine::export_seed_rows)
    /// and re-import them before the first step — the job service's
    /// checkpoints (`coordinator::checkpoint`) do exactly that.
    pub fn snapshot(&self) -> Vec<u8> {
        use crate::util::binio::{seal, ByteWriter};
        let mut w = ByteWriter::new();
        // Config.
        w.put_usize(self.cfg.min_l);
        w.put_usize(self.cfg.max_l);
        w.put_usize(self.cfg.top_k);
        w.put_bool(self.cfg.pd3.deferred_neighbor_kill);
        w.put_bool(self.cfg.pd3.early_stop);
        w.put_u8(match self.cfg.stats_backend {
            StatsBackend::Native => 0,
            StatsBackend::Aot => 1,
            StatsBackend::NaivePerLength => 2,
        });
        w.put_usize(self.cfg.max_retries);
        w.put_f64(self.cfg.r_floor_frac);
        // Cursor.
        w.put_usize(self.n);
        w.put_usize(self.next_m);
        w.put_opt_f64(self.r_start);
        // Rolling stats.
        w.put_bool(self.stats_ready);
        w.put_usize(self.stats.m);
        w.put_f64s(&self.stats.mu);
        w.put_f64s(&self.stats.sig);
        // Threshold-schedule ring.
        w.put_u8(self.last5.len as u8);
        for &x in &self.last5.buf[..self.last5.len] {
            w.put_f64(x);
        }
        // Per-length results.
        w.put_usize(self.lengths.len());
        for lr in &self.lengths {
            w.put_usize(lr.m);
            w.put_f64(lr.r_used);
            w.put_usize(lr.retries);
            w.put_usize(lr.discords.len());
            for d in &lr.discords {
                w.put_usize(d.idx);
                w.put_usize(d.m);
                w.put_f64(d.nn_dist);
            }
        }
        // Metrics (Durations as nanoseconds; saturating at u64::MAX,
        // which is ~584 years of wall time).
        let dur = |d: std::time::Duration| u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
        let dm = &self.metrics.drag;
        for v in [dm.tiles_computed, dm.tiles_skipped, dm.kills_select, dm.kills_refine, dm.survivors] {
            w.put_u64(v);
        }
        w.put_u64(dur(dm.select_time));
        w.put_u64(dur(dm.refine_time));
        w.put_u64(self.metrics.drag_calls);
        w.put_u64(self.metrics.retries);
        w.put_u64(self.metrics.discords);
        let s = &self.metrics.seed;
        for v in [
            s.seed_hits,
            s.seed_advances,
            s.seed_misses,
            s.seed_prefetched,
            s.prefetch_batches,
            s.batches,
            s.batch_tiles,
            s.clamp_saturations,
            s.flat_cells,
        ] {
            w.put_u64(v);
        }
        w.put_u64(dur(self.metrics.prefetch_time));
        w.put_u64(self.metrics.workspace.resets);
        w.put_u64(self.metrics.workspace.grows);
        w.put_u64(dur(self.metrics.stats_time));
        w.put_u64(dur(self.metrics.total_time));
        seal(SNAPSHOT_MAGIC, SNAPSHOT_VERSION, w.bytes())
    }

    /// Rebuild a sweep from [`snapshot`](Self::snapshot) bytes.
    ///
    /// Rejects (with `Err`, never a panic) truncation, checksum or
    /// version mismatches, and payloads whose decoded state violates
    /// the sweep invariants — a tampered checkpoint must not produce a
    /// sweep that panics later.
    pub fn restore(bytes: &[u8]) -> Result<Self> {
        use crate::util::binio::{unseal, ByteReader};
        let payload = unseal(SNAPSHOT_MAGIC, SNAPSHOT_VERSION, bytes)?;
        let mut r = ByteReader::new(payload);
        let cfg = MerlinConfig {
            min_l: r.get_usize()?,
            max_l: r.get_usize()?,
            top_k: r.get_usize()?,
            pd3: Pd3Config {
                deferred_neighbor_kill: r.get_bool()?,
                early_stop: r.get_bool()?,
            },
            stats_backend: match r.get_u8()? {
                0 => StatsBackend::Native,
                1 => StatsBackend::Aot,
                2 => StatsBackend::NaivePerLength,
                other => bail!("unknown stats backend tag {other}"),
            },
            max_retries: r.get_usize()?,
            r_floor_frac: r.get_f64()?,
        };
        let n = r.get_usize()?;
        let next_m = r.get_usize()?;
        let r_start = r.get_opt_f64()?;
        let stats_ready = r.get_bool()?;
        let stats =
            RollingStats { m: r.get_usize()?, mu: r.get_f64s()?, sig: r.get_f64s()? };
        let l5_len = r.get_u8()? as usize;
        if l5_len > 5 {
            bail!("last5 ring length {l5_len} out of range");
        }
        let mut last5 = Last5::default();
        for _ in 0..l5_len {
            last5.push(r.get_f64()?);
        }
        let n_lengths = r.get_usize()?;
        let mut lengths = Vec::with_capacity(n_lengths.min(payload.len() / 24 + 1));
        for _ in 0..n_lengths {
            let m = r.get_usize()?;
            let r_used = r.get_f64()?;
            let retries = r.get_usize()?;
            let n_disc = r.get_usize()?;
            let mut discords = Vec::with_capacity(n_disc.min(payload.len() / 24 + 1));
            for _ in 0..n_disc {
                discords.push(Discord {
                    idx: r.get_usize()?,
                    m: r.get_usize()?,
                    nn_dist: r.get_f64()?,
                });
            }
            lengths.push(LengthResult { m, r_used, retries, discords });
        }
        let dur = |nanos: u64| std::time::Duration::from_nanos(nanos);
        let mut metrics = MerlinMetrics::default();
        metrics.drag.tiles_computed = r.get_u64()?;
        metrics.drag.tiles_skipped = r.get_u64()?;
        metrics.drag.kills_select = r.get_u64()?;
        metrics.drag.kills_refine = r.get_u64()?;
        metrics.drag.survivors = r.get_u64()?;
        metrics.drag.select_time = dur(r.get_u64()?);
        metrics.drag.refine_time = dur(r.get_u64()?);
        metrics.drag_calls = r.get_u64()?;
        metrics.retries = r.get_u64()?;
        metrics.discords = r.get_u64()?;
        metrics.seed.seed_hits = r.get_u64()?;
        metrics.seed.seed_advances = r.get_u64()?;
        metrics.seed.seed_misses = r.get_u64()?;
        metrics.seed.seed_prefetched = r.get_u64()?;
        metrics.seed.prefetch_batches = r.get_u64()?;
        metrics.seed.batches = r.get_u64()?;
        metrics.seed.batch_tiles = r.get_u64()?;
        metrics.seed.clamp_saturations = r.get_u64()?;
        metrics.seed.flat_cells = r.get_u64()?;
        metrics.prefetch_time = dur(r.get_u64()?);
        metrics.workspace.resets = r.get_u64()?;
        metrics.workspace.grows = r.get_u64()?;
        metrics.stats_time = dur(r.get_u64()?);
        metrics.total_time = dur(r.get_u64()?);
        r.finish()?;

        // Invariant checks: a decoded state that violates them would
        // trip debug asserts (or worse, index out of bounds) later.
        validate(&cfg, n)?;
        if !(cfg.min_l <= next_m && next_m <= cfg.max_l + 1) {
            bail!("progress cursor {next_m} outside [{}, {}]", cfg.min_l, cfg.max_l + 1);
        }
        if lengths.len() != next_m - cfg.min_l {
            bail!(
                "length results ({}) inconsistent with cursor (expected {})",
                lengths.len(),
                next_m - cfg.min_l
            );
        }
        if last5.len != lengths.len().min(5) {
            bail!("last5 ring length {} inconsistent with {} completed lengths", last5.len, lengths.len());
        }
        if stats_ready {
            let want_m = next_m.min(cfg.max_l);
            let want_len = n - want_m + 1;
            if stats.m != want_m || stats.mu.len() != want_len || stats.sig.len() != want_len {
                bail!(
                    "rolling stats shape (m={}, {} windows) inconsistent with cursor m={want_m} over n={n}",
                    stats.m,
                    stats.mu.len()
                );
            }
        }
        for lr in &lengths {
            for d in &lr.discords {
                if d.idx + lr.m > n {
                    bail!("discord [{}..+{}] outside series of length {n}", d.idx, lr.m);
                }
            }
        }
        Ok(Self {
            cfg,
            n,
            next_m,
            r_start,
            stats,
            stats_ready,
            last5,
            lengths,
            metrics,
            scored: Vec::new(),
            picked: Vec::new(),
            spare: Vec::new(),
        })
    }

    fn ensure_stats(&mut self, engine: &dyn Engine, t: &[f64]) -> Result<()> {
        if self.stats_ready {
            return Ok(());
        }
        let st = Instant::now();
        match self.cfg.stats_backend {
            StatsBackend::Native | StatsBackend::NaivePerLength => {
                self.stats.recompute(t, self.cfg.min_l);
            }
            StatsBackend::Aot => {
                let s = engine.aot_stats_init(t, self.cfg.min_l)?;
                self.stats = s;
            }
        }
        self.metrics.stats_time += st.elapsed();
        self.stats_ready = true;
        Ok(())
    }

    fn advance_stats(&mut self, engine: &dyn Engine, t: &[f64]) -> Result<()> {
        match self.cfg.stats_backend {
            StatsBackend::Native => self.stats.advance(t),
            StatsBackend::NaivePerLength => self.stats.recompute(t, self.stats.m + 1),
            StatsBackend::Aot => {
                let s = engine.aot_stats_update(t, &self.stats)?;
                self.stats = s;
            }
        }
        Ok(())
    }
}

fn validate(cfg: &MerlinConfig, n: usize) -> Result<()> {
    if !(3 <= cfg.min_l && cfg.min_l <= cfg.max_l) {
        bail!("bad length range [{}, {}]", cfg.min_l, cfg.max_l);
    }
    // Need at least one non-self match at max_l.
    if n < 2 * cfg.max_l {
        bail!("series too short (n={n}) for max_l={} (need n >= 2*max_l)", cfg.max_l);
    }
    Ok(())
}

/// The MERLIN driver bound to an engine: a thin run-to-completion loop
/// over [`MerlinSweep::step`] with a private workspace.
pub struct Merlin<'e> {
    engine: &'e dyn Engine,
    cfg: MerlinConfig,
}

impl<'e> Merlin<'e> {
    pub fn new(engine: &'e dyn Engine, cfg: MerlinConfig) -> Self {
        Self { engine, cfg }
    }

    pub fn config(&self) -> &MerlinConfig {
        &self.cfg
    }

    /// Run arbitrary-length discovery over `t`.
    pub fn run(&self, t: &TimeSeries) -> Result<MerlinResult> {
        let mut sweep = MerlinSweep::new(self.cfg.clone(), t.len())?;
        // Hoisted PD3 arena: every length and every adaptive-r retry of
        // this run recycles one set of bitmaps / minima / tile buffers
        // instead of reallocating them per pd3 call (ROADMAP:
        // "pd3-level workspace reuse").
        let mut ws = MerlinWorkspace::new();
        while sweep.step(self.engine, &t.values, &mut ws)?.is_pending() {}
        Ok(sweep.finish())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::native::NativeEngine;
    use crate::util::rng::Rng;

    fn random_walk_series(n: usize, seed: u64) -> TimeSeries {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        let v = (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect();
        TimeSeries::new("rw", v)
    }

    #[test]
    fn finds_discords_for_every_length() {
        let t = random_walk_series(600, 21);
        let engine = NativeEngine::with_segn(64);
        let cfg = MerlinConfig { min_l: 16, max_l: 32, top_k: 1, ..Default::default() };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        assert_eq!(res.lengths.len(), 17);
        for lr in &res.lengths {
            assert_eq!(lr.discords.len(), 1, "m={}", lr.m);
            assert!(lr.discords[0].nn_dist > 0.0);
            assert!(lr.discords[0].nn_dist >= lr.r_used - 1e-9);
        }
    }

    #[test]
    fn top1_matches_brute_force_per_length() {
        use crate::core::distance::ed2norm;
        let t = random_walk_series(260, 22);
        let engine = NativeEngine::with_segn(32);
        let cfg = MerlinConfig { min_l: 10, max_l: 20, top_k: 1, ..Default::default() };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        for lr in &res.lengths {
            let m = lr.m;
            let nwin = t.len() - m + 1;
            // Brute-force top-1 discord.
            let mut best = (0usize, f64::NEG_INFINITY);
            for i in 0..nwin {
                let mut nn = f64::INFINITY;
                for j in 0..nwin {
                    if i.abs_diff(j) >= m {
                        nn = nn.min(ed2norm(&t.values[i..i + m], &t.values[j..j + m]));
                    }
                }
                if nn.is_finite() && nn > best.1 {
                    best = (i, nn);
                }
            }
            let got = &lr.discords[0];
            assert!(
                (got.nn_dist - best.1.sqrt()).abs() < 1e-6 * (1.0 + got.nn_dist),
                "m={m}: got dist {} want {}",
                got.nn_dist,
                best.1.sqrt()
            );
            // Index can differ only between exact ties.
            if got.idx != best.0 {
                let mut nn = f64::INFINITY;
                for j in 0..nwin {
                    if got.idx.abs_diff(j) >= m {
                        nn = nn.min(ed2norm(
                            &t.values[got.idx..got.idx + m],
                            &t.values[j..j + m],
                        ));
                    }
                }
                assert!((nn - best.1).abs() < 1e-9 * (1.0 + best.1));
            }
        }
    }

    #[test]
    fn stats_backends_agree() {
        let t = random_walk_series(400, 23);
        let engine = NativeEngine::with_segn(64);
        let base = MerlinConfig { min_l: 12, max_l: 24, top_k: 1, ..Default::default() };
        let a = Merlin::new(&engine, base.clone()).run(&t).unwrap();
        let b = Merlin::new(
            &engine,
            MerlinConfig { stats_backend: StatsBackend::NaivePerLength, ..base },
        )
        .run(&t)
        .unwrap();
        for (x, y) in a.lengths.iter().zip(&b.lengths) {
            assert_eq!(x.discords[0].idx, y.discords[0].idx);
            assert!((x.discords[0].nn_dist - y.discords[0].nn_dist).abs() < 1e-6);
        }
    }

    #[test]
    fn top_k_returns_non_overlapping() {
        let t = random_walk_series(800, 24);
        let engine = NativeEngine::with_segn(64);
        let cfg = MerlinConfig { min_l: 16, max_l: 16, top_k: 3, ..Default::default() };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        let d = &res.lengths[0].discords;
        assert!(d.len() >= 2, "expected multiple discords, got {}", d.len());
        for a in 0..d.len() {
            for b in a + 1..d.len() {
                assert!(d[a].idx.abs_diff(d[b].idx) >= 16);
            }
            if a > 0 {
                assert!(d[a - 1].nn_dist >= d[a].nn_dist);
            }
        }
    }

    #[test]
    fn seed_cache_is_exercised_across_lengths() {
        let t = random_walk_series(600, 26);
        let engine = NativeEngine::with_segn(64);
        let cfg = MerlinConfig { min_l: 16, max_l: 24, top_k: 1, ..Default::default() };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        let seed = res.metrics.seed;
        assert!(seed.seed_total() > 0, "native engine must report seed traffic");
        // The length loop runs one bulk prefetch sweep per advanced
        // length; round 0 (self tiles) is computed at every length, so
        // every sweep has rows to advance and the next length consumes
        // them as verbatim hits — no tile falls back to a lazy per-row
        // advance.
        assert_eq!(seed.prefetch_batches, (24 - 16) as u64, "{seed:?}");
        assert!(seed.seed_prefetched >= seed.prefetch_batches, "{seed:?}");
        assert!(seed.seed_hits > 0, "prefetched rows must resurface as hits: {seed:?}");
        assert_eq!(seed.seed_advances, 0, "prefetch subsumes lazy advances: {seed:?}");
    }

    #[test]
    fn rerun_on_warm_prefetched_engine_is_deterministic() {
        // The sweep is an optimization only: re-running MERLIN on an
        // engine whose cache is full of max_l rows (a restarted sweep:
        // misses, then prefetch again) must reproduce the first run
        // exactly (the prefetch recurrence matches the lazy advance
        // bit-for-bit, and both are oracle-checked in the engine tests —
        // here we pin the end-to-end wiring).
        let t = random_walk_series(500, 28);
        let cfg = MerlinConfig { min_l: 12, max_l: 22, top_k: 1, ..Default::default() };
        let warm_engine = NativeEngine::with_segn(64);
        let warm = Merlin::new(&warm_engine, cfg.clone()).run(&t).unwrap();
        // A second run on the *same* engine starts from a cache full of
        // max_l rows (restarted sweep: misses, then prefetch again).
        let rerun = Merlin::new(&warm_engine, cfg).run(&t).unwrap();
        for (a, b) in warm.lengths.iter().zip(&rerun.lengths) {
            assert_eq!(a.discords[0].idx, b.discords[0].idx, "m={}", a.m);
            assert!((a.discords[0].nn_dist - b.discords[0].nn_dist).abs() < 1e-12);
        }
    }

    #[test]
    fn rejects_bad_ranges() {
        let t = random_walk_series(100, 25);
        let engine = NativeEngine::with_segn(32);
        assert!(Merlin::new(
            &engine,
            MerlinConfig { min_l: 2, max_l: 10, ..Default::default() }
        )
        .run(&t)
        .is_err());
        assert!(Merlin::new(
            &engine,
            MerlinConfig { min_l: 60, max_l: 60, ..Default::default() }
        )
        .run(&t)
        .is_err());
    }

    #[test]
    fn constant_series_is_handled() {
        // All-flat series: every window is a twin -> nnDist 0 everywhere;
        // MERLIN must terminate (retry caps) and report nothing/zeros.
        let t = TimeSeries::new("flat", vec![5.0; 200]);
        let engine = NativeEngine::with_segn(32);
        let cfg = MerlinConfig {
            min_l: 8,
            max_l: 10,
            top_k: 1,
            max_retries: 5,
            ..Default::default()
        };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        assert_eq!(res.lengths.len(), 3);
        for lr in &res.lengths {
            for d in &lr.discords {
                assert!(d.nn_dist <= 1e-6);
            }
        }
    }

    #[test]
    fn flat_series_carry_seeds_early_length_thresholds() {
        // All-flat series: no length ever reports a discord, so the r
        // schedule for steps 1..=4 must be seeded by the carry value the
        // no-discord path pushes into `last5` — the invariant behind the
        // `expect` in the step <= 4 branch.  A missing carry would panic
        // right at m = min_l + 1.
        let t = TimeSeries::new("flat", vec![5.0; 160]);
        let engine = NativeEngine::with_segn(16);
        let cfg = MerlinConfig {
            min_l: 8,
            max_l: 13, // covers steps 0..=5: both carry-seeded regimes
            top_k: 1,
            max_retries: 3,
            ..Default::default()
        };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        assert_eq!(res.lengths.len(), 6);
        for lr in &res.lengths {
            assert!(lr.discords.is_empty(), "m={}: flat series has only twins", lr.m);
            assert!(lr.r_used > 0.0 && lr.r_used.is_finite());
        }
    }

    /// Acceptance pin (exact): a manually stepped sweep on a dedicated
    /// engine replays the single-call `Merlin::run` op order verbatim,
    /// so thresholds, retry counts, and discords are bit-identical.
    #[test]
    fn stepped_sweep_is_bit_identical_to_run() {
        let t = random_walk_series(520, 31);
        let cfg = MerlinConfig { min_l: 12, max_l: 24, top_k: 2, ..Default::default() };
        let want = Merlin::new(&NativeEngine::with_segn(64), cfg.clone()).run(&t).unwrap();

        let engine = NativeEngine::with_segn(64);
        let mut ws = MerlinWorkspace::new();
        let mut sweep = MerlinSweep::new(cfg, t.len()).unwrap();
        let mut steps = 0;
        while sweep.step(&engine, &t.values, &mut ws).unwrap().is_pending() {
            steps += 1;
            assert!(steps <= 13, "one step per length");
        }
        let got = sweep.finish();

        assert_eq!(want.lengths.len(), got.lengths.len());
        for (w, g) in want.lengths.iter().zip(&got.lengths) {
            assert_eq!(w.m, g.m);
            assert_eq!(w.retries, g.retries, "m={}", w.m);
            assert_eq!(w.r_used, g.r_used, "m={}", w.m);
            assert_eq!(w.discords, g.discords, "m={}: stepped sweep diverged", w.m);
        }
        assert_eq!(want.metrics.drag_calls, got.metrics.drag_calls);
        assert_eq!(want.metrics.discords, got.metrics.discords);
    }

    /// Acceptance pin (shared state): two sweeps interleaved on *one*
    /// engine + *one* workspace — the scheduler's worst case, where
    /// every step evicts the other tenant's seed-cache binding — still
    /// reproduce their dedicated-engine runs.  Re-seeded rows are only
    /// guaranteed numerically (not bit-) equal to incrementally
    /// advanced ones (the fresh pass uses the four-lane `dot`), hence
    /// the tolerance on distances; indices must match exactly.
    #[test]
    fn interleaved_sweeps_match_dedicated_runs() {
        let t_a = random_walk_series(520, 31);
        let t_b = random_walk_series(520, 32);
        let cfg = MerlinConfig { min_l: 12, max_l: 24, top_k: 2, ..Default::default() };

        let want_a = Merlin::new(&NativeEngine::with_segn(64), cfg.clone()).run(&t_a).unwrap();
        let want_b = Merlin::new(&NativeEngine::with_segn(64), cfg.clone()).run(&t_b).unwrap();

        let engine = NativeEngine::with_segn(64);
        let mut ws = MerlinWorkspace::new();
        let mut sweep_a = MerlinSweep::new(cfg.clone(), t_a.len()).unwrap();
        let mut sweep_b = MerlinSweep::new(cfg, t_b.len()).unwrap();
        while !(sweep_a.done() && sweep_b.done()) {
            if !sweep_a.done() {
                sweep_a.step(&engine, &t_a.values, &mut ws).unwrap();
            }
            if !sweep_b.done() {
                sweep_b.step(&engine, &t_b.values, &mut ws).unwrap();
            }
        }
        let got_a = sweep_a.finish();
        let got_b = sweep_b.finish();

        for (want, got) in [(&want_a, &got_a), (&want_b, &got_b)] {
            assert_eq!(want.lengths.len(), got.lengths.len());
            for (w, g) in want.lengths.iter().zip(&got.lengths) {
                assert_eq!(w.m, g.m);
                assert_eq!(w.discords.len(), g.discords.len(), "m={}", w.m);
                for (wd, gd) in w.discords.iter().zip(&g.discords) {
                    assert_eq!(wd.idx, gd.idx, "m={}", w.m);
                    assert!(
                        (wd.nn_dist - gd.nn_dist).abs() < 1e-9 * (1.0 + wd.nn_dist.abs()),
                        "m={}: {} vs {}",
                        w.m,
                        wd.nn_dist,
                        gd.nn_dist
                    );
                }
            }
        }
    }

    #[test]
    fn sweep_reports_progress_and_is_idempotent_after_done() {
        let t = random_walk_series(300, 33);
        let engine = NativeEngine::with_segn(32);
        let cfg = MerlinConfig { min_l: 10, max_l: 12, top_k: 1, ..Default::default() };
        let mut ws = MerlinWorkspace::new();
        let mut sweep = MerlinSweep::new(cfg, t.len()).unwrap();
        assert_eq!(sweep.progress(), (0, 3));
        assert_eq!(sweep.step(&engine, &t.values, &mut ws).unwrap(), SweepStatus::Pending);
        assert_eq!(sweep.progress(), (1, 3));
        assert_eq!(sweep.step(&engine, &t.values, &mut ws).unwrap(), SweepStatus::Pending);
        assert_eq!(sweep.step(&engine, &t.values, &mut ws).unwrap(), SweepStatus::Done);
        assert!(sweep.done());
        assert_eq!(sweep.progress(), (3, 3));
        // Stepping a finished sweep is a no-op Done, not a panic.
        assert_eq!(sweep.step(&engine, &t.values, &mut ws).unwrap(), SweepStatus::Done);
        assert_eq!(sweep.lengths().len(), 3);
    }

    #[test]
    fn sweep_rejects_series_length_change_between_steps() {
        let t = random_walk_series(300, 34);
        let engine = NativeEngine::with_segn(32);
        let cfg = MerlinConfig { min_l: 10, max_l: 14, top_k: 1, ..Default::default() };
        let mut ws = MerlinWorkspace::new();
        let mut sweep = MerlinSweep::new(cfg, t.len()).unwrap();
        sweep.step(&engine, &t.values, &mut ws).unwrap();
        let err = sweep.step(&engine, &t.values[..299], &mut ws).unwrap_err();
        assert!(err.to_string().contains("series length changed"), "{err}");
    }

    #[test]
    fn rebound_sweep_reproduces_and_recycles() {
        let t = random_walk_series(400, 35);
        let engine = NativeEngine::with_segn(64);
        let cfg = MerlinConfig { min_l: 12, max_l: 16, top_k: 1, ..Default::default() };
        let mut ws = MerlinWorkspace::new();
        let mut sweep = MerlinSweep::new(cfg, t.len()).unwrap();
        while sweep.step(&engine, &t.values, &mut ws).unwrap().is_pending() {}
        let first: Vec<Discord> =
            sweep.lengths().iter().flat_map(|l| l.discords.iter().copied()).collect();
        sweep.rebind(t.len()).unwrap();
        assert!(!sweep.done());
        assert_eq!(sweep.progress(), (0, 5));
        while sweep.step(&engine, &t.values, &mut ws).unwrap().is_pending() {}
        let second: Vec<Discord> =
            sweep.lengths().iter().flat_map(|l| l.discords.iter().copied()).collect();
        assert_eq!(first, second, "a rebound sweep must reproduce the run exactly");
    }

    #[test]
    fn workspace_is_recycled_across_lengths_and_retries() {
        let t = random_walk_series(500, 27);
        let engine = NativeEngine::with_segn(64);
        let cfg = MerlinConfig { min_l: 12, max_l: 20, top_k: 1, ..Default::default() };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        let ws = res.metrics.workspace;
        assert_eq!(ws.resets, res.metrics.drag_calls, "one rebind per pd3 call");
        // The window count only shrinks as m grows, so after the first
        // call every rebind must reuse the arena.
        assert_eq!(ws.grows, 1, "only the cold pd3 call may grow: {ws:?}");
        let s = format!("{}", res.metrics);
        assert!(s.contains("ws(resets/grows)="), "metrics line reports workspace reuse: {s}");
    }

    /// `snapshot` → `restore` mid-sweep, continued on the SAME warm
    /// engine, is indistinguishable from never snapshotting.  (The
    /// cold-engine / seed-row-transfer variants live in
    /// `rust/tests/chaos_faults.rs`.)
    #[test]
    fn snapshot_restore_midsweep_continues_identically() {
        let t = random_walk_series(520, 31);
        let cfg = MerlinConfig { min_l: 12, max_l: 24, top_k: 2, ..Default::default() };
        let engine = NativeEngine::with_segn(64);
        let mut ws = MerlinWorkspace::new();

        let mut reference = MerlinSweep::new(cfg.clone(), t.len()).unwrap();
        while reference.step(&engine, &t.values, &mut ws).unwrap().is_pending() {}
        let want = reference.finish();

        let engine = NativeEngine::with_segn(64);
        let mut sweep = MerlinSweep::new(cfg, t.len()).unwrap();
        for _ in 0..6 {
            assert!(sweep.step(&engine, &t.values, &mut ws).unwrap().is_pending());
        }
        let bytes = sweep.snapshot();
        drop(sweep);
        let mut sweep = MerlinSweep::restore(&bytes).unwrap();
        assert_eq!(sweep.progress(), (6, 13));
        while sweep.step(&engine, &t.values, &mut ws).unwrap().is_pending() {}
        let got = sweep.finish();

        assert_eq!(want.lengths.len(), got.lengths.len());
        for (w, g) in want.lengths.iter().zip(&got.lengths) {
            assert_eq!(w.retries, g.retries, "m={}", w.m);
            assert_eq!(w.r_used.to_bits(), g.r_used.to_bits(), "m={}", w.m);
            assert_eq!(w.discords, g.discords, "m={}: restored sweep diverged", w.m);
        }
        assert_eq!(want.metrics.drag_calls, got.metrics.drag_calls);
        assert_eq!(want.metrics.retries, got.metrics.retries);
    }

    /// Snapshot edge cases: a fresh (zero-step) sweep and a finished
    /// sweep both round-trip, and restored sweeps keep behaving
    /// (fresh one runs to the same result; done one stays done).
    #[test]
    fn snapshot_restore_fresh_and_done_edges() {
        let t = random_walk_series(300, 33);
        let cfg = MerlinConfig { min_l: 10, max_l: 14, top_k: 1, ..Default::default() };
        let engine = NativeEngine::with_segn(64);
        let mut ws = MerlinWorkspace::new();

        let fresh = MerlinSweep::new(cfg.clone(), t.len()).unwrap();
        let mut a = MerlinSweep::restore(&fresh.snapshot()).unwrap();
        assert_eq!(a.progress(), (0, 5));
        while a.step(&engine, &t.values, &mut ws).unwrap().is_pending() {}
        let res_a = a.finish();

        let mut b = MerlinSweep::new(cfg, t.len()).unwrap();
        while b.step(&engine, &t.values, &mut ws).unwrap().is_pending() {}
        let done_bytes = b.snapshot();
        let mut c = MerlinSweep::restore(&done_bytes).unwrap();
        assert!(c.done());
        assert_eq!(c.step(&engine, &t.values, &mut ws).unwrap(), SweepStatus::Done);
        let res_c = c.finish();
        assert_eq!(res_a.lengths.len(), res_c.lengths.len());
        for (x, y) in res_a.lengths.iter().zip(&res_c.lengths) {
            assert_eq!(x.discords, y.discords);
        }
    }

    /// Corruption anywhere in the buffer is an `Err`, never a panic,
    /// and metrics/results survive the round-trip exactly.
    #[test]
    fn snapshot_rejects_corruption_and_preserves_metrics() {
        let t = random_walk_series(400, 35);
        let cfg = MerlinConfig { min_l: 12, max_l: 18, top_k: 1, ..Default::default() };
        let engine = NativeEngine::with_segn(64);
        let mut ws = MerlinWorkspace::new();
        let mut sweep = MerlinSweep::new(cfg, t.len()).unwrap();
        for _ in 0..4 {
            sweep.step(&engine, &t.values, &mut ws).unwrap();
        }
        let bytes = sweep.snapshot();

        let back = MerlinSweep::restore(&bytes).unwrap();
        assert_eq!(back.metrics().drag_calls, sweep.metrics().drag_calls);
        assert_eq!(back.metrics().seed.seed_hits, sweep.metrics().seed.seed_hits);
        assert_eq!(back.lengths().len(), sweep.lengths().len());

        // Truncations at every prefix length.
        for cut in 0..bytes.len() {
            assert!(MerlinSweep::restore(&bytes[..cut]).is_err(), "cut {cut}");
        }
        // Single-bit flips through the buffer (stride keeps it fast).
        for i in (0..bytes.len()).step_by(7) {
            let mut bad = bytes.clone();
            bad[i] ^= 0x10;
            assert!(MerlinSweep::restore(&bad).is_err(), "flip at {i} accepted");
        }
    }
}
