//! MERLIN (Alg. 1): arbitrary-length discord discovery via adaptive
//! range-threshold selection over repeated PD3 calls.
//!
//! For each length `m` in `[minL, maxL]` the driver picks a threshold `r`
//! that is "a little less" than the eventual discord distance — close
//! enough that PD3 prunes almost everything, but not above it (which would
//! return nothing):
//!
//! - `m = minL`: start at the theoretical maximum `2*sqrt(m)`, halve until
//!   PD3 succeeds.
//! - next four lengths: `r = 0.99 * nnDist_{m-1}`, shaving 1% per retry.
//! - afterwards: `r = mean - 2*std` of the previous five nnDists,
//!   subtracting one std per retry.
//!
//! The per-length window statistics are *not* recomputed: the rolling
//! vectors advance by the paper's recurrences (Eqs. 7/8) — the
//! redundant-calculation elimination that headlines the paper — either
//! natively or through the AOT `stats_update` kernel
//! ([`MerlinConfig::stats_backend`]).

use std::time::Instant;

use anyhow::{bail, Result};

use super::drag::{pd3_into, Discord, Pd3Config};
use super::metrics::MerlinMetrics;
use super::workspace::MerlinWorkspace;
use crate::core::series::TimeSeries;
use crate::core::stats::RollingStats;
use crate::core::topk::{top_k_non_overlapping, Scored};
use crate::core::windows::cmp_score_desc;
use crate::engines::{Engine, SeriesView};

/// How the rolling stats vectors are produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum StatsBackend {
    /// f64 in-process (Eq. 4 scan + Eqs. 7/8 recurrence).
    #[default]
    Native,
    /// The AOT `stats_init` / `stats_update` kernels via PJRT (same math,
    /// exercised end-to-end; slower at small n due to call overhead).
    Aot,
    /// Recompute from scratch every length (ablation baseline: what the
    /// paper's recurrences save).
    NaivePerLength,
}

/// MERLIN driver configuration.
#[derive(Clone, Debug)]
pub struct MerlinConfig {
    pub min_l: usize,
    pub max_l: usize,
    /// Top-k discords to report per length (0 = all survivors).
    pub top_k: usize,
    pub pd3: Pd3Config,
    pub stats_backend: StatsBackend,
    /// Retry guard per length (each retry lowers r and re-runs PD3).
    pub max_retries: usize,
    /// Give up lowering r below this fraction of `2*sqrt(m)`.
    pub r_floor_frac: f64,
}

impl Default for MerlinConfig {
    fn default() -> Self {
        Self {
            min_l: 64,
            max_l: 128,
            top_k: 1,
            pd3: Pd3Config::default(),
            stats_backend: StatsBackend::Native,
            max_retries: 60,
            r_floor_frac: 1e-4,
        }
    }
}

/// Per-length outcome.
#[derive(Clone, Debug)]
pub struct LengthResult {
    pub m: usize,
    /// Threshold the successful PD3 call used (ED units).
    pub r_used: f64,
    /// Retries needed at this length.
    pub retries: usize,
    /// Top-k (or all) discords, sorted by nn_dist descending.
    pub discords: Vec<Discord>,
}

/// Whole-run outcome.
#[derive(Clone, Debug)]
pub struct MerlinResult {
    pub lengths: Vec<LengthResult>,
    pub metrics: MerlinMetrics,
}

impl MerlinResult {
    /// Flatten all per-length discords.
    pub fn all_discords(&self) -> impl Iterator<Item = &Discord> {
        self.lengths.iter().flat_map(|l| l.discords.iter())
    }

    /// The single most anomalous subsequence across lengths, scored by the
    /// length-normalized distance (nnDist / (2*sqrt(m)), cf. Eq. 11).
    /// NaN scores rank last ([`cmp_score_desc`]) instead of panicking.
    pub fn top_normalized(&self) -> Option<&Discord> {
        self.all_discords().max_by(|a, b| {
            let na = a.nn_dist / (2.0 * (a.m as f64).sqrt());
            let nb = b.nn_dist / (2.0 * (b.m as f64).sqrt());
            // max_by wants ascending order; the descending comparator
            // with swapped arguments provides it, NaN pinned smallest.
            cmp_score_desc(nb, na)
        })
    }
}

/// The MERLIN driver bound to an engine.
pub struct Merlin<'e> {
    engine: &'e dyn Engine,
    cfg: MerlinConfig,
}

impl<'e> Merlin<'e> {
    pub fn new(engine: &'e dyn Engine, cfg: MerlinConfig) -> Self {
        Self { engine, cfg }
    }

    pub fn config(&self) -> &MerlinConfig {
        &self.cfg
    }

    /// Run arbitrary-length discovery over `t`.
    pub fn run(&self, t: &TimeSeries) -> Result<MerlinResult> {
        let cfg = &self.cfg;
        let n = t.len();
        if !(3 <= cfg.min_l && cfg.min_l <= cfg.max_l) {
            bail!("bad length range [{}, {}]", cfg.min_l, cfg.max_l);
        }
        if cfg.max_l > self.engine.max_m() {
            bail!("max_l {} exceeds engine max_m {}", cfg.max_l, self.engine.max_m());
        }
        // Need at least one non-self match at max_l.
        if n < 2 * cfg.max_l {
            bail!("series too short (n={n}) for max_l={} (need n >= 2*max_l)", cfg.max_l);
        }

        let t_start = Instant::now();
        let mut metrics = MerlinMetrics::default();
        let counters_start = self.engine.perf_counters();
        let mut lengths: Vec<LengthResult> = Vec::new();
        // Ring of the last 5 nnDist minima (ED units).
        let mut last5: Vec<f64> = Vec::new();
        // Hoisted PD3 arena: every length and every adaptive-r retry of
        // this run recycles one set of bitmaps / minima / tile buffers
        // instead of reallocating them per pd3 call (ROADMAP:
        // "pd3-level workspace reuse").
        let mut ws = MerlinWorkspace::new();

        let st0 = Instant::now();
        let mut stats = self.stats_init(&t.values, cfg.min_l)?;
        metrics.stats_time += st0.elapsed();

        for m in cfg.min_l..=cfg.max_l {
            debug_assert_eq!(stats.m, m);
            let view = SeriesView { t: &t.values, stats: &stats };
            let step = m - cfg.min_l;
            let max_r = 2.0 * (m as f64).sqrt();
            let r_floor = cfg.r_floor_frac * max_r;

            // Initial threshold per Alg. 1.
            let mut r = if step == 0 {
                max_r
            } else if step <= 4 {
                // Invariant: `last5` gains exactly one entry per completed
                // length — the no-discord outcome pushes a carry value (see
                // below) — so at step >= 1 it is provably non-empty.  The
                // all-flat-series unit test exercises the carry branch.
                0.99 * last5.last().copied().expect("last5 carries an entry per completed length")
            } else {
                let (mu, sigma) = mean_std(&last5);
                (mu - 2.0 * sigma).clamp(r_floor, max_r)
            };

            let mut retries = 0usize;
            let result = loop {
                metrics.drag_calls += 1;
                pd3_into(self.engine, &view, r, &cfg.pd3, &mut metrics.drag, &mut ws)?;
                let picked = pick_top_k(ws.discords(), m, cfg.top_k);
                let enough = if cfg.top_k == 0 { !picked.is_empty() } else { picked.len() >= cfg.top_k };
                if enough || r <= r_floor || retries >= cfg.max_retries {
                    break LengthResult { m, r_used: r, retries, discords: picked };
                }
                // Lower r per Alg. 1 and retry.
                retries += 1;
                metrics.retries += 1;
                r = if step == 0 {
                    0.5 * r
                } else if step <= 4 {
                    0.99 * r
                } else {
                    let (mu, sigma) = mean_std(&last5);
                    let dec = if sigma > 1e-12 * (1.0 + mu) { sigma } else { 0.05 * mu.max(1e-9) };
                    (r - dec).max(r_floor)
                };
            };

            // Track min nnDist among reported discords for the r schedule.
            let min_nn = result
                .discords
                .iter()
                .map(|d| d.nn_dist)
                .fold(f64::INFINITY, f64::min);
            if min_nn.is_finite() {
                last5.push(min_nn);
            } else {
                // Total failure at this length (pathological series):
                // carry the previous value so the schedule can continue.
                let carry = last5.last().copied().unwrap_or(0.5 * max_r);
                last5.push(carry);
            }
            if last5.len() > 5 {
                last5.remove(0);
            }
            metrics.discords += result.discords.len() as u64;
            lengths.push(result);

            // Advance stats m -> m+1 (Eqs. 7/8) unless this was the last.
            if m < cfg.max_l {
                let st = Instant::now();
                stats = self.stats_advance(stats, &t.values)?;
                metrics.stats_time += st.elapsed();
                // Bulk seed prefetch: advance every cached QT seed row to
                // m+1 in one engine-side sweep while no tiles are in
                // flight, so the next length's tiles open on verbatim
                // cache hits instead of serialized per-row advances under
                // the shard locks (ROADMAP "batch-level seed prefetch").
                let pf = Instant::now();
                self.engine.prefetch_length(&t.values, m + 1);
                metrics.prefetch_time += pf.elapsed();
            }
        }

        metrics.total_time = t_start.elapsed();
        metrics.seed = self.engine.perf_counters().since(counters_start);
        metrics.workspace = ws.counters();
        Ok(MerlinResult { lengths, metrics })
    }

    fn stats_init(&self, t: &[f64], m: usize) -> Result<RollingStats> {
        match self.cfg.stats_backend {
            StatsBackend::Native | StatsBackend::NaivePerLength => {
                Ok(RollingStats::compute(t, m))
            }
            StatsBackend::Aot => self.engine.aot_stats_init(t, m),
        }
    }

    fn stats_advance(&self, stats: RollingStats, t: &[f64]) -> Result<RollingStats> {
        match self.cfg.stats_backend {
            StatsBackend::Native => {
                let mut s = stats;
                s.advance(t);
                Ok(s)
            }
            StatsBackend::NaivePerLength => Ok(RollingStats::compute(t, stats.m + 1)),
            StatsBackend::Aot => self.engine.aot_stats_update(t, &stats),
        }
    }
}

/// Sort by nnDist descending, de-overlap, truncate to k (0 = all).
fn pick_top_k(discords: &[Discord], m: usize, k: usize) -> Vec<Discord> {
    let scored: Vec<Scored> =
        discords.iter().map(|d| Scored { idx: d.idx, nn_dist: d.nn_dist }).collect();
    top_k_non_overlapping(&scored, m, k)
        .into_iter()
        .map(|s| Discord { idx: s.idx, m, nn_dist: s.nn_dist })
        .collect()
}

fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mu = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / n;
    (mu, var.max(0.0).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::native::NativeEngine;
    use crate::util::rng::Rng;

    fn random_walk_series(n: usize, seed: u64) -> TimeSeries {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        let v = (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect();
        TimeSeries::new("rw", v)
    }

    #[test]
    fn finds_discords_for_every_length() {
        let t = random_walk_series(600, 21);
        let engine = NativeEngine::with_segn(64);
        let cfg = MerlinConfig { min_l: 16, max_l: 32, top_k: 1, ..Default::default() };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        assert_eq!(res.lengths.len(), 17);
        for lr in &res.lengths {
            assert_eq!(lr.discords.len(), 1, "m={}", lr.m);
            assert!(lr.discords[0].nn_dist > 0.0);
            assert!(lr.discords[0].nn_dist >= lr.r_used - 1e-9);
        }
    }

    #[test]
    fn top1_matches_brute_force_per_length() {
        use crate::core::distance::ed2norm;
        let t = random_walk_series(260, 22);
        let engine = NativeEngine::with_segn(32);
        let cfg = MerlinConfig { min_l: 10, max_l: 20, top_k: 1, ..Default::default() };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        for lr in &res.lengths {
            let m = lr.m;
            let nwin = t.len() - m + 1;
            // Brute-force top-1 discord.
            let mut best = (0usize, f64::NEG_INFINITY);
            for i in 0..nwin {
                let mut nn = f64::INFINITY;
                for j in 0..nwin {
                    if i.abs_diff(j) >= m {
                        nn = nn.min(ed2norm(&t.values[i..i + m], &t.values[j..j + m]));
                    }
                }
                if nn.is_finite() && nn > best.1 {
                    best = (i, nn);
                }
            }
            let got = &lr.discords[0];
            assert!(
                (got.nn_dist - best.1.sqrt()).abs() < 1e-6 * (1.0 + got.nn_dist),
                "m={m}: got dist {} want {}",
                got.nn_dist,
                best.1.sqrt()
            );
            // Index can differ only between exact ties.
            if got.idx != best.0 {
                let mut nn = f64::INFINITY;
                for j in 0..nwin {
                    if got.idx.abs_diff(j) >= m {
                        nn = nn.min(ed2norm(
                            &t.values[got.idx..got.idx + m],
                            &t.values[j..j + m],
                        ));
                    }
                }
                assert!((nn - best.1).abs() < 1e-9 * (1.0 + best.1));
            }
        }
    }

    #[test]
    fn stats_backends_agree() {
        let t = random_walk_series(400, 23);
        let engine = NativeEngine::with_segn(64);
        let base = MerlinConfig { min_l: 12, max_l: 24, top_k: 1, ..Default::default() };
        let a = Merlin::new(&engine, base.clone()).run(&t).unwrap();
        let b = Merlin::new(
            &engine,
            MerlinConfig { stats_backend: StatsBackend::NaivePerLength, ..base },
        )
        .run(&t)
        .unwrap();
        for (x, y) in a.lengths.iter().zip(&b.lengths) {
            assert_eq!(x.discords[0].idx, y.discords[0].idx);
            assert!((x.discords[0].nn_dist - y.discords[0].nn_dist).abs() < 1e-6);
        }
    }

    #[test]
    fn top_k_returns_non_overlapping() {
        let t = random_walk_series(800, 24);
        let engine = NativeEngine::with_segn(64);
        let cfg = MerlinConfig { min_l: 16, max_l: 16, top_k: 3, ..Default::default() };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        let d = &res.lengths[0].discords;
        assert!(d.len() >= 2, "expected multiple discords, got {}", d.len());
        for a in 0..d.len() {
            for b in a + 1..d.len() {
                assert!(d[a].idx.abs_diff(d[b].idx) >= 16);
            }
            if a > 0 {
                assert!(d[a - 1].nn_dist >= d[a].nn_dist);
            }
        }
    }

    #[test]
    fn seed_cache_is_exercised_across_lengths() {
        let t = random_walk_series(600, 26);
        let engine = NativeEngine::with_segn(64);
        let cfg = MerlinConfig { min_l: 16, max_l: 24, top_k: 1, ..Default::default() };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        let seed = res.metrics.seed;
        assert!(seed.seed_total() > 0, "native engine must report seed traffic");
        // The length loop runs one bulk prefetch sweep per advanced
        // length; round 0 (self tiles) is computed at every length, so
        // every sweep has rows to advance and the next length consumes
        // them as verbatim hits — no tile falls back to a lazy per-row
        // advance.
        assert_eq!(seed.prefetch_batches, (24 - 16) as u64, "{seed:?}");
        assert!(seed.seed_prefetched >= seed.prefetch_batches, "{seed:?}");
        assert!(seed.seed_hits > 0, "prefetched rows must resurface as hits: {seed:?}");
        assert_eq!(seed.seed_advances, 0, "prefetch subsumes lazy advances: {seed:?}");
    }

    #[test]
    fn rerun_on_warm_prefetched_engine_is_deterministic() {
        // The sweep is an optimization only: re-running MERLIN on an
        // engine whose cache is full of max_l rows (a restarted sweep:
        // misses, then prefetch again) must reproduce the first run
        // exactly (the prefetch recurrence matches the lazy advance
        // bit-for-bit, and both are oracle-checked in the engine tests —
        // here we pin the end-to-end wiring).
        let t = random_walk_series(500, 28);
        let cfg = MerlinConfig { min_l: 12, max_l: 22, top_k: 1, ..Default::default() };
        let warm_engine = NativeEngine::with_segn(64);
        let warm = Merlin::new(&warm_engine, cfg.clone()).run(&t).unwrap();
        // A second run on the *same* engine starts from a cache full of
        // max_l rows (restarted sweep: misses, then prefetch again).
        let rerun = Merlin::new(&warm_engine, cfg).run(&t).unwrap();
        for (a, b) in warm.lengths.iter().zip(&rerun.lengths) {
            assert_eq!(a.discords[0].idx, b.discords[0].idx, "m={}", a.m);
            assert!((a.discords[0].nn_dist - b.discords[0].nn_dist).abs() < 1e-12);
        }
    }

    #[test]
    fn rejects_bad_ranges() {
        let t = random_walk_series(100, 25);
        let engine = NativeEngine::with_segn(32);
        assert!(Merlin::new(
            &engine,
            MerlinConfig { min_l: 2, max_l: 10, ..Default::default() }
        )
        .run(&t)
        .is_err());
        assert!(Merlin::new(
            &engine,
            MerlinConfig { min_l: 60, max_l: 60, ..Default::default() }
        )
        .run(&t)
        .is_err());
    }

    #[test]
    fn constant_series_is_handled() {
        // All-flat series: every window is a twin -> nnDist 0 everywhere;
        // MERLIN must terminate (retry caps) and report nothing/zeros.
        let t = TimeSeries::new("flat", vec![5.0; 200]);
        let engine = NativeEngine::with_segn(32);
        let cfg = MerlinConfig {
            min_l: 8,
            max_l: 10,
            top_k: 1,
            max_retries: 5,
            ..Default::default()
        };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        assert_eq!(res.lengths.len(), 3);
        for lr in &res.lengths {
            for d in &lr.discords {
                assert!(d.nn_dist <= 1e-6);
            }
        }
    }

    #[test]
    fn flat_series_carry_seeds_early_length_thresholds() {
        // All-flat series: no length ever reports a discord, so the r
        // schedule for steps 1..=4 must be seeded by the carry value the
        // no-discord path pushes into `last5` — the invariant behind the
        // `expect` in the step <= 4 branch.  A missing carry would panic
        // right at m = min_l + 1.
        let t = TimeSeries::new("flat", vec![5.0; 160]);
        let engine = NativeEngine::with_segn(16);
        let cfg = MerlinConfig {
            min_l: 8,
            max_l: 13, // covers steps 0..=5: both carry-seeded regimes
            top_k: 1,
            max_retries: 3,
            ..Default::default()
        };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        assert_eq!(res.lengths.len(), 6);
        for lr in &res.lengths {
            assert!(lr.discords.is_empty(), "m={}: flat series has only twins", lr.m);
            assert!(lr.r_used > 0.0 && lr.r_used.is_finite());
        }
    }

    #[test]
    fn workspace_is_recycled_across_lengths_and_retries() {
        let t = random_walk_series(500, 27);
        let engine = NativeEngine::with_segn(64);
        let cfg = MerlinConfig { min_l: 12, max_l: 20, top_k: 1, ..Default::default() };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        let ws = res.metrics.workspace;
        assert_eq!(ws.resets, res.metrics.drag_calls, "one rebind per pd3 call");
        // The window count only shrinks as m grows, so after the first
        // call every rebind must reuse the arena.
        assert_eq!(ws.grows, 1, "only the cold pd3 call may grow: {ws:?}");
        let s = format!("{}", res.metrics);
        assert!(s.contains("ws(resets/grows)="), "metrics line reports workspace reuse: {s}");
    }
}
