//! Execution counters for PD3 / MERLIN runs — both for the log output and
//! for the ablation benches (early-stop rate, pruning effectiveness).

use std::time::Duration;

use super::workspace::WorkspaceCounters;
use crate::engines::EnginePerfCounters;

/// Counters for one DRAG (PD3) invocation.
#[derive(Clone, Debug, Default)]
pub struct DragMetrics {
    /// Tiles actually evaluated by the engine.
    pub tiles_computed: u64,
    /// Tiles skipped because their segment was already fully pruned.
    pub tiles_skipped: u64,
    /// Candidate bits cleared during selection / refinement.
    pub kills_select: u64,
    pub kills_refine: u64,
    /// Survivors (range discords) returned.
    pub survivors: u64,
    pub select_time: Duration,
    pub refine_time: Duration,
}

impl DragMetrics {
    pub fn merge(&mut self, other: &DragMetrics) {
        self.tiles_computed += other.tiles_computed;
        self.tiles_skipped += other.tiles_skipped;
        self.kills_select += other.kills_select;
        self.kills_refine += other.kills_refine;
        self.survivors += other.survivors;
        self.select_time += other.select_time;
        self.refine_time += other.refine_time;
    }

    /// Fraction of potential tiles avoided by segment early-stop.
    pub fn skip_ratio(&self) -> f64 {
        let total = self.tiles_computed + self.tiles_skipped;
        if total == 0 {
            0.0
        } else {
            self.tiles_skipped as f64 / total as f64
        }
    }
}

/// Counters for a whole MERLIN run.
#[derive(Clone, Debug, Default)]
pub struct MerlinMetrics {
    pub drag: DragMetrics,
    /// DRAG invocations (including retries with lowered r).
    pub drag_calls: u64,
    /// Retries beyond the first call per length.
    pub retries: u64,
    /// Total discords reported across lengths.
    pub discords: u64,
    /// Engine QT seed cache traffic during this run (hits = same-length
    /// reuse, advances = cross-length `m -> m'` recurrence updates,
    /// misses = full seed passes, prefetched/prefetch_batches = rows and
    /// sweeps of the bulk between-length prefetch).  All-zero for
    /// cache-less engines.
    pub seed: EnginePerfCounters,
    /// Wall time spent in the bulk seed-prefetch sweeps
    /// (`Engine::prefetch_length` between lengths).
    pub prefetch_time: Duration,
    /// Coordinator arena reuse during this run (resets = PD3 calls
    /// through the hoisted workspace; grows = calls whose window count
    /// grew the minima vector — see [`WorkspaceCounters::grows`] for
    /// what that gauge does and does not cover).
    pub workspace: WorkspaceCounters,
    pub stats_time: Duration,
    pub total_time: Duration,
}

impl std::fmt::Display for MerlinMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "drag_calls={} retries={} discords={} tiles={} skipped={} ({:.1}% early-stop) \
             seeds(hit/adv/miss)={}/{}/{} prefetch(rows/batches)={}/{} \
             kernel={} kernel(sat/flat)={}/{} ws(resets/grows)={}/{} \
             select={:.3}s refine={:.3}s stats={:.3}s prefetch={:.3}s total={:.3}s",
            self.drag_calls,
            self.retries,
            self.discords,
            self.drag.tiles_computed,
            self.drag.tiles_skipped,
            100.0 * self.drag.skip_ratio(),
            self.seed.seed_hits,
            self.seed.seed_advances,
            self.seed.seed_misses,
            self.seed.seed_prefetched,
            self.seed.prefetch_batches,
            // The concrete kernel the engine ran (Auto already resolved
            // by the engine); "unset" for engines that predate the gauge.
            self.seed.kernel.map_or("unset", |k| k.name()),
            self.seed.clamp_saturations,
            self.seed.flat_cells,
            self.workspace.resets,
            self.workspace.grows,
            self.drag.select_time.as_secs_f64(),
            self.drag.refine_time.as_secs_f64(),
            self.stats_time.as_secs_f64(),
            self.prefetch_time.as_secs_f64(),
            self.total_time.as_secs_f64(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = DragMetrics { tiles_computed: 10, tiles_skipped: 30, ..Default::default() };
        let b = DragMetrics { tiles_computed: 5, kills_select: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.tiles_computed, 15);
        assert_eq!(a.kills_select, 2);
        assert!((a.skip_ratio() - 30.0 / 45.0).abs() < 1e-12);
    }

    #[test]
    fn skip_ratio_empty_is_zero() {
        assert_eq!(DragMetrics::default().skip_ratio(), 0.0);
    }

    #[test]
    fn display_contains_fields() {
        let mut m = MerlinMetrics { drag_calls: 3, ..Default::default() };
        let s = format!("{m}");
        assert!(s.contains("drag_calls=3"));
        assert!(s.contains("kernel=unset"), "unreported kernel identity missing: {s}");
        assert!(s.contains("kernel(sat/flat)="), "kernel decision gauges missing: {s}");
        m.seed.kernel = Some(crate::engines::TileKernel::Lanes8);
        let s = format!("{m}");
        assert!(s.contains("kernel=lanes8"), "kernel identity missing: {s}");
    }
}
