//! The layer-3 coordinator: MERLIN driver, parallel DRAG (PD3), segment
//! scheduling, the job service, and configuration.

pub mod checkpoint;
pub mod config;
pub mod distributed;
pub mod drag;
pub mod lease;
pub mod merlin;
pub mod metrics;
pub mod segmentation;
pub mod service;
pub mod streaming;
pub mod workspace;
