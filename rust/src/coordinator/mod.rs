//! The layer-3 coordinator: MERLIN driver, parallel DRAG (PD3), segment
//! scheduling, the job service, and configuration.
//!
//! This tree owns long-lived multi-tenant state (job queues, engine
//! leases, checkpoints), so two repo-wide gates are pinned here: no
//! `unsafe` at all, and no panicking `unwrap` outside test code — a
//! worker panic must never be a *library* bug, only a job's.  Lock
//! acquisition goes through `util::sync::{lock_recover, wait_recover}`
//! (no direct `.lock()`; enforced by `palmad-lint`), so one poisoned
//! mutex cannot cascade across tenants.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]
#![forbid(unsafe_code)]

pub mod checkpoint;
pub mod config;
pub mod distributed;
pub mod drag;
pub mod frontend;
pub mod lease;
pub mod merlin;
pub mod metrics;
pub mod queue;
pub mod segmentation;
pub mod service;
pub mod streaming;
pub mod workspace;
