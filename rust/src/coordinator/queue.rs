//! Weighted-fair run queue for the step scheduler: deficit round robin
//! (DRR) over per-tenant job FIFOs.
//!
//! The PR-5 scheduler round-robined a flat `VecDeque<u64>` of job ids,
//! which is fair *per job*: a tenant that submits 50 jobs gets 50 times
//! the step throughput of a tenant that submits one.  `RunQueue`
//! schedules *tenants* instead: each tenant owns a FIFO of queued job
//! ids and a configured weight, and the scheduler serves tenants from a
//! round-robin ring, letting each serve up to `weight` steps per visit
//! (every "packet" costs exactly one step, so the classic DRR quantum
//! degenerates to the weight itself — no fractional deficit carry is
//! needed).  Over any backlogged window, tenant step shares converge to
//! the weight ratio regardless of how many jobs each tenant queues.
//!
//! The legacy flat policy survives as [`SchedPolicy::RoundRobin`] — the
//! measurable baseline for `examples/service_loadgen.rs`, exactly like
//! `TilePipeline::Legacy` and `StreamConfig::legacy_slide` before it.
//!
//! `RunQueue` is plain data: the service guards it with the same run
//! queue mutex + condvar protocol that the loom model
//! `service_shutdown_no_lost_wakeup` explores, so nothing here touches
//! an atomic or lock.  The tenant registry keeps a `HashMap` strictly
//! for name lookup; every iteration that feeds scheduling decisions or
//! metrics walks the registration-ordered `Vec` (numeric-determinism
//! discipline, ANALYSIS.md P2).

use std::collections::{HashMap, VecDeque};

/// Which run-queue policy the scheduler uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Flat per-job round robin — the PR-5 behavior, kept as the
    /// measurable fairness baseline.
    RoundRobin,
    /// Deficit round robin over tenants with per-tenant step budgets.
    #[default]
    WeightedFair,
}

/// One queued step claim: a job id plus scheduling metadata that must
/// be readable under the queue lock alone (the jobs table has its own
/// mutex, and the worker claims jobs *after* popping — taking both
/// locks here would invert the jobs→queue order used at park time).
#[derive(Clone, Copy, Debug)]
struct Entry {
    id: u64,
    tenant: usize,
    /// Small enough (known series length under the configured bound)
    /// to ride along in a cross-tenant batched engine round.
    small: bool,
}

struct Tenant {
    name: String,
    weight: u32,
    jobs: VecDeque<Entry>,
    /// Steps handed out to this tenant (pops, including batched
    /// ride-alongs) — the fairness observable.
    steps: u64,
    /// True while the tenant sits in the `active` ring or is the
    /// current server (invariant: exactly then).
    enlisted: bool,
}

/// A tenant's public scheduling stats (`Service::tenant_shares`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantShare {
    pub name: String,
    pub weight: u32,
    /// Steps scheduled so far.
    pub steps: u64,
    /// Jobs currently queued (not claimed by a worker).
    pub queued: usize,
}

/// Deficit-round-robin run queue (module docs).
pub struct RunQueue {
    policy: SchedPolicy,
    tenants: Vec<Tenant>,
    /// Name → index lookup only; never iterated (ANALYSIS.md P2).
    by_name: HashMap<String, usize>,
    /// Ring of enlisted tenants awaiting their serving turn.
    active: VecDeque<usize>,
    /// Tenant currently being served, with its remaining step budget.
    current: Option<usize>,
    budget: u64,
    /// Flat FIFO for the legacy [`SchedPolicy::RoundRobin`] policy.
    flat: VecDeque<Entry>,
    len: usize,
    /// Times a tenant's budget ran dry with work still queued (the
    /// `wfq(budget_exhausted)=` gauge: weights actively shaping order).
    budget_exhausted: u64,
}

impl RunQueue {
    pub fn new(policy: SchedPolicy) -> Self {
        Self {
            policy,
            tenants: Vec::new(),
            by_name: HashMap::new(),
            active: VecDeque::new(),
            current: None,
            budget: 0,
            flat: VecDeque::new(),
            len: 0,
            budget_exhausted: 0,
        }
    }

    /// Register (or re-weigh) a tenant; returns its stable index.  The
    /// latest submitted weight wins — weights are a client knob, not an
    /// immutable contract, and re-registration is how a tenant adjusts
    /// its share mid-stream.  Callers enforce any tenant-count cap
    /// *before* registering (admission control owns rejection).
    pub fn register(&mut self, name: &str, weight: u32) -> usize {
        let weight = weight.max(1);
        if let Some(&idx) = self.by_name.get(name) {
            self.tenants[idx].weight = weight;
            return idx;
        }
        let idx = self.tenants.len();
        self.tenants.push(Tenant {
            name: name.to_string(),
            weight,
            jobs: VecDeque::new(),
            steps: 0,
            enlisted: false,
        });
        self.by_name.insert(name.to_string(), idx);
        idx
    }

    /// Look up a tenant without registering it.
    pub fn lookup(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    /// Queue a step claim for `tenant`.  Used by submission, resume,
    /// and the worker's park-requeue (requeues bypass admission — a
    /// parked job was already admitted).
    pub fn push(&mut self, tenant: usize, id: u64, small: bool) {
        debug_assert!(tenant < self.tenants.len(), "push for an unregistered tenant");
        let Some(t) = self.tenants.get_mut(tenant) else { return };
        let entry = Entry { id, tenant, small };
        self.len += 1;
        if self.policy == SchedPolicy::RoundRobin {
            self.flat.push_back(entry);
            return;
        }
        t.jobs.push_back(entry);
        if !t.enlisted {
            t.enlisted = true;
            self.active.push_back(tenant);
        }
    }

    /// Dequeue the next step claim under the active policy.
    pub fn pop(&mut self) -> Option<u64> {
        if self.policy == SchedPolicy::RoundRobin {
            let e = self.flat.pop_front()?;
            self.len -= 1;
            self.tenants[e.tenant].steps += 1;
            return Some(e.id);
        }
        loop {
            let t = match self.current {
                Some(t) => t,
                None => {
                    let t = self.active.pop_front()?;
                    self.current = Some(t);
                    self.budget = u64::from(self.tenants[t].weight.max(1));
                    t
                }
            };
            let tenant = &mut self.tenants[t];
            if tenant.jobs.is_empty() {
                // Drained (possibly by a batched ride-along): the
                // tenant leaves the ring until its next push.
                tenant.enlisted = false;
                self.current = None;
                continue;
            }
            if self.budget == 0 {
                // Budget spent with work left: rotate to the back of
                // the ring so the next tenant gets its turn.
                self.active.push_back(t);
                self.budget_exhausted += 1;
                self.current = None;
                continue;
            }
            let e = tenant.jobs.pop_front().expect("non-empty checked above");
            tenant.steps += 1;
            self.budget -= 1;
            self.len -= 1;
            return Some(e.id);
        }
    }

    /// Dequeue one *small* step claim from a tenant other than the
    /// current server, to ride along in a batched engine round (one
    /// lease checkout serving several small tenants back to back).
    ///
    /// The ride-along is not charged against anyone's budget: the
    /// shared round costs the lease pool a single checkout either way,
    /// and the scan only ever takes a queue head, so per-tenant FIFO
    /// order is preserved.  Returns `None` under the legacy policy
    /// (batching is a weighted-fair feature) or when no other tenant's
    /// head entry is small.
    pub fn pop_small_extra(&mut self) -> Option<u64> {
        if self.policy == SchedPolicy::RoundRobin {
            return None;
        }
        // Scan the ring in serving order; `remove(pos)` keeps the ring
        // order of everyone else intact.
        let pos = (0..self.active.len()).find(|&p| {
            let t = self.active[p];
            self.tenants[t].jobs.front().is_some_and(|e| e.small)
        })?;
        let t = self.active[pos];
        let tenant = &mut self.tenants[t];
        let e = tenant.jobs.pop_front().expect("scan found a head entry");
        tenant.steps += 1;
        self.len -= 1;
        if tenant.jobs.is_empty() {
            tenant.enlisted = false;
            self.active.remove(pos);
        }
        Some(e.id)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn budget_exhausted(&self) -> u64 {
        self.budget_exhausted
    }

    /// Drop every queued claim (shutdown drain).  Tenant identities,
    /// weights, and step counters survive — only pending work clears.
    pub fn clear(&mut self) {
        self.flat.clear();
        self.active.clear();
        self.current = None;
        self.budget = 0;
        self.len = 0;
        for t in &mut self.tenants {
            t.jobs.clear();
            t.enlisted = false;
        }
    }

    /// Per-tenant scheduling stats in registration order (stable and
    /// deterministic — never HashMap order).
    pub fn shares(&self) -> Vec<TenantShare> {
        self.tenants
            .iter()
            .map(|t| TenantShare {
                name: t.name.clone(),
                weight: t.weight,
                steps: t.steps,
                queued: t.jobs.len(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut RunQueue, k: usize) -> Vec<u64> {
        (0..k).filter_map(|_| q.pop()).collect()
    }

    /// With every tenant backlogged, DRR serves exactly `weight` steps
    /// per visit: A(w=3), B(w=1) interleave as A A A B A A A B ...
    #[test]
    fn drr_interleaves_by_weight() {
        let mut q = RunQueue::new(SchedPolicy::WeightedFair);
        let a = q.register("a", 3);
        let b = q.register("b", 1);
        for i in 0..6 {
            q.push(a, 100 + i, false);
            q.push(b, 200 + i, false);
        }
        let order = drain(&mut q, 8);
        assert_eq!(order, vec![100, 101, 102, 200, 103, 104, 105, 201]);
        assert_eq!(q.len(), 4, "four of B's entries remain");
        assert!(q.budget_exhausted() >= 2, "A rotated out with work left twice");
    }

    /// Step shares track configured weights exactly over whole rounds,
    /// and well within the 10% fairness tolerance mid-round.
    #[test]
    fn drr_shares_match_weights() {
        let mut q = RunQueue::new(SchedPolicy::WeightedFair);
        let ids = [q.register("w4", 4), q.register("w2", 2), q.register("w1", 1)];
        for k in 0..70 {
            for (t, idx) in ids.iter().enumerate() {
                q.push(*idx, (t as u64) * 1000 + k, false);
            }
        }
        let _ = drain(&mut q, 70);
        let shares = q.shares();
        let steps: Vec<u64> = shares.iter().map(|s| s.steps).collect();
        let total: u64 = steps.iter().sum();
        assert_eq!(total, 70);
        for (s, w) in steps.iter().zip([4.0f64, 2.0, 1.0]) {
            let got = *s as f64 / total as f64;
            let want = w / 7.0;
            assert!(
                (got - want).abs() <= 0.10 * want,
                "share {got:.3} deviates more than 10% from {want:.3} (steps {steps:?})"
            );
        }
    }

    /// A lone 1-weight tenant cannot be starved by a heavy tenant with
    /// a deep backlog: its single job is served within one full round.
    #[test]
    fn light_tenant_is_served_within_one_round() {
        let mut q = RunQueue::new(SchedPolicy::WeightedFair);
        let heavy = q.register("heavy", 8);
        let light = q.register("light", 1);
        for i in 0..100 {
            q.push(heavy, i, false);
        }
        q.push(light, 999, false);
        let order = drain(&mut q, 10);
        assert!(
            order.contains(&999),
            "light tenant must be served within heavy's first quantum + 1 ({order:?})"
        );
    }

    /// The legacy policy preserves flat FIFO order regardless of
    /// weights, and still attributes steps to tenants.
    #[test]
    fn round_robin_policy_is_flat_fifo() {
        let mut q = RunQueue::new(SchedPolicy::RoundRobin);
        let a = q.register("a", 50);
        let b = q.register("b", 1);
        q.push(a, 1, false);
        q.push(b, 2, false);
        q.push(a, 3, false);
        assert_eq!(drain(&mut q, 3), vec![1, 2, 3]);
        assert_eq!(q.pop(), None);
        let shares = q.shares();
        assert_eq!((shares[0].steps, shares[1].steps), (2, 1));
        assert_eq!(q.pop_small_extra(), None, "batching is a weighted-fair feature");
    }

    /// An emptied tenant leaves the ring and re-enlists on push; ids
    /// are never duplicated or dropped.
    #[test]
    fn tenants_leave_and_rejoin_the_ring() {
        let mut q = RunQueue::new(SchedPolicy::WeightedFair);
        let a = q.register("a", 2);
        q.push(a, 1, false);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
        q.push(a, 2, false);
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    /// Re-registering a tenant updates its weight in place.
    #[test]
    fn reregistration_updates_weight() {
        let mut q = RunQueue::new(SchedPolicy::WeightedFair);
        let a = q.register("a", 1);
        assert_eq!(q.register("a", 5), a);
        assert_eq!(q.tenant_count(), 1);
        assert_eq!(q.shares()[0].weight, 5);
        assert_eq!(q.lookup("a"), Some(a));
        assert_eq!(q.lookup("missing"), None);
    }

    /// `pop_small_extra` takes only small queue heads from tenants
    /// other than the current server, preserving per-tenant FIFO.
    #[test]
    fn small_extras_ride_along_from_other_tenants() {
        let mut q = RunQueue::new(SchedPolicy::WeightedFair);
        let a = q.register("a", 1);
        let b = q.register("b", 1);
        let c = q.register("c", 1);
        q.push(a, 10, true);
        q.push(b, 20, false); // big head: not batchable
        q.push(b, 21, true); //  ... even with a small entry behind it
        q.push(c, 30, true);
        let first = q.pop().expect("primary claim");
        assert_eq!(first, 10, "ring order: tenant a is served first");
        // a is drained; b's head is big; c's head is small.
        assert_eq!(q.pop_small_extra(), Some(30));
        assert_eq!(q.pop_small_extra(), None, "no other small head exists");
        assert_eq!(drain(&mut q, 2), vec![20, 21]);
        assert!(q.is_empty());
        let steps: Vec<u64> = q.shares().iter().map(|s| s.steps).collect();
        assert_eq!(steps, vec![1, 2, 1]);
    }

    /// Clearing drops queued work but keeps tenants and counters.
    #[test]
    fn clear_drops_work_keeps_identity() {
        let mut q = RunQueue::new(SchedPolicy::WeightedFair);
        let a = q.register("a", 2);
        q.push(a, 1, false);
        q.push(a, 2, false);
        assert_eq!(q.pop(), Some(1));
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
        assert_eq!(q.tenant_count(), 1);
        assert_eq!(q.shares()[0].steps, 1);
        q.push(a, 3, false);
        assert_eq!(q.pop(), Some(3), "the ring re-forms after a clear");
    }
}
