//! Series segmentation (Fig. 2 / Eq. 9 of the paper).
//!
//! PD3 divides the `N = n - m + 1` subsequences into consecutive segments
//! of `segN` subsequences; each segment maps to one tile row (the GPU
//! thread block of the paper, one tile task per (segment, chunk) pair
//! here).  The paper pads the series with `+inf` dummies so every block is
//! full (Eq. 9); our tile kernels carry explicit validity counts
//! (`na`/`nb`) instead, so the ragged last segment needs no dummy data —
//! [`pad_len`] is still provided (and property-tested) because the
//! benchmarks report it and DESIGN.md documents the equivalence.

/// Segment layout over `nwin` subsequences with tile edge `segn`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segmentation {
    pub nwin: usize,
    pub segn: usize,
    pub nseg: usize,
}

impl Segmentation {
    pub fn new(nwin: usize, segn: usize) -> Self {
        assert!(segn >= 1);
        Self { nwin, segn, nseg: nwin.div_ceil(segn) }
    }

    /// Global index of the first subsequence of segment `s`.
    #[inline]
    pub fn seg_start(&self, s: usize) -> usize {
        s * self.segn
    }

    /// Valid-subsequence range of segment `s` (last segment may be short).
    #[inline]
    pub fn seg_range(&self, s: usize) -> std::ops::Range<usize> {
        let start = self.seg_start(s);
        start..(start + self.segn).min(self.nwin)
    }

    /// Number of valid subsequences in segment `s`.
    #[inline]
    pub fn seg_len(&self, s: usize) -> usize {
        let r = self.seg_range(s);
        r.end - r.start
    }

    /// Which segment a subsequence index belongs to.
    #[inline]
    pub fn segment_of(&self, idx: usize) -> usize {
        idx / self.segn
    }
}

/// The paper's padding formula (Eq. 9): number of dummy elements appended
/// so that `N` is a multiple of the per-segment subsequence count.
///
/// `n` is the series length, `m` the subsequence length, `seglen` the
/// segment length in *elements* (so `segN = seglen - m + 1`).
pub fn pad_len(n: usize, m: usize, seglen: usize) -> usize {
    assert!(seglen >= m);
    let nwin = n - m + 1;
    let segn = seglen - m + 1;
    if nwin % segn == 0 {
        m - 1
    } else {
        nwin.div_ceil(segn) * segn + 2 * (m - 1) - n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_exact_multiple() {
        let s = Segmentation::new(256, 64);
        assert_eq!(s.nseg, 4);
        assert_eq!(s.seg_range(3), 192..256);
        assert_eq!(s.seg_len(3), 64);
        assert_eq!(s.segment_of(191), 2);
        assert_eq!(s.segment_of(192), 3);
    }

    #[test]
    fn layout_ragged_tail() {
        let s = Segmentation::new(250, 64);
        assert_eq!(s.nseg, 4);
        assert_eq!(s.seg_range(3), 192..250);
        assert_eq!(s.seg_len(3), 58);
    }

    #[test]
    fn single_short_segment() {
        let s = Segmentation::new(10, 64);
        assert_eq!(s.nseg, 1);
        assert_eq!(s.seg_range(0), 0..10);
    }

    #[test]
    fn eq9_exact_multiple_case() {
        // N = 91 windows (n=100, m=10); seglen=16 -> segN=7; 91 % 7 == 0.
        assert_eq!(pad_len(100, 10, 16), 9); // m - 1
    }

    #[test]
    fn eq9_general_case_covers_all_segments() {
        // The paper's formula guarantees enough padded elements for
        // ceil(N/segN) full segments of segN windows each, plus chunk
        // slack (the extra m-1 term); it does NOT make the padded window
        // count an exact multiple (the kernels' validity masks absorb the
        // remainder).
        for (n, m, seglen) in [(100usize, 10usize, 20usize), (1000, 50, 128), (333, 7, 32)] {
            let pad = pad_len(n, m, seglen);
            let segn = seglen - m + 1;
            let nwin = n - m + 1;
            let nseg = nwin.div_ceil(segn);
            let padded_nwin = n + pad - m + 1;
            assert!(
                padded_nwin >= nseg * segn,
                "n={n} m={m} seglen={seglen} pad={pad}: {padded_nwin} < {}",
                nseg * segn
            );
            assert!(pad >= m - 1, "pad covers the trailing window overlap");
        }
    }
}
