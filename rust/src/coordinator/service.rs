//! The anomaly-discovery job service: a queue + worker-pool front end over
//! the MERLIN coordinator, with a line-oriented TCP protocol.
//!
//! Shape follows the serving-system framing of the repro (vLLM-router
//! style): clients submit jobs (series spec + length range + top-k), a
//! router thread assigns them to workers, each worker owns an engine and
//! runs MERLIN; clients poll status or run synchronously.
//!
//! Protocol (one request per line, responses `OK ...` / `ERR ...`):
//!
//! ```text
//! RUN gen=<dataset> [n=<len>] [seed=<u64>] minl=<m> maxl=<m> [topk=<k>]
//!   -> OK JOB <id>
//! STATUS <id>
//!   -> OK QUEUED | OK RUNNING | OK FAILED <msg>
//!    | OK DONE <njobs-line>; then one `DISCORD m=<m> idx=<i> dist=<d>`
//!      line per discord and a final `END`
//! METRICS
//!   -> OK METRICS jobs=<n> done=<n> failed=<n> discords=<n>
//! SHUTDOWN -> OK BYE (stops the listener)
//! ```

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Result};

use super::config::{build_engine, EngineOptions};
use super::drag::Discord;
use super::merlin::{Merlin, MerlinConfig};
use crate::core::series::TimeSeries;
use crate::gen::registry;

/// A submitted job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub dataset: String,
    pub n: Option<usize>,
    pub seed: u64,
    pub min_l: usize,
    pub max_l: usize,
    pub top_k: usize,
}

/// Job lifecycle.
#[derive(Clone, Debug)]
pub enum JobState {
    Queued,
    Running,
    Done { discords: Vec<Discord>, seconds: f64 },
    Failed(String),
}

#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    done: AtomicU64,
    failed: AtomicU64,
    discords: AtomicU64,
}

struct Inner {
    queue: Mutex<Vec<(u64, JobSpec)>>,
    jobs: Mutex<HashMap<u64, JobState>>,
    cv: Condvar,
    counters: Counters,
    stop: AtomicBool,
    next_id: AtomicU64,
    engine_opts: EngineOptions,
}

/// The job service handle.
pub struct Service {
    inner: Arc<Inner>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Service {
    /// Start `workers` worker threads, each owning its own engine.
    pub fn start(engine_opts: EngineOptions, workers: usize) -> Result<Self> {
        let inner = Arc::new(Inner {
            queue: Mutex::new(Vec::new()),
            jobs: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            counters: Counters::default(),
            stop: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            engine_opts,
        });
        let mut handles = Vec::new();
        for w in 0..workers.max(1) {
            let inner = Arc::clone(&inner);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("palmad-worker-{w}"))
                    .spawn(move || worker_main(inner))
                    .map_err(|e| anyhow!("spawn worker: {e}"))?,
            );
        }
        Ok(Self { inner, workers: handles })
    }

    /// Submit a job; returns its id.
    pub fn submit(&self, spec: JobSpec) -> u64 {
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        self.inner.counters.submitted.fetch_add(1, Ordering::Relaxed);
        self.inner.jobs.lock().unwrap().insert(id, JobState::Queued);
        self.inner.queue.lock().unwrap().push((id, spec));
        self.inner.cv.notify_one();
        id
    }

    /// Current state of a job.
    pub fn status(&self, id: u64) -> Option<JobState> {
        self.inner.jobs.lock().unwrap().get(&id).cloned()
    }

    /// Block until the job leaves Queued/Running.
    pub fn wait(&self, id: u64) -> Option<JobState> {
        loop {
            match self.status(id) {
                Some(JobState::Queued) | Some(JobState::Running) => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                other => return other,
            }
        }
    }

    /// (submitted, done, failed, discords)
    pub fn metrics(&self) -> (u64, u64, u64, u64) {
        let c = &self.inner.counters;
        (
            c.submitted.load(Ordering::Relaxed),
            c.done.load(Ordering::Relaxed),
            c.failed.load(Ordering::Relaxed),
            c.discords.load(Ordering::Relaxed),
        )
    }

    /// Stop workers (idempotent).
    pub fn shutdown(&mut self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    /// Serve the TCP protocol until a SHUTDOWN request arrives.
    pub fn serve(&self, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        crate::log_info!("palmad service listening on {addr}");
        for stream in listener.incoming() {
            let stream = stream?;
            let done = self.handle_conn(stream);
            if done {
                break;
            }
        }
        Ok(())
    }

    /// Public wrapper over [`Self::handle_conn`] for embedders that run
    /// their own accept loop (see `examples/serve_demo.rs`).
    pub fn handle_conn_public(&self, stream: TcpStream) -> bool {
        self.handle_conn(stream)
    }

    /// Handle one connection; returns true if SHUTDOWN was requested.
    fn handle_conn(&self, stream: TcpStream) -> bool {
        let peer = stream.peer_addr().ok();
        let mut reader = BufReader::new(match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return false,
        });
        let mut out = stream;
        let mut line = String::new();
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => return false,
                Ok(_) => {}
            }
            let req = line.trim();
            if req.is_empty() {
                continue;
            }
            crate::log_debug!("request from {peer:?}: {req}");
            match self.dispatch(req, &mut out) {
                Ok(true) => return true,
                Ok(false) => {}
                Err(e) => {
                    let _ = writeln!(out, "ERR {e}");
                }
            }
        }
    }

    fn dispatch(&self, req: &str, out: &mut TcpStream) -> Result<bool> {
        let mut parts = req.split_whitespace();
        match parts.next().unwrap_or("") {
            "RUN" => {
                let spec = parse_spec(parts)?;
                let id = self.submit(spec);
                writeln!(out, "OK JOB {id}")?;
            }
            "STATUS" => {
                let id: u64 = parts.next().ok_or_else(|| anyhow!("STATUS <id>"))?.parse()?;
                match self.status(id) {
                    None => bail!("no such job {id}"),
                    Some(JobState::Queued) => writeln!(out, "OK QUEUED")?,
                    Some(JobState::Running) => writeln!(out, "OK RUNNING")?,
                    Some(JobState::Failed(e)) => writeln!(out, "OK FAILED {e}")?,
                    Some(JobState::Done { discords, seconds }) => {
                        writeln!(out, "OK DONE count={} seconds={seconds:.3}", discords.len())?;
                        for d in &discords {
                            writeln!(out, "DISCORD m={} idx={} dist={:.6}", d.m, d.idx, d.nn_dist)?;
                        }
                        writeln!(out, "END")?;
                    }
                }
            }
            "METRICS" => {
                let (s, d, f, n) = self.metrics();
                writeln!(out, "OK METRICS jobs={s} done={d} failed={f} discords={n}")?;
            }
            "SHUTDOWN" => {
                writeln!(out, "OK BYE")?;
                return Ok(true);
            }
            other => bail!("unknown request {other:?}"),
        }
        Ok(false)
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn parse_spec<'a>(parts: impl Iterator<Item = &'a str>) -> Result<JobSpec> {
    let mut spec = JobSpec {
        dataset: String::new(),
        n: None,
        seed: 42,
        min_l: 0,
        max_l: 0,
        top_k: 1,
    };
    for p in parts {
        let (k, v) = p.split_once('=').ok_or_else(|| anyhow!("expected key=value, got {p:?}"))?;
        match k {
            "gen" => spec.dataset = v.to_string(),
            "n" => spec.n = Some(v.parse()?),
            "seed" => spec.seed = v.parse()?,
            "minl" => spec.min_l = v.parse()?,
            "maxl" => spec.max_l = v.parse()?,
            "topk" => spec.top_k = v.parse()?,
            other => bail!("unknown key {other:?}"),
        }
    }
    if spec.dataset.is_empty() || spec.min_l == 0 || spec.max_l == 0 {
        bail!("RUN requires gen=, minl=, maxl=");
    }
    Ok(spec)
}

fn worker_main(inner: Arc<Inner>) {
    // Each worker owns its engine (XLA executors are per-thread actors).
    let engine = match build_engine(&inner.engine_opts) {
        Ok(e) => e,
        Err(e) => {
            crate::log_error!("worker failed to build engine: {e}");
            return;
        }
    };
    loop {
        let job = {
            let mut q = inner.queue.lock().unwrap();
            loop {
                if inner.stop.load(Ordering::Relaxed) {
                    return;
                }
                if let Some(j) = q.pop() {
                    break j;
                }
                q = inner.cv.wait(q).unwrap();
            }
        };
        let (id, spec) = job;
        inner.jobs.lock().unwrap().insert(id, JobState::Running);
        let start = std::time::Instant::now();
        let outcome = run_job(&*engine, &spec);
        let state = match outcome {
            Ok(discords) => {
                inner.counters.done.fetch_add(1, Ordering::Relaxed);
                inner.counters.discords.fetch_add(discords.len() as u64, Ordering::Relaxed);
                JobState::Done { discords, seconds: start.elapsed().as_secs_f64() }
            }
            Err(e) => {
                inner.counters.failed.fetch_add(1, Ordering::Relaxed);
                JobState::Failed(e.to_string())
            }
        };
        inner.jobs.lock().unwrap().insert(id, state);
    }
}

fn run_job(engine: &dyn crate::engines::Engine, spec: &JobSpec) -> Result<Vec<Discord>> {
    let series: TimeSeries = match spec.n {
        Some(n) => registry::dataset_prefix(&spec.dataset, n, spec.seed)?.series,
        None => registry::dataset(&spec.dataset, spec.seed)?.series,
    };
    let cfg = MerlinConfig {
        min_l: spec.min_l,
        max_l: spec.max_l,
        top_k: spec.top_k,
        ..Default::default()
    };
    let res = Merlin::new(engine, cfg).run(&series)?;
    Ok(res.all_discords().copied().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec {
            dataset: "ecg2".into(),
            n: Some(2_000),
            seed: 7,
            min_l: 16,
            max_l: 20,
            top_k: 1,
        }
    }

    #[test]
    fn submit_and_wait() {
        let mut svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 2).unwrap();
        let id = svc.submit(spec());
        match svc.wait(id) {
            Some(JobState::Done { discords, .. }) => {
                assert_eq!(discords.len(), 5); // one per length 16..=20
            }
            other => panic!("unexpected state {other:?}"),
        }
        let (s, d, f, n) = svc.metrics();
        assert_eq!((s, d, f), (1, 1, 0));
        assert_eq!(n, 5);
        svc.shutdown();
    }

    #[test]
    fn bad_dataset_fails_cleanly() {
        let mut svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap();
        let id = svc.submit(JobSpec { dataset: "nope".into(), ..spec() });
        match svc.wait(id) {
            Some(JobState::Failed(msg)) => assert!(msg.contains("unknown dataset")),
            other => panic!("unexpected {other:?}"),
        }
        svc.shutdown();
    }

    #[test]
    fn parallel_jobs_complete() {
        let mut svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 4).unwrap();
        let ids: Vec<u64> = (0..6).map(|k| svc.submit(JobSpec { seed: k, ..spec() })).collect();
        for id in ids {
            match svc.wait(id) {
                Some(JobState::Done { .. }) => {}
                other => panic!("job {id}: {other:?}"),
            }
        }
        assert_eq!(svc.metrics().1, 6);
        svc.shutdown();
    }

    #[test]
    fn parse_spec_requires_fields() {
        assert!(parse_spec("gen=ecg minl=8".split_whitespace()).is_err());
        let s = parse_spec("gen=ecg minl=8 maxl=12 topk=2 seed=9".split_whitespace()).unwrap();
        assert_eq!(s.top_k, 2);
        assert_eq!(s.seed, 9);
        assert!(parse_spec("bogus".split_whitespace()).is_err());
    }

    #[test]
    fn tcp_protocol_end_to_end() {
        let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap();
        let svc = std::sync::Arc::new(std::sync::Mutex::new(svc));
        // Bind on an ephemeral port.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let svc2 = Arc::clone(&svc);
        let server = std::thread::spawn(move || {
            for stream in listener.incoming() {
                let stream = stream.unwrap();
                let done = svc2.lock().unwrap().handle_conn(stream);
                if done {
                    break;
                }
            }
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, "RUN gen=ecg2 n=2000 minl=16 maxl=17 topk=1 seed=3").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK JOB "), "{line}");
        let id: u64 = line.trim().rsplit(' ').next().unwrap().parse().unwrap();
        // Poll status until done.
        loop {
            writeln!(conn, "STATUS {id}").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            if line.starts_with("OK DONE") {
                // Read discord lines until END.
                let mut count = 0;
                loop {
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    if line.trim() == "END" {
                        break;
                    }
                    assert!(line.starts_with("DISCORD "), "{line}");
                    count += 1;
                }
                assert_eq!(count, 2);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        writeln!(conn, "METRICS").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("done=1"), "{line}");
        writeln!(conn, "SHUTDOWN").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "OK BYE");
        server.join().unwrap();
    }
}
