//! The anomaly-discovery job service: a fair-share *step* scheduler over
//! resumable [`MerlinSweep`]s, fronted by a line-oriented TCP protocol.
//!
//! The pre-scheduler service ran whole jobs to completion on dedicated
//! per-worker engines, so one 10M-point sweep head-of-line-blocked every
//! small request behind it.  The scheduler instead keeps a weighted-fair
//! run queue of *job ids* ([`RunQueue`]: deficit round robin over
//! per-tenant FIFOs, the PR-5 flat round robin surviving as the
//! [`SchedPolicy::RoundRobin`] baseline) and a fixed worker pool that
//! pulls **steps**: a worker claims a job, checks an engine/workspace
//! pair out of the shared [`EnginePool`] (keyed by job id, so a job's
//! seed cache and arenas come back warm — see `coordinator/lease.rs`),
//! advances the job's sweep by exactly one length, and requeues it at
//! the back of its tenant's FIFO.  Small jobs therefore complete while
//! large ones are still sweeping, a heavy tenant cannot starve light
//! ones (both are integration-tested), cancellation and deadlines take
//! effect at step granularity, and steady-state zero allocation holds
//! across interleaved tenants (`rust/tests/alloc_steady_state.rs`).
//! When several tenants queue *small* jobs, one worker round steps up
//! to [`ServiceConfig::batch_max`] of them through a single engine
//! lease (cross-tenant tile batching — `wfq(batched_rounds)=`).
//!
//! Admission is bounded everywhere: the run queue, job table, tenant
//! registry, and (in `coordinator/frontend.rs`) the connection count
//! all have caps, and crossing one yields a 429-style
//! `ERR BUSY retry_after=<ms>` instead of unbounded growth.
//!
//! Protocol (one request per line, responses `OK ...` / `ERR ...`):
//!
//! ```text
//! RUN gen=<dataset>|data=<upload> [n=<len>] [seed=<u64>] minl=<m> maxl=<m>
//!     [topk=<k>] [deadline=<ms>] [tenant=<name>] [weight=<w>]
//!   -> OK JOB <id>          (parameters are validated at parse time)
//!   -> ERR BUSY retry_after=<ms>  (run queue / job table / tenant
//!      registry at capacity — back off `retry_after` ms and resubmit)
//!   `tenant=` names the fair-share principal (default "default");
//!   `weight=` (1..=max_tenant_weight) sets its step share relative to
//!   other tenants — the latest submitted weight wins.
//! DATA name=<key> n=<count>
//!     ... then <count> whitespace-separated f64 values on following lines
//!   -> OK DATA <key> n=<count>
//! STATUS <id>
//!   -> OK QUEUED | OK RUNNING <done>/<total> | OK CANCELLED
//!    | OK FAILED <msg>
//!    | OK DONE count=<n> seconds=<s>; then one `DISCORD m= idx= dist=`
//!      line per discord and a final `END`
//! CANCEL <id>  -> OK CANCELLED <id>    (queued or mid-sweep jobs only)
//! FORGET <id>  -> OK FORGOTTEN <id>    (terminal jobs only; TTL eviction
//!                                       reclaims forgotten stragglers)
//! FORGET data=<name> -> OK FORGOTTEN data=<name>  (frees an upload slot)
//! RESUME <id> -> OK RESUMED <id>   (reload a checkpointed job; needs a
//!                checkpoint dir and no active job under that id)
//! METRICS
//!   -> OK METRICS jobs= done= failed= cancelled= discords= table=
//!      uploads= sched(steps/preempts/leases)=s/p/l lease(sticky/rebinds)=x/y
//!      faults(retries/panics)=r/p ckpt(saved/resumed)=c/u
//!      ckpt_rm_errs=e wfq(rejected/budget_exhausted/batched_rounds)=r/b/n
//! SHUTDOWN -> OK BYE (drains the scheduler: in-flight steps finish,
//!             queued jobs fail with "shutdown", workers are joined)
//! ```
//!
//! [`Service::serve`] drives connections through the evented front end
//! in `coordinator/frontend.rs` (non-blocking sockets, one reactor
//! thread, no per-connection threads); [`Service::handle_conn_public`]
//! keeps the blocking one-thread-per-connection path for embedders
//! that run their own accept loop.
//!
//! Robustness (see `rust/tests/chaos_faults.rs`):
//!
//! - **Checkpointing**: with [`ServiceConfig::checkpoint_dir`] set, a
//!   job's sweep state (plus engine seed-cache rows, for bit-identical
//!   resume) is durably saved every [`ServiceConfig::checkpoint_every`]
//!   completed lengths via atomic rename (`coordinator/checkpoint.rs`).
//!   Checkpoints are removed when a job completes or is cancelled and
//!   *kept* when it fails (panic, engine error, deadline, shutdown), so
//!   a restarted service auto-resumes interrupted jobs from its boot
//!   journal scan and `RESUME` can re-run post-mortem failures.
//! - **Fault isolation**: a panic inside a sweep step is caught and
//!   fails only that job; transient engine `Err`s are retried with
//!   backoff ([`ServiceConfig::step_retries`]); every service mutex is
//!   acquired through a poison-recovering helper (`util::sync`), so a
//!   panicking worker can never wedge the job table or run queue.
//! - **Housekeeping**: a dedicated heartbeat thread runs TTL eviction
//!   (including the kept-on-Failed checkpoints of evicted jobs) and
//!   deadline reaping every [`ServiceConfig::housekeep_interval`], so a
//!   quiescent service still converges — expiry does not wait for the
//!   next request or worker dequeue.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::util::loomsync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::util::loomsync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Result};

use super::checkpoint::{CheckpointStore, JobCheckpoint};
use super::config::EngineOptions;
use super::drag::Discord;
use super::lease::{EnginePool, Lease, PoolCounters};
use super::merlin::{MerlinConfig, MerlinSweep, SweepStatus};
use super::queue::{RunQueue, SchedPolicy, TenantShare};
use crate::core::series::TimeSeries;
use crate::engines::SeedRowSnapshot;
use crate::gen::registry;
use crate::util::sync::{lock_recover, wait_recover, wait_timeout_recover};

/// Tenant name used when a submission does not set one.
pub const DEFAULT_TENANT: &str = "default";

/// Scheduler + protocol limits (see [`Service::start_with`]).
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub engine_opts: EngineOptions,
    /// Step-worker threads.
    pub workers: usize,
    /// Engines in the shared lease pool (0 = one per worker).
    pub pool_capacity: usize,
    /// How long terminal (done/failed/cancelled) jobs stay queryable
    /// before TTL eviction drops them from the job table.
    pub job_ttl: Duration,
    /// Maximum client-uploaded series held at once (DATA verb).
    pub max_uploads: usize,
    /// Maximum points per uploaded series (DATA headers beyond it are
    /// rejected with `ERR` before any allocation; `Service::upload`
    /// enforces the same bound for embedders).
    pub max_upload_points: usize,
    /// Parse-time absurdity bound on `RUN n=`.
    pub max_series_len: usize,
    /// Where job checkpoints live (`None` = checkpointing off).
    pub checkpoint_dir: Option<PathBuf>,
    /// Save a checkpoint every K completed lengths (min 1).
    pub checkpoint_every: u64,
    /// Transient engine errors tolerated per step before the job fails.
    pub step_retries: usize,
    /// Base backoff between step retries (attempt k sleeps k * this).
    pub step_retry_backoff: Duration,
    /// Run-queue policy ([`SchedPolicy::WeightedFair`] by default;
    /// `RoundRobin` is the PR-5 flat baseline for benchmarks).
    pub sched_policy: SchedPolicy,
    /// Weight for submissions that do not set one (min 1).
    pub default_tenant_weight: u32,
    /// Largest accepted `weight=`; higher asks are rejected at parse.
    pub max_tenant_weight: u32,
    /// Queued step claims admitted before `RUN`/`submit` answers
    /// `ERR BUSY` (0 = unbounded, the legacy behavior).
    pub max_queued: usize,
    /// Job-table entries (any state) admitted before `ERR BUSY`
    /// (0 = unbounded).  TTL eviction frees capacity.
    pub max_jobs: usize,
    /// Distinct tenants admitted before `ERR BUSY` (0 = unbounded).
    pub max_tenants: usize,
    /// Concurrent connections the evented front end accepts before
    /// answering `ERR BUSY` and closing (0 = unbounded).
    pub max_conns: usize,
    /// Back-off hint carried in `ERR BUSY retry_after=<ms>`.
    pub retry_after: Duration,
    /// Heartbeat period for the housekeeper thread (TTL eviction +
    /// deadline reaping on a quiescent service).
    pub housekeep_interval: Duration,
    /// Jobs stepped per engine round: 1 disables batching; k > 1 lets
    /// up to k-1 *small* jobs from other tenants ride along on one
    /// lease checkout (their seed caches rebind — cheap for small
    /// series, and it amortizes pool traffic under many-tenant load).
    pub batch_max: usize,
    /// A job is "small" (batchable) when its series length is known at
    /// submit time and at most this many points.
    pub batch_small_points: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            engine_opts: EngineOptions::default(),
            workers: 2,
            pool_capacity: 0,
            job_ttl: Duration::from_secs(600),
            max_uploads: 64,
            max_upload_points: 4_000_000,
            max_series_len: 50_000_000,
            checkpoint_dir: None,
            checkpoint_every: 4,
            step_retries: 2,
            step_retry_backoff: Duration::from_millis(10),
            sched_policy: SchedPolicy::WeightedFair,
            default_tenant_weight: 1,
            max_tenant_weight: 64,
            max_queued: 1024,
            max_jobs: 4096,
            max_tenants: 256,
            max_conns: 1024,
            retry_after: Duration::from_millis(100),
            housekeep_interval: Duration::from_millis(200),
            batch_max: 4,
            batch_small_points: 100_000,
        }
    }
}

/// A submitted job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub dataset: String,
    pub n: Option<usize>,
    pub seed: u64,
    pub min_l: usize,
    pub max_l: usize,
    pub top_k: usize,
    /// Client-supplied series (DATA upload); takes precedence over
    /// `dataset`.
    pub series: Option<Arc<TimeSeries>>,
    /// Wall-clock budget from submission; exceeding it between steps
    /// fails the job with "deadline exceeded".
    pub deadline: Option<Duration>,
    /// Fair-share principal ([`DEFAULT_TENANT`] when empty).
    pub tenant: String,
    /// Step share relative to other tenants (0 = use
    /// [`ServiceConfig::default_tenant_weight`]; the latest submitted
    /// weight for a tenant wins).
    pub weight: u32,
}

impl Default for JobSpec {
    fn default() -> Self {
        Self {
            dataset: String::new(),
            n: None,
            seed: 42,
            min_l: 0,
            max_l: 0,
            top_k: 1,
            series: None,
            deadline: None,
            tenant: String::new(),
            weight: 0,
        }
    }
}

/// Job lifecycle.
#[derive(Clone, Debug)]
pub enum JobState {
    Queued,
    Running,
    Done { discords: Vec<Discord>, seconds: f64 },
    Failed(String),
    Cancelled,
}

impl JobState {
    fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }
}

struct Job {
    spec: JobSpec,
    state: JobState,
    /// The resumable sweep, parked here between steps (None before the
    /// first step and while a worker has it checked out).
    sweep: Option<MerlinSweep>,
    series: Option<Arc<TimeSeries>>,
    /// A worker currently holds this job's sweep.
    stepping: bool,
    /// Cancellation requested while stepping; honored at step end.
    cancel: bool,
    deadline_at: Option<Instant>,
    finished_at: Option<Instant>,
    /// (lengths completed, lengths total).
    progress: (usize, usize),
    /// Seed-cache rows from a checkpoint, imported into the leased
    /// engine on this job's next step (resume path only).
    pending_seed_rows: Option<Vec<SeedRowSnapshot>>,
    /// Index into the run queue's tenant registry (set at admission).
    tenant: usize,
    /// Batchable: series length known at submit time and within
    /// [`ServiceConfig::batch_small_points`].
    small: bool,
}

#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    done: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    discords: AtomicU64,
    steps: AtomicU64,
    preempts: AtomicU64,
    step_retries: AtomicU64,
    panics: AtomicU64,
    checkpoints: AtomicU64,
    resumes: AtomicU64,
    ckpt_remove_errs: AtomicU64,
    rejected: AtomicU64,
    batched_rounds: AtomicU64,
}

/// Scheduler observability snapshot (the `sched(...)=` metrics line).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedMetrics {
    /// Sweep steps executed.
    pub steps: u64,
    /// Steps after which a still-pending job was requeued behind the
    /// other runnable jobs (the fairness mechanism at work).
    pub preempts: u64,
    /// Jobs cancelled.
    pub cancelled: u64,
    /// Step attempts retried after a transient engine error.
    pub step_retries: u64,
    /// Panics caught and converted into single-job failures.
    pub panics: u64,
    /// Checkpoints durably saved.
    pub checkpoints: u64,
    /// Jobs rebuilt from checkpoints (boot scan + RESUME verb).
    pub resumes: u64,
    /// Checkpoint deletions that failed with a real I/O error (the file
    /// survives and will resurrect its job at next boot).
    pub ckpt_remove_errs: u64,
    /// Admission rejections answered with `ERR BUSY`: submissions over
    /// the queue/job-table/tenant bounds, and connections over
    /// [`ServiceConfig::max_conns`].
    pub rejected: u64,
    /// Times a tenant's step budget ran dry with work still queued —
    /// evidence the configured weights are actively shaping order.
    pub budget_exhausted: u64,
    /// Engine rounds that stepped more than one job on a single lease
    /// checkout (cross-tenant tile batching).
    pub batched_rounds: u64,
    /// Lease-pool traffic.
    pub lease: PoolCounters,
}

pub(crate) struct Inner {
    cfg: ServiceConfig,
    /// Weighted-fair run queue of job ids (guarded with `cv`).
    queue: Mutex<RunQueue>,
    jobs: Mutex<HashMap<u64, Job>>,
    cv: Condvar,
    counters: Counters,
    stop: AtomicBool,
    listener_stop: AtomicBool,
    next_id: AtomicU64,
    pool: EnginePool,
    uploads: Mutex<HashMap<String, Arc<TimeSeries>>>,
    /// Durable job checkpoints (None = checkpointing off).
    store: Option<CheckpointStore>,
    /// Housekeeper parking lot: flag = shutdown requested.  The flag is
    /// stored/read under `hk` with the notify inside the critical
    /// section (the PR-7 lost-wakeup discipline, same as `stop`/`cv`).
    hk: Mutex<bool>,
    hk_cv: Condvar,
    /// Connections currently open in the evented front end (gauge, and
    /// the connection-cap check in `frontend.rs`).
    pub(crate) open_conns: AtomicUsize,
}

/// The job service handle.
pub struct Service {
    inner: Arc<Inner>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Service {
    /// Start with `workers` step workers and a same-sized engine pool.
    pub fn start(engine_opts: EngineOptions, workers: usize) -> Result<Self> {
        Self::start_with(ServiceConfig { engine_opts, workers, ..Default::default() })
    }

    /// Start with explicit scheduler configuration.  With a checkpoint
    /// dir configured, the boot journal scan re-enqueues every job with
    /// a checkpoint on disk (jobs interrupted by a crash or shutdown);
    /// unreadable checkpoints are skipped with a warning, never fatal.
    pub fn start_with(cfg: ServiceConfig) -> Result<Self> {
        let workers = cfg.workers.max(1);
        let capacity = if cfg.pool_capacity == 0 { workers } else { cfg.pool_capacity };
        let pool = EnginePool::new(&cfg.engine_opts, capacity)?;
        let store = match &cfg.checkpoint_dir {
            Some(dir) => Some(CheckpointStore::new(dir.clone())?),
            None => None,
        };
        let policy = cfg.sched_policy;
        let inner = Arc::new(Inner {
            cfg,
            queue: Mutex::new(RunQueue::new(policy)),
            jobs: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            counters: Counters::default(),
            stop: AtomicBool::new(false),
            listener_stop: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            pool,
            uploads: Mutex::new(HashMap::new()),
            store,
            hk: Mutex::new(false),
            hk_cv: Condvar::new(),
            open_conns: AtomicUsize::new(0),
        });
        // Resume before the workers exist: no lock contention, and the
        // first worker to start finds the recovered queue ready.
        if let Some(store) = &inner.store {
            for id in store.scan() {
                let outcome = store.load(id).and_then(|c| resume_job(&inner, c));
                if let Err(e) = outcome {
                    crate::log_warn!("skipping checkpoint for job {id}: {e:#}");
                }
            }
        }
        let mut handles = Vec::new();
        for w in 0..workers {
            let inner = Arc::clone(&inner);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("palmad-worker-{w}"))
                    .spawn(move || worker_main(inner))
                    .map_err(|e| anyhow!("spawn worker: {e}"))?,
            );
        }
        // The housekeeper heartbeat: TTL eviction + deadline reaping on
        // a fixed cadence, so a quiescent service (zero traffic, idle
        // workers) still expires jobs (satellite bugfix — previously
        // eviction only ran piggybacked on submit/METRICS).
        {
            let inner = Arc::clone(&inner);
            handles.push(
                std::thread::Builder::new()
                    .name("palmad-housekeeper".into())
                    .spawn(move || housekeeper_main(inner))
                    .map_err(|e| anyhow!("spawn housekeeper: {e}"))?,
            );
        }
        Ok(Self { inner, workers: Mutex::new(handles) })
    }

    /// Submit a job; returns its id, or an admission-control error
    /// (`BUSY retry_after=<ms>`) when the run queue, job table, or
    /// tenant registry is at capacity.  Submission also runs a TTL
    /// sweep over the job table so terminal entries cannot pile up
    /// between housekeeper heartbeats.
    ///
    /// A submission racing `shutdown()` returns `Ok(id)` with the job
    /// already `Failed("shutdown")`: the stop flag is checked *under
    /// the queue lock* (the same lock `shutdown` holds while setting
    /// it — PR-7 lost-wakeup discipline), so the job either reaches
    /// the queue before the drain clears it, or never reaches it and
    /// is failed here.  Either way `wait` terminates.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        self.evict_expired();
        let cfg = &self.inner.cfg;
        let busy = |why: &str| {
            self.inner.counters.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow!("BUSY retry_after={} ({why})", cfg.retry_after.as_millis())
        };
        let tenant_name =
            if spec.tenant.is_empty() { DEFAULT_TENANT } else { spec.tenant.as_str() };
        let weight = if spec.weight == 0 {
            cfg.default_tenant_weight.max(1)
        } else {
            spec.weight.min(cfg.max_tenant_weight.max(1))
        };
        let known_n = spec.series.as_ref().map(|s| s.len()).or(spec.n);
        let small = known_n.is_some_and(|n| n <= cfg.batch_small_points);
        // ---- Admission gate under the queue lock: bounded queue and
        // tenant registry.  Registration happens here too, so the
        // tenant index is known before the job is published.  (The
        // queue lock is never held across the jobs lock — the worker's
        // park path nests jobs→queue, and nesting queue→jobs here
        // would be a classic ABBA deadlock.)
        let tenant = {
            let mut q = lock_recover(&self.inner.queue);
            if cfg.max_queued > 0 && q.len() >= cfg.max_queued {
                return Err(busy("run queue full"));
            }
            if cfg.max_tenants > 0
                && q.lookup(tenant_name).is_none()
                && q.tenant_count() >= cfg.max_tenants
            {
                return Err(busy("tenant registry full"));
            }
            q.register(tenant_name, weight)
        };
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let total = spec.max_l.saturating_sub(spec.min_l) + 1;
        let job = Job {
            deadline_at: spec.deadline.map(|d| Instant::now() + d),
            series: spec.series.clone(),
            spec,
            state: JobState::Queued,
            sweep: None,
            stepping: false,
            cancel: false,
            finished_at: None,
            progress: (0, total),
            pending_seed_rows: None,
            tenant,
            small,
        };
        // ---- Job-table gate + publish.  The job must be in the table
        // before its id is queued: a worker that pops an id without a
        // table entry drops it as forgotten.
        {
            let mut jobs = lock_recover(&self.inner.jobs);
            if cfg.max_jobs > 0 && jobs.len() >= cfg.max_jobs {
                return Err(busy("job table full"));
            }
            jobs.insert(id, job);
        }
        self.inner.counters.submitted.fetch_add(1, Ordering::Relaxed);
        // ---- Enqueue, with the stop flag checked under the queue
        // lock.  `shutdown()` sets `stop` while holding this mutex and
        // clears the queue afterwards, so exactly one of two serialized
        // orders happens: (a) we enqueue first and the drain fails the
        // job, or (b) we observe `stop` and fail it ourselves.  The
        // pre-PR-9 check-outside-the-lock left a third order where the
        // job stayed Queued forever (loom: `service_submit_vs_shutdown`).
        let stopped = {
            let mut q = lock_recover(&self.inner.queue);
            if self.inner.stop.load(Ordering::Acquire) {
                true
            } else {
                q.push(tenant, id, small);
                self.inner.cv.notify_one();
                false
            }
        };
        if stopped {
            let mut jobs = lock_recover(&self.inner.jobs);
            if let Some(job) = jobs.get_mut(&id) {
                if !job.state.is_terminal() {
                    finalize(job, JobState::Failed("shutdown".into()), &self.inner.counters);
                }
            }
        }
        Ok(id)
    }

    /// Current state of a job.
    ///
    /// A queued job whose deadline has already passed is reaped *here*
    /// (satellite bugfix): under a saturated queue no worker may
    /// dequeue it for a long time, and `STATUS`/`wait` must not report
    /// a deadline-dead job as `QUEUED` in the meantime.  Stepping jobs
    /// are left alone — the worker owns their transition and observes
    /// the deadline at the step boundary.
    pub fn status(&self, id: u64) -> Option<JobState> {
        let mut jobs = lock_recover(&self.inner.jobs);
        let job = jobs.get_mut(&id)?;
        if !job.state.is_terminal()
            && !job.stepping
            && job.deadline_at.is_some_and(|d| Instant::now() > d)
        {
            finalize(job, JobState::Failed("deadline exceeded".into()), &self.inner.counters);
        }
        Some(job.state.clone())
    }

    /// (lengths completed, lengths total) for a job.
    pub fn progress(&self, id: u64) -> Option<(usize, usize)> {
        lock_recover(&self.inner.jobs).get(&id).map(|j| j.progress)
    }

    /// Block until the job leaves Queued/Running.
    pub fn wait(&self, id: u64) -> Option<JobState> {
        loop {
            match self.status(id) {
                Some(JobState::Queued) | Some(JobState::Running) => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                other => return other,
            }
        }
    }

    /// Cancel a queued or running job.  A job mid-step finishes its
    /// current length first; the cancellation lands at the step
    /// boundary.
    pub fn cancel(&self, id: u64) -> Result<()> {
        let mut jobs = lock_recover(&self.inner.jobs);
        let job = jobs.get_mut(&id).ok_or_else(|| anyhow!("no such job {id}"))?;
        match job.state {
            JobState::Queued | JobState::Running => {
                if job.stepping {
                    job.cancel = true;
                } else {
                    finalize(job, JobState::Cancelled, &self.inner.counters);
                    // A cancelled job must not resurrect at next boot.
                    if let Some(store) = &self.inner.store {
                        remove_checkpoint(store, &self.inner.counters, id);
                    }
                }
                Ok(())
            }
            _ => bail!("job {id} already finished"),
        }
    }

    /// Drop a terminal job from the table immediately (TTL eviction
    /// handles the rest).
    pub fn forget(&self, id: u64) -> Result<()> {
        let mut jobs = lock_recover(&self.inner.jobs);
        match jobs.get(&id) {
            None => bail!("no such job {id}"),
            Some(j) if !j.state.is_terminal() => {
                bail!("job {id} is still active; CANCEL it first")
            }
            Some(_) => {
                jobs.remove(&id);
                // FORGET is an explicit discard: drop the checkpoint
                // too (a kept Failed checkpoint stays resumable only
                // while the client still wants the job).
                if let Some(store) = &self.inner.store {
                    remove_checkpoint(store, &self.inner.counters, id);
                }
                Ok(())
            }
        }
    }

    /// Drop terminal jobs older than [`ServiceConfig::job_ttl`], along
    /// with their checkpoints.  Runs on every submit and METRICS, and
    /// from the housekeeper heartbeat.
    pub fn evict_expired(&self) {
        evict_expired_inner(&self.inner);
    }

    /// Jobs currently in the table (any state).
    pub fn job_count(&self) -> usize {
        lock_recover(&self.inner.jobs).len()
    }

    /// Store a client-supplied series under `name` (replaces an
    /// existing upload of the same name).
    pub fn upload(&self, name: &str, series: TimeSeries) -> Result<()> {
        let max = self.inner.cfg.max_upload_points;
        if series.is_empty() || series.len() > max {
            bail!("upload {name:?} has {} points (allowed 1..={max})", series.len());
        }
        let mut up = lock_recover(&self.inner.uploads);
        if !up.contains_key(name) && up.len() >= self.inner.cfg.max_uploads {
            bail!("upload table full ({} series); re-upload an existing name", up.len());
        }
        up.insert(name.to_string(), Arc::new(series));
        Ok(())
    }

    /// Fetch an uploaded series.
    pub fn uploaded(&self, name: &str) -> Option<Arc<TimeSeries>> {
        lock_recover(&self.inner.uploads).get(name).cloned()
    }

    /// Drop an uploaded series (`FORGET data=<name>`) — the eviction
    /// path that keeps the capped upload table reusable.  Jobs already
    /// holding the series keep their `Arc` until they finish.
    pub fn forget_upload(&self, name: &str) -> Result<()> {
        match lock_recover(&self.inner.uploads).remove(name) {
            Some(_) => Ok(()),
            None => bail!("no uploaded series {name:?}"),
        }
    }

    /// Uploaded series currently held.
    pub fn upload_count(&self) -> usize {
        lock_recover(&self.inner.uploads).len()
    }

    /// (submitted, done, failed, discords)
    pub fn metrics(&self) -> (u64, u64, u64, u64) {
        let c = &self.inner.counters;
        (
            c.submitted.load(Ordering::Relaxed),
            c.done.load(Ordering::Relaxed),
            c.failed.load(Ordering::Relaxed),
            c.discords.load(Ordering::Relaxed),
        )
    }

    /// Scheduler observability counters.
    pub fn sched_metrics(&self) -> SchedMetrics {
        let c = &self.inner.counters;
        SchedMetrics {
            steps: c.steps.load(Ordering::Relaxed),
            preempts: c.preempts.load(Ordering::Relaxed),
            cancelled: c.cancelled.load(Ordering::Relaxed),
            step_retries: c.step_retries.load(Ordering::Relaxed),
            panics: c.panics.load(Ordering::Relaxed),
            checkpoints: c.checkpoints.load(Ordering::Relaxed),
            resumes: c.resumes.load(Ordering::Relaxed),
            ckpt_remove_errs: c.ckpt_remove_errs.load(Ordering::Relaxed),
            rejected: c.rejected.load(Ordering::Relaxed),
            budget_exhausted: lock_recover(&self.inner.queue).budget_exhausted(),
            batched_rounds: c.batched_rounds.load(Ordering::Relaxed),
            lease: self.inner.pool.counters(),
        }
    }

    /// Per-tenant scheduling stats (registration order): name, weight,
    /// steps served, jobs queued.  The fairness observable for the
    /// load generator and the weighted-share tests.
    pub fn tenant_shares(&self) -> Vec<TenantShare> {
        lock_recover(&self.inner.queue).shares()
    }

    /// Connections currently open in the evented front end.
    pub fn open_conns(&self) -> usize {
        self.inner.open_conns.load(Ordering::Relaxed)
    }

    /// Frontend admission: register a new connection against
    /// [`ServiceConfig::max_conns`].  `false` means at capacity — the
    /// caller replies `ERR BUSY` and closes (counted in `rejected`).
    pub(crate) fn conn_opened(&self) -> bool {
        let max = self.inner.cfg.max_conns;
        let prev = self.inner.open_conns.fetch_add(1, Ordering::Relaxed);
        if max > 0 && prev >= max {
            self.inner.open_conns.fetch_sub(1, Ordering::Relaxed);
            self.inner.counters.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        true
    }

    /// Frontend bookkeeping: a connection admitted by
    /// [`Self::conn_opened`] has closed.
    pub(crate) fn conn_closed(&self) {
        self.inner.open_conns.fetch_sub(1, Ordering::Relaxed);
    }

    /// The `retry_after` hint for frontend-side BUSY replies.
    pub(crate) fn retry_after_ms(&self) -> u64 {
        self.inner.cfg.retry_after.as_millis() as u64
    }

    /// Has some path requested the accept loop to stop?
    pub(crate) fn listener_stopped(&self) -> bool {
        self.inner.listener_stop.load(Ordering::Acquire)
    }

    /// Ask the accept loop to stop (SHUTDOWN processing).
    pub(crate) fn stop_listener(&self) {
        self.inner.listener_stop.store(true, Ordering::Release);
    }

    /// Rebuild a checkpointed job and enqueue it (the `RESUME` verb).
    /// Errors if checkpointing is off, the checkpoint is missing or
    /// corrupt, or a job with that id is still active.
    pub fn resume(&self, id: u64) -> Result<u64> {
        if self.inner.stop.load(Ordering::Acquire) {
            bail!("service is shutting down");
        }
        let store = self
            .inner
            .store
            .as_ref()
            .ok_or_else(|| anyhow!("checkpointing is not enabled (no checkpoint dir)"))?;
        let ckpt = store.load(id)?;
        let id = resume_job(&self.inner, ckpt)?;
        self.inner.cv.notify_one();
        Ok(id)
    }

    /// Stop the scheduler gracefully (idempotent): workers finish their
    /// in-flight steps and are joined; every job still queued or parked
    /// mid-sweep is marked `Failed("shutdown")` rather than silently
    /// lost.
    pub fn shutdown(&self) {
        {
            // Set `stop` *while holding the queue mutex*.  Workers check
            // `stop` and then wait on `cv` under this mutex; storing the
            // flag (and notifying) without it opens a lost-wakeup window:
            // a worker that has just observed `stop == false` on an empty
            // queue would miss a bare `notify_all` fired before it parks,
            // sleep forever, and wedge the `join` below.  Holding the
            // lock means every worker is either already parked (the
            // notify reaches it) or has not yet taken the lock (it will
            // observe `stop == true` once it does).  The loom model
            // `service_shutdown_no_lost_wakeup` pins this; dropping this
            // guard reintroduces a deadlock the model finds in seconds.
            // The same lock also serializes against `submit`'s enqueue
            // (`service_submit_vs_shutdown`): any submit that beat this
            // store is already queued and drains below; any later one
            // observes `stop` under the lock and self-fails.
            let _q = lock_recover(&self.inner.queue);
            self.inner.stop.store(true, Ordering::Release);
            self.inner.cv.notify_all();
        }
        {
            // Same discipline for the housekeeper's parking lot.
            let mut hk = lock_recover(&self.inner.hk);
            *hk = true;
            self.inner.hk_cv.notify_all();
        }
        let handles: Vec<_> = lock_recover(&self.workers).drain(..).collect();
        for h in handles {
            // ok-drop: join error = worker panicked; the panic was already
            // counted (faults panics=) and its job finalized as Failed, and
            // shutdown must drain the rest regardless.
            let _ = h.join();
        }
        lock_recover(&self.inner.queue).clear();
        let mut jobs = lock_recover(&self.inner.jobs);
        for job in jobs.values_mut() {
            if !job.state.is_terminal() {
                finalize(job, JobState::Failed("shutdown".into()), &self.inner.counters);
            }
        }
    }

    /// Serve the TCP protocol until a SHUTDOWN request arrives, through
    /// the evented front end (`coordinator/frontend.rs`): one reactor
    /// thread multiplexes every connection over non-blocking sockets,
    /// so N idle clients cost N sockets, not N threads.  Binding port 0
    /// picks an ephemeral port, printed as a parseable `LISTENING
    /// <addr>` line for scripts (`scripts/ci.sh --service-smoke`).
    pub fn serve(&self, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        println!("LISTENING {local}");
        // ok-drop: best-effort flush so script parsers see the LISTENING
        // line promptly; a broken stdout must not kill the service.
        std::io::stdout().flush().ok();
        crate::log_info!("palmad service listening on {local}");
        super::frontend::serve_listener(self, listener)
    }

    /// Public wrapper over [`Self::handle_conn`] for embedders that run
    /// their own accept loop (see `examples/serve_demo.rs`).  Returns
    /// true if the connection requested SHUTDOWN; draining the
    /// scheduler is then the embedder's call (`Service::shutdown`).
    pub fn handle_conn_public(&self, stream: TcpStream) -> bool {
        self.handle_conn(stream)
    }

    /// Handle one connection with blocking I/O; returns true if
    /// SHUTDOWN was requested.  [`Self::serve`] does *not* use this —
    /// the evented front end multiplexes connections instead — but the
    /// path stays for embedders with their own accept loop and shares
    /// [`Self::execute_line`] with the reactor, so both speak byte-for-
    /// byte the same protocol.
    ///
    /// Reads run with a short timeout so an idle connection notices a
    /// SHUTDOWN initiated elsewhere and exits instead of pinning the
    /// embedder's accept scope open until the client hangs up.
    fn handle_conn(&self, stream: TcpStream) -> bool {
        let peer = stream.peer_addr().ok();
        // ok-drop: best-effort timeout; without it an idle connection just
        // lingers until the client hangs up — degraded, not wrong.
        let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
        let mut reader = BufReader::new(match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return false,
        });
        let mut out = stream;
        let mut line = String::new();
        loop {
            line.clear();
            // Retry timeouts without clearing: a timeout mid-line keeps
            // the partial bytes already appended to `line`.
            loop {
                match reader.read_line(&mut line) {
                    Ok(0) => return false,
                    Ok(_) => break,
                    Err(e) if is_timeout(&e) => {
                        if self.inner.listener_stop.load(Ordering::Acquire) {
                            return false;
                        }
                    }
                    Err(_) => return false,
                }
            }
            let req = line.trim().to_string();
            if req.is_empty() {
                continue;
            }
            crate::log_debug!("request from {peer:?}: {req}");
            match self.execute_line(&req) {
                LineOutcome::Reply(text) => {
                    if out.write_all(text.as_bytes()).is_err() {
                        return false;
                    }
                }
                LineOutcome::Shutdown(text) => {
                    // ok-drop: the client may hang up right after asking;
                    // the shutdown itself is the caller's job either way.
                    let _ = out.write_all(text.as_bytes());
                    return true;
                }
                LineOutcome::BeginData(mut ing) => {
                    let reply = loop {
                        line.clear();
                        match read_data_line(&mut reader, &mut line, &self.inner.listener_stop)
                        {
                            Ok(0) => break ing.eof_reply(),
                            Ok(_) => {
                                if ing.feed_line(&line) {
                                    break ing.finish(self);
                                }
                            }
                            Err(_) => return false,
                        }
                    };
                    if out.write_all(reply.as_bytes()).is_err() {
                        return false;
                    }
                }
            }
        }
    }

    /// Execute one protocol line and produce its reply — the single
    /// protocol implementation shared by the blocking path
    /// ([`Self::handle_conn`]) and the evented front end
    /// (`coordinator/frontend.rs`).  Never blocks on the connection:
    /// multi-line ingestion (DATA) is returned as a [`DataIngest`]
    /// state machine for the caller to feed.
    pub(crate) fn execute_line(&self, req: &str) -> LineOutcome {
        match self.execute_line_inner(req) {
            Ok(out) => out,
            Err(e) => LineOutcome::Reply(format!("ERR {e}\n")),
        }
    }

    fn execute_line_inner(&self, req: &str) -> Result<LineOutcome> {
        use std::fmt::Write as _;
        let mut parts = req.split_whitespace();
        let mut out = String::new();
        match parts.next().unwrap_or("") {
            "RUN" => {
                if self.inner.stop.load(Ordering::Acquire) {
                    bail!("service is shutting down");
                }
                let (mut spec, data_key) = parse_run_parts(parts)?;
                if let Some(key) = data_key {
                    spec.series = Some(
                        self.uploaded(&key)
                            .ok_or_else(|| anyhow!("no uploaded series {key:?} (see DATA)"))?,
                    );
                }
                validate_spec(&spec, &self.inner.cfg)?;
                let id = self.submit(spec)?;
                writeln!(out, "OK JOB {id}")?;
            }
            "DATA" => {
                let (name, n) = parse_data_header(parts)?;
                let max = self.inner.cfg.max_upload_points;
                if n == 0 || n > max {
                    // The client sends its values regardless of our
                    // verdict, so consume them (sanely bounded claims
                    // only) before erroring — otherwise every value
                    // line would be misread as a command and the
                    // connection would desynchronize permanently.
                    if n > 0 && n <= max.saturating_mul(4) {
                        return Ok(LineOutcome::BeginData(DataIngest::rejecting(
                            n,
                            format!("DATA n={n} out of range (1..={max})"),
                        )));
                    }
                    bail!("DATA n={n} out of range (1..={max})");
                }
                return Ok(LineOutcome::BeginData(DataIngest::accepting(name, n)));
            }
            "STATUS" => {
                let id: u64 = parts.next().ok_or_else(|| anyhow!("STATUS <id>"))?.parse()?;
                match self.status(id) {
                    None => bail!("no such job {id}"),
                    Some(JobState::Queued) => writeln!(out, "OK QUEUED")?,
                    Some(JobState::Running) => {
                        let (done, total) = self.progress(id).unwrap_or((0, 0));
                        writeln!(out, "OK RUNNING {done}/{total}")?;
                    }
                    Some(JobState::Cancelled) => writeln!(out, "OK CANCELLED")?,
                    Some(JobState::Failed(e)) => writeln!(out, "OK FAILED {e}")?,
                    Some(JobState::Done { discords, seconds }) => {
                        writeln!(out, "OK DONE count={} seconds={seconds:.3}", discords.len())?;
                        for d in &discords {
                            writeln!(out, "DISCORD m={} idx={} dist={:.6}", d.m, d.idx, d.nn_dist)?;
                        }
                        writeln!(out, "END")?;
                    }
                }
            }
            "CANCEL" => {
                let id: u64 = parts.next().ok_or_else(|| anyhow!("CANCEL <id>"))?.parse()?;
                self.cancel(id)?;
                writeln!(out, "OK CANCELLED {id}")?;
            }
            "FORGET" => {
                let arg =
                    parts.next().ok_or_else(|| anyhow!("FORGET <id> | FORGET data=<name>"))?;
                if let Some(name) = arg.strip_prefix("data=") {
                    self.forget_upload(name)?;
                    writeln!(out, "OK FORGOTTEN data={name}")?;
                } else {
                    let id: u64 = arg.parse()?;
                    self.forget(id)?;
                    writeln!(out, "OK FORGOTTEN {id}")?;
                }
            }
            "RESUME" => {
                let id: u64 = parts.next().ok_or_else(|| anyhow!("RESUME <id>"))?.parse()?;
                let id = self.resume(id)?;
                writeln!(out, "OK RESUMED {id}")?;
            }
            "METRICS" => {
                self.evict_expired();
                let (s, d, f, n) = self.metrics();
                let sm = self.sched_metrics();
                writeln!(
                    out,
                    "OK METRICS jobs={s} done={d} failed={f} cancelled={} discords={n} \
                     table={} uploads={} sched(steps/preempts/leases)={}/{}/{} \
                     lease(sticky/rebinds)={}/{} faults(retries/panics)={}/{} \
                     ckpt(saved/resumed)={}/{} ckpt_rm_errs={} \
                     wfq(rejected/budget_exhausted/batched_rounds)={}/{}/{}",
                    sm.cancelled,
                    self.job_count(),
                    self.upload_count(),
                    sm.steps,
                    sm.preempts,
                    sm.lease.leases,
                    sm.lease.sticky_hits,
                    sm.lease.rebinds,
                    sm.step_retries,
                    sm.panics,
                    sm.checkpoints,
                    sm.resumes,
                    sm.ckpt_remove_errs,
                    sm.rejected,
                    sm.budget_exhausted,
                    sm.batched_rounds,
                )?;
            }
            "SHUTDOWN" => {
                return Ok(LineOutcome::Shutdown("OK BYE\n".into()));
            }
            other => bail!("unknown request {other:?}"),
        }
        Ok(LineOutcome::Reply(out))
    }
}

/// What executing one protocol line asks the connection driver to do.
pub(crate) enum LineOutcome {
    /// Write this complete reply (newline-terminated, possibly
    /// multi-line) and read the next request line.
    Reply(String),
    /// Switch the connection into DATA ingestion: feed value lines to
    /// the state machine until [`DataIngest::feed_line`] reports
    /// completion, then write [`DataIngest::finish`]'s reply.
    BeginData(DataIngest),
    /// Write this reply, then initiate service shutdown and close.
    Shutdown(String),
}

/// Incremental DATA-upload ingestion, decoupled from any I/O: both the
/// blocking connection path and the reactor feed it one line at a
/// time.  Counting consumed tokens (even rejected or unparsable ones)
/// keeps the request stream in sync — the client sends exactly the
/// announced number of values no matter our verdict.
pub(crate) struct DataIngest {
    name: String,
    n: usize,
    values: Vec<f64>,
    /// First unparsable token (consumed as NaN, reported at the end).
    bad: Option<String>,
    /// Drain-then-error mode: consume the announced values, then reply
    /// with this error instead of storing anything.
    reject: Option<String>,
    /// Whitespace-separated tokens consumed so far.
    seen: usize,
}

impl DataIngest {
    fn accepting(name: String, n: usize) -> Self {
        Self { name, n, values: Vec::with_capacity(n), bad: None, reject: None, seen: 0 }
    }

    fn rejecting(n: usize, err: String) -> Self {
        Self {
            name: String::new(),
            n,
            values: Vec::new(),
            bad: None,
            reject: Some(err),
            seen: 0,
        }
    }

    /// Feed one line of whitespace-separated values; returns true once
    /// the announced count has been consumed.
    pub(crate) fn feed_line(&mut self, line: &str) -> bool {
        for tok in line.split_whitespace() {
            if self.done() {
                break;
            }
            self.seen += 1;
            if self.reject.is_some() {
                continue;
            }
            match tok.parse::<f64>() {
                Ok(v) => self.values.push(v),
                Err(_) => {
                    // Keep consuming to stay in sync; remember the
                    // first offender and count it toward `n`.
                    if self.bad.is_none() {
                        self.bad = Some(tok.to_string());
                    }
                    self.values.push(f64::NAN);
                }
            }
        }
        self.done()
    }

    pub(crate) fn done(&self) -> bool {
        self.seen >= self.n
    }

    /// Reply for a connection that hit EOF mid-ingestion.
    pub(crate) fn eof_reply(&self) -> String {
        match &self.reject {
            Some(e) => format!("ERR {e}\n"),
            None => format!("ERR DATA truncated at {}/{} values\n", self.seen, self.n),
        }
    }

    /// Complete the ingestion: store the upload (or report the
    /// deferred rejection) and produce the protocol reply.
    pub(crate) fn finish(&mut self, svc: &Service) -> String {
        if let Some(e) = &self.reject {
            return format!("ERR {e}\n");
        }
        if let Some(tok) = &self.bad {
            return format!("ERR DATA bad value {tok:?}\n");
        }
        let name = std::mem::take(&mut self.name);
        let values = std::mem::take(&mut self.values);
        let n = self.n;
        match svc.upload(&name, TimeSeries::new(name.as_str(), values)) {
            Ok(()) => format!("OK DATA {name} n={n}\n"),
            Err(e) => format!("ERR {e}\n"),
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Delete a job's checkpoint, counting (and logging) real I/O failures
/// instead of dropping them: an undeletable checkpoint resurrects its
/// job at next boot, so the `ckpt_rm_errs=` METRICS segment is the
/// operator's tell that the store dir needs attention.
fn remove_checkpoint(store: &CheckpointStore, counters: &Counters, id: u64) {
    if let Err(e) = store.remove(id) {
        counters.ckpt_remove_errs.fetch_add(1, Ordering::Relaxed);
        crate::log_warn!("checkpoint remove for job {id} failed: {e}");
    }
}

/// Mark a job terminal, bump the matching counters, and release its
/// per-job state (sweep, series).
fn finalize(job: &mut Job, state: JobState, counters: &Counters) {
    match &state {
        JobState::Done { discords, .. } => {
            counters.done.fetch_add(1, Ordering::Relaxed);
            counters.discords.fetch_add(discords.len() as u64, Ordering::Relaxed);
        }
        JobState::Failed(_) => {
            counters.failed.fetch_add(1, Ordering::Relaxed);
        }
        JobState::Cancelled => {
            counters.cancelled.fetch_add(1, Ordering::Relaxed);
        }
        JobState::Queued | JobState::Running => {}
    }
    job.state = state;
    job.sweep = None;
    job.series = None;
    // The spec holds a second Arc to an uploaded series (set at submit);
    // drop it too, or a terminal job pins the buffer for its whole TTL.
    job.spec.series = None;
    job.stepping = false;
    job.finished_at = Some(Instant::now());
}

/// Parse `RUN` key=value pairs; returns the spec plus the `data=`
/// upload key (resolved by the caller, which owns the upload table).
fn parse_run_parts<'a>(
    parts: impl Iterator<Item = &'a str>,
) -> Result<(JobSpec, Option<String>)> {
    let mut spec = JobSpec::default();
    let mut data_key: Option<String> = None;
    for p in parts {
        let (k, v) = p.split_once('=').ok_or_else(|| anyhow!("expected key=value, got {p:?}"))?;
        match k {
            "gen" => spec.dataset = v.to_string(),
            "data" => data_key = Some(v.to_string()),
            "n" => spec.n = Some(v.parse()?),
            "seed" => spec.seed = v.parse()?,
            "minl" => spec.min_l = v.parse()?,
            "maxl" => spec.max_l = v.parse()?,
            "topk" => spec.top_k = v.parse()?,
            "deadline" => spec.deadline = Some(Duration::from_millis(v.parse()?)),
            "tenant" => spec.tenant = v.to_string(),
            "weight" => spec.weight = v.parse()?,
            other => bail!("unknown key {other:?}"),
        }
    }
    if data_key.is_some() && !spec.dataset.is_empty() {
        bail!("RUN takes gen= or data=, not both");
    }
    if data_key.is_none() && spec.dataset.is_empty() {
        bail!("RUN requires gen=<dataset> or data=<upload>");
    }
    if spec.min_l == 0 || spec.max_l == 0 {
        bail!("RUN requires minl= and maxl=");
    }
    Ok((spec, data_key))
}

/// Parse-time request validation: reject impossible jobs with `ERR`
/// instead of letting a worker thread fail them mid-run.
fn validate_spec(spec: &JobSpec, cfg: &ServiceConfig) -> Result<()> {
    if spec.min_l < 4 {
        bail!("minl must be >= 4 (got {})", spec.min_l);
    }
    if spec.min_l > spec.max_l {
        bail!("minl {} > maxl {}", spec.min_l, spec.max_l);
    }
    if spec.top_k == 0 {
        bail!("topk must be >= 1");
    }
    if let Some(n) = spec.n {
        if n > cfg.max_series_len {
            bail!("n={n} exceeds the service limit {}", cfg.max_series_len);
        }
    }
    if spec.weight > cfg.max_tenant_weight {
        bail!("weight={} exceeds the limit {}", spec.weight, cfg.max_tenant_weight);
    }
    if spec.tenant.len() > 64 {
        bail!("tenant name too long ({} chars, max 64)", spec.tenant.len());
    }
    // Uploaded series have a known length; generated ones only when n=
    // is explicit (dataset defaults are checked by the first step).
    let known_n = spec.series.as_ref().map(|s| s.len()).or(spec.n);
    if let Some(n) = known_n {
        if n < 2 * spec.max_l {
            bail!("series too short (n={n}) for maxl={} (need n >= 2*maxl)", spec.max_l);
        }
    }
    Ok(())
}

fn parse_data_header<'a>(parts: impl Iterator<Item = &'a str>) -> Result<(String, usize)> {
    let mut name: Option<String> = None;
    let mut n: Option<usize> = None;
    for p in parts {
        let (k, v) = p.split_once('=').ok_or_else(|| anyhow!("expected key=value, got {p:?}"))?;
        match k {
            "name" => name = Some(v.to_string()),
            "n" => n = Some(v.parse()?),
            other => bail!("unknown key {other:?}"),
        }
    }
    match (name, n) {
        (Some(name), Some(n)) => Ok((name, n)),
        _ => bail!("DATA requires name= and n="),
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// `read_line` that rides out the connection's read timeout (retrying
/// with the partial bytes kept in `line`) unless `stop` flips.
fn read_data_line(
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
    stop: &AtomicBool,
) -> Result<usize> {
    loop {
        match reader.read_line(line) {
            Ok(n) => return Ok(n),
            Err(e) if is_timeout(&e) => {
                if stop.load(Ordering::Acquire) {
                    bail!("shutdown");
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// The housekeeper heartbeat: every [`ServiceConfig::housekeep_interval`]
/// run TTL eviction and deadline reaping, so expiry never waits for
/// traffic.  Parks on `hk`/`hk_cv` (flag stored under the mutex with
/// the notify inside the critical section, like `stop`/`cv`) so
/// shutdown wakes it promptly instead of waiting out the interval.
fn housekeeper_main(inner: Arc<Inner>) {
    loop {
        {
            let g = lock_recover(&inner.hk);
            if *g {
                return;
            }
            let (g, _timed_out) =
                wait_timeout_recover(&inner.hk_cv, g, inner.cfg.housekeep_interval);
            if *g {
                return;
            }
        }
        evict_expired_inner(&inner);
        reap_deadlines(&inner);
    }
}

/// Drop terminal jobs older than the TTL — and their checkpoints.
/// Before PR 9 a kept-on-Failed checkpoint outlived its TTL-evicted
/// job indefinitely (it would resurrect at every boot); eviction now
/// mirrors FORGET and removes the file with the table entry.
fn evict_expired_inner(inner: &Inner) {
    let ttl = inner.cfg.job_ttl;
    let now = Instant::now();
    let mut evicted: Vec<u64> = Vec::new();
    {
        let mut jobs = lock_recover(&inner.jobs);
        jobs.retain(|id, j| match j.finished_at {
            Some(t) if now.duration_since(t) >= ttl => {
                evicted.push(*id);
                false
            }
            _ => true,
        });
    }
    // order: eviction collects in HashMap order; sorted before the
    // (order-insensitive) checkpoint removals for determinism.
    evicted.sort_unstable();
    if let Some(store) = &inner.store {
        for id in evicted {
            remove_checkpoint(store, &inner.counters, id);
        }
    }
}

/// Fail non-stepping jobs whose deadline has passed (the housekeeper
/// half of the STATUS-side reap in [`Service::status`]): a saturated
/// queue must not postpone `deadline exceeded` until a worker happens
/// to dequeue the job.  Stepping jobs are the worker's to finish.
fn reap_deadlines(inner: &Inner) {
    let now = Instant::now();
    let mut jobs = lock_recover(&inner.jobs);
    for job in jobs.values_mut() {
        if !job.state.is_terminal()
            && !job.stepping
            && job.deadline_at.is_some_and(|d| now > d)
        {
            finalize(job, JobState::Failed("deadline exceeded".into()), &inner.counters);
        }
    }
}

fn worker_main(inner: Arc<Inner>) {
    loop {
        // Pull the next step claim, plus up to batch_max-1 small
        // ride-alongs from *other* tenants (cross-tenant tile
        // batching): the whole round then shares one lease checkout.
        let (id, extras) = {
            let mut q = lock_recover(&inner.queue);
            loop {
                if inner.stop.load(Ordering::Acquire) {
                    return;
                }
                if let Some(id) = q.pop() {
                    let mut extras = Vec::new();
                    while extras.len() + 1 < inner.cfg.batch_max.max(1) {
                        match q.pop_small_extra() {
                            Some(e) => extras.push(e),
                            None => break,
                        }
                    }
                    break (id, extras);
                }
                q = wait_recover(&inner.cv, q);
            }
        };
        if extras.is_empty() {
            guarded_step(&inner, id, None);
        } else {
            inner.counters.batched_rounds.fetch_add(1, Ordering::Relaxed);
            // One checkout for the whole round, keyed by the primary
            // job: the ride-alongs run on its engine (their seed
            // caches rebind — the pool counts that — which is the
            // price of amortizing the lease across small tenants).
            let mut lease = inner.pool.checkout(id);
            guarded_step(&inner, id, Some(&mut lease));
            for extra in extras {
                guarded_step(&inner, extra, Some(&mut lease));
            }
        }
    }
}

/// Run one job step with backstop panic isolation: `step_job` already
/// catches sweep panics, but a panic anywhere else in the step path
/// must fail only this job, not retire the worker thread (which would
/// silently shrink the scheduler until no steps run at all).
fn guarded_step(inner: &Inner, id: u64, shared: Option<&mut Lease<'_>>) {
    if catch_unwind(AssertUnwindSafe(|| step_job(inner, id, shared))).is_err() {
        inner.counters.panics.fetch_add(1, Ordering::Relaxed);
        let mut jobs = lock_recover(&inner.jobs);
        if let Some(job) = jobs.get_mut(&id) {
            if !job.state.is_terminal() {
                finalize(job, JobState::Failed("panic: worker step".into()), &inner.counters);
            }
        }
    }
}

/// How one step's outcome maps onto the job's durable checkpoint.
enum CkptAction {
    /// Save the freshly captured state (job parked, or failed at a
    /// clean boundary worth resuming from).
    Save,
    /// Drop the checkpoint (job done or cancelled — must not
    /// resurrect at the next boot scan).
    Remove,
    /// Leave whatever is on disk (failed mid-step: the last saved
    /// boundary is the best consistent state we have).
    Keep,
}

/// One step attempt, with panics reified as data.
enum StepOutcome {
    Ok(SweepStatus),
    Err(anyhow::Error),
    Panicked(String),
}

/// Best-effort text from a `catch_unwind` payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Advance one job by one sweep step through a leased engine/workspace.
/// With `shared` set (a batched round), the step reuses the caller's
/// checkout instead of taking its own — the engine is keyed to another
/// job, so the sticky seed cache rebinds, but small jobs repay that
/// with one pool round-trip for the whole batch.
fn step_job(inner: &Inner, id: u64, shared: Option<&mut Lease<'_>>) {
    // ---- Claim: move the sweep out of the table so the step runs
    // without holding the jobs lock.
    let (sweep0, series0, spec, seed_rows) = {
        let mut jobs = lock_recover(&inner.jobs);
        let Some(job) = jobs.get_mut(&id) else { return }; // FORGOTten
        if job.stepping || job.state.is_terminal() {
            return; // stale queue entry (cancelled/failed meanwhile)
        }
        if job.cancel {
            finalize(job, JobState::Cancelled, &inner.counters);
            if let Some(store) = &inner.store {
                remove_checkpoint(store, &inner.counters, id);
            }
            return;
        }
        if job.deadline_at.is_some_and(|d| Instant::now() > d) {
            finalize(job, JobState::Failed("deadline exceeded".into()), &inner.counters);
            return;
        }
        job.state = JobState::Running;
        job.stepping = true;
        (job.sweep.take(), job.series.clone(), job.spec.clone(), job.pending_seed_rows.take())
    };

    // ---- Materialize the series + sweep on first step (generation can
    // be expensive; it must not run under the lock or on the protocol
    // thread).
    let fail = |msg: String| {
        let mut jobs = lock_recover(&inner.jobs);
        if let Some(job) = jobs.get_mut(&id) {
            finalize(job, JobState::Failed(msg), &inner.counters);
        }
    };
    let series = match series0 {
        Some(s) => s,
        None => match materialize(&spec) {
            Ok(s) => s,
            Err(e) => return fail(e.to_string()),
        },
    };
    let mut sweep = match sweep0 {
        Some(s) => s,
        None => {
            let cfg = MerlinConfig {
                min_l: spec.min_l,
                max_l: spec.max_l,
                top_k: spec.top_k,
                ..Default::default()
            };
            match MerlinSweep::new(cfg, series.len()) {
                Ok(s) => s,
                Err(e) => return fail(e.to_string()),
            }
        }
    };

    // ---- One step through a keyed lease: same job -> same engine ->
    // warm seed cache and workspace.  The step runs panic-isolated and
    // transient-error-retried; on a checkpoint boundary the sweep
    // snapshot and the engine's seed-cache rows are captured while the
    // lease is still held (the rows live in the leased engine).
    let mut ckpt_state: Option<(Vec<u8>, Vec<SeedRowSnapshot>)> = None;
    let outcome = {
        let mut own: Option<Lease<'_>> = None;
        let lease = match shared {
            Some(l) => l,
            None => own.insert(inner.pool.checkout(id)),
        };
        let (engine, ws) = lease.engine_and_workspace();
        if let Some(rows) = &seed_rows {
            // Resume path: re-arm the QT seed cache so the next length
            // opens on verbatim hits, replaying the uninterrupted
            // run's exact low-order bits.
            engine.import_seed_rows(&series.values, rows);
        }
        let mut attempt = 0usize;
        loop {
            match catch_unwind(AssertUnwindSafe(|| sweep.step(engine, &series.values, ws))) {
                Err(payload) => {
                    // A panicking step leaves the sweep in an unknown
                    // state: never retried, and never parked.
                    inner.counters.panics.fetch_add(1, Ordering::Relaxed);
                    break StepOutcome::Panicked(panic_message(payload.as_ref()));
                }
                Ok(Err(_)) if attempt < inner.cfg.step_retries => {
                    // `step` mutates no sweep state before the point a
                    // transient engine error can surface, so a retry
                    // re-runs the same length from scratch.
                    attempt += 1;
                    inner.counters.step_retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(inner.cfg.step_retry_backoff * attempt as u32);
                }
                Ok(Err(e)) => break StepOutcome::Err(e),
                Ok(Ok(status)) => {
                    let every = inner.cfg.checkpoint_every.max(1);
                    let at_boundary = sweep.progress().0 as u64 % every == 0;
                    if inner.store.is_some()
                        && matches!(status, SweepStatus::Pending)
                        && at_boundary
                    {
                        ckpt_state =
                            Some((sweep.snapshot(), engine.export_seed_rows(&series.values)));
                    }
                    break StepOutcome::Ok(status);
                }
            }
        }
    };
    inner.counters.steps.fetch_add(1, Ordering::Relaxed);

    // ---- Park or finalize.
    let ckpt_action = {
        let mut jobs = lock_recover(&inner.jobs);
        let Some(job) = jobs.get_mut(&id) else { return };
        job.stepping = false;
        job.progress = sweep.progress();
        // An acknowledged CANCEL (the client was already told OK
        // CANCELLED) outranks whatever the in-flight step concluded —
        // even a final step that completed the sweep.
        if job.cancel {
            finalize(job, JobState::Cancelled, &inner.counters);
            CkptAction::Remove
        } else {
            match outcome {
                StepOutcome::Panicked(msg) => {
                    finalize(job, JobState::Failed(format!("panic: {msg}")), &inner.counters);
                    CkptAction::Keep
                }
                StepOutcome::Err(e) => {
                    finalize(job, JobState::Failed(e.to_string()), &inner.counters);
                    CkptAction::Keep
                }
                StepOutcome::Ok(SweepStatus::Done) => {
                    let res = sweep.finish();
                    let discords: Vec<Discord> = res.all_discords().copied().collect();
                    let seconds = res.metrics.total_time.as_secs_f64();
                    finalize(job, JobState::Done { discords, seconds }, &inner.counters);
                    CkptAction::Remove
                }
                StepOutcome::Ok(SweepStatus::Pending) => {
                    if job.deadline_at.is_some_and(|d| Instant::now() > d) {
                        finalize(
                            job,
                            JobState::Failed("deadline exceeded".into()),
                            &inner.counters,
                        );
                        // The just-captured boundary is valid; saving
                        // it lets RESUME restart with a fresh budget
                        // from right here instead of an older save.
                        CkptAction::Save
                    } else {
                        // Requeue at the back of the tenant's FIFO:
                        // weighted-fair across runnable jobs.  (This is
                        // the jobs→queue lock nesting; admission paths
                        // must never nest queue→jobs.)
                        job.sweep = Some(sweep);
                        job.series = Some(series.clone());
                        let (tenant, small) = (job.tenant, job.small);
                        lock_recover(&inner.queue).push(tenant, id, small);
                        inner.counters.preempts.fetch_add(1, Ordering::Relaxed);
                        inner.cv.notify_one();
                        CkptAction::Save
                    }
                }
            }
        }
    };

    // ---- Persist outside the jobs lock (file I/O must not stall the
    // scheduler).  Save uses temp-file + atomic rename, so a crash
    // right here leaves the previous checkpoint intact.
    if let Some(store) = &inner.store {
        match ckpt_action {
            CkptAction::Remove => remove_checkpoint(store, &inner.counters, id),
            CkptAction::Keep => {}
            CkptAction::Save => {
                if let Some((sweep_bytes, rows)) = ckpt_state {
                    let ckpt = build_checkpoint(id, &spec, &series, sweep_bytes, rows);
                    match store.save(&ckpt) {
                        Ok(()) => {
                            inner.counters.checkpoints.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => {
                            crate::log_warn!("checkpoint save for job {id} failed: {e:#}");
                        }
                    }
                }
            }
        }
    }
}

/// Assemble the durable snapshot of a parked job.  Generated series
/// rematerialize deterministically from `(dataset, n, seed)` and are
/// not stored; uploaded series must travel in the checkpoint because
/// the upload table dies with the process.
fn build_checkpoint(
    id: u64,
    spec: &JobSpec,
    series: &TimeSeries,
    sweep: Vec<u8>,
    seed_rows: Vec<SeedRowSnapshot>,
) -> JobCheckpoint {
    let stored_series = if spec.dataset.is_empty() {
        Some((series.name.clone(), series.values.clone()))
    } else {
        None
    };
    JobCheckpoint {
        job_id: id,
        dataset: spec.dataset.clone(),
        n: spec.n.map(|v| v as u64),
        seed: spec.seed,
        min_l: spec.min_l as u64,
        max_l: spec.max_l as u64,
        top_k: spec.top_k as u64,
        deadline_ms: spec.deadline.map(|d| d.as_millis() as u64),
        series: stored_series,
        sweep,
        seed_rows,
        tenant: spec.tenant.clone(),
        weight: spec.weight,
    }
}

/// Rebuild a job from its checkpoint and enqueue it.  Shared by the
/// boot-time journal scan and [`Service::resume`]; the caller notifies
/// the scheduler condvar if workers are already running.  Resume
/// bypasses the BUSY admission gates — the work was admitted once
/// already, and failing a boot-scan recovery over a transient bound
/// would silently strand durable state — but it does observe `stop`
/// under the queue lock exactly like `submit` (the same enqueue-vs-
/// shutdown race exists on this path).
fn resume_job(inner: &Inner, ckpt: JobCheckpoint) -> Result<u64> {
    let id = ckpt.job_id;
    let sweep = MerlinSweep::restore(&ckpt.sweep)?;
    let series = ckpt
        .series
        .map(|(name, values)| Arc::new(TimeSeries::new(name, values)));
    let spec = JobSpec {
        dataset: ckpt.dataset,
        n: ckpt.n.map(|v| v as usize),
        seed: ckpt.seed,
        min_l: ckpt.min_l as usize,
        max_l: ckpt.max_l as usize,
        top_k: ckpt.top_k as usize,
        series: series.clone(),
        // The budget restarts from resume time: a deadline bounds
        // runaway work, it is not a promise about outages.
        deadline: ckpt.deadline_ms.map(Duration::from_millis),
        tenant: ckpt.tenant,
        weight: ckpt.weight,
    };
    let tenant_name =
        if spec.tenant.is_empty() { DEFAULT_TENANT } else { spec.tenant.as_str() };
    let weight = if spec.weight == 0 {
        inner.cfg.default_tenant_weight.max(1)
    } else {
        spec.weight.min(inner.cfg.max_tenant_weight.max(1))
    };
    let tenant = lock_recover(&inner.queue).register(tenant_name, weight);
    let known_n = series.as_ref().map(|s| s.len()).or(spec.n);
    let small = known_n.is_some_and(|n| n <= inner.cfg.batch_small_points);
    let progress = sweep.progress();
    let job = Job {
        deadline_at: spec.deadline.map(|d| Instant::now() + d),
        series,
        spec,
        state: JobState::Queued,
        sweep: Some(sweep),
        stepping: false,
        cancel: false,
        finished_at: None,
        progress,
        pending_seed_rows: Some(ckpt.seed_rows),
        tenant,
        small,
    };
    {
        let mut jobs = lock_recover(&inner.jobs);
        if jobs.get(&id).is_some_and(|j| !j.state.is_terminal()) {
            bail!("job {id} is still active; cannot resume over it");
        }
        jobs.insert(id, job);
    }
    // Fresh submissions must never collide with a resumed id.
    let mut next = inner.next_id.load(Ordering::Relaxed);
    while next <= id {
        match inner.next_id.compare_exchange(
            next,
            id + 1,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => break,
            Err(cur) => next = cur,
        }
    }
    inner.counters.submitted.fetch_add(1, Ordering::Relaxed);
    inner.counters.resumes.fetch_add(1, Ordering::Relaxed);
    // Enqueue with `stop` checked under the queue lock (the submit-vs-
    // shutdown discipline): before PR 9 this path re-queued into a
    // drained scheduler unguarded, stranding the job as QUEUED forever.
    let stopped = {
        let mut q = lock_recover(&inner.queue);
        if inner.stop.load(Ordering::Acquire) {
            true
        } else {
            q.push(tenant, id, small);
            false
        }
    };
    if stopped {
        let mut jobs = lock_recover(&inner.jobs);
        if let Some(job) = jobs.get_mut(&id) {
            if !job.state.is_terminal() {
                finalize(job, JobState::Failed("shutdown".into()), &inner.counters);
            }
        }
        bail!("service is shutting down");
    }
    Ok(id)
}

fn materialize(spec: &JobSpec) -> Result<Arc<TimeSeries>> {
    if let Some(s) = &spec.series {
        return Ok(Arc::clone(s));
    }
    let series = match spec.n {
        Some(n) => registry::dataset_prefix(&spec.dataset, n, spec.seed)?.series,
        None => registry::dataset(&spec.dataset, spec.seed)?.series,
    };
    Ok(Arc::new(series))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec {
            dataset: "ecg2".into(),
            n: Some(2_000),
            seed: 7,
            min_l: 16,
            max_l: 20,
            top_k: 1,
            ..Default::default()
        }
    }

    #[test]
    fn submit_and_wait() {
        let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 2).unwrap();
        let id = svc.submit(spec()).unwrap();
        match svc.wait(id) {
            Some(JobState::Done { discords, .. }) => {
                assert_eq!(discords.len(), 5); // one per length 16..=20
            }
            other => panic!("unexpected state {other:?}"),
        }
        let (s, d, f, n) = svc.metrics();
        assert_eq!((s, d, f), (1, 1, 0));
        assert_eq!(n, 5);
        let sm = svc.sched_metrics();
        assert_eq!(sm.steps, 5, "one step per length");
        assert_eq!(sm.preempts, 4, "every non-final step requeues");
        assert_eq!(sm.lease.leases, 5);
        assert_eq!(sm.lease.sticky_hits, 4, "a lone job always gets its engine back");
        svc.shutdown();
    }

    #[test]
    fn bad_dataset_fails_cleanly() {
        let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap();
        let id = svc.submit(JobSpec { dataset: "nope".into(), ..spec() }).unwrap();
        match svc.wait(id) {
            Some(JobState::Failed(msg)) => assert!(msg.contains("unknown dataset")),
            other => panic!("unexpected {other:?}"),
        }
        svc.shutdown();
    }

    #[test]
    fn parallel_jobs_complete() {
        let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 4).unwrap();
        let ids: Vec<u64> = (0..6).map(|k| svc.submit(JobSpec { seed: k, ..spec() }).unwrap()).collect();
        for id in ids {
            match svc.wait(id) {
                Some(JobState::Done { .. }) => {}
                other => panic!("job {id}: {other:?}"),
            }
        }
        assert_eq!(svc.metrics().1, 6);
        svc.shutdown();
    }

    #[test]
    fn cancel_queued_job_before_any_step() {
        // Zero workers are clamped to one, so make that worker busy
        // with a first job long enough that the second is still queued
        // when the cancel lands.
        let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap();
        let big = svc.submit(JobSpec { min_l: 16, max_l: 120, ..spec() }).unwrap();
        let victim = svc.submit(spec()).unwrap();
        svc.cancel(victim).unwrap();
        assert!(matches!(svc.wait(victim), Some(JobState::Cancelled)));
        // Terminal jobs cannot be re-cancelled.
        assert!(svc.cancel(victim).is_err());
        svc.cancel(big).unwrap();
        assert!(matches!(svc.wait(big), Some(JobState::Cancelled)));
        assert_eq!(svc.sched_metrics().cancelled, 2);
        svc.shutdown();
    }

    #[test]
    fn deadline_exceeded_fails_between_steps() {
        let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap();
        let id = svc.submit(JobSpec {
            min_l: 16,
            max_l: 200,
            n: Some(4_000),
            deadline: Some(Duration::from_millis(1)),
            ..spec()
        }).unwrap();
        match svc.wait(id) {
            Some(JobState::Failed(msg)) => {
                assert!(msg.contains("deadline exceeded"), "{msg}")
            }
            other => panic!("unexpected {other:?}"),
        }
        svc.shutdown();
    }

    #[test]
    fn job_table_stays_bounded_under_churn() {
        let svc = Service::start_with(ServiceConfig {
            engine_opts: EngineOptions { segn: 64, ..Default::default() },
            workers: 2,
            job_ttl: Duration::ZERO,
            ..Default::default()
        })
        .unwrap();
        for k in 0..20 {
            let id = svc.submit(JobSpec { seed: k, min_l: 16, max_l: 17, ..spec() }).unwrap();
            assert!(matches!(svc.wait(id), Some(JobState::Done { .. })));
            // Terminal + zero TTL: the next submission's eviction sweep
            // clears it, so the table never accumulates history.
            assert!(
                svc.job_count() <= 3,
                "job table grew to {} after {k} churn rounds",
                svc.job_count()
            );
        }
        svc.evict_expired();
        assert_eq!(svc.job_count(), 0);
        let (s, d, _, _) = svc.metrics();
        assert_eq!((s, d), (20, 20), "eviction drops table entries, not counters");
        svc.shutdown();
    }

    #[test]
    fn forget_drops_terminal_jobs_only() {
        let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 2).unwrap();
        let id = svc.submit(spec()).unwrap();
        assert!(matches!(svc.wait(id), Some(JobState::Done { .. })));
        svc.forget(id).unwrap();
        assert!(svc.status(id).is_none());
        assert!(svc.forget(id).is_err(), "double FORGET reports no such job");
        let running = svc.submit(JobSpec { max_l: 120, ..spec() }).unwrap();
        assert!(svc.forget(running).is_err(), "active jobs cannot be forgotten");
        svc.cancel(running).unwrap();
        svc.wait(running);
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_jobs_as_failed() {
        let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap();
        // One long job occupies the single worker; the rest must still
        // be queued (or parked mid-sweep) when shutdown lands.
        let ids: Vec<u64> =
            (0..5).map(|k| svc.submit(JobSpec { seed: k, max_l: 120, ..spec() }).unwrap()).collect();
        svc.shutdown();
        let mut failed_shutdown = 0;
        for id in ids {
            match svc.status(id).unwrap() {
                JobState::Failed(msg) if msg == "shutdown" => failed_shutdown += 1,
                JobState::Done { .. } => {} // the in-flight step finished the job
                other => panic!("job {id} after shutdown: {other:?}"),
            }
        }
        assert!(failed_shutdown >= 4, "queued jobs must fail deterministically on shutdown");
        // Idempotent.
        svc.shutdown();
    }

    #[test]
    fn parse_and_validate_reject_bad_runs() {
        let cfg = ServiceConfig::default();
        let parse = |s: &str| parse_run_parts(s.split_whitespace());
        // Parse-shape errors.
        assert!(parse("gen=ecg minl=8").is_err(), "missing maxl");
        assert!(parse("minl=8 maxl=12").is_err(), "missing source");
        assert!(parse("gen=ecg data=x minl=8 maxl=12").is_err(), "both sources");
        assert!(parse("bogus").is_err());
        // Validation errors (each satellite rejection).
        let check = |s: &str| -> Result<()> {
            let (spec, _) = parse(s)?;
            validate_spec(&spec, &cfg)
        };
        assert!(check("gen=ecg minl=64 maxl=32").is_err(), "minl > maxl");
        assert!(check("gen=ecg minl=2 maxl=32").is_err(), "minl < 4");
        assert!(check("gen=ecg minl=8 maxl=32 topk=0").is_err(), "topk = 0");
        assert!(check("gen=ecg minl=8 maxl=32 n=999999999999").is_err(), "absurd n");
        assert!(check("gen=ecg minl=8 maxl=32 n=40").is_err(), "n < 2*maxl");
        // A well-formed request passes.
        let (spec, key) = parse("gen=ecg minl=8 maxl=12 topk=2 seed=9 deadline=5000").unwrap();
        assert!(key.is_none());
        assert_eq!(spec.top_k, 2);
        assert_eq!(spec.seed, 9);
        assert_eq!(spec.deadline, Some(Duration::from_millis(5000)));
        assert!(validate_spec(&spec, &cfg).is_ok());
        let (_, key) = parse("data=mine minl=8 maxl=12").unwrap();
        assert_eq!(key.as_deref(), Some("mine"));
    }

    #[test]
    fn upload_table_is_bounded_and_replaces() {
        let svc = Service::start_with(ServiceConfig {
            workers: 1,
            max_uploads: 2,
            ..Default::default()
        })
        .unwrap();
        svc.upload("a", TimeSeries::new("a", vec![0.0; 64])).unwrap();
        svc.upload("b", TimeSeries::new("b", vec![0.0; 64])).unwrap();
        assert!(svc.upload("c", TimeSeries::new("c", vec![0.0; 64])).is_err(), "table full");
        // Replacing an existing key is always allowed.
        svc.upload("a", TimeSeries::new("a", vec![1.0; 64])).unwrap();
        assert_eq!(svc.upload_count(), 2);
        assert_eq!(svc.uploaded("a").unwrap().values[0], 1.0);
        // Forgetting an upload frees its slot for a new name.
        svc.forget_upload("b").unwrap();
        assert!(svc.forget_upload("b").is_err(), "double forget reports missing");
        svc.upload("c", TimeSeries::new("c", vec![0.0; 64])).unwrap();
        assert_eq!(svc.upload_count(), 2);
        svc.shutdown();
    }

    #[test]
    fn upload_rejects_out_of_bounds_series() {
        let svc = Service::start_with(ServiceConfig {
            workers: 1,
            max_upload_points: 16,
            ..Default::default()
        })
        .unwrap();
        assert!(svc.upload("big", TimeSeries::new("big", vec![0.0; 17])).is_err());
        assert!(svc.upload("empty", TimeSeries::new("empty", Vec::new())).is_err());
        svc.upload("ok", TimeSeries::new("ok", vec![0.0; 16])).unwrap();
        assert_eq!(svc.upload_count(), 1, "only the in-bounds upload landed");
        svc.shutdown();
    }

    #[test]
    fn resume_without_checkpointing_errors() {
        let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap();
        let err = svc.resume(1).unwrap_err().to_string();
        assert!(err.contains("not enabled"), "{err}");
        svc.shutdown();
    }

    #[test]
    fn tcp_protocol_end_to_end() {
        let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap();
        let svc = std::sync::Arc::new(svc);
        // Bind on an ephemeral port.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let svc2 = Arc::clone(&svc);
        let server = std::thread::spawn(move || {
            for stream in listener.incoming() {
                let stream = stream.unwrap();
                let done = svc2.handle_conn(stream);
                if done {
                    break;
                }
            }
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, "RUN gen=ecg2 n=2000 minl=16 maxl=17 topk=1 seed=3").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK JOB "), "{line}");
        let id: u64 = line.trim().rsplit(' ').next().unwrap().parse().unwrap();
        // Poll status until done.
        loop {
            writeln!(conn, "STATUS {id}").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            if line.starts_with("OK DONE") {
                // Read discord lines until END.
                let mut count = 0;
                loop {
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    if line.trim() == "END" {
                        break;
                    }
                    assert!(line.starts_with("DISCORD "), "{line}");
                    count += 1;
                }
                assert_eq!(count, 2);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        writeln!(conn, "METRICS").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("done=1"), "{line}");
        assert!(line.contains("sched(steps/preempts/leases)=2/1/2"), "{line}");
        writeln!(conn, "SHUTDOWN").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "OK BYE");
        server.join().unwrap();
    }
}
