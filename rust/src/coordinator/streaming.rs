//! Online / streaming discord monitoring — the paper's future work (b)
//! ("application of PALMAD in ... online time series anomaly detection").
//!
//! A [`StreamMonitor`] ingests points one at a time and maintains the
//! top-1 discord of the most recent `window` samples at a fixed
//! subsequence length `m`.  Discovery is amortized: a full PD3 pass runs
//! every `refresh` new points (over the engine, through a recycled
//! [`MerlinWorkspace`]), and between passes each *newly completed*
//! subsequence is scored against the current window with early
//! abandoning — so a fresh anomaly is flagged the moment its window
//! completes, not at the next refresh.
//!
//! The steady-state ingest path is built to cost O(1) amortized per
//! point, independent of the window size:
//!
//! - the sample buffer is a [`SlidingWindow`] ring over a fixed
//!   `2 * window` allocation whose slide is one cursor bump per push
//!   (plus one wrap memcpy every `window` pushes) — the previous
//!   `Vec::drain(..excess)` implementation moved the whole window on
//!   *every* push;
//! - the incremental check z-normalizes into monitor-owned scratch
//!   buffers (the previous implementation allocated two fresh vectors
//!   per compared pair) and scans candidates **newest-first**, so on
//!   signals with any recurrent structure it early-exits after a
//!   handful of distance evaluations regardless of window size;
//! - the refresh pass is one rebind + step of a recycled single-length
//!   [`MerlinSweep`] (which owns the rolling-stats storage) over the
//!   monitor's PD3 workspace, so a warmed monitor's whole ingest loop —
//!   refreshes included — performs zero heap allocations (proved by the
//!   counting allocator in `rust/tests/alloc_steady_state.rs`).
//!
//! The alert rule follows the range-discord semantics: a new subsequence
//! whose nearest non-self match within the window is at least the
//! current discord distance is itself a (new) discord and is reported.
//! All reported indices — [`Alert::global_idx`] and
//! [`StreamMonitor::current_discord`] — are **global** stream positions
//! (count of points ingested before the subsequence starts); the
//! monitor rebases PD3's window-local results and invalidates a
//! tracked discord the moment its subsequence slides out of the buffer.

use anyhow::Result;

use super::drag::Discord;
use super::merlin::{MerlinConfig, MerlinSweep, SweepStatus};
use super::metrics::DragMetrics;
use super::workspace::MerlinWorkspace;
use crate::core::distance::{ed2_early_abandon, window_is_flat, znorm_into, znorm_into_flat};
use crate::engines::Engine;

/// Configuration for the monitor.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Sliding-window size (samples kept).
    pub window: usize,
    /// Subsequence length.
    pub m: usize,
    /// Full re-discovery every this many ingested points.
    pub refresh: usize,
    /// Fraction of the current discord distance a new subsequence must
    /// exceed to raise an alert between refreshes (1.0 = strict discord).
    pub alert_frac: f64,
    /// Bench-only baseline: reproduce the pre-workspace slide (a full
    /// `Vec::drain`-style memmove on every push, O(window) per point).
    /// Kept so the ingest benchmark reports an honest before/after from
    /// one binary; production monitors leave this `false`.
    pub legacy_slide: bool,
}

impl Default for StreamConfig {
    fn default() -> Self {
        Self { window: 4_096, m: 64, refresh: 256, alert_frac: 1.0, legacy_slide: false }
    }
}

/// An alert raised by the monitor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Alert {
    /// Global index (over all ingested points) of the anomalous window.
    pub global_idx: usize,
    /// Its nearest-neighbor distance within the sliding window (ED).
    pub nn_dist: f64,
}

/// Operation counters for the ingest path (tests and the microbench
/// assert on these; see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IngestCounters {
    /// Candidate distance evaluations on the incremental (between-
    /// refresh) path.
    pub dist_evals: u64,
    /// Elements memmoved maintaining the sliding buffer (amortized <= 1
    /// per push for the ring; `window - 1` per push for the legacy
    /// drain slide).
    pub window_copies: u64,
    /// Full PD3 refresh passes run.
    pub refreshes: u64,
}

/// Amortized-O(1) sliding window over one fixed `2 * window` buffer.
///
/// The live span is `buf[start .. start + len]`, always contiguous (so
/// it can be handed to `SeriesView` directly).  A push drops the oldest
/// point by bumping `start`; when the span reaches the buffer's end it
/// wraps with one memcpy of `window` elements — once per `window`
/// pushes, hence amortized O(1) data movement per point.
struct SlidingWindow {
    buf: Vec<f64>,
    window: usize,
    start: usize,
    len: usize,
    /// Elements moved by slides (the op-counter behind
    /// [`IngestCounters::window_copies`]).
    copied: u64,
    /// Pre-workspace behavior: memmove the whole span every push.
    legacy: bool,
}

impl SlidingWindow {
    fn new(window: usize, legacy: bool) -> Self {
        Self { buf: vec![0.0; 2 * window], window, start: 0, len: 0, copied: 0, legacy }
    }

    fn push(&mut self, x: f64) {
        if self.legacy {
            // The old `buf.drain(..excess)` slide: O(window) per push.
            if self.len == self.window {
                self.buf.copy_within(self.start + 1..self.start + self.len, self.start);
                self.copied += (self.len - 1) as u64;
                self.len -= 1;
            }
            self.buf[self.start + self.len] = x;
            self.len += 1;
            return;
        }
        if self.len == self.window {
            self.start += 1;
            self.len -= 1;
        }
        if self.start + self.len == self.buf.len() {
            self.buf.copy_within(self.start.., 0);
            self.copied += self.len as u64;
            self.start = 0;
        }
        self.buf[self.start + self.len] = x;
        self.len += 1;
    }

    fn len(&self) -> usize {
        self.len
    }

    fn as_slice(&self) -> &[f64] {
        &self.buf[self.start..self.start + self.len]
    }
}

/// Sliding-window discord monitor (see module docs).
pub struct StreamMonitor<'e> {
    cfg: StreamConfig,
    engine: &'e dyn Engine,
    win: SlidingWindow,
    /// Count of points ingested since the start of the stream.
    ingested: usize,
    since_refresh: usize,
    /// Current top discord of the window, in **global** stream
    /// coordinates (from the last full pass or alert).
    current: Option<Discord>,
    /// Threshold carried over from a discord that slid out of the
    /// window: its *position* is unreportable, but its distance keeps
    /// the incremental alert check live until the next scheduled
    /// refresh.  Without this, every push while the window's top
    /// discord drains out would trigger an immediate full PD3 pass — an
    /// O(window^2)-per-push storm in exactly the post-anomaly regime.
    stale_thr: Option<f64>,
    /// Whether a first full pass has been attempted.  A *pathological*
    /// window (all twins: refresh finds nothing even at the minimum
    /// threshold) yields no usable threshold; retrying is then held to
    /// the scheduled cadence rather than every push — the same
    /// storm-avoidance rationale as `stale_thr`.
    warmed: bool,
    /// Recycled single-length MERLIN sweep (refresh path): the monitor
    /// is just another client of [`MerlinSweep::step`] — one rebind +
    /// one step per refresh, with the initial threshold seeded from the
    /// tracked discord.  The sweep owns the recycled window statistics.
    sweep: MerlinSweep,
    /// Recycled PD3 arena (refresh path).
    ws: MerlinWorkspace,
    /// Cumulative PD3 counters across refreshes.
    drag_metrics: DragMetrics,
    /// Scratch for the incremental check's z-normalized windows.
    new_norm: Vec<f64>,
    cand_norm: Vec<f64>,
    dist_evals: u64,
    refreshes: u64,
}

impl<'e> StreamMonitor<'e> {
    pub fn new(engine: &'e dyn Engine, cfg: StreamConfig) -> Self {
        assert!(cfg.m >= 3 && cfg.window >= 2 * cfg.m, "window must hold >= 2 subsequences");
        let win = SlidingWindow::new(cfg.window, cfg.legacy_slide);
        let m = cfg.m;
        // Single-length sweep, retry policy matching the legacy refresh
        // loop: start from the carried threshold (or the MERLIN seed),
        // halve per retry (the step == 0 schedule), give up after 64
        // retries or below the legacy *absolute* floor of 1e-4 — the
        // sweep's floor is `r_floor_frac * 2*sqrt(m)`, so divide it out
        // rather than silently raising the give-up point (a recurrent
        // window with a tiny top nnDist would otherwise lose its
        // tracked discord).  top_k = 0 keeps every survivor in the
        // workspace for the incremental check's exact-nn lookup.
        let sweep_cfg = MerlinConfig {
            min_l: m,
            max_l: m,
            top_k: 0,
            max_retries: 64,
            r_floor_frac: 1e-4 / (2.0 * (m as f64).sqrt()),
            ..Default::default()
        };
        let sweep = MerlinSweep::new(sweep_cfg, cfg.window)
            .expect("window must hold >= 2 subsequences");
        Self {
            cfg,
            engine,
            win,
            ingested: 0,
            since_refresh: 0,
            current: None,
            stale_thr: None,
            warmed: false,
            sweep,
            ws: MerlinWorkspace::new(),
            drag_metrics: DragMetrics::default(),
            new_norm: vec![0.0; m],
            cand_norm: vec![0.0; m],
            dist_evals: 0,
            refreshes: 0,
        }
    }

    /// Current top discord of the window (None until warm), with
    /// [`Discord::idx`] in global stream coordinates — consistent with
    /// [`Alert::global_idx`].
    pub fn current_discord(&self) -> Option<Discord> {
        self.current
    }

    /// Number of points ingested so far.
    pub fn ingested(&self) -> usize {
        self.ingested
    }

    /// Number of points currently held in the sliding window.
    pub fn window_len(&self) -> usize {
        self.win.len()
    }

    /// Ingest-path operation counters.
    pub fn ingest_counters(&self) -> IngestCounters {
        IngestCounters {
            dist_evals: self.dist_evals,
            window_copies: self.win.copied,
            refreshes: self.refreshes,
        }
    }

    /// Cumulative PD3 metrics across all refresh passes.
    pub fn drag_metrics(&self) -> &DragMetrics {
        &self.drag_metrics
    }

    /// Workspace reuse counters (refresh-path arena recycling).
    pub fn workspace_counters(&self) -> super::workspace::WorkspaceCounters {
        self.ws.counters()
    }

    /// Ingest one point; returns an alert if the newly completed
    /// subsequence is anomalous.
    pub fn push(&mut self, x: f64) -> Result<Option<Alert>> {
        self.win.push(x);
        self.ingested += 1;
        self.since_refresh += 1;

        let n = self.win.len();
        if n < 2 * self.cfg.m {
            return Ok(None); // not warm yet
        }

        // Global index of the oldest buffered point; a tracked discord
        // whose subsequence slid past it is unreportable — stop
        // reporting it, but carry its distance as the alert threshold
        // until the next scheduled refresh re-discovers in-window.
        let base = self.ingested - n;
        if let Some(d) = self.current {
            if d.idx < base {
                self.stale_thr = Some(d.nn_dist);
                self.current = None;
            }
        }

        // Full re-discovery on schedule, or once on first warmth.  A
        // pathological window (no threshold even after a full pass)
        // retries at the scheduled cadence only — never per push.
        let have_thr = self.current.is_some() || self.stale_thr.is_some();
        if (!have_thr && !self.warmed) || self.since_refresh >= self.cfg.refresh {
            let prev_thr = self.current.map(|d| d.nn_dist).or(self.stale_thr);
            self.refresh()?;
            self.since_refresh = 0;
            // The refresh subsumes the incremental check for the
            // just-completed subsequence whenever its outcome is
            // decisive: a survivor entry carries the exact nn (alert
            // iff it beats the pre-refresh threshold), and a kill at
            // the pass's r = 0.99 * prev settles any alert_frac >=
            // 0.99.  Only "killed by the pass but alert_frac < 0.99"
            // still needs the incremental scan below.
            let Some(prev) = prev_thr else { return Ok(None) }; // first warmth: no baseline
            let local_newest = n - self.cfg.m;
            let hit = self.ws.discords().iter().find(|d| d.idx == local_newest).copied();
            if let Some(d) = hit {
                if d.nn_dist >= prev * self.cfg.alert_frac {
                    let alert =
                        Alert { global_idx: self.ingested - self.cfg.m, nn_dist: d.nn_dist };
                    return Ok(Some(alert));
                }
                return Ok(None); // exact nn known: not anomalous
            }
            if self.cfg.alert_frac >= 0.99 || self.current.is_none() {
                // Not anomalous at this margin — or the window went
                // pathological and there is no threshold to check.
                return Ok(None);
            }
            // Fall through: incremental check against the refreshed
            // threshold.
        } else if !have_thr {
            return Ok(None); // pathological window: wait for the schedule
        }

        // Incremental check of the just-completed subsequence.
        let m = self.cfg.m;
        let start = n - m;
        // Invariant: every path into the incremental check carries a
        // threshold — `have_thr` guards the non-refresh path, and the
        // refresh fall-through requires `current` to be Some.
        let threshold = self
            .current
            .map(|d| d.nn_dist)
            .or(self.stale_thr)
            .expect("incremental path requires a threshold")
            * self.cfg.alert_frac;
        let thr2 = threshold * threshold;

        let win = self.win.as_slice();
        let new_win = &win[start..];
        let new_flat = znorm_into_flat(new_win, &mut self.new_norm);
        let mut nn2 = f64::INFINITY;
        // Non-self matches strictly left of the new window, scanned
        // newest-first: on any recurrent signal the closest match is
        // recent, so the `nn2 < thr2` exit fires after O(1) evaluations
        // regardless of window size (asserted by the scaling test).
        for j in (0..=start - m).rev() {
            let w = &win[j..j + m];
            self.dist_evals += 1;
            let d = if new_flat {
                Some(if window_is_flat(w) { 0.0 } else { 2.0 * m as f64 })
            } else {
                znorm_into(w, &mut self.cand_norm);
                ed2_early_abandon(&self.cand_norm, &self.new_norm, nn2)
            };
            if let Some(d) = d {
                nn2 = nn2.min(d);
                if nn2 < thr2 {
                    return Ok(None); // has a close neighbor: not anomalous
                }
            }
        }
        if nn2.is_finite() && nn2 >= thr2 {
            let alert = Alert {
                global_idx: self.ingested - m,
                nn_dist: nn2.max(0.0).sqrt(),
            };
            // It dethrones (or matches) the current discord; `idx` is
            // already global.
            self.current = Some(Discord { idx: alert.global_idx, m, nn_dist: alert.nn_dist });
            self.stale_thr = None;
            return Ok(Some(alert));
        }
        Ok(None)
    }

    /// Full re-discovery over the current window: one rebind + one step
    /// of the monitor's single-length [`MerlinSweep`], through the
    /// recycled workspace (allocation-free once warm).
    fn refresh(&mut self) -> Result<()> {
        let win = self.win.as_slice();
        let base = self.ingested - win.len();
        // Adaptive r: seed the sweep's first threshold from the last
        // known (possibly drained-out) discord distance; `None` falls
        // back to the MERLIN seed `2*sqrt(m)`.
        let r_start = self.current.map(|d| d.nn_dist).or(self.stale_thr).map(|d| 0.99 * d);
        self.sweep.rebind_with(win.len(), r_start)?;
        // Bind, then give the engine its bulk-prefetch hook before the
        // step's retry loop.  The bind must be the unconditional
        // prepare_series (content fingerprint), not prefetch_length's
        // identity-guarded fast path: the ring's slice identity
        // (ptr, len) cycles with period window+1 pushes, so a slid
        // window can present the *same* identity as the previous
        // refresh while holding new content.  For the native engine the
        // hook itself is a no-op here — the monitor runs one fixed
        // length, so after a slide the cache is empty and otherwise
        // every row already sits at `m` (nothing advances, no batch is
        // counted) — but engines carrying other cross-refresh
        // per-length state get their bulk pass before the first pd3
        // call of the retry loop.
        self.sweep.bind_series(self.engine, win)?;
        self.refreshes += 1;
        self.warmed = true;
        let _status = self.sweep.step(self.engine, win, &mut self.ws)?;
        debug_assert_eq!(_status, SweepStatus::Done, "single-length sweep completes in one step");
        self.drag_metrics.merge(&self.sweep.metrics().drag);
        let lr = self.sweep.lengths().last().expect("completed sweep has its length result");
        match lr.discords.first() {
            // Rebase the window-local top survivor (sorted nnDist-
            // descending, NaN-last) to global coordinates.  A "discord"
            // below the legacy absolute floor is an all-twins artifact
            // of the final floor-clamped pass (the sweep evaluates once
            // *at* the floor, where the legacy loop stopped short):
            // latching it would set a near-zero alert threshold and
            // storm alerts, so treat it as pathological instead.
            Some(best) if best.nn_dist >= 1e-4 => {
                self.current =
                    Some(Discord { idx: base + best.idx, m: best.m, nn_dist: best.nn_dist });
            }
            _ => self.current = None, // pathological window (all twins)
        }
        self.stale_thr = None;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::native::NativeEngine;
    use crate::util::rng::Rng;

    fn monitor(engine: &NativeEngine) -> StreamMonitor<'_> {
        StreamMonitor::new(
            engine,
            StreamConfig {
                window: 1_024,
                m: 32,
                refresh: 128,
                alert_frac: 1.0,
                ..StreamConfig::default()
            },
        )
    }

    #[test]
    fn warms_up_then_tracks_discord() {
        let engine = NativeEngine::with_segn(64);
        let mut mon = monitor(&engine);
        let mut rng = Rng::seed(71);
        for i in 0..600 {
            let x = (i as f64 * 0.2).sin() + 0.05 * rng.normal();
            mon.push(x).unwrap();
        }
        assert!(mon.current_discord().is_some());
        assert_eq!(mon.ingested(), 600);
        assert!(mon.workspace_counters().resets > 0, "refresh must recycle the arena");
    }

    #[test]
    fn alerts_on_injected_anomaly_between_refreshes() {
        let engine = NativeEngine::with_segn(64);
        let mut mon = monitor(&engine);
        let mut rng = Rng::seed(72);
        let mut alerts = Vec::new();
        for i in 0..2_000 {
            // Periodic signal with an anomaly burst at 1500..1532 chosen
            // to land between refresh boundaries (1536 = 12 * 128).
            let mut x = (i as f64 * 0.2).sin() + 0.05 * rng.normal();
            if (1_500..1_532).contains(&i) {
                x += if i % 2 == 0 { 2.0 } else { -2.0 };
            }
            if let Some(a) = mon.push(x).unwrap() {
                alerts.push((i, a));
            }
        }
        assert!(
            alerts.iter().any(|&(i, _)| (1_500..1_600).contains(&i)),
            "no alert near the injected burst: {alerts:?}"
        );
        // Alert coordinates are global: an alert fired on push `i` names
        // the subsequence that ends exactly at that push.
        for &(i, a) in &alerts {
            assert_eq!(a.global_idx, i + 1 - 32, "alert at push {i} reported {}", a.global_idx);
        }
    }

    #[test]
    fn no_alerts_on_stationary_periodic_stream() {
        let engine = NativeEngine::with_segn(64);
        let mut mon = StreamMonitor::new(
            &engine,
            StreamConfig {
                window: 1_024,
                m: 32,
                refresh: 128,
                alert_frac: 1.2,
                ..StreamConfig::default()
            },
        );
        let mut count = 0;
        for i in 0..3_000 {
            let x = (i as f64 * 0.2).sin();
            if mon.push(x).unwrap().is_some() {
                count += 1;
            }
        }
        assert_eq!(count, 0, "pure periodic stream should not alert");
    }

    #[test]
    fn window_stays_bounded() {
        let engine = NativeEngine::with_segn(64);
        let mut mon = monitor(&engine);
        for i in 0..5_000 {
            mon.push(i as f64).unwrap();
        }
        assert!(mon.window_len() <= 1_024);
        assert_eq!(mon.win.as_slice().len(), mon.window_len());
    }

    #[test]
    #[should_panic(expected = "window must hold")]
    fn rejects_degenerate_window() {
        let engine = NativeEngine::with_segn(64);
        let _ = StreamMonitor::new(
            &engine,
            StreamConfig {
                window: 40,
                m: 32,
                refresh: 16,
                alert_frac: 1.0,
                ..StreamConfig::default()
            },
        );
    }

    #[test]
    fn ring_slide_is_amortized_o1_per_push() {
        for window in [256usize, 1_024, 4_096] {
            let mut w = SlidingWindow::new(window, false);
            for i in 0..5 * window {
                w.push(i as f64);
            }
            let pushes = (5 * window) as u64;
            assert!(
                w.copied <= pushes + window as u64,
                "window={window}: {} elements moved over {pushes} pushes",
                w.copied
            );
            let s = w.as_slice();
            assert_eq!(s.len(), window);
            assert_eq!(s[0], (4 * window) as f64);
            assert_eq!(*s.last().unwrap(), (5 * window - 1) as f64);
        }
        // The legacy drain slide moves Theta(window) elements per push —
        // kept only as the ingest-bench baseline; this pins the asymmetry
        // the ring rework removes.
        let mut legacy = SlidingWindow::new(1_024, true);
        for i in 0..2_048 {
            legacy.push(i as f64);
        }
        assert!(legacy.copied >= 1_023 * 900, "legacy slide copied only {}", legacy.copied);
        assert_eq!(legacy.as_slice()[0], 1_024.0);
        assert_eq!(*legacy.as_slice().last().unwrap(), 2_047.0);
    }

    /// Regression for the stale-index bug: `current_discord()` used to
    /// report the window-local PD3 index, which went stale on the very
    /// next push once the buffer started draining.
    #[test]
    fn current_discord_is_global_and_survives_drain() {
        let engine = NativeEngine::with_segn(64);
        let mut mon = StreamMonitor::new(
            &engine,
            StreamConfig {
                window: 256,
                m: 16,
                refresh: 64,
                alert_frac: 1.0,
                ..StreamConfig::default()
            },
        );
        let mut checked_at_700 = false;
        for i in 0..1_200usize {
            let mut x = (i as f64 * 0.2).sin() + 0.02 * (i as f64 * 0.013).sin();
            if (600..616).contains(&i) {
                x += if i % 2 == 0 { 2.0 } else { -2.0 };
            }
            mon.push(x).unwrap();
            // Invariant: whatever is reported addresses a subsequence
            // fully inside the current window, in global coordinates.
            if let Some(d) = mon.current_discord() {
                let base = mon.ingested() - mon.window_len();
                assert!(d.idx >= base, "push {i}: stale index {} < window base {base}", d.idx);
                assert!(d.idx + d.m <= mon.ingested(), "push {i}: index past the stream");
            }
            if i == 700 {
                // Window spans [445, 701); the injected anomaly at
                // 600..616 has drained past several refreshes, yet the
                // report must still pin it in global coordinates.
                let d = mon.current_discord().expect("anomaly must be tracked at push 700");
                assert!(
                    (580..=620).contains(&d.idx),
                    "discord at {} does not match the injected anomaly near 600",
                    d.idx
                );
                checked_at_700 = true;
            }
        }
        assert!(checked_at_700);
        assert_eq!(mon.ingested(), 1_200);
    }

    /// The satellite regression: per-push ingest cost must not scale
    /// with the window.  The stream runs to 6000 points, so the
    /// 512-point window slides for ~5.5k pushes while the 2048-point
    /// window holds four times the history — yet both must spend
    /// *identical* incremental distance evaluations (the newest-first
    /// scan exits long before it can see the extra history; a full-
    /// window scan would differ by ~4x here).  The slide itself is
    /// covered by `ring_slide_is_amortized_o1_per_push`.
    #[test]
    fn incremental_check_cost_is_window_size_independent() {
        const PUSHES: usize = 6_000;
        const MEASURE_FROM: usize = 100;
        let evals_for = |window: usize| -> u64 {
            let engine = NativeEngine::with_segn(64);
            let mut mon = StreamMonitor::new(
                &engine,
                StreamConfig {
                    window,
                    m: 32,
                    refresh: 1_000_000, // only the initial warm refresh
                    alert_frac: 100.0,  // generous margin: exit on the first match
                    ..StreamConfig::default()
                },
            );
            let mut at_measure_start = 0;
            for i in 0..PUSHES {
                let x = (i as f64 * 0.2).sin() + 0.05 * (i as f64 * 0.013).sin();
                mon.push(x).unwrap();
                if i + 1 == MEASURE_FROM {
                    at_measure_start = mon.ingest_counters().dist_evals;
                }
            }
            let c = mon.ingest_counters();
            // One warm-up pass only: when the tracked discord drains
            // out, its distance survives as `stale_thr`, so sliding
            // never re-triggers a refresh.
            assert_eq!(c.refreshes, 1, "window={window}: expected only the warm-up refresh");
            c.dist_evals - at_measure_start
        };
        let small = evals_for(512);
        let large = evals_for(2_048);
        let measured = (PUSHES - MEASURE_FROM) as u64;
        assert_eq!(small, large, "incremental scan cost scaled with the window");
        assert!(small <= measured * 64, "scan failed to early-exit: {small}/{measured} pushes");
        assert!(small >= measured, "each push evaluates at least one candidate");
    }
}
