//! Online / streaming discord monitoring — the paper's future work (b)
//! ("application of PALMAD in ... online time series anomaly detection").
//!
//! A [`StreamMonitor`] ingests points one at a time and maintains the
//! top-1 discord of the most recent `window` samples at a fixed
//! subsequence length `m`.  Discovery is amortized: a full PD3 pass runs
//! every `refresh` new points (over the engine), and between passes each
//! *newly completed* subsequence is scored against the current window
//! with early abandoning — so a fresh anomaly is flagged the moment its
//! window completes, not at the next refresh.
//!
//! The alert rule follows the range-discord semantics: a new subsequence
//! whose nearest non-self match within the window is at least the
//! current discord distance is itself a (new) discord and is reported.

use anyhow::Result;

use super::drag::{pd3, Discord, Pd3Config};
use super::metrics::DragMetrics;
use crate::core::distance::{ed2_early_abandon, is_flat, znorm};
use crate::core::stats::RollingStats;
use crate::engines::{Engine, SeriesView};

/// Configuration for the monitor.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Sliding-window size (samples kept).
    pub window: usize,
    /// Subsequence length.
    pub m: usize,
    /// Full re-discovery every this many ingested points.
    pub refresh: usize,
    /// Fraction of the current discord distance a new subsequence must
    /// exceed to raise an alert between refreshes (1.0 = strict discord).
    pub alert_frac: f64,
}

impl Default for StreamConfig {
    fn default() -> Self {
        Self { window: 4_096, m: 64, refresh: 256, alert_frac: 1.0 }
    }
}

/// An alert raised by the monitor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Alert {
    /// Global index (over all ingested points) of the anomalous window.
    pub global_idx: usize,
    /// Its nearest-neighbor distance within the sliding window (ED).
    pub nn_dist: f64,
}

/// Sliding-window discord monitor.
pub struct StreamMonitor<'e> {
    cfg: StreamConfig,
    engine: &'e dyn Engine,
    buf: Vec<f64>,
    /// Count of points ingested since the start of the stream.
    ingested: usize,
    since_refresh: usize,
    /// Current benchmark discord of the window (from the last full pass).
    current: Option<Discord>,
}

impl<'e> StreamMonitor<'e> {
    pub fn new(engine: &'e dyn Engine, cfg: StreamConfig) -> Self {
        assert!(cfg.m >= 3 && cfg.window >= 2 * cfg.m, "window must hold >= 2 subsequences");
        Self { cfg, engine, buf: Vec::new(), ingested: 0, since_refresh: 0, current: None }
    }

    /// Current top discord of the window (None until warm).
    pub fn current_discord(&self) -> Option<Discord> {
        self.current
    }

    /// Number of points ingested so far.
    pub fn ingested(&self) -> usize {
        self.ingested
    }

    /// Ingest one point; returns an alert if the newly completed
    /// subsequence is anomalous.
    pub fn push(&mut self, x: f64) -> Result<Option<Alert>> {
        self.buf.push(x);
        if self.buf.len() > self.cfg.window {
            let excess = self.buf.len() - self.cfg.window;
            self.buf.drain(..excess);
        }
        self.ingested += 1;
        self.since_refresh += 1;

        if self.buf.len() < 2 * self.cfg.m {
            return Ok(None); // not warm yet
        }

        // Full re-discovery on schedule (or first time warm).
        if self.current.is_none() || self.since_refresh >= self.cfg.refresh {
            self.refresh()?;
            self.since_refresh = 0;
            return Ok(None); // refresh subsumes the incremental check
        }

        // Incremental check of the just-completed subsequence.
        let m = self.cfg.m;
        let n = self.buf.len();
        let start = n - m;
        let new_win = &self.buf[start..];
        let threshold = match &self.current {
            Some(d) => d.nn_dist * self.cfg.alert_frac,
            None => return Ok(None),
        };
        let thr2 = threshold * threshold;

        let new_norm = znorm(new_win);
        let new_flat = {
            let mu = new_win.iter().sum::<f64>() / m as f64;
            let ms = new_win.iter().map(|v| v * v).sum::<f64>() / m as f64;
            let sig = (ms - mu * mu).max(0.0).sqrt().max(crate::core::stats::SIGMA_FLOOR);
            is_flat(sig, mu)
        };
        let mut nn2 = f64::INFINITY;
        for j in 0..=(start - m) {
            // Non-self matches strictly left of the new window.
            let w = &self.buf[j..j + m];
            let d = if new_flat {
                let mu = w.iter().sum::<f64>() / m as f64;
                let ms = w.iter().map(|v| v * v).sum::<f64>() / m as f64;
                let sig = (ms - mu * mu).max(0.0).sqrt().max(crate::core::stats::SIGMA_FLOOR);
                Some(if is_flat(sig, mu) { 0.0 } else { 2.0 * m as f64 })
            } else {
                ed2_early_abandon(&znorm(w), &new_norm, nn2)
            };
            if let Some(d) = d {
                nn2 = nn2.min(d);
                if nn2 < thr2 {
                    return Ok(None); // has a close neighbor: not anomalous
                }
            }
        }
        if nn2.is_finite() && nn2 >= thr2 {
            let alert = Alert {
                global_idx: self.ingested - m,
                nn_dist: nn2.max(0.0).sqrt(),
            };
            // It dethrones (or matches) the current discord.
            self.current = Some(Discord { idx: start, m, nn_dist: alert.nn_dist });
            return Ok(Some(alert));
        }
        Ok(None)
    }

    /// Full PD3 pass over the current window.
    fn refresh(&mut self) -> Result<()> {
        let m = self.cfg.m;
        let stats = RollingStats::compute(&self.buf, m);
        let view = SeriesView { t: &self.buf, stats: &stats };
        // Adaptive r: reuse the last known discord distance, else start
        // from the MERLIN seed.
        let mut r = match &self.current {
            Some(d) => 0.99 * d.nn_dist,
            None => 2.0 * (m as f64).sqrt(),
        };
        let mut metrics = DragMetrics::default();
        for _ in 0..64 {
            let found = pd3(self.engine, &view, r, &Pd3Config::default(), &mut metrics)?;
            if let Some(best) =
                found.into_iter().max_by(|a, b| a.nn_dist.partial_cmp(&b.nn_dist).unwrap())
            {
                self.current = Some(best);
                return Ok(());
            }
            r *= 0.5;
            if r < 1e-4 {
                break;
            }
        }
        self.current = None; // pathological window (all twins)
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::native::NativeEngine;
    use crate::util::rng::Rng;

    fn monitor(engine: &NativeEngine) -> StreamMonitor<'_> {
        StreamMonitor::new(
            engine,
            StreamConfig { window: 1_024, m: 32, refresh: 128, alert_frac: 1.0 },
        )
    }

    #[test]
    fn warms_up_then_tracks_discord() {
        let engine = NativeEngine::with_segn(64);
        let mut mon = monitor(&engine);
        let mut rng = Rng::seed(71);
        for i in 0..600 {
            let x = (i as f64 * 0.2).sin() + 0.05 * rng.normal();
            mon.push(x).unwrap();
        }
        assert!(mon.current_discord().is_some());
        assert_eq!(mon.ingested(), 600);
    }

    #[test]
    fn alerts_on_injected_anomaly_between_refreshes() {
        let engine = NativeEngine::with_segn(64);
        let mut mon = monitor(&engine);
        let mut rng = Rng::seed(72);
        let mut alerts = Vec::new();
        for i in 0..2_000 {
            // Periodic signal with an anomaly burst at 1500..1532 chosen
            // to land between refresh boundaries (1536 = 12 * 128).
            let mut x = (i as f64 * 0.2).sin() + 0.05 * rng.normal();
            if (1_500..1_532).contains(&i) {
                x += if i % 2 == 0 { 2.0 } else { -2.0 };
            }
            if let Some(a) = mon.push(x).unwrap() {
                alerts.push((i, a));
            }
        }
        assert!(
            alerts.iter().any(|&(i, _)| (1_500..1_600).contains(&i)),
            "no alert near the injected burst: {alerts:?}"
        );
    }

    #[test]
    fn no_alerts_on_stationary_periodic_stream() {
        let engine = NativeEngine::with_segn(64);
        let mut mon = StreamMonitor::new(
            &engine,
            StreamConfig { window: 1_024, m: 32, refresh: 128, alert_frac: 1.2 },
        );
        let mut count = 0;
        for i in 0..3_000 {
            let x = (i as f64 * 0.2).sin();
            if mon.push(x).unwrap().is_some() {
                count += 1;
            }
        }
        assert_eq!(count, 0, "pure periodic stream should not alert");
    }

    #[test]
    fn window_stays_bounded() {
        let engine = NativeEngine::with_segn(64);
        let mut mon = monitor(&engine);
        for i in 0..5_000 {
            mon.push(i as f64).unwrap();
        }
        assert!(mon.buf.len() <= 1_024);
    }

    #[test]
    #[should_panic(expected = "window must hold")]
    fn rejects_degenerate_window() {
        let engine = NativeEngine::with_segn(64);
        let _ = StreamMonitor::new(
            &engine,
            StreamConfig { window: 40, m: 32, refresh: 16, alert_frac: 1.0 },
        );
    }
}
