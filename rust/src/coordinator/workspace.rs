//! Reusable PD3/MERLIN working set — the coordinator-level analogue of
//! the engine's per-worker `TileScratch` arena (ROADMAP item
//! "pd3-level workspace reuse").
//!
//! One [`MerlinWorkspace`] holds every per-run buffer a PD3 invocation
//! needs: the candidate / neighbor bitmaps, the nearest-neighbor minima
//! vector, the per-round task and row lists, the recycled tile-output
//! blocks, and the survivor list.  MERLIN's per-length retry loop
//! (`coordinator/merlin.rs`), the streaming monitor's refresh path
//! (`coordinator/streaming.rs`), and the distributed exchange simulation
//! (`coordinator/distributed.rs`) all recycle one arena across every
//! [`super::drag::pd3_into`] call instead of reallocating ~five vectors
//! plus two bitmaps per call.  The counting-allocator suite
//! (`rust/tests/alloc_steady_state.rs`) proves the retry loop and the
//! warm streaming ingest loop reach a zero-allocation steady state.

use crate::core::bitmap::Bitmap;
use crate::engines::TileTask;
use crate::runtime::types::TileOutputs;

use super::drag::Discord;

/// Arena reuse counters (see [`MerlinWorkspace::counters`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkspaceCounters {
    /// PD3 runs that rebound this arena.
    pub resets: u64,
    /// Rebinds whose window count exceeded every earlier run's (cold
    /// start, or a longer series).  Gauged by the minima vector only —
    /// round-scoped buffers (tasks, tile blocks) can still grow to
    /// their own high-water marks on later calls without being counted
    /// here.
    pub grows: u64,
}

impl WorkspaceCounters {
    /// Counter deltas relative to an earlier snapshot (the arena is
    /// lifetime-counted; stepped sweeps scope it per step so pooled
    /// arenas attribute reuse to the tenant that actually ran).
    pub fn since(self, earlier: WorkspaceCounters) -> WorkspaceCounters {
        WorkspaceCounters {
            resets: self.resets.saturating_sub(earlier.resets),
            grows: self.grows.saturating_sub(earlier.grows),
        }
    }

    /// Fold another snapshot's counts into this one.
    pub fn accumulate(&mut self, other: WorkspaceCounters) {
        self.resets += other.resets;
        self.grows += other.grows;
    }
}

/// Reusable working set for [`super::drag::pd3_into`] (module docs).
#[derive(Debug, Default)]
pub struct MerlinWorkspace {
    /// `Cand` bitmap (Alg. 3 l.1).
    pub(crate) cand: Bitmap,
    /// `Neighbor` bitmap (only consulted under
    /// [`super::drag::Pd3Config::deferred_neighbor_kill`]).
    pub(crate) neighbor: Bitmap,
    /// Running nearest-neighbor squared-distance minima per window.
    pub(crate) nn_dist: Vec<f64>,
    /// Tile tasks of the current round.
    pub(crate) tasks: Vec<TileTask>,
    /// (segment, chunk) index pair per task of the current round.
    pub(crate) rows: Vec<(usize, usize)>,
    /// Recycled engine output blocks (`Engine::compute_tiles_into`).
    pub(crate) tile_buf: Vec<TileOutputs>,
    /// Survivors of the last run.
    pub(crate) discords: Vec<Discord>,
    counters: WorkspaceCounters,
}

impl MerlinWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Survivors of the last PD3 run (exact nnDist, ED units).
    pub fn discords(&self) -> &[Discord] {
        &self.discords
    }

    /// Number of currently live candidates (the distributed
    /// coordinator's exchanged-set size).
    pub fn candidate_count(&self) -> usize {
        self.cand.count()
    }

    /// Number of live candidates with window index in `[lo, hi)` —
    /// word-masked, so a node counting its own slice pays O(slice).
    pub fn candidate_count_in(&self, lo: usize, hi: usize) -> usize {
        self.cand.count_in_range(lo, hi)
    }

    /// Lifetime reuse counters.
    pub fn counters(&self) -> WorkspaceCounters {
        self.counters
    }

    /// Rebind to `nwin` windows with every window a live candidate
    /// (classic PD3).  Reuses all storage; only growth allocates.
    pub(crate) fn reset_all_candidates(&mut self, nwin: usize) {
        self.counters.resets += 1;
        if self.nn_dist.capacity() < nwin {
            self.counters.grows += 1;
        }
        self.cand.reset_ones(nwin);
        self.neighbor.reset_ones(nwin);
        self.nn_dist.clear();
        self.nn_dist.resize(nwin, f64::INFINITY);
        self.discords.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_recycles_and_counts() {
        let mut ws = MerlinWorkspace::new();
        ws.reset_all_candidates(500);
        assert_eq!(ws.cand.count(), 500);
        assert_eq!(ws.nn_dist.len(), 500);
        assert!(ws.nn_dist.iter().all(|d| d.is_infinite()));
        let ptr = ws.nn_dist.as_ptr();
        ws.cand.clear(3);
        ws.nn_dist[3] = 1.0;
        ws.discords.push(Discord { idx: 3, m: 8, nn_dist: 1.0 });
        ws.reset_all_candidates(400);
        assert_eq!(ws.cand.count(), 400);
        assert!(ws.discords.is_empty());
        assert!(ws.nn_dist.iter().all(|d| d.is_infinite()));
        assert_eq!(ws.nn_dist.as_ptr(), ptr, "shrinking reset reallocated");
        let c = ws.counters();
        assert_eq!(c.resets, 2);
        assert_eq!(c.grows, 1, "only the cold reset grows");
    }
}
