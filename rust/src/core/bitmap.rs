//! Candidate / neighbor bitmaps (Fig. 1 of the paper).
//!
//! PD3 tracks which subsequences are still discord candidates (`Cand`) and
//! which have been ruled out as nearest neighbors of pruned candidates
//! (`Neighbor`).  Both are dense bitsets over the `N = n - m + 1`
//! subsequences, with the word-level operations the refinement phase needs
//! (elementwise conjunction, any-in-range for segment early-stop).

/// Dense bitset over subsequence indices.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Bitmap {
    len: usize,
    words: Vec<u64>,
}

impl Bitmap {
    /// All-true bitmap of `len` bits (candidates start all-live, Alg. 3 l.1).
    pub fn ones(len: usize) -> Self {
        let nwords = len.div_ceil(64);
        let mut words = vec![u64::MAX; nwords];
        Self::mask_tail(len, &mut words);
        Self { len, words }
    }

    /// All-false bitmap.
    pub fn zeros(len: usize) -> Self {
        Self { len, words: vec![0; len.div_ceil(64)] }
    }

    /// Reinitialize in place to all-true over `len` bits, reusing the
    /// word storage — the workspace-recycling hook: once the buffer has
    /// reached capacity this never touches the allocator.
    pub fn reset_ones(&mut self, len: usize) {
        let nwords = len.div_ceil(64);
        self.words.clear();
        self.words.resize(nwords, u64::MAX);
        Self::mask_tail(len, &mut self.words);
        self.len = len;
    }

    fn mask_tail(len: usize, words: &mut [u64]) {
        let rem = len % 64;
        if rem != 0 {
            if let Some(last) = words.last_mut() {
                *last &= (1u64 << rem) - 1;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let w = &mut self.words[i / 64];
        if v {
            *w |= 1 << (i % 64);
        } else {
            *w &= !(1 << (i % 64));
        }
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        self.set(i, false);
    }

    /// Elementwise conjunction (`Cand <- Cand AND Neighbor`, Alg. 4 l.2).
    pub fn and_with(&mut self, other: &Bitmap) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Is any bit in `[start, end)` set?  (Segment liveness check,
    /// Alg. 3 l.14 / Alg. 4 l.3.)  `end` is clamped to `len`.
    pub fn any_in_range(&self, start: usize, end: usize) -> bool {
        let end = end.min(self.len);
        if start >= end {
            return false;
        }
        let (ws, wo) = (start / 64, start % 64);
        let (we, eo) = ((end - 1) / 64, (end - 1) % 64 + 1);
        if ws == we {
            let mask = (u64::MAX << wo) & (u64::MAX >> (64 - eo));
            return self.words[ws] & mask != 0;
        }
        if self.words[ws] & (u64::MAX << wo) != 0 {
            return true;
        }
        for w in &self.words[ws + 1..we] {
            if *w != 0 {
                return true;
            }
        }
        self.words[we] & (u64::MAX >> (64 - eo)) != 0
    }

    /// Number of set bits in `[start, end)` (`end` clamped to `len`).
    /// Word-masked, so counting a narrow slice of a huge bitmap costs
    /// O(slice), not O(len) — the distributed coordinator's per-node
    /// metric path.
    pub fn count_in_range(&self, start: usize, end: usize) -> usize {
        let end = end.min(self.len);
        if start >= end {
            return 0;
        }
        let (ws, wo) = (start / 64, start % 64);
        let (we, eo) = ((end - 1) / 64, (end - 1) % 64 + 1);
        if ws == we {
            let mask = (u64::MAX << wo) & (u64::MAX >> (64 - eo));
            return (self.words[ws] & mask).count_ones() as usize;
        }
        let mut c = (self.words[ws] & (u64::MAX << wo)).count_ones() as usize;
        for w in &self.words[ws + 1..we] {
            c += w.count_ones() as usize;
        }
        c + (self.words[we] & (u64::MAX >> (64 - eo))).count_ones() as usize
    }

    /// Iterate indices of set bits.
    pub fn iter_set(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ones_and_tail_mask() {
        let b = Bitmap::ones(70);
        assert_eq!(b.count(), 70);
        assert!(b.get(69));
        let b = Bitmap::ones(64);
        assert_eq!(b.count(), 64);
        let b = Bitmap::ones(0);
        assert_eq!(b.count(), 0);
    }

    #[test]
    fn set_get_clear() {
        let mut b = Bitmap::zeros(130);
        b.set(0, true);
        b.set(64, true);
        b.set(129, true);
        assert_eq!(b.count(), 3);
        assert!(b.get(0) && b.get(64) && b.get(129));
        b.clear(64);
        assert!(!b.get(64));
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn and_with() {
        let mut a = Bitmap::ones(100);
        let mut n = Bitmap::ones(100);
        n.clear(10);
        n.clear(99);
        a.and_with(&n);
        assert!(!a.get(10) && !a.get(99) && a.get(11));
        assert_eq!(a.count(), 98);
    }

    #[test]
    fn any_in_range() {
        let mut b = Bitmap::zeros(256);
        b.set(100, true);
        assert!(b.any_in_range(100, 101));
        assert!(b.any_in_range(0, 256));
        assert!(b.any_in_range(64, 128));
        assert!(!b.any_in_range(0, 100));
        assert!(!b.any_in_range(101, 256));
        assert!(!b.any_in_range(100, 100));
        // end past len clamps
        assert!(b.any_in_range(0, 10_000));
    }

    #[test]
    fn any_in_range_word_boundaries() {
        let mut b = Bitmap::zeros(192);
        b.set(63, true);
        assert!(b.any_in_range(0, 64));
        assert!(!b.any_in_range(64, 192));
        b.clear(63);
        b.set(64, true);
        assert!(!b.any_in_range(0, 64));
        assert!(b.any_in_range(64, 65));
    }

    #[test]
    fn any_in_range_boundary_cases() {
        // Empty bitmap: every query is false.
        let b = Bitmap::zeros(0);
        assert!(!b.any_in_range(0, 0));
        assert!(!b.any_in_range(0, 10));
        assert!(!b.any_in_range(5, 3));

        // Single-bit bitmap.
        let mut b = Bitmap::zeros(1);
        assert!(!b.any_in_range(0, 1));
        b.set(0, true);
        assert!(b.any_in_range(0, 1));
        assert!(b.any_in_range(0, usize::MAX)); // end clamps
        assert!(!b.any_in_range(1, 1));

        // start >= len.
        let mut b = Bitmap::zeros(100);
        b.set(99, true);
        assert!(!b.any_in_range(100, 200));
        assert!(b.any_in_range(99, 100));
        assert!(b.any_in_range(99, 1_000_000));

        // Inverted / empty ranges.
        assert!(!b.any_in_range(50, 50));
        assert!(!b.any_in_range(60, 40));
    }

    #[test]
    fn any_in_range_exact_word_edges() {
        // Bits at every word edge of a 3-word bitmap.
        for bit in [0usize, 63, 64, 127, 128, 191] {
            let mut b = Bitmap::zeros(192);
            b.set(bit, true);
            // Tight range hits.
            assert!(b.any_in_range(bit, bit + 1), "bit {bit}");
            // One-off ranges miss.
            if bit > 0 {
                assert!(!b.any_in_range(0, bit), "bit {bit} [0,bit)");
            }
            assert!(!b.any_in_range(bit + 1, 192), "bit {bit} (bit,192)");
            // Ranges spanning multiple words still find it.
            assert!(b.any_in_range(0, 192));
            assert!(b.any_in_range(bit.saturating_sub(65), (bit + 66).min(192)));
        }
    }

    #[test]
    fn any_in_range_full_word_span_middle() {
        // A set bit in a middle whole word must be found by ranges that
        // enter the word-span loop (start and end in different words).
        let mut b = Bitmap::zeros(256);
        b.set(100, true);
        assert!(b.any_in_range(10, 250));
        assert!(b.any_in_range(64, 128));
        assert!(b.any_in_range(65, 127));
        b.clear(100);
        assert!(!b.any_in_range(10, 250));
    }

    #[test]
    fn any_in_range_tail_word_masking() {
        // len not a multiple of 64: the tail mask must not leak phantom
        // bits into range queries ending at/after len.
        let b = Bitmap::ones(70);
        assert!(b.any_in_range(64, 70));
        assert!(b.any_in_range(69, 70));
        assert!(b.any_in_range(69, 100)); // clamped
        let mut b = Bitmap::zeros(70);
        b.set(69, true);
        assert!(b.any_in_range(64, 70));
        assert!(!b.any_in_range(64, 69));
    }

    #[test]
    fn count_in_range_matches_naive() {
        let mut b = Bitmap::zeros(200);
        for i in [0, 3, 63, 64, 65, 127, 128, 150, 199] {
            b.set(i, true);
        }
        for (s, e) in [(0, 200), (0, 64), (64, 128), (63, 65), (150, 150), (150, 151), (10, 63),
            (128, 1_000), (199, 200), (5, 3)]
        {
            let naive = b.iter_set().filter(|&i| i >= s && i < e.min(200)).count();
            assert_eq!(b.count_in_range(s, e), naive, "[{s},{e})");
        }
        assert_eq!(b.count_in_range(0, 200), b.count());
    }

    #[test]
    fn reset_reuses_storage() {
        let mut b = Bitmap::ones(200);
        b.clear(5);
        let ptr = {
            b.reset_ones(130);
            assert_eq!(b.len(), 130);
            assert_eq!(b.count(), 130, "reset_ones must revive cleared bits");
            assert!(b.get(129) && !b.any_in_range(130, 200));
            b.words.as_ptr()
        };
        // Shrinking and re-growing within capacity must not reallocate.
        b.reset_ones(64);
        assert_eq!(b.count(), 64);
        b.reset_ones(190);
        assert_eq!(b.count(), 190);
        assert_eq!(b.words.as_ptr(), ptr, "reset within capacity reallocated");
        // Tail masking after a reset: phantom bits must not leak.
        b.reset_ones(70);
        assert_eq!(b.count(), 70);
        assert!(!b.any_in_range(70, 1_000));
    }

    #[test]
    fn iter_set() {
        let mut b = Bitmap::zeros(200);
        for i in [0, 3, 64, 65, 199] {
            b.set(i, true);
        }
        let got: Vec<usize> = b.iter_set().collect();
        assert_eq!(got, vec![0, 3, 64, 65, 199]);
    }
}
