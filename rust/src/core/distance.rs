//! Normalized Euclidean distance (Eqs. 4-6) and early-abandoning variants.
//!
//! The whole stack works with the *squared* z-normalized Euclidean
//! distance, as the paper does ("we employ the square of the Euclidean
//! metric", §2.1).  Two equivalent forms are implemented:
//!
//! - [`ed2norm`] — direct: z-normalize both windows, sum squared diffs.
//! - [`ed2norm_from_qt`] — the Mueen dot-product form (Eq. 6) used by all
//!   fast paths:  `ED^2 = 2m * (1 - (QT - m*mu_a*mu_b) / (m*sig_a*sig_b))`.
//!
//! The correlation term is clamped to `[-1, 1]` so rounding can never
//! produce a (meaningless) negative squared distance; the maximum possible
//! value is `4m`, i.e. max ED is `2*sqrt(m)` — the bound MERLIN uses to
//! seed its threshold search.

use super::stats::SIGMA_FLOOR;

/// Lane width of the default multi-lane tile kernel
/// (`TileKernel::Lanes4`): columns are processed in fixed `[f64; LANES]`
/// chunks with a scalar tail.  f64x4 is one AVX2 register.  Wider and
/// narrower kernels are *not* constant bumps on this value: every lane
/// variant (`Lanes4`, the f64x8 AVX-512 `Lanes8`, the f32
/// `Lanes4F32`) is an instantiation of the width/element-generic
/// [`ed2_lane_chunk_w`] via [`LaneElem`], and `TileKernel::Auto`
/// picks between `Lanes8` and `Lanes4` once per process with
/// `is_x86_feature_detected!("avx512f")` (cached in a `OnceLock`; see
/// `engines::TileKernel::resolve` and EXPERIMENTS.md §SIMD for the
/// dispatch table).
pub const LANES: usize = 4;

/// Widest lane width any kernel instantiates (`TileKernel::Lanes8`).
/// Tile scratch rows are padded to a multiple of this so every kernel
/// can load full-width chunks without overrunning a live row.
pub const MAX_LANES: usize = 8;

/// Relative threshold for treating a window as constant ("flat"):
/// `sigma <= FLAT_EPS * max(|mu|, 1)` (see [`is_flat`]).
///
/// The Eq. 6 correlation form is numerically meaningless for flat windows
/// (0/0 after catastrophic cancellation), so the stack pins their
/// semantics instead: flat-vs-flat distance is 0 (twins), flat-vs-normal
/// is `2m` (zero correlation).  The test is *relative* because sliding
/// (cumsum/recurrence) statistics carry rounding drift proportional to
/// `eps * E[x^2]`: a truly constant window at level 1e6 can report a
/// sigma around 1e-1 from drift alone.  Any window whose true relative
/// variation is below 1e-6 has no numerically meaningful z-normalized
/// shape, so pinning it to the flat convention is well-defined and — most
/// importantly — *consistent* across the f64 native engine, the f32 AOT
/// kernel, and the oracles.  Must match `FLAT_EPS` in
/// `python/compile/shapes.py`.
pub const FLAT_EPS: f64 = 1e-6;

/// The stack-wide flat-window test (see [`FLAT_EPS`]).
#[inline]
pub fn is_flat(sig: f64, mu: f64) -> bool {
    sig <= FLAT_EPS * mu.abs().max(1.0)
}

/// [`is_flat`] for a raw window, deriving mu/sigma on the fly (one
/// O(m) pass; used where no precomputed rolling stats cover the
/// window, e.g. the stream monitor's incremental check).
pub fn window_is_flat(w: &[f64]) -> bool {
    let (mu, sig) = window_stats(w);
    is_flat(sig, mu)
}

/// z-normalize `w` into `out` and report its flatness in one pass
/// (mu/sigma are derived once and shared — the stream monitor's
/// per-push path would otherwise compute them twice).
pub fn znorm_into_flat(w: &[f64], out: &mut [f64]) -> bool {
    let (mu, sig) = window_stats(w);
    for (o, &x) in out.iter_mut().zip(w) {
        *o = (x - mu) / sig;
    }
    is_flat(sig, mu)
}

fn window_stats(w: &[f64]) -> (f64, f64) {
    let m = w.len() as f64;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    for &x in w {
        s1 += x;
        s2 += x * x;
    }
    let mu = s1 / m;
    let sig = (s2 / m - mu * mu).max(0.0).sqrt().max(SIGMA_FLOOR);
    (mu, sig)
}

/// z-normalize a window into `out` (Eq. 4 with the sigma floor).
pub fn znorm_into(w: &[f64], out: &mut [f64]) {
    let m = w.len() as f64;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    for &x in w {
        s1 += x;
        s2 += x * x;
    }
    let mu = s1 / m;
    let sig = (s2 / m - mu * mu).max(0.0).sqrt().max(SIGMA_FLOOR);
    for (o, &x) in out.iter_mut().zip(w) {
        *o = (x - mu) / sig;
    }
}

/// z-normalize a window, allocating.
pub fn znorm(w: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; w.len()];
    znorm_into(w, &mut out);
    out
}

fn sigma_of(w: &[f64]) -> f64 {
    let m = w.len() as f64;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    for &x in w {
        s1 += x;
        s2 += x * x;
    }
    let mu = s1 / m;
    (s2 / m - mu * mu).max(0.0).sqrt().max(SIGMA_FLOOR)
}

/// Squared z-normalized Euclidean distance, direct form (Eq. 5 over Eq. 4),
/// with the flat-window convention (see [`FLAT_EPS`]).
pub fn ed2norm(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mean = |w: &[f64]| w.iter().sum::<f64>() / w.len() as f64;
    let flat_a = is_flat(sigma_of(a), mean(a));
    let flat_b = is_flat(sigma_of(b), mean(b));
    match (flat_a, flat_b) {
        (true, true) => return 0.0,
        (true, false) | (false, true) => return 2.0 * a.len() as f64,
        _ => {}
    }
    let an = znorm(a);
    let bn = znorm(b);
    an.iter().zip(&bn).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Squared z-normalized Euclidean distance from a raw dot product (Eq. 6).
///
/// `qt = dot(a, b)` over the *raw* windows; `mu/sig` are their raw stats.
// hot-path: Eq. 6 distance, evaluated once per candidate pair in every
// slow-path tile column and stream refresh.
#[inline]
pub fn ed2norm_from_qt(qt: f64, m: usize, mu_a: f64, sig_a: f64, mu_b: f64, sig_b: f64) -> f64 {
    let mf = m as f64;
    let flat_a = is_flat(sig_a, mu_a);
    let flat_b = is_flat(sig_b, mu_b);
    if flat_a || flat_b {
        return if flat_a && flat_b { 0.0 } else { 2.0 * mf };
    }
    // panic-free: float division (f64 operands; sig floored at
    // SIGMA_FLOOR and the flat guard above keeps it meaningful).
    let corr = (qt - mf * mu_a * mu_b) / (mf * sig_a * sig_b);
    corr_to_ed2(corr, 2.0 * mf)
}

/// Clamped Eq. 6 correlation → squared distance: `two_m * (1 - clamp(corr))`.
///
/// The single definition of the clamp both tile kernels share — keeping
/// it here (rather than inlined per kernel) is what makes "same clamp
/// decisions" a structural property instead of a testing hope.  NaN
/// passes through (`clamp(NaN) = NaN`), so a NaN-contaminated column
/// yields a NaN distance, which every downstream fold ignores (`min`
/// keeps the other operand, `d < r2` is false).
// hot-path: shared clamp of both tile kernels, once per fast-path column.
#[inline]
pub fn corr_to_ed2(corr: f64, two_m: f64) -> f64 {
    two_m * (1.0 - corr.clamp(-1.0, 1.0))
}

/// Did the Eq. 6 correlation leave `[-1, 1]` — i.e. will
/// [`corr_to_ed2`]'s clamp bite?  NaN reports `false` (the clamp
/// propagates it rather than saturating).  Both tile kernels count this
/// per fast-path column into `EnginePerfCounters::clamp_saturations`;
/// equal counts across kernels certify equal clamp decisions.
// hot-path: saturation gauge, once per fast-path column.
#[inline]
pub fn corr_saturates(corr: f64) -> bool {
    corr > 1.0 || corr < -1.0
}

/// Element type of a width-generic tile-kernel lane.
///
/// The per-row kernel passes (`engines/scratch.rs`) and the lane chunk
/// below are generic over this trait so `Lanes4` (f64x4), `Lanes8`
/// (f64x8) and `Lanes4F32` (f32x4) share one set of loop bodies instead
/// of three near-copies.  The `f64` impl delegates straight to the
/// scalar helpers above ([`corr_to_ed2`], [`corr_saturates`], identity
/// `from_f64`), which makes "f64 lane kernels are bit-identical to the
/// scalar oracle" a structural property rather than a testing hope; the
/// `f32` impl performs the *same operation sequence* in f32, and its
/// rounding is what the tolerance band in
/// `rust/tests/kernel_conformance.rs` budgets for.
pub trait LaneElem:
    Copy
    + PartialOrd
    + core::ops::Add<Output = Self>
    + core::ops::Sub<Output = Self>
    + core::ops::Mul<Output = Self>
    + core::fmt::Debug
    + 'static
{
    const ZERO: Self;
    const INFINITY: Self;
    /// Narrow (f32) or pass through (f64) a series/stat value.
    fn from_f64(x: f64) -> Self;
    /// Widen back for the f64 tile outputs (exact for both impls).
    fn to_f64(self) -> f64;
    /// IEEE minNum: propagates the non-NaN operand, like `f64::min`.
    fn min(self, other: Self) -> Self;
    /// The shared clamp, [`corr_to_ed2`], at this element's precision.
    fn corr_to_ed2(self, two_m: Self) -> Self;
    /// The shared saturation gauge, [`corr_saturates`].
    fn saturates(self) -> bool;
}

impl LaneElem for f64 {
    const ZERO: Self = 0.0;
    const INFINITY: Self = f64::INFINITY;
    #[inline]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn min(self, other: Self) -> Self {
        f64::min(self, other)
    }
    #[inline]
    fn corr_to_ed2(self, two_m: Self) -> Self {
        corr_to_ed2(self, two_m)
    }
    #[inline]
    fn saturates(self) -> bool {
        corr_saturates(self)
    }
}

impl LaneElem for f32 {
    const ZERO: Self = 0.0;
    const INFINITY: Self = f32::INFINITY;
    #[inline]
    fn from_f64(x: f64) -> Self {
        // order: deliberate f64 -> f32 narrowing — the Lanes4F32 kernel's
        // whole point; the banded comparator in kernel_conformance.rs
        // budgets for exactly this rounding (EXPERIMENTS.md §SIMD
        // derives the bound, ANALYSIS.md catalogues the note).
        x as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn min(self, other: Self) -> Self {
        f32::min(self, other)
    }
    #[inline]
    fn corr_to_ed2(self, two_m: Self) -> Self {
        two_m * (1.0 - self.clamp(-1.0, 1.0))
    }
    #[inline]
    fn saturates(self) -> bool {
        self > 1.0 || self < -1.0
    }
}

/// One `W`-wide chunk of a tile kernel's fast distance path:
/// `dist[l] = two_m * (1 - clamp((qt[l] - mmu_b[l]*mu_a) *
/// (inv_msig_b[l]*inv_sig_a)))`, all lanes independent and branchless.
/// Returns the number of saturated (clamped) lanes.
///
/// Per-element operation order is identical to the scalar loop, so the
/// f64 instantiations (`W = 4` for `Lanes4`, `W = 8` for `Lanes8`) are
/// bit-identical to the scalar oracle at any width (Rust never
/// contracts float ops into FMAs; pinned by
/// `rust/tests/kernel_conformance.rs`).  Fixed-size array refs give the
/// autovectorizer exact extents — no in-loop bounds checks.
// hot-path: every lane kernel's distance chunk, every fast-path column.
#[inline]
pub fn ed2_lane_chunk_w<E: LaneElem, const W: usize>(
    qt: &[E; W],
    mmu_b: &[E; W],
    inv_msig_b: &[E; W],
    mu_a: E,
    inv_sig_a: E,
    two_m: E,
    dist: &mut [E; W],
) -> u64 {
    let mut corr = [E::ZERO; W];
    for l in 0..W {
        corr[l] = (qt[l] - mmu_b[l] * mu_a) * (inv_msig_b[l] * inv_sig_a);
    }
    let mut sat = 0u64;
    for &c in &corr {
        sat += c.saturates() as u64;
    }
    for l in 0..W {
        dist[l] = corr[l].corr_to_ed2(two_m);
    }
    sat
}

/// [`ed2_lane_chunk_w`] at the default width/element (`f64x4`) — the
/// `Lanes4` kernel's chunk, kept as a named entry point for the
/// no-panic probe and the PR-4 conformance tests.
// hot-path: the Lanes4 kernel's distance chunk, every fast-path column.
#[inline]
pub fn ed2_lane_chunk(
    qt: &[f64; LANES],
    mmu_b: &[f64; LANES],
    inv_msig_b: &[f64; LANES],
    mu_a: f64,
    inv_sig_a: f64,
    two_m: f64,
    dist: &mut [f64; LANES],
) -> u64 {
    ed2_lane_chunk_w::<f64, LANES>(qt, mmu_b, inv_msig_b, mu_a, inv_sig_a, two_m, dist)
}

/// Dot product of two raw f64 windows at element precision `E`: each
/// factor is narrowed through [`LaneElem::from_f64`] *before* the
/// multiply, so the f32 instantiation models an accelerator that
/// received f32 inputs (not an f64 dot rounded at the end).  The f64
/// instantiation is the identity narrowing — bit-identical to the
/// historical `dot`.
// hot-path: QT seeding — every tile's first row and every seed-cache
// miss pays one call per column.
#[inline]
pub fn dot_w<E: LaneElem>(a: &[f64], b: &[f64]) -> E {
    debug_assert_eq!(a.len(), b.len());
    // Four-lane manual unroll: reliably autovectorizes and keeps four
    // independent accumulators (better rounding + ILP than a single chain).
    let mut acc = [E::ZERO; 4];
    let chunks = a.len() / 4;
    // panic-free: i ranges over c*4 with c < chunks = a.len()/4, so
    // i+3 < a.len(); the tail loop is bounded by a.len(); b is the
    // same length (debug-asserted, guaranteed by every caller).
    for c in 0..chunks {
        let i = c * 4;
        acc[0] = acc[0] + E::from_f64(a[i]) * E::from_f64(b[i]);
        acc[1] = acc[1] + E::from_f64(a[i + 1]) * E::from_f64(b[i + 1]);
        acc[2] = acc[2] + E::from_f64(a[i + 2]) * E::from_f64(b[i + 2]);
        acc[3] = acc[3] + E::from_f64(a[i + 3]) * E::from_f64(b[i + 3]);
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s = s + E::from_f64(a[i]) * E::from_f64(b[i]);
    }
    s
}

/// Dot product of two raw windows ([`dot_w`] at f64).
// hot-path: QT seeding — every tile's first row and every seed-cache
// miss pays one call per column.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot_w::<f64>(a, b)
}

/// Early-abandoning squared distance between two *pre-normalized* windows.
///
/// Returns `None` as soon as the partial sum exceeds `cutoff` (the
/// `EarlyAbandonED` of Alg. 2); otherwise the exact squared distance.
// hot-path: candidate refinement inner loop (Alg. 2 EarlyAbandonED).
#[inline]
pub fn ed2_early_abandon(an: &[f64], bn: &[f64], cutoff: f64) -> Option<f64> {
    debug_assert_eq!(an.len(), bn.len());
    let mut s = 0.0;
    // Check the abandon condition every 8 lanes: per-element checks cost
    // more than they save (measured in the microbench suite).
    let mut i = 0;
    let n = an.len();
    // panic-free: k < i+8 <= n = an.len() in the blocked loop and
    // k < n in the tail; bn has the same length (debug-asserted,
    // guaranteed by both call sites in the tile pipeline).
    while i + 8 <= n {
        for k in i..i + 8 {
            let d = an[k] - bn[k];
            s += d * d;
        }
        if s >= cutoff {
            return None;
        }
        i += 8;
    }
    // panic-free: tail indices k < n = an.len() = bn.len().
    for k in i..n {
        let d = an[k] - bn[k];
        s += d * d;
    }
    if s >= cutoff {
        None
    } else {
        Some(s)
    }
}

/// Maximum possible ED (non-squared) between two z-normalized m-windows:
/// `2*sqrt(m)` — MERLIN's initial threshold (Alg. 1 line 1).
#[inline]
pub fn max_ed(m: usize) -> f64 {
    2.0 * (m as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn qt_form_matches_direct() {
        let mut rng = Rng::seed(3);
        let a: Vec<f64> = (0..64).map(|_| rng.normal() * 3.0 + 100.0).collect();
        let b: Vec<f64> = (0..64).map(|_| rng.normal() * 3.0 + 100.0).collect();
        let m = a.len();
        let stat = |w: &[f64]| {
            let mu = w.iter().sum::<f64>() / m as f64;
            let ms = w.iter().map(|x| x * x).sum::<f64>() / m as f64;
            (mu, (ms - mu * mu).max(0.0).sqrt().max(SIGMA_FLOOR))
        };
        let (ma, sa) = stat(&a);
        let (mb, sb) = stat(&b);
        let d1 = ed2norm(&a, &b);
        let d2 = ed2norm_from_qt(dot(&a, &b), m, ma, sa, mb, sb);
        assert!((d1 - d2).abs() < 1e-6, "{d1} vs {d2}");
    }

    #[test]
    fn distance_of_identical_windows_is_zero() {
        let a: Vec<f64> = (0..32).map(|x| (x as f64).cos()).collect();
        assert!(ed2norm(&a, &a) < 1e-12);
        // Scale/offset invariance of z-normalization.
        let b: Vec<f64> = a.iter().map(|x| 5.0 * x - 3.0).collect();
        assert!(ed2norm(&a, &b) < 1e-12);
    }

    #[test]
    fn anticorrelated_hits_upper_bound() {
        let a: Vec<f64> = (0..32).map(|x| (x as f64 * 0.7).sin()).collect();
        let b: Vec<f64> = a.iter().map(|x| -x).collect();
        let d = ed2norm(&a, &b);
        let bound = max_ed(32).powi(2);
        assert!((d - bound).abs() < 1e-9, "{d} vs {bound}");
    }

    #[test]
    fn constant_windows_are_finite() {
        let a = vec![2.0; 16];
        let b = vec![5.0; 16];
        let d = ed2norm(&a, &b);
        assert!(d.is_finite());
        // Both normalize to ~zero vectors -> distance ~0.
        assert!(d < 1e-6);
    }

    #[test]
    fn early_abandon_agrees_when_not_abandoned() {
        let mut rng = Rng::seed(9);
        for _ in 0..50 {
            let a: Vec<f64> = (0..37).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..37).map(|_| rng.normal()).collect();
            let an = znorm(&a);
            let bn = znorm(&b);
            let exact: f64 = an.iter().zip(&bn).map(|(x, y)| (x - y) * (x - y)).sum();
            match ed2_early_abandon(&an, &bn, exact + 1e-9) {
                Some(d) => assert!((d - exact).abs() < 1e-9),
                None => panic!("abandoned below cutoff"),
            }
            assert!(ed2_early_abandon(&an, &bn, exact * 0.5).is_none() || exact < 1e-12);
        }
    }

    #[test]
    fn clamp_prevents_negative_distance() {
        // Force corr slightly above 1 via rounding-sized perturbation.
        let d = ed2norm_from_qt(16.0000001, 16, 0.0, 1.0, 0.0, 1.0);
        assert!(d >= 0.0);
    }

    #[test]
    fn corr_saturation_predicate_matches_clamp() {
        for (corr, sat) in [
            (0.5, false),
            (1.0, false),
            (-1.0, false),
            (1.0 + 1e-12, true),
            (-1.5, true),
            (f64::INFINITY, true),
            (f64::NEG_INFINITY, true),
            (f64::NAN, false),
        ] {
            assert_eq!(corr_saturates(corr), sat, "corr={corr}");
            let d = corr_to_ed2(corr, 8.0);
            if corr.is_nan() {
                assert!(d.is_nan(), "NaN must propagate, got {d}");
            } else {
                // Saturation iff the clamp changed the value.
                let clamped = corr.clamp(-1.0, 1.0);
                assert_eq!(sat, clamped != corr);
                assert!((0.0..=16.0).contains(&d), "corr={corr}: d={d}");
            }
        }
    }

    #[test]
    fn lane_chunk_is_bit_identical_to_scalar_ops() {
        let mut rng = Rng::seed(11);
        for case in 0..50 {
            let qt: [f64; LANES] = std::array::from_fn(|_| rng.normal() * 40.0);
            let mmu_b: [f64; LANES] = std::array::from_fn(|_| rng.normal() * 3.0);
            let inv_msig_b: [f64; LANES] = std::array::from_fn(|_| rng.range(0.01, 2.0));
            let (mu_a, inv_sig_a) = (rng.normal(), rng.range(0.05, 3.0));
            let two_m = 2.0 * rng.int_in(4, 64) as f64;
            let mut lane = [0.0f64; LANES];
            let got_sat =
                ed2_lane_chunk(&qt, &mmu_b, &inv_msig_b, mu_a, inv_sig_a, two_m, &mut lane);
            let mut want_sat = 0u64;
            for l in 0..LANES {
                let corr = (qt[l] - mmu_b[l] * mu_a) * (inv_msig_b[l] * inv_sig_a);
                want_sat += corr_saturates(corr) as u64;
                let want = corr_to_ed2(corr, two_m);
                assert_eq!(
                    lane[l].to_bits(),
                    want.to_bits(),
                    "case {case} lane {l}: {} vs {want}",
                    lane[l]
                );
            }
            assert_eq!(got_sat, want_sat, "case {case}");
        }
    }

    #[test]
    fn lane_chunk_w8_is_bit_identical_to_scalar_ops() {
        // Same oracle as the LANES=4 test above, at the Lanes8 width:
        // the f64 instantiation must stay bit-exact at *any* W.
        let mut rng = Rng::seed(13);
        for case in 0..50 {
            let qt: [f64; MAX_LANES] = std::array::from_fn(|_| rng.normal() * 40.0);
            let mmu_b: [f64; MAX_LANES] = std::array::from_fn(|_| rng.normal() * 3.0);
            let inv_msig_b: [f64; MAX_LANES] = std::array::from_fn(|_| rng.range(0.01, 2.0));
            let (mu_a, inv_sig_a) = (rng.normal(), rng.range(0.05, 3.0));
            let two_m = 2.0 * rng.int_in(4, 64) as f64;
            let mut lane = [0.0f64; MAX_LANES];
            let got_sat = ed2_lane_chunk_w::<f64, MAX_LANES>(
                &qt,
                &mmu_b,
                &inv_msig_b,
                mu_a,
                inv_sig_a,
                two_m,
                &mut lane,
            );
            let mut want_sat = 0u64;
            for l in 0..MAX_LANES {
                let corr = (qt[l] - mmu_b[l] * mu_a) * (inv_msig_b[l] * inv_sig_a);
                want_sat += corr_saturates(corr) as u64;
                let want = corr_to_ed2(corr, two_m);
                assert_eq!(lane[l].to_bits(), want.to_bits(), "case {case} lane {l}");
            }
            assert_eq!(got_sat, want_sat, "case {case}");
        }
    }

    #[test]
    fn f32_lane_chunk_matches_f32_scalar_sequence() {
        // The f32 instantiation must perform the exact scalar f32
        // operation sequence per lane (same structural guarantee the
        // f64 kernels get, one precision down).
        let mut rng = Rng::seed(19);
        for case in 0..50 {
            let qt: [f32; LANES] = std::array::from_fn(|_| (rng.normal() * 40.0) as f32);
            let mmu_b: [f32; LANES] = std::array::from_fn(|_| (rng.normal() * 3.0) as f32);
            let inv_msig_b: [f32; LANES] = std::array::from_fn(|_| rng.range(0.01, 2.0) as f32);
            let (mu_a, inv_sig_a) = (rng.normal() as f32, rng.range(0.05, 3.0) as f32);
            let two_m = 2.0f32 * rng.int_in(4, 64) as f32;
            let mut lane = [0.0f32; LANES];
            let got_sat = ed2_lane_chunk_w::<f32, LANES>(
                &qt,
                &mmu_b,
                &inv_msig_b,
                mu_a,
                inv_sig_a,
                two_m,
                &mut lane,
            );
            let mut want_sat = 0u64;
            for l in 0..LANES {
                let corr = (qt[l] - mmu_b[l] * mu_a) * (inv_msig_b[l] * inv_sig_a);
                want_sat += (corr > 1.0 || corr < -1.0) as u64;
                let want = two_m * (1.0 - corr.clamp(-1.0, 1.0));
                assert_eq!(lane[l].to_bits(), want.to_bits(), "case {case} lane {l}");
            }
            assert_eq!(got_sat, want_sat, "case {case}");
        }
    }

    #[test]
    fn dot_w_f64_is_dot_and_f32_is_close() {
        let mut rng = Rng::seed(23);
        for n in [0usize, 1, 3, 4, 7, 37, 256] {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let d64: f64 = dot_w::<f64>(&a, &b);
            assert_eq!(d64.to_bits(), dot(&a, &b).to_bits(), "n={n}");
            let d32: f32 = dot_w::<f32>(&a, &b);
            assert!((d32 as f64 - d64).abs() <= 1e-3 * (1.0 + d64.abs()), "n={n}: {d32} vs {d64}");
        }
    }
}
