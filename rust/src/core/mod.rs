//! Time-series primitives shared by every layer of the coordinator:
//! series containers, rolling statistics (Eqs. 4/7/8), normalized
//! Euclidean distance (Eq. 6), candidate bitmaps and top-k selection.
#![forbid(unsafe_code)]

pub mod bitmap;
pub mod distance;
pub mod series;
pub mod stats;
pub mod topk;
pub mod windows;
