//! Time series container and I/O.
//!
//! A [`TimeSeries`] is an in-RAM `f64` sequence (the paper assumes the
//! series fits in main memory, §2.1) plus a name used in reports.  Loaders
//! cover the formats the benchmark datasets ship in: one-value-per-line
//! text, CSV column extract, and raw little-endian `f32`/`f64` binary.

use std::fmt;
use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A univariate time series, chronologically ordered (Eq. 1).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TimeSeries {
    pub name: String,
    pub values: Vec<f64>,
}

impl TimeSeries {
    pub fn new(name: impl Into<String>, values: Vec<f64>) -> Self {
        Self { name: name.into(), values }
    }

    /// Length `n = |T|`.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Number of `m`-length subsequences: `N = n - m + 1` (Eq. 2).
    pub fn subsequence_count(&self, m: usize) -> usize {
        if m == 0 || m > self.len() {
            0
        } else {
            self.len() - m + 1
        }
    }

    /// Borrow the `m`-length subsequence starting at `i` (0-based).
    pub fn subsequence(&self, i: usize, m: usize) -> &[f64] {
        &self.values[i..i + m]
    }

    /// Prefix of the series (used by the length-scalability benches).
    pub fn prefix(&self, n: usize) -> TimeSeries {
        TimeSeries::new(self.name.clone(), self.values[..n.min(self.len())].to_vec())
    }

    /// Global min/max (used by plotting / heatmap normalization).
    pub fn min_max(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &v in &self.values {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        (lo, hi)
    }

    /// Load one-value-per-line text (comments with `#`, blanks skipped).
    pub fn from_text(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut values = Vec::new();
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line?;
            let s = line.trim();
            if s.is_empty() || s.starts_with('#') {
                continue;
            }
            let v: f64 = s
                .parse()
                .with_context(|| format!("{}:{}: bad value {s:?}", path.display(), lineno + 1))?;
            values.push(v);
        }
        let name = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
        Ok(Self::new(name, values))
    }

    /// Load one column of a CSV file (0-based column index, optional header).
    pub fn from_csv(path: impl AsRef<Path>, column: usize) -> Result<Self> {
        let path = path.as_ref();
        let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut values = Vec::new();
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line?;
            let s = line.trim();
            if s.is_empty() || s.starts_with('#') {
                continue;
            }
            let field = s.split(',').nth(column).with_context(|| {
                format!("{}:{}: no column {column}", path.display(), lineno + 1)
            })?;
            match field.trim().parse::<f64>() {
                Ok(v) => values.push(v),
                // Tolerate a single header row.
                Err(_) if lineno == 0 => continue,
                Err(e) => bail!("{}:{}: {e}", path.display(), lineno + 1),
            }
        }
        let name = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
        Ok(Self::new(name, values))
    }

    /// Load raw little-endian `f64` binary.
    pub fn from_f64_binary(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut buf = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?
            .read_to_end(&mut buf)?;
        if buf.len() % 8 != 0 {
            bail!("{}: length {} not a multiple of 8", path.display(), buf.len());
        }
        let values =
            buf.chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().expect("chunks_exact yields 8-byte chunks")))
            .collect();
        let name = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
        Ok(Self::new(name, values))
    }

    /// Write one-value-per-line text.
    pub fn to_text(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
        for v in &self.values {
            writeln!(f, "{v}")?;
        }
        Ok(())
    }

    /// Write raw little-endian `f64` binary.
    pub fn to_f64_binary(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
        for v in &self.values {
            f.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// The series values as `f32` (the tile-kernel interchange dtype).
    pub fn to_f32(&self) -> Vec<f32> {
        // order: deliberate f64 -> f32 narrowing at the kernel boundary;
        // every engine consumes the same f32 bits, so cross-engine
        // conformance is unaffected (see ANALYSIS.md §P2).
        self.values.iter().map(|&v| v as f32).collect()
    }
}

impl fmt::Display for TimeSeries {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} (n={})", self.name, self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subsequence_count_edges() {
        let t = TimeSeries::new("t", vec![0.0; 10]);
        assert_eq!(t.subsequence_count(3), 8);
        assert_eq!(t.subsequence_count(10), 1);
        assert_eq!(t.subsequence_count(11), 0);
        assert_eq!(t.subsequence_count(0), 0);
    }

    #[test]
    fn subsequence_borrow() {
        let t = TimeSeries::new("t", (0..10).map(|x| x as f64).collect());
        assert_eq!(t.subsequence(2, 3), &[2.0, 3.0, 4.0]);
    }

    #[test]
    fn prefix_clamps() {
        let t = TimeSeries::new("t", (0..10).map(|x| x as f64).collect());
        assert_eq!(t.prefix(4).len(), 4);
        assert_eq!(t.prefix(100).len(), 10);
    }

    #[test]
    fn text_roundtrip() {
        let dir = std::env::temp_dir().join("palmad_series_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.txt");
        let t = TimeSeries::new("x", vec![1.5, -2.25, 3.0]);
        t.to_text(&p).unwrap();
        let u = TimeSeries::from_text(&p).unwrap();
        assert_eq!(t.values, u.values);
    }

    #[test]
    fn binary_roundtrip() {
        let dir = std::env::temp_dir().join("palmad_series_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.f64");
        let t = TimeSeries::new("x", vec![1.5, f64::MIN_POSITIVE, -0.0, 1e300]);
        t.to_f64_binary(&p).unwrap();
        let u = TimeSeries::from_f64_binary(&p).unwrap();
        assert_eq!(t.values, u.values);
    }

    #[test]
    fn csv_column() {
        let dir = std::env::temp_dir().join("palmad_series_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.csv");
        std::fs::write(&p, "time,temp\n0,20.5\n1,21.0\n2,19.75\n").unwrap();
        let t = TimeSeries::from_csv(&p, 1).unwrap();
        assert_eq!(t.values, vec![20.5, 21.0, 19.75]);
    }

    #[test]
    fn min_max() {
        let t = TimeSeries::new("t", vec![3.0, -1.0, 2.0]);
        assert_eq!(t.min_max(), (-1.0, 3.0));
    }
}
