//! Rolling subsequence statistics — the paper's redundancy-avoidance core.
//!
//! MERLIN calls DRAG once per subsequence length `m in [minL, maxL]`.
//! Computing each length's window means/standard-deviations from scratch
//! costs `O(n)` per length with a cumulative scan, but the paper's Eqs. 7/8
//! make the step `m -> m+1` a *branch-free elementwise* update which both
//! the AOT `stats_update` kernel and [`RollingStats::advance`] implement:
//!
//! ```text
//! mu'_i     = (m * mu_i + t_{i+m}) / (m + 1)                      (Eq. 7)
//! sigma'^2_i = m/(m+1) * (sigma_i^2 + (mu_i - t_{i+m})^2 / (m+1)) (Eq. 8)
//! ```
//!
//! Everything is kept in `f64`: the cancellation in `E[x^2] - mu^2` is
//! catastrophic in `f32` for large-magnitude series (random walks).
//! Standard deviations are floored at [`SIGMA_FLOOR`] so constant
//! (stuck-sensor) windows produce finite distances — required by the
//! PolyTER case study (§5) and matching matrix-profile practice.

use super::distance::{is_flat, LaneElem, LANES};

/// Floor applied to every sigma.  Must equal `python/compile/shapes.py::SIGMA_FLOOR`.
pub const SIGMA_FLOOR: f64 = 1e-8;

/// Per-column stat products of the tile kernel's fast distance path:
/// `mmu_b[j] = m * mu[j]`, `inv_msig_b[j] = 1 / (m * sig[j])`; returns
/// whether any column is flat (which routes the whole tile through the
/// general Eq. 6 path).  `mu`/`sig` are the chunk's window stats
/// (`stats.mu[cs..cs+nb]`).
///
/// Chunked over [`LANES`] columns with a scalar tail, but every lane
/// performs the exact scalar operation sequence — elementwise maps are
/// bit-identical under any chunking, so every tile kernel shares this
/// one implementation (one more place where "same decisions" is
/// structural, not tested-for).  Generic over the *output* element
/// only: products are always computed in f64 and then narrowed through
/// [`LaneElem::from_f64`] (identity for f64 — bit-identical to the
/// historical monomorphic version; one rounding for the f32 kernel).
/// Crucially, the flat decision is always taken on the f64 stats, so
/// flat routing is kernel-invariant by construction.
// hot-path: per-column stat products, once per tile bind.
pub fn stat_products_into<E: LaneElem>(
    mu: &[f64],
    sig: &[f64],
    mf: f64,
    mmu_b: &mut [E],
    inv_msig_b: &mut [E],
) -> bool {
    let nb = mu.len();
    debug_assert!(sig.len() == nb && mmu_b.len() == nb && inv_msig_b.len() == nb);
    let mut flat = [false; LANES];
    // panic-free: LANES is a nonzero const; j+l < chunks*LANES <= nb,
    // and all four slices have length >= nb (debug-asserted above,
    // sliced to exactly nb by the tile binder).  1/(mf*sig) is float
    // division (sig floored at SIGMA_FLOOR).
    let chunks = nb / LANES;
    for c in 0..chunks {
        let j = c * LANES;
        for l in 0..LANES {
            mmu_b[j + l] = E::from_f64(mf * mu[j + l]);
        }
        for l in 0..LANES {
            inv_msig_b[j + l] = E::from_f64(1.0 / (mf * sig[j + l]));
        }
        for l in 0..LANES {
            // panic-free: same j+l < nb bound as the lanes above.
            flat[l] |= is_flat(sig[j + l], mu[j + l]);
        }
    }
    let mut any_flat = flat.iter().any(|&f| f);
    // panic-free: scalar tail, j < nb bounds every slice access.
    for j in chunks * LANES..nb {
        mmu_b[j] = E::from_f64(mf * mu[j]);
        inv_msig_b[j] = E::from_f64(1.0 / (mf * sig[j]));
        any_flat |= is_flat(sig[j], mu[j]);
    }
    any_flat
}

/// Mean/std vectors for all `m`-length windows of one series.
///
/// `mu[i]`, `sig[i]` describe `T[i .. i+m)`; both have `n - m + 1` live
/// entries.  [`RollingStats::advance`] mutates them in place to describe
/// the `m+1` windows (one fewer entry).
#[derive(Clone, Debug)]
pub struct RollingStats {
    pub m: usize,
    pub mu: Vec<f64>,
    pub sig: Vec<f64>,
}

impl RollingStats {
    /// Initial computation (Eq. 4) via a single cumulative pass.
    ///
    /// Uses running sums with per-window compensation: the cumulative sums
    /// are f64 and windows are recovered by differencing, which for the
    /// value ranges in this repo keeps |err| well under the test tolerance
    /// (verified against [`naive`] by unit + property tests).
    pub fn compute(t: &[f64], m: usize) -> Self {
        let mut s = Self { m, mu: Vec::new(), sig: Vec::new() };
        s.recompute(t, m);
        s
    }

    /// Recompute in place for a (possibly different) series and length,
    /// reusing the existing `mu`/`sig` storage.  The streaming monitor's
    /// refresh path depends on this: once the buffers have reached the
    /// window's capacity, re-statting a slid window allocates nothing.
    // hot-path: O(n) cumulative stat pass, once per sweep seed and per
    // stream refresh.
    pub fn recompute(&mut self, t: &[f64], m: usize) {
        // panic-free: deliberate precondition check at the entry point,
        // outside the per-window loop (an invalid m is a caller bug).
        assert!(m >= 2 && m <= t.len(), "m={m} out of range for n={}", t.len());
        let cnt = t.len() - m + 1;
        self.m = m;
        self.mu.clear();
        self.sig.clear();
        self.mu.reserve(cnt);
        self.sig.reserve(cnt);
        // Seed window.
        // panic-free: m <= t.len() (asserted above); in the slide loop
        // i+m-1 <= cnt-1+m-1 < t.len(); mf = m as f64 >= 2.0 so the
        // mean/var divisions are nonzero float divisions.
        let mut s1 = 0.0f64;
        let mut s2 = 0.0f64;
        for &v in &t[..m] {
            s1 += v;
            s2 += v * v;
        }
        let mf = m as f64;
        for i in 0..cnt {
            if i > 0 {
                // panic-free: i >= 1 and i+m-1 <= cnt-1+m-1 < t.len().
                let out = t[i - 1];
                let inn = t[i + m - 1];
                s1 += inn - out;
                s2 += inn * inn - out * out;
            }
            // panic-free: mf >= 2.0, nonzero float division.
            let mean = s1 / mf;
            let var = (s2 / mf - mean * mean).max(0.0);
            self.mu.push(mean);
            self.sig.push(var.sqrt().max(SIGMA_FLOOR));
        }
        // One re-accumulation pass every few thousand slides would guard
        // drift; for n <= 2^24 and the magnitudes exercised here the drift
        // is < 1e-9 relative (property-tested), so we keep the single pass.
    }

    /// Reference implementation: direct two-pass mean/std per window.
    pub fn naive(t: &[f64], m: usize) -> Self {
        assert!(m >= 2 && m <= t.len());
        let cnt = t.len() - m + 1;
        let mut mu = Vec::with_capacity(cnt);
        let mut sig = Vec::with_capacity(cnt);
        for i in 0..cnt {
            let w = &t[i..i + m];
            let mean = w.iter().sum::<f64>() / m as f64;
            let ms = w.iter().map(|&x| x * x).sum::<f64>() / m as f64;
            let var = (ms - mean * mean).max(0.0);
            mu.push(mean);
            sig.push(var.sqrt().max(SIGMA_FLOOR));
        }
        Self { m, mu, sig }
    }

    /// Number of live windows.
    pub fn len(&self) -> usize {
        self.mu.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mu.is_empty()
    }

    /// Recurrent update `m -> m+1` (Eqs. 7/8), in place.
    ///
    /// After the call the vectors have one fewer live entry.  `t` must be
    /// the same series the stats were computed from.
    // hot-path: Eqs. 7/8 elementwise m -> m+1 update, once per length.
    pub fn advance(&mut self, t: &[f64]) {
        let m = self.m as f64;
        let m1 = m + 1.0;
        let cnt = self.len() - 1;
        // panic-free: i < cnt < len() bounds mu/sig; i + self.m <=
        // cnt-1+m < t.len() for same-series t (documented contract);
        // m1 >= 3.0 so the divisions are nonzero float divisions.
        for i in 0..cnt {
            let tn = t[i + self.m];
            let mu = self.mu[i];
            let sig = self.sig[i];
            self.mu[i] = (m * mu + tn) / m1;
            let d = mu - tn;
            let var = (m / m1) * (sig * sig + d * d / m1);
            self.sig[i] = var.max(0.0).sqrt().max(SIGMA_FLOOR);
        }
        self.mu.truncate(cnt);
        self.sig.truncate(cnt);
        self.m += 1;
    }

    /// Copy a `[start, start+len)` slice of the stats into f32 buffers,
    /// padding past-the-end with (mu=0, sig=1) — the neutral values the
    /// tile kernel expects for invalid windows.
    pub fn slice_f32(&self, start: usize, len: usize, mu_out: &mut [f32], sig_out: &mut [f32]) {
        assert!(mu_out.len() >= len && sig_out.len() >= len);
        for k in 0..len {
            let i = start + k;
            if i < self.len() {
                // order: deliberate f64 -> f32 narrowing at the AOT
                // kernel boundary; both engines consume the same f32
                // bits, so rounding here cannot diverge across engines.
                mu_out[k] = self.mu[i] as f32;
                sig_out[k] = self.sig[i] as f32;
            } else {
                mu_out[k] = 0.0;
                sig_out[k] = 1.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn compute_matches_naive_random_walk() {
        let mut rng = Rng::seed(7);
        let t: Vec<f64> = {
            let mut acc = 0.0;
            (0..500)
                .map(|_| {
                    acc += rng.normal();
                    acc
                })
                .collect()
        };
        for m in [2, 3, 16, 100, 499, 500] {
            let a = RollingStats::compute(&t, m);
            let b = RollingStats::naive(&t, m);
            assert_eq!(a.len(), b.len());
            for i in 0..a.len() {
                assert!(close(a.mu[i], b.mu[i], 1e-10), "mu m={m} i={i}");
                assert!(close(a.sig[i], b.sig[i], 1e-8), "sig m={m} i={i}");
            }
        }
    }

    #[test]
    fn advance_matches_fresh_compute() {
        let mut rng = Rng::seed(42);
        let t: Vec<f64> = (0..300).map(|_| rng.normal() * 10.0 + 5.0).collect();
        let mut s = RollingStats::compute(&t, 8);
        for m in 9..=40 {
            s.advance(&t);
            let fresh = RollingStats::naive(&t, m);
            assert_eq!(s.m, m);
            assert_eq!(s.len(), fresh.len());
            for i in 0..s.len() {
                assert!(close(s.mu[i], fresh.mu[i], 1e-9), "mu m={m} i={i}");
                assert!(close(s.sig[i], fresh.sig[i], 1e-7), "sig m={m} i={i}");
            }
        }
    }

    #[test]
    fn recompute_reuses_storage_and_matches_fresh() {
        let mut rng = Rng::seed(17);
        let t1: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
        let t2: Vec<f64> = (0..280).map(|_| rng.normal() * 3.0 + 1.0).collect();
        let mut s = RollingStats::compute(&t1, 12);
        let ptr = s.mu.as_ptr();
        s.recompute(&t2, 20);
        assert_eq!(s.mu.as_ptr(), ptr, "recompute within capacity reallocated");
        let fresh = RollingStats::naive(&t2, 20);
        assert_eq!(s.m, 20);
        assert_eq!(s.len(), fresh.len());
        for i in 0..s.len() {
            assert!(close(s.mu[i], fresh.mu[i], 1e-10), "mu i={i}");
            assert!(close(s.sig[i], fresh.sig[i], 1e-8), "sig i={i}");
        }
    }

    #[test]
    fn sigma_floor_on_constant_series() {
        let t = vec![3.25; 64];
        let s = RollingStats::compute(&t, 8);
        for &x in &s.sig {
            assert_eq!(x, SIGMA_FLOOR);
        }
        let s = RollingStats::naive(&t, 8);
        for &x in &s.sig {
            assert_eq!(x, SIGMA_FLOOR);
        }
    }

    #[test]
    fn advance_shrinks_by_one() {
        let t: Vec<f64> = (0..50).map(|x| (x as f64).sin()).collect();
        let mut s = RollingStats::compute(&t, 4);
        assert_eq!(s.len(), 47);
        s.advance(&t);
        assert_eq!(s.len(), 46);
        assert_eq!(s.m, 5);
    }

    #[test]
    fn stat_products_match_direct_loop_any_width() {
        let mut rng = Rng::seed(29);
        // Widths off the lane grid: tail-only, tail + chunks, exact.
        for nb in [0usize, 1, 2, 3, 4, 5, 7, 8, 11, 16, 33] {
            let mu: Vec<f64> = (0..nb).map(|_| rng.normal() * 5.0).collect();
            let mut sig: Vec<f64> = (0..nb).map(|_| rng.range(0.01, 4.0)).collect();
            if nb > 2 {
                sig[nb / 2] = SIGMA_FLOOR; // a flat column
            }
            let mf = 16.0;
            let mut mmu = vec![0.0; nb];
            let mut inv = vec![0.0; nb];
            let any_flat = stat_products_into(&mu, &sig, mf, &mut mmu, &mut inv);
            let mut want_flat = false;
            for j in 0..nb {
                assert_eq!(mmu[j].to_bits(), (mf * mu[j]).to_bits(), "nb={nb} j={j}");
                assert_eq!(inv[j].to_bits(), (1.0 / (mf * sig[j])).to_bits(), "nb={nb} j={j}");
                want_flat |= is_flat(sig[j], mu[j]);
            }
            assert_eq!(any_flat, want_flat, "nb={nb}");
            assert_eq!(any_flat, nb > 2, "nb={nb}: planted flat column must be seen");
        }
    }

    #[test]
    fn slice_f32_pads_neutral() {
        let t: Vec<f64> = (0..20).map(|x| x as f64).collect();
        let s = RollingStats::compute(&t, 4);
        let mut mu = [0f32; 8];
        let mut sig = [0f32; 8];
        s.slice_f32(s.len() - 2, 8, &mut mu, &mut sig);
        assert!(mu[0] != 0.0 && mu[1] != 0.0);
        for k in 2..8 {
            assert_eq!(mu[k], 0.0);
            assert_eq!(sig[k], 1.0);
        }
    }
}
