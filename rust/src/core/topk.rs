//! Top-k discord selection.
//!
//! DRAG returns *all* range discords (subsequences whose nearest non-self
//! match is at distance >= r).  MERLIN's callers usually want the top-k
//! per length: the k mutually non-overlapping survivors with the largest
//! nearest-neighbor distances (§2.1, top-k generalization).

use super::windows::{cmp_score_desc, overlaps};

/// One scored subsequence (index + nearest-neighbor distance, ED units).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scored {
    pub idx: usize,
    pub nn_dist: f64,
}

/// Pick the top-k mutually non-overlapping scored subsequences.
///
/// `k = 0` means "all survivors" (still de-overlapped) — used when
/// collecting every discord for the heatmap.
pub fn top_k_non_overlapping(items: &[Scored], m: usize, k: usize) -> Vec<Scored> {
    let mut scratch = items.to_vec();
    let mut out = Vec::new();
    top_k_non_overlapping_into(&mut scratch, m, k, &mut out);
    out
}

/// In-place variant of [`top_k_non_overlapping`] for hot callers
/// (MERLIN's per-length step): sorts `items` (score descending, NaN
/// last, index-ascending ties — the same total order as
/// [`super::windows::non_overlapping`]) and fills `out` with the
/// greedy non-overlapping prefix, truncated to `k` (0 = all).  Both
/// buffers are caller-owned scratch, so a warmed caller allocates
/// nothing (the sort is unstable and the comparator total, hence no
/// merge buffer and a deterministic result).
pub fn top_k_non_overlapping_into(
    items: &mut [Scored],
    m: usize,
    k: usize,
    out: &mut Vec<Scored>,
) {
    items.sort_unstable_by(|a, b| cmp_score_desc(a.nn_dist, b.nn_dist).then(a.idx.cmp(&b.idx)));
    out.clear();
    'outer: for s in items.iter() {
        if k != 0 && out.len() >= k {
            break;
        }
        for kept in out.iter() {
            if overlaps(s.idx, kept.idx, m) {
                continue 'outer;
            }
        }
        out.push(*s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(idx: usize, d: f64) -> Scored {
        Scored { idx, nn_dist: d }
    }

    #[test]
    fn picks_k_best() {
        let items = vec![s(0, 1.0), s(100, 9.0), s(200, 5.0), s(300, 7.0)];
        let got = top_k_non_overlapping(&items, 10, 2);
        assert_eq!(got, vec![s(100, 9.0), s(300, 7.0)]);
    }

    #[test]
    fn k_zero_returns_all_deoverlapped() {
        let items = vec![s(0, 1.0), s(1, 2.0), s(50, 3.0)];
        let got = top_k_non_overlapping(&items, 5, 0);
        assert_eq!(got, vec![s(50, 3.0), s(1, 2.0)]);
    }

    #[test]
    fn overlapping_survivors_deduped() {
        let items = vec![s(10, 5.0), s(11, 4.9), s(12, 4.8)];
        let got = top_k_non_overlapping(&items, 3, 3);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], s(10, 5.0));
    }

    #[test]
    fn empty_input() {
        assert!(top_k_non_overlapping(&[], 4, 3).is_empty());
    }

    #[test]
    fn exact_score_ties_break_by_lowest_index() {
        // Equal scores must pick deterministically: index ascending.
        let items = vec![s(500, 3.0), s(100, 3.0), s(300, 3.0)];
        let got = top_k_non_overlapping(&items, 10, 2);
        assert_eq!(got, vec![s(100, 3.0), s(300, 3.0)]);
    }

    #[test]
    fn tied_overlapping_candidates_keep_earliest() {
        // Three mutually overlapping items with identical scores: exactly
        // one survives and it is the lowest index.
        let items = vec![s(12, 7.0), s(10, 7.0), s(11, 7.0)];
        let got = top_k_non_overlapping(&items, 5, 0);
        assert_eq!(got, vec![s(10, 7.0)]);
    }

    #[test]
    fn adjacent_windows_at_exact_overlap_boundary() {
        // |i - j| == m is NOT an overlap: both survive; |i - j| == m - 1 is.
        let items = vec![s(0, 9.0), s(4, 8.0), s(9, 7.0)];
        let got = top_k_non_overlapping(&items, 4, 3);
        // 0 kills nothing at distance 4 (= m); 4 survives; 9 is 5 away
        // from 4 — survives too.
        assert_eq!(got, vec![s(0, 9.0), s(4, 8.0), s(9, 7.0)]);
        let got = top_k_non_overlapping(&[s(0, 9.0), s(3, 8.0)], 4, 2);
        assert_eq!(got, vec![s(0, 9.0)], "|i-j| = m-1 must be de-overlapped");
    }

    #[test]
    fn k_larger_than_survivors_returns_all() {
        let items = vec![s(0, 1.0), s(50, 2.0)];
        let got = top_k_non_overlapping(&items, 10, 99);
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn duplicate_indices_collapse_to_one() {
        let items = vec![s(20, 5.0), s(20, 4.0)];
        let got = top_k_non_overlapping(&items, 3, 2);
        assert_eq!(got, vec![s(20, 5.0)]);
    }

    #[test]
    fn nan_scores_rank_last_without_panicking() {
        // A NaN sample in an input series propagates into nnDist; the
        // selection must neither panic (the old partial_cmp unwrap) nor
        // let the NaN outrank a real discord.
        let items = vec![s(0, f64::NAN), s(50, 2.0), s(100, f64::NAN)];
        let got = top_k_non_overlapping(&items, 10, 2);
        assert_eq!(got[0], s(50, 2.0));
        assert_eq!(got.len(), 2);
        assert_eq!(got[1].idx, 0, "NaN entries keep deterministic index order");
        assert!(got[1].nn_dist.is_nan());
    }
}
