//! Top-k discord selection.
//!
//! DRAG returns *all* range discords (subsequences whose nearest non-self
//! match is at distance >= r).  MERLIN's callers usually want the top-k
//! per length: the k mutually non-overlapping survivors with the largest
//! nearest-neighbor distances (§2.1, top-k generalization).

use super::windows::non_overlapping;

/// One scored subsequence (index + nearest-neighbor distance, ED units).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scored {
    pub idx: usize,
    pub nn_dist: f64,
}

/// Pick the top-k mutually non-overlapping scored subsequences.
///
/// `k = 0` means "all survivors" (still de-overlapped) — used when
/// collecting every discord for the heatmap.
pub fn top_k_non_overlapping(items: &[Scored], m: usize, k: usize) -> Vec<Scored> {
    let pairs: Vec<(usize, f64)> = items.iter().map(|s| (s.idx, s.nn_dist)).collect();
    let kept = non_overlapping(pairs, m);
    let take = if k == 0 { kept.len() } else { k.min(kept.len()) };
    kept[..take].iter().map(|&(idx, nn_dist)| Scored { idx, nn_dist }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(idx: usize, d: f64) -> Scored {
        Scored { idx, nn_dist: d }
    }

    #[test]
    fn picks_k_best() {
        let items = vec![s(0, 1.0), s(100, 9.0), s(200, 5.0), s(300, 7.0)];
        let got = top_k_non_overlapping(&items, 10, 2);
        assert_eq!(got, vec![s(100, 9.0), s(300, 7.0)]);
    }

    #[test]
    fn k_zero_returns_all_deoverlapped() {
        let items = vec![s(0, 1.0), s(1, 2.0), s(50, 3.0)];
        let got = top_k_non_overlapping(&items, 5, 0);
        assert_eq!(got, vec![s(50, 3.0), s(1, 2.0)]);
    }

    #[test]
    fn overlapping_survivors_deduped() {
        let items = vec![s(10, 5.0), s(11, 4.9), s(12, 4.8)];
        let got = top_k_non_overlapping(&items, 3, 3);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], s(10, 5.0));
    }

    #[test]
    fn empty_input() {
        assert!(top_k_non_overlapping(&[], 4, 3).is_empty());
    }
}
