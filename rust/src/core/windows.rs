//! Subsequence-window helpers: overlap predicates and index arithmetic
//! shared by the coordinator, the baselines, and the tests.

/// Do the `m`-windows starting at `i` and `j` trivially match
/// (overlap), i.e. is `|i - j| < m`?  Non-self matches require
/// `|i - j| >= m` (§2.1).
#[inline]
pub fn overlaps(i: usize, j: usize, m: usize) -> bool {
    i.abs_diff(j) < m
}

/// Number of `m`-windows in an `n`-length series.
#[inline]
pub fn window_count(n: usize, m: usize) -> usize {
    if m == 0 || m > n {
        0
    } else {
        n - m + 1
    }
}

/// Greedily filter `(index, score)` pairs (sorted by caller) so that kept
/// indices are mutually non-overlapping for window length `m`.
pub fn non_overlapping(mut items: Vec<(usize, f64)>, m: usize) -> Vec<(usize, f64)> {
    // Stable on equal scores: sort by (score desc, index asc).
    items.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let mut kept: Vec<(usize, f64)> = Vec::new();
    'outer: for (i, s) in items {
        for &(j, _) in &kept {
            if overlaps(i, j, m) {
                continue 'outer;
            }
        }
        kept.push((i, s));
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_predicate() {
        assert!(overlaps(10, 10, 1));
        assert!(overlaps(10, 12, 3));
        assert!(!overlaps(10, 13, 3));
        assert!(!overlaps(13, 10, 3));
        assert!(overlaps(0, 4, 5));
    }

    #[test]
    fn window_count_edges() {
        assert_eq!(window_count(10, 3), 8);
        assert_eq!(window_count(10, 10), 1);
        assert_eq!(window_count(10, 11), 0);
        assert_eq!(window_count(0, 3), 0);
    }

    #[test]
    fn non_overlapping_keeps_best() {
        let items = vec![(0, 1.0), (2, 5.0), (10, 3.0), (11, 4.0)];
        let kept = non_overlapping(items, 4);
        // 2 (5.0) kills 0; 11 (4.0) kills 10.
        assert_eq!(kept, vec![(2, 5.0), (11, 4.0)]);
    }

    #[test]
    fn non_overlapping_tie_breaks_by_index() {
        let items = vec![(5, 2.0), (1, 2.0)];
        let kept = non_overlapping(items, 10);
        assert_eq!(kept, vec![(1, 2.0)]);
    }
}
