//! Subsequence-window helpers: overlap predicates, index arithmetic, and
//! the NaN-total score ordering shared by the coordinator, the analysis
//! layer, the baselines, and the tests.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use std::cmp::Ordering;

/// Do the `m`-windows starting at `i` and `j` trivially match
/// (overlap), i.e. is `|i - j| < m`?  Non-self matches require
/// `|i - j| >= m` (§2.1).
#[inline]
pub fn overlaps(i: usize, j: usize, m: usize) -> bool {
    i.abs_diff(j) < m
}

/// Number of `m`-windows in an `n`-length series.
#[inline]
pub fn window_count(n: usize, m: usize) -> usize {
    if m == 0 || m > n {
        0
    } else {
        n - m + 1
    }
}

/// Total descending order over scores, with NaN pinned *last*.
///
/// Ranking paths used `partial_cmp(..).unwrap()`, so a single NaN score
/// — one bad CSV cell survives every parsing path and propagates into
/// nnDist — panicked the whole run.  This comparator is total
/// ([`f64::total_cmp`] on the non-NaN side) and pins the NaN placement:
/// a NaN score ranks below every real score, `-inf` included, so it can
/// neither panic a sort nor displace a finite candidate; equal-score
/// ties (NaN vs NaN included) are left to the caller's tie-breaker.
#[inline]
pub fn cmp_score_desc(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (false, false) => b.total_cmp(&a),
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater, // NaN sorts after any real b
        (false, true) => Ordering::Less,
    }
}

/// Greedily filter `(index, score)` pairs so that kept indices are
/// mutually non-overlapping for window length `m`.  Ordering is
/// [`cmp_score_desc`] (score descending, NaN last) with index-ascending
/// tie-breaks, so the result is deterministic for any input.
pub fn non_overlapping(mut items: Vec<(usize, f64)>, m: usize) -> Vec<(usize, f64)> {
    items.sort_by(|a, b| cmp_score_desc(a.1, b.1).then(a.0.cmp(&b.0)));
    let mut kept: Vec<(usize, f64)> = Vec::new();
    'outer: for (i, s) in items {
        for &(j, _) in &kept {
            if overlaps(i, j, m) {
                continue 'outer;
            }
        }
        kept.push((i, s));
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_predicate() {
        assert!(overlaps(10, 10, 1));
        assert!(overlaps(10, 12, 3));
        assert!(!overlaps(10, 13, 3));
        assert!(!overlaps(13, 10, 3));
        assert!(overlaps(0, 4, 5));
    }

    #[test]
    fn window_count_edges() {
        assert_eq!(window_count(10, 3), 8);
        assert_eq!(window_count(10, 10), 1);
        assert_eq!(window_count(10, 11), 0);
        assert_eq!(window_count(0, 3), 0);
    }

    #[test]
    fn non_overlapping_keeps_best() {
        let items = vec![(0, 1.0), (2, 5.0), (10, 3.0), (11, 4.0)];
        let kept = non_overlapping(items, 4);
        // 2 (5.0) kills 0; 11 (4.0) kills 10.
        assert_eq!(kept, vec![(2, 5.0), (11, 4.0)]);
    }

    #[test]
    fn non_overlapping_tie_breaks_by_index() {
        let items = vec![(5, 2.0), (1, 2.0)];
        let kept = non_overlapping(items, 10);
        assert_eq!(kept, vec![(1, 2.0)]);
    }

    #[test]
    fn non_overlapping_survives_nan_scores() {
        // Regression: a NaN sample in an input series panicked the
        // partial_cmp sort.  NaN entries now rank last and never
        // displace a real candidate.
        let items = vec![(20, f64::NAN), (10, 1.0), (0, f64::NAN), (30, 2.0)];
        let kept = non_overlapping(items, 4);
        assert_eq!(kept[0].0, 30);
        assert_eq!(kept[1].0, 10);
        assert_eq!(kept[2].0, 0, "NaN ties break by index");
        assert!(kept[2].1.is_nan());
        assert_eq!(kept[3].0, 20);
        assert!(kept[3].1.is_nan());
    }

    #[test]
    fn cmp_score_desc_is_total_and_pins_nan_last() {
        use std::cmp::Ordering::*;
        assert_eq!(cmp_score_desc(2.0, 1.0), Less, "bigger score first");
        assert_eq!(cmp_score_desc(1.0, 2.0), Greater);
        assert_eq!(cmp_score_desc(1.0, 1.0), Equal);
        assert_eq!(cmp_score_desc(f64::NAN, f64::NEG_INFINITY), Greater, "NaN after -inf");
        assert_eq!(cmp_score_desc(f64::INFINITY, f64::NAN), Less);
        assert_eq!(cmp_score_desc(f64::NAN, f64::NAN), Equal);
        // Both NaN sign bits get the same placement.
        assert_eq!(cmp_score_desc(-f64::NAN, f64::NEG_INFINITY), Greater);
    }
}
