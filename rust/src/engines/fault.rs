//! Deterministic fault injection for the chaos suite.
//!
//! [`FaultyEngine`] wraps any inner [`Engine`] and misbehaves on a
//! fixed, seed-driven schedule — transient `Err`s every Nth tile
//! batch, one injected panic, per-call latency, NaN contamination of
//! one tile's minima — so the robustness machinery (the step
//! scheduler's retry-with-backoff, `catch_unwind` worker isolation,
//! checkpoint/resume) can be exercised reproducibly in tests instead
//! of waiting for real hardware or concurrency faults.
//!
//! Faults are injected on the *calling* thread, above the inner
//! engine's own thread pool, which is what makes the injected panic
//! catchable by the scheduler's `catch_unwind` — the wrapper models a
//! misbehaving engine boundary, not a crashed pool worker.
//!
//! Everything is counted: tests assert the faults actually fired
//! (a chaos test whose fault never triggers is a green light lying).
#![forbid(unsafe_code)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{bail, Result};

use super::{Engine, EnginePerfCounters, SeedRowSnapshot, SeriesView, TileTask};
use crate::core::stats::RollingStats;
use crate::runtime::types::TileOutputs;
use crate::util::rng::Rng;

/// Deterministic misbehavior schedule.  All knobs are off by default;
/// call indices are 1-based counts of tile-batch computations
/// (`compute_tiles` / `compute_tiles_into`).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Seed for the deterministic choices a fault must make (which
    /// tile to contaminate); two runs with the same plan inject
    /// identically.
    pub seed: u64,
    /// Fail every Nth tile-batch call with a transient `Err`
    /// (0 = never).  Retried calls advance the counter, so a retry
    /// after call `N` is call `N + 1` and succeeds.
    pub error_every: u64,
    /// Panic on exactly this call index (0 = never).  One-shot by
    /// construction: the counter moves past it.
    pub panic_at: u64,
    /// Contaminate one tile of exactly this call's output with NaN
    /// minima (0 = never).  The batch itself succeeds — this models
    /// silent numeric corruption, which downstream ranking must
    /// tolerate (NaN ranks last) rather than crash on.
    pub nan_at: u64,
    /// Sleep this long at the top of every tile-batch call
    /// (Duration::ZERO = no delay).  For latency/timeout testing.
    pub latency: Duration,
}

/// Counts of faults actually injected (tests assert these fired).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct InjectedFaults {
    pub errors: u64,
    pub panics: u64,
    pub nans: u64,
}

/// An [`Engine`] decorator that injects faults per [`FaultPlan`] and
/// otherwise delegates everything — including the seed-row transfer
/// and AOT hooks — to the inner engine, so a faulty engine is a
/// drop-in for any pipeline the service can lease.
pub struct FaultyEngine {
    inner: Box<dyn Engine>,
    plan: FaultPlan,
    calls: AtomicU64,
    errors: AtomicU64,
    panics: AtomicU64,
    nans: AtomicU64,
}

impl FaultyEngine {
    pub fn new(inner: Box<dyn Engine>, plan: FaultPlan) -> Self {
        Self {
            inner,
            plan,
            calls: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            nans: AtomicU64::new(0),
        }
    }

    /// Tile-batch calls seen so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Faults injected so far.
    pub fn injected(&self) -> InjectedFaults {
        InjectedFaults {
            errors: self.errors.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            nans: self.nans.load(Ordering::Relaxed),
        }
    }

    /// Pre-call fault gate: latency, panic, transient error — in that
    /// order.  Returns this call's 1-based index for the post-call
    /// NaN decision.
    fn gate(&self) -> Result<u64> {
        let call = self.calls.fetch_add(1, Ordering::SeqCst) + 1;
        if !self.plan.latency.is_zero() {
            std::thread::sleep(self.plan.latency);
        }
        if self.plan.panic_at != 0 && call == self.plan.panic_at {
            self.panics.fetch_add(1, Ordering::SeqCst);
            panic!("injected engine panic (tile-batch call {call})");
        }
        if self.plan.error_every != 0 && call % self.plan.error_every == 0 {
            self.errors.fetch_add(1, Ordering::SeqCst);
            bail!("injected transient engine fault (tile-batch call {call})");
        }
        Ok(call)
    }

    /// Post-call NaN contamination of one deterministic tile.
    fn maybe_contaminate(&self, call: u64, out: &mut [TileOutputs]) {
        if self.plan.nan_at == 0 || call != self.plan.nan_at || out.is_empty() {
            return;
        }
        let pick = (Rng::seed(self.plan.seed ^ call).next_u64() % out.len() as u64) as usize;
        let tile = &mut out[pick];
        tile.row_min.fill(f64::NAN);
        tile.col_min.fill(f64::NAN);
        self.nans.fetch_add(1, Ordering::SeqCst);
    }
}

impl Engine for FaultyEngine {
    fn name(&self) -> &'static str {
        "faulty"
    }

    fn segn(&self) -> usize {
        self.inner.segn()
    }

    fn max_m(&self) -> usize {
        self.inner.max_m()
    }

    fn compute_tiles(
        &self,
        view: &SeriesView<'_>,
        r2: f64,
        tasks: &[TileTask],
    ) -> Result<Vec<TileOutputs>> {
        let call = self.gate()?;
        let mut out = self.inner.compute_tiles(view, r2, tasks)?;
        self.maybe_contaminate(call, &mut out);
        Ok(out)
    }

    fn compute_tiles_into(
        &self,
        view: &SeriesView<'_>,
        r2: f64,
        tasks: &[TileTask],
        out: &mut Vec<TileOutputs>,
    ) -> Result<()> {
        let call = self.gate()?;
        self.inner.compute_tiles_into(view, r2, tasks, out)?;
        self.maybe_contaminate(call, &mut out[..tasks.len()]);
        Ok(())
    }

    fn prepare_series(&self, view: &SeriesView<'_>) {
        self.inner.prepare_series(view);
    }

    fn prefetch_length(&self, t: &[f64], next_m: usize) -> u64 {
        self.inner.prefetch_length(t, next_m)
    }

    fn perf_counters(&self) -> EnginePerfCounters {
        self.inner.perf_counters()
    }

    fn export_seed_rows(&self, t: &[f64]) -> Vec<SeedRowSnapshot> {
        self.inner.export_seed_rows(t)
    }

    fn import_seed_rows(&self, t: &[f64], rows: &[SeedRowSnapshot]) -> u64 {
        self.inner.import_seed_rows(t, rows)
    }

    fn aot_stats_init(&self, t: &[f64], m: usize) -> Result<RollingStats> {
        self.inner.aot_stats_init(t, m)
    }

    fn aot_stats_update(&self, t: &[f64], stats: &RollingStats) -> Result<RollingStats> {
        self.inner.aot_stats_update(t, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::native::NativeEngine;

    fn view_fixture(n: usize, m: usize) -> (Vec<f64>, RollingStats) {
        let mut acc = 0.0;
        let t: Vec<f64> = (0..n)
            .map(|i| {
                acc += ((i * 37 + 11) % 17) as f64 / 17.0 - 0.5;
                acc
            })
            .collect();
        let mut stats = RollingStats { m, mu: Vec::new(), sig: Vec::new() };
        stats.recompute(&t, m);
        (t, stats)
    }

    fn tasks() -> Vec<TileTask> {
        vec![
            TileTask { seg_start: 0, chunk_start: 0 },
            TileTask { seg_start: 0, chunk_start: 32 },
        ]
    }

    #[test]
    fn error_cadence_is_every_nth() {
        let (t, stats) = view_fixture(200, 8);
        let view = SeriesView { t: &t, stats: &stats };
        let eng = FaultyEngine::new(
            Box::new(NativeEngine::with_segn(32)),
            FaultPlan { error_every: 3, ..Default::default() },
        );
        let mut outcomes = Vec::new();
        for _ in 0..6 {
            outcomes.push(eng.compute_tiles(&view, 1.0, &tasks()).is_ok());
        }
        assert_eq!(outcomes, [true, true, false, true, true, false]);
        assert_eq!(eng.injected().errors, 2);
        assert_eq!(eng.calls(), 6);
    }

    #[test]
    fn panic_fires_once_and_is_catchable() {
        let (t, stats) = view_fixture(200, 8);
        let eng = FaultyEngine::new(
            Box::new(NativeEngine::with_segn(32)),
            FaultPlan { panic_at: 2, ..Default::default() },
        );
        let run = |eng: &FaultyEngine| {
            let view = SeriesView { t: &t, stats: &stats };
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                eng.compute_tiles(&view, 1.0, &tasks()).map(|_| ())
            }))
        };
        assert!(matches!(run(&eng), Ok(Ok(()))), "call 1 clean");
        assert!(run(&eng).is_err(), "call 2 panics");
        assert!(matches!(run(&eng), Ok(Ok(()))), "call 3 clean again");
        assert_eq!(eng.injected(), InjectedFaults { errors: 0, panics: 1, nans: 0 });
    }

    #[test]
    fn nan_contamination_hits_one_deterministic_tile() {
        let (t, stats) = view_fixture(200, 8);
        let view = SeriesView { t: &t, stats: &stats };
        let plan = FaultPlan { seed: 99, nan_at: 1, ..Default::default() };
        let poisoned = |eng: &FaultyEngine| {
            let out = eng.compute_tiles(&view, 1.0, &tasks()).unwrap();
            let bad: Vec<usize> = out
                .iter()
                .enumerate()
                .filter(|(_, o)| o.row_min.iter().any(|x| x.is_nan()))
                .map(|(i, _)| i)
                .collect();
            assert_eq!(eng.injected().nans, 1);
            bad
        };
        let a = poisoned(&FaultyEngine::new(
            Box::new(NativeEngine::with_segn(32)),
            plan.clone(),
        ));
        let b = poisoned(&FaultyEngine::new(Box::new(NativeEngine::with_segn(32)), plan));
        assert_eq!(a.len(), 1, "exactly one tile contaminated");
        assert_eq!(a, b, "same seed, same tile");
    }

    #[test]
    fn clean_plan_is_transparent() {
        let (t, stats) = view_fixture(300, 10);
        let view = SeriesView { t: &t, stats: &stats };
        let inner = NativeEngine::with_segn(32);
        let want = inner.compute_tiles(&view, 2.0, &tasks()).unwrap();
        let eng =
            FaultyEngine::new(Box::new(NativeEngine::with_segn(32)), FaultPlan::default());
        let got = eng.compute_tiles(&view, 2.0, &tasks()).unwrap();
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.row_min, g.row_min);
            assert_eq!(w.col_min, g.col_min);
            assert_eq!(w.row_kill, g.row_kill);
            assert_eq!(w.col_kill, g.col_kill);
        }
        assert_eq!(eng.injected(), InjectedFaults::default());
        assert_eq!(eng.segn(), 32);
        assert_eq!(eng.name(), "faulty");
    }
}
