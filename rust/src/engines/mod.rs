//! Tile-computation engines.
//!
//! The PD3 coordinator is engine-agnostic: it schedules (segment, chunk)
//! tile tasks and folds the reduced results into its bitmaps.  Two
//! implementations exist:
//!
//! - [`native::NativeEngine`] — pure rust, thread-pooled, `f64`
//!   throughout; the correctness oracle and the CPU-performance baseline.
//!   Its steady-state tile loop is allocation-free: output blocks are
//!   recycled through [`Engine::compute_tiles_into`], per-worker buffers
//!   live in a [`scratch::TileScratch`] arena, and QT seed rows are
//!   reused across subsequence lengths ([`scratch::QtSeedCache`]).
//! - [`xla::XlaEngine`] — the AOT path: Pallas/JAX-compiled HLO executed
//!   via PJRT, exactly what would run on a TPU (interpret-lowered here).
//!
//! Panicking `unwrap`s are denied tree-wide (engines run inside
//! fault-isolated workers; errors must surface as `Result`s, not
//! poisoned locks).  `#![forbid(unsafe_code)]` cannot sit here because
//! it would propagate to [`native`]/[`scratch`] — the two modules
//! allowlisted for `unsafe` slot writes (CONCURRENCY.md) — so the
//! unsafe-free children ([`fault`], [`xla`]) carry it per file instead.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod fault;
pub mod native;
pub mod scratch;
pub mod xla;

use anyhow::Result;

use crate::core::stats::RollingStats;
use crate::runtime::types::TileOutputs;

pub use crate::core::distance::{LANES, MAX_LANES};

/// CLI/env spellings of every concrete tile kernel, in conformance-matrix
/// order.  `scripts/ci.sh --kernel-matrix` extracts this list textually
/// (single line, keep it one) so a new variant cannot dodge the matrix
/// by forgetting a shell edit; `auto` is deliberately absent — it
/// resolves to one of these.
pub const KERNEL_NAMES: &[&str] = &["scalar", "lanes4", "lanes8", "lanes4f32"];

/// Inner-loop kernel of the native tile pipeline.
///
/// All f64 kernels are bit-identical by construction: every pass is
/// either an elementwise map (distances, QT recurrence, column folds —
/// chunking cannot change per-element rounding, and Rust never
/// contracts float ops into FMAs) or a reduction whose operator is
/// insensitive to lane regrouping over these inputs (`min` with `+inf`
/// identities and NaN-dropping semantics, boolean OR).  The
/// differential harness in `rust/tests/kernel_conformance.rs` pins that
/// claim, so `Scalar` stays available as the bit-level oracle and the
/// bench baseline.  `Lanes4F32` is the deliberate exception: it runs
/// the same loop bodies at f32 precision and is held to a *derived
/// tolerance band* (index-exact discords, distances within the band)
/// rather than bit equality — the first tolerance-banded leg of the
/// cross-engine conformance suite.  Production configs run `Auto`,
/// which resolves once per process to the widest f64 kernel the host
/// supports.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TileKernel {
    /// Resolve at first use: `Lanes8` when the host has AVX-512F
    /// (`is_x86_feature_detected!("avx512f")`), else `Lanes4`.  The
    /// decision is made once per process, cached in a `OnceLock`, and
    /// reported via [`EnginePerfCounters::kernel`] / the METRICS
    /// `kernel=` segment.
    #[default]
    Auto,
    /// Per-column scalar loops — the oracle and the `simd_kernel` bench
    /// baseline.
    Scalar,
    /// Explicit [`LANES`]-wide chunks of `[f64; LANES]` accumulators
    /// (branchless, fixed-extent array refs for the vectorizer) with a
    /// scalar tail for widths off the lane grid.
    Lanes4,
    /// The same loop bodies at `W = 8` (`[f64; 8]` chunks — one AVX-512
    /// zmm register).  Plain safe Rust: correct on any CPU, only *fast*
    /// with AVX-512F, which is why `Auto` gates it on feature detection
    /// rather than compiling it conditionally.
    Lanes8,
    /// The same loop bodies at `W = 4` over **f32** — the accelerator
    /// parity kernel.  Series values and stat products are narrowed at
    /// the tile boundary, QT rows are seeded and recurred in f32, and
    /// only the per-row/column minima are widened back into the f64
    /// tile outputs.  Flat routing stays on the f64 stats, so
    /// `flat_cells` is kernel-invariant; distances carry f32 rounding
    /// and are conformance-checked against the derived band instead of
    /// bit equality.
    Lanes4F32,
}

impl TileKernel {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "scalar" => Ok(Self::Scalar),
            "lanes4" => Ok(Self::Lanes4),
            "lanes8" => Ok(Self::Lanes8),
            "lanes4f32" => Ok(Self::Lanes4F32),
            other => {
                anyhow::bail!("unknown tile kernel {other:?} (auto|scalar|lanes4|lanes8|lanes4f32)")
            }
        }
    }

    /// The CLI/env spelling ([`KERNEL_NAMES`] entry, or `"auto"`).
    pub fn name(self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Scalar => "scalar",
            Self::Lanes4 => "lanes4",
            Self::Lanes8 => "lanes8",
            Self::Lanes4F32 => "lanes4f32",
        }
    }

    /// `PALMAD_TILE_KERNEL` override, else the default.  This is the
    /// hook `scripts/ci.sh --kernel-matrix` uses to run the whole
    /// conformance + allocation suite under each kernel without code
    /// changes; an unparseable value panics rather than silently testing
    /// the default kernel twice.
    pub fn from_env() -> Self {
        match std::env::var("PALMAD_TILE_KERNEL") {
            Ok(s) => Self::parse(&s)
                .expect("PALMAD_TILE_KERNEL must be one of auto|scalar|lanes4|lanes8|lanes4f32"),
            Err(_) => Self::default(),
        }
    }

    /// Collapse [`TileKernel::Auto`] to the concrete kernel this host
    /// runs: `Lanes8` when AVX-512F is available, else `Lanes4`.
    /// Concrete kernels return themselves unchanged, so `resolve` is
    /// idempotent and safe to call at every tile entry.  The feature
    /// probe runs once per process; the decision is cached in a
    /// `OnceLock` (no atomics beyond the lock's own — see
    /// CONCURRENCY.md scope note).
    pub fn resolve(self) -> Self {
        match self {
            Self::Auto => *AUTO_KERNEL.get_or_init(Self::detect),
            concrete => concrete,
        }
    }

    /// The feature probe behind [`TileKernel::resolve`].
    fn detect() -> Self {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx512f") {
                return Self::Lanes8;
            }
        }
        Self::Lanes4
    }
}

/// Cached [`TileKernel::Auto`] resolution (one feature probe per
/// process; every engine and every tile sees the same decision).
static AUTO_KERNEL: std::sync::OnceLock<TileKernel> = std::sync::OnceLock::new();

/// One (segment, chunk) pair to evaluate at the current length `m`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileTask {
    /// Global index of the segment's first subsequence.
    pub seg_start: usize,
    /// Global index of the chunk's first subsequence.
    pub chunk_start: usize,
}

/// Read-only view of the series + current-length stats handed to engines.
pub struct SeriesView<'a> {
    pub t: &'a [f64],
    pub stats: &'a RollingStats,
}

impl SeriesView<'_> {
    /// Number of valid `m`-windows.
    pub fn n_windows(&self) -> usize {
        self.stats.len()
    }
}

/// Cumulative per-engine performance counters (QT seed cache traffic
/// and batch-submission volume).
///
/// Engines without internal caches report all-zero seed fields.
/// Counters are lifetime totals; use [`EnginePerfCounters::since`] to
/// scope them to one run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EnginePerfCounters {
    /// Seed rows reused verbatim (same length — MERLIN `r`-retries).
    pub seed_hits: u64,
    /// Seed rows advanced `m -> m'` by the dot-product recurrence.
    pub seed_advances: u64,
    /// Seed rows computed by the full `O(segn * m)` pass.
    pub seed_misses: u64,
    /// Seed rows advanced by the bulk prefetch sweep
    /// ([`Engine::prefetch_length`]); these resurface as `seed_hits`
    /// when the next length's tiles consume them.
    pub seed_prefetched: u64,
    /// Bulk prefetch sweeps that found rows to advance (one per
    /// advanced length on a warm cache; sweeps over an empty or
    /// already-current cache are not counted).
    pub prefetch_batches: u64,
    /// Tile batches submitted (one per coordinator round).
    pub batches: u64,
    /// Tiles evaluated across those batches.
    pub batch_tiles: u64,
    /// Fast-path columns whose Eq. 6 correlation left `[-1, 1]` and was
    /// clamped.  Deterministic for a given workload and — because both
    /// kernels share one clamp definition — identical across
    /// [`TileKernel`]s; the conformance suite compares it directly to
    /// certify equal clamp decisions.  Zero on the legacy pipeline
    /// (which predates the counter) and on cache-less engines.
    pub clamp_saturations: u64,
    /// Columns evaluated through the flat-window (general Eq. 6) path —
    /// rows where the segment window or any chunk column is flat.  All
    /// kernels route these through one shared scalar implementation
    /// (keyed on the f64 stats even under `Lanes4F32`), so the count is
    /// kernel-invariant by construction.
    pub flat_cells: u64,
    /// The *resolved* tile kernel this engine runs ([`TileKernel::Auto`]
    /// collapsed to its concrete choice) — how a `--kernel auto` run
    /// reports which kernel the host actually got.  `None` for engines
    /// without tile kernels (XLA, oracles) and on pre-dispatch
    /// snapshots; surfaces in the METRICS `kernel=` segment.
    pub kernel: Option<TileKernel>,
}

impl EnginePerfCounters {
    /// Counter deltas relative to an earlier snapshot.
    pub fn since(self, earlier: EnginePerfCounters) -> EnginePerfCounters {
        EnginePerfCounters {
            seed_hits: self.seed_hits.saturating_sub(earlier.seed_hits),
            seed_advances: self.seed_advances.saturating_sub(earlier.seed_advances),
            seed_misses: self.seed_misses.saturating_sub(earlier.seed_misses),
            seed_prefetched: self.seed_prefetched.saturating_sub(earlier.seed_prefetched),
            prefetch_batches: self.prefetch_batches.saturating_sub(earlier.prefetch_batches),
            batches: self.batches.saturating_sub(earlier.batches),
            batch_tiles: self.batch_tiles.saturating_sub(earlier.batch_tiles),
            clamp_saturations: self.clamp_saturations.saturating_sub(earlier.clamp_saturations),
            flat_cells: self.flat_cells.saturating_sub(earlier.flat_cells),
            // The kernel is an identity, not a count — deltas keep it.
            kernel: self.kernel,
        }
    }

    /// Total seed requests.
    pub fn seed_total(&self) -> u64 {
        self.seed_hits + self.seed_advances + self.seed_misses
    }

    /// Fold another snapshot's counts into this one.  Stepped MERLIN
    /// sweeps scope the engine counters per step (snapshot before,
    /// [`EnginePerfCounters::since`] after, accumulate into the run's
    /// metrics), so a shared engine interleaving several tenants still
    /// attributes traffic to the job that caused it.
    pub fn accumulate(&mut self, other: EnginePerfCounters) {
        self.seed_hits += other.seed_hits;
        self.seed_advances += other.seed_advances;
        self.seed_misses += other.seed_misses;
        self.seed_prefetched += other.seed_prefetched;
        self.prefetch_batches += other.prefetch_batches;
        self.batches += other.batches;
        self.batch_tiles += other.batch_tiles;
        self.clamp_saturations += other.clamp_saturations;
        self.flat_cells += other.flat_cells;
        // First engine to report a kernel wins; later steps on the same
        // engine report the same resolved kernel anyway (the dispatch
        // cache is process-wide).
        self.kernel = self.kernel.or(other.kernel);
    }
}

/// One QT seed row lifted out of an engine's per-series cache, in
/// engine-independent coordinates: segment anchor `a`, chunk start
/// `cs`, the length `m` the dots are current at, and the raw dot
/// products themselves.
///
/// Exists for crash-safe checkpointing (`coordinator::checkpoint`):
/// a resumed sweep on a cold engine would *re-seed* rows with the full
/// four-lane dot pass, which rounds differently in the low-order bits
/// than the incremental cross-length advance a warm engine performs
/// (see `engines::scratch`, test `cross_length_advance_matches_fresh_dots`).
/// Carrying the rows through the checkpoint makes resume bit-identical
/// to an uninterrupted run, which is what the chaos suite asserts.
#[derive(Clone, Debug, PartialEq)]
pub struct SeedRowSnapshot {
    pub a: usize,
    pub cs: usize,
    pub m: usize,
    pub qt: Vec<f64>,
}

/// A tile-computation backend.
pub trait Engine: Send + Sync {
    fn name(&self) -> &'static str;

    /// Tile edge (the paper's `segN`): every task covers
    /// `[start, start + segn)` subsequences on each side.
    fn segn(&self) -> usize;

    /// Largest subsequence length this engine can serve.
    fn max_m(&self) -> usize;

    /// Evaluate a batch of tiles at subsequence length `view.stats.m`
    /// with squared threshold `r2`.  Results are index-aligned to `tasks`.
    fn compute_tiles(
        &self,
        view: &SeriesView<'_>,
        r2: f64,
        tasks: &[TileTask],
    ) -> Result<Vec<TileOutputs>>;

    /// Like [`Engine::compute_tiles`], but recycles the caller's output
    /// blocks: on return `out[i]` holds task `i`'s result for every
    /// `i < tasks.len()`.  Implementations may leave additional recycled
    /// blocks past that index (the native engine grows `out` but never
    /// shrinks it, so PD3's tapering rounds keep block storage alive);
    /// callers must index by task, not drain the vector.  Callers that
    /// keep `out` alive across rounds (the PD3 driver's workspace does)
    /// avoid re-allocating the four result vectors per tile — the native
    /// engine's round loop is allocation-free once warmed.  The default
    /// forwards to `compute_tiles`.
    fn compute_tiles_into(
        &self,
        view: &SeriesView<'_>,
        r2: f64,
        tasks: &[TileTask],
        out: &mut Vec<TileOutputs>,
    ) -> Result<()> {
        let results = self.compute_tiles(view, r2, tasks)?;
        out.clear();
        out.extend(results);
        Ok(())
    }

    /// Called once per PD3 run before any tile of `view` is evaluated.
    /// Engines with per-series caches validate / reset them here; the
    /// default is a no-op.
    fn prepare_series(&self, _view: &SeriesView<'_>) {}

    /// Advance engine-internal per-series state (e.g. the native QT seed
    /// cache) to subsequence length `next_m` in one bulk pass, so the
    /// next length's tiles find their seed rows ready instead of
    /// advancing them one at a time under the cache locks.  MERLIN's
    /// length loop calls this between lengths (after length `m`
    /// completes, before any `m + 1` tile is scheduled) and the stream
    /// monitor's refresh calls it before its retry loop.  Returns the
    /// number of rows prefetched; engines without such caches ignore it.
    fn prefetch_length(&self, _t: &[f64], _next_m: usize) -> u64 {
        0
    }

    /// Snapshot of the engine's cumulative performance counters.
    fn perf_counters(&self) -> EnginePerfCounters {
        EnginePerfCounters::default()
    }

    /// Export the QT seed rows currently bound to series `t`, sorted by
    /// `(a, cs)` so the output is deterministic.  Engines without a
    /// seed cache (or not bound to `t`) return an empty vector —
    /// checkpoints then degrade to numerically-equal (not bit-equal)
    /// resume, never to wrong results.
    fn export_seed_rows(&self, _t: &[f64]) -> Vec<SeedRowSnapshot> {
        Vec::new()
    }

    /// Re-install previously exported rows for series `t`, binding the
    /// cache to `t` first.  Returns the number of rows accepted (cache
    /// capacity may drop some; dropped rows cost a re-seed, not
    /// correctness).  No-op default for cache-less engines.
    fn import_seed_rows(&self, _t: &[f64], _rows: &[SeedRowSnapshot]) -> u64 {
        0
    }

    /// Run the AOT `stats_init` kernel (Eq. 4), if this engine has one.
    fn aot_stats_init(&self, _t: &[f64], _m: usize) -> Result<RollingStats> {
        anyhow::bail!("engine {:?} has no AOT stats kernels", self.name())
    }

    /// Run the AOT `stats_update` kernel (Eqs. 7/8), if available.
    fn aot_stats_update(&self, _t: &[f64], _stats: &RollingStats) -> Result<RollingStats> {
        anyhow::bail!("engine {:?} has no AOT stats kernels", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_names_round_trip_and_exclude_auto() {
        for &name in KERNEL_NAMES {
            let k = TileKernel::parse(name).expect("KERNEL_NAMES entry must parse");
            assert_eq!(k.name(), name);
            assert_ne!(k, TileKernel::Auto, "auto must not sit in the concrete matrix");
            assert_eq!(k.resolve(), k, "concrete kernels are dispatch fixed points");
        }
        assert!(TileKernel::parse("avx512").is_err());
    }

    #[test]
    fn auto_is_default_and_resolves_to_a_cached_f64_lane_kernel() {
        assert_eq!(TileKernel::default(), TileKernel::Auto);
        let first = TileKernel::Auto.resolve();
        assert!(
            matches!(first, TileKernel::Lanes4 | TileKernel::Lanes8),
            "auto resolved to {first:?}"
        );
        assert_eq!(TileKernel::Auto.resolve(), first, "dispatch decision must be cached");
    }
}
