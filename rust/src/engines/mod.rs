//! Tile-computation engines.
//!
//! The PD3 coordinator is engine-agnostic: it schedules (segment, chunk)
//! tile tasks and folds the reduced results into its bitmaps.  Two
//! implementations exist:
//!
//! - [`native::NativeEngine`] — pure rust, thread-pooled, `f64`
//!   throughout; the correctness oracle and the CPU-performance baseline.
//! - [`xla::XlaEngine`] — the AOT path: Pallas/JAX-compiled HLO executed
//!   via PJRT, exactly what would run on a TPU (interpret-lowered here).

pub mod native;
pub mod xla;

use anyhow::Result;

use crate::core::stats::RollingStats;
use crate::runtime::types::TileOutputs;

/// One (segment, chunk) pair to evaluate at the current length `m`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileTask {
    /// Global index of the segment's first subsequence.
    pub seg_start: usize,
    /// Global index of the chunk's first subsequence.
    pub chunk_start: usize,
}

/// Read-only view of the series + current-length stats handed to engines.
pub struct SeriesView<'a> {
    pub t: &'a [f64],
    pub stats: &'a RollingStats,
}

impl SeriesView<'_> {
    /// Number of valid `m`-windows.
    pub fn n_windows(&self) -> usize {
        self.stats.len()
    }
}

/// A tile-computation backend.
pub trait Engine: Send + Sync {
    fn name(&self) -> &'static str;

    /// Tile edge (the paper's `segN`): every task covers
    /// `[start, start + segn)` subsequences on each side.
    fn segn(&self) -> usize;

    /// Largest subsequence length this engine can serve.
    fn max_m(&self) -> usize;

    /// Evaluate a batch of tiles at subsequence length `view.stats.m`
    /// with squared threshold `r2`.  Results are index-aligned to `tasks`.
    fn compute_tiles(
        &self,
        view: &SeriesView<'_>,
        r2: f64,
        tasks: &[TileTask],
    ) -> Result<Vec<TileOutputs>>;

    /// Run the AOT `stats_init` kernel (Eq. 4), if this engine has one.
    fn aot_stats_init(&self, _t: &[f64], _m: usize) -> Result<RollingStats> {
        anyhow::bail!("engine {:?} has no AOT stats kernels", self.name())
    }

    /// Run the AOT `stats_update` kernel (Eqs. 7/8), if available.
    fn aot_stats_update(&self, _t: &[f64], _stats: &RollingStats) -> Result<RollingStats> {
        anyhow::bail!("engine {:?} has no AOT stats kernels", self.name())
    }
}
