//! Tile-computation engines.
//!
//! The PD3 coordinator is engine-agnostic: it schedules (segment, chunk)
//! tile tasks and folds the reduced results into its bitmaps.  Two
//! implementations exist:
//!
//! - [`native::NativeEngine`] — pure rust, thread-pooled, `f64`
//!   throughout; the correctness oracle and the CPU-performance baseline.
//!   Its steady-state tile loop is allocation-free: output blocks are
//!   recycled through [`Engine::compute_tiles_into`], per-worker buffers
//!   live in a [`scratch::TileScratch`] arena, and QT seed rows are
//!   reused across subsequence lengths ([`scratch::QtSeedCache`]).
//! - [`xla::XlaEngine`] — the AOT path: Pallas/JAX-compiled HLO executed
//!   via PJRT, exactly what would run on a TPU (interpret-lowered here).
//!
//! Panicking `unwrap`s are denied tree-wide (engines run inside
//! fault-isolated workers; errors must surface as `Result`s, not
//! poisoned locks).  `#![forbid(unsafe_code)]` cannot sit here because
//! it would propagate to [`native`]/[`scratch`] — the two modules
//! allowlisted for `unsafe` slot writes (CONCURRENCY.md) — so the
//! unsafe-free children ([`fault`], [`xla`]) carry it per file instead.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod fault;
pub mod native;
pub mod scratch;
pub mod xla;

use anyhow::Result;

use crate::core::stats::RollingStats;
use crate::runtime::types::TileOutputs;

pub use crate::core::distance::LANES;

/// Inner-loop kernel of the native tile pipeline.
///
/// Both kernels are bit-identical by construction: every pass is either
/// an elementwise map (distances, QT recurrence, column folds — chunking
/// cannot change per-element rounding, and Rust never contracts float
/// ops into FMAs) or a reduction whose operator is insensitive to lane
/// regrouping over these inputs (`min` with `+inf` identities and
/// NaN-dropping semantics, boolean OR).  The differential harness in
/// `rust/tests/kernel_conformance.rs` pins that claim, so `Scalar` stays
/// available as the bit-level oracle and the bench baseline while
/// `Lanes4` is what production configs run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TileKernel {
    /// Per-column scalar loops — the oracle and the `simd_kernel` bench
    /// baseline.
    Scalar,
    /// Explicit [`LANES`]-wide chunks of `[f64; LANES]` accumulators
    /// (branchless, fixed-extent array refs for the vectorizer) with a
    /// scalar tail for widths off the lane grid.
    #[default]
    Lanes4,
}

impl TileKernel {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "scalar" => Ok(Self::Scalar),
            "lanes4" => Ok(Self::Lanes4),
            other => anyhow::bail!("unknown tile kernel {other:?} (scalar|lanes4)"),
        }
    }

    /// `PALMAD_TILE_KERNEL` override, else the default.  This is the
    /// hook `scripts/ci.sh --kernel-matrix` uses to run the whole
    /// conformance + allocation suite under each kernel without code
    /// changes; an unparseable value panics rather than silently testing
    /// the default kernel twice.
    pub fn from_env() -> Self {
        match std::env::var("PALMAD_TILE_KERNEL") {
            Ok(s) => Self::parse(&s).expect("PALMAD_TILE_KERNEL must be `scalar` or `lanes4`"),
            Err(_) => Self::default(),
        }
    }
}

/// One (segment, chunk) pair to evaluate at the current length `m`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileTask {
    /// Global index of the segment's first subsequence.
    pub seg_start: usize,
    /// Global index of the chunk's first subsequence.
    pub chunk_start: usize,
}

/// Read-only view of the series + current-length stats handed to engines.
pub struct SeriesView<'a> {
    pub t: &'a [f64],
    pub stats: &'a RollingStats,
}

impl SeriesView<'_> {
    /// Number of valid `m`-windows.
    pub fn n_windows(&self) -> usize {
        self.stats.len()
    }
}

/// Cumulative per-engine performance counters (QT seed cache traffic
/// and batch-submission volume).
///
/// Engines without internal caches report all-zero seed fields.
/// Counters are lifetime totals; use [`EnginePerfCounters::since`] to
/// scope them to one run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EnginePerfCounters {
    /// Seed rows reused verbatim (same length — MERLIN `r`-retries).
    pub seed_hits: u64,
    /// Seed rows advanced `m -> m'` by the dot-product recurrence.
    pub seed_advances: u64,
    /// Seed rows computed by the full `O(segn * m)` pass.
    pub seed_misses: u64,
    /// Seed rows advanced by the bulk prefetch sweep
    /// ([`Engine::prefetch_length`]); these resurface as `seed_hits`
    /// when the next length's tiles consume them.
    pub seed_prefetched: u64,
    /// Bulk prefetch sweeps that found rows to advance (one per
    /// advanced length on a warm cache; sweeps over an empty or
    /// already-current cache are not counted).
    pub prefetch_batches: u64,
    /// Tile batches submitted (one per coordinator round).
    pub batches: u64,
    /// Tiles evaluated across those batches.
    pub batch_tiles: u64,
    /// Fast-path columns whose Eq. 6 correlation left `[-1, 1]` and was
    /// clamped.  Deterministic for a given workload and — because both
    /// kernels share one clamp definition — identical across
    /// [`TileKernel`]s; the conformance suite compares it directly to
    /// certify equal clamp decisions.  Zero on the legacy pipeline
    /// (which predates the counter) and on cache-less engines.
    pub clamp_saturations: u64,
    /// Columns evaluated through the flat-window (general Eq. 6) path —
    /// rows where the segment window or any chunk column is flat.  Both
    /// kernels route these through one shared scalar implementation, so
    /// the count is kernel-invariant by construction.
    pub flat_cells: u64,
}

impl EnginePerfCounters {
    /// Counter deltas relative to an earlier snapshot.
    pub fn since(self, earlier: EnginePerfCounters) -> EnginePerfCounters {
        EnginePerfCounters {
            seed_hits: self.seed_hits.saturating_sub(earlier.seed_hits),
            seed_advances: self.seed_advances.saturating_sub(earlier.seed_advances),
            seed_misses: self.seed_misses.saturating_sub(earlier.seed_misses),
            seed_prefetched: self.seed_prefetched.saturating_sub(earlier.seed_prefetched),
            prefetch_batches: self.prefetch_batches.saturating_sub(earlier.prefetch_batches),
            batches: self.batches.saturating_sub(earlier.batches),
            batch_tiles: self.batch_tiles.saturating_sub(earlier.batch_tiles),
            clamp_saturations: self.clamp_saturations.saturating_sub(earlier.clamp_saturations),
            flat_cells: self.flat_cells.saturating_sub(earlier.flat_cells),
        }
    }

    /// Total seed requests.
    pub fn seed_total(&self) -> u64 {
        self.seed_hits + self.seed_advances + self.seed_misses
    }

    /// Fold another snapshot's counts into this one.  Stepped MERLIN
    /// sweeps scope the engine counters per step (snapshot before,
    /// [`EnginePerfCounters::since`] after, accumulate into the run's
    /// metrics), so a shared engine interleaving several tenants still
    /// attributes traffic to the job that caused it.
    pub fn accumulate(&mut self, other: EnginePerfCounters) {
        self.seed_hits += other.seed_hits;
        self.seed_advances += other.seed_advances;
        self.seed_misses += other.seed_misses;
        self.seed_prefetched += other.seed_prefetched;
        self.prefetch_batches += other.prefetch_batches;
        self.batches += other.batches;
        self.batch_tiles += other.batch_tiles;
        self.clamp_saturations += other.clamp_saturations;
        self.flat_cells += other.flat_cells;
    }
}

/// One QT seed row lifted out of an engine's per-series cache, in
/// engine-independent coordinates: segment anchor `a`, chunk start
/// `cs`, the length `m` the dots are current at, and the raw dot
/// products themselves.
///
/// Exists for crash-safe checkpointing (`coordinator::checkpoint`):
/// a resumed sweep on a cold engine would *re-seed* rows with the full
/// four-lane dot pass, which rounds differently in the low-order bits
/// than the incremental cross-length advance a warm engine performs
/// (see `engines::scratch`, test `cross_length_advance_matches_fresh_dots`).
/// Carrying the rows through the checkpoint makes resume bit-identical
/// to an uninterrupted run, which is what the chaos suite asserts.
#[derive(Clone, Debug, PartialEq)]
pub struct SeedRowSnapshot {
    pub a: usize,
    pub cs: usize,
    pub m: usize,
    pub qt: Vec<f64>,
}

/// A tile-computation backend.
pub trait Engine: Send + Sync {
    fn name(&self) -> &'static str;

    /// Tile edge (the paper's `segN`): every task covers
    /// `[start, start + segn)` subsequences on each side.
    fn segn(&self) -> usize;

    /// Largest subsequence length this engine can serve.
    fn max_m(&self) -> usize;

    /// Evaluate a batch of tiles at subsequence length `view.stats.m`
    /// with squared threshold `r2`.  Results are index-aligned to `tasks`.
    fn compute_tiles(
        &self,
        view: &SeriesView<'_>,
        r2: f64,
        tasks: &[TileTask],
    ) -> Result<Vec<TileOutputs>>;

    /// Like [`Engine::compute_tiles`], but recycles the caller's output
    /// blocks: on return `out[i]` holds task `i`'s result for every
    /// `i < tasks.len()`.  Implementations may leave additional recycled
    /// blocks past that index (the native engine grows `out` but never
    /// shrinks it, so PD3's tapering rounds keep block storage alive);
    /// callers must index by task, not drain the vector.  Callers that
    /// keep `out` alive across rounds (the PD3 driver's workspace does)
    /// avoid re-allocating the four result vectors per tile — the native
    /// engine's round loop is allocation-free once warmed.  The default
    /// forwards to `compute_tiles`.
    fn compute_tiles_into(
        &self,
        view: &SeriesView<'_>,
        r2: f64,
        tasks: &[TileTask],
        out: &mut Vec<TileOutputs>,
    ) -> Result<()> {
        let results = self.compute_tiles(view, r2, tasks)?;
        out.clear();
        out.extend(results);
        Ok(())
    }

    /// Called once per PD3 run before any tile of `view` is evaluated.
    /// Engines with per-series caches validate / reset them here; the
    /// default is a no-op.
    fn prepare_series(&self, _view: &SeriesView<'_>) {}

    /// Advance engine-internal per-series state (e.g. the native QT seed
    /// cache) to subsequence length `next_m` in one bulk pass, so the
    /// next length's tiles find their seed rows ready instead of
    /// advancing them one at a time under the cache locks.  MERLIN's
    /// length loop calls this between lengths (after length `m`
    /// completes, before any `m + 1` tile is scheduled) and the stream
    /// monitor's refresh calls it before its retry loop.  Returns the
    /// number of rows prefetched; engines without such caches ignore it.
    fn prefetch_length(&self, _t: &[f64], _next_m: usize) -> u64 {
        0
    }

    /// Snapshot of the engine's cumulative performance counters.
    fn perf_counters(&self) -> EnginePerfCounters {
        EnginePerfCounters::default()
    }

    /// Export the QT seed rows currently bound to series `t`, sorted by
    /// `(a, cs)` so the output is deterministic.  Engines without a
    /// seed cache (or not bound to `t`) return an empty vector —
    /// checkpoints then degrade to numerically-equal (not bit-equal)
    /// resume, never to wrong results.
    fn export_seed_rows(&self, _t: &[f64]) -> Vec<SeedRowSnapshot> {
        Vec::new()
    }

    /// Re-install previously exported rows for series `t`, binding the
    /// cache to `t` first.  Returns the number of rows accepted (cache
    /// capacity may drop some; dropped rows cost a re-seed, not
    /// correctness).  No-op default for cache-less engines.
    fn import_seed_rows(&self, _t: &[f64], _rows: &[SeedRowSnapshot]) -> u64 {
        0
    }

    /// Run the AOT `stats_init` kernel (Eq. 4), if this engine has one.
    fn aot_stats_init(&self, _t: &[f64], _m: usize) -> Result<RollingStats> {
        anyhow::bail!("engine {:?} has no AOT stats kernels", self.name())
    }

    /// Run the AOT `stats_update` kernel (Eqs. 7/8), if available.
    fn aot_stats_update(&self, _t: &[f64], _stats: &RollingStats) -> Result<RollingStats> {
        anyhow::bail!("engine {:?} has no AOT stats kernels", self.name())
    }
}
