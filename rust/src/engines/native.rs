//! Pure-rust tile engine: the correctness oracle and CPU baseline.
//!
//! Implements the same computation as the AOT tile kernel (layer 2's
//! `tile_min`) but in `f64` using the paper's QT diagonal recurrence
//! (Eq. 10): the dot product of neighboring window pairs differs by one
//! multiply-add, so a whole `segn x segn` tile costs
//! `O(segn * m + segn^2)` instead of `O(segn^2 * m)`.
//!
//! The tile pipeline is built for **steady-state zero allocation** and
//! **cross-length reuse** (EXPERIMENTS.md §Perf):
//!
//! - every intermediate lives in a per-worker [`TileScratch`] arena;
//! - output blocks are recycled through [`Engine::compute_tiles_into`];
//! - tile batches run on a persistent [`RoundPool`] whose round
//!   submission allocates nothing (no job boxing, no per-item lock —
//!   results go to disjoint slots);
//! - the `O(segn * m)` QT seed pass of each tile is served from a
//!   [`QtSeedCache`] that MERLIN's length sweep advances `m -> m+1` with
//!   one multiply-add per column (`dot_{m+1}(a,b) = dot_m(a,b) +
//!   t[a+m] * t[b+m]`) — the paper's Eq. 7/8 redundancy elimination
//!   extended to the dot-product layer;
//! - the inner distance loop is a set of branchless SoA passes over
//!   contiguous scratch (distances, exclusion mask, min-folds, kill
//!   masks), dispatched on [`TileKernel`]: `Auto` (default) resolves
//!   once per process to the widest f64 lane kernel the host supports
//!   (`Lanes8` under AVX-512F, else `Lanes4`), the lane kernels run
//!   explicit fixed-width chunks so vectorization is pinned by
//!   construction, `Scalar` keeps the per-column loops as the bit-level
//!   oracle, and `Lanes4F32` runs the same lane bodies at f32 for
//!   accelerator parity; the old fused per-cell closure vectorized not
//!   at all.
//!
//! The pre-optimization pipeline is preserved as
//! [`TilePipeline::Legacy`] / [`compute_tile_alloc`] so the microbench
//! reports an honest before/after from one binary.

// Gauges go through the loomsync shim (audited in CONCURRENCY.md
// §native.rs); `OnceLock` stays `std` — it only lazily constructs the
// round pool, and the loom models never race first-time construction.
use crate::util::loomsync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use anyhow::Result;

use super::scratch::{
    col_folds, col_folds_w, distance_row, distance_row_w, general_distance_row,
    general_distance_row_f32, qt_recurrence_row, qt_recurrence_row_w, row_folds, row_folds_w,
    with_tile_scratch, QtSeedCache, TileKernelStats, TileScratch,
};
use super::{Engine, EnginePerfCounters, SeedRowSnapshot, SeriesView, TileKernel, TileTask};
use crate::core::distance::{dot, dot_w, ed2norm_from_qt, is_flat, LANES};
use crate::core::stats::stat_products_into;
use crate::runtime::types::TileOutputs;
use crate::util::pool::{self, RoundPool, SliceWriter};

/// Which tile pipeline [`NativeEngine`] runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TilePipeline {
    /// Zero-allocation scratch-arena pipeline with QT seed reuse.
    #[default]
    Scratch,
    /// Pre-optimization reference: per-tile heap allocation, fused
    /// per-cell loop, mutex-collected results.  Kept as the bench
    /// baseline and a second oracle.
    Legacy,
}

/// Configuration for [`NativeEngine`].
#[derive(Clone, Debug)]
pub struct NativeConfig {
    /// Tile edge (paper's `segN`).
    pub segn: usize,
    /// Worker threads for tile batches.
    pub threads: usize,
    /// Pipeline selection (benches flip this; default [`TilePipeline::Scratch`]).
    pub pipeline: TilePipeline,
    /// Inner-loop kernel of the scratch pipeline (the legacy pipeline
    /// predates the kernel split and ignores this).  Default:
    /// `PALMAD_TILE_KERNEL` env override, else [`TileKernel::Auto`],
    /// which resolves once per process to `Lanes8`/`Lanes4` by CPU
    /// feature detection — the env hook is what `scripts/ci.sh
    /// --kernel-matrix` flips.
    pub kernel: TileKernel,
}

impl Default for NativeConfig {
    fn default() -> Self {
        Self {
            segn: 256,
            threads: pool::default_threads(),
            pipeline: TilePipeline::default(),
            kernel: TileKernel::from_env(),
        }
    }
}

/// Pure-rust engine.
pub struct NativeEngine {
    cfg: NativeConfig,
    /// Persistent workers (spawned on first parallel batch; the
    /// submitting thread participates, so this holds `threads - 1`).
    round_pool: OnceLock<RoundPool>,
    /// Cross-length QT seed cache (scratch pipeline only).
    seeds: QtSeedCache,
    /// Batch-submission volume (reported via `perf_counters`).
    batches: AtomicU64,
    batch_tiles: AtomicU64,
    /// Kernel decision gauges (scratch pipeline only): fast-path clamp
    /// saturations and flat-routed columns, flushed once per tile.
    clamp_saturations: AtomicU64,
    flat_cells: AtomicU64,
}

impl NativeEngine {
    pub fn new(cfg: NativeConfig) -> Self {
        assert!(cfg.segn >= 1);
        Self {
            cfg,
            round_pool: OnceLock::new(),
            seeds: QtSeedCache::new(),
            batches: AtomicU64::new(0),
            batch_tiles: AtomicU64::new(0),
            clamp_saturations: AtomicU64::new(0),
            flat_cells: AtomicU64::new(0),
        }
    }

    pub fn with_segn(segn: usize) -> Self {
        Self::new(NativeConfig { segn, ..Default::default() })
    }

    fn pool(&self) -> &RoundPool {
        self.round_pool
            .get_or_init(|| RoundPool::new(self.cfg.threads.saturating_sub(1)))
    }

    /// Fold one tile's kernel event counts into the engine gauges.  The
    /// zero check keeps quiet workloads (no saturation, no flat windows
    /// — the common case) off the shared cache lines entirely.
    fn note_kernel_stats(&self, ks: TileKernelStats) {
        if ks.saturated != 0 {
            self.clamp_saturations.fetch_add(ks.saturated, Ordering::Relaxed);
        }
        if ks.flat_cells != 0 {
            self.flat_cells.fetch_add(ks.flat_cells, Ordering::Relaxed);
        }
    }

    /// Retire every cached QT seed row into the cache's spare pools
    /// (memory-pressure hook).  The row allocations are recycled by
    /// subsequent misses, so a clear does not break the engine's
    /// zero-steady-state-allocation guarantee.
    pub fn clear_seed_cache(&self) {
        self.seeds.clear();
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn segn(&self) -> usize {
        self.cfg.segn
    }

    fn max_m(&self) -> usize {
        usize::MAX
    }

    fn compute_tiles(
        &self,
        view: &SeriesView<'_>,
        r2: f64,
        tasks: &[TileTask],
    ) -> Result<Vec<TileOutputs>> {
        let mut out = Vec::new();
        self.compute_tiles_into(view, r2, tasks, &mut out)?;
        Ok(out)
    }

    fn compute_tiles_into(
        &self,
        view: &SeriesView<'_>,
        r2: f64,
        tasks: &[TileTask],
        out: &mut Vec<TileOutputs>,
    ) -> Result<()> {
        let segn = self.cfg.segn;
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_tiles.fetch_add(tasks.len() as u64, Ordering::Relaxed);
        if self.cfg.pipeline == TilePipeline::Legacy {
            let results =
                pool::parallel_map_indexed_locked(tasks.len(), self.cfg.threads, |i| {
                    compute_tile_alloc(view, segn, r2, tasks[i])
                });
            out.clear();
            out.extend(results);
            return Ok(());
        }

        // Guard against callers switching series without prepare_series
        // (the identity check is O(1); a mismatch triggers the full
        // content fingerprint + cache invalidation).
        if !self.seeds.is_bound(view.t) {
            self.seeds.prepare(view.t);
        }

        // Recycle the caller's output blocks, grow-only: a shrinking
        // round (PD3's rounds taper as `nseg - k`) must not drop block
        // storage that the next round — or the next PD3 call over the
        // same workspace — would have to reallocate.  Entries past
        // `tasks.len()` are simply left untouched.
        if out.len() < tasks.len() {
            out.resize_with(tasks.len(), || TileOutputs::sized(segn));
        }
        // Resolve `Auto` once up front so every tile of the batch (and
        // every worker) runs the same concrete kernel.
        let kernel = self.cfg.kernel.resolve();
        let threads = self.cfg.threads.max(1).min(tasks.len().max(1));
        if threads <= 1 || tasks.len() <= 1 {
            for (task, o) in tasks.iter().zip(out.iter_mut()) {
                let ks = with_tile_scratch(|s| {
                    compute_tile_into(view, segn, r2, *task, kernel, s, Some(&self.seeds), o)
                });
                self.note_kernel_stats(ks);
            }
            return Ok(());
        }
        let seeds = &self.seeds;
        let slots = SliceWriter::new(&mut out[..tasks.len()]);
        self.pool().run(tasks.len(), |i| {
            // SAFETY: the round cursor hands out each index exactly
            // once, and `out` outlives the (blocking) round.
            let o = unsafe { slots.slot(i) };
            let ks = with_tile_scratch(|s| {
                compute_tile_into(view, segn, r2, tasks[i], kernel, s, Some(seeds), o)
            });
            self.note_kernel_stats(ks);
        });
        Ok(())
    }

    fn prepare_series(&self, view: &SeriesView<'_>) {
        if self.cfg.pipeline == TilePipeline::Scratch {
            self.seeds.prepare(view.t);
        }
    }

    fn prefetch_length(&self, t: &[f64], next_m: usize) -> u64 {
        if self.cfg.pipeline != TilePipeline::Scratch {
            return 0;
        }
        // O(1) identity guard only — callers switching series should
        // bind via prepare_series first (the streaming refresh does;
        // MERLIN's length loop is already bound).  This re-prepare is a
        // safety net for direct callers, and cannot see through an
        // identity collision (same ptr/len, new content) — exactly why
        // the cache's authoritative validation stays the content
        // fingerprint in prepare.
        if !self.seeds.is_bound(t) {
            self.seeds.prepare(t);
        }
        let pool = if self.cfg.threads > 1 { Some(self.pool()) } else { None };
        self.seeds.advance_all(t, next_m, pool)
    }

    fn perf_counters(&self) -> EnginePerfCounters {
        let mut c = self.seeds.counters();
        c.batches = self.batches.load(Ordering::Relaxed);
        c.batch_tiles = self.batch_tiles.load(Ordering::Relaxed);
        c.clamp_saturations = self.clamp_saturations.load(Ordering::Relaxed);
        c.flat_cells = self.flat_cells.load(Ordering::Relaxed);
        // Identity, not a count: the concrete kernel this engine's tiles
        // run (Auto resolved), for METRICS `kernel=` visibility.
        c.kernel = Some(self.cfg.kernel.resolve());
        c
    }

    fn export_seed_rows(&self, t: &[f64]) -> Vec<SeedRowSnapshot> {
        // The f32 kernel seeds each tile with fresh f32 dot products (no
        // QtSeedCache rows are consumed), so exporting the f64 cache
        // would checkpoint state the restore path never reads — resume
        // re-seeds instead, trivially bit-identical.
        if self.cfg.pipeline != TilePipeline::Scratch
            || self.cfg.kernel.resolve() == TileKernel::Lanes4F32
        {
            return Vec::new();
        }
        self.seeds.export_rows(t)
    }

    fn import_seed_rows(&self, t: &[f64], rows: &[SeedRowSnapshot]) -> u64 {
        if self.cfg.pipeline != TilePipeline::Scratch
            || self.cfg.kernel.resolve() == TileKernel::Lanes4F32
        {
            return 0;
        }
        self.seeds.import_rows(t, rows)
    }
}

/// Evaluate one (segment, chunk) tile into recycled buffers.
///
/// Semantics identical to the AOT kernel: pairs inside the exclusion zone
/// `|gi - gj| < m` or out of window bounds contribute `+inf` minima and
/// never kill.  With `seeds: None` the first row's QT products are
/// computed fresh (bit-identical to [`compute_tile_alloc`]); with a cache
/// they are reused/advanced across lengths (equal within the oracle
/// tolerance — the recurrence rounds differently).  The per-row SoA
/// passes live in [`super::scratch`] and dispatch on `kernel` (`Auto`
/// is resolved here, so direct callers get the same detection as the
/// engine); every f64 kernel produces bit-identical outputs, while
/// [`TileKernel::Lanes4F32`] routes to the f32 twin loop below and is
/// equal within the documented tolerance band (see [`TileKernel`]).
/// Returns the tile's kernel event counts for the engine gauges.
#[allow(clippy::too_many_arguments)] // the tile pipeline's full context
pub(crate) fn compute_tile_into(
    view: &SeriesView<'_>,
    segn: usize,
    r2: f64,
    task: TileTask,
    kernel: TileKernel,
    scratch: &mut TileScratch,
    seeds: Option<&QtSeedCache>,
    out: &mut TileOutputs,
) -> TileKernelStats {
    let kernel = kernel.resolve();
    if kernel == TileKernel::Lanes4F32 {
        // The f32 loop ignores the f64 seed cache by design: fresh f32
        // seed dots per tile keep its precision story self-contained.
        return compute_tile_into_f32(view, segn, r2, task, scratch, out);
    }
    let m = view.stats.m;
    let t = view.t;
    let nwin = view.n_windows();
    let (ss, cs) = (task.seg_start, task.chunk_start);
    let na = segn.min(nwin.saturating_sub(ss));
    let nb = segn.min(nwin.saturating_sub(cs));

    let mut kstats = TileKernelStats::default();
    out.reset(segn);
    if na == 0 || nb == 0 {
        return kstats;
    }
    scratch.ensure(segn);
    let TileScratch { mmu_b, inv_msig_b, qt, qt_prev, dist, .. } = scratch;

    let mu = &view.stats.mu;
    let sig = &view.stats.sig;

    // Per-column precomputation for the fast path (reused by every row):
    // dist = 2m - 2m * clamp((qt - (m*mu_b)*mu_a) * (1/(m*sig_b)) / sig_a).
    let mf = m as f64;
    let two_m = 2.0 * mf;
    let any_flat = stat_products_into(
        &mu[cs..cs + nb],
        &sig[cs..cs + nb],
        mf,
        &mut mmu_b[..nb],
        &mut inv_msig_b[..nb],
    );

    for i in 0..na {
        let a = ss + i;
        // Exclusion zone |a - b| < m, b = cs + j: hoisted to a j-interval
        // and applied as a mask over the distance row below.
        let jlo = (a + 1).saturating_sub(m).saturating_sub(cs).min(nb); // first excluded
        let jhi = (a + m).saturating_sub(cs).min(nb); // one past last excluded

        let mu_a = mu[a];
        let sig_a = sig[a];
        let inv_sig_a = 1.0 / sig_a;
        let general = any_flat || is_flat(sig_a, mu_a);

        if i == 0 {
            // Seed row: cached/advanced when possible, else direct dot
            // products, O(nb * m).
            match seeds {
                Some(cache) => cache.seed_into(t, m, a, cs, nb, &mut qt[..nb]),
                None => {
                    let wa = &t[a..a + m];
                    for (j, q) in qt[..nb].iter_mut().enumerate() {
                        *q = dot(wa, &t[cs + j..cs + j + m]);
                    }
                }
            }
        } else {
            // Diagonal recurrence (Eq. 10): O(1) per cell, branch-free
            // (kept as its own pass — fusing it with the distance loop
            // measured slower; EXPERIMENTS.md §Perf).
            qt_recurrence_row(kernel, t, m, a, cs, &qt_prev[..nb], &mut qt[..nb]);
        }

        // Pass 1 — distances into contiguous scratch, branchless.  The
        // excluded interval is computed too (cheaper than branching) and
        // masked right after.
        if !general {
            kstats.saturated += distance_row(
                kernel,
                &qt[..nb],
                &mmu_b[..nb],
                &inv_msig_b[..nb],
                mu_a,
                inv_sig_a,
                two_m,
                &mut dist[..nb],
            );
        } else {
            // Flat-window path: full Eq. 6 semantics per cell, one
            // shared implementation for both kernels.
            kstats.flat_cells += nb as u64;
            general_distance_row(&qt[..nb], m, mu_a, sig_a, mu, sig, cs, &mut dist[..nb]);
        }
        for d in &mut dist[jlo..jhi] {
            *d = f64::INFINITY;
        }

        // Pass 2 — row folds (min + kill-any) over the distance row.
        let (rmin, rkill) = row_folds(kernel, &dist[..nb], r2);
        out.row_min[i] = rmin;
        out.row_kill[i] = rkill;

        // Pass 3 — column folds (elementwise min + kill mask).
        col_folds(kernel, &dist[..nb], r2, &mut out.col_min[..nb], &mut out.col_kill[..nb]);

        std::mem::swap(qt, qt_prev);
    }
    kstats
}

/// f32 twin of the tile loop above, behind [`TileKernel::Lanes4F32`].
///
/// Same pass structure, one precision down: the series stays f64 and is
/// narrowed on the fly at the loads ([`LaneElem::from_f64`] inside
/// `dot_w` / `qt_recurrence_row_w` / `stat_products_into`), the row
/// passes run the shared width-generic bodies at `<f32, LANES>`, and the
/// folded minima widen exactly back into the f64 [`TileOutputs`].  Flat
/// detection stays on the f64 stats, so `flat_cells` routing is
/// kernel-invariant by construction and the general path reuses the f64
/// Eq. 6 scalar core.  Seed rows are fresh f32 dot products every tile —
/// no [`QtSeedCache`] coupling, which is why the engine exports no seed
/// rows under this kernel.  Equality contract vs. the f64 kernels is the
/// tolerance band in `tests/kernel_conformance.rs`.
///
/// [`LaneElem::from_f64`]: crate::core::distance::LaneElem::from_f64
fn compute_tile_into_f32(
    view: &SeriesView<'_>,
    segn: usize,
    r2: f64,
    task: TileTask,
    scratch: &mut TileScratch,
    out: &mut TileOutputs,
) -> TileKernelStats {
    let m = view.stats.m;
    let t = view.t;
    let nwin = view.n_windows();
    let (ss, cs) = (task.seg_start, task.chunk_start);
    let na = segn.min(nwin.saturating_sub(ss));
    let nb = segn.min(nwin.saturating_sub(cs));

    let mut kstats = TileKernelStats::default();
    out.reset(segn);
    if na == 0 || nb == 0 {
        return kstats;
    }
    scratch.ensure_f32(segn);
    let TileScratch { mmu_b32, inv_msig_b32, qt32, qt_prev32, dist32, col_min32, .. } = scratch;

    let mu = &view.stats.mu;
    let sig = &view.stats.sig;
    let mf = m as f64;
    // order: the kernel's working-precision constants are narrowed once
    // per tile, before any per-cell arithmetic touches them.
    let two_m32 = (2.0 * mf) as f32;
    let r2f = r2 as f32;
    let any_flat = stat_products_into::<f32>(
        &mu[cs..cs + nb],
        &sig[cs..cs + nb],
        mf,
        &mut mmu_b32[..nb],
        &mut inv_msig_b32[..nb],
    );
    // Column minima fold in f32 and widen (exactly) once per tile.
    for c in col_min32[..nb].iter_mut() {
        *c = f32::INFINITY;
    }

    for i in 0..na {
        let a = ss + i;
        let jlo = (a + 1).saturating_sub(m).saturating_sub(cs).min(nb); // first excluded
        let jhi = (a + m).saturating_sub(cs).min(nb); // one past last excluded

        let mu_a = mu[a];
        let sig_a = sig[a];
        let general = any_flat || is_flat(sig_a, mu_a);
        // order: per-row stats narrow after the f64 reciprocal — same
        // sequence `stat_products_into` uses for the column factors.
        let mu_a32 = mu_a as f32;
        let inv_sig_a32 = (1.0 / sig_a) as f32;

        if i == 0 {
            // Seed row: fresh f32-accumulated dot products, O(nb * m).
            let wa = &t[a..a + m];
            for (j, q) in qt32[..nb].iter_mut().enumerate() {
                *q = dot_w::<f32>(wa, &t[cs + j..cs + j + m]);
            }
        } else {
            qt_recurrence_row_w::<f32, LANES>(t, m, a, cs, &qt_prev32[..nb], &mut qt32[..nb]);
        }

        if !general {
            kstats.saturated += distance_row_w::<f32, LANES>(
                &qt32[..nb],
                &mmu_b32[..nb],
                &inv_msig_b32[..nb],
                mu_a32,
                inv_sig_a32,
                two_m32,
                &mut dist32[..nb],
            );
        } else {
            // Flat-window path: widen qt, run the shared f64 Eq. 6 core,
            // narrow the result — flat decisions never happen in f32.
            kstats.flat_cells += nb as u64;
            general_distance_row_f32(&qt32[..nb], m, mu_a, sig_a, mu, sig, cs, &mut dist32[..nb]);
        }
        for d in &mut dist32[jlo..jhi] {
            *d = f32::INFINITY;
        }

        let (rmin, rkill) = row_folds_w::<f32, LANES>(&dist32[..nb], r2f);
        out.row_min[i] = f64::from(rmin); // exact widening
        out.row_kill[i] = rkill;

        col_folds_w::<f32, LANES>(&dist32[..nb], r2f, &mut col_min32[..nb], &mut out.col_kill[..nb]);

        std::mem::swap(qt32, qt_prev32);
    }
    for (o, &c) in out.col_min[..nb].iter_mut().zip(col_min32[..nb].iter()) {
        *o = f64::from(c); // exact widening (infinities included)
    }
    kstats
}

/// Evaluate one (segment, chunk) tile, allocating a fresh output block,
/// with the default kernel.
///
/// Uses this thread's scratch arena and no seed cache — deterministic and
/// bit-identical to the engine's cold-cache batch path; the oracle entry
/// point for tests and benches.
pub fn compute_tile(view: &SeriesView<'_>, segn: usize, r2: f64, task: TileTask) -> TileOutputs {
    compute_tile_with_kernel(view, segn, r2, task, TileKernel::default())
}

/// [`compute_tile`] with an explicit kernel — the entry point the
/// differential conformance harness and the `simd_kernel` microbench
/// drive (the f64 kernels are bit-identical, so which one
/// [`compute_tile`] defaults to — `Auto` resolves to `Lanes8` or
/// `Lanes4` — is a performance choice, not a semantic one;
/// `Lanes4F32` is the deliberate tolerance-banded exception).
pub fn compute_tile_with_kernel(
    view: &SeriesView<'_>,
    segn: usize,
    r2: f64,
    task: TileTask,
    kernel: TileKernel,
) -> TileOutputs {
    let mut out = TileOutputs::sized(segn);
    with_tile_scratch(|scratch| {
        compute_tile_into(view, segn, r2, task, kernel, scratch, None, &mut out);
    });
    out
}

/// The pre-optimization tile evaluation, verbatim: allocates ~8 vectors
/// per tile and folds everything through one fused per-cell closure.
/// Kept as the microbench "before" side and as an independent oracle.
pub fn compute_tile_alloc(
    view: &SeriesView<'_>,
    segn: usize,
    r2: f64,
    task: TileTask,
) -> TileOutputs {
    let m = view.stats.m;
    let t = view.t;
    let nwin = view.n_windows();
    let (ss, cs) = (task.seg_start, task.chunk_start);
    let na = segn.min(nwin.saturating_sub(ss));
    let nb = segn.min(nwin.saturating_sub(cs));

    let mut out = TileOutputs {
        row_min: vec![f64::INFINITY; segn],
        col_min: vec![f64::INFINITY; segn],
        row_kill: vec![false; segn],
        col_kill: vec![false; segn],
    };
    if na == 0 || nb == 0 {
        return out;
    }

    let mu = &view.stats.mu;
    let sig = &view.stats.sig;

    let mf = m as f64;
    let two_m = 2.0 * mf;
    let mut mmu_b = vec![0.0f64; nb];
    let mut inv_msig_b = vec![0.0f64; nb];
    let mut any_flat = false;
    for j in 0..nb {
        let b = cs + j;
        mmu_b[j] = mf * mu[b];
        inv_msig_b[j] = 1.0 / (mf * sig[b]);
        any_flat |= is_flat(sig[b], mu[b]);
    }

    // qt[j] holds dot(T[a..a+m], T[b..b+m]) for the current row's a.
    let mut qt = vec![0.0f64; nb];
    let mut qt_prev = vec![0.0f64; nb];

    for i in 0..na {
        let a = ss + i;
        let jlo = (a + 1).saturating_sub(m).saturating_sub(cs).min(nb); // first excluded
        let jhi = (a + m).saturating_sub(cs).min(nb); // one past last excluded

        let mu_a = mu[a];
        let sig_a = sig[a];
        let inv_sig_a = 1.0 / sig_a;
        let mut rmin = f64::INFINITY;
        let mut rkill = false;
        let general = any_flat || is_flat(sig_a, mu_a);

        if i == 0 {
            let wa = &t[a..a + m];
            for (j, q) in qt.iter_mut().enumerate() {
                let b = cs + j;
                *q = dot(wa, &t[b..b + m]);
            }
        } else {
            let head = t[a - 1];
            let tail = t[a + m - 1];
            qt[0] = dot(&t[a..a + m], &t[cs..cs + m]);
            for j in 1..nb {
                let b = cs + j;
                qt[j] = qt_prev[j - 1] + tail * t[b + m - 1] - head * t[b - 1];
            }
        }

        let mut cell = |j: usize, rmin: &mut f64, rkill: &mut bool| {
            let d = if general {
                let b = cs + j;
                ed2norm_from_qt(qt[j], m, mu_a, sig_a, mu[b], sig[b])
            } else {
                let corr = (qt[j] - mmu_b[j] * mu_a) * (inv_msig_b[j] * inv_sig_a);
                two_m * (1.0 - corr.clamp(-1.0, 1.0))
            };
            if d < *rmin {
                *rmin = d;
            }
            if d < out.col_min[j] {
                out.col_min[j] = d;
            }
            if d < r2 {
                *rkill = true;
                out.col_kill[j] = true;
            }
        };
        for j in 0..jlo {
            cell(j, &mut rmin, &mut rkill);
        }
        for j in jhi..nb {
            cell(j, &mut rmin, &mut rkill);
        }
        out.row_min[i] = rmin;
        out.row_kill[i] = rkill;
        std::mem::swap(&mut qt, &mut qt_prev);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::ed2norm;
    use crate::core::stats::RollingStats;
    use crate::util::rng::Rng;

    /// Brute-force oracle mirroring `ref.dist_tile_ref` in python.
    fn oracle(t: &[f64], ss: usize, cs: usize, segn: usize, m: usize, r2: f64) -> TileOutputs {
        let nwin = t.len() - m + 1;
        let mut out = TileOutputs {
            row_min: vec![f64::INFINITY; segn],
            col_min: vec![f64::INFINITY; segn],
            row_kill: vec![false; segn],
            col_kill: vec![false; segn],
        };
        for i in 0..segn {
            let a = ss + i;
            if a >= nwin {
                continue;
            }
            for j in 0..segn {
                let b = cs + j;
                if b >= nwin || a.abs_diff(b) < m {
                    continue;
                }
                let d = ed2norm(&t[a..a + m], &t[b..b + m]);
                out.row_min[i] = out.row_min[i].min(d);
                out.col_min[j] = out.col_min[j].min(d);
                if d < r2 {
                    out.row_kill[i] = true;
                    out.col_kill[j] = true;
                }
            }
        }
        out
    }

    fn assert_outputs_close(got: &TileOutputs, want: &TileOutputs, segn: usize) {
        for k in 0..segn {
            let (g, w) = (got.row_min[k], want.row_min[k]);
            assert_eq!(g.is_finite(), w.is_finite(), "row {k} finiteness");
            if w.is_finite() {
                assert!((g - w).abs() < 1e-6 * (1.0 + w), "row {k}: {g} vs {w}");
            }
            let (g, w) = (got.col_min[k], want.col_min[k]);
            assert_eq!(g.is_finite(), w.is_finite(), "col {k} finiteness");
            if w.is_finite() {
                assert!((g - w).abs() < 1e-6 * (1.0 + w), "col {k}: {g} vs {w}");
            }
            assert_eq!(got.row_kill[k], want.row_kill[k], "row_kill {k}");
            assert_eq!(got.col_kill[k], want.col_kill[k], "col_kill {k}");
        }
    }

    fn check(t: &[f64], ss: usize, cs: usize, segn: usize, m: usize, r2: f64) {
        let stats = RollingStats::compute(t, m);
        let view = SeriesView { t, stats: &stats };
        let want = oracle(t, ss, cs, segn, m, r2);
        let task = TileTask { seg_start: ss, chunk_start: cs };
        let got = compute_tile(&view, segn, r2, task);
        assert_outputs_close(&got, &want, segn);
        // The legacy pipeline is a second oracle: must agree bit-exactly
        // with the scratch pipeline on the cold path.
        let legacy = compute_tile_alloc(&view, segn, r2, task);
        assert_eq!(got.row_min, legacy.row_min);
        assert_eq!(got.col_min, legacy.col_min);
        assert_eq!(got.row_kill, legacy.row_kill);
        assert_eq!(got.col_kill, legacy.col_kill);
    }

    fn random_walk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect()
    }

    #[test]
    fn matches_oracle_disjoint_tiles() {
        let t = random_walk(400, 1);
        check(&t, 0, 128, 32, 24, 10.0);
        check(&t, 64, 300, 32, 24, 20.0);
    }

    #[test]
    fn matches_oracle_self_tile_with_exclusion() {
        let t = random_walk(300, 2);
        check(&t, 40, 40, 48, 16, 8.0);
    }

    #[test]
    fn matches_oracle_overlapping_tiles() {
        let t = random_walk(300, 3);
        // Chunk starting inside the segment (partial exclusion).
        check(&t, 50, 70, 32, 25, 12.0);
        // Chunk to the LEFT of the segment (refinement phase).
        check(&t, 120, 30, 32, 25, 12.0);
    }

    #[test]
    fn matches_oracle_at_series_edge() {
        let t = random_walk(150, 4);
        // Tail tile: fewer than segn valid windows on both sides.
        check(&t, 100, 120, 32, 20, 5.0);
    }

    #[test]
    fn empty_when_out_of_bounds() {
        let t = random_walk(100, 5);
        let stats = RollingStats::compute(&t, 10);
        let view = SeriesView { t: &t, stats: &stats };
        let out = compute_tile(&view, 16, 1.0, TileTask { seg_start: 95, chunk_start: 0 });
        assert!(out.row_min.iter().all(|x| x.is_infinite()));
    }

    #[test]
    fn batch_api_matches_single() {
        let t = random_walk(500, 6);
        let stats = RollingStats::compute(&t, 32);
        let view = SeriesView { t: &t, stats: &stats };
        let engine = NativeEngine::with_segn(64);
        engine.prepare_series(&view);
        let tasks = vec![
            TileTask { seg_start: 0, chunk_start: 0 },
            TileTask { seg_start: 0, chunk_start: 64 },
            TileTask { seg_start: 128, chunk_start: 300 },
        ];
        let batch = engine.compute_tiles(&view, 9.0, &tasks).unwrap();
        for (k, task) in tasks.iter().enumerate() {
            let single = compute_tile(&view, 64, 9.0, *task);
            assert_eq!(batch[k].row_min, single.row_min);
            assert_eq!(batch[k].col_kill, single.col_kill);
        }
    }

    #[test]
    fn batch_counters_track_submissions() {
        let t = random_walk(300, 13);
        let stats = RollingStats::compute(&t, 16);
        let view = SeriesView { t: &t, stats: &stats };
        let engine = NativeEngine::with_segn(32);
        engine.prepare_series(&view);
        let tasks = vec![
            TileTask { seg_start: 0, chunk_start: 0 },
            TileTask { seg_start: 0, chunk_start: 32 },
        ];
        engine.compute_tiles(&view, 4.0, &tasks).unwrap();
        engine.compute_tiles(&view, 4.0, &tasks[..1]).unwrap();
        let c = engine.perf_counters();
        assert_eq!(c.batches, 2);
        assert_eq!(c.batch_tiles, 3);
    }

    #[test]
    fn constant_regions_finite() {
        // Stuck sensor: long constant run (PolyTER case study §5).
        let mut t = random_walk(200, 7);
        for v in &mut t[50..120] {
            *v = 42.0;
        }
        check(&t, 32, 96, 32, 16, 4.0);
    }

    #[test]
    fn recycled_buffers_and_seed_hits_stay_exact() {
        let t = random_walk(600, 8);
        let stats = RollingStats::compute(&t, 24);
        let view = SeriesView { t: &t, stats: &stats };
        let engine = NativeEngine::with_segn(64);
        engine.prepare_series(&view);
        let tasks: Vec<TileTask> = (0..4)
            .map(|k| TileTask { seg_start: 64 * k, chunk_start: 64 * ((k + 2) % 5) })
            .collect();
        let mut first = Vec::new();
        engine.compute_tiles_into(&view, 6.0, &tasks, &mut first).unwrap();
        // Second round: recycled outputs + warm seed cache (pure hits)
        // must reproduce the first round verbatim.
        let mut second = Vec::new();
        engine.compute_tiles_into(&view, 6.0, &tasks, &mut second).unwrap();
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.row_min, b.row_min);
            assert_eq!(a.col_min, b.col_min);
            assert_eq!(a.row_kill, b.row_kill);
            assert_eq!(a.col_kill, b.col_kill);
        }
        let c = engine.perf_counters();
        assert_eq!(c.seed_misses, 4, "first round seeds fresh");
        assert_eq!(c.seed_hits, 4, "second round served from cache");
    }

    #[test]
    fn cross_length_seed_advance_matches_fresh_engine() {
        let t = random_walk(700, 9);
        let engine = NativeEngine::with_segn(64);
        let tasks: Vec<TileTask> = (0..3)
            .map(|k| TileTask { seg_start: 64 * k, chunk_start: 64 * (k + 3) })
            .collect();
        let mut buf = Vec::new();
        // Sweep m = 20..28 on one engine (cache advances across lengths).
        let mut stats = RollingStats::compute(&t, 20);
        let mut swept: Vec<Vec<TileOutputs>> = Vec::new();
        for _ in 20..=28 {
            let view = SeriesView { t: &t, stats: &stats };
            engine.prepare_series(&view);
            engine.compute_tiles_into(&view, 5.0, &tasks, &mut buf).unwrap();
            swept.push(buf.clone());
            stats.advance(&t);
        }
        assert!(engine.perf_counters().seed_advances > 0, "sweep must advance seeds");
        // Every swept length must match a cold evaluation within the
        // oracle tolerance (the advance recurrence rounds differently).
        for (step, got) in swept.iter().enumerate() {
            let m = 20 + step;
            let fresh_stats = RollingStats::compute(&t, m);
            let view = SeriesView { t: &t, stats: &fresh_stats };
            for (k, task) in tasks.iter().enumerate() {
                let want = compute_tile(&view, 64, 5.0, *task);
                for i in 0..64 {
                    let (g, w) = (got[k].row_min[i], want.row_min[i]);
                    assert_eq!(g.is_finite(), w.is_finite(), "m={m} task {k} row {i}");
                    if w.is_finite() {
                        assert!((g - w).abs() < 1e-6 * (1.0 + w), "m={m} task {k} row {i}: {g} vs {w}");
                    }
                }
            }
        }
    }

    #[test]
    fn bulk_prefetch_keeps_seed_misses_flat_across_lengths() {
        // The tentpole counter pin: with prefetch_length called between
        // lengths, every length after the first is served entirely from
        // prefetched rows — seed_misses never moves again, and no tile
        // ever falls back to the lazy per-row advance.
        let t = random_walk(900, 14);
        let engine = NativeEngine::with_segn(64);
        let tasks: Vec<TileTask> = (0..4)
            .map(|k| TileTask { seg_start: 64 * k, chunk_start: 64 * (k + 4) })
            .collect();
        let (m0, steps) = (24usize, 8usize);
        let mut stats = RollingStats::compute(&t, m0);
        let mut buf = Vec::new();
        for step in 0..=steps {
            let view = SeriesView { t: &t, stats: &stats };
            engine.prepare_series(&view);
            engine.compute_tiles_into(&view, 5.0, &tasks, &mut buf).unwrap();
            let c = engine.perf_counters();
            assert_eq!(
                c.seed_misses,
                tasks.len() as u64,
                "step {step}: misses must stay flat after the first length"
            );
            if step < steps {
                stats.advance(&t);
                assert_eq!(
                    engine.prefetch_length(&t, m0 + step + 1),
                    tasks.len() as u64,
                    "step {step}: every cached row advances"
                );
            }
        }
        let c = engine.perf_counters();
        assert_eq!(c.seed_advances, 0, "prefetch subsumes all lazy advances");
        assert_eq!(c.seed_prefetched, (steps * tasks.len()) as u64);
        assert_eq!(c.prefetch_batches, steps as u64);
        assert_eq!(c.seed_hits, (steps * tasks.len()) as u64);
    }

    #[test]
    fn bulk_prefetch_is_bit_exact_with_lazy_advance() {
        // Two engines over the same sweep: one advances rows lazily
        // (per-tile, under the shard locks), one through the bulk sweep.
        // The sweep uses the lazy advance's operation order, so every
        // tile output must agree bit-for-bit.
        let t = random_walk(800, 15);
        let lazy = NativeEngine::with_segn(64);
        let bulk = NativeEngine::with_segn(64);
        let tasks: Vec<TileTask> = (0..4)
            .map(|k| TileTask { seg_start: 64 * k, chunk_start: 64 * ((k + 2) % 6) })
            .collect();
        let (m0, steps) = (20usize, 6usize);
        let mut stats = RollingStats::compute(&t, m0);
        let (mut lbuf, mut bbuf) = (Vec::new(), Vec::new());
        for step in 0..=steps {
            let view = SeriesView { t: &t, stats: &stats };
            lazy.prepare_series(&view);
            bulk.prepare_series(&view);
            lazy.compute_tiles_into(&view, 5.0, &tasks, &mut lbuf).unwrap();
            bulk.compute_tiles_into(&view, 5.0, &tasks, &mut bbuf).unwrap();
            for (k, (a, b)) in lbuf.iter().zip(&bbuf).enumerate() {
                assert_eq!(a.row_min, b.row_min, "m={} task {k}", m0 + step);
                assert_eq!(a.col_min, b.col_min, "m={} task {k}", m0 + step);
                assert_eq!(a.row_kill, b.row_kill, "m={} task {k}", m0 + step);
                assert_eq!(a.col_kill, b.col_kill, "m={} task {k}", m0 + step);
            }
            if step < steps {
                stats.advance(&t);
                bulk.prefetch_length(&t, m0 + step + 1);
            }
        }
        let (cl, cb) = (lazy.perf_counters(), bulk.perf_counters());
        assert_eq!(cl.seed_misses, cb.seed_misses, "prefetch must not add misses");
        assert!(cl.seed_advances > 0 && cb.seed_advances == 0);
        assert!(cb.seed_prefetched > 0);
    }

    #[test]
    fn legacy_pipeline_ignores_prefetch() {
        let t = random_walk(300, 16);
        let engine = NativeEngine::new(NativeConfig {
            segn: 32,
            pipeline: TilePipeline::Legacy,
            ..Default::default()
        });
        assert_eq!(engine.prefetch_length(&t, 10), 0);
        assert_eq!(engine.perf_counters().prefetch_batches, 0);
    }

    #[test]
    fn switching_series_without_prepare_is_safe() {
        // Callers that alternate series through the plain compute_tiles
        // API (no prepare_series) must never see another series' cached
        // seeds: the engine's O(1) identity guard re-binds the cache.
        let t1 = random_walk(500, 11);
        let t2 = random_walk(500, 12);
        let m = 20;
        let engine = NativeEngine::with_segn(64);
        let tasks = vec![
            TileTask { seg_start: 0, chunk_start: 128 },
            TileTask { seg_start: 64, chunk_start: 256 },
        ];
        let s1 = RollingStats::compute(&t1, m);
        let v1 = SeriesView { t: &t1, stats: &s1 };
        engine.compute_tiles(&v1, 4.0, &tasks).unwrap(); // caches t1 seeds
        let s2 = RollingStats::compute(&t2, m);
        let v2 = SeriesView { t: &t2, stats: &s2 };
        let got = engine.compute_tiles(&v2, 4.0, &tasks).unwrap();
        for (k, task) in tasks.iter().enumerate() {
            let want = compute_tile(&v2, 64, 4.0, *task);
            assert_eq!(got[k].row_min, want.row_min, "task {k}");
            assert_eq!(got[k].col_min, want.col_min, "task {k}");
            assert_eq!(got[k].col_kill, want.col_kill, "task {k}");
        }
    }

    #[test]
    fn kernels_agree_bitwise_and_count_identically() {
        // Off-grid tile edge (33 % LANES != 0) plus a stuck-sensor
        // plateau, so the lane tail loop AND the shared flat path are
        // both on the hot path; threads > 1 exercises the per-tile
        // counter flush through the pool.
        let mut t = random_walk(700, 17);
        for v in &mut t[300..420] {
            *v = 7.5;
        }
        let m = 24;
        let stats = RollingStats::compute(&t, m);
        let view = SeriesView { t: &t, stats: &stats };
        let nwin = view.n_windows();
        let mk = |kernel| {
            NativeEngine::new(NativeConfig { segn: 33, threads: 4, kernel, ..Default::default() })
        };
        let scalar = mk(TileKernel::Scalar);
        let mut tasks: Vec<TileTask> = (0..8)
            .map(|k| TileTask { seg_start: 33 * (k % 4) + 250, chunk_start: 33 * k })
            .collect();
        // Tail tiles: a single-column chunk and a single-row segment (for
        // Lanes8, segn % 8 = 1 exercises sub-width tails everywhere).
        tasks.push(TileTask { seg_start: 0, chunk_start: nwin - 1 });
        tasks.push(TileTask { seg_start: nwin - 1, chunk_start: 100 });
        scalar.prepare_series(&view);
        let a = scalar.compute_tiles(&view, 6.0, &tasks).unwrap();
        let ca = scalar.perf_counters();
        assert_eq!(ca.kernel, Some(TileKernel::Scalar), "counters must name the kernel");
        assert!(ca.flat_cells > 0, "plateau rows must be counted through the flat path");
        for kern in [TileKernel::Lanes4, TileKernel::Lanes8, TileKernel::Auto] {
            let lanes = mk(kern);
            lanes.prepare_series(&view);
            let b = lanes.compute_tiles(&view, 6.0, &tasks).unwrap();
            for (k, (x, y)) in a.iter().zip(&b).enumerate() {
                let bits = |v: &[f64]| v.iter().map(|d| d.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&x.row_min), bits(&y.row_min), "{kern:?} task {k} row_min");
                assert_eq!(bits(&x.col_min), bits(&y.col_min), "{kern:?} task {k} col_min");
                assert_eq!(x.row_kill, y.row_kill, "{kern:?} task {k} row_kill");
                assert_eq!(x.col_kill, y.col_kill, "{kern:?} task {k} col_kill");
            }
            let cb = lanes.perf_counters();
            assert_eq!(
                ca.clamp_saturations, cb.clamp_saturations,
                "{kern:?} took different clamp decisions"
            );
            assert_eq!(ca.flat_cells, cb.flat_cells, "{kern:?} routed the flat path differently");
            assert_eq!(cb.kernel, Some(kern.resolve()), "{kern:?} counters must resolve Auto");
        }
    }

    #[test]
    fn f32_kernel_engine_runs_and_exports_no_seed_rows() {
        // The tolerance-band conformance proper lives in
        // tests/kernel_conformance.rs; this is the engine-level contract:
        // the f32 kernel computes through the same batch path, reports
        // itself in the counters, and opts out of seed-row checkpoints
        // (fresh f32 seeds every tile — nothing to round-trip).
        let t = random_walk(600, 21);
        let m = 20;
        let stats = RollingStats::compute(&t, m);
        let view = SeriesView { t: &t, stats: &stats };
        let mk = |kernel| {
            NativeEngine::new(NativeConfig { segn: 33, threads: 2, kernel, ..Default::default() })
        };
        let f32e = mk(TileKernel::Lanes4F32);
        let f64e = mk(TileKernel::Lanes4);
        let tasks: Vec<TileTask> =
            (0..4).map(|k| TileTask { seg_start: 33 * k, chunk_start: 66 * k }).collect();
        f32e.prepare_series(&view);
        f64e.prepare_series(&view);
        let a = f32e.compute_tiles(&view, 6.0, &tasks).unwrap();
        let b = f64e.compute_tiles(&view, 6.0, &tasks).unwrap();
        // Same error bound the conformance harness derives:
        // band(m) = 2m * (m + 8) * KAPPA * eps_f32 (EXPERIMENTS.md §SIMD).
        let mf = m as f64;
        let band = 2.0 * mf * (mf + 8.0) * 4096.0 * f64::from(f32::EPSILON);
        for (k, (x, y)) in a.iter().zip(&b).enumerate() {
            for (i, (&d32, &d64)) in x.row_min.iter().zip(&y.row_min).enumerate() {
                if d64.is_finite() {
                    assert!((d32 - d64).abs() <= band, "task {k} row {i}: {d32} vs {d64}");
                } else {
                    assert!(!d32.is_finite(), "task {k} row {i}: finite f32 vs inf f64");
                }
            }
        }
        assert_eq!(f32e.perf_counters().kernel, Some(TileKernel::Lanes4F32));
        assert!(
            f32e.export_seed_rows(&t).is_empty(),
            "f32 kernel must not checkpoint f64 seed rows"
        );
        assert!(!f64e.export_seed_rows(&t).is_empty(), "f64 export stays live");
        // Importing under the f32 kernel is a no-op by the same rule.
        assert_eq!(f32e.import_seed_rows(&t, &f64e.export_seed_rows(&t)), 0);
    }

    #[test]
    fn legacy_pipeline_engine_matches_scratch_engine() {
        let t = random_walk(900, 10);
        let stats = RollingStats::compute(&t, 32);
        let view = SeriesView { t: &t, stats: &stats };
        let scratch = NativeEngine::new(NativeConfig { segn: 64, ..Default::default() });
        let legacy = NativeEngine::new(NativeConfig {
            segn: 64,
            pipeline: TilePipeline::Legacy,
            ..Default::default()
        });
        scratch.prepare_series(&view);
        let tasks: Vec<TileTask> = (0..6)
            .map(|k| TileTask { seg_start: 128 * (k % 3), chunk_start: 64 * k })
            .collect();
        let a = scratch.compute_tiles(&view, 8.0, &tasks).unwrap();
        let b = legacy.compute_tiles(&view, 8.0, &tasks).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.row_min, y.row_min);
            assert_eq!(x.col_min, y.col_min);
            assert_eq!(x.row_kill, y.row_kill);
            assert_eq!(x.col_kill, y.col_kill);
        }
    }
}
