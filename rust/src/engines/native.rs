//! Pure-rust tile engine: the correctness oracle and CPU baseline.
//!
//! Implements the same computation as the AOT tile kernel (layer 2's
//! `tile_min`) but in `f64` using the paper's QT diagonal recurrence
//! (Eq. 10): the dot product of neighboring window pairs differs by one
//! multiply-add, so a whole `segn x segn` tile costs
//! `O(segn * m + segn^2)` instead of `O(segn^2 * m)`.
//!
//! Tasks in a batch run across a scoped thread pool
//! ([`crate::util::pool::parallel_map_indexed`]); each task is
//! independent, so the batch scales to the tile-skew limit.

use anyhow::Result;

use super::{Engine, SeriesView, TileTask};
use crate::core::distance::{dot, ed2norm_from_qt, is_flat};
use crate::runtime::types::TileOutputs;
use crate::util::pool;

/// Configuration for [`NativeEngine`].
#[derive(Clone, Debug)]
pub struct NativeConfig {
    /// Tile edge (paper's `segN`).
    pub segn: usize,
    /// Worker threads for tile batches.
    pub threads: usize,
}

impl Default for NativeConfig {
    fn default() -> Self {
        Self { segn: 256, threads: pool::default_threads() }
    }
}

/// Pure-rust engine.
pub struct NativeEngine {
    cfg: NativeConfig,
}

impl NativeEngine {
    pub fn new(cfg: NativeConfig) -> Self {
        assert!(cfg.segn >= 1);
        Self { cfg }
    }

    pub fn with_segn(segn: usize) -> Self {
        Self::new(NativeConfig { segn, ..Default::default() })
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn segn(&self) -> usize {
        self.cfg.segn
    }

    fn max_m(&self) -> usize {
        usize::MAX
    }

    fn compute_tiles(
        &self,
        view: &SeriesView<'_>,
        r2: f64,
        tasks: &[TileTask],
    ) -> Result<Vec<TileOutputs>> {
        let segn = self.cfg.segn;
        Ok(pool::parallel_map_indexed(tasks.len(), self.cfg.threads, |i| {
            compute_tile(view, segn, r2, tasks[i])
        }))
    }
}

/// Evaluate one (segment, chunk) tile; see module docs.
///
/// Semantics identical to the AOT kernel: pairs inside the exclusion zone
/// `|gi - gj| < m` or out of window bounds contribute `+inf` minima and
/// never kill.
pub fn compute_tile(view: &SeriesView<'_>, segn: usize, r2: f64, task: TileTask) -> TileOutputs {
    let m = view.stats.m;
    let t = view.t;
    let nwin = view.n_windows();
    let (ss, cs) = (task.seg_start, task.chunk_start);
    let na = segn.min(nwin.saturating_sub(ss));
    let nb = segn.min(nwin.saturating_sub(cs));

    let mut out = TileOutputs {
        row_min: vec![f64::INFINITY; segn],
        col_min: vec![f64::INFINITY; segn],
        row_kill: vec![false; segn],
        col_kill: vec![false; segn],
    };
    if na == 0 || nb == 0 {
        return out;
    }

    let mu = &view.stats.mu;
    let sig = &view.stats.sig;

    // Per-column precomputation for the fast path (reused by every row):
    // dist = 2m - 2m * clamp((qt - (m*mu_b)*mu_a) * (1/(m*sig_b)) / sig_a).
    let mf = m as f64;
    let two_m = 2.0 * mf;
    let mut mmu_b = vec![0.0f64; nb];
    let mut inv_msig_b = vec![0.0f64; nb];
    let mut any_flat = false;
    for j in 0..nb {
        let b = cs + j;
        mmu_b[j] = mf * mu[b];
        inv_msig_b[j] = 1.0 / (mf * sig[b]);
        any_flat |= is_flat(sig[b], mu[b]);
    }

    // qt[j] holds dot(T[a..a+m], T[b..b+m]) for the current row's a.
    let mut qt = vec![0.0f64; nb];
    let mut qt_prev = vec![0.0f64; nb];

    for i in 0..na {
        let a = ss + i;
        // Exclusion zone |a - b| < m, b = cs + j: hoist to a j-interval so
        // the inner loop stays branch-light (perf pass; see EXPERIMENTS.md
        // §Perf for the before/after).
        let jlo = (a + 1).saturating_sub(m).saturating_sub(cs).min(nb); // first excluded
        let jhi = (a + m).saturating_sub(cs).min(nb); // one past last excluded

        let mu_a = mu[a];
        let sig_a = sig[a];
        let inv_sig_a = 1.0 / sig_a;
        let mut rmin = f64::INFINITY;
        let mut rkill = false;
        let general = any_flat || is_flat(sig_a, mu_a);

        if i == 0 {
            // Seed row: direct dot products, O(nb * m).
            let wa = &t[a..a + m];
            for (j, q) in qt.iter_mut().enumerate() {
                let b = cs + j;
                *q = dot(wa, &t[b..b + m]);
            }
        } else {
            // Diagonal recurrence (Eq. 10): O(1) per cell, branch-free,
            // vectorizable (kept as its own pass — fusing it with the
            // distance loop measured slower; EXPERIMENTS.md §Perf).
            let head = t[a - 1];
            let tail = t[a + m - 1];
            qt[0] = dot(&t[a..a + m], &t[cs..cs + m]);
            for j in 1..nb {
                let b = cs + j;
                qt[j] = qt_prev[j - 1] + tail * t[b + m - 1] - head * t[b - 1];
            }
        }

        let mut cell = |j: usize, rmin: &mut f64, rkill: &mut bool| {
            let d = if general {
                let b = cs + j;
                ed2norm_from_qt(qt[j], m, mu_a, sig_a, mu[b], sig[b])
            } else {
                // dist = 2m * (1 - clamp((qt - (m*mu_b)*mu_a) / (m*sig_b*sig_a)))
                let corr = (qt[j] - mmu_b[j] * mu_a) * (inv_msig_b[j] * inv_sig_a);
                two_m * (1.0 - corr.clamp(-1.0, 1.0))
            };
            if d < *rmin {
                *rmin = d;
            }
            if d < out.col_min[j] {
                out.col_min[j] = d;
            }
            if d < r2 {
                *rkill = true;
                out.col_kill[j] = true;
            }
        };
        for j in 0..jlo {
            cell(j, &mut rmin, &mut rkill);
        }
        for j in jhi..nb {
            cell(j, &mut rmin, &mut rkill);
        }
        out.row_min[i] = rmin;
        out.row_kill[i] = rkill;
        std::mem::swap(&mut qt, &mut qt_prev);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::ed2norm;
    use crate::core::stats::RollingStats;
    use crate::util::rng::Rng;

    /// Brute-force oracle mirroring `ref.dist_tile_ref` in python.
    fn oracle(t: &[f64], ss: usize, cs: usize, segn: usize, m: usize, r2: f64) -> TileOutputs {
        let nwin = t.len() - m + 1;
        let mut out = TileOutputs {
            row_min: vec![f64::INFINITY; segn],
            col_min: vec![f64::INFINITY; segn],
            row_kill: vec![false; segn],
            col_kill: vec![false; segn],
        };
        for i in 0..segn {
            let a = ss + i;
            if a >= nwin {
                continue;
            }
            for j in 0..segn {
                let b = cs + j;
                if b >= nwin || a.abs_diff(b) < m {
                    continue;
                }
                let d = ed2norm(&t[a..a + m], &t[b..b + m]);
                out.row_min[i] = out.row_min[i].min(d);
                out.col_min[j] = out.col_min[j].min(d);
                if d < r2 {
                    out.row_kill[i] = true;
                    out.col_kill[j] = true;
                }
            }
        }
        out
    }

    fn check(t: &[f64], ss: usize, cs: usize, segn: usize, m: usize, r2: f64) {
        let stats = RollingStats::compute(t, m);
        let view = SeriesView { t, stats: &stats };
        let got = compute_tile(&view, segn, r2, TileTask { seg_start: ss, chunk_start: cs });
        let want = oracle(t, ss, cs, segn, m, r2);
        for k in 0..segn {
            let (g, w) = (got.row_min[k], want.row_min[k]);
            assert_eq!(g.is_finite(), w.is_finite(), "row {k} finiteness");
            if w.is_finite() {
                assert!((g - w).abs() < 1e-6 * (1.0 + w), "row {k}: {g} vs {w}");
            }
            let (g, w) = (got.col_min[k], want.col_min[k]);
            assert_eq!(g.is_finite(), w.is_finite(), "col {k} finiteness");
            if w.is_finite() {
                assert!((g - w).abs() < 1e-6 * (1.0 + w), "col {k}: {g} vs {w}");
            }
            assert_eq!(got.row_kill[k], want.row_kill[k], "row_kill {k}");
            assert_eq!(got.col_kill[k], want.col_kill[k], "col_kill {k}");
        }
    }

    fn random_walk(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed(seed);
        let mut acc = 0.0;
        (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect()
    }

    #[test]
    fn matches_oracle_disjoint_tiles() {
        let t = random_walk(400, 1);
        check(&t, 0, 128, 32, 24, 10.0);
        check(&t, 64, 300, 32, 24, 20.0);
    }

    #[test]
    fn matches_oracle_self_tile_with_exclusion() {
        let t = random_walk(300, 2);
        check(&t, 40, 40, 48, 16, 8.0);
    }

    #[test]
    fn matches_oracle_overlapping_tiles() {
        let t = random_walk(300, 3);
        // Chunk starting inside the segment (partial exclusion).
        check(&t, 50, 70, 32, 25, 12.0);
        // Chunk to the LEFT of the segment (refinement phase).
        check(&t, 120, 30, 32, 25, 12.0);
    }

    #[test]
    fn matches_oracle_at_series_edge() {
        let t = random_walk(150, 4);
        // Tail tile: fewer than segn valid windows on both sides.
        check(&t, 100, 120, 32, 20, 5.0);
    }

    #[test]
    fn empty_when_out_of_bounds() {
        let t = random_walk(100, 5);
        let stats = RollingStats::compute(&t, 10);
        let view = SeriesView { t: &t, stats: &stats };
        let out = compute_tile(&view, 16, 1.0, TileTask { seg_start: 95, chunk_start: 0 });
        assert!(out.row_min.iter().all(|x| x.is_infinite()));
    }

    #[test]
    fn batch_api_matches_single() {
        let t = random_walk(500, 6);
        let stats = RollingStats::compute(&t, 32);
        let view = SeriesView { t: &t, stats: &stats };
        let engine = NativeEngine::with_segn(64);
        let tasks = vec![
            TileTask { seg_start: 0, chunk_start: 0 },
            TileTask { seg_start: 0, chunk_start: 64 },
            TileTask { seg_start: 128, chunk_start: 300 },
        ];
        let batch = engine.compute_tiles(&view, 9.0, &tasks).unwrap();
        for (k, task) in tasks.iter().enumerate() {
            let single = compute_tile(&view, 64, 9.0, *task);
            assert_eq!(batch[k].row_min, single.row_min);
            assert_eq!(batch[k].col_kill, single.col_kill);
        }
    }

    #[test]
    fn constant_regions_finite() {
        // Stuck sensor: long constant run (PolyTER case study §5).
        let mut t = random_walk(200, 7);
        for v in &mut t[50..120] {
            *v = 42.0;
        }
        check(&t, 32, 96, 32, 16, 4.0);
    }
}
