//! Per-worker scratch arena and the cross-length QT seed cache — the
//! allocation-free substrate of the native tile pipeline.
//!
//! **Scratch arena.**  One [`TileScratch`] per worker thread holds every
//! intermediate buffer a tile evaluation needs (per-column stat products,
//! the two QT diagonal rows, the SoA distance row).  Buffers are sized
//! once per tile edge and reused for every subsequent tile, so the
//! steady-state inner loop performs zero heap allocations (verified by
//! the counting-allocator integration test).
//!
//! **QT seed cache.**  The paper eliminates cross-length redundancy for
//! the rolling statistics (Eqs. 7/8); this cache extends the same idea to
//! the dot-product layer.  Every tile's first row needs the seed products
//! `QT[j] = dot(T[a..a+m], T[b..b+m])` — an `O(segn * m)` pass.  But the
//! dot products of a *fixed* index pair obey their own recurrence in `m`:
//!
//! ```text
//! dot_{m+1}(a, b) = dot_m(a, b) + t[a+m] * t[b+m]
//! ```
//!
//! so when MERLIN re-visits a (segment, chunk) tile at the next length,
//! the cached seed row advances with one multiply-add per column instead
//! of being recomputed from scratch, and a retry at the *same* length
//! (MERLIN's adaptive-`r` loop re-runs PD3 constantly) reuses it outright.
//! Keys are `(seg_start, chunk_start)` global indices, which are
//! length-independent (segment boundaries are multiples of `segn`).
//!
//! The cache is validated against the live series by a full-content
//! fingerprint ([`QtSeedCache::prepare`], called by PD3 once per run); a
//! different series clears it.  Entries whose stored length exceeds the
//! requested one (MERLIN restarting a sweep) are recomputed in place.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::EnginePerfCounters;
use crate::core::distance::dot;

/// Reusable per-worker buffers for one tile evaluation.
///
/// All vectors are kept at the engine's tile edge (`segn`) and only the
/// `[..nb]` prefix of each is meaningful during a given tile.
#[derive(Debug, Default)]
pub struct TileScratch {
    /// `m * mu[b]` per column (fast-path distance transform).
    pub(crate) mmu_b: Vec<f64>,
    /// `1 / (m * sig[b])` per column.
    pub(crate) inv_msig_b: Vec<f64>,
    /// QT diagonal row for the current segment row.
    pub(crate) qt: Vec<f64>,
    /// QT row of the previous segment row (Eq. 10 recurrence input).
    pub(crate) qt_prev: Vec<f64>,
    /// SoA distance row: distances first, folds after (branchless).
    pub(crate) dist: Vec<f64>,
}

impl TileScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every buffer to tile edge `segn` (no-op once warmed).
    pub(crate) fn ensure(&mut self, segn: usize) {
        if self.qt.len() < segn {
            self.mmu_b.resize(segn, 0.0);
            self.inv_msig_b.resize(segn, 0.0);
            self.qt.resize(segn, 0.0);
            self.qt_prev.resize(segn, 0.0);
            self.dist.resize(segn, 0.0);
        }
    }
}

thread_local! {
    static TILE_SCRATCH: RefCell<TileScratch> = RefCell::new(TileScratch::new());
}

/// Run `f` with this thread's scratch arena (lazily created, then reused
/// for the thread's lifetime — persistent pool workers pay once).
pub(crate) fn with_tile_scratch<R>(f: impl FnOnce(&mut TileScratch) -> R) -> R {
    TILE_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// One cached seed row: `qt[j] = dot_m(a, cs + j)` for a tile's first
/// segment row `a` against its chunk columns.
#[derive(Debug)]
struct SeedRow {
    /// Subsequence length the products are valid for.
    m: usize,
    qt: Vec<f64>,
}

/// Bound on cached rows: with `segn = 256` this caps the cache at
/// ~8 MiB.  The near-diagonal tiles that PD3 revisits at every length
/// are inserted first (round 0 of selection), which is exactly the set
/// worth keeping; overflow keys simply stay uncached.
const MAX_CACHED_ROWS: usize = 4096;

#[derive(Debug, Default)]
struct SeedMap {
    /// Full-content fingerprint of the series the rows belong to.
    fingerprint: u64,
    /// Identity (`as_ptr`, `len`) of the last-bound series buffer: the
    /// O(1) fast check the engine runs per batch to catch callers that
    /// switch series without [`QtSeedCache::prepare`].
    bound: (usize, usize),
    rows: HashMap<(usize, usize), SeedRow>,
    /// Rows evicted by a series change, kept so their allocations can
    /// be recycled by the next misses.  The streaming monitor re-binds
    /// the cache on every refresh (the window's *content* slides), so
    /// without this free-list each refresh would reallocate every seed
    /// row — the counting-allocator test pins the recycled behavior.
    spares: Vec<SeedRow>,
}

fn identity(t: &[f64]) -> (usize, usize) {
    (t.as_ptr() as usize, t.len())
}

/// Concurrent cross-length QT seed cache (see module docs).
#[derive(Debug, Default)]
pub struct QtSeedCache {
    inner: Mutex<SeedMap>,
    hits: AtomicU64,
    advances: AtomicU64,
    misses: AtomicU64,
}

/// Full-content series fingerprint (FNV-1a over the length and every
/// sample's bit pattern).  An O(n) pass per PD3 call is noise next to
/// the tile work it guards, and — unlike sampled hashing — it cannot
/// miss an in-place edit (e.g. anomaly injection between runs on the
/// same buffer), which would silently corrupt every cached seed.
fn fingerprint(t: &[f64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h ^= t.len() as u64;
    h = h.wrapping_mul(0x1_0000_0001_b3);
    for &v in t {
        h ^= v.to_bits();
        h = h.wrapping_mul(0x1_0000_0001_b3);
    }
    h
}

impl QtSeedCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bind the cache to `t`: clears all rows when the series *content*
    /// changed since the last call (no-op on the hot path).  This is the
    /// authoritative validation — callers that mutate a series buffer in
    /// place must go through it (PD3 calls it once per run).
    pub fn prepare(&self, t: &[f64]) {
        let fp = fingerprint(t);
        let mut g = self.inner.lock().unwrap();
        if g.fingerprint != fp {
            g.fingerprint = fp;
            let SeedMap { rows, spares, .. } = &mut *g;
            spares.extend(rows.drain().map(|(_, row)| row));
            spares.truncate(MAX_CACHED_ROWS);
        }
        g.bound = identity(t);
    }

    /// O(1) check that `t` is the buffer the cache was last bound to.
    /// The engine consults this per batch and re-`prepare`s on mismatch,
    /// so even direct `compute_tiles` callers that alternate series
    /// without preparing get correct seeds.  (A different series at the
    /// same address and length is indistinguishable here — that case is
    /// what `prepare`'s content fingerprint covers.)
    pub fn is_bound(&self, t: &[f64]) -> bool {
        self.inner.lock().unwrap().bound == identity(t)
    }

    /// Drop every cached row (tests / memory pressure).
    pub fn clear(&self) {
        self.inner.lock().unwrap().rows.clear();
    }

    /// Lifetime counters (hits / cross-length advances / misses).
    pub fn counters(&self) -> EnginePerfCounters {
        EnginePerfCounters {
            seed_hits: self.hits.load(Ordering::Relaxed),
            seed_advances: self.advances.load(Ordering::Relaxed),
            seed_misses: self.misses.load(Ordering::Relaxed),
            ..EnginePerfCounters::default()
        }
    }

    /// Produce the seed row `qt_out[j] = dot_m(a, cs + j)` for
    /// `j in 0..nb`, reusing / advancing the cached row for
    /// `(a, cs)` when possible.  `qt_out.len()` must equal `nb`.
    pub(crate) fn seed_into(
        &self,
        t: &[f64],
        m: usize,
        a: usize,
        cs: usize,
        nb: usize,
        qt_out: &mut [f64],
    ) {
        debug_assert_eq!(qt_out.len(), nb);
        let key = (a, cs);
        let ident = identity(t);
        // Both critical sections verify the cache is still bound to
        // *this* buffer: two PD3 runs on one shared engine with
        // different (live, hence non-aliasing) series would otherwise
        // race `prepare` and cross-pollinate rows mid-flight.  On a
        // binding mismatch this call simply computes fresh products and
        // leaves the cache alone.
        let (taken, spare, bound_ok) = {
            let mut g = self.inner.lock().unwrap();
            if g.bound == ident {
                let taken = g.rows.remove(&key);
                let spare = if taken.is_none() { g.spares.pop() } else { None };
                (taken, spare, true)
            } else {
                (None, None, false)
            }
        };
        let row = match taken {
            // Same length: verbatim reuse (MERLIN's r-retries).
            Some(mut row) if row.m == m && row.qt.len() >= nb => {
                row.qt.truncate(nb);
                qt_out.copy_from_slice(&row.qt);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(row)
            }
            // Shorter cached length: advance each product with one
            // multiply-add per step (the dot-product recurrence).  The
            // window count only shrinks as m grows, so `nb` here is
            // never larger than the cached row.
            Some(mut row) if row.m < m && row.qt.len() >= nb => {
                row.qt.truncate(nb);
                for k in row.m..m {
                    let ta = t[a + k];
                    let tb = &t[cs + k..cs + k + nb];
                    for (q, &b) in row.qt.iter_mut().zip(tb) {
                        *q += ta * b;
                    }
                }
                row.m = m;
                qt_out.copy_from_slice(&row.qt);
                self.advances.fetch_add(1, Ordering::Relaxed);
                Some(row)
            }
            // Miss (cold, a sweep restarted at a shorter length, or a
            // fresh series): full O(nb * m) seed pass, stored for next
            // time.  The stale row's allocation — or a spare evicted by
            // a series change — is recycled when present.
            other => {
                let wa = &t[a..a + m];
                for (j, q) in qt_out.iter_mut().enumerate() {
                    *q = dot(wa, &t[cs + j..cs + j + m]);
                }
                self.misses.fetch_add(1, Ordering::Relaxed);
                if bound_ok {
                    let mut row =
                        other.or(spare).unwrap_or_else(|| SeedRow { m, qt: Vec::new() });
                    row.m = m;
                    row.qt.clear();
                    row.qt.extend_from_slice(qt_out);
                    Some(row)
                } else {
                    // Binding race: don't build a row the guarded
                    // insert below would just drop.
                    None
                }
            }
        };
        if let Some(row) = row {
            let mut g = self.inner.lock().unwrap();
            if g.bound == ident && (g.rows.len() < MAX_CACHED_ROWS || g.rows.contains_key(&key)) {
                g.rows.insert(key, row);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series(n: usize) -> Vec<f64> {
        (0..n).map(|i| ((i * 37 + 11) % 101) as f64 * 0.25 - 7.0).collect()
    }

    fn fresh_seed(t: &[f64], m: usize, a: usize, cs: usize, nb: usize) -> Vec<f64> {
        (0..nb).map(|j| dot(&t[a..a + m], &t[cs + j..cs + j + m])).collect()
    }

    #[test]
    fn miss_then_hit_is_exact() {
        let t = series(256);
        let cache = QtSeedCache::new();
        cache.prepare(&t);
        let (m, a, cs, nb) = (16, 3, 40, 32);
        let mut first = vec![0.0; nb];
        cache.seed_into(&t, m, a, cs, nb, &mut first);
        assert_eq!(first, fresh_seed(&t, m, a, cs, nb));
        let mut second = vec![0.0; nb];
        cache.seed_into(&t, m, a, cs, nb, &mut second);
        assert_eq!(first, second, "hit must return the stored row verbatim");
        let c = cache.counters();
        assert_eq!((c.seed_misses, c.seed_hits, c.seed_advances), (1, 1, 0));
    }

    #[test]
    fn cross_length_advance_matches_fresh_dots() {
        let t = series(300);
        let cache = QtSeedCache::new();
        cache.prepare(&t);
        let (a, cs) = (5, 64);
        let mut buf = vec![0.0; 48];
        cache.seed_into(&t, 12, a, cs, 48, &mut buf);
        // Advance 12 -> 20 in one step; columns shrink too.
        let nb = 40;
        let mut got = vec![0.0; nb];
        cache.seed_into(&t, 20, a, cs, nb, &mut got);
        let want = fresh_seed(&t, 20, a, cs, nb);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9 * (1.0 + w.abs()), "{g} vs {w}");
        }
        assert_eq!(cache.counters().seed_advances, 1);
    }

    #[test]
    fn shorter_request_recomputes() {
        let t = series(200);
        let cache = QtSeedCache::new();
        cache.prepare(&t);
        let mut buf = vec![0.0; 16];
        cache.seed_into(&t, 24, 0, 50, 16, &mut buf);
        let mut back = vec![0.0; 16];
        cache.seed_into(&t, 10, 0, 50, 16, &mut back);
        assert_eq!(back, fresh_seed(&t, 10, 0, 50, 16));
        assert_eq!(cache.counters().seed_misses, 2);
    }

    #[test]
    fn prepare_invalidates_on_series_change() {
        let t1 = series(128);
        let mut t2 = t1.clone();
        t2[60] += 1.0;
        let cache = QtSeedCache::new();
        cache.prepare(&t1);
        let mut buf = vec![0.0; 8];
        cache.seed_into(&t1, 8, 0, 30, 8, &mut buf);
        cache.prepare(&t2);
        let mut after = vec![0.0; 8];
        cache.seed_into(&t2, 8, 0, 30, 8, &mut after);
        assert_eq!(after, fresh_seed(&t2, 8, 0, 30, 8));
        let c = cache.counters();
        assert_eq!((c.seed_misses, c.seed_hits), (2, 0));
    }

    #[test]
    fn rebinding_series_recycles_rows_correctly() {
        // The streaming-refresh pattern: the bound content changes on
        // every prepare.  Recycled spare rows must never leak another
        // series' products.
        let t1 = series(200);
        let t2: Vec<f64> = t1.iter().map(|v| v * 1.5 + 2.0).collect();
        let cache = QtSeedCache::new();
        for _ in 0..4 {
            for t in [&t1, &t2] {
                cache.prepare(t);
                let mut buf = vec![0.0; 24];
                cache.seed_into(t, 12, 4, 60, 24, &mut buf);
                assert_eq!(buf, fresh_seed(t, 12, 4, 60, 24));
            }
        }
        let c = cache.counters();
        assert_eq!(c.seed_hits, 0, "every rebind must invalidate: {c:?}");
        assert_eq!(c.seed_misses, 8);
    }

    #[test]
    fn scratch_ensure_is_idempotent() {
        let mut s = TileScratch::new();
        s.ensure(64);
        let p = s.qt.as_ptr();
        s.ensure(64);
        s.ensure(32);
        assert_eq!(s.qt.as_ptr(), p);
        assert_eq!(s.qt.len(), 64);
    }
}
