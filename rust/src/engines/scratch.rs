//! Per-worker scratch arena, the SoA tile-kernel row passes, and the
//! cross-length QT seed cache — the allocation-free substrate of the
//! native tile pipeline.
//!
//! **Scratch arena.**  One [`TileScratch`] per worker thread holds every
//! intermediate buffer a tile evaluation needs (per-column stat products,
//! the two QT diagonal rows, the SoA distance row).  Buffers are sized
//! once per tile edge — rounded up to a [`MAX_LANES`] multiple so lane
//! chunks of *any* kernel width never meet a short row — and reused for
//! every subsequent tile, so the steady-state inner loop performs zero
//! heap allocations (verified by the counting-allocator integration
//! test).  The f32 twin buffers of the `Lanes4F32` kernel are allocated
//! lazily by [`TileScratch::ensure_f32`], so f64 runs pay nothing for
//! them.
//!
//! **Tile-kernel row passes.**  The SoA inner loop lives here as four
//! explicit per-row passes ([`qt_recurrence_row`], [`distance_row`] /
//! [`general_distance_row`], [`row_folds`], [`col_folds`]), each
//! dispatched on [`TileKernel`]: `Scalar` keeps the pre-refactor
//! per-column loops verbatim (the bit-level oracle), while every lane
//! kernel — `Lanes4` (`[f64; 4]` chunks), `Lanes8` (`[f64; 8]`),
//! `Lanes4F32` (`[f32; 4]`) — is an instantiation of one set of
//! width/element-generic bodies ([`qt_recurrence_row_w`],
//! [`distance_row_w`], [`row_folds_w`], [`col_folds_w`]) over
//! [`LaneElem`]: explicit accumulators, fixed-extent chunk reborrows,
//! and a scalar tail — vectorization pinned down by construction
//! instead of autovectorizer hope.  Every f64 lane performs the exact
//! scalar operation sequence and the only reductions (`min`, OR) are
//! regroup-insensitive here, so all f64 kernels are bit-identical at
//! any width (differentially tested by
//! `rust/tests/kernel_conformance.rs`); the f32 instantiation is the
//! same bodies one precision down, held to the derived tolerance band
//! instead.  The flat-window general path is one shared scalar f64
//! implementation — the f32 kernel, too, takes its flat decisions on
//! the f64 stats — so clamp/flat routing cannot diverge; every kernel
//! counts them ([`TileKernelStats`]) into `EnginePerfCounters` as the
//! observable certificate.
//!
//! **QT seed cache.**  The paper eliminates cross-length redundancy for
//! the rolling statistics (Eqs. 7/8); this cache extends the same idea to
//! the dot-product layer.  Every tile's first row needs the seed products
//! `QT[j] = dot(T[a..a+m], T[b..b+m])` — an `O(segn * m)` pass.  But the
//! dot products of a *fixed* index pair obey their own recurrence in `m`:
//!
//! ```text
//! dot_{m+1}(a, b) = dot_m(a, b) + t[a+m] * t[b+m]
//! ```
//!
//! so when MERLIN re-visits a (segment, chunk) tile at the next length,
//! the cached seed row advances with one multiply-add per column instead
//! of being recomputed from scratch, and a retry at the *same* length
//! (MERLIN's adaptive-`r` loop re-runs PD3 constantly) reuses it outright.
//! Keys are `(seg_start, chunk_start)` global indices, which are
//! length-independent (segment boundaries are multiples of `segn`).
//!
//! **Storage layout.**  Rows live in [`SHARD_COUNT`] independently locked
//! shards (key-hashed), not one global `Mutex<HashMap>`: concurrent tile
//! workers of one batch touch disjoint shards with high probability, and
//! the engine's per-batch "is this still the bound series?" guard is a
//! pair of atomic loads ([`QtSeedCache::is_bound`]) instead of a mutex
//! round trip.  Content rebinds bump an epoch counter; any row taken out
//! of a shard before a rebind fails the epoch check on reinsertion, so a
//! racing [`QtSeedCache::prepare`] can never cross-pollinate series.
//!
//! **Bulk prefetch.**  Lazy per-tile advances serialize on the shard
//! locks and only fire when a tile happens to revisit its key.
//! [`QtSeedCache::advance_all`] instead advances *every* cached row to
//! the next length in one contiguous sweep — rows are pulled out of
//! their shards into a reusable work list, advanced in parallel through
//! the engine's persistent `RoundPool` (chunked, so the per-item claim
//! cost stays negligible), and reinserted.  MERLIN's length loop calls
//! it between lengths (via `Engine::prefetch_length`), so the next
//! length's tiles open on verbatim cache hits.  The sweep uses the exact
//! per-column operation order of the lazy advance, so a prefetched row
//! is bit-identical to a lazily advanced one.
//!
//! The cache is validated against the live series by a full-content
//! fingerprint ([`QtSeedCache::prepare`], called by PD3 once per run); a
//! different series evicts every row into a per-shard spare pool so the
//! allocations are recycled by later misses ([`QtSeedCache::clear`]
//! recycles the same way).  Entries whose stored length exceeds the
//! requested one (MERLIN restarting a sweep) are recomputed in place.

use std::cell::RefCell;
use std::collections::HashMap;
use crate::util::loomsync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::util::loomsync::Mutex;

use super::{EnginePerfCounters, SeedRowSnapshot, TileKernel};
use crate::core::distance::{
    corr_saturates, corr_to_ed2, dot, dot_w, ed2_lane_chunk_w, ed2norm_from_qt, LaneElem, LANES,
    MAX_LANES,
};
use crate::util::pool::{RoundPool, SliceWriter};
use crate::util::sync::lock_recover;

/// Reusable per-worker buffers for one tile evaluation.
///
/// All vectors are kept at the engine's tile edge (`segn`), rounded up
/// to a [`MAX_LANES`] multiple so a chunk of any kernel width can never
/// touch a short row; only the `[..nb]` prefix of each is meaningful
/// during a given tile.  The `*32` twins serve the `Lanes4F32` kernel
/// and stay empty (zero heap cost) until [`TileScratch::ensure_f32`]
/// runs — f64 workloads never allocate them.
#[derive(Debug, Default)]
pub struct TileScratch {
    /// `m * mu[b]` per column (fast-path distance transform).
    pub(crate) mmu_b: Vec<f64>,
    /// `1 / (m * sig[b])` per column.
    pub(crate) inv_msig_b: Vec<f64>,
    /// QT diagonal row for the current segment row.
    pub(crate) qt: Vec<f64>,
    /// QT row of the previous segment row (Eq. 10 recurrence input).
    pub(crate) qt_prev: Vec<f64>,
    /// SoA distance row: distances first, folds after (branchless).
    pub(crate) dist: Vec<f64>,
    /// f32 twin of `mmu_b` (`Lanes4F32` only).
    pub(crate) mmu_b32: Vec<f32>,
    /// f32 twin of `inv_msig_b`.
    pub(crate) inv_msig_b32: Vec<f32>,
    /// f32 twin of `qt`.
    pub(crate) qt32: Vec<f32>,
    /// f32 twin of `qt_prev`.
    pub(crate) qt_prev32: Vec<f32>,
    /// f32 twin of `dist`.
    pub(crate) dist32: Vec<f32>,
    /// f32 column-minimum accumulator (folded per row, widened into the
    /// f64 tile outputs once per tile — widening is exact).
    pub(crate) col_min32: Vec<f32>,
}

impl TileScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every f64 buffer to tile edge `segn`, lane-aligned (no-op
    /// once warmed).  The rounding to a [`MAX_LANES`] multiple
    /// guarantees the tail of every row stays in-bounds for a
    /// full-width load of *any* kernel — including the widest — even if
    /// a future kernel revision replaces the scalar tail loop with a
    /// masked/overlapping full chunk.
    pub(crate) fn ensure(&mut self, segn: usize) {
        let cap = segn.next_multiple_of(MAX_LANES);
        if self.qt.len() < cap {
            self.mmu_b.resize(cap, 0.0);
            self.inv_msig_b.resize(cap, 0.0);
            self.qt.resize(cap, 0.0);
            self.qt_prev.resize(cap, 0.0);
            self.dist.resize(cap, 0.0);
        }
    }

    /// [`TileScratch::ensure`] for the f32 twins — called only on the
    /// `Lanes4F32` tile path, so the twins are a one-time allocation on
    /// the first f32 tile and free for every f64 workload.
    pub(crate) fn ensure_f32(&mut self, segn: usize) {
        let cap = segn.next_multiple_of(MAX_LANES);
        if self.qt32.len() < cap {
            self.mmu_b32.resize(cap, 0.0);
            self.inv_msig_b32.resize(cap, 0.0);
            self.qt32.resize(cap, 0.0);
            self.qt_prev32.resize(cap, 0.0);
            self.dist32.resize(cap, 0.0);
            self.col_min32.resize(cap, 0.0);
        }
    }
}

/// Per-tile kernel event counts, accumulated locally during one tile
/// evaluation and flushed into the engine's atomics once per tile (two
/// relaxed adds — the hot loop itself touches no shared state).
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct TileKernelStats {
    /// Fast-path columns whose correlation saturated the clamp.
    pub saturated: u64,
    /// Columns evaluated through the shared flat-window general path.
    pub flat_cells: u64,
}

/// Eq. 10 diagonal-recurrence row fill:
/// `qt[j] = qt_prev[j-1] + tail * t[cs+j+m-1] - head * t[cs+j-1]` for
/// `j >= 1`, with `qt[0]` re-seeded by a direct dot product.  `qt` and
/// `qt_prev` are the `[..nb]` prefixes of the scratch rows.
///
/// Elementwise given `qt_prev`, so the lane chunking is bit-identical to
/// the scalar loop.  `Scalar` keeps the pre-refactor loop verbatim (the
/// oracle stays an *independent* implementation); every lane kernel
/// dispatches into the width-generic [`qt_recurrence_row_w`].  `Auto`
/// and `Lanes4F32` cannot reach the f64 passes (the tile entry resolves
/// `Auto` and routes `Lanes4F32` to the f32 loop first), so the default
/// arm folding them onto `W = 4` is a harmless total-match fallback,
/// not a decision point.
// hot-path: Eq. 10 QT recurrence, every non-first tile row.
#[inline]
pub(crate) fn qt_recurrence_row(
    kernel: TileKernel,
    t: &[f64],
    m: usize,
    a: usize,
    cs: usize,
    qt_prev: &[f64],
    qt: &mut [f64],
) {
    match kernel {
        TileKernel::Scalar => {
            let nb = qt.len();
            debug_assert!(nb >= 1 && qt_prev.len() == nb);
            // panic-free: tile geometry — the caller iterates rows
            // a >= 1 with a+m-1 < t.len() and columns cs..cs+nb where
            // every column is a valid window start (cs+nb-1+m <=
            // t.len()), so all t/qt/qt_prev accesses below stay in
            // bounds; nb >= 1 covers qt[0].
            let head = t[a - 1];
            let tail = t[a + m - 1];
            qt[0] = dot(&t[a..a + m], &t[cs..cs + m]);
            for j in 1..nb {
                let b = cs + j;
                qt[j] = qt_prev[j - 1] + tail * t[b + m - 1] - head * t[b - 1];
            }
        }
        TileKernel::Lanes8 => qt_recurrence_row_w::<f64, MAX_LANES>(t, m, a, cs, qt_prev, qt),
        _ => qt_recurrence_row_w::<f64, LANES>(t, m, a, cs, qt_prev, qt),
    }
}

/// Width/element-generic body of [`qt_recurrence_row`]: the shared lane
/// loop every non-scalar kernel instantiates (`f64x4`, `f64x8`,
/// `f32x4`).  Series loads narrow through [`LaneElem::from_f64`]
/// (identity at f64 — bit-identical to the historical `Lanes4` arm).
// hot-path: Eq. 10 QT recurrence lane body, every non-first tile row.
#[inline]
pub(crate) fn qt_recurrence_row_w<E: LaneElem, const W: usize>(
    t: &[f64],
    m: usize,
    a: usize,
    cs: usize,
    qt_prev: &[E],
    qt: &mut [E],
) {
    let nb = qt.len();
    debug_assert!(nb >= 1 && qt_prev.len() == nb);
    // panic-free: tile geometry — the caller iterates rows a >= 1 with
    // a+m-1 < t.len() and columns cs..cs+nb where every column is a
    // valid window start (cs+nb-1+m <= t.len()), so all t/qt/qt_prev
    // accesses below stay in bounds; nb >= 1 covers qt[0].
    let head = E::from_f64(t[a - 1]);
    let tail = E::from_f64(t[a + m - 1]);
    qt[0] = dot_w::<E>(&t[a..a + m], &t[cs..cs + m]);
    let mut j = 1;
    // panic-free: j+W <= nb bounds every lane slice (rows are aligned
    // to MAX_LANES >= W by TileScratch::ensure); the tail loop is
    // bounded by nb with the same geometry as the scalar arm.
    while j + W <= nb {
        let p: &[E; W] = chunk(&qt_prev[j - 1..], "qt_prev");
        let tt: [E; W] = load_chunk(&t[cs + j + m - 1..]);
        let th: [E; W] = load_chunk(&t[cs + j - 1..]);
        let q: &mut [E; W] = chunk_mut(&mut qt[j..]);
        for l in 0..W {
            q[l] = p[l] + tail * tt[l] - head * th[l];
        }
        j += W;
    }
    // panic-free: tail columns j < nb, same bounds as above.
    for j in j..nb {
        let b = cs + j;
        qt[j] = qt_prev[j - 1] + tail * E::from_f64(t[b + m - 1]) - head * E::from_f64(t[b - 1]);
    }
}

/// Fast-path distance row (Eq. 6 with precomputed column products):
/// `dist[j] = two_m * (1 - clamp((qt[j] - mmu_b[j]*mu_a) *
/// (inv_msig_b[j]*inv_sig_a)))`.  Returns the number of saturated
/// (clamped) columns — the clamp-decision gauge every kernel must agree
/// on.  All slices are the `[..nb]` prefixes.  Dispatch follows
/// [`qt_recurrence_row`]: verbatim scalar oracle, width-generic lane
/// body ([`distance_row_w`]) for the rest.
// hot-path: fast-path distance row, every tile row.
#[inline]
#[allow(clippy::too_many_arguments)] // one row's full operand set
pub(crate) fn distance_row(
    kernel: TileKernel,
    qt: &[f64],
    mmu_b: &[f64],
    inv_msig_b: &[f64],
    mu_a: f64,
    inv_sig_a: f64,
    two_m: f64,
    dist: &mut [f64],
) -> u64 {
    match kernel {
        TileKernel::Scalar => {
            let nb = dist.len();
            debug_assert!(qt.len() == nb && mmu_b.len() == nb && inv_msig_b.len() == nb);
            let mut sat = 0u64;
            // panic-free: j < nb bounds every slice access
            // (debug-asserted above, sized by the tile binder).
            for j in 0..nb {
                let corr = (qt[j] - mmu_b[j] * mu_a) * (inv_msig_b[j] * inv_sig_a);
                sat += corr_saturates(corr) as u64;
                dist[j] = corr_to_ed2(corr, two_m);
            }
            sat
        }
        TileKernel::Lanes8 => distance_row_w::<f64, MAX_LANES>(
            qt, mmu_b, inv_msig_b, mu_a, inv_sig_a, two_m, dist,
        ),
        _ => distance_row_w::<f64, LANES>(qt, mmu_b, inv_msig_b, mu_a, inv_sig_a, two_m, dist),
    }
}

/// Width/element-generic body of [`distance_row`]: full-width
/// [`ed2_lane_chunk_w`] chunks plus the scalar-sequence tail.
// hot-path: fast-path distance row lane body, every tile row.
#[inline]
#[allow(clippy::too_many_arguments)] // one row's full operand set
pub(crate) fn distance_row_w<E: LaneElem, const W: usize>(
    qt: &[E],
    mmu_b: &[E],
    inv_msig_b: &[E],
    mu_a: E,
    inv_sig_a: E,
    two_m: E,
    dist: &mut [E],
) -> u64 {
    let nb = dist.len();
    debug_assert!(qt.len() == nb && mmu_b.len() == nb && inv_msig_b.len() == nb);
    let mut sat = 0u64;
    // panic-free: W is a nonzero const width; j+W <= nb for every chunk
    // and all operand slices have length nb (debug-asserted above,
    // sized by the tile binder).
    let chunks = nb / W;
    for c in 0..chunks {
        let j = c * W;
        sat += ed2_lane_chunk_w::<E, W>(
            chunk(&qt[j..], "qt"),
            chunk(&mmu_b[j..], "mmu_b"),
            chunk(&inv_msig_b[j..], "inv_msig_b"),
            mu_a,
            inv_sig_a,
            two_m,
            // panic-free: same j+W <= nb chunk bound.
            chunk_mut(&mut dist[j..]),
        );
    }
    // panic-free: scalar tail, j < nb bounds every slice access.
    for j in chunks * W..nb {
        let corr = (qt[j] - mmu_b[j] * mu_a) * (inv_msig_b[j] * inv_sig_a);
        sat += corr.saturates() as u64;
        dist[j] = corr.corr_to_ed2(two_m);
    }
    sat
}

/// Flat-window (general Eq. 6) distance row — deliberately **shared
/// verbatim** by both kernels, so flat-vs-fast routing and the clamp
/// decisions inside [`ed2norm_from_qt`] are kernel-invariant by
/// construction.  The flat path is rare (stuck-sensor plateaus,
/// NaN-contaminated windows, which stat NaN mu and floored sigma and
/// therefore classify flat); lane-chunking it would buy nothing.
// hot-path: flat-tile distance row (rare route, still per-column work).
#[inline]
#[allow(clippy::too_many_arguments)] // one row's full operand set
pub(crate) fn general_distance_row(
    qt: &[f64],
    m: usize,
    mu_a: f64,
    sig_a: f64,
    mu: &[f64],
    sig: &[f64],
    cs: usize,
    dist: &mut [f64],
) {
    // panic-free: j < dist.len() = nb <= qt.len(), and b = cs+j stays
    // under mu/sig len because every tile column is a valid window
    // start (binder invariant).
    for (j, d) in dist.iter_mut().enumerate() {
        let b = cs + j;
        *d = ed2norm_from_qt(qt[j], m, mu_a, sig_a, mu[b], sig[b]);
    }
}

/// [`general_distance_row`] for the f32 kernel: the f32 QT is widened
/// (exactly) into the *same shared f64 implementation* — flat
/// classification and the flat-distance conventions stay keyed on the
/// f64 stats, so flat routing and `flat_cells` counts are
/// kernel-invariant even under `Lanes4F32`; only the final distance is
/// narrowed back.
// hot-path: flat-tile distance row, f32 kernel (rare route).
#[inline]
#[allow(clippy::too_many_arguments)] // one row's full operand set
pub(crate) fn general_distance_row_f32(
    qt: &[f32],
    m: usize,
    mu_a: f64,
    sig_a: f64,
    mu: &[f64],
    sig: &[f64],
    cs: usize,
    dist: &mut [f32],
) {
    // panic-free: same binder invariant as general_distance_row.
    for (j, d) in dist.iter_mut().enumerate() {
        let b = cs + j;
        // order: deliberate f64 -> f32 narrowing of the flat-path
        // distance — the Lanes4F32 kernel's output precision; the flat
        // *decision* happened in f64 inside ed2norm_from_qt.
        *d = ed2norm_from_qt(qt[j] as f64, m, mu_a, sig_a, mu[b], sig[b]) as f32;
    }
}

/// Row folds over the distance row: `(min, any < r2)`.
///
/// The lane variants keep `W` independent accumulators and combine them
/// once; `min` over these distances is insensitive to that regrouping
/// (the identity is `+inf`, NaNs are dropped by `min`'s IEEE minNum
/// semantics, and `-0.0` cannot occur — distances are produced as
/// `two_m * (1 - clamp)` or by the flat conventions, all `>= +0.0`), so
/// every f64 variant returns bit-identical results at any width.
// hot-path: per-row min/kill folds, every tile row.
#[inline]
pub(crate) fn row_folds(kernel: TileKernel, dist: &[f64], r2: f64) -> (f64, bool) {
    match kernel {
        TileKernel::Scalar => {
            let mut rmin = f64::INFINITY;
            for &d in dist {
                rmin = rmin.min(d);
            }
            let mut rkill = false;
            for &d in dist {
                rkill |= d < r2;
            }
            (rmin, rkill)
        }
        TileKernel::Lanes8 => row_folds_w::<f64, MAX_LANES>(dist, r2),
        _ => row_folds_w::<f64, LANES>(dist, r2),
    }
}

/// Width/element-generic body of [`row_folds`].
// hot-path: per-row min/kill fold lane body, every tile row.
#[inline]
pub(crate) fn row_folds_w<E: LaneElem, const W: usize>(dist: &[E], r2: E) -> (E, bool) {
    let mut minacc = [E::INFINITY; W];
    let mut killacc = [false; W];
    // panic-free: W is a nonzero const width and j+W <= chunks*W <=
    // dist.len() bounds each chunk; the tail slice below starts at
    // chunks*W <= dist.len().
    let chunks = dist.len() / W;
    for c in 0..chunks {
        let j = c * W;
        let dc: &[E; W] = chunk(&dist[j..], "dist");
        for l in 0..W {
            minacc[l] = minacc[l].min(dc[l]);
        }
        for l in 0..W {
            killacc[l] |= dc[l] < r2;
        }
    }
    // Width-generic combine so no width can silently drop accumulators.
    let mut rmin = E::INFINITY;
    for &v in &minacc {
        rmin = rmin.min(v);
    }
    let mut rkill = killacc.iter().any(|&k| k);
    // panic-free: chunks*W <= dist.len(), valid range start.
    for &d in &dist[chunks * W..] {
        rmin = rmin.min(d);
        rkill |= d < r2;
    }
    (rmin, rkill)
}

/// Column folds: elementwise `col_min[j] = min(col_min[j], dist[j])` and
/// `col_kill[j] |= dist[j] < r2`.  Elementwise, hence bit-identical
/// across f64 kernels; the lane variants are branchless (`min` instead
/// of the scalar oracle's compare-and-store, equivalent because
/// `col_min` can never hold NaN — it starts at `+inf` and only adopts
/// values that won a `<` comparison).
// hot-path: per-column min/kill folds, every tile row.
#[inline]
pub(crate) fn col_folds(
    kernel: TileKernel,
    dist: &[f64],
    r2: f64,
    col_min: &mut [f64],
    col_kill: &mut [bool],
) {
    match kernel {
        TileKernel::Scalar => {
            let nb = dist.len();
            debug_assert!(col_min.len() == nb && col_kill.len() == nb);
            for (c, &d) in col_min.iter_mut().zip(dist) {
                if d < *c {
                    *c = d;
                }
            }
            for (k, &d) in col_kill.iter_mut().zip(dist) {
                *k |= d < r2;
            }
        }
        TileKernel::Lanes8 => col_folds_w::<f64, MAX_LANES>(dist, r2, col_min, col_kill),
        _ => col_folds_w::<f64, LANES>(dist, r2, col_min, col_kill),
    }
}

/// Width/element-generic body of [`col_folds`].
// hot-path: per-column min/kill fold lane body, every tile row.
#[inline]
pub(crate) fn col_folds_w<E: LaneElem, const W: usize>(
    dist: &[E],
    r2: E,
    col_min: &mut [E],
    col_kill: &mut [bool],
) {
    let nb = dist.len();
    debug_assert!(col_min.len() == nb && col_kill.len() == nb);
    // panic-free: W is a nonzero const width; j+W <= nb and all three
    // slices have length nb (debug-asserted above).
    let chunks = nb / W;
    for c in 0..chunks {
        let j = c * W;
        let dc: &[E; W] = chunk(&dist[j..], "dist");
        let cm: &mut [E; W] = chunk_mut(&mut col_min[j..]);
        for l in 0..W {
            cm[l] = cm[l].min(dc[l]);
        }
        let ck: &mut [bool; W] = bool_chunk_mut(&mut col_kill[j..]);
        for l in 0..W {
            ck[l] |= dc[l] < r2;
        }
    }
    // panic-free: scalar tail, j < nb bounds every access.
    for j in chunks * W..nb {
        if dist[j] < col_min[j] {
            col_min[j] = dist[j];
        }
        col_kill[j] |= dist[j] < r2;
    }
}

/// First `W` elements of `s` as a fixed-extent array ref (the compiler
/// folds the length check into the chunk loop's bound).
// hot-path: lane-chunk reborrow, several per tile-row chunk.
#[inline]
fn chunk<'a, E: LaneElem, const W: usize>(s: &'a [E], what: &str) -> &'a [E; W] {
    // panic-free: every caller slices at j with j+W <= row length
    // (rows aligned to MAX_LANES >= W by TileScratch::ensure), so
    // s.len() >= W; the panic arm is the unreachable-invariant report,
    // kept over unchecked access so a future geometry bug fails loudly.
    s[..W].try_into().unwrap_or_else(|_| panic!("short {what} lane chunk"))
}

// hot-path: mutable lane-chunk reborrow, several per tile-row chunk.
#[inline]
fn chunk_mut<E: LaneElem, const W: usize>(s: &mut [E]) -> &mut [E; W] {
    // panic-free: same caller bound as chunk; expect is the loud
    // unreachable-invariant report.
    (&mut s[..W]).try_into().expect("short mutable lane chunk")
}

// hot-path: kill-flag lane-chunk reborrow, once per tile-row chunk.
#[inline]
fn bool_chunk_mut<const W: usize>(s: &mut [bool]) -> &mut [bool; W] {
    // panic-free: same caller bound as chunk; expect is the loud
    // unreachable-invariant report.
    (&mut s[..W]).try_into().expect("short kill lane chunk")
}

/// `[E; W]` copied out of an f64 slice through [`LaneElem::from_f64`]
/// (identity — and elided — at f64; the narrowing load at f32).
// hot-path: series lane-chunk load, several per tile-row chunk.
#[inline]
fn load_chunk<E: LaneElem, const W: usize>(s: &[f64]) -> [E; W] {
    // panic-free: every caller slices at j with j+W elements available
    // (same geometry as chunk), so l < W indexes in bounds.
    std::array::from_fn(|l| E::from_f64(s[l]))
}

thread_local! {
    static TILE_SCRATCH: RefCell<TileScratch> = RefCell::new(TileScratch::new());
}

/// Run `f` with this thread's scratch arena (lazily created, then reused
/// for the thread's lifetime — persistent pool workers pay once).
pub(crate) fn with_tile_scratch<R>(f: impl FnOnce(&mut TileScratch) -> R) -> R {
    TILE_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// One cached seed row: `qt[j] = dot_m(a, cs + j)` for a tile's first
/// segment row `a` against its chunk columns.
#[derive(Debug)]
struct SeedRow {
    /// Subsequence length the products are valid for.
    m: usize,
    qt: Vec<f64>,
}

/// Shard fan-out (power of two).  Concurrent workers of one tile batch
/// hash to distinct shards with high probability, so the take/insert
/// critical sections stop convoying the way the old single-map mutex did.
const SHARD_COUNT: usize = 16;

/// Bound on cached rows *per shard*: with `segn = 256` the 16-shard total
/// of 4096 rows caps the cache at ~8 MiB.  The near-diagonal tiles that
/// PD3 revisits at every length are inserted first (round 0 of
/// selection), which is exactly the set worth keeping; overflow keys
/// simply stay uncached.  The spare pools honor the same per-shard bound.
const MAX_ROWS_PER_SHARD: usize = 256;

/// Indices per cursor claim in the bulk-prefetch fan-out: one row's
/// advance is a single multiply-add pass over a few hundred columns, so
/// per-item claims would rival the work itself.
const PREFETCH_CHUNK: usize = 8;

/// One key-hashed slice of the cache.
#[derive(Debug, Default)]
struct Shard {
    rows: HashMap<(usize, usize), SeedRow>,
    /// Rows evicted by a series change, a `clear()`, or the prefetch
    /// sweep's range cut, kept so their allocations can be recycled by
    /// the next misses.  The streaming monitor re-binds the cache on
    /// every refresh (the window's *content* slides), so without this
    /// free-list each refresh would reallocate every seed row — the
    /// counting-allocator test pins the recycled behavior.
    spares: Vec<SeedRow>,
}

impl Shard {
    /// Keep `row`'s allocation for a future miss (content is treated as
    /// garbage: reuse always rewrites it in full).
    fn recycle(&mut self, row: SeedRow) {
        if self.spares.len() < MAX_ROWS_PER_SHARD {
            self.spares.push(row);
        }
    }

    /// Move every live row into the spare pool.
    fn evict_all(&mut self) {
        let Shard { rows, spares } = self;
        // order: drain order only decides which evicted allocations the
        // bounded spare pool keeps; spares carry no numeric state, so
        // no result or checkpoint byte depends on it.
        for (_, row) in rows.drain() {
            if spares.len() < MAX_ROWS_PER_SHARD {
                spares.push(row);
            }
        }
    }
}

fn shard_of(key: (usize, usize)) -> usize {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in [key.0 as u64, key.1 as u64] {
        h ^= v;
        h = h.wrapping_mul(0x1_0000_0001_b3);
    }
    (h >> 32) as usize & (SHARD_COUNT - 1)
}

fn identity(t: &[f64]) -> (usize, usize) {
    (t.as_ptr() as usize, t.len())
}

/// Advance `row` — seed products `dot_{row.m}(a, cs + j)` for
/// `j in 0..row.qt.len()` — to length `next_m` via the dot-product
/// recurrence (one fused multiply-add per column per step).
///
/// The single source of truth for the advance operation order: the lazy
/// per-tile path ([`QtSeedCache::seed_into`]) and the bulk prefetch
/// sweep ([`QtSeedCache::advance_all`]) both call it, so their products
/// are bit-identical by construction — the invariant the prefetch
/// property tests pin.
// hot-path: cross-length seed advance, per cached row per length step.
#[inline]
fn advance_row(t: &[f64], a: usize, cs: usize, row: &mut SeedRow, next_m: usize) {
    let nb = row.qt.len();
    // panic-free: callers (seed_into, advance_all) only advance rows
    // whose windows fit t at next_m — a+next_m <= t.len() and
    // cs+nb-1+next_m <= t.len() (import_rows re-checks, advance_all
    // cuts the range) — so a+k and the tb slice stay in bounds.
    for k in row.m..next_m {
        let ta = t[a + k];
        let tb = &t[cs + k..cs + k + nb];
        for (q, &b) in row.qt.iter_mut().zip(tb) {
            *q += ta * b;
        }
    }
    row.m = next_m;
}

/// A row pulled out of its shard for one bulk-prefetch sweep.
#[derive(Debug)]
struct SweepItem {
    a: usize,
    cs: usize,
    row: SeedRow,
}

/// Concurrent cross-length QT seed cache (see module docs).
#[derive(Debug)]
pub struct QtSeedCache {
    shards: [Mutex<Shard>; SHARD_COUNT],
    /// `(as_ptr, len)` identity of the last-bound series buffer, split
    /// over two atomics: the read-mostly fast check the engine runs per
    /// batch ([`QtSeedCache::is_bound`]) without taking any lock.  A
    /// mixed (torn) read cannot impersonate a live series — two live
    /// buffers never share a base pointer — and every decision that
    /// touches rows re-reads it under the owning shard's lock.
    bound_ptr: AtomicUsize,
    bound_len: AtomicUsize,
    /// Bumped by every content rebind; take/insert pairs verify it
    /// unchanged so in-flight rows of a previous binding are dropped to
    /// the spare pool instead of poisoning the new one.
    epoch: AtomicU64,
    /// Full-content fingerprint of the bound series (prepare-only; also
    /// serializes concurrent prepares end-to-end).
    fingerprint: Mutex<u64>,
    /// Reusable work list for [`QtSeedCache::advance_all`].
    sweep: Mutex<Vec<SweepItem>>,
    hits: AtomicU64,
    advances: AtomicU64,
    misses: AtomicU64,
    prefetched: AtomicU64,
    prefetch_batches: AtomicU64,
}

impl Default for QtSeedCache {
    fn default() -> Self {
        Self {
            shards: std::array::from_fn(|_| Mutex::new(Shard::default())),
            bound_ptr: AtomicUsize::new(0),
            bound_len: AtomicUsize::new(0),
            epoch: AtomicU64::new(0),
            fingerprint: Mutex::new(0),
            sweep: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            advances: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            prefetched: AtomicU64::new(0),
            prefetch_batches: AtomicU64::new(0),
        }
    }
}

/// Full-content series fingerprint (FNV-1a over the length and every
/// sample's bit pattern).  An O(n) pass per PD3 call is noise next to
/// the tile work it guards, and — unlike sampled hashing — it cannot
/// miss an in-place edit (e.g. anomaly injection between runs on the
/// same buffer), which would silently corrupt every cached seed.
fn fingerprint(t: &[f64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h ^= t.len() as u64;
    h = h.wrapping_mul(0x1_0000_0001_b3);
    for &v in t {
        h ^= v.to_bits();
        h = h.wrapping_mul(0x1_0000_0001_b3);
    }
    h
}

impl QtSeedCache {
    pub fn new() -> Self {
        Self::default()
    }

    fn bound(&self) -> (usize, usize) {
        (self.bound_ptr.load(Ordering::Acquire), self.bound_len.load(Ordering::Acquire))
    }

    /// Bind the cache to `t`: evicts all rows (into the spare pools)
    /// when the series *content* changed since the last call (no-op on
    /// the hot path).  This is the authoritative validation — callers
    /// that mutate a series buffer in place must go through it (PD3
    /// calls it once per run).
    pub fn prepare(&self, t: &[f64]) {
        let fp = fingerprint(t);
        let mut guard = lock_recover(&self.fingerprint);
        if *guard != fp {
            *guard = fp;
            // New content.  Order matters: retire the binding to the
            // unreachable sentinel `(0, 0)` (no live slice has a null
            // base pointer) *before* bumping the epoch and evicting, so
            // that for the whole eviction window every take/reinsert
            // that re-reads the binding under a shard lock sees either
            // (old epoch) — its reinsert then fails the epoch check —
            // or the sentinel — its take computes fresh and caches
            // nothing.  Publishing the new identity first (or last,
            // with the old one still visible) would let a racing
            // seed_into slip a stale-series row into an already-evicted
            // shard.
            self.bound_ptr.store(0, Ordering::Release);
            self.bound_len.store(0, Ordering::Release);
            self.epoch.fetch_add(1, Ordering::AcqRel);
            for shard in &self.shards {
                lock_recover(shard).evict_all();
            }
        }
        let ident = identity(t);
        self.bound_ptr.store(ident.0, Ordering::Release);
        self.bound_len.store(ident.1, Ordering::Release);
    }

    /// O(1) lock-free check that `t` is the buffer the cache was last
    /// bound to.  The engine consults this per batch and re-`prepare`s
    /// on mismatch, so even direct `compute_tiles` callers that
    /// alternate series without preparing get correct seeds.  (A
    /// different series at the same address and length is
    /// indistinguishable here — that case is what `prepare`'s content
    /// fingerprint covers.)
    pub fn is_bound(&self, t: &[f64]) -> bool {
        self.bound() == identity(t)
    }

    /// Retire every cached row (tests / memory pressure).  Rows go to
    /// the per-shard spare pools, not the allocator: a pressure-driven
    /// clear must not break the zero-steady-state-allocation guarantee,
    /// so the next misses rebuild into recycled storage.
    pub fn clear(&self) {
        for shard in &self.shards {
            lock_recover(shard).evict_all();
        }
    }

    /// Export every cached row bound to `t` in engine-independent
    /// coordinates, sorted by `(a, cs)` so checkpoints are
    /// deterministic.  Returns empty when the cache is not bound to
    /// `t` (or a racing rebind moves the binding mid-export) — callers
    /// then simply checkpoint without rows, which degrades resume from
    /// bit-identical to numerically-equal, never to wrong.
    pub fn export_rows(&self, t: &[f64]) -> Vec<SeedRowSnapshot> {
        if !self.is_bound(t) {
            return Vec::new();
        }
        let ident = identity(t);
        let mut out = Vec::new();
        for shard in &self.shards {
            let g = lock_recover(shard);
            if self.bound() != ident {
                // A concurrent prepare() rebound the cache: anything
                // collected so far may mix series — discard it all.
                return Vec::new();
            }
            for (&(a, cs), row) in &g.rows {
                out.push(SeedRowSnapshot { a, cs, m: row.m, qt: row.qt.clone() });
            }
        }
        out.sort_unstable_by_key(|r| (r.a, r.cs));
        out
    }

    /// Re-install exported rows for series `t`: binds the cache to `t`
    /// (content fingerprint, so a byte-identical regenerated buffer
    /// rebinds without eviction), then inserts each row under its
    /// shard lock, honoring the per-shard capacity.  Rows whose
    /// coordinates fall outside `t` are skipped — a tampered
    /// checkpoint must not plant out-of-bounds reads for
    /// [`advance_row`] to hit later.  Returns the rows accepted.
    pub fn import_rows(&self, t: &[f64], rows: &[SeedRowSnapshot]) -> u64 {
        self.prepare(t);
        let ident = identity(t);
        let epoch0 = self.epoch.load(Ordering::Acquire);
        let mut accepted = 0u64;
        // order: `rows` is the checkpoint's slice (sorted by (a, cs) at
        // export), not a map — insertion replays checkpoint order.
        for r in rows {
            if r.m == 0 || r.qt.is_empty() {
                continue;
            }
            // The row's dots read t[a..a+m] and t[cs+j..cs+j+m] for
            // j < qt.len(); both ends must stay in bounds even after a
            // future advance (checked again there via the window cut).
            if r.a + r.m > t.len() || r.cs + (r.qt.len() - 1) + r.m > t.len() {
                continue;
            }
            let key = (r.a, r.cs);
            let mut g = lock_recover(&self.shards[shard_of(key)]);
            if self.epoch.load(Ordering::Acquire) != epoch0 || self.bound() != ident {
                break; // racing prepare: later rows would poison the new binding
            }
            if g.rows.len() < MAX_ROWS_PER_SHARD || g.rows.contains_key(&key) {
                let mut row = g.spares.pop().unwrap_or_else(|| SeedRow { m: 0, qt: Vec::new() });
                row.m = r.m;
                row.qt.clear();
                row.qt.extend_from_slice(&r.qt);
                g.rows.insert(key, row);
                accepted += 1;
            }
        }
        accepted
    }

    /// Lifetime counters (hits / cross-length advances / misses /
    /// bulk-prefetch volume).
    pub fn counters(&self) -> EnginePerfCounters {
        EnginePerfCounters {
            seed_hits: self.hits.load(Ordering::Relaxed),
            seed_advances: self.advances.load(Ordering::Relaxed),
            seed_misses: self.misses.load(Ordering::Relaxed),
            seed_prefetched: self.prefetched.load(Ordering::Relaxed),
            prefetch_batches: self.prefetch_batches.load(Ordering::Relaxed),
            ..EnginePerfCounters::default()
        }
    }

    #[cfg(test)]
    fn spare_rows(&self) -> usize {
        self.shards.iter().map(|s| lock_recover(s).spares.len()).sum()
    }

    #[cfg(test)]
    fn live_rows(&self) -> usize {
        self.shards.iter().map(|s| lock_recover(s).rows.len()).sum()
    }

    /// Advance every cached seed row to subsequence length `next_m` in
    /// one bulk sweep (the ROADMAP "batch-level seed prefetch" item):
    ///
    /// 1. pull each advanceable row (`row.m < next_m`, still inside the
    ///    next length's window range) out of its shard into a reusable
    ///    work list — rows already at/past `next_m` stay put, rows that
    ///    fall off the range are recycled;
    /// 2. run the dot-product recurrence over the work list — fanned out
    ///    through `pool` in [`PREFETCH_CHUNK`]-sized index chunks when
    ///    one is supplied, inline otherwise — using the exact per-column
    ///    operation order of the lazy advance in
    ///    [`QtSeedCache::seed_into`], so prefetched rows are
    ///    bit-identical to lazily advanced ones;
    /// 3. reinsert the rows (dropping to the spare pools if a racing
    ///    [`QtSeedCache::prepare`] rebound the cache mid-sweep).
    ///
    /// No-op unless the cache is currently bound to `t`.  Returns the
    /// number of rows advanced and reinserted.
    pub fn advance_all(&self, t: &[f64], next_m: usize, pool: Option<&RoundPool>) -> u64 {
        if next_m == 0 || !self.is_bound(t) {
            return 0;
        }
        let nwin_next = match t.len().checked_sub(next_m) {
            Some(d) => d + 1,
            None => return 0,
        };
        let epoch0 = self.epoch.load(Ordering::Acquire);
        let ident = identity(t);
        let mut work = lock_recover(&self.sweep);
        work.clear();
        for shard in &self.shards {
            let mut g = lock_recover(shard);
            if self.epoch.load(Ordering::Acquire) != epoch0 || self.bound() != ident {
                break; // racing prepare: stop collecting
            }
            let Shard { rows, spares } = &mut *g;
            rows.retain(|&(a, cs), row| {
                if row.m >= next_m {
                    // Same-length retry reuse, or a restarted (shorter)
                    // sweep whose stale rows the next miss rebuilds.
                    return true;
                }
                let keep_cols = nwin_next.saturating_sub(cs).min(row.qt.len());
                if a >= nwin_next || keep_cols == 0 {
                    // Off the end of the next length's window range.
                    if spares.len() < MAX_ROWS_PER_SHARD {
                        spares.push(SeedRow { m: 0, qt: std::mem::take(&mut row.qt) });
                    }
                    return false;
                }
                row.qt.truncate(keep_cols);
                work.push(SweepItem {
                    a,
                    cs,
                    row: SeedRow { m: row.m, qt: std::mem::take(&mut row.qt) },
                });
                false
            });
        }

        let n = work.len();
        if n > 0 {
            let advance_one =
                |item: &mut SweepItem| advance_row(t, item.a, item.cs, &mut item.row, next_m);
            match pool {
                Some(pool) if n > 1 => {
                    let slots = SliceWriter::new(&mut work[..]);
                    pool.run_chunked(n, PREFETCH_CHUNK, |i| {
                        // SAFETY: the round cursor hands out each index
                        // exactly once, and `work` (held under the sweep
                        // mutex) outlives the blocking round.
                        advance_one(unsafe { slots.slot(i) });
                    });
                }
                _ => work.iter_mut().for_each(advance_one),
            }
        }

        // Reinsert with one lock acquisition per shard: group the work
        // list by shard (in-place sort, no allocation) and drain each
        // run under a single guard, re-reading the binding once per
        // shard — the same freshness protocol as seed_into's insert.
        work.sort_unstable_by_key(|it| shard_of((it.a, it.cs)));
        let mut advanced = 0u64;
        while !work.is_empty() {
            let s = shard_of((work[0].a, work[0].cs));
            let run = work.iter().take_while(|it| shard_of((it.a, it.cs)) == s).count();
            let mut g = lock_recover(&self.shards[s]);
            let fresh =
                self.epoch.load(Ordering::Acquire) == epoch0 && self.bound() == ident;
            for item in work.drain(..run) {
                let key = (item.a, item.cs);
                if fresh && (g.rows.len() < MAX_ROWS_PER_SHARD || g.rows.contains_key(&key)) {
                    g.rows.insert(key, item.row);
                    advanced += 1;
                } else {
                    g.recycle(item.row);
                }
            }
        }
        // Only sweeps that found rows to advance count as batches — a
        // bound cache with nothing below `next_m` (e.g. the streaming
        // monitor's fixed-length refreshes) must not skew the
        // rows-per-batch metric with empty entries.
        if n > 0 {
            self.prefetched.fetch_add(advanced, Ordering::Relaxed);
            self.prefetch_batches.fetch_add(1, Ordering::Relaxed);
        }
        advanced
    }

    /// Produce the seed row `qt_out[j] = dot_m(a, cs + j)` for
    /// `j in 0..nb`, reusing / advancing the cached row for
    /// `(a, cs)` when possible.  `qt_out.len()` must equal `nb`.
    // hot-path: seed-row lookup/advance/recompute, once per tile bind.
    pub fn seed_into(
        &self,
        t: &[f64],
        m: usize,
        a: usize,
        cs: usize,
        nb: usize,
        qt_out: &mut [f64],
    ) {
        debug_assert_eq!(qt_out.len(), nb);
        let key = (a, cs);
        let ident = identity(t);
        // panic-free: shard_of masks with SHARD_COUNT-1, always in range.
        let shard = &self.shards[shard_of(key)];
        // Both critical sections re-read the binding under the shard
        // lock: two PD3 runs on one shared engine with different (live,
        // hence non-aliasing) series would otherwise race `prepare` and
        // cross-pollinate rows mid-flight.  On a binding mismatch this
        // call simply computes fresh products and leaves the cache alone.
        let (taken, spare, epoch0, bound_ok) = {
            let mut g = lock_recover(shard);
            let epoch0 = self.epoch.load(Ordering::Acquire);
            if self.bound() == ident {
                let taken = g.rows.remove(&key);
                let spare = if taken.is_none() { g.spares.pop() } else { None };
                (taken, spare, epoch0, true)
            } else {
                (None, None, epoch0, false)
            }
        };
        let row = match taken {
            // Same length: verbatim reuse (MERLIN's r-retries, and every
            // post-prefetch tile of a swept length).
            Some(mut row) if row.m == m && row.qt.len() >= nb => {
                row.qt.truncate(nb);
                qt_out.copy_from_slice(&row.qt);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(row)
            }
            // Shorter cached length: advance via the shared recurrence
            // ([`advance_row`] — the same code the bulk sweep runs).
            // The window count only shrinks as m grows, so `nb` here is
            // never larger than the cached row.
            Some(mut row) if row.m < m && row.qt.len() >= nb => {
                row.qt.truncate(nb);
                advance_row(t, a, cs, &mut row, m);
                qt_out.copy_from_slice(&row.qt);
                self.advances.fetch_add(1, Ordering::Relaxed);
                Some(row)
            }
            // Miss (cold, a sweep restarted at a shorter length, or a
            // fresh series): full O(nb * m) seed pass, stored for next
            // time.  The stale row's allocation — or a spare evicted by
            // a series change — is recycled when present.
            other => {
                // panic-free: tile geometry again — a and cs+j (j < nb)
                // are valid window starts for length m, so both slices
                // end at or before t.len().
                let wa = &t[a..a + m];
                for (j, q) in qt_out.iter_mut().enumerate() {
                    *q = dot(wa, &t[cs + j..cs + j + m]);
                }
                self.misses.fetch_add(1, Ordering::Relaxed);
                if bound_ok {
                    let mut row =
                        other.or(spare).unwrap_or_else(|| SeedRow { m, qt: Vec::new() });
                    row.m = m;
                    row.qt.clear();
                    row.qt.extend_from_slice(qt_out);
                    Some(row)
                } else {
                    // Binding race: don't build a row the guarded
                    // insert below would just drop.
                    None
                }
            }
        };
        if let Some(row) = row {
            let mut g = lock_recover(shard);
            let fresh =
                self.epoch.load(Ordering::Acquire) == epoch0 && self.bound() == ident;
            if fresh && (g.rows.len() < MAX_ROWS_PER_SHARD || g.rows.contains_key(&key)) {
                g.rows.insert(key, row);
            } else {
                // The binding moved while we computed (or the shard is
                // full): the products may belong to a retired series —
                // keep only the allocation.
                g.recycle(row);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series(n: usize) -> Vec<f64> {
        (0..n).map(|i| ((i * 37 + 11) % 101) as f64 * 0.25 - 7.0).collect()
    }

    fn fresh_seed(t: &[f64], m: usize, a: usize, cs: usize, nb: usize) -> Vec<f64> {
        (0..nb).map(|j| dot(&t[a..a + m], &t[cs + j..cs + j + m])).collect()
    }

    #[test]
    fn miss_then_hit_is_exact() {
        let t = series(256);
        let cache = QtSeedCache::new();
        cache.prepare(&t);
        let (m, a, cs, nb) = (16, 3, 40, 32);
        let mut first = vec![0.0; nb];
        cache.seed_into(&t, m, a, cs, nb, &mut first);
        assert_eq!(first, fresh_seed(&t, m, a, cs, nb));
        let mut second = vec![0.0; nb];
        cache.seed_into(&t, m, a, cs, nb, &mut second);
        assert_eq!(first, second, "hit must return the stored row verbatim");
        let c = cache.counters();
        assert_eq!((c.seed_misses, c.seed_hits, c.seed_advances), (1, 1, 0));
    }

    #[test]
    fn cross_length_advance_matches_fresh_dots() {
        let t = series(300);
        let cache = QtSeedCache::new();
        cache.prepare(&t);
        let (a, cs) = (5, 64);
        let mut buf = vec![0.0; 48];
        cache.seed_into(&t, 12, a, cs, 48, &mut buf);
        // Advance 12 -> 20 in one step; columns shrink too.
        let nb = 40;
        let mut got = vec![0.0; nb];
        cache.seed_into(&t, 20, a, cs, nb, &mut got);
        let want = fresh_seed(&t, 20, a, cs, nb);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9 * (1.0 + w.abs()), "{g} vs {w}");
        }
        assert_eq!(cache.counters().seed_advances, 1);
    }

    #[test]
    fn shorter_request_recomputes() {
        let t = series(200);
        let cache = QtSeedCache::new();
        cache.prepare(&t);
        let mut buf = vec![0.0; 16];
        cache.seed_into(&t, 24, 0, 50, 16, &mut buf);
        let mut back = vec![0.0; 16];
        cache.seed_into(&t, 10, 0, 50, 16, &mut back);
        assert_eq!(back, fresh_seed(&t, 10, 0, 50, 16));
        assert_eq!(cache.counters().seed_misses, 2);
    }

    #[test]
    fn prepare_invalidates_on_series_change() {
        let t1 = series(128);
        let mut t2 = t1.clone();
        t2[60] += 1.0;
        let cache = QtSeedCache::new();
        cache.prepare(&t1);
        let mut buf = vec![0.0; 8];
        cache.seed_into(&t1, 8, 0, 30, 8, &mut buf);
        cache.prepare(&t2);
        let mut after = vec![0.0; 8];
        cache.seed_into(&t2, 8, 0, 30, 8, &mut after);
        assert_eq!(after, fresh_seed(&t2, 8, 0, 30, 8));
        let c = cache.counters();
        assert_eq!((c.seed_misses, c.seed_hits), (2, 0));
    }

    #[test]
    fn rebinding_series_recycles_rows_correctly() {
        // The streaming-refresh pattern: the bound content changes on
        // every prepare.  Recycled spare rows must never leak another
        // series' products.
        let t1 = series(200);
        let t2: Vec<f64> = t1.iter().map(|v| v * 1.5 + 2.0).collect();
        let cache = QtSeedCache::new();
        for _ in 0..4 {
            for t in [&t1, &t2] {
                cache.prepare(t);
                let mut buf = vec![0.0; 24];
                cache.seed_into(t, 12, 4, 60, 24, &mut buf);
                assert_eq!(buf, fresh_seed(t, 12, 4, 60, 24));
            }
        }
        let c = cache.counters();
        assert_eq!(c.seed_hits, 0, "every rebind must invalidate: {c:?}");
        assert_eq!(c.seed_misses, 8);
    }

    #[test]
    fn concurrent_rebinds_never_serve_stale_products() {
        // Stress regression for the eviction-window race: prepare()
        // retires the binding to the sentinel before bumping the epoch
        // and evicting, so a seed_into racing a rebind can neither trust
        // a mid-eviction binding nor slip a stale-series row past the
        // reinsert epoch check.  Every returned row must match the
        // caller's own series, always.
        use std::sync::atomic::AtomicBool;
        let t1 = series(300);
        let t2: Vec<f64> = t1.iter().map(|v| v * -1.25 + 3.0).collect();
        let cache = QtSeedCache::new();
        let stop = AtomicBool::new(false);
        let (cache_ref, stop_ref) = (&cache, &stop);
        std::thread::scope(|scope| {
            for t in [&t1, &t2] {
                scope.spawn(move || {
                    let want = fresh_seed(t, 10, 2, 50, 32);
                    let mut buf = vec![0.0; 32];
                    while !stop_ref.load(Ordering::Relaxed) {
                        cache_ref.prepare(t);
                        cache_ref.seed_into(t, 10, 2, 50, 32, &mut buf);
                        assert_eq!(buf, want, "stale products for another series");
                    }
                });
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
            stop.store(true, Ordering::Relaxed);
        });
    }

    #[test]
    fn clear_recycles_rows_into_spares() {
        let t = series(400);
        let cache = QtSeedCache::new();
        cache.prepare(&t);
        let mut buf = vec![0.0; 32];
        for k in 0..6 {
            cache.seed_into(&t, 16, k * 3, 100 + k * 40, 32, &mut buf);
        }
        assert_eq!(cache.live_rows(), 6);
        assert_eq!(cache.spare_rows(), 0);
        cache.clear();
        assert_eq!(cache.live_rows(), 0);
        assert_eq!(cache.spare_rows(), 6, "clear must recycle, not drop");
        // Re-seeding pops the spares back into service and stays exact.
        cache.seed_into(&t, 16, 0, 100, 32, &mut buf);
        assert_eq!(buf, fresh_seed(&t, 16, 0, 100, 32));
        assert_eq!(cache.spare_rows(), 5);
    }

    /// Export → import into a fresh cache (bound to a *different* but
    /// byte-identical buffer, like a service resume that regenerated
    /// the series) must reproduce the donor's rows bit-exactly: the
    /// next seed request is a verbatim hit with the donor's products.
    #[test]
    fn export_import_round_trips_rows_bit_exact() {
        let t = series(400);
        let cache = QtSeedCache::new();
        cache.prepare(&t);
        let mut buf = vec![0.0; 32];
        for k in 0..6 {
            cache.seed_into(&t, 16, k * 3, 100 + k * 40, 32, &mut buf);
        }
        // Advance the rows so the export carries post-recurrence state
        // (the case a fresh re-seed cannot reproduce bit-for-bit).
        cache.advance_all(&t, 20, None);
        let rows = cache.export_rows(&t);
        assert_eq!(rows.len(), 6);
        assert!(rows.windows(2).all(|w| (w[0].a, w[0].cs) < (w[1].a, w[1].cs)), "sorted");

        let t2 = t.clone(); // different buffer, identical content
        let fresh = QtSeedCache::new();
        assert_eq!(fresh.import_rows(&t2, &rows), 6);
        let before = fresh.counters();
        let mut got = vec![0.0; 32];
        cache.seed_into(&t, 20, 0, 100, 32, &mut buf); // donor's own row (hit)
        fresh.seed_into(&t2, 20, 0, 100, 32, &mut got);
        let after = fresh.counters();
        assert_eq!(after.seed_hits, before.seed_hits + 1, "imported row must hit verbatim");
        assert_eq!(
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            buf.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "imported row diverged from the donor's"
        );

        // Unbound cache exports nothing; rows outside the series are
        // rejected on import (tampered-checkpoint defense).
        assert!(QtSeedCache::new().export_rows(&t).is_empty());
        let bogus = [SeedRowSnapshot { a: 395, cs: 100, m: 16, qt: vec![1.0; 32] }];
        assert_eq!(fresh.import_rows(&t2, &bogus), 0);
    }

    #[test]
    fn advance_all_matches_lazy_advance_bit_exact() {
        let t = series(500);
        let lazy = QtSeedCache::new();
        let bulk = QtSeedCache::new();
        lazy.prepare(&t);
        bulk.prepare(&t);
        let keys = [(0usize, 60usize), (7, 130), (31, 222), (64, 300)];
        let nb = 48;
        let mut buf = vec![0.0; nb];
        for &(a, cs) in &keys {
            lazy.seed_into(&t, 10, a, cs, nb, &mut buf);
            bulk.seed_into(&t, 10, a, cs, nb, &mut buf);
        }
        // Walk both caches 10 -> 14, the bulk one through the sweep.
        for next_m in 11..=14 {
            assert_eq!(bulk.advance_all(&t, next_m, None), keys.len() as u64);
            for &(a, cs) in &keys {
                let mut l = vec![0.0; nb];
                let mut b = vec![0.0; nb];
                lazy.seed_into(&t, next_m, a, cs, nb, &mut l);
                bulk.seed_into(&t, next_m, a, cs, nb, &mut b);
                assert_eq!(l, b, "prefetched row differs at m={next_m} key=({a},{cs})");
            }
        }
        let (cl, cb) = (lazy.counters(), bulk.counters());
        assert_eq!(cl.seed_misses, cb.seed_misses, "prefetch must not add misses");
        assert_eq!(cb.seed_advances, 0, "prefetch subsumes the lazy advances");
        assert_eq!(cb.seed_prefetched, 4 * keys.len() as u64);
        assert_eq!(cb.prefetch_batches, 4);
        assert_eq!(cl.seed_advances, 4 * keys.len() as u64);
    }

    #[test]
    fn advance_all_parallel_matches_serial() {
        // Scaled-down profile under Miri (interpreted execution): same
        // protocol, fewer rows — the aliasing checks don't need volume.
        let (n, nkeys, nb, span) = if cfg!(miri) { (400, 8, 16, 150) } else { (2000, 60, 64, 900) };
        let t = series(n);
        let serial = QtSeedCache::new();
        let parallel = QtSeedCache::new();
        serial.prepare(&t);
        parallel.prepare(&t);
        let mut buf = vec![0.0; nb];
        let keys: Vec<(usize, usize)> =
            (0..nkeys).map(|k| (k * 17 % span, span + (k * 13) % span)).collect();
        for &(a, cs) in &keys {
            serial.seed_into(&t, 20, a, cs, nb, &mut buf);
            parallel.seed_into(&t, 20, a, cs, nb, &mut buf);
        }
        let pool = RoundPool::new(3);
        assert_eq!(serial.advance_all(&t, 25, None), keys.len() as u64);
        assert_eq!(parallel.advance_all(&t, 25, Some(&pool)), keys.len() as u64);
        for &(a, cs) in &keys {
            let mut s = vec![0.0; nb];
            let mut p = vec![0.0; nb];
            serial.seed_into(&t, 25, a, cs, nb, &mut s);
            parallel.seed_into(&t, 25, a, cs, nb, &mut p);
            assert_eq!(s, p, "pool fan-out changed a row at key ({a},{cs})");
        }
    }

    #[test]
    fn advance_all_unbound_is_noop() {
        let t1 = series(200);
        let t2 = series(201);
        let cache = QtSeedCache::new();
        cache.prepare(&t1);
        let mut buf = vec![0.0; 16];
        cache.seed_into(&t1, 8, 0, 50, 16, &mut buf);
        assert_eq!(cache.advance_all(&t2, 9, None), 0, "unbound series must not sweep");
        assert_eq!(cache.counters().prefetch_batches, 0);
        // The t1 row is untouched and still hits at its own length.
        cache.seed_into(&t1, 8, 0, 50, 16, &mut buf);
        assert_eq!(cache.counters().seed_hits, 1);
    }

    #[test]
    fn advance_all_recycles_rows_past_the_window_range() {
        // With n = 100 and next_m = 21 there are 80 windows (0..=79); a
        // row keyed at cs = 79 keeps one column, one keyed at the last
        // m=20 row index (a = 80) falls off the range.
        let t = series(100);
        let cache = QtSeedCache::new();
        cache.prepare(&t);
        let mut buf = vec![0.0; 1];
        cache.seed_into(&t, 20, 0, 79, 1, &mut buf);
        cache.seed_into(&t, 20, 80, 0, 1, &mut buf);
        assert_eq!(cache.advance_all(&t, 21, None), 1, "only the in-range row advances");
        assert_eq!(cache.live_rows(), 1);
        assert_eq!(cache.spare_rows(), 1, "the out-of-range row is recycled");
        cache.seed_into(&t, 21, 0, 79, 1, &mut buf);
        assert_eq!(buf, fresh_seed(&t, 21, 0, 79, 1));
        assert_eq!(cache.counters().seed_hits, 1);
    }

    #[test]
    fn scratch_ensure_is_idempotent() {
        let mut s = TileScratch::new();
        s.ensure(64);
        let p = s.qt.as_ptr();
        s.ensure(64);
        s.ensure(32);
        assert_eq!(s.qt.as_ptr(), p);
        assert_eq!(s.qt.len(), 64);
    }

    #[test]
    fn scratch_rows_are_lane_aligned() {
        // An off-grid tile edge gets MAX_LANES-aligned rows, so a chunk
        // of *any* kernel width (including Lanes8) ending at the row
        // boundary stays in-bounds, and re-ensuring at the aligned size
        // reuses storage.
        let mut s = TileScratch::new();
        s.ensure(33);
        assert_eq!(s.qt.len(), 40);
        assert_eq!(s.dist.len(), 40);
        assert_eq!(s.mmu_b.len(), 40);
        let p = s.qt.as_ptr();
        s.ensure(40);
        s.ensure(1);
        assert_eq!(s.qt.as_ptr(), p, "aligned re-ensure must not reallocate");
        assert_eq!(s.qt.len(), 40);
        // The f32 twins are lazy: untouched by ensure(), aligned the
        // same way once the f32 path asks for them.
        assert!(s.qt32.is_empty() && s.col_min32.is_empty());
        s.ensure_f32(33);
        assert_eq!(s.qt32.len(), 40);
        assert_eq!(s.dist32.len(), 40);
        assert_eq!(s.col_min32.len(), 40);
        let p32 = s.qt32.as_ptr();
        s.ensure_f32(40);
        assert_eq!(s.qt32.as_ptr(), p32, "aligned f32 re-ensure must not reallocate");
    }

    /// Deterministic-but-irregular row data for the kernel-pass tests.
    fn row(n: usize, salt: u64) -> Vec<f64> {
        (0..n)
            .map(|i| {
                let x = (i as u64).wrapping_mul(0x9E37_79B9).wrapping_add(salt) % 1009;
                x as f64 * 0.37 - 180.0
            })
            .collect()
    }

    #[test]
    fn distance_row_lanes_matches_scalar_and_counts_saturation() {
        // Widths off the lane grid, plus synthetic products that force
        // every clamp outcome: in-range, saturated high/low, and NaN.
        for nb in [1usize, 2, 3, 4, 5, 7, 8, 11, 19] {
            let mut qt = row(nb, 1);
            let mmu_b = vec![0.0; nb];
            let mut inv_msig_b = vec![0.25; nb];
            // Column 0: corr = 4 * qt[0] -> saturate for |qt[0]| large.
            qt[0] = 10.0; // corr 10 -> clamped to 1, dist 0
            if nb > 2 {
                qt[2] = -10.0; // clamped to -1, dist 4m
            }
            if nb > 4 {
                qt[4] = f64::NAN; // NaN propagates, never counts
                inv_msig_b[4] = 1.0;
            }
            let (mu_a, inv_sig_a, two_m) = (0.0, 4.0, 32.0);
            let mut ds = vec![0.0; nb];
            let ss = distance_row(
                TileKernel::Scalar, &qt, &mmu_b, &inv_msig_b, mu_a, inv_sig_a, two_m, &mut ds,
            );
            let want_sat = (0..nb)
                .filter(|&j| {
                    corr_saturates((qt[j] - mmu_b[j] * mu_a) * (inv_msig_b[j] * inv_sig_a))
                })
                .count() as u64;
            assert_eq!(ss, want_sat, "nb={nb}");
            assert!(ss >= 1 + (nb > 2) as u64, "nb={nb}: planted saturations missed");
            for lane_kernel in [TileKernel::Lanes4, TileKernel::Lanes8] {
                let mut dl = vec![0.0; nb];
                let sl = distance_row(
                    lane_kernel, &qt, &mmu_b, &inv_msig_b, mu_a, inv_sig_a, two_m, &mut dl,
                );
                assert_eq!(ss, sl, "nb={nb} {lane_kernel:?}: saturation counts diverge");
                for j in 0..nb {
                    assert_eq!(
                        ds[j].to_bits(),
                        dl[j].to_bits(),
                        "nb={nb} {lane_kernel:?} j={j}: {} vs {}",
                        ds[j],
                        dl[j]
                    );
                }
                assert_eq!(dl[0], 0.0, "clamped-high distance");
                if nb > 2 {
                    assert_eq!(dl[2], 2.0 * two_m, "clamped-low distance");
                }
                if nb > 4 {
                    assert!(dl[4].is_nan(), "NaN column must propagate");
                }
            }
        }
    }

    #[test]
    fn folds_match_scalar_with_nan_inf_and_tail() {
        for nb in [1usize, 3, 4, 6, 8, 13] {
            let mut dist = row(nb, 7).iter().map(|x| x.abs()).collect::<Vec<_>>();
            dist[0] = f64::INFINITY;
            if nb > 1 {
                dist[1] = f64::NAN;
            }
            if nb > 5 {
                dist[5] = 0.0;
            }
            let r2 = 40.0;
            let (ms, ks) = row_folds(TileKernel::Scalar, &dist, r2);
            for lane_kernel in [TileKernel::Lanes4, TileKernel::Lanes8] {
                let (ml, kl) = row_folds(lane_kernel, &dist, r2);
                assert_eq!(ms.to_bits(), ml.to_bits(), "nb={nb} {lane_kernel:?}: {ms} vs {ml}");
                assert_eq!(ks, kl, "nb={nb} {lane_kernel:?}: row kill");
                assert!(!ml.is_nan(), "NaN must never survive a min fold");

                let mut cm_s = vec![f64::INFINITY; nb];
                let mut cm_l = vec![f64::INFINITY; nb];
                let mut ck_s = vec![false; nb];
                let mut ck_l = vec![false; nb];
                // Two passes so the second folds into non-trivial state.
                for pass in 0..2 {
                    let shifted: Vec<f64> =
                        dist.iter().map(|d| d * (1.0 + pass as f64 * 0.5)).collect();
                    col_folds(TileKernel::Scalar, &shifted, r2, &mut cm_s, &mut ck_s);
                    col_folds(lane_kernel, &shifted, r2, &mut cm_l, &mut ck_l);
                }
                for j in 0..nb {
                    assert_eq!(cm_s[j].to_bits(), cm_l[j].to_bits(), "nb={nb} col {j}");
                    assert_eq!(ck_s[j], ck_l[j], "nb={nb} col kill {j}");
                }
                if nb > 1 {
                    assert!(cm_l[1].is_infinite(), "NaN column must leave col_min untouched");
                }
            }
        }
    }

    #[test]
    fn qt_recurrence_lanes_matches_scalar() {
        let t = series(300);
        let (m, a, cs) = (17, 40, 90);
        for nb in [1usize, 2, 4, 5, 9, 32, 61] {
            let prev = row(nb, 3);
            let mut qs = vec![0.0; nb];
            qt_recurrence_row(TileKernel::Scalar, &t, m, a, cs, &prev, &mut qs);
            for lane_kernel in [TileKernel::Lanes4, TileKernel::Lanes8] {
                let mut ql = vec![0.0; nb];
                qt_recurrence_row(lane_kernel, &t, m, a, cs, &prev, &mut ql);
                for j in 0..nb {
                    assert_eq!(qs[j].to_bits(), ql[j].to_bits(), "nb={nb} {lane_kernel:?} j={j}");
                }
            }
        }
    }

    #[test]
    fn f32_row_passes_mirror_f64_structure() {
        // The f32 instantiations run the same bodies one precision
        // down: distances stay close to the f64 kernel's, fold
        // *structure* (which column wins, NaN hygiene) is preserved.
        let t = series(300);
        let (m, a, cs) = (17, 40, 90);
        for nb in [1usize, 3, 5, 9, 32] {
            let prev64 = row(nb, 3);
            let prev32: Vec<f32> = prev64.iter().map(|&x| x as f32).collect();
            let mut q64 = vec![0.0f64; nb];
            let mut q32 = vec![0.0f32; nb];
            qt_recurrence_row(TileKernel::Lanes4, &t, m, a, cs, &prev64, &mut q64);
            qt_recurrence_row_w::<f32, LANES>(&t, m, a, cs, &prev32, &mut q32);
            for j in 0..nb {
                let rel = (q32[j] as f64 - q64[j]).abs() / (1.0 + q64[j].abs());
                assert!(rel < 1e-3, "nb={nb} j={j}: qt {q32:?} vs {q64:?}");
            }
            // Kill thresholds far outside the data range: decisions
            // must agree whenever the margin dwarfs f32 rounding.
            let (m64, k64) = row_folds(TileKernel::Lanes4, &q64, 1.0e15);
            let (m32, k32) = row_folds_w::<f32, LANES>(&q32, 1.0e15f32);
            assert_eq!(k64, k32, "nb={nb}: everything under a huge r2 kills");
            assert!(k32, "nb={nb}");
            let (_, k64n) = row_folds(TileKernel::Lanes4, &q64, -1.0e15);
            let (_, k32n) = row_folds_w::<f32, LANES>(&q32, -1.0e15f32);
            assert_eq!(k64n, k32n, "nb={nb}: nothing under a huge negative r2 kills");
            assert!(!k32n, "nb={nb}");
            let rel = (m32 as f64 - m64).abs() / (1.0 + m64.abs());
            assert!(rel < 1e-3, "nb={nb}: row min {m32} vs {m64}");
        }
    }
}
