//! AOT engine: tiles evaluated by the Pallas/JAX-compiled HLO artifacts
//! through the PJRT executor actor.
//!
//! This is the production path of the three-layer architecture: the same
//! executable that would run on a TPU (here interpret-lowered for the CPU
//! PJRT plugin) is loaded once and invoked per tile.  The engine's job is
//! marshalling: slicing the raw series and the `f64` stats into the fixed
//! `f32` buffers the artifact expects.
#![forbid(unsafe_code)]

use anyhow::Result;

use super::{Engine, SeriesView, TileTask};
use crate::runtime::artifact::ArtifactSet;
use crate::runtime::executor::Executor;
use crate::runtime::types::{TileInputs, TileOutputs, TileShape};

/// PJRT-backed engine.
///
/// Holds `shards` executor actors (each owns its own `PjRtClient` and
/// compiled-executable cache); tile batches are split across the shards
/// so PJRT executions overlap.  One shard handles the (cheap, O(n))
/// stats kernels.  Sharding was the single biggest win of the L3 perf
/// pass (see EXPERIMENTS.md §Perf): one actor serializes every tile.
pub struct XlaEngine {
    executors: Vec<Executor>,
    artifacts: ArtifactSet,
    segn: usize,
    max_m: usize,
}

/// Default executor shard count: enough to overlap PJRT call overhead
/// without oversubscribing XLA's own intra-op thread pool.
pub fn default_shards() -> usize {
    (std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4) / 4).clamp(1, 4)
}

impl XlaEngine {
    /// Start an engine over an artifact directory with the given tile edge
    /// (`segn` must be one of the compiled buckets).
    pub fn new(artifacts: ArtifactSet, segn: usize) -> Result<Self> {
        Self::with_shards(artifacts, segn, default_shards())
    }

    /// Explicit shard count (benches sweep this).
    pub fn with_shards(artifacts: ArtifactSet, segn: usize, shards: usize) -> Result<Self> {
        let max_m = artifacts
            .max_m_for_segn(segn)
            .ok_or_else(|| anyhow::anyhow!("no tile artifacts with segn={segn}"))?;
        let executors = (0..shards.max(1))
            .map(|_| Executor::start(artifacts.clone()))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { executors, artifacts, segn, max_m })
    }

    /// Access to an underlying executor (stats kernels, tests).
    pub fn executor(&self) -> &Executor {
        &self.executors[0]
    }

    pub fn artifacts(&self) -> &ArtifactSet {
        &self.artifacts
    }

    /// Build the fixed-shape input buffers for one task.
    fn marshal(&self, view: &SeriesView<'_>, shape: TileShape, r2: f64, task: TileTask) -> TileInputs {
        let src_len = shape.src_len();
        let t = view.t;
        let stats = view.stats;
        let nwin = view.n_windows();

        let slice_f32 = |start: usize| -> Vec<f32> {
            let mut out = vec![0f32; src_len];
            if start < t.len() {
                let avail = (t.len() - start).min(src_len);
                for (o, &v) in out[..avail].iter_mut().zip(&t[start..start + avail]) {
                    // order: deliberate f64 -> f32 narrowing at the tile
                    // boundary; same bits every engine sees.
                    *o = v as f32;
                }
            }
            out
        };

        let mut mu_a = vec![0f32; shape.segn];
        let mut sig_a = vec![1f32; shape.segn];
        let mut mu_b = vec![0f32; shape.segn];
        let mut sig_b = vec![1f32; shape.segn];
        stats.slice_f32(task.seg_start, shape.segn, &mut mu_a, &mut sig_a);
        stats.slice_f32(task.chunk_start, shape.segn, &mut mu_b, &mut sig_b);

        let na = shape.segn.min(nwin.saturating_sub(task.seg_start));
        let nb = shape.segn.min(nwin.saturating_sub(task.chunk_start));

        TileInputs {
            seg_src: slice_f32(task.seg_start),
            chunk_src: slice_f32(task.chunk_start),
            mu_a,
            sig_a,
            mu_b,
            sig_b,
            m: stats.m as i32,
            delta: task.chunk_start as i32 - task.seg_start as i32,
            na: na as i32,
            nb: nb as i32,
            // order: threshold narrowed once per task, identically for every
            // engine and every replay of the same task.
            r2: r2 as f32,
        }
    }
}

impl XlaEngine {
    /// Pad `t` (downcast to f32) to the stats bucket >= n.
    fn padded_t(&self, t: &[f64], nmax: usize) -> Vec<f32> {
        let mut out = vec![0f32; nmax];
        for (o, &v) in out.iter_mut().zip(t) {
            // order: deliberate f64 -> f32 narrowing at the stats-program
            // boundary; bucket padding does not change the narrowed bits.
            *o = v as f32;
        }
        out
    }
}

impl Engine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn segn(&self) -> usize {
        self.segn
    }

    fn max_m(&self) -> usize {
        self.max_m
    }

    fn compute_tiles(
        &self,
        view: &SeriesView<'_>,
        r2: f64,
        tasks: &[TileTask],
    ) -> Result<Vec<TileOutputs>> {
        let shape = self.artifacts.select_tile(self.segn, view.stats.m)?;
        // Split the batch across executor shards; each shard's sub-batch
        // runs on its own PJRT client concurrently.
        let shards = self.executors.len().min(tasks.len()).max(1);
        let chunk = tasks.len().div_ceil(shards);
        let mut results: Vec<Result<Vec<TileOutputs>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..shards)
                .map(|s| {
                    let lo = s * chunk;
                    let hi = ((s + 1) * chunk).min(tasks.len());
                    let exec = &self.executors[s];
                    let slice = &tasks[lo..hi];
                    scope.spawn(move || {
                        let inputs: Vec<TileInputs> = slice
                            .iter()
                            .map(|&task| self.marshal(view, shape, r2, task))
                            .collect();
                        exec.tile_batch(shape, inputs)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("shard thread panicked")).collect()
        });
        let mut out = Vec::with_capacity(tasks.len());
        for r in results.drain(..) {
            out.extend(r?);
        }
        // The artifact's SEGN may exceed the engine's logical segn only if
        // select_tile returned a larger bucket; truncate defensively.
        for o in &mut out {
            o.row_min.truncate(self.segn);
            o.col_min.truncate(self.segn);
            o.row_kill.truncate(self.segn);
            o.col_kill.truncate(self.segn);
        }
        Ok(out)
    }

    fn aot_stats_init(&self, t: &[f64], m: usize) -> Result<crate::core::stats::RollingStats> {
        let nmax = self.artifacts.select_stats(t.len())?;
        let (mut mu, mut sig) = self.executor().stats_init(nmax, self.padded_t(t, nmax), m as i32)?;
        let nwin = t.len() + 1 - m;
        mu.truncate(nwin);
        sig.truncate(nwin);
        Ok(crate::core::stats::RollingStats { m, mu, sig })
    }

    fn aot_stats_update(
        &self,
        t: &[f64],
        stats: &crate::core::stats::RollingStats,
    ) -> Result<crate::core::stats::RollingStats> {
        let nmax = self.artifacts.select_stats(t.len())?;
        let mut mu = stats.mu.clone();
        let mut sig = stats.sig.clone();
        mu.resize(nmax, 0.0);
        sig.resize(nmax, 1.0);
        let (mut mu2, mut sig2) =
            self.executor().stats_update(nmax, self.padded_t(t, nmax), mu, sig, stats.m as i32)?;
        let m2 = stats.m + 1;
        let nwin = t.len() + 1 - m2;
        mu2.truncate(nwin);
        sig2.truncate(nwin);
        Ok(crate::core::stats::RollingStats { m: m2, mu: mu2, sig: sig2 })
    }
}
