//! Synthetic electrocardiogram — surrogate for the ECG / ECG-2 /
//! Koski-ECG traces (Tab. 1).
//!
//! Each beat is a sum of Gaussian bumps approximating the P-QRS-T complex
//! (the standard ECG phantom construction); beat-to-beat interval and
//! amplitude jitter make normal beats near-but-not-exactly repeating, so
//! nearest-neighbor distances behave like the real recordings'.
//! [`ecg_with_pvc`] plants premature ventricular contractions: wide,
//! inverted beats — the canonical ECG discord.

use crate::core::series::TimeSeries;
use crate::util::rng::Rng;

/// One P-QRS-T complex sampled at offset `x` in [0, 1) of the beat.
fn beat_waveform(x: f64, amp: f64, width_scale: f64) -> f64 {
    // (center, sigma, amplitude) per wave, in beat-relative units.
    const WAVES: [(f64, f64, f64); 5] = [
        (0.18, 0.025, 0.15),  // P
        (0.345, 0.010, -0.12), // Q
        (0.37, 0.012, 1.0),   // R
        (0.395, 0.010, -0.25), // S
        (0.60, 0.040, 0.30),  // T
    ];
    let mut v = 0.0;
    for (c, s, a) in WAVES {
        let s = s * width_scale;
        let d = (x - c) / s;
        v += a * (-0.5 * d * d).exp();
    }
    amp * v
}

/// Normal synthetic ECG: `n` samples at `fs` Hz, ~`bpm` beats/minute.
pub fn ecg(n: usize, fs: f64, bpm: f64, seed: u64) -> TimeSeries {
    ecg_with_pvc(n, fs, bpm, &[], seed)
}

/// Synthetic ECG with premature (PVC-like) beats planted at the given
/// beat numbers.  Returns the series; the sample position of beat `k` is
/// approximately `k * fs * 60 / bpm`.
pub fn ecg_with_pvc(n: usize, fs: f64, bpm: f64, pvc_beats: &[usize], seed: u64) -> TimeSeries {
    let mut rng = Rng::seed(seed);
    let mut values = vec![0.0; n];
    let nominal = fs * 60.0 / bpm; // samples per beat
    let mut beat_start = 0.0f64;
    let mut beat_no = 0usize;
    while (beat_start as usize) < n {
        let is_pvc = pvc_beats.contains(&beat_no);
        // Beat-to-beat jitter, clamped so no *normal* beat becomes an
        // accidental discord (unclamped Gaussian tails occasionally produce
        // a one-off stretched beat that out-scores the planted PVC).
        let jit = (0.02 * rng.normal()).clamp(-0.035, 0.035);
        let period = nominal * (1.0 + jit) * if is_pvc { 0.75 } else { 1.0 };
        let amp = 1.0 + (0.05 * rng.normal()).clamp(-0.1, 0.1);
        let (amp, width) = if is_pvc { (-1.4 * amp, 3.0) } else { (amp, 1.0) };
        let start = beat_start as usize;
        let len = period as usize;
        for k in 0..len {
            let i = start + k;
            if i >= n {
                break;
            }
            values[i] += beat_waveform(k as f64 / period, amp, width);
        }
        beat_start += period;
        beat_no += 1;
    }
    // Baseline wander + measurement noise.
    for (i, v) in values.iter_mut().enumerate() {
        *v += 0.05 * (2.0 * std::f64::consts::PI * i as f64 / (fs * 7.0)).sin();
        *v += 0.01 * rng.normal();
    }
    TimeSeries::new(format!("ecg_{n}"), values)
}

/// Approximate sample index of beat `k`.
pub fn beat_sample(fs: f64, bpm: f64, k: usize) -> usize {
    (k as f64 * fs * 60.0 / bpm) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn periodic_structure() {
        let fs = 128.0;
        let t = ecg(4096, fs, 60.0, 1);
        assert_eq!(t.len(), 4096);
        // R peaks ~1.0 per second: count samples above 0.6.
        let peaks = t.values.windows(3).filter(|w| w[1] > 0.6 && w[1] >= w[0] && w[1] >= w[2]).count();
        let seconds = 4096.0 / fs;
        assert!(
            (peaks as f64) > 0.7 * seconds && (peaks as f64) < 1.6 * seconds,
            "peaks={peaks} over {seconds}s"
        );
    }

    #[test]
    fn pvc_beat_is_inverted() {
        let fs = 128.0;
        let pvc = 10;
        let t = ecg_with_pvc(4096, fs, 60.0, &[pvc], 2);
        let s = beat_sample(fs, 60.0, pvc);
        let e = (s + 128).min(t.len());
        let min_in_pvc = t.values[s..e].iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min_in_pvc < -0.8, "PVC negative peak missing: {min_in_pvc}");
    }

    #[test]
    fn deterministic() {
        assert_eq!(ecg(1000, 128.0, 70.0, 3).values, ecg(1000, 128.0, 70.0, 3).values);
    }
}
