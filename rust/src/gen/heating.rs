//! Synthetic smart-heating (PolyTER-like) lecture-hall temperature trace —
//! the case-study workload of §5: one year at 4 samples/hour (n = 35040),
//! with planted anomalies mirroring the paper's top-6 discoveries:
//! three long stuck-sensor plateaus, two short sensor dropouts, and one
//! period of inefficient heating mode.

use crate::core::series::TimeSeries;
use crate::util::rng::Rng;

/// Samples per day (15-minute cadence).
pub const SAMPLES_PER_DAY: usize = 96;
/// One year.
pub const YEAR: usize = 365 * SAMPLES_PER_DAY; // 35040

/// A planted anomaly (ground truth for the case study).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlantedAnomaly {
    pub start: usize,
    pub len: usize,
    pub kind: HeatingAnomaly,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeatingAnomaly {
    /// Sensor outputs one constant value for a long stretch.
    StuckSensor,
    /// Short dropout: a spike to a bogus constant.
    ShortDropout,
    /// Heating set to an inefficient regime (offset mean + weak schedule).
    InefficientMode,
}

/// The case-study trace with the standard anomaly set.
pub fn heating_year(seed: u64) -> (TimeSeries, Vec<PlantedAnomaly>) {
    let anomalies = vec![
        PlantedAnomaly { start: 30 * SAMPLES_PER_DAY, len: 5 * SAMPLES_PER_DAY, kind: HeatingAnomaly::StuckSensor },
        PlantedAnomaly { start: 150 * SAMPLES_PER_DAY, len: 3 * SAMPLES_PER_DAY, kind: HeatingAnomaly::StuckSensor },
        PlantedAnomaly { start: 300 * SAMPLES_PER_DAY, len: 4 * SAMPLES_PER_DAY, kind: HeatingAnomaly::StuckSensor },
        PlantedAnomaly { start: 90 * SAMPLES_PER_DAY + 40, len: 10, kind: HeatingAnomaly::ShortDropout },
        PlantedAnomaly { start: 200 * SAMPLES_PER_DAY + 60, len: 14, kind: HeatingAnomaly::ShortDropout },
        PlantedAnomaly { start: 250 * SAMPLES_PER_DAY, len: 6 * SAMPLES_PER_DAY, kind: HeatingAnomaly::InefficientMode },
    ];
    (heating(YEAR, &anomalies, seed), anomalies)
}

/// Generate `n` samples of lecture-hall temperature with planted anomalies.
pub fn heating(n: usize, anomalies: &[PlantedAnomaly], seed: u64) -> TimeSeries {
    let mut rng = Rng::seed(seed);
    let mut values = Vec::with_capacity(n);
    // Outdoor temperature: annual sinusoid + day/night + weather noise.
    let mut weather = 0.0f64;
    for i in 0..n {
        let day = i / SAMPLES_PER_DAY;
        let tod = (i % SAMPLES_PER_DAY) as f64 / SAMPLES_PER_DAY as f64; // time of day
        let season = -12.0 * (2.0 * std::f64::consts::PI * (day as f64 - 15.0) / 365.0).cos();
        weather += 0.02 * rng.normal() - 0.002 * weather;
        let outdoor = 6.0 + season + 4.0 * (2.0 * std::f64::consts::PI * (tod - 0.6)).sin() + weather;

        // Indoor control: setpoint schedule (occupied 8-18h on workdays).
        let weekday = day % 7 < 5;
        let occupied = weekday && (0.33..0.75).contains(&tod);
        let setpoint = if occupied { 21.5 } else { 17.0 };
        // First-order coupling to outdoor + control tracking.
        let coupling = 0.12 * (outdoor - setpoint);
        let indoor = setpoint + coupling + 0.35 * rng.normal();
        values.push(indoor);
    }
    // Apply anomalies.
    for a in anomalies {
        let end = (a.start + a.len).min(n);
        match a.kind {
            HeatingAnomaly::StuckSensor => {
                let v = values[a.start];
                for x in &mut values[a.start..end] {
                    *x = v;
                }
            }
            HeatingAnomaly::ShortDropout => {
                for x in &mut values[a.start..end] {
                    *x = 0.0; // sensor reports 0 C
                }
            }
            HeatingAnomaly::InefficientMode => {
                for (k, x) in values[a.start..end].iter_mut().enumerate() {
                    // Overheated nights, flattened schedule.
                    let tod = ((a.start + k) % SAMPLES_PER_DAY) as f64 / SAMPLES_PER_DAY as f64;
                    *x = 23.5 + 1.0 * (2.0 * std::f64::consts::PI * tod).sin() + 0.3 * rng.normal();
                }
            }
        }
    }
    TimeSeries::new(format!("heating_{n}"), values)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn year_length() {
        let (t, anomalies) = heating_year(1);
        assert_eq!(t.len(), 35_040);
        assert_eq!(anomalies.len(), 6);
    }

    #[test]
    fn stuck_region_is_constant() {
        let (t, a) = heating_year(2);
        let stuck = a.iter().find(|x| x.kind == HeatingAnomaly::StuckSensor).unwrap();
        let s = &t.values[stuck.start..stuck.start + stuck.len];
        assert!(s.iter().all(|&v| v == s[0]));
    }

    #[test]
    fn occupied_hours_are_warmer() {
        let t = heating(7 * SAMPLES_PER_DAY, &[], 3);
        // Monday noon vs Monday 3am.
        let noon = t.values[SAMPLES_PER_DAY / 2];
        let night = t.values[SAMPLES_PER_DAY / 8];
        assert!(noon > night + 2.0, "noon {noon} night {night}");
    }

    #[test]
    fn deterministic() {
        assert_eq!(heating_year(4).0.values, heating_year(4).0.values);
    }
}
