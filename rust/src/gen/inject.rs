//! Generic anomaly injectors: plant ground-truth subsequence anomalies
//! into any series so accuracy (hit/miss against the planted region) can
//! be scored — the capability the paper's real traces lack.

use crate::core::series::TimeSeries;
use crate::util::rng::Rng;

/// Kinds of planted subsequence anomalies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InjectionKind {
    /// Replace with a constant (stuck sensor).
    Flatline,
    /// Add a short large-amplitude spike train.
    SpikeTrain,
    /// Shift the level by a constant offset.
    LevelShift,
    /// Multiply local variability (noise burst).
    NoiseBurst,
    /// Time-reverse the window (shape anomaly, subtle).
    Reversal,
}

/// A planted anomaly record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Injection {
    pub start: usize,
    pub len: usize,
    pub kind: InjectionKind,
}

impl Injection {
    /// Does a discovered discord `[idx, idx+m)` overlap this injection?
    pub fn hit(&self, idx: usize, m: usize) -> bool {
        let (a1, a2) = (self.start, self.start + self.len);
        let (b1, b2) = (idx, idx + m);
        a1 < b2 && b1 < a2
    }
}

/// Apply an injection in place.
pub fn inject(t: &mut TimeSeries, inj: Injection, seed: u64) {
    let mut rng = Rng::seed(seed ^ inj.start as u64);
    let end = (inj.start + inj.len).min(t.len());
    let window = &mut t.values[inj.start..end];
    match inj.kind {
        InjectionKind::Flatline => {
            let v = window[0];
            for x in window.iter_mut() {
                *x = v;
            }
        }
        InjectionKind::SpikeTrain => {
            let scale = local_scale(window);
            for (k, x) in window.iter_mut().enumerate() {
                *x += if k % 2 == 0 { 4.0 * scale } else { -4.0 * scale };
            }
        }
        InjectionKind::LevelShift => {
            let scale = local_scale(window);
            for x in window.iter_mut() {
                *x += 6.0 * scale;
            }
        }
        InjectionKind::NoiseBurst => {
            let scale = local_scale(window);
            for x in window.iter_mut() {
                *x += 3.0 * scale * rng.normal();
            }
        }
        InjectionKind::Reversal => {
            window.reverse();
        }
    }
}

fn local_scale(w: &[f64]) -> f64 {
    let m = w.len() as f64;
    let mu = w.iter().sum::<f64>() / m;
    let var = w.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / m;
    var.sqrt().max(0.05 * mu.abs()).max(1e-3)
}

/// Plant `count` non-overlapping random injections of length `len`,
/// returning the records (sorted by start).
pub fn inject_random(
    t: &mut TimeSeries,
    count: usize,
    len: usize,
    kinds: &[InjectionKind],
    seed: u64,
) -> Vec<Injection> {
    assert!(!kinds.is_empty());
    let mut rng = Rng::seed(seed);
    let mut placed: Vec<Injection> = Vec::new();
    let mut guard = 0;
    while placed.len() < count && guard < 10_000 {
        guard += 1;
        let start = rng.below(t.len().saturating_sub(2 * len).max(1));
        // Keep a len-sized buffer around existing injections.
        if placed.iter().any(|p| start < p.start + p.len + len && p.start < start + 2 * len) {
            continue;
        }
        let kind = kinds[rng.below(kinds.len())];
        let inj = Injection { start, len, kind };
        inject(t, inj, seed.wrapping_add(placed.len() as u64));
        placed.push(inj);
    }
    placed.sort_by_key(|p| p.start);
    placed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_walk::random_walk;

    #[test]
    fn hit_overlap_logic() {
        let inj = Injection { start: 100, len: 20, kind: InjectionKind::Flatline };
        assert!(inj.hit(90, 15)); // overlaps start
        assert!(inj.hit(110, 5)); // inside
        assert!(!inj.hit(120, 10)); // starts at end
        assert!(!inj.hit(80, 20)); // ends at start
    }

    #[test]
    fn flatline_flattens() {
        let mut t = random_walk(500, 1);
        inject(&mut t, Injection { start: 100, len: 30, kind: InjectionKind::Flatline }, 9);
        let w = &t.values[100..130];
        assert!(w.iter().all(|&v| v == w[0]));
    }

    #[test]
    fn spike_train_changes_window() {
        let mut t = random_walk(500, 2);
        let before = t.values[200..220].to_vec();
        inject(&mut t, Injection { start: 200, len: 20, kind: InjectionKind::SpikeTrain }, 9);
        let diff: f64 = t.values[200..220]
            .iter()
            .zip(&before)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1.0);
        // Outside untouched.
        assert_eq!(t.values[199], random_walk(500, 2).values[199]);
    }

    #[test]
    fn random_injections_dont_overlap() {
        let mut t = random_walk(5000, 3);
        let placed = inject_random(&mut t, 5, 50, &[InjectionKind::SpikeTrain], 7);
        assert_eq!(placed.len(), 5);
        for w in placed.windows(2) {
            assert!(w[0].start + w[0].len <= w[1].start);
        }
    }

    #[test]
    fn reversal_preserves_values() {
        let mut t = random_walk(300, 4);
        let mut before = t.values[50..90].to_vec();
        inject(&mut t, Injection { start: 50, len: 40, kind: InjectionKind::Reversal }, 9);
        before.reverse();
        assert_eq!(&t.values[50..90], &before[..]);
    }
}
