//! Synthetic dataset generators — surrogates for the paper's Tab. 1.
//!
//! The paper evaluates on non-redistributable traces (NASA shuttle valve
//! current, PhysioNet ECGs, Koski-ECG, respiration, Dutch power demand,
//! PolyTER heating sensors).  None are fetchable in this offline
//! environment, so each generator synthesizes a series with the same
//! length, sampling character, and anomaly structure; the injectors
//! additionally plant *ground-truth* anomalies at known positions — which
//! real traces cannot provide — so the example programs can check that
//! discovered discords hit the planted regions (accuracy, not just speed).
//!
//! Every generator is deterministic in its `u64` seed (see
//! [`crate::util::rng::Rng`]); EXPERIMENTS.md records the seeds used.
#![forbid(unsafe_code)]

pub mod ecg;
pub mod heating;
pub mod inject;
pub mod power;
pub mod random_walk;
pub mod registry;
pub mod respiration;
pub mod shuttle;

pub use inject::{Injection, InjectionKind};
pub use registry::{dataset, dataset_names, DatasetSpec};
