//! Synthetic office power demand — surrogate for the Dutch research
//! center's 1997 consumption trace (Tab. 1, 15-min sampling, strong
//! daily + weekly structure; anomalies are holidays/outages where a
//! workday looks like a weekend).

use crate::core::series::TimeSeries;
use crate::util::rng::Rng;

/// Samples per day at 15-minute resolution.
pub const SAMPLES_PER_DAY: usize = 96;

/// Generate `days` days of 15-min power demand.  `holiday_days` lists
/// weekday indices that behave like weekends (the planted anomalies).
pub fn power_demand(days: usize, holiday_days: &[usize], seed: u64) -> TimeSeries {
    let mut rng = Rng::seed(seed);
    let n = days * SAMPLES_PER_DAY;
    let mut values = Vec::with_capacity(n);
    for day in 0..days {
        let weekday = day % 7; // 0..4 workdays, 5..6 weekend
        let is_work = weekday < 5 && !holiday_days.contains(&day);
        let day_amp = if is_work { 1.0 + 0.05 * rng.normal() } else { 0.25 + 0.03 * rng.normal() };
        for s in 0..SAMPLES_PER_DAY {
            let hour = s as f64 * 24.0 / SAMPLES_PER_DAY as f64;
            // Occupancy curve: ramp 7-9h, plateau, lunch dip, ramp-down 17-19h.
            let occ = smoothstep(hour, 7.0, 9.0) * (1.0 - 0.25 * gauss(hour, 12.5, 0.7))
                * (1.0 - smoothstep(hour, 17.0, 19.5));
            let base = 20.0; // kW baseline (HVAC, servers)
            let load = base + 80.0 * day_amp * occ;
            values.push(load + 1.5 * rng.normal());
        }
    }
    TimeSeries::new(format!("power_{days}d"), values)
}

fn smoothstep(x: f64, lo: f64, hi: f64) -> f64 {
    let t = ((x - lo) / (hi - lo)).clamp(0.0, 1.0);
    t * t * (3.0 - 2.0 * t)
}

fn gauss(x: f64, c: f64, s: f64) -> f64 {
    let d = (x - c) / s;
    (-0.5 * d * d).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weekly_structure() {
        let t = power_demand(14, &[], 1);
        assert_eq!(t.len(), 14 * SAMPLES_PER_DAY);
        let day_mean = |d: usize| {
            t.values[d * SAMPLES_PER_DAY..(d + 1) * SAMPLES_PER_DAY].iter().sum::<f64>()
                / SAMPLES_PER_DAY as f64
        };
        // Workday (Mon=0) well above weekend (Sat=5).
        assert!(day_mean(0) > 1.4 * day_mean(5), "{} vs {}", day_mean(0), day_mean(5));
    }

    #[test]
    fn holiday_looks_like_weekend() {
        let t = power_demand(14, &[2], 2);
        let day_mean = |d: usize| {
            t.values[d * SAMPLES_PER_DAY..(d + 1) * SAMPLES_PER_DAY].iter().sum::<f64>()
                / SAMPLES_PER_DAY as f64
        };
        assert!(day_mean(2) < 0.6 * day_mean(1));
    }

    #[test]
    fn deterministic() {
        assert_eq!(power_demand(3, &[], 9).values, power_demand(3, &[], 9).values);
    }
}
