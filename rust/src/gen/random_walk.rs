//! Random-walk series (Pearson 1905), the paper's synthetic workload
//! (RandomWalk1M / RandomWalk2M, Tab. 1).

use crate::core::series::TimeSeries;
use crate::util::rng::Rng;

/// Standard Gaussian random walk of length `n`.
pub fn random_walk(n: usize, seed: u64) -> TimeSeries {
    let mut rng = Rng::seed(seed);
    let mut acc = 0.0;
    let values = (0..n)
        .map(|_| {
            acc += rng.normal();
            acc
        })
        .collect();
    TimeSeries::new(format!("random_walk_{n}"), values)
}

/// Random walk with one planted "jitter burst" anomaly of length `len` at
/// `at`: the walk's steps become heavy-tailed there, producing a window
/// shape far from every other window.
pub fn random_walk_with_anomaly(n: usize, at: usize, len: usize, seed: u64) -> TimeSeries {
    assert!(at + len <= n);
    let mut rng = Rng::seed(seed);
    let mut acc = 0.0;
    let values = (0..n)
        .map(|i| {
            let step = if (at..at + len).contains(&i) {
                // Alternating large steps: a saw-tooth burst.
                if i % 2 == 0 {
                    3.0 + rng.normal().abs()
                } else {
                    -(3.0 + rng.normal().abs())
                }
            } else {
                rng.normal()
            };
            acc += step;
            acc
        })
        .collect();
    TimeSeries::new(format!("random_walk_anom_{n}"), values)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sized() {
        let a = random_walk(1000, 5);
        let b = random_walk(1000, 5);
        assert_eq!(a.values, b.values);
        assert_eq!(a.len(), 1000);
        let c = random_walk(1000, 6);
        assert_ne!(a.values, c.values);
    }

    #[test]
    fn walk_is_cumulative() {
        let t = random_walk(10_000, 7);
        // A random walk wanders: the range should be much wider than one
        // step's scale.
        let (lo, hi) = t.min_max();
        assert!(hi - lo > 10.0);
    }

    #[test]
    fn anomaly_region_has_larger_steps() {
        let t = random_walk_with_anomaly(2000, 1000, 50, 8);
        let step_mag = |r: std::ops::Range<usize>| {
            r.map(|i| (t.values[i + 1] - t.values[i]).abs()).sum::<f64>() / 50.0
        };
        assert!(step_mag(1000..1050) > 2.0 * step_mag(100..150));
    }
}
