//! Dataset registry: the Tab. 1 roster by name, with the paper's lengths
//! and discord lengths, backed by the synthetic surrogate generators.
//!
//! `dataset("ecg")` returns the surrogate series plus the experiment
//! parameters (n, discord length) that Tab. 1 prescribes, so the benches
//! and examples can iterate the roster exactly as the paper does.

use anyhow::{bail, Result};

use super::{ecg, heating, power, random_walk, respiration, shuttle};
use crate::core::series::TimeSeries;

/// One Tab. 1 row.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Series length per Tab. 1.
    pub n: usize,
    /// Discord length per Tab. 1 (minL = maxL in the comparison runs).
    pub m: usize,
    pub domain: &'static str,
    pub series: TimeSeries,
}

/// Names in Tab. 1 order.
pub fn dataset_names() -> &'static [&'static str] {
    &[
        "space_shuttle",
        "ecg",
        "ecg2",
        "koski_ecg",
        "respiration",
        "power_demand",
        "random_walk_1m",
        "random_walk_2m",
    ]
}

/// Build a Tab. 1 surrogate by name (deterministic in `seed`).
pub fn dataset(name: &str, seed: u64) -> Result<DatasetSpec> {
    let spec = match name {
        // 50k samples of valve cycles; paper's discord length 150.
        "space_shuttle" => {
            let t = shuttle::shuttle_valve(250, 200, &[137], seed);
            DatasetSpec { name: "space_shuttle", n: 50_000, m: 150, domain: "NASA valve current", series: t }
        }
        // 45k ECG at 180 Hz-ish; discord length 200.
        "ecg" => {
            let t = ecg::ecg_with_pvc(45_000, 180.0, 72.0, &[210], seed);
            DatasetSpec { name: "ecg", n: 45_000, m: 200, domain: "electrocardiogram", series: t }
        }
        // 21.6k ECG; discord length 400 (slower sampling relative to beat).
        "ecg2" => {
            let t = ecg::ecg_with_pvc(21_600, 360.0, 68.0, &[25], seed);
            DatasetSpec { name: "ecg2", n: 21_600, m: 400, domain: "electrocardiogram", series: t }
        }
        // 100k Koski ECG; discord length 458.
        "koski_ecg" => {
            let t = ecg::ecg_with_pvc(100_000, 400.0, 65.0, &[95], seed);
            DatasetSpec { name: "koski_ecg", n: 100_000, m: 458, domain: "electrocardiogram", series: t }
        }
        // 24 125 respiration samples; discord length 250.
        "respiration" => {
            let mut t = respiration::respiration(24_125, 10.0, 14_000, seed);
            t.name = "respiration".into();
            DatasetSpec { name: "respiration", n: 24_125, m: 250, domain: "breathing (thorax)", series: t }
        }
        // 33 220 power samples (346 days); discord length 750.
        "power_demand" => {
            let days = 347;
            let mut t = power::power_demand(days, &[100, 242], seed);
            t.values.truncate(33_220);
            t.name = "power_demand".into();
            DatasetSpec { name: "power_demand", n: 33_220, m: 750, domain: "office energy", series: t }
        }
        "random_walk_1m" => {
            let t = random_walk::random_walk(1_000_000, seed);
            DatasetSpec { name: "random_walk_1m", n: 1_000_000, m: 512, domain: "synthetic", series: t }
        }
        "random_walk_2m" => {
            let t = random_walk::random_walk(2_000_000, seed);
            DatasetSpec { name: "random_walk_2m", n: 2_000_000, m: 512, domain: "synthetic", series: t }
        }
        "heating" => {
            let (t, _) = heating::heating_year(seed);
            DatasetSpec { name: "heating", n: 35_040, m: 48, domain: "smart heating (PolyTER)", series: t }
        }
        other => bail!("unknown dataset {other:?}; known: {:?}", dataset_names()),
    };
    Ok(spec)
}

/// Like [`dataset`] but truncated/scaled to `n` samples (scalability runs).
pub fn dataset_prefix(name: &str, n: usize, seed: u64) -> Result<DatasetSpec> {
    let mut spec = dataset(name, seed)?;
    if n < spec.series.len() {
        spec.series = spec.series.prefix(n);
    }
    spec.n = spec.series.len();
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_matches_tab1_lengths() {
        // Keep the big random walks out of the unit-test path.
        for (name, n, m) in [
            ("space_shuttle", 50_000, 150),
            ("ecg", 45_000, 200),
            ("ecg2", 21_600, 400),
            ("respiration", 24_125, 250),
            ("power_demand", 33_220, 750),
        ] {
            let d = dataset(name, 1).unwrap();
            assert_eq!(d.series.len(), n, "{name}");
            assert_eq!(d.m, m, "{name}");
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(dataset("nope", 1).is_err());
    }

    #[test]
    fn prefix_truncates() {
        let d = dataset_prefix("ecg2", 5_000, 1).unwrap();
        assert_eq!(d.series.len(), 5_000);
    }
}
