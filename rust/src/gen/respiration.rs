//! Synthetic respiration (thorax-extension) trace — surrogate for the
//! Keogh HOTSAX respiration dataset ("a patient awakes"): slow
//! quasi-sinusoidal breathing whose rate/depth shifts at a planted
//! transition, which is where the real trace's discord lives.

use crate::core::series::TimeSeries;
use crate::util::rng::Rng;

/// `n` samples at `fs` Hz; breathing transitions from deep-sleep
/// (slow, deep) to awake (faster, shallower, irregular) at sample
/// `wake_at` (pass `n` for no transition).
pub fn respiration(n: usize, fs: f64, wake_at: usize, seed: u64) -> TimeSeries {
    let mut rng = Rng::seed(seed);
    let mut values = Vec::with_capacity(n);
    let mut phase = 0.0f64;
    let mut rate = 0.22; // Hz, deep sleep
    let mut depth = 1.0;
    for i in 0..n {
        let awake = i >= wake_at;
        // Smooth parameter drift toward the regime's target.
        let (target_rate, target_depth) = if awake { (0.42, 0.45) } else { (0.22, 1.0) };
        rate += 0.002 * (target_rate - rate) + 0.0003 * rng.normal();
        depth += 0.002 * (target_depth - depth) + 0.0008 * rng.normal();
        // Awake breathing is irregular: phase jitter.
        let jitter = if awake { 0.15 } else { 0.03 };
        phase += 2.0 * std::f64::consts::PI * rate / fs * (1.0 + jitter * rng.normal());
        let v = depth * phase.sin() + 0.02 * rng.normal();
        values.push(v);
    }
    TimeSeries::new(format!("respiration_{n}"), values)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regimes_differ() {
        let fs = 10.0;
        let t = respiration(24_000, fs, 12_000, 3);
        let amp = |r: std::ops::Range<usize>| {
            let s = &t.values[r];
            let lo = s.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            hi - lo
        };
        // Sleep amplitude clearly larger than awake.
        assert!(amp(2000..6000) > 1.5 * amp(18_000..22_000));
    }

    #[test]
    fn deterministic_and_bounded() {
        let a = respiration(5000, 10.0, 5000, 4);
        assert_eq!(a.values, respiration(5000, 10.0, 5000, 4).values);
        let (lo, hi) = a.min_max();
        assert!(lo > -2.0 && hi < 2.0);
    }
}
