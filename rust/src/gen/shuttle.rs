//! Synthetic Marotta valve solenoid-current trace — surrogate for the
//! NASA "Space shuttle" dataset (Tab. 1): repeated energize/de-energize
//! cycles whose current waveform has a charging rise, inductive knee, hold
//! plateau and decay; the classic anomaly is a cycle with a deformed knee
//! (degraded valve).

use crate::core::series::TimeSeries;
use crate::util::rng::Rng;

/// One valve cycle of `len` samples into `out`, with waveform deformation
/// `defect` in [0, 1] (0 = healthy).
fn cycle(out: &mut [f64], defect: f64, rng: &mut Rng) {
    let len = out.len();
    let on = (len as f64 * 0.55) as usize;
    let rise = (len as f64 * 0.08).max(2.0) as usize;
    for (k, o) in out.iter_mut().enumerate() {
        let v = if k < rise {
            // Charging rise toward peak with an inductive overshoot knee.
            let x = k as f64 / rise as f64;
            1.3 * x - 0.3 * x * x
        } else if k < on {
            // Knee dip then hold plateau; the defect flattens/shifts the knee.
            let x = (k - rise) as f64 / (on - rise) as f64;
            let knee_depth = 0.25 * (1.0 - defect);
            let knee_pos = 0.25 + 0.35 * defect;
            let d = (x - knee_pos) / 0.08;
            1.0 - knee_depth * (-0.5 * d * d).exp()
        } else {
            // De-energized decay.
            let x = (k - on) as f64 / (len - on) as f64;
            (1.0 - x).powi(3) * 0.2
        };
        *o = v + 0.01 * rng.normal();
    }
}

/// `cycles` valve actuations of ~`cycle_len` samples; `defect_cycles`
/// lists cycle indices with a degraded waveform.
pub fn shuttle_valve(cycles: usize, cycle_len: usize, defect_cycles: &[usize], seed: u64) -> TimeSeries {
    let mut rng = Rng::seed(seed);
    let mut values = vec![0.0; cycles * cycle_len];
    for c in 0..cycles {
        let defect = if defect_cycles.contains(&c) { 0.9 } else { 0.03 * rng.uniform() };
        let s = c * cycle_len;
        cycle(&mut values[s..s + cycle_len], defect, &mut rng);
    }
    TimeSeries::new(format!("shuttle_{}", cycles * cycle_len), values)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_repeat() {
        let t = shuttle_valve(10, 200, &[], 1);
        assert_eq!(t.len(), 2000);
        // Two healthy cycles should be near-identical.
        let d: f64 = (0..200)
            .map(|k| (t.values[200 + k] - t.values[400 + k]).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(d < 1.0, "healthy cycles differ too much: {d}");
    }

    #[test]
    fn defect_cycle_differs() {
        let t = shuttle_valve(10, 200, &[5], 2);
        let dist = |a: usize, b: usize| -> f64 {
            (0..200).map(|k| (t.values[a + k] - t.values[b + k]).powi(2)).sum::<f64>().sqrt()
        };
        let healthy = dist(200, 400);
        let defect = dist(1000, 400);
        assert!(defect > 3.0 * healthy, "defect {defect} vs healthy {healthy}");
    }

    #[test]
    fn deterministic() {
        assert_eq!(
            shuttle_valve(5, 100, &[2], 3).values,
            shuttle_valve(5, 100, &[2], 3).values
        );
    }
}
