//! # PALMAD — Parallel Arbitrary-Length MERLIN-based Anomaly Discovery
//!
//! Reproduction of Zymbler & Kraeva, *"High-performance Time Series Anomaly
//! Discovery on Graphics Processors"* (2023) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **Layer 1** (`python/compile/kernels/`): Pallas distance-tile and
//!   recurrent-statistics kernels, AOT-lowered to HLO text.
//! - **Layer 2** (`python/compile/model.py`): JAX graphs wrapping the
//!   kernels (window materialization, Eq. 6 distance transform, exclusion
//!   masking, reductions).
//! - **Layer 3** (this crate): the coordinator — MERLIN's adaptive-`r`
//!   driver ([`coordinator::merlin`]), the parallel two-phase DRAG
//!   ([`coordinator::drag`]), segment scheduling, engines (pure-rust
//!   [`engines::native`] and PJRT-backed [`engines::xla`]), baseline
//!   algorithms, generators, benchmarking and analysis tooling.
//!
//! Python runs only at build time (`make artifacts`); the binary serves
//! requests from compiled HLO artifacts via the PJRT C API.

// Unsafe-code discipline (CONCURRENCY.md): every `unsafe` operation
// must sit in an explicit `unsafe { .. }` block even inside `unsafe fn`,
// and entire module trees opt out of unsafe wholesale via
// `#![forbid(unsafe_code)]` — the only modules allowed to contain any
// are `util::pool` and `engines::{native, scratch}` (enforced both here
// and by `palmad-lint`'s SAFETY-comment rule).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod core;
pub mod engines;
pub mod gen;
pub mod runtime;
pub mod testkit;
pub mod util;

pub use crate::coordinator::drag::Discord;
pub use crate::coordinator::merlin::{Merlin, MerlinConfig, MerlinResult, MerlinSweep, SweepStatus};
pub use crate::core::series::TimeSeries;
