//! `palmad` — the command-line front end.
//!
//! ```text
//! palmad run      --data ecg --min-l 64 --max-l 128 --top-k 3
//! palmad heatmap  --data heating --min-l 48 --max-l 672 --out heatmap.ppm
//! palmad serve    --addr 127.0.0.1:7700 --workers 4
//! palmad generate --data power_demand --out power.txt
//! palmad datasets
//! ```

#![forbid(unsafe_code)]

use anyhow::Result;

use palmad::analysis::{heatmap::Heatmap, image, ranking, report::Table};
use palmad::coordinator::config::{build_engine, EngineChoice, EngineOptions};
use palmad::coordinator::merlin::{Merlin, MerlinConfig, StatsBackend};
use palmad::coordinator::service::{Service, ServiceConfig};
use palmad::core::series::TimeSeries;
use palmad::gen::registry;
use palmad::util::cli::{Cli, Command};

fn cli() -> Cli {
    Cli::new("palmad", "parallel arbitrary-length MERLIN-based anomaly discovery")
        .command(
            Command::new("run", "discover discords in a series")
                .req("data", "dataset name (see `datasets`) or a file path (.txt/.csv/.f64)")
                .opt("n", "0", "truncate/generate to this length (0 = dataset default)")
                .opt("seed", "42", "generator seed")
                .opt("min-l", "64", "minimum discord length")
                .opt("max-l", "128", "maximum discord length")
                .opt("top-k", "1", "discords per length (0 = all)")
                .opt("engine", "native", "tile engine: native | xla")
                .opt("segn", "256", "tile edge (XLA: a compiled bucket)")
                .opt("threads", "0", "native engine threads (0 = auto)")
                .opt("kernel", "", "native tile kernel: auto | lanes8 | lanes4 | lanes4f32 | scalar (default: $PALMAD_TILE_KERNEL or auto)")
                .opt("stats", "native", "stats backend: native | aot | naive")
                .opt("json", "", "write results as JSON to this path")
                .opt("checkpoint-dir", "", "save resumable sweep checkpoints here")
                .opt("checkpoint-every", "4", "checkpoint every K completed lengths")
                .switch("resume", "resume from the checkpoint in --checkpoint-dir")
                .switch("verbose", "debug logging"),
        )
        .command(
            Command::new("heatmap", "discord heatmap + top interesting discords (case study)")
                .req("data", "dataset name or file path")
                .opt("n", "0", "truncate to this length")
                .opt("seed", "42", "generator seed")
                .opt("min-l", "48", "minimum discord length")
                .opt("max-l", "672", "maximum discord length")
                .opt("stride", "1", "length stride (speeds up wide ranges)")
                .opt("engine", "native", "tile engine: native | xla")
                .opt("segn", "256", "tile edge")
                .opt("kernel", "", "native tile kernel: auto | lanes8 | lanes4 | lanes4f32 | scalar")
                .opt("top", "6", "interesting discords to report (Eq. 12)")
                .opt("out", "heatmap.ppm", "output heatmap image (PPM)"),
        )
        .command(
            Command::new("serve", "run the TCP job service (step scheduler)")
                .opt("addr", "127.0.0.1:7700", "listen address (port 0 = ephemeral)")
                .opt("workers", "2", "step-worker threads")
                .opt("pool", "0", "engine lease pool capacity (0 = one per worker)")
                .opt("ttl-secs", "600", "terminal-job retention before TTL eviction")
                .opt("engine", "native", "tile engine: native | xla")
                .opt("segn", "256", "tile edge")
                .opt("kernel", "", "native tile kernel: auto | lanes8 | lanes4 | lanes4f32 | scalar")
                .opt("checkpoint-dir", "", "job checkpoint dir (enables RESUME + auto-resume)")
                .opt("checkpoint-every", "4", "checkpoint every K completed lengths")
                .opt("policy", "wfq", "scheduling policy: wfq (weighted fair) | rr (flat FIFO)")
                .opt("default-weight", "1", "weight for jobs that name no tenant/weight")
                .opt("max-queued", "1024", "run-queue bound before ERR BUSY (0 = unbounded)")
                .opt("max-conns", "1024", "open-connection bound before ERR BUSY (0 = unbounded)")
                .opt("batch-max", "4", "max jobs stepped per engine lease round (1 = off)"),
        )
        .command(
            Command::new("generate", "write a synthetic dataset to a file")
                .req("data", "dataset name")
                .opt("n", "0", "truncate to this length")
                .opt("seed", "42", "generator seed")
                .req("out", "output path (.txt or .f64)"),
        )
        .command(Command::new("datasets", "list the Tab. 1 dataset roster"))
}

fn load_series(data: &str, n: usize, seed: u64) -> Result<TimeSeries> {
    if data.contains('/') || data.contains('.') {
        let p = std::path::Path::new(data);
        let t = match p.extension().and_then(|e| e.to_str()) {
            Some("f64") => TimeSeries::from_f64_binary(p)?,
            Some("csv") => TimeSeries::from_csv(p, 1)?,
            _ => TimeSeries::from_text(p)?,
        };
        Ok(if n > 0 { t.prefix(n) } else { t })
    } else if n > 0 {
        Ok(registry::dataset_prefix(data, n, seed)?.series)
    } else {
        Ok(registry::dataset(data, seed)?.series)
    }
}

fn engine_opts(args: &palmad::util::cli::Args) -> Result<EngineOptions> {
    let mut opts = EngineOptions {
        choice: EngineChoice::parse(args.get("engine")?)?,
        segn: args.get_usize("segn")?,
        ..Default::default()
    };
    if let Ok(t) = args.get_usize("threads") {
        if t > 0 {
            opts.threads = t;
        }
    }
    if let Some(k) = args.get_opt("kernel") {
        opts.kernel = palmad::engines::TileKernel::parse(k)?;
    }
    Ok(opts)
}

fn cmd_run(args: &palmad::util::cli::Args) -> Result<()> {
    if args.get_switch("verbose") {
        palmad::util::logger::set_level(palmad::util::logger::Level::Debug);
    }
    let series = load_series(args.get("data")?, args.get_usize("n")?, args.get_u64("seed")?)?;
    let opts = engine_opts(args)?;
    let engine = build_engine(&opts)?;
    let stats_backend = match args.get("stats")? {
        "native" => StatsBackend::Native,
        "aot" => StatsBackend::Aot,
        "naive" => StatsBackend::NaivePerLength,
        other => anyhow::bail!("unknown stats backend {other:?}"),
    };
    let cfg = MerlinConfig {
        min_l: args.get_usize("min-l")?,
        max_l: args.get_usize("max-l")?,
        top_k: args.get_usize("top-k")?,
        stats_backend,
        ..Default::default()
    };
    println!("series: {series}; engine: {} (segn={})", engine.name(), engine.segn());
    let res = match args.get_opt("checkpoint-dir") {
        Some(dir) => run_checkpointed(
            &*engine,
            cfg,
            &series,
            (args.get("data")?, args.get_u64("seed")?),
            dir,
            args.get_u64("checkpoint-every")?,
            args.get_switch("resume"),
        )?,
        None => Merlin::new(&*engine, cfg).run(&series)?,
    };

    let mut table = Table::new(
        format!("discords of {}", series.name),
        &["m", "idx", "nnDist", "nnDist/2sqrt(m)", "r_used", "retries"],
    );
    for lr in &res.lengths {
        for d in &lr.discords {
            table.row(&[
                d.m.to_string(),
                d.idx.to_string(),
                format!("{:.4}", d.nn_dist),
                format!("{:.4}", d.nn_dist / (2.0 * (d.m as f64).sqrt())),
                format!("{:.4}", lr.r_used),
                lr.retries.to_string(),
            ]);
        }
    }
    print!("{}", table.to_text());
    println!("metrics: {}", res.metrics);

    if let Some(path) = args.get_opt("json") {
        std::fs::write(path, table.to_json().to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// A crash-safe `run`: drive the sweep step by step, durably
/// checkpointing every `every` completed lengths under `job-0.ckpt` in
/// `dir`; with `resume`, pick up from that checkpoint (bit-identical to
/// the uninterrupted run — the engine's QT seed-cache rows travel in
/// the checkpoint).  The checkpoint is removed once the sweep finishes.
#[allow(clippy::too_many_arguments)]
fn run_checkpointed(
    engine: &dyn palmad::engines::Engine,
    cfg: MerlinConfig,
    series: &TimeSeries,
    (data, seed): (&str, u64),
    dir: &str,
    every: u64,
    resume: bool,
) -> Result<palmad::coordinator::merlin::MerlinResult> {
    use palmad::coordinator::checkpoint::{CheckpointStore, JobCheckpoint};
    use palmad::coordinator::merlin::{MerlinSweep, SweepStatus};
    use palmad::coordinator::workspace::MerlinWorkspace;

    // The CLI runs one sweep at a time; it always occupies slot 0.
    const CLI_JOB: u64 = 0;
    let store = CheckpointStore::new(dir)?;
    let mut sweep = if resume {
        let ckpt = store.load(CLI_JOB)?;
        if ckpt.n != Some(series.len() as u64) {
            anyhow::bail!(
                "checkpoint in {dir} was taken on a {}-point series; got {} points \
                 (same --data/--n/--seed required to resume)",
                ckpt.n.unwrap_or(0),
                series.len()
            );
        }
        let sweep = MerlinSweep::restore(&ckpt.sweep)?;
        let rearmed = engine.import_seed_rows(&series.values, &ckpt.seed_rows);
        let (done, total) = sweep.progress();
        println!("resuming at {done}/{total} lengths ({rearmed} seed rows re-armed)");
        sweep
    } else {
        MerlinSweep::new(cfg, series.len())?
    };
    let every = every.max(1);
    let mut ws = MerlinWorkspace::new();
    loop {
        match sweep.step(engine, &series.values, &mut ws)? {
            SweepStatus::Done => break,
            SweepStatus::Pending => {
                if sweep.progress().0 as u64 % every == 0 {
                    store.save(&JobCheckpoint {
                        job_id: CLI_JOB,
                        dataset: data.to_string(),
                        n: Some(series.len() as u64),
                        seed,
                        min_l: sweep.config().min_l as u64,
                        max_l: sweep.config().max_l as u64,
                        top_k: sweep.config().top_k as u64,
                        deadline_ms: None,
                        series: None,
                        sweep: sweep.snapshot(),
                        seed_rows: engine.export_seed_rows(&series.values),
                        // The CLI is single-tenant; resume maps these
                        // to the service defaults anyway.
                        tenant: String::new(),
                        weight: 0,
                    })?;
                }
            }
        }
    }
    if let Err(e) = store.remove(CLI_JOB) {
        eprintln!("warn: could not remove checkpoint: {e}");
    }
    Ok(sweep.finish())
}

fn cmd_heatmap(args: &palmad::util::cli::Args) -> Result<()> {
    let series = load_series(args.get("data")?, args.get_usize("n")?, args.get_u64("seed")?)?;
    let opts = engine_opts(args)?;
    let engine = build_engine(&opts)?;
    let (min_l, max_l) = (args.get_usize("min-l")?, args.get_usize("max-l")?);
    let stride = args.get_usize("stride")?.max(1);
    println!("heatmap over {series}, lengths {min_l}..{max_l} stride {stride}");

    // Wide ranges are run in strided sub-ranges (collect-all per length).
    let mut all_lengths = Vec::new();
    let mut m = min_l;
    while m <= max_l {
        let cfg = MerlinConfig { min_l: m, max_l: m, top_k: 0, ..Default::default() };
        let res = Merlin::new(&*engine, cfg).run(&series)?;
        all_lengths.extend(res.lengths);
        m += stride;
    }
    let res = palmad::coordinator::merlin::MerlinResult {
        lengths: all_lengths,
        metrics: Default::default(),
    };

    let hm = Heatmap::from_result(&res, series.len());
    let out = args.get("out")?;
    image::render_heatmap(&hm, out, 1600, 400)?;
    println!("wrote {out}");

    let top = ranking::top_k_interesting(&hm, args.get_usize("top")?);
    let mut table = Table::new("top interesting discords (Eq. 12)", &["rank", "idx", "m", "score"]);
    for (k, r) in top.iter().enumerate() {
        table.row(&[
            (k + 1).to_string(),
            r.idx.to_string(),
            r.m.to_string(),
            format!("{:.4}", r.score),
        ]);
    }
    print!("{}", table.to_text());
    Ok(())
}

fn cmd_serve(args: &palmad::util::cli::Args) -> Result<()> {
    let policy = match args.get("policy")? {
        "wfq" => palmad::coordinator::queue::SchedPolicy::WeightedFair,
        "rr" => palmad::coordinator::queue::SchedPolicy::RoundRobin,
        other => anyhow::bail!("unknown --policy {other:?} (expected wfq | rr)"),
    };
    let cfg = ServiceConfig {
        engine_opts: engine_opts(args)?,
        workers: args.get_usize("workers")?,
        pool_capacity: args.get_usize("pool")?,
        job_ttl: std::time::Duration::from_secs(args.get_u64("ttl-secs")?),
        checkpoint_dir: args.get_opt("checkpoint-dir").map(Into::into),
        checkpoint_every: args.get_u64("checkpoint-every")?,
        sched_policy: policy,
        default_tenant_weight: args.get_u64("default-weight")? as u32,
        max_queued: args.get_usize("max-queued")?,
        max_conns: args.get_usize("max-conns")?,
        batch_max: args.get_usize("batch-max")?,
        ..Default::default()
    };
    let svc = Service::start_with(cfg)?;
    svc.serve(args.get("addr")?)
}

fn cmd_generate(args: &palmad::util::cli::Args) -> Result<()> {
    let series = load_series(args.get("data")?, args.get_usize("n")?, args.get_u64("seed")?)?;
    let out = args.get("out")?;
    if out.ends_with(".f64") {
        series.to_f64_binary(out)?;
    } else {
        series.to_text(out)?;
    }
    println!("wrote {} samples to {out}", series.len());
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    let mut table = Table::new("Tab. 1 dataset roster (synthetic surrogates)", &["name", "n", "discord m", "domain"]);
    for name in registry::dataset_names() {
        // Big walks are expensive to generate just for listing; use specs.
        let (n, m, domain) = match *name {
            "space_shuttle" => (50_000, 150, "NASA valve current"),
            "ecg" => (45_000, 200, "electrocardiogram"),
            "ecg2" => (21_600, 400, "electrocardiogram"),
            "koski_ecg" => (100_000, 458, "electrocardiogram"),
            "respiration" => (24_125, 250, "breathing (thorax)"),
            "power_demand" => (33_220, 750, "office energy"),
            "random_walk_1m" => (1_000_000, 512, "synthetic"),
            "random_walk_2m" => (2_000_000, 512, "synthetic"),
            _ => unreachable!(),
        };
        table.row(&[name.to_string(), n.to_string(), m.to_string(), domain.to_string()]);
    }
    table.row(&["heating".into(), "35040".into(), "48..672".into(), "smart heating (PolyTER, §5)".into()]);
    print!("{}", table.to_text());
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let (cmd, args) = match cli.parse(&argv) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    match cmd {
        "run" => cmd_run(&args),
        "heatmap" => cmd_heatmap(&args),
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "datasets" => cmd_datasets(),
        _ => unreachable!(),
    }
}
