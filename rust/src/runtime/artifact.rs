//! Artifact manifest: what `make artifacts` compiled and where.
//!
//! The manifest is line-oriented (`kind segn mmax nmax file`) so no JSON
//! parser is needed on the rust side.  Shape selection picks the smallest
//! compiled bucket that fits a request; the coordinator then masks/pads up
//! to the bucket's shape.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::types::TileShape;

/// Parsed manifest + artifact directory.
#[derive(Clone, Debug, Default)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    /// (segn, mmax) -> file name.
    pub tiles: BTreeMap<TileShape, String>,
    /// nmax -> file name.
    pub stats_init: BTreeMap<usize, String>,
    pub stats_update: BTreeMap<usize, String>,
}

impl ArtifactSet {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("read {} (run `make artifacts`)", manifest.display()))?;
        let mut set = ArtifactSet { dir, ..Default::default() };
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 5 {
                bail!("manifest:{}: expected 5 fields, got {}", lineno + 1, f.len());
            }
            let segn: usize = f[1].parse().context("segn")?;
            let mmax: usize = f[2].parse().context("mmax")?;
            let nmax: usize = f[3].parse().context("nmax")?;
            let file = f[4].to_string();
            match f[0] {
                "tile" => {
                    set.tiles.insert(TileShape { segn, mmax }, file);
                }
                "stats_init" => {
                    set.stats_init.insert(nmax, file);
                }
                "stats_update" => {
                    set.stats_update.insert(nmax, file);
                }
                other => bail!("manifest:{}: unknown kind {other:?}", lineno + 1),
            }
        }
        Ok(set)
    }

    /// Default artifact directory: `$PALMAD_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("PALMAD_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Pick a tile shape: prefers `segn` exactly equal to the request (the
    /// coordinator's segment size is itself chosen from the compiled grid)
    /// and the smallest `mmax >= m`.
    pub fn select_tile(&self, segn: usize, m: usize) -> Result<TileShape> {
        let mut best: Option<TileShape> = None;
        for shape in self.tiles.keys() {
            if shape.segn == segn && shape.mmax >= m {
                match best {
                    Some(b) if b.mmax <= shape.mmax => {}
                    _ => best = Some(*shape),
                }
            }
        }
        best.ok_or_else(|| {
            anyhow::anyhow!(
                "no tile artifact with segn={segn}, mmax>={m}; compiled: {:?}",
                self.tiles.keys().collect::<Vec<_>>()
            )
        })
    }

    /// All compiled segment sizes (ascending).
    pub fn tile_segns(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.tiles.keys().map(|s| s.segn).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Largest compiled MMAX for a given segn.
    pub fn max_m_for_segn(&self, segn: usize) -> Option<usize> {
        self.tiles.keys().filter(|s| s.segn == segn).map(|s| s.mmax).max()
    }

    /// Pick the smallest stats bucket >= n.
    pub fn select_stats(&self, n: usize) -> Result<usize> {
        self.stats_init
            .keys()
            .copied()
            .find(|&nmax| nmax >= n && self.stats_update.contains_key(&nmax))
            .ok_or_else(|| anyhow::anyhow!("no stats artifact bucket >= {n}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(lines: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("palmad_manifest_{}", lines.len()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        f.write_all(lines.as_bytes()).unwrap();
        dir
    }

    #[test]
    fn parse_and_select() {
        let dir = write_manifest(
            "# kind segn mmax nmax file\n\
             tile 64 128 0 tile_64x128.hlo.txt\n\
             tile 64 512 0 tile_64x512.hlo.txt\n\
             tile 256 512 0 tile_256x512.hlo.txt\n\
             stats_init 0 0 16384 si.hlo.txt\n\
             stats_update 0 0 16384 su.hlo.txt\n\
             stats_init 0 0 65536 si2.hlo.txt\n\
             stats_update 0 0 65536 su2.hlo.txt\n",
        );
        let set = ArtifactSet::load(&dir).unwrap();
        assert_eq!(set.select_tile(64, 100).unwrap(), TileShape { segn: 64, mmax: 128 });
        assert_eq!(set.select_tile(64, 200).unwrap(), TileShape { segn: 64, mmax: 512 });
        assert!(set.select_tile(64, 600).is_err());
        assert!(set.select_tile(128, 100).is_err());
        assert_eq!(set.select_stats(10_000).unwrap(), 16384);
        assert_eq!(set.select_stats(20_000).unwrap(), 65536);
        assert!(set.select_stats(100_000).is_err());
        assert_eq!(set.tile_segns(), vec![64, 256]);
        assert_eq!(set.max_m_for_segn(64), Some(512));
    }

    #[test]
    fn rejects_malformed() {
        let dir = write_manifest("tile 64 128 tile.hlo.txt\n");
        assert!(ArtifactSet::load(&dir).is_err());
        let dir = write_manifest("blob 1 2 3 f\n");
        assert!(ArtifactSet::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_is_error() {
        assert!(ArtifactSet::load("/nonexistent_dir_palmad").is_err());
    }
}
