//! The kernel executor: an actor thread owning the PJRT client.
//!
//! The `xla` crate's types wrap raw C pointers and are not `Send`, so one
//! dedicated thread owns the `PjRtClient` and every compiled executable;
//! the rest of the system talks to it through typed channel requests.
//! Executables are compiled lazily from HLO text on first use and cached
//! for the lifetime of the executor (MERLIN's length sweep reuses one
//! tile executable for every `m <= MMAX` — no recompiles).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{anyhow, Context, Result};

use super::artifact::ArtifactSet;
use super::types::{TileInputs, TileOutputs, TileShape};

enum Request {
    TileBatch {
        shape: TileShape,
        inputs: Vec<TileInputs>,
        reply: Sender<Result<Vec<TileOutputs>>>,
    },
    StatsInit {
        nmax: usize,
        t: Vec<f32>,
        m: i32,
        reply: Sender<Result<(Vec<f64>, Vec<f64>)>>,
    },
    StatsUpdate {
        nmax: usize,
        t: Vec<f32>,
        mu: Vec<f64>,
        sig: Vec<f64>,
        m: i32,
        reply: Sender<Result<(Vec<f64>, Vec<f64>)>>,
    },
    Shutdown,
}

/// Handle to the executor actor.  Clonable; dropping the last handle shuts
/// the actor down.
pub struct Executor {
    tx: Sender<Request>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Executor {
    /// Start the actor for a given artifact set.
    pub fn start(artifacts: ArtifactSet) -> Result<Self> {
        let (tx, rx) = channel();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("palmad-xla-executor".into())
            .spawn(move || actor_main(artifacts, rx, ready_tx))
            .context("spawn executor thread")?;
        ready_rx.recv().context("executor startup")??;
        Ok(Self { tx, handle: Some(handle) })
    }

    /// Execute a batch of tile tasks against the `shape` executable.
    pub fn tile_batch(&self, shape: TileShape, inputs: Vec<TileInputs>) -> Result<Vec<TileOutputs>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::TileBatch { shape, inputs, reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Run the AOT `stats_init` kernel (Eq. 4).  `t` must be padded to `nmax`.
    pub fn stats_init(&self, nmax: usize, t: Vec<f32>, m: i32) -> Result<(Vec<f64>, Vec<f64>)> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::StatsInit { nmax, t, m, reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Run the AOT `stats_update` kernel (Eqs. 7/8).
    pub fn stats_update(
        &self,
        nmax: usize,
        t: Vec<f32>,
        mu: Vec<f64>,
        sig: Vec<f64>,
        m: i32,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::StatsUpdate { nmax, t, mu, sig, m, reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        // ok-drop: send fails only if the actor already exited — the state
        // shutdown is driving toward.
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            // ok-drop: join error = actor panicked; callers already saw the
            // broken channel as an `executor gone` error, and Drop must not
            // unwind.
            let _ = h.join();
        }
    }
}

/// State owned by the actor thread.
struct Actor {
    artifacts: ArtifactSet,
    client: xla::PjRtClient,
    tiles: HashMap<TileShape, xla::PjRtLoadedExecutable>,
    stats_init: HashMap<usize, xla::PjRtLoadedExecutable>,
    stats_update: HashMap<usize, xla::PjRtLoadedExecutable>,
}

fn actor_main(artifacts: ArtifactSet, rx: Receiver<Request>, ready: Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // ok-drop: a dropped `ready` receiver means the constructor
            // already gave up on this actor; nobody is left to notify.
            let _ = ready.send(Err(anyhow!("PjRtClient::cpu: {e}")));
            return;
        }
    };
    // ok-drop: same as the error arm — receiver gone means nobody waits.
    let _ = ready.send(Ok(()));
    let mut actor = Actor {
        artifacts,
        client,
        tiles: HashMap::new(),
        stats_init: HashMap::new(),
        stats_update: HashMap::new(),
    };
    while let Ok(req) = rx.recv() {
        match req {
            Request::TileBatch { shape, inputs, reply } => {
                // ok-drop: reply-channel sends (all three arms) fail only
                // when the requester stopped waiting; the actor just moves
                // on to the next request.
                let _ = reply.send(actor.run_tile_batch(shape, inputs));
            }
            Request::StatsInit { nmax, t, m, reply } => {
                // ok-drop: requester gone (see above).
                let _ = reply.send(actor.run_stats_init(nmax, t, m));
            }
            Request::StatsUpdate { nmax, t, mu, sig, m, reply } => {
                // ok-drop: requester gone (see above).
                let _ = reply.send(actor.run_stats_update(nmax, t, mu, sig, m));
            }
            Request::Shutdown => break,
        }
    }
}

fn compile(client: &xla::PjRtClient, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compile {}: {e}", path.display()))
}

impl Actor {
    fn tile_exe(&mut self, shape: TileShape) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.tiles.contains_key(&shape) {
            let file = self
                .artifacts
                .tiles
                .get(&shape)
                .ok_or_else(|| anyhow!("no tile artifact for {shape:?}"))?;
            let exe = compile(&self.client, &self.artifacts.path_of(file))?;
            self.tiles.insert(shape, exe);
        }
        Ok(&self.tiles[&shape])
    }

    fn run_tile_batch(
        &mut self,
        shape: TileShape,
        inputs: Vec<TileInputs>,
    ) -> Result<Vec<TileOutputs>> {
        self.tile_exe(shape)?;
        let exe = &self.tiles[&shape];
        let mut out = Vec::with_capacity(inputs.len());
        for inp in &inputs {
            out.push(run_tile_one(exe, shape, inp)?);
        }
        Ok(out)
    }

    fn run_stats_init(&mut self, nmax: usize, t: Vec<f32>, m: i32) -> Result<(Vec<f64>, Vec<f64>)> {
        if !self.stats_init.contains_key(&nmax) {
            let file = self
                .artifacts
                .stats_init
                .get(&nmax)
                .ok_or_else(|| anyhow!("no stats_init artifact for nmax={nmax}"))?;
            let exe = compile(&self.client, &self.artifacts.path_of(file))?;
            self.stats_init.insert(nmax, exe);
        }
        anyhow::ensure!(t.len() == nmax, "stats_init: t must be padded to {nmax}");
        let exe = &self.stats_init[&nmax];
        let args = vec![xla::Literal::vec1(&t), xla::Literal::scalar(m)];
        let mut tup = execute_tuple(exe, &args)?;
        let sig = tup.pop().expect("stats_init kernel returns (mu, sig)").to_vec::<f64>()?;
        let mu = tup.pop().expect("stats_init kernel returns (mu, sig)").to_vec::<f64>()?;
        Ok((mu, sig))
    }

    fn run_stats_update(
        &mut self,
        nmax: usize,
        t: Vec<f32>,
        mu: Vec<f64>,
        sig: Vec<f64>,
        m: i32,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        if !self.stats_update.contains_key(&nmax) {
            let file = self
                .artifacts
                .stats_update
                .get(&nmax)
                .ok_or_else(|| anyhow!("no stats_update artifact for nmax={nmax}"))?;
            let exe = compile(&self.client, &self.artifacts.path_of(file))?;
            self.stats_update.insert(nmax, exe);
        }
        anyhow::ensure!(
            t.len() == nmax && mu.len() == nmax && sig.len() == nmax,
            "stats_update: buffers must be padded to {nmax}"
        );
        let exe = &self.stats_update[&nmax];
        let args = vec![
            xla::Literal::vec1(&t),
            xla::Literal::vec1(&mu),
            xla::Literal::vec1(&sig),
            xla::Literal::scalar(m),
        ];
        let mut tup = execute_tuple(exe, &args)?;
        let sig2 = tup.pop().expect("stats_update kernel returns (mu, sig)").to_vec::<f64>()?;
        let mu2 = tup.pop().expect("stats_update kernel returns (mu, sig)").to_vec::<f64>()?;
        Ok((mu2, sig2))
    }
}

/// Execute and unpack the (return_tuple=True) result literal.
fn execute_tuple(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    let bufs = exe.execute::<xla::Literal>(args).map_err(|e| anyhow!("execute: {e}"))?;
    let lit = bufs[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e}"))?;
    lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e}"))
}

fn run_tile_one(
    exe: &xla::PjRtLoadedExecutable,
    shape: TileShape,
    inp: &TileInputs,
) -> Result<TileOutputs> {
    let src_len = shape.src_len();
    anyhow::ensure!(
        inp.seg_src.len() == src_len && inp.chunk_src.len() == src_len,
        "tile src slices must be {src_len} (got {}, {})",
        inp.seg_src.len(),
        inp.chunk_src.len()
    );
    anyhow::ensure!(
        inp.mu_a.len() == shape.segn
            && inp.sig_a.len() == shape.segn
            && inp.mu_b.len() == shape.segn
            && inp.sig_b.len() == shape.segn,
        "tile stats slices must be {}",
        shape.segn
    );
    let args = vec![
        xla::Literal::vec1(&inp.seg_src),
        xla::Literal::vec1(&inp.chunk_src),
        xla::Literal::vec1(&inp.mu_a),
        xla::Literal::vec1(&inp.sig_a),
        xla::Literal::vec1(&inp.mu_b),
        xla::Literal::vec1(&inp.sig_b),
        xla::Literal::scalar(inp.m),
        xla::Literal::scalar(inp.delta),
        xla::Literal::scalar(inp.na),
        xla::Literal::scalar(inp.nb),
        xla::Literal::scalar(inp.r2),
    ];
    let mut tup = execute_tuple(exe, &args)?;
    anyhow::ensure!(tup.len() == 4, "tile kernel returned {} outputs", tup.len());
    let col_kill = tup.pop().expect("tile tuple arity checked above").to_vec::<f32>()?;
    let row_kill = tup.pop().expect("tile tuple arity checked above").to_vec::<f32>()?;
    let col_min = tup.pop().expect("tile tuple arity checked above").to_vec::<f32>()?;
    let row_min = tup.pop().expect("tile tuple arity checked above").to_vec::<f32>()?;
    Ok(TileOutputs {
        row_min: row_min.iter().map(|&x| x as f64).collect(),
        col_min: col_min.iter().map(|&x| x as f64).collect(),
        row_kill: row_kill.iter().map(|&x| x != 0.0).collect(),
        col_kill: col_kill.iter().map(|&x| x != 0.0).collect(),
    })
}
