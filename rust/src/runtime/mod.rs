//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py`) and executes them from the rust request path.
//!
//! - [`artifact`] — manifest parsing + shape-bucket selection.
//! - [`executor`] — a dedicated actor thread owning the (non-`Send`)
//!   `PjRtClient` and compiled executables; callers talk to it through
//!   typed channel requests.
//! - [`types`] — plain-old-data request/response structs shared with the
//!   engines.
#![forbid(unsafe_code)]

pub mod artifact;
pub mod executor;
pub mod types;

/// Probe whether a PJRT client can actually be constructed in this build.
///
/// `false` when the workspace is built against the offline `xla` stub
/// (vendor/xla) or when no PJRT plugin is loadable.  AOT-dependent tests
/// and benches gate on this (plus artifact presence) so `cargo test -q`
/// is green in every environment.
pub fn pjrt_runtime_available() -> bool {
    std::panic::catch_unwind(|| xla::PjRtClient::cpu().is_ok()).unwrap_or(false)
}
