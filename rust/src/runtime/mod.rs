//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py`) and executes them from the rust request path.
//!
//! - [`artifact`] — manifest parsing + shape-bucket selection.
//! - [`executor`] — a dedicated actor thread owning the (non-`Send`)
//!   `PjRtClient` and compiled executables; callers talk to it through
//!   typed channel requests.
//! - [`types`] — plain-old-data request/response structs shared with the
//!   engines.

pub mod artifact;
pub mod executor;
pub mod types;
