//! Plain-old-data request/response types for the kernel executor and the
//! engines.  Mirrors the layer-2 signatures in `python/compile/model.py`.

/// Inputs for one `tile_min` invocation (one (segment, chunk) pair).
///
/// Slice/stat buffers are `f32` — the tile-kernel interchange dtype; the
/// coordinator keeps its master copies in `f64` and downcasts per task.
#[derive(Clone, Debug)]
pub struct TileInputs {
    /// Raw series slice starting at the segment's first subsequence,
    /// length `SEGN + MMAX - 1`, zero-padded past the series end.
    pub seg_src: Vec<f32>,
    /// Same for the chunk.
    pub chunk_src: Vec<f32>,
    /// Window stats for the segment rows / chunk columns, length `SEGN`,
    /// padded with (mu=0, sig=1).
    pub mu_a: Vec<f32>,
    pub sig_a: Vec<f32>,
    pub mu_b: Vec<f32>,
    pub sig_b: Vec<f32>,
    /// Live subsequence length (`m <= MMAX`).
    pub m: i32,
    /// `chunk_global_start - seg_global_start` (may be negative in the
    /// refinement phase's left scan).
    pub delta: i32,
    /// Valid window counts in segment / chunk (`<= SEGN`).
    pub na: i32,
    pub nb: i32,
    /// Squared range-discord threshold.
    pub r2: f32,
}

/// Outputs of one `tile_min` invocation.
///
/// `row_*` refer to segment subsequences, `col_*` to chunk subsequences.
/// Invalid/excluded entries are `+inf` minima and `false` kills.
/// Minima are `f64` at the coordinator boundary; the XLA engine upcasts
/// the kernel's `f32` results.
#[derive(Clone, Debug, Default)]
pub struct TileOutputs {
    pub row_min: Vec<f64>,
    pub col_min: Vec<f64>,
    pub row_kill: Vec<bool>,
    pub col_kill: Vec<bool>,
}

impl TileOutputs {
    /// Fresh output block for tile edge `segn`, initialized to the
    /// neutral values (`+inf` minima, no kills).
    pub fn sized(segn: usize) -> Self {
        Self {
            row_min: vec![f64::INFINITY; segn],
            col_min: vec![f64::INFINITY; segn],
            row_kill: vec![false; segn],
            col_kill: vec![false; segn],
        }
    }

    /// Reinitialize in place for tile edge `segn`.
    ///
    /// This is the buffer-recycling hook of the zero-allocation tile
    /// pipeline: once the four vectors have reached `segn` capacity,
    /// `reset` never touches the allocator again (`clear` + `resize`
    /// reuse the existing storage).
    pub fn reset(&mut self, segn: usize) {
        self.row_min.clear();
        self.row_min.resize(segn, f64::INFINITY);
        self.col_min.clear();
        self.col_min.resize(segn, f64::INFINITY);
        self.row_kill.clear();
        self.row_kill.resize(segn, false);
        self.col_kill.clear();
        self.col_kill.resize(segn, false);
    }
}

/// Shape key of a tile artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TileShape {
    pub segn: usize,
    pub mmax: usize,
}

impl TileShape {
    /// Length of the raw source slice (`tile_src_len` in shapes.py).
    pub fn src_len(&self) -> usize {
        self.segn + self.mmax - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn src_len_matches_python() {
        assert_eq!(TileShape { segn: 64, mmax: 128 }.src_len(), 191);
        assert_eq!(TileShape { segn: 512, mmax: 512 }.src_len(), 1023);
    }

    #[test]
    fn tile_outputs_reset_recycles_storage() {
        let mut o = TileOutputs::sized(8);
        o.row_min[3] = 1.5;
        o.col_kill[7] = true;
        let ptr = o.row_min.as_ptr();
        o.reset(8);
        assert!(o.row_min.iter().all(|x| x.is_infinite()));
        assert!(o.col_kill.iter().all(|&k| !k));
        assert_eq!(o.row_min.as_ptr(), ptr, "reset must not reallocate");
        // Shrinking reuses storage too.
        o.reset(4);
        assert_eq!(o.row_min.len(), 4);
        assert_eq!(o.row_min.as_ptr(), ptr);
    }
}
