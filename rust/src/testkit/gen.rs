//! Random-input generators for properties: series shapes that exercise
//! the distance/stat code differently (walks, noise, periodic, flat
//! plateaus, large offsets).

use crate::util::rng::Rng;

/// Series generator kinds for property tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeriesGen {
    Walk,
    Noise,
    Periodic,
    /// Walk with a flat plateau (stuck sensor) somewhere inside.
    WithPlateau,
    /// Noise around a huge offset (cancellation stress).
    LargeOffset,
}

impl SeriesGen {
    pub const ALL: [SeriesGen; 5] = [
        SeriesGen::Walk,
        SeriesGen::Noise,
        SeriesGen::Periodic,
        SeriesGen::WithPlateau,
        SeriesGen::LargeOffset,
    ];

    /// Pick a random kind.
    pub fn random(rng: &mut Rng) -> SeriesGen {
        Self::ALL[rng.below(Self::ALL.len())]
    }

    /// Generate `n` samples.
    pub fn generate(self, n: usize, rng: &mut Rng) -> Vec<f64> {
        match self {
            SeriesGen::Walk => {
                let mut acc = 0.0;
                (0..n)
                    .map(|_| {
                        acc += rng.normal();
                        acc
                    })
                    .collect()
            }
            SeriesGen::Noise => (0..n).map(|_| rng.normal()).collect(),
            SeriesGen::Periodic => {
                let freq = rng.range(0.05, 0.5);
                let noise = rng.range(0.0, 0.2);
                (0..n).map(|i| (i as f64 * freq).sin() + noise * rng.normal()).collect()
            }
            SeriesGen::WithPlateau => {
                let mut acc = 0.0;
                let mut v: Vec<f64> = (0..n)
                    .map(|_| {
                        acc += rng.normal();
                        acc
                    })
                    .collect();
                if n >= 8 {
                    let len = rng.int_in(n / 8, n / 2);
                    let start = rng.below(n - len);
                    let val = v[start];
                    for x in &mut v[start..start + len] {
                        *x = val;
                    }
                }
                v
            }
            SeriesGen::LargeOffset => {
                let off = rng.range(1e4, 1e6);
                (0..n).map(|_| off + rng.normal() * rng.range(0.1, 10.0)).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kinds_generate_requested_length() {
        let mut rng = Rng::seed(1);
        for kind in SeriesGen::ALL {
            let v = kind.generate(100, &mut rng);
            assert_eq!(v.len(), 100, "{kind:?}");
            assert!(v.iter().all(|x| x.is_finite()), "{kind:?}");
        }
    }

    #[test]
    fn plateau_exists() {
        let mut rng = Rng::seed(2);
        let v = SeriesGen::WithPlateau.generate(200, &mut rng);
        // Find at least 10 consecutive equal values.
        let mut run = 1;
        let mut best = 1;
        for w in v.windows(2) {
            if w[0] == w[1] {
                run += 1;
                best = best.max(run);
            } else {
                run = 1;
            }
        }
        assert!(best >= 10, "longest run {best}");
    }

    #[test]
    fn large_offset_is_large() {
        let mut rng = Rng::seed(3);
        let v = SeriesGen::LargeOffset.generate(50, &mut rng);
        assert!(v[0].abs() > 1e3);
    }
}
