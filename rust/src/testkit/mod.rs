//! Property-based testing mini-framework (proptest replacement).
//!
//! A property runs against `cases` deterministically-seeded random inputs;
//! on failure the framework reports the failing case number and seed so
//! the case reproduces with `PALMAD_PROP_SEED=<seed> cargo test <name>`.
#![forbid(unsafe_code)]

pub mod gen;
pub mod prop;

pub use gen::SeriesGen;
pub use prop::{check, Config};
