//! The property runner.

use crate::util::rng::Rng;

/// Property-run configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: u64,
    /// Base seed; each case `k` runs with seed `base ^ k`-derived stream.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 32, base_seed: 0x9E3779B97F4A7C15 }
    }
}

/// Run `property(case_rng)` for each case; the closure returns
/// `Err(message)` to fail.  Panics (like proptest) with a reproduction
/// seed on the first failure.
///
/// If `PALMAD_PROP_SEED` is set, only that single seed is run — the
/// reproduction path.
pub fn check<F>(name: &str, cfg: Config, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    if let Ok(s) = std::env::var("PALMAD_PROP_SEED") {
        let seed: u64 = s.parse().expect("PALMAD_PROP_SEED must be a u64");
        let mut rng = Rng::seed(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property {name:?} failed under PALMAD_PROP_SEED={seed}: {msg}");
        }
        return;
    }
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_mul(case.wrapping_add(1)).wrapping_add(case);
        let mut rng = Rng::seed(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property {name:?} failed on case {case}/{} — reproduce with \
                 PALMAD_PROP_SEED={seed}: {msg}",
                cfg.cases
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("tautology", Config { cases: 10, ..Default::default() }, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("uniform out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "PALMAD_PROP_SEED=")]
    fn failing_property_reports_seed() {
        check("always-fails", Config { cases: 3, ..Default::default() }, |_| {
            Err("nope".into())
        });
    }

    #[test]
    fn cases_get_distinct_streams() {
        let mut first_draws = Vec::new();
        check("collect", Config { cases: 8, ..Default::default() }, |rng| {
            first_draws.push(rng.next_u64());
            Ok(())
        });
        let mut dedup = first_draws.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), first_draws.len());
    }
}
