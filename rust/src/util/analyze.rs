//! Hot-path dataflow analysis (the `palmad-analyze` binary's engine).
//!
//! Where `palmad-lint` (PR 7) is a line scanner, this module
//! reconstructs per-function scopes — brace-aware, over
//! comment/string-blanked code — and runs three passes over designated
//! modules (full annotation grammar in ANALYSIS.md):
//!
//! **P1 panic-freedom.**  In functions marked hot (a `// hot-path: <why>`
//! header comment the analyzer discovers in the contiguous comment block
//! above the signature), every implicit panic site must be justified by
//! a `// panic-free: <why>` note within [`PANIC_WINDOW`] lines:
//! slice/array indexing (exempt when the receiver is a fixed-extent
//! array bound in the same function), `unwrap`/`expect`, non-literal
//! `/` or `%`, the `assert!` family (`debug_assert!` is exempt — it is
//! compiled out of release kernels), and explicit `panic!`-family
//! macros.
//!
//! **P2 numeric determinism.**  In result-bearing modules (`core/`,
//! `engines/`, `coordinator/`): iterating a `HashMap`/`HashSet`-typed
//! binding needs a later `.sort*` in the same function or an
//! `// order: <why>` note; `mul_add` (contracts rounding), reductions
//! in pool-adjacent functions, and `as f32` narrowing casts each need
//! an `// order:` note.
//!
//! **P3 result discipline.**  Everywhere in `rust/src`: `let _ = ...`
//! and statement-position `....ok();` need an `// ok-drop: <why>`
//! reason within [`OKDROP_WINDOW`] lines — or the value handled.
//!
//! Cross-cutting: an annotation marker with no reason text after the
//! colon is rejected (`note-grammar`), and every file in [`HOT_FILES`]
//! must mark at least one function hot (`hot-coverage`), so deleting
//! markers cannot silently disarm P1.
//!
//! Like the lint, the analyzer is textual, not a parser: portability
//! into `scripts/analyze_invariants.py` (the toolchain-free mirror run
//! by CI when cargo is absent) is a design constraint.  Rules,
//! designated-file lists, windows, and the fixture suite must match the
//! python mirror exactly; the fixtures in this module's tests and in
//! the script's `--self-test` are the same inputs with the same
//! expected rule ids, keeping the two honest.
#![forbid(unsafe_code)]

use std::path::{Path, PathBuf};

use crate::util::lint::{has_comment, strip_rust, test_region_start};

/// Roots scanned relative to the repo root.  Narrower than the lint's
/// (library code only): P1–P3 are production-code discipline, and test
/// modules inside `rust/src` are already exempted per-file.
pub const SCAN_ROOTS: &[&str] = &["rust/src"];

/// Files that must mark at least one function with a hot-path header.
pub const HOT_FILES: &[&str] = &[
    "rust/src/core/distance.rs",
    "rust/src/core/stats.rs",
    "rust/src/engines/scratch.rs",
    "rust/src/util/pool.rs",
];

/// Module prefixes whose results feed `MerlinResult` / checkpoint
/// bytes; P2 runs only here.
pub const DETERMINISM_PREFIXES: &[&str] =
    &["rust/src/core/", "rust/src/engines/", "rust/src/coordinator/"];

/// How many lines above a panic site a `panic-free:` note may sit.
pub const PANIC_WINDOW: usize = 12;

/// How many lines above a P2 site an `order:` note may sit.
pub const ORDER_WINDOW: usize = 8;

/// How many lines above a dropped result an `ok-drop:` note may sit.
pub const OKDROP_WINDOW: usize = 4;

fn is_word(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

/// Next non-space/tab index at or after `i`.
fn skip_ws(b: &[u8], mut i: usize) -> usize {
    while i < b.len() && (b[i] == b' ' || b[i] == b'\t') {
        i += 1;
    }
    i
}

/// Length of the identifier run starting at `i` (0 if none).
fn ident_len(b: &[u8], i: usize) -> usize {
    if i >= b.len() || !is_ident_start(b[i]) {
        return 0;
    }
    let mut j = i + 1;
    while j < b.len() && is_word(b[j]) {
        j += 1;
    }
    j - i
}

/// The maximal identifier ending just before byte `end` (exclusive),
/// with any leading digits trimmed (an identifier cannot start with a
/// digit); `None` if empty after trimming.
fn ident_before(b: &[u8], end: usize) -> Option<(usize, usize)> {
    let mut start = end;
    while start > 0 && is_word(b[start - 1]) {
        start -= 1;
    }
    while start < end && b[start].is_ascii_digit() {
        start += 1;
    }
    if start < end {
        Some((start, end))
    } else {
        None
    }
}

/// True if `word` occurs at `i` with word boundaries on both sides.
fn word_at(b: &[u8], i: usize, word: &str) -> bool {
    let w = word.as_bytes();
    if i + w.len() > b.len() || &b[i..i + w.len()] != w {
        return false;
    }
    let before_ok = i == 0 || !is_word(b[i - 1]);
    let after_ok = i + w.len() >= b.len() || !is_word(b[i + w.len()]);
    before_ok && after_ok
}

/// All `(position_of_fn_keyword, name)` pairs on one code line.
fn fn_starts(line: &str) -> Vec<(usize, String)> {
    let b = line.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i + 1 < b.len() {
        if word_at(b, i, "fn") {
            let mut j = i + 2;
            let ws = skip_ws(b, j);
            if ws > j {
                j = ws;
                let len = ident_len(b, j);
                if len > 0 {
                    out.push((i, line[j..j + len].to_string()));
                    i = j + len;
                    continue;
                }
            }
        }
        i += 1;
    }
    out
}

/// Indexing sites on one line, in order: `Some(receiver)` for
/// `ident[..]`, `None` for `)[..]` / `][..]` chains.
fn index_hits(line: &str) -> Vec<Option<String>> {
    let b = line.as_bytes();
    let mut out = Vec::new();
    for j in 1..b.len() {
        if b[j] != b'[' {
            continue;
        }
        let prev = b[j - 1];
        if is_word(prev) {
            if let Some((s, e)) = ident_before(b, j) {
                out.push(Some(line[s..e].to_string()));
            }
        } else if prev == b')' || prev == b']' {
            out.push(None);
        }
    }
    out
}

/// `name: &[T; N]` / `name: &mut [T; N]` fixed-extent reference params.
fn fixed_param_bindings(line: &str, out: &mut std::collections::HashSet<String>) {
    let b = line.as_bytes();
    for colon in 0..b.len() {
        if b[colon] != b':' {
            continue;
        }
        // Identifier (with trailing ws allowed) before the colon.
        let mut e = colon;
        while e > 0 && (b[e - 1] == b' ' || b[e - 1] == b'\t') {
            e -= 1;
        }
        let Some((s, e)) = ident_before(b, e) else { continue };
        // `&`, optional `mut `, then `[ ... ; ... ]` with no nested
        // brackets (the textual signature of a fixed-extent array).
        let mut j = skip_ws(b, colon + 1);
        if j >= b.len() || b[j] != b'&' {
            continue;
        }
        j = skip_ws(b, j + 1);
        if word_at(b, j, "mut") {
            let k = skip_ws(b, j + 3);
            if k == j + 3 {
                continue; // `mut` must be followed by whitespace
            }
            j = k;
        }
        if j >= b.len() || b[j] != b'[' {
            continue;
        }
        j += 1;
        let mut semi = None;
        while j < b.len() {
            match b[j] {
                b';' => {
                    semi = Some(j);
                    break;
                }
                b'[' | b']' => break,
                _ => j += 1,
            }
        }
        let Some(semi) = semi else { continue };
        let mut k = semi + 1;
        let mut closed = false;
        while k < b.len() {
            match b[k] {
                b']' => {
                    closed = true;
                    break;
                }
                b'[' => break,
                _ => k += 1,
            }
        }
        if closed {
            out.insert(line[s..e].to_string());
        }
    }
}

/// `let x = [...]` / `let x: [T; N] = [...]` array-literal bindings.
fn fixed_let_bindings(line: &str, out: &mut std::collections::HashSet<String>) {
    let b = line.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if !word_at(b, i, "let") {
            i += 1;
            continue;
        }
        let mut j = skip_ws(b, i + 3);
        if j == i + 3 {
            i += 3;
            continue; // `let` must be followed by whitespace
        }
        if word_at(b, j, "mut") {
            let k = skip_ws(b, j + 3);
            if k == j + 3 {
                i = j;
                continue;
            }
            j = k;
        }
        let len = ident_len(b, j);
        if len == 0 {
            i = j;
            continue;
        }
        let (ns, ne) = (j, j + len);
        j = skip_ws(b, ne);
        // Optional `: [T; N]` annotation (no nested brackets).
        if j < b.len() && b[j] == b':' {
            j = skip_ws(b, j + 1);
            if j >= b.len() || b[j] != b'[' {
                i = ne;
                continue;
            }
            j += 1;
            let mut semi = false;
            while j < b.len() {
                match b[j] {
                    b';' => {
                        semi = true;
                        j += 1;
                        break;
                    }
                    b'[' | b']' => break,
                    _ => j += 1,
                }
            }
            if !semi {
                i = ne;
                continue;
            }
            let mut closed = false;
            while j < b.len() {
                match b[j] {
                    b']' => {
                        closed = true;
                        j += 1;
                        break;
                    }
                    b'[' => break,
                    _ => j += 1,
                }
            }
            if !closed {
                i = ne;
                continue;
            }
            j = skip_ws(b, j);
        }
        if j < b.len() && b[j] == b'=' {
            let j = skip_ws(b, j + 1);
            if j < b.len() && b[j] == b'[' {
                out.insert(line[ns..ne].to_string());
            }
        }
        i = ne;
    }
}

/// `.method(` with optional whitespace around the dot and name.
fn dot_call_hit(line: &str, names: &[&str], next: &[u8]) -> bool {
    let b = line.as_bytes();
    for dot in 0..b.len() {
        if b[dot] != b'.' {
            continue;
        }
        let j = skip_ws(b, dot + 1);
        for name in names {
            if word_at(b, j, name) {
                let k = skip_ws(b, j + name.len());
                if k < b.len() && next.contains(&b[k]) {
                    return true;
                }
            }
        }
    }
    false
}

fn unwrap_hit(line: &str) -> bool {
    dot_call_hit(line, &["unwrap", "expect"], b"(")
}

fn fma_hit(line: &str) -> bool {
    dot_call_hit(line, &["mul_add"], b"(")
}

fn reduce_hit(line: &str) -> bool {
    dot_call_hit(line, &["sum", "product", "fold"], b":(<")
}

fn assert_hit(line: &str) -> bool {
    let b = line.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if b[i..].starts_with(b"assert") && (i == 0 || !is_word(b[i - 1])) {
            let mut j = i + 6;
            if b[j..].starts_with(b"_eq") || b[j..].starts_with(b"_ne") {
                j += 3;
            }
            if j < b.len() && b[j] == b'!' {
                let k = skip_ws(b, j + 1);
                if k < b.len() && (b[k] == b'(' || b[k] == b'[') {
                    return true;
                }
            }
            i = j;
        } else {
            i += 1;
        }
    }
    false
}

fn panic_hit(line: &str) -> bool {
    let b = line.as_bytes();
    for name in ["panic", "unreachable", "todo", "unimplemented"] {
        let w = name.as_bytes();
        let mut i = 0;
        while i + w.len() < b.len() {
            if &b[i..i + w.len()] == w
                && (i == 0 || !is_word(b[i - 1]))
                && b[i + w.len()] == b'!'
            {
                return true;
            }
            i += 1;
        }
    }
    false
}

/// `(path::)*Hash{Map,Set}` at `i`, word-bounded on the right.
fn path_to_hash(b: &[u8], mut i: usize) -> bool {
    loop {
        if word_at(b, i, "HashMap") || word_at(b, i, "HashSet") {
            return true;
        }
        let len = ident_len(b, i);
        if len == 0 {
            return false;
        }
        if b[i + len..].starts_with(b"::") {
            i += len + 2;
        } else {
            return false;
        }
    }
}

/// Identifiers declared with a HashMap/HashSet type on one line
/// (params, struct fields, and `let` bindings with inferred-from-init
/// or annotated types).
fn hash_bindings_on_line(line: &str, out: &mut std::collections::HashSet<String>) {
    let b = line.as_bytes();
    // `name : [&][mut ] path::Hash{Map,Set}`
    for colon in 0..b.len() {
        if b[colon] != b':' {
            continue;
        }
        let mut e = colon;
        while e > 0 && (b[e - 1] == b' ' || b[e - 1] == b'\t') {
            e -= 1;
        }
        let Some((s, e)) = ident_before(b, e) else { continue };
        let mut j = skip_ws(b, colon + 1);
        if j < b.len() && b[j] == b'&' {
            j = skip_ws(b, j + 1);
        }
        if word_at(b, j, "mut") {
            let k = skip_ws(b, j + 3);
            if k > j + 3 {
                j = k;
            }
        }
        if path_to_hash(b, j) {
            out.insert(line[s..e].to_string());
        }
    }
    // `let [mut] name [: T] = path::Hash{Map,Set}...`
    let mut i = 0;
    while i < b.len() {
        if !word_at(b, i, "let") {
            i += 1;
            continue;
        }
        let mut j = skip_ws(b, i + 3);
        if j == i + 3 {
            i += 3;
            continue;
        }
        if word_at(b, j, "mut") {
            let k = skip_ws(b, j + 3);
            if k > j + 3 {
                j = k;
            }
        }
        let len = ident_len(b, j);
        if len == 0 {
            i = j;
            continue;
        }
        let (ns, ne) = (j, j + len);
        // Optional annotation: anything up to `=` with no `;`.
        let mut k = ne;
        let mut eq = None;
        while k < b.len() {
            match b[k] {
                b'=' => {
                    eq = Some(k);
                    break;
                }
                b';' => break,
                _ => k += 1,
            }
        }
        if let Some(eq) = eq {
            // Without an annotation only whitespace may separate the
            // name from `=`; with `:` anything short of `;` goes.
            let direct = skip_ws(b, ne) == eq;
            let annotated = skip_ws(b, ne) < b.len() && b[skip_ws(b, ne)] == b':';
            if (direct || annotated) && path_to_hash(b, skip_ws(b, eq + 1)) {
                out.insert(line[ns..ne].to_string());
            }
        }
        i = ne;
    }
}

/// Receivers of order-sensitive iteration calls (`.iter()`, `.drain()`,
/// …) on one line, in order.
fn hash_iter_receivers(line: &str) -> Vec<String> {
    const METHODS: &[&str] =
        &["iter", "iter_mut", "values", "values_mut", "keys", "drain", "retain", "into_iter"];
    let b = line.as_bytes();
    let mut out = Vec::new();
    for dot in 1..b.len() {
        if b[dot] != b'.' {
            continue;
        }
        let mut e = dot;
        while e > 0 && (b[e - 1] == b' ' || b[e - 1] == b'\t') {
            e -= 1;
        }
        let Some((s, e)) = ident_before(b, e) else { continue };
        let j = skip_ws(b, dot + 1);
        for m in METHODS {
            if word_at(b, j, m) {
                let k = skip_ws(b, j + m.len());
                if k < b.len() && b[k] == b'(' {
                    out.push(line[s..e].to_string());
                    break;
                }
            }
        }
    }
    out
}

/// The (possibly dotted) iteration target of the first `for … in` on
/// the line.
fn for_in_target(line: &str) -> Option<String> {
    let b = line.as_bytes();
    let mut i = 0;
    let for_at = loop {
        if i >= b.len() {
            return None;
        }
        if word_at(b, i, "for") {
            break i;
        }
        i += 1;
    };
    let mut j = for_at + 3;
    while j < b.len() {
        if word_at(b, j, "in") {
            let mut k = skip_ws(b, j + 2);
            if k == j + 2 {
                j += 1;
                continue; // `in` must be followed by whitespace
            }
            if k < b.len() && b[k] == b'&' {
                k += 1;
            }
            if word_at(b, k, "mut") {
                let n = skip_ws(b, k + 3);
                if n > k + 3 {
                    k = n;
                }
            }
            if k < b.len() && is_ident_start(b[k]) {
                let mut e = k + 1;
                while e < b.len() && (is_word(b[e]) || b[e] == b'.') {
                    e += 1;
                }
                return Some(line[k..e].to_string());
            }
            return None;
        }
        j += 1;
    }
    None
}

fn f32_cast_hit(line: &str) -> bool {
    let b = line.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if word_at(b, i, "as") {
            let j = skip_ws(b, i + 2);
            if j > i + 2 && word_at(b, j, "f32") {
                return true;
            }
        }
        i += 1;
    }
    false
}

fn let_drop_hit(line: &str) -> bool {
    let b = line.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if word_at(b, i, "let") {
            let j = skip_ws(b, i + 3);
            if j > i + 3 && j < b.len() && b[j] == b'_' && !is_word(*b.get(j + 1).unwrap_or(&b' '))
            {
                let k = skip_ws(b, j + 1);
                if k < b.len() && b[k] == b'=' {
                    return true;
                }
            }
        }
        i += 1;
    }
    false
}

fn sort_call_hit(line: &str) -> bool {
    const SUFFIXES: &[&str] =
        &["", "_unstable", "_by", "_by_key", "_unstable_by", "_unstable_by_key"];
    let b = line.as_bytes();
    for dot in 0..b.len() {
        if b[dot] != b'.' {
            continue;
        }
        let j = skip_ws(b, dot + 1);
        if !b[j..].starts_with(b"sort") {
            continue;
        }
        let len = ident_len(b, j);
        let name = &line[j..j + len];
        if let Some(sfx) = name.strip_prefix("sort") {
            if SUFFIXES.contains(&sfx) {
                let k = skip_ws(b, j + len);
                if k < b.len() && b[k] == b'(' {
                    return true;
                }
            }
        }
    }
    false
}

fn pool_hit(line: &str) -> bool {
    let b = line.as_bytes();
    (0..b.len()).any(|i| word_at(b, i, "pool") || word_at(b, i, "Pool"))
}

/// Annotation markers on a comment line whose reason text is empty.
fn empty_note_markers(comment: &str) -> Vec<&'static str> {
    let mut out = Vec::new();
    for marker in ["hot-path", "panic-free", "order", "ok-drop"] {
        let needle = format!("{marker}:");
        let mut from = 0;
        while let Some(pos) = comment[from..].find(&needle) {
            let after = from + pos + needle.len();
            if comment[after..].trim_start_matches([' ', '\t']).is_empty() {
                out.push(marker);
            }
            from = after;
        }
    }
    out
}

/// True if the `/` or `%` at byte `pos` cannot panic: float division
/// (float literal or f32/f64 suffix adjacent) or a nonzero
/// integer-literal divisor.
fn div_exempt(line: &str, pos: usize) -> bool {
    let left = line[..pos].trim_end();
    let lb = left.as_bytes();
    // `…digit.digits*` / `….digits+` / `…f32|f64` (word-bounded).
    let mut e = lb.len();
    while e > 0 && lb[e - 1].is_ascii_digit() {
        e -= 1;
    }
    if e > 0 && lb[e - 1] == b'.' && (e < lb.len() || (e > 1 && lb[e - 2].is_ascii_digit())) {
        // `.digits+` always passes; a trailing bare `1.` needs the
        // digit before the dot.
        return true;
    }
    for sfx in ["f32", "f64"] {
        if left.ends_with(sfx) {
            let at = lb.len() - 3;
            if at == 0 || !is_word(lb[at - 1]) {
                return true;
            }
        }
    }
    let right = line[pos + 1..].trim_start();
    let rb = right.as_bytes();
    if !rb.is_empty() {
        // `digits+.` / `.digits+` / `digits+[_]f32|f64` float literals.
        let mut d = 0;
        while d < rb.len() && rb[d].is_ascii_digit() {
            d += 1;
        }
        if d > 0 && d < rb.len() && rb[d] == b'.' {
            return true;
        }
        if rb[0] == b'.' && rb.len() > 1 && rb[1].is_ascii_digit() {
            return true;
        }
        if d > 0 {
            let f = if rb[d..].starts_with(b"_") { d + 1 } else { d };
            for sfx in [b"f32", b"f64"] {
                if rb[f..].starts_with(sfx)
                    && !rb.get(f + 3).copied().is_some_and(is_word)
                {
                    return true;
                }
            }
        }
        // Nonzero integer-literal divisor.
        if (b'1'..=b'9').contains(&rb[0]) {
            return true;
        }
    }
    false
}

/// One reconstructed function scope.
struct FnScope {
    name: String,
    /// Line index of the signature's `fn` keyword.
    header: usize,
    /// Line index of the matching closing brace.
    close: usize,
    hot: bool,
    /// Fixed-extent array bindings (indexing them cannot be
    /// out-of-bounds-by-variable in the way P1 polices).
    fixed: std::collections::HashSet<String>,
    /// Body mentions a pool (gates p2-float-reduce).
    pooled: bool,
}

/// Brace-aware scope reconstruction over blanked code lines.  Returns
/// the functions plus a per-line map to the innermost covering
/// function (`usize::MAX` when none).  A function spans its header
/// line through the line of its closing brace.
fn reconstruct_functions(code: &[String], comments: &[String]) -> (Vec<FnScope>, Vec<usize>) {
    let mut fns: Vec<FnScope> = Vec::new();
    let mut stack: Vec<usize> = Vec::new();
    let mut open_depths: Vec<i64> = Vec::new();
    let mut pending: Option<(String, usize)> = None;
    let mut pend_nest: i64 = 0;
    let mut depth: i64 = 0;
    for (i, line) in code.iter().enumerate() {
        let starts = fn_starts(line);
        let b = line.as_bytes();
        for (j, &c) in b.iter().enumerate() {
            if pending.is_none() {
                if let Some((_, name)) = starts.iter().find(|(p, _)| *p == j) {
                    pending = Some((name.clone(), i));
                    pend_nest = 0;
                }
            }
            if pending.is_some() && (c == b'(' || c == b'[') {
                pend_nest += 1;
            } else if pending.is_some() && (c == b')' || c == b']') {
                pend_nest -= 1;
            } else if c == b';' && pending.is_some() && pend_nest == 0 {
                pending = None; // trait declaration, no body
            } else if c == b'{' {
                if let Some((name, header)) = pending.take() {
                    fns.push(FnScope {
                        name,
                        header,
                        close: code.len().saturating_sub(1),
                        hot: false,
                        fixed: std::collections::HashSet::new(),
                        pooled: false,
                    });
                    stack.push(fns.len() - 1);
                    open_depths.push(depth);
                }
                depth += 1;
            } else if c == b'}' {
                depth -= 1;
                if let (Some(&top), Some(&od)) = (stack.last(), open_depths.last()) {
                    if od == depth {
                        fns[top].close = i;
                        stack.pop();
                        open_depths.pop();
                    }
                }
            }
        }
    }
    let mut line_fn = vec![usize::MAX; code.len()];
    for (idx, f) in fns.iter().enumerate() {
        // Later functions are inner: innermost wins.
        for slot in line_fn.iter_mut().take(f.close + 1).skip(f.header) {
            *slot = idx;
        }
    }
    for f in fns.iter_mut() {
        // Hot marker: trailing on the header line, or in the contiguous
        // comment/attribute block directly above it.
        if comments[f.header].contains("hot-path:") {
            f.hot = true;
        }
        let mut k = f.header;
        while k > 0 {
            k -= 1;
            let code_trim = code[k].trim();
            let has_code = !code_trim.is_empty() && !code_trim.starts_with("#[");
            let comment_blank = comments[k].trim().is_empty();
            if comment_blank && (has_code || code_trim.is_empty()) {
                break; // code line with no comment, or a blank line
            }
            if comments[k].contains("hot-path:") {
                f.hot = true;
            }
            if has_code {
                break; // trailing comment on a code line: last one taken
            }
        }
        for bl in code.iter().take(f.close + 1).skip(f.header) {
            fixed_param_bindings(bl, &mut f.fixed);
            fixed_let_bindings(bl, &mut f.fixed);
            if pool_hit(bl) {
                f.pooled = true;
            }
        }
    }
    (fns, line_fn)
}

/// Analyze one file; returns `path:line: [rule] message` strings.
pub fn scan_file(relpath: &str, text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let (code, comments) = strip_rust(text);
    let relpath = relpath.replace('\\', "/");
    let tests_at = test_region_start(&code);
    let (fns, line_fn) = reconstruct_functions(&code, &comments);
    let mut hashes = std::collections::HashSet::new();
    for line in code.iter().take(tests_at) {
        hash_bindings_on_line(line, &mut hashes);
    }
    let determinism = DETERMINISM_PREFIXES.iter().any(|p| relpath.starts_with(p));

    if HOT_FILES.contains(&relpath.as_str()) && !fns.iter().any(|f| f.hot && f.header < tests_at)
    {
        out.push(format!(
            "{relpath}:1: [hot-coverage] file is on the hot-path list but marks no \
             function with a `hot-path:` header"
        ));
    }

    for (i, line) in code.iter().enumerate() {
        let lineno = i + 1;
        if i >= tests_at {
            break;
        }

        for marker in empty_note_markers(&comments[i]) {
            out.push(format!(
                "{relpath}:{lineno}: [note-grammar] `{marker}:` marker with no reason text"
            ));
        }

        let f = if line_fn[i] == usize::MAX { None } else { Some(&fns[line_fn[i]]) };

        // --- P1: panic-freedom in hot functions -----------------------
        if let Some(f) = f.filter(|f| f.hot) {
            let pf = has_comment(&comments, i, PANIC_WINDOW, &["panic-free:"]);
            for recv in index_hits(line) {
                if let Some(name) = &recv {
                    if f.fixed.contains(name) {
                        continue;
                    }
                }
                if !pf {
                    let name = recv.as_deref().unwrap_or("?");
                    out.push(format!(
                        "{relpath}:{lineno}: [p1-index] indexing `{name}[..]` in hot fn \
                         `{}` without a fixed-extent binding or `// panic-free:` note",
                        f.name
                    ));
                }
                break; // one report per line
            }
            if unwrap_hit(line) && !pf {
                out.push(format!(
                    "{relpath}:{lineno}: [p1-unwrap] unwrap/expect in hot fn `{}` without \
                     a `// panic-free:` note",
                    f.name
                ));
            }
            for (pos, &c) in line.as_bytes().iter().enumerate() {
                if (c == b'/' || c == b'%') && !div_exempt(line, pos) && !pf {
                    out.push(format!(
                        "{relpath}:{lineno}: [p1-div] non-literal `/` or `%` in hot fn \
                         `{}` without a `// panic-free:` note",
                        f.name
                    ));
                    break;
                }
            }
            if assert_hit(line) && !pf {
                out.push(format!(
                    "{relpath}:{lineno}: [p1-assert] assert! in hot fn `{}` without a \
                     `// panic-free:` note (debug_assert! is exempt)",
                    f.name
                ));
            }
            if panic_hit(line) && !pf {
                out.push(format!(
                    "{relpath}:{lineno}: [p1-panic] explicit panic path in hot fn `{}` \
                     without a `// panic-free:` note",
                    f.name
                ));
            }
        }

        // --- P2: numeric determinism in result-bearing modules --------
        if determinism {
            if let Some(f) = f {
                let od = has_comment(&comments, i, ORDER_WINDOW, &["order:"]);
                let mut hit =
                    hash_iter_receivers(line).into_iter().find(|r| hashes.contains(r));
                if hit.is_none() {
                    if let Some(target) = for_in_target(line) {
                        let last =
                            target.rsplit('.').next().unwrap_or(target.as_str()).to_string();
                        if hashes.contains(&last) {
                            hit = Some(target);
                        }
                    }
                }
                if let Some(hit) = hit {
                    let sorts_later =
                        (i..=f.close).any(|j| sort_call_hit(&code[j]));
                    if !od && !sorts_later {
                        out.push(format!(
                            "{relpath}:{lineno}: [p2-hash-iter] iteration over \
                             hash-ordered `{hit}` in `{}` with no later sort and no \
                             `// order:` note",
                            f.name
                        ));
                    }
                }
                if fma_hit(line) && !od {
                    out.push(format!(
                        "{relpath}:{lineno}: [p2-fma] mul_add contracts rounding; needs \
                         an `// order:` note"
                    ));
                }
                if f.pooled && reduce_hit(line) && !od {
                    out.push(format!(
                        "{relpath}:{lineno}: [p2-float-reduce] reduction in pool-adjacent \
                         fn `{}` needs an `// order:` note",
                        f.name
                    ));
                }
                if f32_cast_hit(line) && !od {
                    out.push(format!(
                        "{relpath}:{lineno}: [p2-float-cast] `as f32` narrows; needs an \
                         `// order:` note"
                    ));
                }
            }
        }

        // --- P3: result discipline ------------------------------------
        let okd = has_comment(&comments, i, OKDROP_WINDOW, &["ok-drop:"]);
        if let_drop_hit(line) && !okd {
            out.push(format!(
                "{relpath}:{lineno}: [p3-let-drop] `let _ =` without an `// ok-drop:` \
                 reason (handle the value or justify the drop)"
            ));
        }
        let stripped = line.trim();
        if stripped.contains(".ok();")
            && !stripped.contains('=')
            && !stripped.contains("return")
            && !okd
        {
            out.push(format!(
                "{relpath}:{lineno}: [p3-ok-discard] statement-position `.ok();` without \
                 an `// ok-drop:` reason"
            ));
        }
    }
    out
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<String>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let text = std::fs::read_to_string(&path)?;
            out.extend(scan_file(&rel, &text));
        }
    }
    Ok(())
}

/// Analyze the repo rooted at `root`; returns all violations.
pub fn run(root: &Path) -> std::io::Result<Vec<String>> {
    let mut out = Vec::new();
    for scan_root in SCAN_ROOTS {
        let top = root.join(scan_root);
        if top.is_dir() {
            walk(&top, root, &mut out)?;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(relpath: &str, text: &str) -> Vec<String> {
        scan_file(relpath, text)
            .iter()
            .map(|v| v.split('[').nth(1).unwrap().split(']').next().unwrap().to_string())
            .collect()
    }

    /// The shared fixture suite: identical inputs and expected rule ids
    /// to `scripts/analyze_invariants.py --self-test`.  Grow both or
    /// neither.
    const HOT: &str = "// hot-path: fixture kernel.\n";

    fn fixtures() -> Vec<(&'static str, String, Vec<&'static str>)> {
        vec![
            // P1: the seeded violation — an unguarded index in a hot fn.
            (
                "rust/src/core/x.rs",
                format!("{HOT}fn f(t: &[f64], i: usize) -> f64 {{ t[i] }}\n"),
                vec!["p1-index"],
            ),
            (
                "rust/src/core/x.rs",
                format!(
                    "{HOT}fn f(t: &[f64], i: usize) -> f64 {{\n    \
                     // panic-free: caller guarantees i < t.len().\n    t[i]\n}}\n"
                ),
                vec![],
            ),
            ("rust/src/core/x.rs", format!("{HOT}fn f(c: &mut [f64; 4]) {{ c[0] = 1.0; }}\n"), vec![]),
            (
                "rust/src/core/x.rs",
                format!("{HOT}fn f() -> f64 {{\n    let acc = [0.0f64; 4];\n    acc[3]\n}}\n"),
                vec![],
            ),
            // P1 applies only to hot-marked functions.
            ("rust/src/core/x.rs", "fn f(t: &[f64], i: usize) -> f64 { t[i] }\n".into(), vec![]),
            (
                "rust/src/core/x.rs",
                format!("{HOT}fn f(r: Option<u8>) -> u8 {{ r.unwrap() }}\n"),
                vec!["p1-unwrap"],
            ),
            (
                "rust/src/core/x.rs",
                format!(
                    "{HOT}fn f(r: Option<u8>) -> u8 {{\n    \
                     // panic-free: seeded by caller, always Some.\n    r.expect(\"seeded\")\n}}\n"
                ),
                vec![],
            ),
            (
                "rust/src/core/x.rs",
                format!("{HOT}fn f(a: u64, b: u64) -> u64 {{ a / b }}\n"),
                vec!["p1-div"],
            ),
            ("rust/src/core/x.rs", format!("{HOT}fn f(a: usize) -> usize {{ a / 4 }}\n"), vec![]),
            ("rust/src/core/x.rs", format!("{HOT}fn f(s: f64) -> f64 {{ 1.0 / s }}\n"), vec![]),
            (
                "rust/src/core/x.rs",
                format!("{HOT}fn f(m: usize) {{ assert!(m >= 2); }}\n"),
                vec!["p1-assert"],
            ),
            (
                "rust/src/core/x.rs",
                format!("{HOT}fn f(m: usize) {{ debug_assert!(m >= 2); }}\n"),
                vec![],
            ),
            (
                "rust/src/core/x.rs",
                format!("{HOT}fn f() {{ panic!(\"boom\"); }}\n"),
                vec!["p1-panic"],
            ),
            // note-grammar: a marker with no reason text is rejected.
            ("rust/src/core/x.rs", "// hot-path:\nfn f() {}\n".into(), vec!["note-grammar"]),
            // hot-coverage: hot-listed files must mark a function.
            ("rust/src/core/stats.rs", "fn f() {}\n".into(), vec!["hot-coverage"]),
            // P2: the seeded violation — a HashMap-order-dependent result.
            (
                "rust/src/engines/x.rs",
                "fn f(m: &HashMap<u64, f64>, out: &mut Vec<f64>) {\n    \
                 for (_k, v) in m.iter() {\n        out.push(*v);\n    }\n}\n"
                    .into(),
                vec!["p2-hash-iter"],
            ),
            (
                "rust/src/engines/x.rs",
                "fn f(m: &HashMap<u64, f64>, out: &mut Vec<f64>) {\n    \
                 for (_k, v) in m.iter() {\n        out.push(*v);\n    }\n    \
                 out.sort_unstable_by(|a, b| a.total_cmp(b));\n}\n"
                    .into(),
                vec![],
            ),
            (
                "rust/src/engines/x.rs",
                "fn f(m: &HashMap<u64, f64>, out: &mut Vec<f64>) {\n    \
                 // order: gauge aggregation; result is order-insensitive.\n    \
                 for (_k, v) in m.iter() {\n        out.push(*v);\n    }\n}\n"
                    .into(),
                vec![],
            ),
            (
                "rust/src/engines/x.rs",
                "fn f(m: &BTreeMap<u64, f64>, out: &mut Vec<f64>) {\n    \
                 for (_k, v) in m.iter() {\n        out.push(*v);\n    }\n}\n"
                    .into(),
                vec![],
            ),
            (
                "rust/src/core/x.rs",
                "fn f(a: f64, b: f64, c: f64) -> f64 { a.mul_add(b, c) }\n".into(),
                vec!["p2-fma"],
            ),
            (
                "rust/src/core/x.rs",
                "// order: fused once, never mixed with the unfused path.\n\
                 fn f(a: f64, b: f64, c: f64) -> f64 { a.mul_add(b, c) }\n"
                    .into(),
                vec![],
            ),
            (
                "rust/src/core/x.rs",
                "fn f(pool: &RoundPool, xs: &[f64]) -> f64 { xs.iter().sum() }\n".into(),
                vec!["p2-float-reduce"],
            ),
            ("rust/src/core/x.rs", "fn f(xs: &[f64]) -> f64 { xs.iter().sum() }\n".into(), vec![]),
            (
                "rust/src/core/x.rs",
                "fn f(x: f64) -> f32 { x as f32 }\n".into(),
                vec!["p2-float-cast"],
            ),
            (
                "rust/src/core/x.rs",
                "// order: narrowed once at export; consumers compare f32 bits.\n\
                 fn f(x: f64) -> f32 { x as f32 }\n"
                    .into(),
                vec![],
            ),
            // P2 is scoped to result-bearing modules.
            ("rust/src/util/x.rs", "fn f(x: f64) -> f32 { x as f32 }\n".into(), vec![]),
            // P3: the seeded violation — a bare `let _ =` on a Result.
            (
                "rust/src/util/x.rs",
                "fn f() { let _ = std::fs::remove_file(\"x\"); }\n".into(),
                vec!["p3-let-drop"],
            ),
            (
                "rust/src/util/x.rs",
                "fn f() {\n    // ok-drop: best-effort cleanup; missing file is fine.\n    \
                 let _ = std::fs::remove_file(\"x\");\n}\n"
                    .into(),
                vec![],
            ),
            (
                "rust/src/util/x.rs",
                "#[cfg(test)]\nmod tests {\n    fn f() { let _ = std::fs::remove_file(\"x\"); }\n}\n"
                    .into(),
                vec![],
            ),
            (
                "rust/src/util/x.rs",
                "fn f(w: &mut impl Write) { w.flush().ok(); }\n".into(),
                vec!["p3-ok-discard"],
            ),
            (
                "rust/src/util/x.rs",
                "fn f(s: &str) { let x = s.parse::<u8>().ok(); }\n".into(),
                vec![],
            ),
        ]
    }

    #[test]
    fn fixture_suite_matches_python_mirror() {
        let mut failed = Vec::new();
        for (path, text, want) in fixtures() {
            let got = rules(path, &text);
            if got != want {
                failed.push(format!("{path}: want {want:?}, got {got:?}\n  text: {text:?}"));
            }
        }
        assert!(failed.is_empty(), "{}", failed.join("\n"));
    }

    #[test]
    fn window_bounds_are_enforced() {
        // A note PANIC_WINDOW+1 lines above the site no longer covers it.
        let pad = "    let y = 1;\n".repeat(PANIC_WINDOW + 1);
        let src = format!(
            "{HOT}fn f(t: &[f64], i: usize) -> f64 {{\n    \
             // panic-free: too far away.\n{pad}    t[i]\n}}\n"
        );
        assert_eq!(rules("rust/src/core/x.rs", &src), ["p1-index"]);
    }

    #[test]
    fn hot_marker_block_stops_at_blank_lines() {
        // A marker separated from the fn by a blank line does not attach.
        let src = "// hot-path: detached marker.\n\nfn f(t: &[f64], i: usize) -> f64 { t[i] }\n";
        assert!(rules("rust/src/core/x.rs", src).is_empty());
    }

    #[test]
    fn whole_tree_is_clean() {
        // The real gate: zero violations over the repo (mirrors
        // `ci.sh --analyze` / `scripts/analyze_invariants.py .`).
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let violations = run(root).expect("analyzer walks the repo");
        assert!(violations.is_empty(), "{}", violations.join("\n"));
    }
}
