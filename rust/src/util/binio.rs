//! Minimal binary (de)serialization for checkpoint files.
//!
//! serde/bincode are not available offline, so checkpoints use a tiny
//! hand-rolled little-endian codec: fixed-width integers, `f64` as raw
//! IEEE-754 bits (`to_bits`/`from_bits`, so round-trips are exact to
//! the bit, including NaN payloads and signed zeros), and
//! length-prefixed byte strings.  Every read is bounds-checked and
//! returns `Err` on truncation — a torn or corrupt checkpoint must be
//! rejected, never panic.
//!
//! Envelope convention (used by `coordinator::checkpoint` and
//! `MerlinSweep::snapshot`): an 8-byte magic, a `u32` format version,
//! the payload, then a trailing FNV-1a 64-bit checksum over everything
//! before it.  The checksum catches torn writes that survived the
//! atomic-rename discipline (e.g. a corrupted filesystem); the version
//! gates forward compatibility.
#![forbid(unsafe_code)]

use anyhow::{bail, Result};

/// FNV-1a 64-bit over a byte slice.  Matches the fingerprint family
/// already used by the engine seed cache (`engines::scratch`).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Append-only little-endian writer.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` travels as `u64` so the format is identical across
    /// pointer widths.
    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Raw IEEE-754 bits — exact round-trip, no text formatting.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_usize(v.len());
        self.buf.extend_from_slice(v);
    }

    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    pub fn put_f64s(&mut self, v: &[f64]) {
        self.put_usize(v.len());
        for &x in v {
            self.put_f64(x);
        }
    }

    pub fn put_opt_u64(&mut self, v: Option<u64>) {
        match v {
            Some(x) => {
                self.put_u8(1);
                self.put_u64(x);
            }
            None => self.put_u8(0),
        }
    }

    pub fn put_opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.put_u8(1);
                self.put_f64(x);
            }
            None => self.put_u8(0),
        }
    }
}

/// Bounds-checked little-endian reader over a borrowed slice.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("truncated checkpoint: need {n} bytes, have {}", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// `usize` from the wire `u64`, rejecting values that overflow the
    /// native width (only possible on 32-bit targets).
    pub fn get_usize(&mut self) -> Result<usize> {
        let v = self.get_u64()?;
        usize::try_from(v).map_err(|_| anyhow::anyhow!("length {v} overflows usize"))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("invalid bool byte {other}"),
        }
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_usize()?;
        // A corrupt length prefix must not trigger a huge allocation;
        // `take` bounds it against the remaining buffer first.
        self.take(n)
    }

    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        Ok(String::from_utf8(b.to_vec())?)
    }

    pub fn get_f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.get_usize()?;
        if n.saturating_mul(8) > self.remaining() {
            bail!("truncated checkpoint: f64 vector of {n} exceeds remaining bytes");
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_f64()?);
        }
        Ok(v)
    }

    pub fn get_opt_u64(&mut self) -> Result<Option<u64>> {
        Ok(if self.get_bool()? { Some(self.get_u64()?) } else { None })
    }

    pub fn get_opt_f64(&mut self) -> Result<Option<f64>> {
        Ok(if self.get_bool()? { Some(self.get_f64()?) } else { None })
    }

    /// All payload consumed?  Trailing garbage means a format mismatch.
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("checkpoint has {} trailing bytes", self.remaining());
        }
        Ok(())
    }
}

/// Wrap a payload in the standard envelope: magic, version, payload,
/// FNV-1a checksum of everything before the checksum.
pub fn seal(magic: &[u8; 8], version: u32, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(magic.len() + 4 + payload.len() + 8);
    out.extend_from_slice(magic);
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(payload);
    let sum = fnv1a64(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Verify an envelope and return its payload slice.
pub fn unseal<'a>(magic: &[u8; 8], version: u32, bytes: &'a [u8]) -> Result<&'a [u8]> {
    if bytes.len() < magic.len() + 4 + 8 {
        bail!("checkpoint too short ({} bytes)", bytes.len());
    }
    let (head, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(sum_bytes.try_into().expect("8-byte split"));
    let got = fnv1a64(head);
    if want != got {
        bail!("checkpoint checksum mismatch (stored {want:#018x}, computed {got:#018x})");
    }
    if &head[..8] != magic {
        bail!("checkpoint magic mismatch (expected {:?})", std::str::from_utf8(magic).unwrap_or("?"));
    }
    let ver = u32::from_le_bytes(head[8..12].try_into().expect("4-byte slice"));
    if ver != version {
        bail!("checkpoint format version {ver} unsupported (expected {version})");
    }
    Ok(&head[12..])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_round_trips() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX);
        w.put_usize(12345);
        w.put_f64(-0.0);
        w.put_f64(f64::NAN);
        w.put_bool(true);
        w.put_str("hello ✓");
        w.put_f64s(&[1.5, -2.25, 1e-300]);
        w.put_opt_u64(None);
        w.put_opt_u64(Some(9));
        w.put_opt_f64(Some(3.125));
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_usize().unwrap(), 12345);
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.get_f64().unwrap().is_nan());
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_str().unwrap(), "hello ✓");
        assert_eq!(r.get_f64s().unwrap(), vec![1.5, -2.25, 1e-300]);
        assert_eq!(r.get_opt_u64().unwrap(), None);
        assert_eq!(r.get_opt_u64().unwrap(), Some(9));
        assert_eq!(r.get_opt_f64().unwrap(), Some(3.125));
        r.finish().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = ByteWriter::new();
        w.put_f64s(&[1.0, 2.0, 3.0]);
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            let mut r = ByteReader::new(&bytes[..cut]);
            assert!(r.get_f64s().is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn corrupt_length_prefix_rejected() {
        let mut w = ByteWriter::new();
        w.put_bytes(b"abc");
        let mut bytes = w.into_bytes();
        // Inflate the length prefix far beyond the buffer.
        bytes[0] = 0xFF;
        bytes[1] = 0xFF;
        let mut r = ByteReader::new(&bytes);
        assert!(r.get_bytes().is_err());
    }

    #[test]
    fn envelope_rejects_tampering() {
        let sealed = seal(b"PALMTEST", 3, b"payload-bytes");
        assert_eq!(unseal(b"PALMTEST", 3, &sealed).unwrap(), b"payload-bytes");
        // Flip one payload byte: checksum catches it.
        let mut bad = sealed.clone();
        bad[14] ^= 0x40;
        assert!(unseal(b"PALMTEST", 3, &bad).is_err());
        // Truncate: too-short error.
        assert!(unseal(b"PALMTEST", 3, &sealed[..10]).is_err());
        // Wrong version (re-sealed so the checksum is valid).
        let other = seal(b"PALMTEST", 4, b"payload-bytes");
        assert!(unseal(b"PALMTEST", 3, &other).is_err());
        // Wrong magic (valid checksum).
        let other = seal(b"PALMWHAT", 3, b"payload-bytes");
        assert!(unseal(b"PALMTEST", 3, &other).is_err());
    }
}
