//! Minimal declarative CLI parser (clap replacement).
//!
//! Supports `program <subcommand> --flag value --switch` with typed
//! accessors, defaults, and generated help text.  Only what the `palmad`
//! binary and the bench harnesses need.
#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// Declared option.
#[derive(Clone, Debug)]
struct Opt {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_switch: bool,
}

/// Declarative command spec: name, help, options.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub help: &'static str,
    opts: Vec<Opt>,
}

impl Command {
    pub fn new(name: &'static str, help: &'static str) -> Self {
        Self { name, help, opts: Vec::new() }
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: Some(default.to_string()), is_switch: false });
        self
    }

    /// `--name <value>`, required.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_switch: false });
        self
    }

    /// Boolean `--name` switch (defaults to false).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_switch: true });
        self
    }

    fn usage(&self) -> String {
        let mut s = String::new();
        // ok-drop: fmt::Write into String cannot fail (also the per-option
        // line below).
        let _ = writeln!(s, "  {} — {}", self.name, self.help);
        for o in &self.opts {
            let kind = if o.is_switch {
                "(switch)".to_string()
            } else {
                match &o.default {
                    Some(d) => format!("(default: {d})"),
                    None => "(required)".to_string(),
                }
            };
            // ok-drop: infallible String write (see above).
            let _ = writeln!(s, "      --{:<18} {} {}", o.name, o.help, kind);
        }
        s
    }
}

/// Parsed arguments for one command.
#[derive(Clone, Debug)]
pub struct Args {
    values: BTreeMap<&'static str, String>,
    switches: BTreeMap<&'static str, bool>,
}

impl Args {
    pub fn get(&self, name: &str) -> Result<&str> {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)?.parse().with_context(|| format!("--{name} expects an integer"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name)?.parse().with_context(|| format!("--{name} expects an integer"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)?.parse().with_context(|| format!("--{name} expects a number"))
    }

    pub fn get_switch(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }

    /// Option that may be absent (declared with default "" meaning unset).
    pub fn get_opt(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str()).filter(|s| !s.is_empty())
    }
}

/// Top-level parser: a set of commands.
#[derive(Default)]
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    commands: Vec<Command>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Self { program, about, commands: Vec::new() }
    }

    pub fn command(mut self, c: Command) -> Self {
        self.commands.push(c);
        self
    }

    pub fn help(&self) -> String {
        let mut s = String::new();
        // ok-drop: fmt::Write into String cannot fail (both lines).
        let _ = writeln!(s, "{} — {}\n", self.program, self.about);
        let _ = writeln!(s, "USAGE: {} <command> [--opt value ...]\n\nCOMMANDS:", self.program);
        for c in &self.commands {
            s.push_str(&c.usage());
        }
        s
    }

    /// Parse `argv[1..]`.  Returns the command name and its parsed args.
    pub fn parse(&self, argv: &[String]) -> Result<(&'static str, Args)> {
        let Some(cmd_name) = argv.first() else {
            bail!("no command given\n\n{}", self.help());
        };
        if cmd_name == "help" || cmd_name == "--help" || cmd_name == "-h" {
            bail!("{}", self.help());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| anyhow!("unknown command {cmd_name:?}\n\n{}", self.help()))?;

        let mut values = BTreeMap::new();
        let mut switches = BTreeMap::new();
        for o in &cmd.opts {
            if let Some(d) = &o.default {
                values.insert(o.name, d.clone());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let name = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --option, got {a:?}\n\n{}", cmd.usage()))?;
            let opt = cmd
                .opts
                .iter()
                .find(|o| o.name == name)
                .ok_or_else(|| anyhow!("unknown option --{name}\n\n{}", cmd.usage()))?;
            if opt.is_switch {
                switches.insert(opt.name, true);
                i += 1;
            } else {
                let v = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("--{name} expects a value"))?;
                values.insert(opt.name, v.clone());
                i += 2;
            }
        }
        for o in &cmd.opts {
            if !o.is_switch && !values.contains_key(o.name) {
                bail!("missing required option --{}\n\n{}", o.name, cmd.usage());
            }
        }
        Ok((cmd.name, Args { values, switches }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("palmad", "test").command(
            Command::new("run", "run discovery")
                .req("input", "series path")
                .opt("min-l", "64", "min length")
                .switch("verbose", "chatty"),
        )
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_defaults_switches() {
        let (cmd, args) =
            cli().parse(&argv(&["run", "--input", "x.txt", "--verbose"])).unwrap();
        assert_eq!(cmd, "run");
        assert_eq!(args.get("input").unwrap(), "x.txt");
        assert_eq!(args.get_usize("min-l").unwrap(), 64);
        assert!(args.get_switch("verbose"));
    }

    #[test]
    fn override_default() {
        let (_, args) =
            cli().parse(&argv(&["run", "--input", "x", "--min-l", "128"])).unwrap();
        assert_eq!(args.get_usize("min-l").unwrap(), 128);
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse(&argv(&["run"])).is_err());
    }

    #[test]
    fn unknown_command_and_option() {
        assert!(cli().parse(&argv(&["nope"])).is_err());
        assert!(cli().parse(&argv(&["run", "--input", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn help_lists_commands() {
        let h = cli().help();
        assert!(h.contains("run"));
        assert!(h.contains("--input"));
    }
}
