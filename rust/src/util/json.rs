//! Minimal JSON *writer* (serde replacement for report output).
//!
//! The repo emits machine-readable experiment reports (bench rows, discord
//! lists) as JSON for downstream plotting; inputs use line-oriented
//! formats, so only serialization is needed.
#![forbid(unsafe_code)]

use std::fmt::Write;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    /// Insert into an object (panics on non-objects — programmer error).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Self {
        match &mut self {
            Json::Obj(pairs) => pairs.push((key.to_string(), value.into())),
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                // ok-drop: fmt::Write into String cannot fail.
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    // ok-drop: infallible String write.
                    let _ = write!(out, "{x}");
                } else {
                    // JSON has no inf/nan; report as null.
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            // ok-drop: infallible String write.
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Int(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Int(v as i64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::from(true).to_string(), "true");
        assert_eq!(Json::from(1.5).to_string(), "1.5");
        assert_eq!(Json::from(42usize).to_string(), "42");
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn string_escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::from("\u{1}").to_string(), "\"\\u0001\"");
    }

    #[test]
    fn nested() {
        let j = Json::obj()
            .set("name", "ecg")
            .set("n", 45000usize)
            .set("times", vec![1.0, 2.5])
            .set("inner", Json::obj().set("ok", true));
        assert_eq!(
            j.to_string(),
            r#"{"name":"ecg","n":45000,"times":[1,2.5],"inner":{"ok":true}}"#
        );
    }
}
