//! Repo-invariant lint (the `palmad-lint` binary's engine).
//!
//! Enforces the source-level concurrency/unsafety invariants documented
//! in `CONCURRENCY.md` §"Invariants enforced by palmad-lint":
//!
//! 1. every `unsafe` block/fn/impl carries a `// SAFETY:` comment (or a
//!    `# Safety` doc section) within the preceding [`SAFETY_WINDOW`]
//!    lines;
//! 2. `transmute` appears only in allowlisted files (today: the
//!    scoped-job lifetime erasure in `util/pool.rs`);
//! 3. every atomic operation in non-test library code maps to a row of
//!    the CONCURRENCY.md audit table — with its `Ordering` listed there
//!    — or carries an inline `// ordering:` comment; `Relaxed` is
//!    rejected on atomics whose row marks them as publication flags;
//! 4. no direct `.lock()` in `coordinator/` (poison-recovering helpers
//!    in `util::sync` only);
//! 5. no `.unwrap()` in non-test library code outside allowlisted files
//!    (`expect("...")` with the invariant spelled out is the sanctioned
//!    alternative).
//!
//! The lint is a *textual* scanner, not a parser: comments and string
//! literal contents are blanked before token rules run, and an atomic
//! call site is recognised by an `Ordering::` argument inside its own
//! balanced parens (so `Vec::swap` or a neighbouring statement's
//! ordering never confuses it).  That keeps the implementation portable
//! enough to mirror in `scripts/lint_invariants.py`, which runs the
//! identical rules on machines with no Rust toolchain; the fixtures in
//! this module's tests and in the script's `--self-test` are the same
//! inputs with the same expected hits, keeping the two honest.
#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Roots scanned relative to the repo root (`vendor/` is deliberately
/// absent: the loom checker is test-only infrastructure with its own
/// suite, never compiled into production builds).
pub const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "examples"];

/// Files allowed to contain `transmute` (see `erase_job_lifetime`).
const TRANSMUTE_ALLOWLIST: &[&str] = &["rust/src/util/pool.rs"];

/// Files allowed to call `.unwrap()` outside test code: the round-pool
/// worker-side lock unwraps propagate poison deliberately (a panicked
/// round must not present half-written results as clean).
const UNWRAP_ALLOWLIST: &[&str] = &["rust/src/util/pool.rs"];

/// How many lines above an `unsafe` token a SAFETY comment may sit.
const SAFETY_WINDOW: usize = 12;

/// How many lines above an atomic op an `// ordering:` note may sit.
const ORDERING_WINDOW: usize = 8;

const ATOMIC_METHODS: &[&str] = &[
    "load",
    "store",
    "swap",
    "fetch_add",
    "fetch_sub",
    "fetch_and",
    "fetch_or",
    "fetch_xor",
    "fetch_max",
    "fetch_min",
    "fetch_update",
    "compare_exchange_weak",
    "compare_exchange",
];

/// One row of the CONCURRENCY.md audit table, keyed by (file, atomic).
pub struct AuditRow {
    orderings: Vec<String>,
    publication: bool,
}

/// The parsed audit table: `(file, atomic name)` → row.
pub type AuditTable = HashMap<(String, String), AuditRow>;

fn is_word(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Whole-word containment (`unsafe` matches, `unsafe_code` does not).
fn has_word(s: &str, w: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = s[start..].find(w) {
        let at = start + pos;
        let before_ok = at == 0 || !s[..at].chars().next_back().is_some_and(is_word);
        let after = at + w.len();
        let after_ok = after >= s.len() || !s[after..].chars().next().is_some_and(is_word);
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

/// Split source into (code, comments) per line: `code[i]` has comments
/// and string/char-literal contents blanked (quotes kept, non-ASCII
/// mapped to spaces), `comments[i]` holds line `i`'s comment text.
pub fn strip_rust(text: &str) -> (Vec<String>, Vec<String>) {
    enum St {
        Normal,
        Line,
        Block(u32),
        Str,
        RawStr(usize),
    }
    let chars: Vec<char> = text.chars().collect();
    let (mut code, mut comments) = (Vec::new(), Vec::new());
    let (mut cur_code, mut cur_comment) = (String::new(), String::new());
    let mut st = St::Normal;
    let mut i = 0;
    let at = |k: usize| chars.get(k).copied();
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(st, St::Line) {
                st = St::Normal;
            }
            code.push(std::mem::take(&mut cur_code));
            comments.push(std::mem::take(&mut cur_comment));
            i += 1;
            continue;
        }
        match st {
            St::Line => {
                cur_comment.push(c);
                i += 1;
            }
            St::Block(depth) => {
                if c == '/' && at(i + 1) == Some('*') {
                    st = St::Block(depth + 1);
                    cur_comment.push_str("/*");
                    i += 2;
                } else if c == '*' && at(i + 1) == Some('/') {
                    cur_comment.push_str("*/");
                    st = if depth == 1 { St::Normal } else { St::Block(depth - 1) };
                    i += 2;
                } else {
                    cur_comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    cur_code.push('"');
                    st = St::Normal;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' && (0..hashes).all(|k| at(i + 1 + k) == Some('#')) {
                    cur_code.push('"');
                    st = St::Normal;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
            St::Normal => {
                let prev_word = i > 0 && is_word(chars[i - 1]);
                if c == '/' && at(i + 1) == Some('/') {
                    st = St::Line;
                    cur_comment.push_str("//");
                    i += 2;
                } else if c == '/' && at(i + 1) == Some('*') {
                    st = St::Block(1);
                    cur_comment.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    cur_code.push('"');
                    st = St::Str;
                    i += 1;
                } else if !prev_word && (c == 'r' || (c == 'b' && at(i + 1) == Some('r'))) {
                    // Possible raw string: [b]r#*"
                    let mut j = i + if c == 'b' { 2 } else { 1 };
                    let mut hashes = 0;
                    while at(j) == Some('#') {
                        hashes += 1;
                        j += 1;
                    }
                    if at(j) == Some('"') {
                        cur_code.push_str("r\"");
                        st = St::RawStr(hashes);
                        i = j + 1;
                    } else {
                        cur_code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime tick.
                    if at(i + 1) == Some('\\') {
                        let mut j = i + 2;
                        while j < chars.len() && chars[j] != '\'' && j < i + 12 {
                            j += 1;
                        }
                        cur_code.push_str("''");
                        i = j + 1;
                    } else if at(i + 2) == Some('\'') && at(i + 1) != Some('\\') {
                        cur_code.push_str("''");
                        i += 3;
                    } else {
                        cur_code.push(c); // lifetime
                        i += 1;
                    }
                } else {
                    cur_code.push(if c.is_ascii() { c } else { ' ' });
                    i += 1;
                }
            }
        }
    }
    code.push(cur_code);
    comments.push(cur_comment);
    (code, comments)
}

/// First line index of the `#[cfg(test)] mod tests` tail (or `len`).
pub(crate) fn test_region_start(code: &[String]) -> usize {
    for (i, line) in code.iter().enumerate() {
        if line.trim() != "#[cfg(test)]" {
            continue;
        }
        for next in code.iter().take((i + 4).min(code.len())).skip(i + 1) {
            let t = next.trim().strip_prefix("pub ").unwrap_or(next.trim());
            if let Some(rest) = t.strip_prefix("mod tests") {
                if !rest.chars().next().is_some_and(is_word) {
                    return i;
                }
            }
        }
    }
    code.len()
}

/// Parse CONCURRENCY.md's audit table; also returns table self-check
/// violations (publication=yes rows listing Relaxed).
pub fn parse_audit_table(md: &str) -> (AuditTable, Vec<String>) {
    let mut table = AuditTable::new();
    let mut errors = Vec::new();
    for (idx, raw) in md.lines().enumerate() {
        let line = raw.trim();
        if !line.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> =
            line.trim_matches('|').split('|').map(str::trim).collect();
        if cells.len() < 6
            || cells[0] == "File"
            || cells[0].is_empty()
            || cells[0].chars().all(|c| c == '-' || c == ' ')
        {
            continue;
        }
        let (path, names, orderings, publication) = (cells[0], cells[1], cells[3], cells[4]);
        let publication = publication.to_ascii_lowercase().starts_with("yes");
        let ords: Vec<String> = orderings
            .split(|c: char| !c.is_ascii_alphanumeric())
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
        if publication && ords.iter().any(|o| o == "Relaxed") {
            errors.push(format!(
                "CONCURRENCY.md:{}: [relaxed-publication] row '{}' is \
                 publication=yes but lists Relaxed",
                idx + 1,
                names
            ));
        }
        for name in names.split(',') {
            table.insert(
                (path.to_string(), name.trim().to_string()),
                AuditRow { orderings: ords.clone(), publication },
            );
        }
    }
    (table, errors)
}

pub(crate) fn has_comment(comments: &[String], upto: usize, window: usize, needles: &[&str]) -> bool {
    let lo = upto.saturating_sub(window);
    comments[lo..=upto].iter().any(|l| needles.iter().any(|n| l.contains(n)))
}

/// One atomic call site found on a code line.
struct AtomicSite {
    receiver: Option<String>,
    method: String,
    /// Index just past the method's opening paren, within the line.
    args_from: usize,
}

/// Trailing `ident` or `ident[...]` of a code line, if any.
fn trailing_receiver(line: &str) -> Option<String> {
    let t = line.trim_end();
    let t = if t.ends_with(']') {
        let mut depth = 0usize;
        let mut cut = None;
        for (k, c) in t.char_indices().rev() {
            match c {
                ']' => depth += 1,
                '[' => {
                    depth -= 1;
                    if depth == 0 {
                        cut = Some(k);
                        break;
                    }
                }
                _ => {}
            }
        }
        &t[..cut?]
    } else {
        t
    };
    let t = t.trim_end();
    let start = t
        .char_indices()
        .rev()
        .take_while(|(_, c)| is_word(*c))
        .last()
        .map(|(k, _)| k)?;
    let ident = &t[start..];
    let first = ident.chars().next()?;
    if first.is_ascii_digit() {
        return None;
    }
    Some(ident.to_string())
}

/// Scan a code line for `.method(` occurrences of the atomic methods,
/// resolving the receiver (possibly indexed, possibly on an earlier
/// line via `prev_lines`).
fn atomic_sites(line: &str, prev_lines: &[String]) -> Vec<AtomicSite> {
    let mut sites = Vec::new();
    let bytes = line.as_bytes();
    for dot in 0..bytes.len() {
        if bytes[dot] != b'.' {
            continue;
        }
        let mut j = dot + 1;
        while j < bytes.len() && (bytes[j] as char).is_ascii_whitespace() {
            j += 1;
        }
        let m0 = j;
        while j < bytes.len() && is_word(bytes[j] as char) {
            j += 1;
        }
        let method = &line[m0..j];
        if !ATOMIC_METHODS.contains(&method) {
            continue;
        }
        while j < bytes.len() && (bytes[j] as char).is_ascii_whitespace() {
            j += 1;
        }
        if j >= bytes.len() || bytes[j] != b'(' {
            continue;
        }
        let receiver = trailing_receiver(&line[..dot]).or_else(|| {
            if line[..dot].trim().is_empty() {
                prev_lines
                    .iter()
                    .rev()
                    .take(3)
                    .find_map(|p| trailing_receiver(p))
            } else {
                None
            }
        });
        sites.push(AtomicSite { receiver, method: method.to_string(), args_from: j + 1 });
    }
    sites
}

/// `Ordering::X` variants inside the balanced-paren argument list that
/// starts just before `window[from..]` (the caller strips up to and
/// including the opening paren).
fn orderings_in_args(window: &str, from: usize) -> Vec<String> {
    let mut depth = 1i32;
    let mut end = window.len();
    for (k, c) in window[from..].char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    end = from + k;
                    break;
                }
            }
            _ => {}
        }
    }
    let args = &window[from..end];
    let mut out = Vec::new();
    let mut start = 0;
    while let Some(pos) = args[start..].find("Ordering::") {
        let at = start + pos + "Ordering::".len();
        let name: String = args[at..].chars().take_while(|c| c.is_ascii_alphabetic()).collect();
        if !name.is_empty() && !out.contains(&name) {
            out.push(name);
        }
        start = at;
    }
    out
}

/// Lint one file's source text; returns `path:line: [rule] msg` lines.
pub fn scan_file(relpath: &str, text: &str, table: &AuditTable) -> Vec<String> {
    let mut out = Vec::new();
    let (code, comments) = strip_rust(text);
    let is_test_file = relpath.starts_with("rust/tests/") || relpath.starts_with("examples/");
    let tests_at = if is_test_file { 0 } else { test_region_start(&code) };
    let in_coordinator = relpath.starts_with("rust/src/coordinator/");

    for (i, line) in code.iter().enumerate() {
        let lineno = i + 1;
        let in_test = is_test_file || i >= tests_at;

        if has_word(line, "unsafe")
            && !has_comment(&comments, i, SAFETY_WINDOW, &["SAFETY:", "# Safety"])
        {
            out.push(format!(
                "{relpath}:{lineno}: [safety-comment] `unsafe` without a // SAFETY: \
                 comment (or /// # Safety section) in the preceding {SAFETY_WINDOW} lines"
            ));
        }

        if has_word(line, "transmute") && !TRANSMUTE_ALLOWLIST.contains(&relpath) {
            out.push(format!(
                "{relpath}:{lineno}: [transmute-allowlist] transmute outside {TRANSMUTE_ALLOWLIST:?}"
            ));
        }

        if in_test {
            continue;
        }

        if in_coordinator && line.contains(".lock()") {
            out.push(format!(
                "{relpath}:{lineno}: [coordinator-lock] direct .lock() in coordinator/ \
                 (use util::sync::{{lock_recover, wait_recover}})"
            ));
        }

        if line.contains(".unwrap()") && !UNWRAP_ALLOWLIST.contains(&relpath) {
            out.push(format!(
                "{relpath}:{lineno}: [unwrap-allowlist] .unwrap() outside allowlisted \
                 files (use expect(\"...\") with the invariant)"
            ));
        }

        for site in atomic_sites(line, &code[i.saturating_sub(3)..i]) {
            let mut window = line.clone();
            for extra in code.iter().take((i + 4).min(code.len())).skip(i + 1) {
                window.push(' ');
                window.push_str(extra);
            }
            let ords = orderings_in_args(&window, site.args_from);
            if ords.is_empty() {
                continue; // not an atomic op (Vec::swap, etc.)
            }
            let key = site
                .receiver
                .as_ref()
                .map(|r| (relpath.to_string(), r.clone()));
            match key.and_then(|k| table.get(&k)) {
                Some(row) => {
                    for o in &ords {
                        if !row.orderings.contains(o) {
                            out.push(format!(
                                "{relpath}:{lineno}: [atomic-ordering] {}.{} uses \
                                 Ordering::{o}, not listed in its CONCURRENCY.md row",
                                site.receiver.as_deref().unwrap_or("?"),
                                site.method
                            ));
                        }
                    }
                    if row.publication && ords.iter().any(|o| o == "Relaxed") {
                        out.push(format!(
                            "{relpath}:{lineno}: [relaxed-publication] Relaxed on \
                             publication flag `{}`",
                            site.receiver.as_deref().unwrap_or("?")
                        ));
                    }
                }
                None => {
                    if !has_comment(&comments, i, ORDERING_WINDOW, &["ordering:"]) {
                        out.push(format!(
                            "{relpath}:{lineno}: [atomic-audited] atomic op on `{}` has no \
                             CONCURRENCY.md row and no inline `// ordering:` comment",
                            site.receiver.as_deref().unwrap_or("?")
                        ));
                    }
                }
            }
        }
    }
    out
}

fn walk(dir: &Path, root: &Path, table: &AuditTable, out: &mut Vec<String>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .map(|e| e.map(|e| e.path()))
        .collect::<Result<_, _>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, root, table, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            let text = std::fs::read_to_string(&p)?;
            out.extend(scan_file(&rel, &text, table));
        }
    }
    Ok(())
}

/// Lint the whole repo rooted at `root`; returns all violations.
pub fn run(root: &Path) -> std::io::Result<Vec<String>> {
    let md = std::fs::read_to_string(root.join("CONCURRENCY.md"))?;
    let (table, mut violations) = parse_audit_table(&md);
    for sr in SCAN_ROOTS {
        let top = root.join(sr);
        if top.is_dir() {
            walk(&top, root, &table, &mut violations)?;
        }
    }
    Ok(violations)
}

// The fixtures below are duplicated (same inputs, same expected rule
// ids) in scripts/lint_invariants.py `--self-test`; change both
// together.
#[cfg(test)]
mod tests {
    use super::*;

    fn rules(relpath: &str, text: &str, table_md: &str) -> Vec<String> {
        let (table, errs) = parse_audit_table(table_md);
        assert!(errs.is_empty(), "{errs:?}");
        scan_file(relpath, text, &table)
            .iter()
            .map(|v| v.split('[').nth(1).unwrap().split(']').next().unwrap().to_string())
            .collect()
    }

    const TABLE: &str = "| rust/src/audited.rs | good | store | Release | yes | fixture |\n";

    #[test]
    fn undocumented_unsafe_is_flagged() {
        assert_eq!(rules("rust/src/x.rs", "fn f() { unsafe { g(); } }\n", ""), ["safety-comment"]);
    }

    #[test]
    fn documented_unsafe_passes() {
        let src = "// SAFETY: g has no preconditions.\nfn f() { unsafe { g(); } }\n";
        assert!(rules("rust/src/x.rs", src, "").is_empty());
    }

    #[test]
    fn strings_are_blanked() {
        assert!(rules("rust/src/x.rs", "fn f() { let s = \"unsafe transmute\"; }\n", "")
            .is_empty());
    }

    #[test]
    fn transmute_outside_allowlist_is_flagged() {
        let src = "fn f() { core::mem::transmute::<u8, i8>(0) }\n";
        assert_eq!(rules("rust/src/x.rs", src, ""), ["transmute-allowlist"]);
    }

    #[test]
    fn transmute_in_pool_with_safety_passes() {
        let src = "// SAFETY: ok.\nunsafe { transmute::<u8, i8>(0) }\n";
        assert!(rules("rust/src/util/pool.rs", src, "").is_empty());
    }

    #[test]
    fn direct_lock_in_coordinator_is_flagged() {
        let src = "fn f(m: &Mutex<u8>) { let _ = m.lock(); }\n";
        assert_eq!(rules("rust/src/coordinator/x.rs", src, ""), ["coordinator-lock"]);
    }

    #[test]
    fn test_module_is_exempt_from_lock_rule() {
        let src = "#[cfg(test)]\nmod tests {\n  fn f(m: &Mutex<u8>) { let _ = m.lock(); }\n}\n";
        assert!(rules("rust/src/coordinator/x.rs", src, "").is_empty());
    }

    #[test]
    fn unwrap_outside_allowlist_is_flagged() {
        let src = "fn f() { None::<u8>.unwrap(); }\n";
        assert_eq!(rules("rust/src/x.rs", src, ""), ["unwrap-allowlist"]);
        assert!(rules("examples/x.rs", src, "").is_empty());
    }

    #[test]
    fn unannotated_atomic_is_flagged() {
        let src = "fn f(a: &A) { a.flag.store(true, Ordering::SeqCst); }\n";
        assert_eq!(rules("rust/src/x.rs", src, ""), ["atomic-audited"]);
    }

    #[test]
    fn inline_ordering_comment_passes() {
        let src = "fn f(a: &A) {\n  // ordering: SeqCst because fixture.\n  \
                   a.flag.store(true, Ordering::SeqCst);\n}\n";
        assert!(rules("rust/src/x.rs", src, "").is_empty());
    }

    #[test]
    fn vec_swap_is_not_an_atomic() {
        assert!(rules("rust/src/x.rs", "fn f(v: &mut Vec<u8>) { v.swap(0, 1); }\n", "")
            .is_empty());
    }

    #[test]
    fn audited_atomic_passes_and_relaxed_on_publication_fails() {
        let ok = "fn f(a: &A) { a.good.store(true, Ordering::Release); }\n";
        assert!(rules("rust/src/audited.rs", ok, TABLE).is_empty());
        let bad = "fn f(a: &A) { a.good.store(true, Ordering::Relaxed); }\n";
        assert_eq!(
            rules("rust/src/audited.rs", bad, TABLE),
            ["atomic-ordering", "relaxed-publication"]
        );
    }

    #[test]
    fn publication_row_listing_relaxed_is_rejected() {
        let (_, errs) =
            parse_audit_table("| rust/src/y.rs | f | store | Relaxed | yes | bad |\n");
        assert_eq!(errs.len(), 1);
    }

    #[test]
    fn neighbouring_statement_ordering_does_not_bleed() {
        // Receiver `a` has no row: the `Ordering::` inside *its own*
        // parens decides, not the next statement's.
        let src = "fn f(v: &mut Vec<u8>, a: &A) {\n    v.swap(0, 1);\n    \
                   a.flag.store(true, Ordering::SeqCst);\n}\n";
        assert_eq!(rules("rust/src/x.rs", src, ""), ["atomic-audited"]);
    }

    #[test]
    fn multiline_receiver_resolves() {
        let src = "fn f(a: &A) {\n    a.counters.really_long_name\n        \
                   .fetch_add(1, Ordering::Relaxed);\n}\n";
        assert_eq!(
            rules("rust/src/x.rs", src, ""),
            ["atomic-audited"],
            "receiver on the previous line must still be resolved"
        );
    }

    #[test]
    fn whole_tree_is_clean() {
        // The real gate: zero violations over the repo, using the
        // checked-in CONCURRENCY.md (mirrors `ci.sh --lint-invariants`).
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let violations = run(root).expect("lint walks the repo");
        assert!(violations.is_empty(), "{}", violations.join("\n"));
    }
}
