//! Tiny leveled logger (log/env_logger replacement).
//!
//! Level comes from `PALMAD_LOG` (`error|warn|info|debug|trace`, default
//! `info`); output goes to stderr with a monotonic timestamp so bench runs
//! stay parseable.
#![forbid(unsafe_code)]

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let parsed = std::env::var("PALMAD_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info) as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the level programmatically (tests, CLI `--verbose`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Is `l` currently enabled?
pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

/// Log a preformatted message (use the macros instead).
pub fn log(l: Level, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    eprintln!("[{t:10.3}s {}] {msg}", l.tag());
}

#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_trace { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::parse("bogus"), None);
    }
}
