//! Sync-primitive shim: `std::sync` in normal builds, the vendored
//! `loom` model checker under `--cfg palmad_loom`.
//!
//! The concurrency core (`util::pool`, `util::sync`, `engines::scratch`,
//! `engines::native`, `coordinator::lease`) imports its mutexes,
//! condvars, atomics, and thread-spawning through this module instead of
//! `std`, so the *production types themselves* — not hand-copied
//! sketches of them — are what `rust/tests/loom_models.rs` explores
//! under every bounded interleaving:
//!
//! ```text
//! RUSTFLAGS="--cfg palmad_loom" cargo test --test loom_models --release
//! ```
//!
//! (or `scripts/ci.sh --loom`).  In normal builds every re-export is a
//! zero-cost alias of the `std` item, so nothing changes for production
//! code.  Under `palmad_loom`, loom primitives only function inside a
//! `loom::model(..)` closure; the rest of the test suite is not built
//! under that cfg (the CI leg runs only `--test loom_models`).
//!
//! `std::sync::PoisonError`/`LockResult` are shared by both sides, so
//! poison-recovery code (`util::sync`) is identical under either cfg.
//!
//! What the model checker covers — and what it cannot — is documented in
//! `vendor/loom/src/lib.rs` and the per-atomic table in `CONCURRENCY.md`.
#![forbid(unsafe_code)]

#[cfg(not(palmad_loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, WaitTimeoutResult};

#[cfg(palmad_loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard, WaitTimeoutResult};

pub use std::sync::{LockResult, PoisonError};

pub mod atomic {
    #[cfg(not(palmad_loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};

    #[cfg(palmad_loom)]
    pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
}

pub mod thread {
    #[cfg(not(palmad_loom))]
    pub use std::thread::{spawn, yield_now, Builder, JoinHandle};

    #[cfg(palmad_loom)]
    pub use loom::thread::{spawn, yield_now, Builder, JoinHandle};
}
