//! Offline-environment substrates (DESIGN.md §3): the crates a project
//! would normally pull from crates.io (rayon/clap/serde/criterion) are not
//! available here, so minimal purpose-built replacements live in this
//! module tree.

pub mod analyze;
pub mod binio;
pub mod cli;
pub mod json;
pub mod lint;
pub mod logger;
pub mod loomsync;
pub mod pool;
pub mod rng;
pub mod sync;
