//! Thread-pool primitives (rayon replacement).
//!
//! Three entry points:
//!
//! - [`ThreadPool::run`] — execute a batch of independent boxed closures
//!   and wait for all of them (panics are propagated).
//! - [`parallel_map_indexed`] — "apply f to 0..n in parallel, collect
//!   results in order", the shape of every baseline sweep.  Results are
//!   written lock-free into disjoint slots; the old mutex-per-item
//!   collection is preserved as [`parallel_map_indexed_locked`] for the
//!   regression test and the bench baseline.
//! - [`RoundPool`] — a *persistent* worker pool for the native tile
//!   engine's steady-state loop: submitting a round performs **zero heap
//!   allocations** (no job boxing, no channel sends — a condvar broadcast
//!   plus an atomic work cursor), which `std::thread::scope` +
//!   per-job `Box` fundamentally cannot do.
//!
//! Work is always distributed by an atomic cursor (dynamic scheduling —
//! tile costs are skewed by early abandons), and writes go to disjoint
//! slots through [`SliceWriter`], so no ordering lock is ever taken on
//! the result path.
//!
//! Concurrency verification: [`RoundPool`] and [`SliceWriter`] take
//! their primitives from [`crate::util::loomsync`], so
//! `rust/tests/loom_models.rs` model-checks the round handoff and the
//! slot-publication protocol on the *production* types under
//! `--cfg palmad_loom` (see `CONCURRENCY.md` for the ordering audit).
//! [`ThreadPool`] stays on plain `std` + mpsc: it is the boxed-job
//! legacy pool, not part of the zero-alloc engine path, and mpsc is
//! outside the model checker's vocabulary.

use crate::util::loomsync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::loomsync::{thread as lthread, Arc, Condvar, Mutex};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed worker pool over an mpsc queue.
pub struct ThreadPool {
    tx: Sender<Msg>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let (tx, rx) = channel::<Msg>();
        // std (not loomsync) on purpose: see the module docs.
        let rx = std::sync::Arc::new(std::sync::Mutex::new(rx));
        let handles = (0..n)
            .map(|i| {
                let rx = std::sync::Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("palmad-pool-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx, handles }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Run all jobs, blocking until every one has finished.
    // hot-path: batch submission loop — one send per tile job, every sweep round.
    pub fn run(&self, jobs: Vec<Job>) {
        let (done_tx, done_rx) = channel();
        let n = jobs.len();
        for job in jobs {
            let done = done_tx.clone();
            self.tx
                .send(Msg::Run(Box::new(move || {
                    job();
                    // ok-drop: completion ping; recv side gone means `run`
                    // already bailed on a panic — nothing to report to.
                    let _ = done.send(());
                })))
                // panic-free: deliberate invariant report — workers only exit
                // on Shutdown, so a closed channel here is pool-teardown
                // misuse, not a data-path condition.
                .expect("pool send");
        }
        for _ in 0..n {
            // panic-free: deliberate propagation — a dropped `done_tx` means a
            // worker unwound mid-job; surfacing it beats hanging the caller.
            done_rx.recv().expect("pool worker panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            // ok-drop: send fails only if every worker already exited, which
            // is exactly the state shutdown is driving toward.
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            // ok-drop: join error = worker panicked; the panic was already
            // surfaced to the submitter by `run`, and Drop must not unwind.
            let _ = h.join();
        }
    }
}

/// Default parallelism: available cores, capped at 16 (the tile batches
/// are memory-bandwidth-bound; more threads stop helping well before 16).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Shared writer over **disjoint** slots of a mutable slice.
///
/// The work-distribution cursor hands every index to exactly one worker,
/// so slot writes never alias; this type just carries the pointer across
/// the thread boundary without a lock.
pub(crate) struct SliceWriter<T> {
    ptr: *mut T,
    len: usize,
    /// Model-checking only: per-slot claim flags so a protocol bug that
    /// hands the same index to two threads fails *deterministically*
    /// inside the loom models instead of silently double-dropping `T`.
    /// Gated on `palmad_loom` — NOT `debug_assertions` — because
    /// `SliceWriter::new` sits on the zero-steady-state-allocation path
    /// proven by `rust/tests/alloc_steady_state.rs`, which runs in debug
    /// builds; allocating a claim map there would break the proof.
    #[cfg(palmad_loom)]
    claimed: Vec<AtomicBool>,
}

// SAFETY: SliceWriter only moves `T` values across threads (each slot is
// written/borrowed by at most one thread at a time, enforced by the
// callers' index-claiming protocol), so `T: Send` suffices.  The loom
// models in rust/tests/loom_models.rs check the claiming protocol of
// both production callers (cursor fetch_add in `parallel_map_indexed` /
// `RoundPool::run`).
unsafe impl<T: Send> Send for SliceWriter<T> {}
unsafe impl<T: Send> Sync for SliceWriter<T> {}

impl<T> SliceWriter<T> {
    pub(crate) fn new(slice: &mut [T]) -> Self {
        Self {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            #[cfg(palmad_loom)]
            claimed: (0..slice.len()).map(|_| AtomicBool::new(false)).collect(),
        }
    }

    /// Model-checking guard: every slot index must be claimed by exactly
    /// one `write`/`slot` call per round.  A second claim is a protocol
    /// violation (two threads got the same index) and fails the model.
    #[cfg(palmad_loom)]
    fn claim_once(&self, i: usize) {
        assert!(
            !self.claimed[i].swap(true, Ordering::SeqCst),
            "SliceWriter slot {i} claimed twice — the index-distribution protocol aliased"
        );
    }

    /// Overwrite slot `i`.
    ///
    /// # Safety
    /// `i` must be claimed by exactly one thread (no concurrent access to
    /// the same slot), and the underlying slice must outlive the write.
    pub(crate) unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len, "SliceWriter write out of bounds: {i} >= {}", self.len);
        #[cfg(palmad_loom)]
        self.claim_once(i);
        // SAFETY: `i < len` (asserted above in debug builds, guaranteed
        // by the caller's claiming protocol in release), the slot is not
        // concurrently accessed (caller contract), and `ptr` outlives
        // `self` (caller contract on the backing slice).
        unsafe { *self.ptr.add(i) = value };
    }

    /// Exclusive reference to slot `i`.
    ///
    /// # Safety
    /// Same contract as [`SliceWriter::write`]: the caller must guarantee
    /// no other live reference to slot `i` exists for the borrow's life.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn slot(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len, "SliceWriter slot out of bounds: {i} >= {}", self.len);
        #[cfg(palmad_loom)]
        self.claim_once(i);
        // SAFETY: same argument as in `write` — in-bounds by the claiming
        // protocol, exclusivity and lifetime by the caller contract.
        unsafe { &mut *self.ptr.add(i) }
    }
}

/// Apply `f(i)` for `i in 0..n` across `threads` scoped workers; results
/// are returned in index order.  Work is distributed by an atomic cursor
/// (dynamic scheduling); each result is written lock-free into its own
/// slot — the former mutex-per-item critical section serialized workers
/// exactly when tiles finished close together (see
/// [`parallel_map_indexed_locked`], kept as the reference).
// hot-path: tile fan-out — one call per sweep round, one item per tile.
pub fn parallel_map_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return (0..n).map(&f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = SliceWriter::new(&mut out);
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let slots = &slots;
            let cursor = &cursor;
            let f = &f;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: the cursor hands out each index exactly once,
                // and `out` outlives the scope.
                unsafe { slots.write(i, Some(v)) };
            });
        }
    });
    // panic-free: deliberate invariant report — the cursor hands out every
    // index in 0..n exactly once and the scope joins all workers, so an
    // empty slot is a scheduler bug worth failing loudly on.
    out.into_iter().map(|v| v.expect("worker filled slot")).collect()
}

/// The pre-optimization collection strategy: a global `Mutex` around the
/// result vector, locked once per finished item.  Kept (unused by
/// production code) as the semantic reference for the regression test and
/// as the "before" side of the pool microbench.
pub fn parallel_map_indexed_locked<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return (0..n).map(&f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let cursor = AtomicUsize::new(0);
    let slots = Mutex::new(&mut out);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(v);
            });
        }
    });
    out.into_iter().map(|v| v.expect("worker filled slot")).collect()
}

/// One round's shared state (guarded by [`RoundShared::state`]).
struct RoundState {
    /// Round counter; workers wake when it moves past what they've seen.
    epoch: u64,
    /// Item count of the current round.
    n: usize,
    /// Erased pointer to the round's job closure.  Only valid while the
    /// round is in flight; cleared by `run` before it returns.
    job: Option<&'static (dyn Fn(usize) + Sync)>,
    /// Workers still executing the current round.
    active: usize,
    shutdown: bool,
}

struct RoundShared {
    state: Mutex<RoundState>,
    start: Condvar,
    done: Condvar,
    cursor: AtomicUsize,
    panicked: AtomicBool,
}

/// Persistent worker pool with allocation-free round submission.
///
/// Workers park on a condvar between rounds.  [`RoundPool::run`] installs
/// a lifetime-erased reference to the round closure, bumps the epoch,
/// broadcasts, participates in the round itself, then blocks until every
/// worker has drained the cursor — so the closure (and everything it
/// borrows) is guaranteed live for exactly the duration workers can see
/// it.  No `Box`, no channel message, no per-item lock.
pub struct RoundPool {
    shared: Arc<RoundShared>,
    /// Serializes concurrent submitters: the round protocol runs one
    /// round at a time (an engine shared across threads stays correct;
    /// rounds just queue up behind each other).
    submit: Mutex<()>,
    handles: Vec<lthread::JoinHandle<()>>,
}

/// Erase the lifetime of a round-job reference for storage in
/// [`RoundState::job`].
///
/// This is the **only** `transmute` in the codebase (enforced by
/// `palmad-lint`), and its soundness is a protocol property rather than
/// a type-system one:
///
/// - The erased reference is stored in `RoundState::job` under the state
///   lock, *after* the work cursor has been reset, and only by
///   [`RoundPool::run`] while it holds the `submit` lock.
/// - Workers dereference it only between observing the epoch bump (under
///   the same state lock) and decrementing `active`.
/// - `run` does not return until `active == 0` **and** it has cleared
///   the slot back to `None` — so every dereference happens within the
///   dynamic extent of `run`'s borrow of the closure.
///
/// The `round_pool_job_slot_cleared_after_round` unit test pins the
/// observable half of the invariant, and the RoundPool models in
/// `rust/tests/loom_models.rs` explore the handoff interleavings.
fn erase_job_lifetime(job: &(dyn Fn(usize) + Sync)) -> &'static (dyn Fn(usize) + Sync) {
    // SAFETY: see above — the round protocol contains every dereference
    // of the erased reference within the lifetime of the original.
    unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(job) }
}

impl RoundPool {
    /// Spawn `workers` persistent threads (0 is allowed: rounds then run
    /// entirely on the submitting thread).
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(RoundShared {
            state: Mutex::new(RoundState {
                epoch: 0,
                n: 0,
                job: None,
                active: 0,
                shutdown: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
            cursor: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = Arc::clone(&shared);
                lthread::Builder::new()
                    .name(format!("palmad-round-{w}"))
                    .spawn(move || worker_main(&shared))
                    .expect("spawn round-pool worker")
            })
            .collect();
        Self { shared, submit: Mutex::new(()), handles }
    }

    /// Worker-thread count (the submitter participates on top of this).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `f(i)` for every `i in 0..n` across the workers plus the
    /// calling thread; returns when all items are done.  Steady-state
    /// cost: one mutex broadcast in, one mutex wait out, zero allocations.
    // hot-path: round submission — every engine distance round funnels here.
    pub fn run<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        // Inline fast path: with no workers — or a single item, which
        // the submitting thread would claim anyway — the broadcast +
        // wait round protocol is pure overhead.  The streaming
        // monitor's small refresh rounds hit this constantly.
        if self.handles.is_empty() || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        // One round at a time; a poisoned lock (panicked round) is fine
        // to reuse — the protocol state is reset per round.
        let _round_guard = match self.submit.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        // Lifetime containment is the round protocol's core invariant;
        // see `erase_job_lifetime` for the argument.
        let job = erase_job_lifetime(&f);
        {
            // panic-free: deliberate poison propagation — state-lock holders
            // touch only plain counters; a panic under this lock is a pool
            // bug and every later round should fail loudly, not limp on.
            let mut st = self.shared.state.lock().unwrap();
            self.shared.cursor.store(0, Ordering::Relaxed);
            st.n = n;
            st.job = Some(job);
            st.active = self.handles.len();
            st.epoch += 1;
            self.shared.start.notify_all();
        }
        // The submitting thread pulls items too (a 1-thread engine never
        // pays a handoff).
        loop {
            let i = self.shared.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            run_item(&self.shared, job, i);
        }
        // panic-free: same deliberate poison propagation as the round-start
        // lock above; `wait` only errs on that same poisoned mutex.
        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        st.job = None;
        drop(st);
        if self.shared.panicked.swap(false, Ordering::SeqCst) {
            // panic-free: deliberate re-raise — run_item caught a worker
            // unwind to keep the round protocol consistent; the submitter
            // is the right thread to actually observe the failure.
            panic!("RoundPool worker panicked during round");
        }
    }

    /// Like [`RoundPool::run`], but workers claim `chunk` consecutive
    /// indices per cursor bump instead of one: `f` is still called once
    /// per index in `0..n`, each index by exactly one thread.  The right
    /// shape for rounds of many tiny items (e.g. the seed-prefetch row
    /// sweep: one multiply-add pass over a few hundred columns per item),
    /// where a per-item atomic claim would rival the item's work.
    // hot-path: chunked round submission for rounds of many tiny items.
    pub fn run_chunked<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let chunk = chunk.max(1);
        self.run(n.div_ceil(chunk), |c| {
            let lo = c * chunk;
            for i in lo..(lo + chunk).min(n) {
                f(i);
            }
        });
    }
}

// hot-path: per-item dispatch — wraps every round item in panic isolation.
fn run_item(shared: &RoundShared, job: &(dyn Fn(usize) + Sync), i: usize) {
    if catch_unwind(AssertUnwindSafe(|| job(i))).is_err() {
        shared.panicked.store(true, Ordering::SeqCst);
    }
}

// hot-path: worker park/claim loop — every worker round-trip per round.
fn worker_main(shared: &RoundShared) {
    let mut seen = 0u64;
    loop {
        let (job, n) = {
            // panic-free: deliberate poison propagation (see RoundPool::run);
            // `wait` errs only on the same poisoned state mutex.
            let mut st = shared.state.lock().unwrap();
            while !st.shutdown && st.epoch == seen {
                st = shared.start.wait(st).unwrap();
            }
            if st.shutdown {
                return;
            }
            seen = st.epoch;
            // panic-free: deliberate invariant report — `run` installs the
            // job before bumping the epoch under this same lock, so an empty
            // slot after an epoch move is a protocol bug.
            (st.job.expect("round job installed"), st.n)
        };
        loop {
            let i = shared.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            run_item(shared, job, i);
        }
        // panic-free: deliberate poison propagation, as at the claim above.
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_all();
        }
    }
}

impl Drop for RoundPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.start.notify_all();
        }
        for h in self.handles.drain(..) {
            // ok-drop: join error = worker panicked; already surfaced to the
            // submitting round by `run`, and Drop must not unwind.
            let _ = h.join();
        }
    }
}

/// Model-checking scenario bodies for `rust/tests/loom_models.rs`.
///
/// These live here (not in the test file) because they exercise the
/// crate-private [`SliceWriter`]; the integration test wraps each in
/// `loom::model(...)`, which explores every bounded interleaving of the
/// loom threads they spawn.  Compiled only under `--cfg palmad_loom`.
#[cfg(palmad_loom)]
pub mod loom_scenarios {
    use super::*;

    /// Two threads write disjoint slots through one `SliceWriter`: the
    /// claim map proves no slot is ever claimed twice, and the join
    /// publishes both writes back to the owning thread.
    pub fn slice_writer_disjoint_publication() {
        let mut out: Vec<u64> = vec![0; 2];
        let slots = Arc::new(SliceWriter::new(&mut out));
        let writer = {
            let slots = Arc::clone(&slots);
            // SAFETY: slot 0 is claimed only by this thread, slot 1 only
            // by the spawning thread, and `out` outlives the join below.
            lthread::spawn(move || unsafe { slots.write(0, 11) })
        };
        // SAFETY: slot 1 is claimed only by this thread (see above).
        unsafe { slots.write(1, 22) };
        writer.join().expect("writer thread completes");
        drop(slots);
        assert_eq!(out, [11, 22], "both writes must be visible after the join");
    }

    /// Aliased claims are a *detected* protocol violation: both threads
    /// write slot 0, and `claim_once` must fail the model.  The caller
    /// asserts the model panics — this pins the guard itself, so the
    /// disjointness proofs above cannot pass vacuously.
    pub fn slice_writer_aliased_claim() {
        let mut out: Vec<u64> = vec![0; 1];
        let slots = Arc::new(SliceWriter::new(&mut out));
        let writer = {
            let slots = Arc::clone(&slots);
            // SAFETY: deliberately violates the disjointness contract to
            // prove the loom claim guard catches it; both writes store a
            // plain u64 (no drop, no uninit read), so the only UB risk —
            // the data race — is exactly what the model serializes.
            lthread::spawn(move || unsafe { slots.write(0, 1) })
        };
        // SAFETY: see above — intentional aliasing under the model.
        unsafe { slots.write(0, 2) };
        // Propagate the child's claim failure if the child lost the race
        // (otherwise the write above already panicked): every schedule
        // must end in a panic for the caller's catch_unwind to observe.
        writer.join().expect("child claim must also have succeeded");
        drop(slots);
    }

    /// One worker plus the submitting thread drain a two-item round;
    /// every interleaving of the broadcast/claim/done protocol must run
    /// each item exactly once, and `Drop`'s shutdown handshake must join
    /// the worker without deadlock.
    pub fn round_pool_round_completes() {
        let pool = RoundPool::new(1);
        let counter = AtomicUsize::new(0);
        pool.run(2, |_| {
            // ordering: SeqCst — model-only completion counter; strongest
            // ordering since it exists purely to assert the protocol.
            counter.fetch_add(1, Ordering::SeqCst);
        });
        // ordering: SeqCst — read after the round barrier (see above).
        assert_eq!(counter.load(Ordering::SeqCst), 2, "each item runs exactly once per round");
    }

    /// The production slot-write pattern (`engines/scratch.rs`,
    /// `engines/native.rs`): a round writes disjoint `SliceWriter` slots
    /// via the cursor protocol.  The claim map rejects any interleaving
    /// where the cursor hands an index out twice, and `run`'s barrier
    /// must publish all slots before returning.
    pub fn round_pool_disjoint_slots() {
        let pool = RoundPool::new(1);
        let mut out: Vec<u64> = vec![0; 2];
        let slots = SliceWriter::new(&mut out);
        // SAFETY: the round cursor hands each index to exactly one
        // thread (checked by the claim map under this cfg), and `out`
        // outlives the round — `run` returns only after all items done.
        pool.run(2, |i| unsafe { slots.write(i, i as u64 + 1) });
        drop(pool);
        drop(slots);
        assert_eq!(out, [1, 2], "round results must be published by the done barrier");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let jobs: Vec<Job> = (0..100)
            .map(|i| {
                let c = Arc::clone(&counter);
                Box::new(move || {
                    c.fetch_add(i as u64, Ordering::Relaxed);
                }) as Job
            })
            .collect();
        pool.run(jobs);
        assert_eq!(counter.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn pool_reusable_across_batches() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..5 {
            let c = Arc::clone(&counter);
            pool.run(vec![Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            })]);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let got = parallel_map_indexed(1000, 8, |i| i * 2);
        assert_eq!(got, (0..1000).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_borrows_locals() {
        let data: Vec<f64> = (0..100).map(|x| x as f64).collect();
        let got = parallel_map_indexed(100, 4, |i| data[i] + 1.0);
        assert_eq!(got[99], 100.0);
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert!(parallel_map_indexed(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map_indexed(1, 4, |i| i + 7), vec![7]);
    }

    /// Contention regression: tiny items maximize pressure on the result
    /// path.  The lock-free writer must stay correct under it and agree
    /// with the mutex-collected reference exactly.  (Scaled down under
    /// Miri — the aliasing checks are per-access, not per-volume.)
    #[test]
    fn parallel_map_contention_regression() {
        let rounds = if cfg!(miri) { 2u64 } else { 5u64 };
        for round in 0..rounds {
            let n = if cfg!(miri) { 500 } else { 50_000 };
            let free = parallel_map_indexed(n, 8, |i| i as u64 ^ round);
            assert_eq!(free.len(), n);
            for (i, v) in free.iter().enumerate() {
                assert_eq!(*v, i as u64 ^ round, "slot {i} torn/misplaced");
            }
            let locked = parallel_map_indexed_locked(n, 8, |i| i as u64 ^ round);
            assert_eq!(free, locked, "lock-free diverged from mutex reference");
        }
    }

    /// Drop-heavy payloads through the lock-free path: every value must
    /// land intact (no double drops / leaks corrupting content).
    #[test]
    fn parallel_map_owned_payloads() {
        let got = parallel_map_indexed(500, 6, |i| vec![i; (i % 7) + 1]);
        for (i, v) in got.iter().enumerate() {
            assert_eq!(v.len(), (i % 7) + 1);
            assert!(v.iter().all(|&x| x == i));
        }
    }

    #[test]
    fn round_pool_runs_rounds_and_reuses_workers() {
        let (rounds, n) = if cfg!(miri) { (3u64, 100u64) } else { (10, 1000) };
        let pool = RoundPool::new(3);
        let counter = AtomicU64::new(0);
        for _ in 0..rounds {
            pool.run(n as usize, |i| {
                counter.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(counter.load(Ordering::Relaxed), rounds * (n * (n + 1) / 2));
    }

    #[test]
    fn round_pool_writes_disjoint_slots() {
        let n = if cfg!(miri) { 500 } else { 20_000 };
        let pool = RoundPool::new(4);
        let mut out = vec![0u64; n];
        let slots = SliceWriter::new(&mut out);
        pool.run(n, |i| {
            // SAFETY: cursor gives each index to exactly one thread.
            unsafe { slots.write(i, (i as u64).wrapping_mul(3) + 1) };
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i as u64).wrapping_mul(3) + 1);
        }
    }

    /// The observable half of the `erase_job_lifetime` invariant: once
    /// `run` returns, the job slot is cleared and no worker is active, so
    /// the lifetime-erased reference cannot be dereferenced again.
    #[test]
    fn round_pool_job_slot_cleared_after_round() {
        let pool = RoundPool::new(2);
        pool.run(8, |_| {});
        let st = pool.shared.state.lock().expect("round-pool state lock");
        assert!(st.job.is_none(), "job reference must not outlive its round");
        assert_eq!(st.active, 0, "no worker may still be inside the round");
    }

    #[test]
    fn round_pool_chunked_covers_every_index_once() {
        let pool = RoundPool::new(3);
        for (n, chunk) in [(0usize, 4usize), (1, 4), (7, 3), (1000, 8), (1000, 1), (5, 100)] {
            let mut out = vec![0u8; n];
            let slots = SliceWriter::new(&mut out);
            pool.run_chunked(n, chunk, |i| {
                // SAFETY: chunked cursor hands out each index exactly once.
                unsafe { *slots.slot(i) += 1 };
            });
            assert!(out.iter().all(|&c| c == 1), "n={n} chunk={chunk}: {out:?}");
        }
    }

    #[test]
    fn round_pool_zero_workers_runs_inline() {
        let pool = RoundPool::new(0);
        let counter = AtomicU64::new(0);
        pool.run(100, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn round_pool_empty_round_is_noop() {
        let pool = RoundPool::new(2);
        pool.run(0, |_| panic!("must not be called"));
    }

    #[test]
    fn round_pool_single_item_runs_inline() {
        let pool = RoundPool::new(2);
        let counter = AtomicU64::new(0);
        for _ in 0..50 {
            pool.run(1, |i| {
                assert_eq!(i, 0);
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(counter.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn round_pool_concurrent_submitters_serialize() {
        let (subs, rounds, n) = if cfg!(miri) { (2u64, 3u64, 50u64) } else { (4, 20, 500) };
        let pool = Arc::new(RoundPool::new(2));
        let total = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..subs)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                std::thread::spawn(move || {
                    for _ in 0..rounds {
                        pool.run(n as usize, |i| {
                            total.fetch_add(i as u64, Ordering::Relaxed);
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), subs * rounds * ((n - 1) * n / 2));
    }

    #[test]
    fn round_pool_propagates_worker_panic() {
        let pool = RoundPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(64, |i| {
                if i == 13 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the submitter");
        // The pool must stay usable after a panicked round.
        let counter = AtomicU64::new(0);
        pool.run(32, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 32);
    }
}
